"""Config schema for the framework.

Everything is a frozen dataclass so configs are hashable (usable as jit
static args) and serializable. One module per assigned architecture lives
next to this file; ``registry.py`` maps ``--arch <id>`` to a ModelConfig.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class MLAConfig:
    """Multi-head latent attention (MiniCPM3 / DeepSeek-V2 style)."""
    q_lora_rank: int = 768
    kv_lora_rank: int = 256
    qk_nope_head_dim: int = 64
    qk_rope_head_dim: int = 32
    v_head_dim: int = 64


@dataclass(frozen=True)
class AttentionConfig:
    kind: str = "gqa"            # 'gqa' | 'mla' | 'none'
    n_heads: int = 16
    n_kv_heads: int = 16
    head_dim: Optional[int] = None   # default d_model // n_heads
    qkv_bias: bool = False           # qwen-style
    window: Optional[int] = None     # sliding-window attention size
    rope_theta: float = 10_000.0
    mla: Optional[MLAConfig] = None
    causal: bool = True

    def resolved_head_dim(self, d_model: int) -> int:
        return self.head_dim if self.head_dim is not None else d_model // self.n_heads


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 8
    top_k: int = 2
    expert_ff: int = 1024
    capacity_factor: float = 1.25
    # Arctic-style: a dense FFN residual branch computed in parallel with MoE.
    dense_residual_ff: Optional[int] = None
    aux_loss_coef: float = 0.01
    router_dtype: str = "float32"


# ---------------------------------------------------------------------------
# Recurrent blocks (RG-LRU / RWKV)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class RGLRUConfig:
    """RecurrentGemma / Griffin recurrent block."""
    lru_width: Optional[int] = None   # default d_model
    conv_width: int = 4
    block_pattern: Tuple[str, ...] = ("rec", "rec", "attn")  # Griffin 2:1


@dataclass(frozen=True)
class RWKVConfig:
    head_dim: int = 64
    decay_lora: int = 64
    token_shift_lora: int = 32
    chunk_size: int = 128


# ---------------------------------------------------------------------------
# Model
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "decoder"      # decoder | encdec | hybrid | ssm | vlm | audio
    n_layers: int = 12
    d_model: int = 1024
    d_ff: int = 4096
    vocab_size: int = 32_000
    attention: AttentionConfig = field(default_factory=AttentionConfig)
    moe: Optional[MoEConfig] = None
    rglru: Optional[RGLRUConfig] = None
    rwkv: Optional[RWKVConfig] = None
    norm: str = "rmsnorm"        # rmsnorm | layernorm
    act: str = "swiglu"          # swiglu | geglu | gelu | relu
    tie_embeddings: bool = True
    dtype: str = "bfloat16"
    # Frontends (assignment: modality frontends are stubs providing embeds).
    frontend: Optional[str] = None   # None | 'vision' | 'audio'
    n_frontend_tokens: int = 0       # patches / frames prepended to the seq
    # Encoder-decoder split (seamless): n_layers counts each stack.
    enc_layers: int = 0
    dec_layers: int = 0
    # Cross-attention encoder memory length used by decode shapes.
    enc_memory_len: int = 3200
    # First k layers use a dense FFN even in MoE models.
    first_dense_layers: int = 0

    @property
    def is_encdec(self) -> bool:
        return self.family == "encdec"

    @property
    def attention_free(self) -> bool:
        return self.attention.kind == "none"

    @property
    def subquadratic(self) -> bool:
        """Can this arch decode at 500k context with bounded state?"""
        if self.attention_free or self.rwkv is not None:
            return True
        if self.rglru is not None:
            return True  # local attention window bounds the cache
        return self.attention.window is not None

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# DLRM (the paper's own model family, Table I)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class DLRMConfig:
    name: str = "dlrm"
    n_tables: int = 5
    rows_per_table: int = 200_000
    emb_dim: int = 32                 # paper default: 32-dim embeddings
    lookups_per_table: int = 20       # gathers per table ("M" in Fig. 2)
    dense_features: int = 13          # criteo-style continuous features
    bottom_mlp: Tuple[int, ...] = (512, 256, 32)
    top_mlp: Tuple[int, ...] = (512, 256, 1)
    dtype: str = "float32"
    # Heterogeneous tables (Centaur's workload characterization: vocab
    # sizes and access skew vary wildly per table). When set, each table
    # t owns a private (table_rows[t] + 1, table_dims[t]) arena served
    # through a TableGroupSource, a per-table projection lifts dim_t into
    # the shared interaction width `emb_dim`, and the synthetic trace
    # draws table t's ids from Zipf(table_alphas[t]). All three tuples
    # must have n_tables entries; rows_per_table/emb_dim keep their
    # uniform meaning only as the envelope (max) for bucket/spec sizing.
    table_rows: Optional[Tuple[int, ...]] = None
    table_dims: Optional[Tuple[int, ...]] = None
    table_alphas: Optional[Tuple[float, ...]] = None

    def __post_init__(self):
        for f in ("table_rows", "table_dims", "table_alphas"):
            v = getattr(self, f)
            assert v is None or len(v) == self.n_tables, \
                (f, len(v), self.n_tables)
        assert (self.table_rows is None) == (self.table_dims is None), \
            "heterogeneous configs set table_rows AND table_dims together"

    @property
    def heterogeneous(self) -> bool:
        return self.table_rows is not None

    @property
    def resolved_table_rows(self) -> Tuple[int, ...]:
        return (self.table_rows if self.table_rows is not None
                else (self.rows_per_table,) * self.n_tables)

    @property
    def resolved_table_dims(self) -> Tuple[int, ...]:
        return (self.table_dims if self.table_dims is not None
                else (self.emb_dim,) * self.n_tables)

    @property
    def table_bytes(self) -> int:
        if self.heterogeneous:
            return 4 * sum(r * d for r, d in zip(self.table_rows,
                                                 self.table_dims))
        return self.n_tables * self.rows_per_table * self.emb_dim * 4

    @property
    def n_interact_features(self) -> int:
        # reduced embedding per table + bottom-mlp output vector
        return self.n_tables + 1


# ---------------------------------------------------------------------------
# Shapes (assigned input-shape set)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                    # 'train' | 'prefill' | 'decode'


TRAIN_4K = ShapeConfig("train_4k", 4_096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524_288, 1, "decode")

LM_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES_BY_NAME = {s.name: s for s in LM_SHAPES}


def shape_applicable(model: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether a (arch, shape) cell runs; reason recorded in the dry-run."""
    if shape.name == "long_500k" and not model.subquadratic:
        return False, "skipped: pure full-attention arch (quadratic at 524k ctx)"
    return True, "ok"


# ---------------------------------------------------------------------------
# Training / runtime
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class OptimizerConfig:
    name: str = "adamw"          # sgd | adamw | adafactor
    lr: float = 3e-4
    weight_decay: float = 0.01
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    # row-wise adagrad for DLRM embedding tables (paper-standard)
    embedding_opt: str = "rowwise_adagrad"


@dataclass(frozen=True)
class RuntimeConfig:
    remat: bool = True                 # activation checkpointing on scan body
    grad_compression: Optional[str] = None   # None | 'int8'
    microbatches: int = 1
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    optimizer: OptimizerConfig = field(default_factory=OptimizerConfig)
