"""internvl2-2b [vlm] — InternViT + InternLM2 backbone. 24L d_model=2048 16H
(GQA kv=8) d_ff=8192 vocab=92553. [arXiv:2404.16821; hf]

Per the assignment, the vision frontend is a STUB: ``input_specs()`` provides
precomputed patch embeddings that are prepended to the token sequence.
"""
from repro.configs.base import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b",
    family="vlm",
    n_layers=24,
    d_model=2048,
    d_ff=8192,
    vocab_size=92_553,
    attention=AttentionConfig(kind="gqa", n_heads=16, n_kv_heads=8),
    act="swiglu",
    norm="rmsnorm",
    tie_embeddings=False,
    frontend="vision",
    n_frontend_tokens=256,   # 256 patch embeddings per image
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, d_ff=160, vocab_size=256,
    attention=AttentionConfig(kind="gqa", n_heads=4, n_kv_heads=2),
    n_frontend_tokens=8,
)
