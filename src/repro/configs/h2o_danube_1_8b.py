"""h2o-danube-1.8b [dense] — llama+mistral mix with sliding-window attention.

24L d_model=2560 32H (GQA kv=8) d_ff=6912 vocab=32000. [arXiv:2401.16818; hf]
"""
from repro.configs.base import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-1.8b",
    family="decoder",
    n_layers=24,
    d_model=2560,
    d_ff=6912,
    vocab_size=32_000,
    attention=AttentionConfig(
        kind="gqa", n_heads=32, n_kv_heads=8, window=4096, rope_theta=10_000.0
    ),
    act="swiglu",
    norm="rmsnorm",
    tie_embeddings=False,
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, d_ff=160, vocab_size=256,
    attention=AttentionConfig(kind="gqa", n_heads=4, n_kv_heads=2, window=16),
)
