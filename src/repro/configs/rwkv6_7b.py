"""rwkv6-7b [ssm] — Finch, data-dependent decay, attention-free.

32L d_model=4096 d_ff=14336 vocab=65536. [arXiv:2404.05892; hf]
"""
from repro.configs.base import AttentionConfig, ModelConfig, RWKVConfig

CONFIG = ModelConfig(
    name="rwkv6-7b",
    family="ssm",
    n_layers=32,
    d_model=4096,
    d_ff=14336,
    vocab_size=65_536,
    attention=AttentionConfig(kind="none", n_heads=64, n_kv_heads=64),
    rwkv=RWKVConfig(head_dim=64, decay_lora=64, token_shift_lora=32,
                    chunk_size=128),
    act="relu",   # rwkv channel-mix uses relu^2; handled in the block
    norm="layernorm",
    tie_embeddings=False,
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, d_ff=128, vocab_size=256,
    attention=AttentionConfig(kind="none", n_heads=4, n_kv_heads=4),
    rwkv=RWKVConfig(head_dim=16, decay_lora=16, token_shift_lora=8,
                    chunk_size=16),
)
