"""smollm-360m [dense] — llama-arch small. 32L d_model=960 15H (GQA kv=5)
d_ff=2560 vocab=49152. [hf:HuggingFaceTB/SmolLM-135M; hf]"""
from repro.configs.base import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    name="smollm-360m",
    family="decoder",
    n_layers=32,
    d_model=960,
    d_ff=2560,
    vocab_size=49_152,
    attention=AttentionConfig(kind="gqa", n_heads=15, n_kv_heads=5),
    act="swiglu",
    norm="rmsnorm",
    tie_embeddings=True,
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=60, d_ff=160, vocab_size=256,
    attention=AttentionConfig(kind="gqa", n_heads=3, n_kv_heads=1),
)
