"""recurrentgemma-9b [hybrid] — RG-LRU + local attention, 2 recurrent : 1
attention. 38L d_model=4096 16H (MQA kv=1) d_ff=12288 vocab=256000.
[arXiv:2402.19427; unverified]
"""
from repro.configs.base import AttentionConfig, ModelConfig, RGLRUConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,   # 38 blocks following the (rec, rec, attn) pattern
    d_model=4096,
    d_ff=12288,
    vocab_size=256_000,
    attention=AttentionConfig(kind="gqa", n_heads=16, n_kv_heads=1,
                              head_dim=256, window=2048),
    rglru=RGLRUConfig(lru_width=4096, conv_width=4,
                      block_pattern=("rec", "rec", "attn")),
    act="geglu",
    norm="rmsnorm",
    tie_embeddings=True,
)

SMOKE = CONFIG.replace(
    n_layers=3, d_model=64, d_ff=160, vocab_size=256,
    attention=AttentionConfig(kind="gqa", n_heads=4, n_kv_heads=1,
                              head_dim=16, window=16),
    rglru=RGLRUConfig(lru_width=64, conv_width=4),
)
