"""kimi-k2-1t-a32b [moe] — trillion-param MoE (paper-table config).

61L d_model=7168 64H (GQA kv=8) d_ff=2048(expert) vocab=163840, MoE 384e
top-8. [arXiv:2501.kimi2; unverified]
"""
from repro.configs.base import AttentionConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="decoder",
    n_layers=61,
    d_model=7168,
    d_ff=2048,               # expert FFN width
    vocab_size=163_840,
    attention=AttentionConfig(kind="gqa", n_heads=64, n_kv_heads=8),
    moe=MoEConfig(n_experts=384, top_k=8, expert_ff=2048,
                  capacity_factor=1.25),
    act="swiglu",
    norm="rmsnorm",
    tie_embeddings=False,
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, d_ff=64, vocab_size=256,
    attention=AttentionConfig(kind="gqa", n_heads=4, n_kv_heads=2),
    moe=MoEConfig(n_experts=8, top_k=2, expert_ff=64, capacity_factor=2.0),
)
