"""arctic-480b [moe] — 128 experts top-2 + dense residual branch.

35L d_model=7168 56H (GQA kv=8) d_ff=4864(expert) vocab=32000.
[hf:Snowflake/snowflake-arctic-base; hf]
"""
from repro.configs.base import AttentionConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    family="decoder",
    n_layers=35,
    d_model=7168,
    d_ff=4864,
    vocab_size=32_000,
    attention=AttentionConfig(kind="gqa", n_heads=56, n_kv_heads=8),
    moe=MoEConfig(n_experts=128, top_k=2, expert_ff=4864,
                  capacity_factor=1.25,
                  dense_residual_ff=4864),   # arctic dense-MoE hybrid residual
    act="swiglu",
    norm="rmsnorm",
    tie_embeddings=False,
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, d_ff=64, vocab_size=256,
    attention=AttentionConfig(kind="gqa", n_heads=4, n_kv_heads=2),
    moe=MoEConfig(n_experts=8, top_k=2, expert_ff=64, capacity_factor=2.0,
                  dense_residual_ff=64),
)
