"""seamless-m4t-large-v2 [audio] — encoder-decoder, multimodal backbone.

24L(+24L dec) d_model=1024 16H (kv=16) d_ff=8192 vocab=256206.
[arXiv:2308.11596; hf]

Per the assignment, the audio frontend is a STUB: ``input_specs()`` provides
precomputed frame embeddings as the encoder input.
"""
from repro.configs.base import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="encdec",
    n_layers=48,
    enc_layers=24,
    dec_layers=24,
    d_model=1024,
    d_ff=8192,
    vocab_size=256_206,
    attention=AttentionConfig(kind="gqa", n_heads=16, n_kv_heads=16),
    act="gelu",
    norm="layernorm",
    tie_embeddings=True,
    frontend="audio",
    n_frontend_tokens=3200,   # encoder memory length for decode shapes
    enc_memory_len=3200,
)

SMOKE = CONFIG.replace(
    n_layers=4, enc_layers=2, dec_layers=2, d_model=64, d_ff=128,
    vocab_size=256,
    attention=AttentionConfig(kind="gqa", n_heads=4, n_kv_heads=4),
    n_frontend_tokens=16, enc_memory_len=16,
)
