"""Registry mapping ``--arch <id>`` to configs (full + smoke)."""
from __future__ import annotations

from typing import Dict, Tuple

from repro.configs.base import (LM_SHAPES, SHAPES_BY_NAME, ModelConfig,
                                ShapeConfig, shape_applicable)
from repro.configs import (arctic_480b, h2o_danube_1_8b, internvl2_2b,
                           kimi_k2_1t_a32b, minicpm3_4b, qwen1_5_4b,
                           recurrentgemma_9b, rwkv6_7b,
                           seamless_m4t_large_v2, smollm_360m)
from repro.configs.dlrm import DLRM_CONFIGS, DLRM_SMOKE

_MODULES = {
    "h2o-danube-1.8b": h2o_danube_1_8b,
    "qwen1.5-4b": qwen1_5_4b,
    "minicpm3-4b": minicpm3_4b,
    "smollm-360m": smollm_360m,
    "internvl2-2b": internvl2_2b,
    "recurrentgemma-9b": recurrentgemma_9b,
    "kimi-k2-1t-a32b": kimi_k2_1t_a32b,
    "arctic-480b": arctic_480b,
    "seamless-m4t-large-v2": seamless_m4t_large_v2,
    "rwkv6-7b": rwkv6_7b,
}

ARCHS: Dict[str, ModelConfig] = {k: m.CONFIG for k, m in _MODULES.items()}
SMOKE_ARCHS: Dict[str, ModelConfig] = {k: m.SMOKE for k, m in _MODULES.items()}
ARCH_IDS = tuple(ARCHS)


def get_arch(arch_id: str) -> ModelConfig:
    if arch_id not in ARCHS:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(ARCHS)}")
    return ARCHS[arch_id]


def get_smoke(arch_id: str) -> ModelConfig:
    return SMOKE_ARCHS[arch_id]


def get_shape(name: str) -> ShapeConfig:
    return SHAPES_BY_NAME[name]


def iter_cells() -> Tuple[Tuple[str, str, bool, str], ...]:
    """All 40 (arch, shape) cells with applicability + reason."""
    out = []
    for arch_id, cfg in ARCHS.items():
        for shape in LM_SHAPES:
            ok, reason = shape_applicable(cfg, shape)
            out.append((arch_id, shape.name, ok, reason))
    return tuple(out)


def get_dlrm(name: str):
    if name == "dlrm_smoke":
        return DLRM_SMOKE
    return DLRM_CONFIGS[name]
