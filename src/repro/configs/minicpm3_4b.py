"""minicpm3-4b [dense] — multi-head latent attention (MLA).

62L d_model=2560 40H d_ff=6400 vocab=73448. [hf:openbmb/MiniCPM3-4B; hf]
"""
from repro.configs.base import AttentionConfig, MLAConfig, ModelConfig

CONFIG = ModelConfig(
    name="minicpm3-4b",
    family="decoder",
    n_layers=62,
    d_model=2560,
    d_ff=6400,
    vocab_size=73_448,
    attention=AttentionConfig(
        kind="mla", n_heads=40, n_kv_heads=40,
        mla=MLAConfig(q_lora_rank=768, kv_lora_rank=256,
                      qk_nope_head_dim=64, qk_rope_head_dim=32, v_head_dim=64),
    ),
    act="swiglu",
    norm="rmsnorm",
    tie_embeddings=True,
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, d_ff=160, vocab_size=256,
    attention=AttentionConfig(
        kind="mla", n_heads=4, n_kv_heads=4,
        mla=MLAConfig(q_lora_rank=32, kv_lora_rank=16,
                      qk_nope_head_dim=8, qk_rope_head_dim=4, v_head_dim=8),
    ),
)
