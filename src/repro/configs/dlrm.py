"""DLRM configurations — paper Table I (the paper's own benchmark suite).

| Model   | # Tables | Gathers/table | Table size | MLP size |
|---------|----------|---------------|------------|----------|
| DLRM(1) | 5        | 20            | 128 MB     | 57.4 KB  |
| DLRM(2) | 50       | 20            | 1.28 GB    | 57.4 KB  |
| DLRM(3) | 5        | 80            | 128 MB     | 57.4 KB  |
| DLRM(4) | 50       | 80            | 1.28 GB    | 57.4 KB  |
| DLRM(5) | 50       | 80            | 3.2 GB     | 57.4 KB  |
| DLRM(6) | 5        | 2             | 128 MB     | 557 KB   |

Table size = n_tables * rows * 32 dims * 4 B. 128 MB over 5 tables at 32-dim
fp32 → 200k rows/table; DLRM(5)'s 3.2 GB over 50 tables → 500k rows/table.
DLRM(6) has a deliberately heavyweight MLP (557 KB) and light embedding stage.
"""
from repro.configs.base import DLRMConfig

DLRM_CONFIGS = {
    "dlrm1": DLRMConfig(name="dlrm1", n_tables=5, rows_per_table=200_000,
                        lookups_per_table=20,
                        bottom_mlp=(512, 256, 32), top_mlp=(512, 256, 1)),
    "dlrm2": DLRMConfig(name="dlrm2", n_tables=50, rows_per_table=200_000,
                        lookups_per_table=20,
                        bottom_mlp=(512, 256, 32), top_mlp=(512, 256, 1)),
    "dlrm3": DLRMConfig(name="dlrm3", n_tables=5, rows_per_table=200_000,
                        lookups_per_table=80,
                        bottom_mlp=(512, 256, 32), top_mlp=(512, 256, 1)),
    "dlrm4": DLRMConfig(name="dlrm4", n_tables=50, rows_per_table=200_000,
                        lookups_per_table=80,
                        bottom_mlp=(512, 256, 32), top_mlp=(512, 256, 1)),
    "dlrm5": DLRMConfig(name="dlrm5", n_tables=50, rows_per_table=500_000,
                        lookups_per_table=80,
                        bottom_mlp=(512, 256, 32), top_mlp=(512, 256, 1)),
    # heavyweight MLP: ~557 KB of fp32 weights, tiny embedding stage
    "dlrm6": DLRMConfig(name="dlrm6", n_tables=5, rows_per_table=200_000,
                        lookups_per_table=2,
                        bottom_mlp=(1024, 512, 32), top_mlp=(1024, 512, 1)),
}

# Small variants usable on a laptop / in smoke tests.
DLRM_SMOKE = DLRMConfig(name="dlrm_smoke", n_tables=3, rows_per_table=1000,
                        lookups_per_table=4, emb_dim=16,
                        bottom_mlp=(64, 16), top_mlp=(64, 1))
