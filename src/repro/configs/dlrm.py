"""DLRM configurations — paper Table I (the paper's own benchmark suite),
plus heterogeneous-table variants (Centaur's workload characterization:
per-table vocab sizes and access skew vary by orders of magnitude, which
is why the sparse stage is many independent gather-reduce streams).

| Model   | # Tables | Gathers/table | Table size | MLP size |
|---------|----------|---------------|------------|----------|
| DLRM(1) | 5        | 20            | 128 MB     | 57.4 KB  |
| DLRM(2) | 50       | 20            | 1.28 GB    | 57.4 KB  |
| DLRM(3) | 5        | 80            | 128 MB     | 57.4 KB  |
| DLRM(4) | 50       | 80            | 1.28 GB    | 57.4 KB  |
| DLRM(5) | 50       | 80            | 3.2 GB     | 57.4 KB  |
| DLRM(6) | 5        | 2             | 128 MB     | 557 KB   |

Table size = n_tables * rows * 32 dims * 4 B. 128 MB over 5 tables at 32-dim
fp32 → 200k rows/table; DLRM(5)'s 3.2 GB over 50 tables → 500k rows/table.
DLRM(6) has a deliberately heavyweight MLP (557 KB) and light embedding stage.
"""
from repro.configs.base import DLRMConfig

DLRM_CONFIGS = {
    "dlrm1": DLRMConfig(name="dlrm1", n_tables=5, rows_per_table=200_000,
                        lookups_per_table=20,
                        bottom_mlp=(512, 256, 32), top_mlp=(512, 256, 1)),
    "dlrm2": DLRMConfig(name="dlrm2", n_tables=50, rows_per_table=200_000,
                        lookups_per_table=20,
                        bottom_mlp=(512, 256, 32), top_mlp=(512, 256, 1)),
    "dlrm3": DLRMConfig(name="dlrm3", n_tables=5, rows_per_table=200_000,
                        lookups_per_table=80,
                        bottom_mlp=(512, 256, 32), top_mlp=(512, 256, 1)),
    "dlrm4": DLRMConfig(name="dlrm4", n_tables=50, rows_per_table=200_000,
                        lookups_per_table=80,
                        bottom_mlp=(512, 256, 32), top_mlp=(512, 256, 1)),
    "dlrm5": DLRMConfig(name="dlrm5", n_tables=50, rows_per_table=500_000,
                        lookups_per_table=80,
                        bottom_mlp=(512, 256, 32), top_mlp=(512, 256, 1)),
    # heavyweight MLP: ~557 KB of fp32 weights, tiny embedding stage
    "dlrm6": DLRMConfig(name="dlrm6", n_tables=5, rows_per_table=200_000,
                        lookups_per_table=2,
                        bottom_mlp=(1024, 512, 32), top_mlp=(1024, 512, 1)),
}

# Small variants usable on a laptop / in smoke tests.
DLRM_SMOKE = DLRMConfig(name="dlrm_smoke", n_tables=3, rows_per_table=1000,
                        lookups_per_table=4, emb_dim=16,
                        bottom_mlp=(64, 16), top_mlp=(64, 1))


def make_heterogeneous(name: str, n_tables: int, *, seed: int = 0,
                       min_rows: int = 2_000, max_rows: int = 500_000,
                       dims=(8, 16, 32, 64), emb_dim: int = 32,
                       lookups_per_table: int = 20,
                       bottom_mlp=(512, 256, 32),
                       top_mlp=(512, 256, 1)) -> DLRMConfig:
    """Draw a Centaur-style heterogeneous table inventory: vocab sizes
    log-uniform over [min_rows, max_rows] (production tables span orders
    of magnitude), embedding dims from `dims`, and a per-table Zipf skew
    alpha in [1.02, 1.3] (some tables are nearly uniform, some extremely
    hot-headed). Deterministic in `seed`."""
    import numpy as np
    rng = np.random.RandomState(seed)
    rows = np.exp(rng.uniform(np.log(min_rows), np.log(max_rows),
                              n_tables)).astype(np.int64)
    table_dims = rng.choice(dims, n_tables)
    alphas = rng.uniform(1.02, 1.3, n_tables)
    return DLRMConfig(
        name=name, n_tables=n_tables,
        rows_per_table=int(rows.max()), emb_dim=emb_dim,
        lookups_per_table=lookups_per_table,
        bottom_mlp=tuple(bottom_mlp), top_mlp=tuple(top_mlp),
        table_rows=tuple(int(r) for r in rows),
        table_dims=tuple(int(d) for d in table_dims),
        table_alphas=tuple(float(a) for a in alphas))


# Heterogeneous inventories (kept OUT of DLRM_CONFIGS: the scaled bench
# helpers rescale the uniform rows_per_table field, which would desync a
# heterogeneous row inventory).
DLRM_HET_CONFIGS = {
    "dlrm_het1": make_heterogeneous("dlrm_het1", 8, seed=1),
    "dlrm_het2": make_heterogeneous("dlrm_het2", 26, seed=2,
                                    lookups_per_table=38),
}

# Heterogeneous smoke config: hand-picked extremes (a big skewed table, a
# mid table, a tiny near-uniform one) so tests exercise mixed dims and
# mixed vocab without drawing anything.
DLRM_HET_SMOKE = DLRMConfig(
    name="dlrm_het_smoke", n_tables=3, rows_per_table=2000,
    lookups_per_table=4, emb_dim=16, bottom_mlp=(64, 16), top_mlp=(64, 1),
    table_rows=(2000, 150, 9), table_dims=(16, 8, 4),
    table_alphas=(1.2, 1.05, 1.02))
