"""qwen1.5-4b [dense] — QKV bias. 40L d_model=2560 20H (GQA kv=20) d_ff=6912
vocab=151936. [hf:Qwen/Qwen1.5-0.5B; hf]"""
from repro.configs.base import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-4b",
    family="decoder",
    n_layers=40,
    d_model=2560,
    d_ff=6912,
    vocab_size=151_936,
    attention=AttentionConfig(
        kind="gqa", n_heads=20, n_kv_heads=20, qkv_bias=True, rope_theta=1_000_000.0
    ),
    act="swiglu",
    norm="rmsnorm",
    tie_embeddings=False,
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, d_ff=160, vocab_size=256,
    attention=AttentionConfig(kind="gqa", n_heads=4, n_kv_heads=4, qkv_bias=True),
)
