"""Per-request span tracing for the serving pipeline.

A *span* is one named, timed region (``enqueue``, ``batch``,
``bucket_pad``, ``sparse_lookup``, ``interaction``, ``mlp``,
``respond``). Spans nest: ``Tracer.span()`` is a context manager and the
tracer maintains a stack, so a ``serve_step`` span contains the
``sparse_lookup`` span it opened. Finished spans land in a bounded deque
(oldest dropped) — tracing a replica for a week costs the same memory as
tracing it for a minute.

Two hook layers bridge our spans to XLA's own tooling:

* ``stage(name)`` — used *inside* jitted code (dlrm / embedding_source).
  Disabled (the default) it returns a shared ``nullcontext`` singleton:
  no object allocation, no trace-side effects, and the compiled HLO is
  byte-identical (pinned by ``tests/test_obs.py`` via op histograms).
  Enabled it opens ``jax.named_scope`` + ``jax.profiler.TraceAnnotation``
  so the stage names show up in XLA profiles aligned with our spans.
* ``step_annotation(n)`` — ``jax.profiler.StepTraceAnnotation`` wrapper
  for the serve/train step loop, same disabled-is-free contract.
"""
from __future__ import annotations

import itertools
import time
from collections import deque
from contextlib import contextmanager, nullcontext
from typing import Deque, Dict, Iterator, List, Optional

__all__ = ["Span", "Tracer", "stage", "step_annotation",
           "enable_stage_annotations", "stage_annotations_enabled"]

# Stage hooks are module-level (not per-Tracer) because they run inside
# jitted functions that know nothing about engine instances. One shared
# disabled singleton keeps the off path allocation-free and lets tests
# assert `stage("x") is stage("y")`.
_NULL = nullcontext()
_STAGE_ANNOTATIONS = False


def enable_stage_annotations(on: bool = True) -> None:
    """Globally toggle named_scope/TraceAnnotation emission in jitted
    stages. Off by default; flipping it on forces retrace (the scopes
    are metadata-only — same ops, pinned by test)."""
    global _STAGE_ANNOTATIONS
    _STAGE_ANNOTATIONS = bool(on)


def stage_annotations_enabled() -> bool:
    return _STAGE_ANNOTATIONS


@contextmanager
def _annotated(name: str):
    import jax

    with jax.named_scope(name), jax.profiler.TraceAnnotation(name):
        yield


def stage(name: str):
    """Context manager wrapping one pipeline stage inside jitted code."""
    if not _STAGE_ANNOTATIONS:
        return _NULL
    return _annotated(name)


def step_annotation(step_num: int, name: str = "serve_step"):
    """StepTraceAnnotation for the host-side step loop."""
    if not _STAGE_ANNOTATIONS:
        return _NULL
    import jax

    return jax.profiler.StepTraceAnnotation(name, step_num=step_num)


class Span:
    """One finished (or open) timed region."""

    __slots__ = ("name", "trace_id", "span_id", "parent_id", "start",
                 "end", "attrs")

    def __init__(self, name: str, trace_id: int, span_id: int,
                 parent_id: Optional[int], start: float,
                 attrs: Optional[Dict] = None):
        self.name = name
        self.trace_id = trace_id
        self.span_id = span_id
        self.parent_id = parent_id
        self.start = start
        self.end: Optional[float] = None
        self.attrs: Dict = dict(attrs or {})

    @property
    def duration_ms(self) -> float:
        return ((self.end or time.perf_counter()) - self.start) * 1e3

    def to_dict(self) -> Dict:
        return {"name": self.name, "trace_id": self.trace_id,
                "span_id": self.span_id, "parent_id": self.parent_id,
                "start": self.start, "end": self.end,
                "duration_ms": self.duration_ms, "attrs": self.attrs}


class Tracer:
    """Bounded collector of nested spans.

    ``enabled=False`` (the default for a bare engine) turns ``span()``
    into the shared null context — the serve path pays one attribute
    check, nothing else.
    """

    def __init__(self, *, enabled: bool = True, max_spans: int = 4096):
        self.enabled = enabled
        self.finished: Deque[Span] = deque(maxlen=max_spans)
        self._stack: List[Span] = []
        self._ids = itertools.count(1)
        self._trace_ids = itertools.count(1)

    @contextmanager
    def _span_cm(self, name: str, attrs: Optional[Dict]) -> Iterator[Span]:
        parent = self._stack[-1] if self._stack else None
        s = Span(name,
                 trace_id=(parent.trace_id if parent
                           else next(self._trace_ids)),
                 span_id=next(self._ids),
                 parent_id=parent.span_id if parent else None,
                 start=time.perf_counter(), attrs=attrs)
        self._stack.append(s)
        try:
            yield s
        finally:
            s.end = time.perf_counter()
            self._stack.pop()
            self.finished.append(s)

    def span(self, name: str, attrs: Optional[Dict] = None):
        if not self.enabled:
            return _NULL
        return self._span_cm(name, attrs)

    def record(self, name: str, start: float, end: float,
               attrs: Optional[Dict] = None) -> Optional[Span]:
        """Append an already-timed span (perf_counter timestamps),
        nested under the currently open span if any. Used when the timed
        region ends before its logical parent opens (e.g. the batcher
        drain that precedes the serve_step span it belongs to)."""
        if not self.enabled:
            return None
        parent = self._stack[-1] if self._stack else None
        s = Span(name,
                 trace_id=(parent.trace_id if parent
                           else next(self._trace_ids)),
                 span_id=next(self._ids),
                 parent_id=parent.span_id if parent else None,
                 start=start, attrs=attrs)
        s.end = end
        self.finished.append(s)
        return s

    def spans(self, name: Optional[str] = None) -> List[Span]:
        if name is None:
            return list(self.finished)
        return [s for s in self.finished if s.name == name]

    def traces(self) -> Dict[int, List[Span]]:
        """Finished spans grouped by trace, each in finish order."""
        out: Dict[int, List[Span]] = {}
        for s in self.finished:
            out.setdefault(s.trace_id, []).append(s)
        return out

    def clear(self) -> None:
        self.finished.clear()
