"""repro.obs — the telemetry subsystem (metrics, traces, events).

Three layers, one bundle:

* :mod:`repro.obs.metrics` — ``MetricsRegistry`` of counters, gauges,
  and bounded-memory streaming histograms (p50/p95/p99 without keeping
  every sample), with JSON ``snapshot()`` and Prometheus-style
  ``exposition()``.
* :mod:`repro.obs.tracing` — ``Tracer`` for nested per-request spans
  through the serving pipeline, plus the jit-side ``stage()`` /
  ``step_annotation()`` hooks that line our spans up with XLA profiles
  (``jax.named_scope`` + ``jax.profiler.TraceAnnotation``). Off by
  default with a zero-cost null path.
* :mod:`repro.obs.events` — ``EventLog`` for the versioned-swap
  protocol (swaps, rebuilds, refreshes, stale rejections) with
  per-version hit-rate attribution.

``Telemetry`` is the bundle consumers take as one constructor argument:

    from repro import obs
    engine = RecEngine(cfg, params, source="cached",
                       telemetry=obs.Telemetry(tracing=True))
    ...
    print(engine.telemetry.registry.exposition())
    print(engine.telemetry.events.hit_rate_by_version())

``Telemetry(metrics=False)`` is the genuinely uninstrumented
configuration: the engine records nothing and never dispatches the
hit-rate probe — this is the baseline the ``obs_overhead`` benchmark
scenario compares against.
"""
from __future__ import annotations

from typing import Optional

from repro.obs.events import Event, EventLog
from repro.obs.metrics import Counter, Gauge, Histogram, MetricsRegistry
from repro.obs.tracing import (Span, Tracer, enable_stage_annotations,
                               stage, stage_annotations_enabled,
                               step_annotation)

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "Span", "Tracer", "stage", "step_annotation",
    "enable_stage_annotations", "stage_annotations_enabled",
    "Event", "EventLog", "Telemetry",
]


class Telemetry:
    """One bundle of registry + tracer + event log.

    ``metrics``  — master switch. False means *nothing* is recorded and
                   the engine skips even dispatching accounting work
                   (the hit-rate probe); histograms/counters stay empty.
    ``tracing``  — collect per-request spans (host-side timing).
    ``device_stages`` — run the serving forward as separately jitted
                   stages with a sync between each, recording per-stage
                   *device* time — the live Fig-5 mode. Costs the
                   stage-boundary syncs; only turn on when you want the
                   characterization.
    """

    def __init__(self, *, metrics: bool = True, tracing: bool = False,
                 device_stages: bool = False, max_spans: int = 4096,
                 max_events: int = 4096,
                 registry: Optional[MetricsRegistry] = None):
        self.enabled = bool(metrics)
        self.registry = registry if registry is not None else MetricsRegistry()
        self.tracer = Tracer(enabled=tracing and self.enabled,
                             max_spans=max_spans)
        self.events = EventLog(max_events=max_events)
        self.device_stages = bool(device_stages) and self.enabled

    @classmethod
    def disabled(cls) -> "Telemetry":
        """The uninstrumented configuration (obs_overhead baseline)."""
        return cls(metrics=False)

    def span(self, name: str, attrs=None):
        return self.tracer.span(name, attrs)

    def emit(self, kind: str, version=None, **attrs):
        if not self.enabled:
            return None
        return self.events.emit(kind, version, **attrs)

    def snapshot(self) -> dict:
        """Registry snapshot + recent events, JSON-able (--metrics-json)."""
        snap = self.registry.snapshot()
        snap["events"] = [e.to_dict() for e in self.events.events]
        snap["hit_rate_by_version"] = {
            str(k): v for k, v in
            self.events.hit_rate_by_version().items()}
        return snap
