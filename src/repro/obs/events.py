"""Structured event log for the versioned-swap protocol.

The trainer → fleet broadcast path (``VersionedSource`` /
``VersionedHotCache``) is the one part of the system where "what
happened when" genuinely matters after the fact: did the p99 regression
start at the v12 hot-cache rebuild or the v13 quantized-cold refresh?
Did a replica reject a stale broadcast? ``stats()`` can't answer those —
an append-only (bounded) event log can.

Event kinds emitted by the engine/trainers:

    ``source_swap``        engine accepted a new source version
    ``cache_swap``         engine accepted a new hot-cache version
    ``stale_rejected``     engine rejected an out-of-order broadcast
    ``hot_cache_rebuild``  trainer rebuilt the hot set from trace counts
    ``quantized_refresh``  trainer re-quantized cold rows touched by grads
    ``publish``            trainer stamped + broadcast an artifact
    ``retune``             engine re-derived its padding buckets
    ``shed``               scheduler dropped a request at admission (SLA)
    ``downgrade``          scheduler served a batch on the int8 path
    ``drain``              engine/scheduler flushed the queue (totals)

Fleet-layer kinds (emitted by ``repro.fleet`` — the chaos channel and
the crash/recovery runner):

    ``broadcast_dropped``    chaos channel dropped a broadcast artifact
    ``broadcast_reordered``  chaos channel delivered an artifact out of
                             order (delayed past a newer version)
    ``replica_restore``      replica re-bootstrapped from a checkpointed
                             source artifact (``restore_source``)
    ``trainer_resume``       trainer resumed from its latest checkpoint
                             after a (simulated) crash

Every event carries ``version`` where applicable; ``source_swap`` /
``cache_swap`` events additionally carry the *outgoing* version's hit
statistics (``hits``/``lookups``, per-table for groups), which is what
makes ``hit_rate_by_version()`` — per-version hit-rate attribution —
possible: the engine snapshots its counters at the swap boundary, right
before they reset.
"""
from __future__ import annotations

import json
import time
from collections import deque
from typing import Deque, Dict, List, Optional

__all__ = ["Event", "EventLog"]


class Event:
    __slots__ = ("kind", "time", "version", "attrs")

    def __init__(self, kind: str, version: Optional[int] = None,
                 attrs: Optional[Dict] = None, *,
                 time_s: Optional[float] = None):
        self.kind = kind
        self.version = version
        self.attrs: Dict = dict(attrs or {})
        self.time = time.time() if time_s is None else time_s

    def to_dict(self) -> Dict:
        return {"kind": self.kind, "time": self.time,
                "version": self.version, **self.attrs}

    def __repr__(self):
        v = f" v{self.version}" if self.version is not None else ""
        return f"<Event {self.kind}{v} {self.attrs}>"


class EventLog:
    """Bounded append-only log with per-version hit-rate attribution."""

    def __init__(self, *, max_events: int = 4096):
        self.events: Deque[Event] = deque(maxlen=max_events)

    def emit(self, kind: str, version: Optional[int] = None,
             **attrs) -> Event:
        e = Event(kind, version, attrs)
        self.events.append(e)
        return e

    def query(self, kind: Optional[str] = None,
              version: Optional[int] = None) -> List[Event]:
        out = []
        for e in self.events:
            if kind is not None and e.kind != kind:
                continue
            if version is not None and e.version != version:
                continue
            out.append(e)
        return out

    def hit_rate_by_version(self) -> Dict[int, Optional[float]]:
        """Hit rate attributed to each *outgoing* source/cache version.

        Swap events carry the hit/lookup totals accumulated while that
        version was live (snapshotted by the engine at the boundary).
        Versions that served no lookups map to ``None`` — unknown, not
        0.0, matching the ``stats()`` convention.
        """
        out: Dict[int, Optional[float]] = {}
        for e in self.events:
            if e.kind not in ("source_swap", "cache_swap"):
                continue
            prev = e.attrs.get("prev_version")
            if prev is None:
                continue
            hits, lookups = e.attrs.get("hits"), e.attrs.get("lookups")
            if not lookups:
                out[prev] = None
            else:
                out[prev] = float(hits) / float(lookups)
        return out

    def to_jsonl(self) -> str:
        return "\n".join(json.dumps(e.to_dict()) for e in self.events)

    def __len__(self):
        return len(self.events)
