"""Bounded-memory serving metrics: counters, gauges, streaming histograms.

The serving plane used to keep ``self.latencies``/``self.batch_sizes`` as
plain Python lists — O(requests served) memory, guaranteed to OOM a
long-lived replica. Every instrument here is O(1) in the number of
observations:

* ``Counter`` / ``Gauge`` — one float each;
* ``Histogram`` — a fixed log-spaced bucket array (streaming p50/p95/p99
  by in-bucket interpolation, relative error bounded by the bucket growth
  factor) plus a fixed-size ring of the most recent raw samples, which
  buys two things: *exact* percentiles while the stream still fits the
  ring (so short runs report the same numbers the old unbounded list
  did), and exact rolling-N percentiles forever after. A second bucket
  array forms the *window* view (``reset_window``), used by the engine
  for since-last-swap percentiles — a post-swap latency regression shows
  up instead of being averaged into history.

``MetricsRegistry`` is the one place instruments live: get-or-create by
(name, labels), JSON ``snapshot()`` for dashboards/artifacts, and
Prometheus-style text ``exposition()`` for scrapers.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import numpy as np

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry"]


def _fmt(v: float) -> str:
    """Deterministic number formatting for the exposition text."""
    f = float(v)
    if f == int(f) and abs(f) < 1e15:
        return str(int(f))
    return f"{f:.6g}"


class Counter:
    """Monotone accumulator (requests served, cache hits, ...)."""

    __slots__ = ("name", "help", "labels", "_value")

    def __init__(self, name: str, help: str = "",
                 labels: Optional[Dict[str, str]] = None):
        self.name = name
        self.help = help
        self.labels = dict(labels or {})
        self._value = 0.0

    def inc(self, n: float = 1.0) -> None:
        assert n >= 0, f"counter {self.name} can only go up (got {n})"
        self._value += n

    @property
    def value(self) -> float:
        return self._value


class Gauge:
    """Point-in-time value (source version, queue depth, loss, ...)."""

    __slots__ = ("name", "help", "labels", "_value")

    def __init__(self, name: str, help: str = "",
                 labels: Optional[Dict[str, str]] = None):
        self.name = name
        self.help = help
        self.labels = dict(labels or {})
        self._value = 0.0

    def set(self, v: float) -> None:
        self._value = float(v)

    @property
    def value(self) -> float:
        return self._value


class Histogram:
    """Streaming histogram with bounded memory and three percentile views.

    * ``percentile(q)`` — since construction. Exact (``np.percentile``
      over the raw ring) while ``count <= ring`` samples have been seen;
      afterwards a bucket-interpolated estimate whose relative error is
      bounded by ``growth - 1`` (the bucket width ratio).
    * ``percentile(q, window='window')`` — since the last
      ``reset_window()`` (bucket estimate). The serving engine resets
      this window on every version swap.
    * ``percentile(q, window='rolling')`` — exact over the last
      ``min(count, ring)`` samples.

    Values below ``lo`` clamp into the first bucket, above ``hi`` into
    the last — the estimate degrades gracefully instead of growing state.
    """

    __slots__ = ("name", "help", "labels", "_bounds", "_counts",
                 "_window_counts", "_ring", "_ring_pos", "count",
                 "window_count", "total", "_growth")

    def __init__(self, name: str, help: str = "",
                 labels: Optional[Dict[str, str]] = None, *,
                 lo: float = 1e-3, hi: float = 1e5, growth: float = 1.08,
                 ring: int = 2048):
        assert lo > 0 and hi > lo and growth > 1 and ring >= 1
        self.name = name
        self.help = help
        self.labels = dict(labels or {})
        n = int(np.ceil(np.log(hi / lo) / np.log(growth))) + 1
        self._bounds = lo * growth ** np.arange(n + 1)
        self._growth = growth
        self._counts = np.zeros(n, np.int64)
        self._window_counts = np.zeros(n, np.int64)
        self._ring = np.zeros(ring, np.float64)
        self._ring_pos = 0
        self.count = 0
        self.window_count = 0
        self.total = 0.0

    @property
    def ring_size(self) -> int:
        return len(self._ring)

    def record(self, v: float) -> None:
        v = float(v)
        b = int(np.searchsorted(self._bounds, v, side="right")) - 1
        b = min(max(b, 0), len(self._counts) - 1)
        self._counts[b] += 1
        self._window_counts[b] += 1
        self._ring[self._ring_pos] = v
        self._ring_pos = (self._ring_pos + 1) % len(self._ring)
        self.count += 1
        self.window_count += 1
        self.total += v

    def reset_window(self) -> None:
        """Start a fresh 'window' view (cumulative/rolling untouched)."""
        self._window_counts[:] = 0
        self.window_count = 0

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def ring_values(self) -> np.ndarray:
        """The last min(count, ring) raw samples, oldest first."""
        n = min(self.count, len(self._ring))
        if n < len(self._ring):
            return self._ring[:n].copy()
        p = self._ring_pos
        return np.concatenate([self._ring[p:], self._ring[:p]])

    def _bucket_percentile(self, q: float, counts: np.ndarray,
                           n: int) -> float:
        if n == 0:
            return 0.0
        target = q / 100.0 * n
        cum = np.cumsum(counts)
        b = int(np.searchsorted(cum, max(target, 1e-12)))
        b = min(b, len(counts) - 1)
        prev = cum[b - 1] if b > 0 else 0
        inside = counts[b]
        frac = (target - prev) / inside if inside else 0.0
        lo, hi = self._bounds[b], self._bounds[b + 1]
        return float(lo + (hi - lo) * min(max(frac, 0.0), 1.0))

    def percentile(self, q: float, window: str = "cumulative") -> float:
        if window == "cumulative":
            if self.count == 0:
                return 0.0
            if self.count <= len(self._ring):
                # the stream still fits the ring: exact, bit-for-bit what
                # an unbounded list would have reported
                return float(np.percentile(self.ring_values(), q))
            return self._bucket_percentile(q, self._counts, self.count)
        if window == "window":
            return self._bucket_percentile(q, self._window_counts,
                                           self.window_count)
        if window == "rolling":
            if self.count == 0:
                return 0.0
            return float(np.percentile(self.ring_values(), q))
        raise ValueError(f"unknown percentile window {window!r} "
                         "(cumulative | window | rolling)")

    def fraction_leq(self, v: float, window: str = "cumulative") -> float:
        """Fraction of observations <= v (the SLA-attainment query).
        Exact from the raw ring while the stream fits it (or for the
        rolling window); bucket-interpolated afterwards."""
        if window == "rolling" or (window == "cumulative"
                                   and self.count <= len(self._ring)):
            vals = self.ring_values()
            return float(np.mean(vals <= v)) if len(vals) else 0.0
        counts, n = ((self._counts, self.count)
                     if window == "cumulative"
                     else (self._window_counts, self.window_count))
        if n == 0:
            return 0.0
        b = int(np.searchsorted(self._bounds, v, side="right")) - 1
        if b < 0:
            return 0.0
        b = min(b, len(counts) - 1)
        below = int(np.sum(counts[:b]))
        lo, hi = self._bounds[b], self._bounds[b + 1]
        frac = min(max((v - lo) / (hi - lo), 0.0), 1.0)
        return float(below + frac * counts[b]) / n

    def summary(self) -> Dict[str, float]:
        return {"count": self.count,
                "sum": self.total,
                "mean": self.mean,
                "p50": self.percentile(50),
                "p95": self.percentile(95),
                "p99": self.percentile(99)}


def _key(name: str, labels: Optional[Dict[str, str]]) -> str:
    if not labels:
        return name
    inner = ",".join(f'{k}="{labels[k]}"' for k in sorted(labels))
    return f"{name}{{{inner}}}"


class MetricsRegistry:
    """Get-or-create instrument store with JSON + Prometheus views."""

    def __init__(self):
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._histograms: Dict[str, Histogram] = {}

    def counter(self, name: str, help: str = "",
                labels: Optional[Dict[str, str]] = None) -> Counter:
        return self._counters.setdefault(_key(name, labels),
                                         Counter(name, help, labels))

    def gauge(self, name: str, help: str = "",
              labels: Optional[Dict[str, str]] = None) -> Gauge:
        return self._gauges.setdefault(_key(name, labels),
                                       Gauge(name, help, labels))

    def histogram(self, name: str, help: str = "",
                  labels: Optional[Dict[str, str]] = None,
                  **kwargs) -> Histogram:
        key = _key(name, labels)
        if key not in self._histograms:
            self._histograms[key] = Histogram(name, help, labels, **kwargs)
        return self._histograms[key]

    def histograms(self, name: str) -> Dict[str, Histogram]:
        """Every labeled variant of one histogram family."""
        return {k: h for k, h in self._histograms.items()
                if h.name == name}

    def snapshot(self) -> Dict[str, Dict]:
        """JSON-able view of every instrument (the --metrics-json body)."""
        return {
            "counters": {k: c.value for k, c in
                         sorted(self._counters.items())},
            "gauges": {k: g.value for k, g in sorted(self._gauges.items())},
            "histograms": {k: h.summary() for k, h in
                           sorted(self._histograms.items())},
        }

    def exposition(self) -> str:
        """Prometheus text format. Histograms render as summaries
        (streaming quantiles + _sum/_count)."""
        lines = []
        seen_help = set()

        def header(inst, kind):
            if inst.name not in seen_help:
                seen_help.add(inst.name)
                if inst.help:
                    lines.append(f"# HELP {inst.name} {inst.help}")
                lines.append(f"# TYPE {inst.name} {kind}")

        for key, c in sorted(self._counters.items()):
            header(c, "counter")
            lines.append(f"{key} {_fmt(c.value)}")
        for key, g in sorted(self._gauges.items()):
            header(g, "gauge")
            lines.append(f"{key} {_fmt(g.value)}")
        for key, h in sorted(self._histograms.items()):
            header(h, "summary")
            base = dict(h.labels)
            for q in (0.5, 0.95, 0.99):
                lab = _key(h.name, dict(base, quantile=str(q)))
                lines.append(f"{lab} {_fmt(h.percentile(q * 100))}")
            lines.append(f"{_key(h.name + '_sum', base)} {_fmt(h.total)}")
            lines.append(f"{_key(h.name + '_count', base)} "
                         f"{_fmt(h.count)}")
        return "\n".join(lines) + "\n"
