"""Roofline model for TPU v5e (the TARGET hardware; container is CPU-only).

Terms are *per-device seconds* derived from the compiled dry-run artifact
(cost_analysis / memory_analysis / HLO collective parse — all per-device):

    t_compute    = HLO_flops / PEAK_FLOPS
    t_memory     = HLO_bytes_accessed / HBM_BW
    t_collective = collective_bytes / ICI_BW

MODEL_FLOPS uses the 6·N·D convention (2·N·D for inference), with N the
matmul-visible parameter count: embedding-table lookups are excluded, the
LM head is included (even when tied).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Tuple

# TPU v5e per-chip constants (assignment-specified)
PEAK_FLOPS = 197e12       # bf16 FLOP/s
HBM_BW = 819e9            # B/s
ICI_BW = 50e9             # B/s per link

HBM_BYTES = 16 * 1024**3  # v5e HBM capacity


@dataclass
class RooflineReport:
    t_compute: float
    t_memory: float
    t_collective: float
    dominant: str
    model_flops: float
    hlo_flops: float
    useful_ratio: float        # MODEL_FLOPS / (HLO_flops * chips)
    roofline_fraction: float   # t_dominant==compute ? t_c/sum : t_c/max

    def as_dict(self) -> Dict:
        return self.__dict__.copy()


def terms(flops_per_dev: float, bytes_per_dev: float,
          coll_bytes_per_dev: float) -> Tuple[float, float, float]:
    return (flops_per_dev / PEAK_FLOPS,
            bytes_per_dev / HBM_BW,
            coll_bytes_per_dev / ICI_BW)


def analyze(flops_per_dev: float, bytes_per_dev: float,
            coll_bytes_per_dev: float, model_flops: float,
            chips: int) -> RooflineReport:
    tc, tm, tl = terms(flops_per_dev, bytes_per_dev, coll_bytes_per_dev)
    pairs = {"compute": tc, "memory": tm, "collective": tl}
    dominant = max(pairs, key=pairs.get)
    hlo_total = flops_per_dev * chips
    useful = model_flops / hlo_total if hlo_total else 0.0
    # fraction of the dominant-term bound actually spent on useful math:
    # ideal time = model_flops/(chips*peak); achievable time >= max(term)
    ideal = model_flops / (chips * PEAK_FLOPS)
    bound = max(tc, tm, tl)
    frac = ideal / bound if bound > 0 else 0.0
    return RooflineReport(tc, tm, tl, dominant, model_flops,
                          flops_per_dev, useful, frac)


# ---------------------------------------------------------------------------
# MODEL_FLOPS (6·N·D convention)
# ---------------------------------------------------------------------------

def _walk(tree, path=()):
    if isinstance(tree, dict):
        for k, v in tree.items():
            yield from _walk(v, path + (k,))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            yield from _walk(v, path + (str(i),))
    else:
        yield path, tree


def count_params(shapes_tree, cfg) -> Tuple[float, float]:
    """(N_total_matmul, N_active_matmul) from a ShapeDtypeStruct tree.

    Excludes the embedding gather table; for MoE archs expert weights count
    at top_k/n_experts utilization in N_active.
    """
    import numpy as np
    total = 0.0
    active = 0.0
    for path, leaf in _walk(shapes_tree):
        n = float(np.prod(leaf.shape)) if leaf.shape else 1.0
        joined = "/".join(path)
        if "embed" in joined:
            continue                      # lookup, not matmul
        total += n
        if "moe" in joined and path[-1] in ("wg", "wu", "wd"):
            active += n * cfg.moe.top_k / cfg.moe.n_experts
        else:
            active += n
    # tied LM head: add D*V once (matmul exists even though param is shared)
    if getattr(cfg, "tie_embeddings", False):
        from repro.models.embedding import padded_vocab
        head = cfg.d_model * padded_vocab(cfg.vocab_size)
        total += head
        active += head
    return total, active


def model_flops(cfg, shape, shapes_tree) -> float:
    """6·N·D for training, 2·N·D for inference steps."""
    _, n_active = count_params(shapes_tree, cfg)
    if shape.kind == "train":
        d = shape.global_batch * shape.seq_len
        return 6.0 * n_active * d
    if shape.kind == "prefill":
        d = shape.global_batch * shape.seq_len
        return 2.0 * n_active * d
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch
