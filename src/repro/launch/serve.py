"""Serving launcher: batched DLRM inference (the paper's deployment) or LM
decode via the continuous-batching engine.

    PYTHONPATH=src python -m repro.launch.serve --arch dlrm1 --requests 64
    PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m --smoke
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.dlrm import DLRM_CONFIGS, DLRM_SMOKE
from repro.configs.registry import ARCHS, SMOKE_ARCHS
from repro.core import dlrm as dlrm_mod
from repro.core.hybrid import make_pipelined_serve_step
from repro.data import DLRMSynthetic
from repro.launch.mesh import make_production_mesh
from repro.models import api
from repro.serving import Batcher, DecodeEngine, Request


def serve_dlrm(args) -> None:
    cfg = DLRM_SMOKE if args.smoke else DLRM_CONFIGS[args.arch]
    mesh = None if args.mesh == "none" else make_production_mesh(
        multi_pod=(args.mesh == "multipod"))
    params = dlrm_mod.init(jax.random.PRNGKey(0), cfg,
                           mesh.shape["model"] if mesh else 1)
    serve = jax.jit(make_pipelined_serve_step(cfg, args.microbatches, mesh)
                    if args.pipelined else dlrm_mod.make_serve_step(cfg, mesh))
    data = DLRMSynthetic(cfg, seed=1)
    lat = []
    for _ in range(args.requests // args.batch_size):
        b = data.batch(args.batch_size)
        batch = {"dense": jnp.asarray(b["dense"]),
                 "indices": jnp.asarray(b["indices"])}
        t0 = time.time()
        probs = serve(params, batch)
        probs.block_until_ready()
        lat.append(time.time() - t0)
    arr = np.array(lat[1:] or lat)   # drop compile step
    print(f"dlrm serve: {args.requests} reqs, batch {args.batch_size}, "
          f"p50 {np.percentile(arr, 50)*1e3:.2f} ms "
          f"p99 {np.percentile(arr, 99)*1e3:.2f} ms")


def serve_lm(args) -> None:
    cfg = (SMOKE_ARCHS if args.smoke else ARCHS)[args.arch]
    params, _ = api.init(jax.random.PRNGKey(0), cfg)
    engine = DecodeEngine(cfg, params, n_slots=args.batch_size,
                          max_len=args.max_len)
    batcher = Batcher(max_batch=args.batch_size)
    rng = np.random.RandomState(0)
    for rid in range(args.requests):
        batcher.submit(Request(
            rid=rid,
            prompt=rng.randint(0, cfg.vocab_size, size=(args.prompt_len,))
            .astype(np.int32),
            max_new_tokens=args.new_tokens))
    while len(engine.latencies) < args.requests:
        if engine.idle():
            wave = batcher.take()
            if not wave:
                break
            engine.admit(wave)
        engine.step()
    print(f"lm serve stats: {engine.stats()}")


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="dlrm1")
    p.add_argument("--smoke", action="store_true")
    p.add_argument("--mesh", default="none",
                   choices=("none", "pod", "multipod"))
    p.add_argument("--requests", type=int, default=64)
    p.add_argument("--batch-size", type=int, default=8)
    p.add_argument("--pipelined", action="store_true",
                   help="DLRM: overlap sparse/dense via microbatch pipeline")
    p.add_argument("--microbatches", type=int, default=4)
    p.add_argument("--prompt-len", type=int, default=8)
    p.add_argument("--new-tokens", type=int, default=8)
    p.add_argument("--max-len", type=int, default=128)
    args = p.parse_args()
    if args.arch.startswith("dlrm"):
        serve_dlrm(args)
    else:
        serve_lm(args)


if __name__ == "__main__":
    main()
