import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# --- everything below may import jax ---------------------------------------
import argparse     # noqa: E402
import json         # noqa: E402
import time         # noqa: E402
import traceback    # noqa: E402
from pathlib import Path  # noqa: E402

import jax          # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs.base import shape_applicable  # noqa: E402
from repro.configs.registry import ARCH_IDS, get_arch, get_shape  # noqa: E402
from repro.launch import hlo_analysis, roofline  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models import api  # noqa: E402

RESULTS_DIR = Path(__file__).resolve().parents[3] / "benchmarks" / "results"

SHAPE_NAMES = ("train_4k", "prefill_32k", "decode_32k", "long_500k")


def _cell_path(mesh_name: str, arch: str, shape: str) -> Path:
    return RESULTS_DIR / f"dryrun_{mesh_name}_{arch}_{shape}.json"


def lower_cell(arch_id: str, shape_name: str, multi_pod: bool,
               opt_override=None) -> dict:
    """Lower + compile one (arch x shape x mesh) cell; return the record."""
    cfg = get_arch(arch_id)
    shape = get_shape(shape_name)
    mesh_name = "multipod" if multi_pod else "pod"
    rec = {"arch": arch_id, "shape": shape_name, "mesh": mesh_name}

    ok, reason = shape_applicable(cfg, shape)
    if not ok:
        rec.update(status="skipped", reason=reason)
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    t0 = time.time()

    def _shardings(tree):
        return jax.tree_util.tree_map(lambda s: s.sharding, tree)

    if shape.kind == "train":
        opt_name, opt, step = api.make_train_step(cfg, optimizer=opt_override,
                                                  mesh=mesh)
        params_sds, opt_sds, _ = api.train_state_specs(cfg, opt_name, opt,
                                                       mesh)
        batch_sds = api.input_specs(cfg, shape, mesh)
        with mesh:
            # out shardings pinned to the inputs' so donation aliases
            lowered = jax.jit(
                step, donate_argnums=(0, 1),
                out_shardings=(_shardings(params_sds), _shardings(opt_sds),
                               None)).lower(params_sds, opt_sds, batch_sds)
            compiled = lowered.compile()
        rec["optimizer"] = opt_name
        shapes_tree = params_sds
    elif shape.kind == "prefill":
        step = api.make_prefill_step(cfg, shape.seq_len, mesh=mesh)
        opt_name, opt = api.default_optimizer(cfg)
        params_sds, _, _ = api.train_state_specs(cfg, opt_name, opt, mesh)
        batch_sds = api.input_specs(cfg, shape, mesh)
        with mesh:
            lowered = jax.jit(step).lower(params_sds, batch_sds)
            compiled = lowered.compile()
        shapes_tree = params_sds
    else:  # decode
        step = api.make_decode_fn(cfg, mesh=mesh)
        opt_name, opt = api.default_optimizer(cfg)
        params_sds, _, _ = api.train_state_specs(cfg, opt_name, opt, mesh)
        cache_sds = api.cache_specs(cfg, shape.global_batch, shape.seq_len,
                                    mesh)
        batch_sds = api.input_specs(cfg, shape, mesh)
        with mesh:
            lowered = jax.jit(
                step, donate_argnums=(1,),
                out_shardings=(None, _shardings(cache_sds))).lower(
                params_sds, cache_sds, batch_sds)
            compiled = lowered.compile()
        shapes_tree = params_sds

    compile_s = time.time() - t0

    ca = compiled.cost_analysis()
    ma = compiled.memory_analysis()
    raw_flops, raw_bytes = hlo_analysis.parse_flops_bytes(ca)
    # XLA counts while bodies once; use the trip-count-aware HLO analysis
    hlo_text = compiled.as_text()
    hlo = hlo_analysis.analyze(hlo_text)
    flops, bytes_acc = hlo["flops"], hlo["bytes"]
    coll = hlo["collectives"]
    # flash-kernel substitution estimate: the Pallas kernel (TPU target)
    # keeps score blocks in VMEM — subtract their measured HBM traffic
    score_bytes = hlo_analysis.score_block_traffic(hlo_text)

    mf = roofline.model_flops(cfg, shape, shapes_tree)
    rep = roofline.analyze(flops, bytes_acc, coll.get("total", 0.0), mf,
                           chips)

    per_dev_bytes = (ma.argument_size_in_bytes + ma.output_size_in_bytes
                     + ma.temp_size_in_bytes - ma.alias_size_in_bytes)
    # read-once lower bound on memory time: every input byte touched once
    t_mem_ideal = ma.argument_size_in_bytes / roofline.HBM_BW
    rec.update(
        status="ok",
        chips=chips,
        compile_s=round(compile_s, 1),
        flops_per_dev=flops,
        bytes_accessed_per_dev=bytes_acc,
        xla_raw_flops=raw_flops,
        xla_raw_bytes=raw_bytes,
        collective_bytes=coll,
        memory={
            "argument": ma.argument_size_in_bytes,
            "output": ma.output_size_in_bytes,
            "temp": ma.temp_size_in_bytes,
            "alias": ma.alias_size_in_bytes,
            "per_device_total": per_dev_bytes,
            "fits_hbm": bool(per_dev_bytes <= roofline.HBM_BYTES),
        },
        roofline=rep.as_dict() | {
            "t_memory_ideal": t_mem_ideal,
            "score_block_bytes": score_bytes,
            "t_memory_flash": max(0.0, bytes_acc - score_bytes)
            / roofline.HBM_BW,
        },
    )
    # the assignment asks these be printed
    print(f"[{mesh_name}|{arch_id}|{shape_name}] memory_analysis: {ma}")
    print(f"[{mesh_name}|{arch_id}|{shape_name}] cost_analysis: "
          f"flops={flops:.3e} bytes={bytes_acc:.3e} "
          f"coll={coll.get('total', 0.0):.3e}")
    return rec


def run_cell(arch_id: str, shape_name: str, multi_pod: bool,
             force: bool = False) -> dict:
    mesh_name = "multipod" if multi_pod else "pod"
    path = _cell_path(mesh_name, arch_id, shape_name)
    if path.exists() and not force:
        rec = json.loads(path.read_text())
        if rec.get("status") in ("ok", "skipped"):
            print(f"[cached] {mesh_name}|{arch_id}|{shape_name}: "
                  f"{rec['status']}")
            return rec
    try:
        rec = lower_cell(arch_id, shape_name, multi_pod)
    except Exception as e:  # record failures — they are bugs to fix
        rec = {"arch": arch_id, "shape": shape_name, "mesh": mesh_name,
               "status": "error", "error": f"{type(e).__name__}: {e}",
               "traceback": traceback.format_exc()[-4000:]}
        print(f"[ERROR] {mesh_name}|{arch_id}|{shape_name}: {e}")
    RESULTS_DIR.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(rec, indent=2))
    return rec


def main() -> None:
    p = argparse.ArgumentParser(description="multi-pod dry-run")
    p.add_argument("--arch", default=None, help="arch id (default: all)")
    p.add_argument("--shape", default=None, choices=SHAPE_NAMES)
    p.add_argument("--mesh", default="both",
                   choices=("pod", "multipod", "both"))
    p.add_argument("--force", action="store_true")
    p.add_argument("--list", action="store_true")
    args = p.parse_args()

    archs = [args.arch] if args.arch else list(ARCH_IDS)
    shapes = [args.shape] if args.shape else list(SHAPE_NAMES)
    meshes = {"pod": [False], "multipod": [True],
              "both": [False, True]}[args.mesh]

    if args.list:
        for a in archs:
            for s in shapes:
                ok, why = shape_applicable(get_arch(a), get_shape(s))
                print(f"{a:26s} {s:12s} {'RUN' if ok else why}")
        return

    n_ok = n_skip = n_err = 0
    for multi_pod in meshes:
        for a in archs:
            for s in shapes:
                rec = run_cell(a, s, multi_pod, force=args.force)
                st = rec["status"]
                n_ok += st == "ok"
                n_skip += st == "skipped"
                n_err += st == "error"
    print(f"\ndry-run summary: ok={n_ok} skipped={n_skip} errors={n_err}")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
