"""Post-compile HLO analysis: trip-count-aware flops / bytes / collectives.

XLA's ``compiled.cost_analysis()`` counts a ``while`` body ONCE — a 61-layer
scanned transformer reports ~1/61 of its real flops (verified empirically).
The roofline would be garbage. This module re-derives the three roofline
inputs from ``compiled.as_text()`` with loop trip counts multiplied through:

  * flops       — 2 * |result| * contraction_size for every ``dot`` op
                  (CPU/TPU HLO keeps dots top-level; conv-free models here);
  * bytes       — Σ (result + operand bytes) over memory-touching top-level
                  ops (fusions, dots, copies, slices, collectives, ...);
                  zero-copy ops (bitcast, get-tuple-element, parameter,
                  tuple, while plumbing) excluded;
  * collectives — result bytes of all-gather / all-reduce / reduce-scatter /
                  all-to-all / collective-permute, by kind.

Trip counts are read from each while condition's largest s32 constant.
Nested loops (layer scan x kv-chunk scan) multiply recursively. Fusion /
call / conditional edges are traversed with trip 1 (dots inside count;
fusion-internal bytes do not — the fusion op itself accounts its traffic).
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

# ops that do not touch memory themselves
_ZERO_COPY = {"parameter", "constant", "tuple", "get-tuple-element",
              "bitcast", "while", "conditional", "call", "after-all",
              "partition-id", "replica-id", "iota", "bitcast-convert"}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
# result type is a tuple "(... /*index=3*/ ...)" (no nested parens) or a
# plain "f32[16,24]{1,0}" token
_DEF_RE = re.compile(r"^(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*"
                     r"(\([^)]*\)|[\w\[\]\{\},]+)\s*([\w\-]+)\(")
_COMP_HEADER_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->")
_WHILE_RE = re.compile(
    r"while\(.*?condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)")
_CALLS_RE = re.compile(r"calls=%?([\w\.\-]+)")
_TO_APPLY_RE = re.compile(r"to_apply=%?([\w\.\-]+)")
_CONST_RE = re.compile(r"s32\[\]\s+constant\((\d+)\)")
_LHS_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")


def _split_top(s: str) -> List[str]:
    """Split on commas at bracket depth 0 (commas inside [],{},() stay)."""
    out: List[str] = []
    depth = 0
    cur: List[str] = []
    for ch in s:
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        if ch == "," and depth == 0:
            out.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    if cur:
        out.append("".join(cur))
    return out


def _operand_names(ls: str, op: str) -> List[str]:
    """Operand variable names of ``op(...)`` on a definition line.

    Tolerates both HLO text flavors: bare names (``dot(%x, %y)`` /
    ``dot(x, y)``) and operand types printed inline
    (``dot(f32[8,8]{1,0} %x, ...)``, older XLA) — the name is always the
    last whitespace token of each top-level comma chunk.
    """
    paren = ls.find(op + "(")
    if paren < 0:
        return []
    i = paren + len(op) + 1
    start = i
    depth = 1
    while i < len(ls) and depth:
        if ls[i] == "(":
            depth += 1
        elif ls[i] == ")":
            depth -= 1
        i += 1
    names = []
    for chunk in _split_top(ls[start:i - 1]):
        toks = chunk.strip().split()
        if toks:
            names.append(toks[-1].lstrip("%"))
    return names


def _shape_elems_bytes(type_str: str) -> Tuple[int, int]:
    elems = 0
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        elems += n
        total += n * _DTYPE_BYTES[dtype]
    return elems, total


def _dims(type_str: str) -> Optional[List[int]]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None
    return [int(d) for d in m.group(2).split(",") if d]


class _Comp:
    __slots__ = ("flops", "bytes", "coll", "edges")

    def __init__(self):
        self.flops = 0.0
        self.bytes = 0.0
        self.coll: Dict[str, float] = defaultdict(float)
        self.edges: List[Tuple[str, str]] = []   # (kind, comp or cond name)


def _parse(hlo_text: str):
    comps: Dict[str, List[str]] = {}
    order: List[str] = []
    entry = None
    cur = None
    for line in hlo_text.splitlines():
        ls = line.strip()
        m = _COMP_HEADER_RE.match(ls)
        if m and ls.endswith("{"):
            cur = m.group(2)
            comps[cur] = []
            order.append(cur)
            if m.group(1):
                entry = cur
        elif cur is not None and ls and ls != "}":
            comps[cur].append(ls)
    return comps, entry


def count_ops(hlo_text: str) -> Dict[str, int]:
    """Opcode histogram over every computation in the module (fusion and
    called sub-computations included). This is the timing-free structural
    signal the perf gates assert on — e.g. "the fused forward lowers
    scatter-free" or "the cached path walks the stream once" hold or fail
    regardless of how noisy the host's clock is."""
    comps_lines, _ = _parse(hlo_text)
    counts: Dict[str, int] = {}
    for lines in comps_lines.values():
        for ls in lines:
            dm = _DEF_RE.match(ls)
            if dm:
                op = dm.group(3)
                counts[op] = counts.get(op, 0) + 1
    return counts


def analyze(hlo_text: str) -> Dict[str, object]:
    comps_lines, entry = _parse(hlo_text)
    comps: Dict[str, _Comp] = {}
    trip_counts: Dict[str, int] = {}

    for name, lines in comps_lines.items():
        c = _Comp()
        symbols: Dict[str, str] = {}
        consts: List[int] = []
        # first pass: symbol table (var -> result type)
        for ls in lines:
            dm = _DEF_RE.match(ls)
            if dm:
                symbols[dm.group(1)] = dm.group(2)
        for ls in lines:
            consts.extend(int(x) for x in _CONST_RE.findall(ls))
            dm = _DEF_RE.match(ls)
            if not dm:
                continue
            var, rtype, op = dm.group(1), dm.group(2), dm.group(3)
            _, rbytes = _shape_elems_bytes(rtype)
            relems, _ = _shape_elems_bytes(rtype)

            # --- edges to other computations ---
            wm = _WHILE_RE.search(ls)
            if wm:
                c.edges.append(("while", wm.group(2) + "|" + wm.group(1)))
            else:
                # A fusion accounts its own traffic at the fusion op, so its
                # sub-computation contributes flops only; a plain call (e.g.
                # XLA:CPU's parallel_* wrappers via to_apply) is transparent
                # and must propagate bytes too.
                kind = "fusion" if op == "fusion" else "call"
                for cm in _CALLS_RE.findall(ls):
                    c.edges.append((kind, cm))
                for cm in _TO_APPLY_RE.findall(ls):
                    c.edges.append((kind, cm))

            # --- collectives ---
            base_op = re.sub(r"-(start|done)$", "", op)
            if base_op in COLLECTIVES:
                if op.endswith("-done"):
                    continue
                c.coll[base_op] += rbytes
                c.bytes += rbytes * 2
                continue

            # --- flops: dot ops ---
            if op == "dot":
                contract = 1
                lm = _LHS_CONTRACT_RE.search(ls)
                operands = _operand_names(ls, "dot")
                if lm and operands:
                    lhs_type = symbols.get(operands[0])
                    ldims = _dims(lhs_type) if lhs_type else None
                    if ldims:
                        for i in lm.group(1).split(","):
                            if i:
                                idx = int(i)
                                if idx < len(ldims):
                                    contract *= ldims[idx]
                c.flops += 2.0 * relems * contract
                c.bytes += rbytes
                for nm in operands:
                    t = symbols.get(nm)
                    if t:
                        c.bytes += _shape_elems_bytes(t)[1]
                continue

            # --- bytes: memory-touching ops ---
            if op in _ZERO_COPY:
                continue

            def _operand_bytes() -> List[int]:
                return [_shape_elems_bytes(symbols[nm])[1]
                        if nm in symbols else 0
                        for nm in _operand_names(ls, op)]

            if op in ("dynamic-slice", "slice", "gather"):
                # reads only the sliced region (~= result), writes result
                c.bytes += 2 * rbytes
            elif op == "dynamic-update-slice":
                # in-place: touches only the updated region (operand 1)
                ob = _operand_bytes()
                upd = ob[1] if len(ob) > 1 else rbytes
                c.bytes += 2 * upd
            elif op == "fusion" and ("dynamic-update-slice" in var
                                     or "dynamic_update_slice" in var):
                # in-place update fusion: full-buffer operand isn't traffic
                ob = _operand_bytes()
                big = max(ob) if ob else 0
                c.bytes += 2 * sum(b for b in ob if b != big) or 2 * rbytes
            elif op == "fusion" and ("dynamic-slice" in var
                                     or "dynamic_slice" in var
                                     or var.startswith("slice")):
                # slice-reading fusion: reads ~result-sized region
                c.bytes += 2 * rbytes
            elif op == "scatter":
                ob = _operand_bytes()
                upd = ob[2] if len(ob) > 2 else rbytes
                c.bytes += 2 * upd
            else:
                c.bytes += rbytes + sum(_operand_bytes())
        if consts:
            trip_counts[name] = max(consts)
        comps[name] = c

    memo: Dict[str, Tuple[float, float, Dict[str, float]]] = {}

    def totals(name: str, stack) -> Tuple[float, float, Dict[str, float]]:
        if name in memo:
            return memo[name]
        if name not in comps or name in stack:
            return 0.0, 0.0, {}
        c = comps[name]
        f, b = c.flops, c.bytes
        coll = dict(c.coll)
        for kind, ref in c.edges:
            if kind == "while":
                body, cond = ref.split("|")
                trips = trip_counts.get(cond, 1)
                sf, sb, sc = totals(body, stack | {name})
                cf, cb, cc = totals(cond, stack | {name})
                f += (sf + cf) * trips
                b += (sb + cb) * trips
                for k, v in sc.items():
                    coll[k] = coll.get(k, 0.0) + v * trips
            else:
                sf, sb, sc = totals(ref, stack | {name})
                f += sf
                if kind == "call":
                    b += sb
                # fusion-internal bytes already accounted at the fusion op
                for k, v in sc.items():
                    coll[k] = coll.get(k, 0.0) + v
        memo[name] = (f, b, coll)
        return memo[name]

    if entry is None:
        return {"flops": 0.0, "bytes": 0.0, "collectives": {"total": 0.0}}
    f, b, coll = totals(entry, frozenset())
    out_coll = {k: float(v) for k, v in coll.items()}
    out_coll["total"] = float(sum(coll.values()))
    return {"flops": float(f), "bytes": float(b), "collectives": out_coll}


def score_block_traffic(hlo_text: str,
                        chunk_sizes=(256, 512, 800, 1024, 2048)) -> float:
    """Per-device bytes attributable to materialized attention score blocks.

    The XLA-fallback chunked attention writes/reads f32 (.., qc, kc) score
    tensors through HBM; the flash Pallas kernel keeps them in VMEM. This
    classifies score-block ops by shape (ndim>=4, both trailing dims chunk-
    sized, f32) or chunk-square dots, trip-multiplied like `analyze` — the
    measured quantity the kernel deletes (EXPERIMENTS §Perf)."""
    comps_lines, entry = _parse(hlo_text)
    trips: Dict[str, float] = defaultdict(float)

    def walk(name, mult, stack):
        if name in stack:
            return
        trips[name] += mult
        for ls in comps_lines.get(name, []):
            wm = _WHILE_RE.search(ls)
            if wm:
                cond, body = wm.group(1), wm.group(2)
                consts = []
                for l2 in comps_lines.get(cond, []):
                    consts += [int(x) for x in _CONST_RE.findall(l2)]
                t = max(consts) if consts else 1
                walk(body, mult * t, stack | {name})

    if entry is None:
        return 0.0
    walk(entry, 1.0, frozenset())

    def _is_score(type_str: Optional[str], op: str = "fusion") -> bool:
        if not type_str or not type_str.startswith(("f32", "bf16")):
            return False
        dims = _dims(type_str)
        return bool(dims and len(dims) >= 2
                    and dims[-1] in chunk_sizes and dims[-2] in chunk_sizes
                    and (len(dims) >= 4 or op == "dot"))

    total = 0.0
    for name, lines in comps_lines.items():
        t = trips.get(name, 0.0)
        if not t:
            continue
        symbols: Dict[str, str] = {}
        for ls in lines:
            dm = _DEF_RE.match(ls)
            if dm:
                symbols[dm.group(1)] = dm.group(2)
        for ls in lines:
            dm = _DEF_RE.match(ls)
            if not dm:
                continue
            _, rtype, op = dm.groups()
            if op in _ZERO_COPY:
                continue
            # score-shaped results (writes)
            if _is_score(rtype, op):
                total += _shape_elems_bytes(rtype)[1] * t
            # score-shaped operands (reads at the consumer)
            paren = ls.find(op + "(")
            if paren >= 0:
                om = _OPERANDS_RE.search(ls[paren:])
                if om:
                    for nm in om.group(1).split(","):
                        ot = symbols.get(nm.strip().lstrip("%"))
                        if _is_score(ot, "operand"):
                            total += _shape_elems_bytes(ot)[1] * t
    return float(total)


def convert_traffic(hlo_text: str) -> float:
    """Per-device bytes spent on pure dtype-conversion ops (bf16<->f32).

    XLA-CPU has no native bf16 FMA: every bf16 dot operand is converted to
    f32 through memory (sometimes hoisted to whole-buffer copies). The TPU
    MXU consumes bf16 directly, so this traffic exists only in the dry-run
    backend. Classified as: standalone `convert` ops, or fusions named
    wrapped_convert / convert_* whose result is f32/bf16; counted
    (result + operands resolvable) x loop trips.
    """
    comps_lines, entry = _parse(hlo_text)
    trips: Dict[str, float] = defaultdict(float)

    def walk(name, mult, stack):
        if name in stack:
            return
        trips[name] += mult
        for ls in comps_lines.get(name, []):
            wm = _WHILE_RE.search(ls)
            if wm:
                cond, body = wm.group(1), wm.group(2)
                consts = []
                for l2 in comps_lines.get(cond, []):
                    consts += [int(x) for x in _CONST_RE.findall(l2)]
                walk(body, mult * (max(consts) if consts else 1),
                     stack | {name})

    if entry is None:
        return 0.0
    walk(entry, 1.0, frozenset())
    total = 0.0
    for name, lines in comps_lines.items():
        t = trips.get(name, 0.0)
        if not t:
            continue
        symbols: Dict[str, str] = {}
        for ls in lines:
            dm = _DEF_RE.match(ls)
            if dm:
                symbols[dm.group(1)] = dm.group(2)
        for ls in lines:
            dm = _DEF_RE.match(ls)
            if not dm:
                continue
            var, rtype, op = dm.groups()
            is_conv = (op == "convert"
                       or (op == "fusion"
                           and ("wrapped_convert" in var
                                or var.startswith("convert"))))
            if not is_conv:
                continue
            _, rb = _shape_elems_bytes(rtype)
            b = rb
            paren = ls.find(op + "(")
            if paren >= 0:
                om = _OPERANDS_RE.search(ls[paren:])
                if om:
                    for nm in om.group(1).split(","):
                        tpd = symbols.get(nm.strip().lstrip("%"))
                        if tpd:
                            b += _shape_elems_bytes(tpd)[1]
            total += b * t
    return float(total)


def parse_collectives(hlo_text: str) -> Dict[str, float]:
    """Back-compat wrapper: collective bytes by kind (+ total)."""
    return analyze(hlo_text)["collectives"]


def parse_flops_bytes(cost_analysis: dict) -> Tuple[float, float]:
    """Raw XLA numbers (while bodies counted once — kept for reference)."""
    return (float(cost_analysis.get("flops", 0.0)),
            float(cost_analysis.get("bytes accessed", 0.0)))
