"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so
importing this module never touches jax device state. The dry-run process
forces 512 host devices before any jax import; real deployments get real
TPU device counts.
"""
from __future__ import annotations

from typing import Optional, Sequence

from repro import compat
import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()[:n]
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for the production mesh, have "
            f"{len(devices)} — run under "
            f"XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            f"for the dry-run")
    return compat.make_mesh(shape, axes, devices=devices)


def make_mesh(shape: Sequence[int], axes: Sequence[str],
              devices: Optional[Sequence] = None) -> jax.sharding.Mesh:
    """Arbitrary mesh (tests / elastic rescale)."""
    n = 1
    for s in shape:
        n *= s
    devices = (devices or jax.devices())[:n]
    return compat.make_mesh(shape, axes, devices=devices)
