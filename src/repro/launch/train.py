"""Training launcher: DLRM (the paper's workload) and any assigned LM arch.

    PYTHONPATH=src python -m repro.launch.train --arch dlrm1 --steps 200
    PYTHONPATH=src python -m repro.launch.train --arch dlrm1 --steps 200 \
        --ragged --online-cache          # online ragged training + live cache
    PYTHONPATH=src python -m repro.launch.train --arch smollm-360m \
        --smoke --steps 50 --ckpt-dir /tmp/ckpt --resume

Production runs pass --mesh pod|multipod (256/512 chips); CPU runs use the
reduced smoke configs. Fault tolerance: periodic async checkpoints, resume
with --resume, straggler monitor logging.
"""
from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.checkpoint import CheckpointManager
from repro.configs.dlrm import DLRM_CONFIGS, DLRM_SMOKE
from repro.configs.registry import ARCHS, SMOKE_ARCHS
from repro.core import dlrm as dlrm_mod
from repro.data import DLRMSynthetic, LMSynthetic
from repro.distributed.fault_tolerance import StragglerMonitor
from repro.launch.mesh import make_production_mesh
from repro.models import api


def train_dlrm_ragged(args) -> float:
    """Online ragged training: row-wise sparse optimizer + (optionally) a
    live hot-row cache that re-ranks itself from the decayed histogram."""
    from repro.training import OnlineCacheConfig, OnlineTrainer

    from repro.distributed.sharding import place_row_sharded

    cfg = DLRM_SMOKE if args.smoke else DLRM_CONFIGS[args.arch]
    mesh = _mesh(args)
    key = jax.random.PRNGKey(args.seed)
    shards = mesh.shape["model"] if mesh else 1
    params = dlrm_mod.init(key, cfg, shards)
    # the arena *lives* row-sharded: the sharded train step and the sharded
    # serving cold pass both consume it in place, no per-step reshard
    params["arena"] = place_row_sharded(params["arena"], mesh)
    max_l = 2 * cfg.lookups_per_table
    cache_cfg = None
    if args.online_cache:
        cache_cfg = OnlineCacheConfig(k=args.cache_k,
                                      refresh_every=args.cache_refresh,
                                      quantize_cold=args.quantize_cold)
    telemetry = obs.Telemetry(tracing=args.trace)
    if args.trace:
        obs.enable_stage_annotations(True)
    trainer = OnlineTrainer(cfg, params, max_l=max_l,
                            sparse=not args.dense_grads,
                            cache_cfg=cache_cfg, mesh=mesh,
                            telemetry=telemetry)
    data = DLRMSynthetic(cfg, seed=args.seed)
    ckpt = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    mon = StragglerMonitor()
    start = 0
    if ckpt and args.resume and ckpt.latest_step() is not None:
        (trainer.params, trainer.opt_state), _ = ckpt.restore(
            (trainer.params, trainer.opt_state))
        start = ckpt.latest_step() + 1
        print(f"resumed from step {start - 1}")

    pad_to = args.batch_size * cfg.n_tables * max_l
    loss = float("nan")
    for step in range(start, args.steps):
        t0 = time.time()
        batch = data.ragged_batch(args.batch_size, max_l=max_l,
                                  pad_to=pad_to)
        loss = trainer.train_step(batch)
        mon.record(step, time.time() - t0)
        if step % args.log_every == 0:
            extra = (f" cache v{trainer.version}" if args.online_cache
                     else "")
            if args.quantize_cold and trainer.cold_q is not None:
                extra += f" dirty_q={int(trainer._dirty_q.sum())}"
            print(f"step {step:5d} loss {loss:.4f} "
                  f"({time.time() - t0:.3f}s){extra}")
        if ckpt and (step + 1) % args.ckpt_every == 0:
            ckpt.save_async(step, (trainer.params, trainer.opt_state))
    if ckpt:
        ckpt.wait()
    print(f"final loss {loss:.4f} "
          f"(straggler events: {len(mon.events)})")
    if args.metrics_json:
        _dump_metrics(telemetry, args.metrics_json)
    return loss


def _dump_metrics(telemetry, path: str) -> None:
    """Write the registry snapshot (+ swap events) as one JSON file."""
    import json

    with open(path, "w") as f:
        json.dump(telemetry.snapshot(), f, indent=2, default=str)
    print(f"metrics snapshot -> {path}")


def train_dlrm(args) -> float:
    if args.ragged:
        return train_dlrm_ragged(args)
    cfg = DLRM_SMOKE if args.smoke else DLRM_CONFIGS[args.arch]
    mesh = _mesh(args)
    key = jax.random.PRNGKey(args.seed)
    shards = mesh.shape["model"] if mesh else 1
    params = dlrm_mod.init(key, cfg, shards)
    opt, step_fn = dlrm_mod.make_train_step(cfg, mesh=mesh)
    opt_state = opt.init(params)
    step_jit = jax.jit(step_fn, donate_argnums=(0, 1))

    data = DLRMSynthetic(cfg, seed=args.seed)
    ckpt = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    mon = StragglerMonitor()
    start = 0
    if ckpt and args.resume and ckpt.latest_step() is not None:
        (params, opt_state), _ = ckpt.restore((params, opt_state))
        start = ckpt.latest_step() + 1
        print(f"resumed from step {start - 1}")

    loss = float("nan")
    for step in range(start, args.steps):
        t0 = time.time()
        batch = {k: jnp.asarray(v)
                 for k, v in data.batch(args.batch_size).items()}
        params, opt_state, loss = step_jit(params, opt_state, batch)
        mon.record(step, time.time() - t0)
        if step % args.log_every == 0:
            print(f"step {step:5d} loss {float(loss):.4f} "
                  f"({time.time() - t0:.3f}s)")
        if ckpt and (step + 1) % args.ckpt_every == 0:
            ckpt.save_async(step, (params, opt_state))
    if ckpt:
        ckpt.wait()
    print(f"final loss {float(loss):.4f} "
          f"(straggler events: {len(mon.events)})")
    return float(loss)


def train_lm(args) -> float:
    cfg = (SMOKE_ARCHS if args.smoke else ARCHS)[args.arch]
    mesh = _mesh(args)
    key = jax.random.PRNGKey(args.seed)
    params, _ = api.init(key, cfg)
    opt_name, opt, step_fn = api.make_train_step(cfg, mesh=mesh)
    opt_state = opt.init(params)
    step_jit = jax.jit(step_fn, donate_argnums=(0, 1))

    data = LMSynthetic(cfg, seed=args.seed)
    ckpt = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    mon = StragglerMonitor()
    start = 0
    if ckpt and args.resume and ckpt.latest_step() is not None:
        (params, opt_state), _ = ckpt.restore((params, opt_state))
        start = ckpt.latest_step() + 1
        print(f"resumed from step {start - 1}")

    loss = float("nan")
    for step in range(start, args.steps):
        t0 = time.time()
        raw = data.batch(args.batch_size, args.seq_len)
        batch = {k: jnp.asarray(v) for k, v in raw.items()}
        if "frames" in batch:
            batch["frames"] = batch["frames"].astype(jnp.bfloat16)
        if "patches" in batch:
            batch["patches"] = batch["patches"].astype(jnp.bfloat16)
        params, opt_state, metrics = step_jit(params, opt_state, batch)
        loss = metrics["loss"]
        mon.record(step, time.time() - t0)
        if step % args.log_every == 0:
            print(f"step {step:5d} loss {float(loss):.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"({time.time() - t0:.3f}s)")
        if ckpt and (step + 1) % args.ckpt_every == 0:
            ckpt.save_async(step, (params, opt_state))
    if ckpt:
        ckpt.wait()
    print(f"final loss {float(loss):.4f} "
          f"(straggler events: {len(mon.events)})")
    return float(loss)


def _mesh(args):
    if getattr(args, "shards", 1) > 1:
        if args.mesh != "none":
            raise SystemExit(
                "--shards builds its own N-way 'model' mesh and cannot be "
                "combined with --mesh pod/multipod (the production meshes "
                "fix their own model-axis width); pass one or the other")
        from repro.launch.mesh import make_mesh
        return make_mesh((args.shards,), ("model",))
    if args.mesh == "none":
        return None
    return make_production_mesh(multi_pod=(args.mesh == "multipod"))


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="dlrm1",
                   help="dlrm1..dlrm6 or an assigned LM arch id")
    p.add_argument("--smoke", action="store_true",
                   help="use the reduced config (CPU-runnable)")
    p.add_argument("--mesh", default="none",
                   choices=("none", "pod", "multipod"))
    p.add_argument("--steps", type=int, default=100)
    p.add_argument("--batch-size", type=int, default=32)
    p.add_argument("--seq-len", type=int, default=64)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--log-every", type=int, default=10)
    p.add_argument("--ckpt-dir", default=None)
    p.add_argument("--ckpt-every", type=int, default=50)
    p.add_argument("--resume", action="store_true")
    p.add_argument("--ragged", action="store_true",
                   help="DLRM: train on ragged SparseLengthsSum batches "
                        "with the row-wise sparse optimizer")
    p.add_argument("--online-cache", action="store_true",
                   help="with --ragged: maintain a live versioned hot-row "
                        "cache from the decayed trace histogram")
    p.add_argument("--dense-grads", action="store_true",
                   help="with --ragged: densified-gradient baseline "
                        "instead of the row-wise sparse optimizer")
    p.add_argument("--cache-k", type=int, default=2048)
    p.add_argument("--cache-refresh", type=int, default=50)
    p.add_argument("--quantize-cold", action="store_true",
                   help="with --online-cache: maintain an int8 cold "
                        "arena incrementally (only rows touched since "
                        "the last rebuild are re-quantized)")
    p.add_argument("--shards", type=int, default=1,
                   help="row-shard the embedding arena over an N-way "
                        "'model' mesh (DLRM; with --ragged the sparse "
                        "optimizer applies shard-local row updates)")
    p.add_argument("--metrics-json", default=None,
                   help="with --ragged: write the telemetry registry "
                        "snapshot (counters/gauges/histograms + swap "
                        "events) to this path at exit")
    p.add_argument("--trace", action="store_true",
                   help="with --ragged: collect host spans and enable "
                        "jax.profiler stage annotations in jitted code")
    args = p.parse_args()

    if args.shards > 1:
        # must land before the first backend touch; on CPU this simulates
        # the N chips the mesh needs (real TPU fleets already have them)
        flags = os.environ.get("XLA_FLAGS", "")
        if "xla_force_host_platform_device_count" not in flags:
            os.environ["XLA_FLAGS"] = (
                f"{flags} --xla_force_host_platform_device_count="
                f"{args.shards}").strip()

    if args.arch.startswith("dlrm"):
        train_dlrm(args)
    else:
        train_lm(args)


if __name__ == "__main__":
    main()
