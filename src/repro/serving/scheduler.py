"""SLA-aware continuous batching for the recommendation engine.

The synchronous serve loop (``RecEngine.step``) releases lockstep
waves: admit, pad, forward, respond — the host idles while the device
computes and vice versa, and under overload the queue (and p99) grows
without bound because every arriving request is eventually served, no
matter how stale. This module is the ROADMAP's serving plane:

* ``plan_batch`` — the admission decision as a PURE function of
  (queue waits, SLA policy, service estimates): shed the hopeless
  prefix, downgrade the batch to the int8 source when the
  full-precision path would blow the SLA, serve the rest. Pure means
  hypothesis-testable: same inputs, same plan, every time.
* ``ServiceEstimator`` — deterministic EWMA service-time model per
  (path, bucket), corrected by every settled batch.
* ``SlaScheduler`` — the continuous-batching loop itself: a FIFO
  admission queue, a pipeline of in-flight (dispatched, unsettled)
  ``InflightBatch``es so the next micro-batch is assembled while the
  previous one computes (refill, no wave barrier), and shed/downgrade
  decisions from ``plan_batch`` at every ``pump()``.

Overload behavior is explicit, not emergent: a request that cannot
make its deadline even on the cheapest path is shed AT ADMISSION — it
never touches the device, and a ``shed`` event accounts for it; a
batch whose full-precision prediction crosses the downgrade margin
serves from the engine's int8 source (``RecEngine.enable_downgrade``)
— the same jit with a different call-time pytree, pre-compiled by the
warm pool, so per-batch path selection never recompiles.

The per-slot machinery (dispatch/settle futures + a wait-ordered
queue) is deliberately engine-shape-agnostic so ``DecodeEngine``'s
aligned-wave loop can adopt it next.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass
from typing import Callable, Deque, Dict, Optional, Sequence

import numpy as np

from repro.serving.rec_engine import (InflightBatch, RecEngine,
                                      RecRequest, _bucket)

__all__ = ["BatchPlan", "ServiceEstimator", "SlaPolicy", "SlaScheduler",
           "plan_batch"]


@dataclass(frozen=True)
class SlaPolicy:
    """The serving SLA contract the scheduler enforces.

    * ``sla_ms`` — the p99 latency target.
    * ``shed_margin`` — shed a request once even the cheapest available
      path would land it past ``sla_ms * shed_margin`` (1.0 = shed at
      the SLA itself; >1 tolerates a grace band).
    * ``downgrade_margin`` — serve the batch on the int8 path once the
      full-precision prediction crosses ``sla_ms * downgrade_margin``.
      Keep it <= ``shed_margin``: downgrade is the escape hatch BEFORE
      shedding, and the planner's admitted-head-makes-the-deadline
      invariant is only guaranteed under that ordering.
    * ``max_queue`` — hard admission cap: beyond this depth ``submit``
      sheds immediately (None = unbounded, deadline shedding only).
    * ``default_service_ms`` — the estimator's cold-start prior; until a
      batch settles, plans assume this per-batch service time.
    """
    sla_ms: float = 50.0
    shed_margin: float = 1.0
    downgrade_margin: float = 0.7
    allow_shed: bool = True
    allow_downgrade: bool = True
    max_queue: Optional[int] = None
    default_service_ms: float = 5.0


@dataclass(frozen=True)
class BatchPlan:
    """One admission decision: drop ``shed`` requests from the queue
    head, dispatch the next ``serve`` (on the downgrade path when
    ``downgraded``). ``predicted_ms`` is the planned completion latency
    of the admitted head (0.0 when nothing is served)."""
    shed: int
    serve: int
    downgraded: bool
    predicted_ms: float


def plan_batch(waits_ms: Sequence[float], *, slots: int,
               policy: SlaPolicy, est_full_ms: float,
               est_cheap_ms: float, inflight_ms: float = 0.0) -> BatchPlan:
    """Decide one dispatch from the queue head — a pure function.

    ``waits_ms`` is the FIFO queue's per-request wait, head (oldest)
    first — non-increasing by construction. ``inflight_ms`` is the
    estimated device time still owed to already-dispatched batches (the
    new batch queues behind them). Decisions, in order:

    1. SHED the head prefix that cannot make ``sla_ms * shed_margin``
       even on the cheapest path (waits only grow between here and the
       device). Non-increasing waits mean the hopeless requests are
       exactly a prefix, so shedding never reorders FIFO.
    2. SERVE the next ``min(slots, remaining)`` requests.
    3. DOWNGRADE the batch to the int8 path when the admitted head's
       full-precision prediction crosses ``sla_ms * downgrade_margin``
       (and the estimator says the cheap path actually is cheaper).

    Deterministic given (queue state, policy, estimates): no clocks, no
    randomness — the hypothesis property the tests pin. When
    ``allow_shed`` and ``downgrade_margin <= shed_margin``, the
    admitted head's ``predicted_ms`` never exceeds the shed deadline.
    """
    deadline = policy.sla_ms * policy.shed_margin
    cheapest = (min(est_full_ms, est_cheap_ms) if policy.allow_downgrade
                else est_full_ms)
    n = len(waits_ms)
    shed = 0
    if policy.allow_shed:
        while shed < n and \
                waits_ms[shed] + inflight_ms + cheapest > deadline:
            shed += 1
    serve = min(int(slots), n - shed)
    if serve <= 0:
        return BatchPlan(shed=shed, serve=0, downgraded=False,
                         predicted_ms=0.0)
    head = waits_ms[shed]
    downgraded = bool(
        policy.allow_downgrade and est_cheap_ms < est_full_ms
        and head + inflight_ms + est_full_ms
        > policy.sla_ms * policy.downgrade_margin)
    predicted = head + inflight_ms + (est_cheap_ms if downgraded
                                      else est_full_ms)
    return BatchPlan(shed=shed, serve=serve, downgraded=downgraded,
                     predicted_ms=predicted)


class ServiceEstimator:
    """Deterministic EWMA service-time model per (path kind, bucket).

    Unobserved pairs fall back, in order: the nearest observed bucket
    on the same path (bucket cost is mostly fixed overhead at serving
    batch sizes, so no rescaling); an unobserved ``downgrade`` path
    borrows the primary estimate (the safe, conservative prior — the
    planner then only downgrades once a real settle shows the int8
    path cheaper); a cold estimator returns ``default_ms``.
    """

    def __init__(self, default_ms: float = 5.0, alpha: float = 0.25):
        self.default_ms = float(default_ms)
        self.alpha = float(alpha)
        self._ewma: Dict[tuple, float] = {}

    def observe(self, kind: str, bucket: int, ms: float) -> None:
        key = (kind, int(bucket))
        prev = self._ewma.get(key)
        self._ewma[key] = float(ms) if prev is None \
            else (1.0 - self.alpha) * prev + self.alpha * float(ms)

    def estimate(self, kind: str, bucket: int) -> float:
        key = (kind, int(bucket))
        if key in self._ewma:
            return self._ewma[key]
        same = [(abs(b - bucket), b) for k, b in self._ewma if k == kind]
        if same:
            return self._ewma[(kind, min(same)[1])]
        if kind == "downgrade":
            return self.estimate("primary", bucket)
        return self.default_ms


class SlaScheduler:
    """Continuous-batching admission in front of a ``RecEngine``.

    ``submit`` enqueues FIFO (or sheds on the hard queue cap); ``pump``
    is one scheduling turn — settle in-flight batches past
    ``pipeline_depth``, plan against the live queue, dispatch at most
    one micro-batch; ``drain`` settles and serves everything left (the
    end-of-stream flush — deadline shedding still applies). Invariant
    at every point: ``submitted == served + shed + queued + inflight``.

    Telemetry rides the engine's bundle: counters ``rec_shed_total`` /
    ``rec_downgraded_total`` / ``rec_refills_total``, the shared
    ``rec_queue_depth`` gauge, and ``shed`` / ``downgrade`` / ``drain``
    events — every shed request is accounted for by exactly one event.
    """

    def __init__(self, engine: RecEngine,
                 policy: Optional[SlaPolicy] = None, *,
                 pipeline_depth: int = 2,
                 estimator: Optional[ServiceEstimator] = None,
                 clock: Callable[[], float] = time.monotonic):
        policy = policy if policy is not None else SlaPolicy()
        assert pipeline_depth >= 1, pipeline_depth
        assert engine.layout != "fixed", \
            "continuous batching serves the ragged production path"
        self.engine = engine
        self.policy = policy
        self.pipeline_depth = pipeline_depth
        self.telemetry = engine.telemetry
        self._clock = clock
        self.estimator = (estimator if estimator is not None
                          else ServiceEstimator(
                              default_ms=policy.default_service_ms))
        if policy.allow_downgrade:
            engine.enable_downgrade()
        reg = self.telemetry.registry
        self._c_shed = reg.counter(
            "rec_shed_total", "requests shed at admission (SLA)")
        self._c_down = reg.counter(
            "rec_downgraded_total",
            "requests served on the int8 downgrade path")
        self._c_refill = reg.counter(
            "rec_refills_total",
            "micro-batches dispatched while another was in flight")
        self._g_queue = reg.gauge(
            "rec_queue_depth",
            "admission-queue depth (set on enqueue "
            "and after every serve/drain)")
        self._queue: Deque[RecRequest] = deque()
        self._inflight: Deque[InflightBatch] = deque()
        self.submitted = 0
        self.served = 0
        self.shed = 0
        self.downgraded = 0

    def __len__(self) -> int:
        return len(self._queue)

    @property
    def inflight(self) -> int:
        """Requests dispatched but not yet settled."""
        return sum(len(ib.reqs) for ib in self._inflight)

    def warmup(self, calibrate: bool = True) -> None:
        """Pre-trigger every (path, bucket) compile-cache entry off the
        SLA clock — with downgrade enabled this covers BOTH source
        treedefs per bucket, so refill never stalls on a compile.

        ``calibrate`` additionally times each warmed (path, bucket)
        pair (already compiled, so these are honest execution samples)
        and seeds the estimator — without it the planner would sit on
        the cold-start prior, and in particular could never discover
        the int8 path is cheaper until it had already downgraded once.
        The probes bypass dispatch/settle, so none of the engine's
        serving counters or histograms see warmup traffic.
        """
        eng = self.engine
        eng.warmup()
        if not calibrate:
            return
        dummy = [RecRequest(
            rid=-1,
            dense=np.zeros(eng.cfg.dense_features, np.float32),
            sparse_ids=[np.zeros(0, np.int32)] * eng.cfg.n_tables)]
        for bucket in eng.buckets:
            batch, _ = eng._assemble(dummy, bucket)
            probes = [("primary", lambda: eng._run_serve(batch))]
            if eng.downgrade_source is not None:
                probes.append(("downgrade",
                               lambda: eng._serve(eng.params, batch,
                                                  eng.downgrade_source)))
            samples = {kind: [] for kind, _ in probes}
            for _ in range(3):          # interleaved: share clock drift
                for kind, run in probes:
                    t0 = self._clock()
                    np.asarray(run())
                    samples[kind].append((self._clock() - t0) * 1e3)
            for kind, ms in samples.items():
                self.estimator.observe(kind, bucket,
                                       float(np.median(ms)))

    # -- admission ----------------------------------------------------------

    def submit(self, req: RecRequest) -> bool:
        """Enqueue FIFO; returns False when the hard queue cap shed it."""
        self.submitted += 1
        if self.policy.max_queue is not None and self.policy.allow_shed \
                and len(self._queue) >= self.policy.max_queue:
            self._shed_one(req, reason="queue_full")
            return False
        self._queue.append(req)
        if self.telemetry.enabled:
            self._g_queue.set(len(self._queue))
        return True

    def _shed_one(self, req: RecRequest, reason: str) -> None:
        req.shed = True
        req.finished_at = time.time()
        self.shed += 1
        if self.telemetry.enabled:
            self._c_shed.inc()
        self.telemetry.emit(
            "shed", version=self.engine.source_version, rid=req.rid,
            reason=reason,
            waited_ms=(self._clock() - req.submitted_mono) * 1e3)

    # -- the scheduling turn ------------------------------------------------

    def _plan(self) -> BatchPlan:
        now = self._clock()
        waits = [(now - r.submitted_mono) * 1e3 for r in self._queue]
        slots = self.engine.max_batch
        bucket = _bucket(min(len(waits), slots), self.engine.buckets)
        est_full = self.estimator.estimate("primary", bucket)
        est_cheap = (self.estimator.estimate("downgrade", bucket)
                     if self.policy.allow_downgrade else est_full)
        inflight_ms = 0.0
        for ib in self._inflight:
            kind = "downgrade" if ib.downgraded else "primary"
            est = self.estimator.estimate(kind, ib.bucket)
            inflight_ms += max(
                0.0, est - (now - ib.dispatched_mono) * 1e3)
        return plan_batch(waits, slots=slots, policy=self.policy,
                          est_full_ms=est_full, est_cheap_ms=est_cheap,
                          inflight_ms=inflight_ms)

    def _apply(self, plan: BatchPlan) -> None:
        for _ in range(plan.shed):
            self._shed_one(self._queue.popleft(), reason="deadline")
        if plan.serve > 0:
            reqs = [self._queue.popleft() for _ in range(plan.serve)]
            if plan.downgraded:
                self.downgraded += plan.serve
                if self.telemetry.enabled:
                    self._c_down.inc(plan.serve)
                self.telemetry.emit(
                    "downgrade", version=self.engine.source_version,
                    n=plan.serve, rid0=reqs[0].rid,
                    predicted_ms=plan.predicted_ms)
            if self._inflight and self.telemetry.enabled:
                self._c_refill.inc()
            self._inflight.append(
                self.engine.dispatch(reqs, downgraded=plan.downgraded))
        if self.telemetry.enabled:
            self._g_queue.set(len(self._queue))

    def _settle_one(self) -> int:
        ib = self._inflight.popleft()
        n = self.engine.settle(ib)
        self.served += n
        self.estimator.observe(
            "downgrade" if ib.downgraded else "primary", ib.bucket,
            (self._clock() - ib.dispatched_mono) * 1e3)
        return n

    def pump(self) -> int:
        """One scheduling turn; returns requests settled this turn.

        Settles any batch past the pipeline depth (its device work
        finished while newer batches were assembled), then plans and
        dispatches at most one refill micro-batch. Idle turns (empty
        queue) settle one in-flight batch early so responses never wait
        for the next arrival.
        """
        settled = 0
        while len(self._inflight) >= self.pipeline_depth:
            settled += self._settle_one()
        if self._queue:
            self._apply(self._plan())
        elif self._inflight:
            settled += self._settle_one()
        return settled

    def drain(self) -> int:
        """Settle every in-flight batch and serve the remaining queue;
        emits the final ``drain`` event. Returns requests served here."""
        n = 0
        while self._queue or self._inflight:
            if self._queue:
                self._apply(self._plan())
            if self._inflight:
                n += self._settle_one()
        self.engine._collect_pending()   # reporting boundary
        if self.telemetry.enabled:
            self._g_queue.set(0)
        self.telemetry.emit(
            "drain", version=self.engine.source_version,
            served=self.served, shed=self.shed,
            downgraded=self.downgraded)
        return n

    # -- reporting ----------------------------------------------------------

    def stats(self) -> Dict:
        """Engine latency stats plus the scheduler's admission ledger
        (shed/downgrade fractions are of all submitted requests)."""
        out = dict(self.engine.stats())
        denom = self.submitted or 1
        out.update(
            submitted=self.submitted, served=self.served,
            shed=self.shed, downgraded=self.downgraded,
            queued=len(self._queue), inflight=self.inflight,
            shed_frac=self.shed / denom,
            downgrade_frac=self.downgraded / denom)
        if self.telemetry.enabled:
            qw = self.telemetry.registry.histogram(
                "rec_queue_wait_ms",
                "admission-to-dispatch queue wait")
            if qw.count:
                out["queue_wait_p50_ms"] = qw.percentile(50)
                out["queue_wait_p99_ms"] = qw.percentile(99)
        return out
