"""Recommendation serving engine — the paper's actual deployment target.

The LM engine (``engine.py``) serves token streams; this engine serves
CTR-prediction traffic (paper Section IV-A: user-facing inference with firm
SLAs) over the ragged production sparse path:

* ``RecRequest`` — one user impression: dense features + per-table ragged
  sparse id lists (the SparseLengthsSum format of paper Fig. 2);
* ``RecBatcher`` — admission queue with (max_batch, max_wait_ms)
  micro-batching, the standard SLA/throughput knob;
* ``RecEngine`` — drains the batcher, pads each micro-batch to a static
  *bucket* shape (batch rounded up to a bucket size with empty-bag dummy
  rows, flat index stream padded to bucket*T*max_l) so every bucket
  compiles exactly once, then serves one ragged forward whose embedding
  stage is a single ``embedding_source.lookup_bags`` over the engine's
  ``EmbeddingSource`` pytree.

  WHICH source serves is a declarative plan, not a kwarg soup: the engine
  takes ``source=`` as a ``SourceSpec`` (or an already-built
  ``EmbeddingSource``), with the old path strings kept as thin aliases:

    - ``"fixed"``   — legacy fixed-L layout (regression baseline);
    - ``"ragged"``  — fp arena, row-sharded when the plan has a mesh;
    - ``"sharded"`` — ragged with the mesh *required* (a misconfigured
                      replica can never silently fall back to replicated);
    - ``"cached"``  — hot-row cache over any cold source (fp or int8,
                      replicated or sharded).

  The source is a call-time jit argument, so ``update_source`` swaps ANY
  component — hot cache, quantized cold arena, the full fp arena —
  without recompiling (same treedef + leaf shapes = compiled-cache hit),
  and stale (lower-version) swaps are rejected at this boundary.

  Per-request latency percentiles (p50/p95/p99) are exported by
  ``stats()``; hit-rate accounting is per-path-correct: a non-cached
  source reports ``cache_hit_rate=None`` (never a fake 0.0), and the
  counters reset on version bumps so the post-swap rate reflects the
  live cache.
"""
from __future__ import annotations

import time
import warnings
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import DLRMConfig
from repro.core import dlrm
from repro.core import embedding_source as es
from repro.core import sparse_engine as se
from repro.core.embedding_source import SourceSpec


@dataclass
class RecRequest:
    rid: int
    dense: np.ndarray                   # (dense_features,) float32
    sparse_ids: List[np.ndarray]        # per table: (l_t,) int32, l_t<=max_l
    submitted_at: float = field(default_factory=time.time)
    started_at: Optional[float] = None
    finished_at: Optional[float] = None
    prob: Optional[float] = None        # predicted CTR, set when served


class RecBatcher:
    """Admission queue: release a micro-batch when it is full or when the
    oldest request has waited max_wait_ms (the SLA knob)."""

    def __init__(self, max_batch: int = 32, max_wait_ms: float = 2.0):
        self.max_batch = max_batch
        self.max_wait_ms = max_wait_ms
        self._queue: List[RecRequest] = []

    def __len__(self) -> int:
        return len(self._queue)

    def submit(self, req: RecRequest):
        self._queue.append(req)

    def take(self, force: bool = False) -> List[RecRequest]:
        if not self._queue:
            return []
        oldest = time.time() - self._queue[0].submitted_at
        if force or len(self._queue) >= self.max_batch \
                or oldest * 1e3 >= self.max_wait_ms:
            batch = self._queue[:self.max_batch]
            self._queue = self._queue[self.max_batch:]
            return batch
        return []


def _bucket(n: int, buckets: Sequence[int]) -> int:
    for b in buckets:
        if n <= b:
            return b
    return buckets[-1]


def tune_buckets(sizes: Sequence[int], max_batch: int,
                 n_buckets: int = 6) -> tuple:
    """Pick pad-bucket boundaries from an observed micro-batch-size
    histogram instead of fixed powers of two.

    Boundaries are the ceil-quantiles of the observed sizes (equal traffic
    mass per bucket), deduplicated, with max_batch always present as the
    catch-all. Fewer distinct observed sizes than n_buckets simply yields
    fewer buckets — each observed size then pads to itself (zero waste).
    Observed sizes above max_batch clip to it: the batcher never releases
    more than max_batch, so a larger bucket would only be compiled, never
    hit.
    """
    if len(sizes) == 0:
        return tuple(sorted({1, max_batch}))
    arr = np.sort(np.minimum(np.asarray(sizes, np.int64), max_batch))
    qs = [arr[min(len(arr) - 1, int(np.ceil((i + 1) / n_buckets * len(arr)))
                 - 1)] for i in range(n_buckets)]
    out = sorted({int(q) for q in qs if q >= 1} | {max_batch})
    return tuple(out)


class RecEngine:
    """Batcher-fed DLRM inference; the embedding stage is ONE
    ``lookup_bags`` over a swappable ``EmbeddingSource`` pytree.

    ``source`` accepts:
      * a path string — ``'fixed' | 'ragged' | 'sharded' | 'cached'`` —
        the thin aliases onto a ``SourceSpec`` (cache_k / quantize_cold /
        mesh feed the plan);
      * a ``SourceSpec`` — the declarative plan, built against
        ``params['arena']`` (+ ``cache_trace`` for the hot ranking); a
        plan with ``tables=`` (per-table ``TablePlan``s) builds a
        heterogeneous ``TableGroupSource`` from ``params['tables']`` and
        a *list* of per-table trace histograms, and ``stats()`` reports
        ``cache_hit_rate`` as a per-table mapping (None for members
        without a hot cache);
      * an ``EmbeddingSource`` — served as-is (ragged layout).
    """

    PATHS = SourceSpec.PATH_NAMES

    def __init__(self, cfg: DLRMConfig, params: Dict, *,
                 source: Union[str, SourceSpec, es.EmbeddingSource,
                               None] = None,
                 max_l: Optional[int] = None,
                 max_batch: int = 32, max_wait_ms: float = 2.0,
                 buckets: Sequence[int] = (1, 2, 4, 8, 16, 32),
                 cache_k: int = 0, cache_trace=None,
                 quantize_cold: bool = False,
                 auto_tune_after: Optional[int] = None,
                 mesh: Optional[jax.sharding.Mesh] = None,
                 path: Optional[str] = None):
        if path is not None:
            warnings.warn(
                "RecEngine(path=...) is deprecated; pass source=<path "
                "string | SourceSpec | EmbeddingSource> instead",
                DeprecationWarning, stacklevel=2)
            assert source is None, "pass source= OR path=, not both"
            source = path
        self.cfg = cfg
        self.source: Optional[es.EmbeddingSource] = None
        self.params = params
        self.spec = dlrm.arena_spec(cfg)
        self.max_l = max_l if max_l is not None else cfg.lookups_per_table
        self.mesh = mesh
        self.batcher = RecBatcher(max_batch, max_wait_ms)
        self.max_batch = max_batch
        self.buckets = tuple(sorted(set(buckets) | {max_batch}))
        self.auto_tune_after = auto_tune_after
        self._retuned = False
        self.batch_sizes: List[int] = []     # observed micro-batch sizes
        self.latencies: List[float] = []
        self.served = 0
        self._hits = 0.0                     # per-table arrays for groups
        self._lookups = 0
        self.source_version = 0

        if source is None:
            source = "ragged"
        if isinstance(source, (str, SourceSpec)):
            self.plan: Optional[SourceSpec] = SourceSpec.from_path(
                source, cache_k=cache_k, quantize_cold=quantize_cold,
                mesh=mesh)
            self.path = self.plan.path_name()
            # a table-group plan builds from the per-table arenas (and a
            # LIST of per-table trace histograms)
            arena = (params["tables"] if self.plan.tables is not None
                     else params["arena"])
            self.source = self.plan.build(arena, self.spec, cache_trace)
        else:
            assert isinstance(source, es.EmbeddingSource), source
            assert not cache_k and cache_trace is None \
                and not quantize_cold, \
                ("cache_k/cache_trace/quantize_cold are SourceSpec plan "
                 "inputs; a pre-built EmbeddingSource is served as-is — "
                 "compose a CachedSource/QuantizedArena yourself or pass "
                 "a SourceSpec instead of silently dropping the kwargs")
            self.plan = None
            self.path = es.describe_source(source)
            self.source = source
        self.layout = ("fixed" if self.plan is not None
                       and self.plan.layout == "fixed" else "ragged")

        if self.layout == "fixed":
            step = dlrm.make_serve_step(cfg, mesh)
            self._serve = jax.jit(step)
        else:
            # the source is a call-time pytree argument so update_source
            # can swap any component — hot cache, int8 cold arena, the
            # full fp arena — without recompiling (same treedef + leaf
            # shapes = compiled-cache hit)
            step = dlrm.make_ragged_serve_step(cfg, max_l=self.max_l,
                                               mesh=mesh)
            self._serve = jax.jit(step)
        if self.grouped:
            # the whole source is the jit argument, so per-table hit
            # accounting survives every no-recompile member swap; the
            # engine's static max_l lets the counters ride the same
            # one-relayout fused dispatch as the lookup itself
            self._hit_rate = jax.jit(
                lambda s, i, o: es.group_hit_counts(s, i, o,
                                                    max_l=self.max_l))
        else:
            self._hit_rate = jax.jit(
                lambda c, i, o: se.cache_hit_rate(c, self.spec, i, o))
        self._reset_hit_counters()

    @property
    def grouped(self) -> bool:
        """Serving a heterogeneous TableGroupSource?"""
        return isinstance(self.source, es.TableGroupSource)

    def _reset_hit_counters(self) -> None:
        if self.grouped:
            t = len(self.source.members)
            self._hits = np.zeros(t, np.int64)
            self._lookups = np.zeros(t, np.int64)
        else:
            self._hits = 0.0
            self._lookups = 0

    # -- the swap boundary --------------------------------------------------

    @property
    def params(self) -> Dict:
        return self._params

    @params.setter
    def params(self, params: Dict) -> None:
        """Swapping the live params rebinds the source's fp-arena leaves,
        so 'params and cache swap together' keeps meaning one assignment
        plus one ``update_cache`` — exactly the pre-API protocol. The
        rebound source has identical leaf shapes, so no recompile."""
        self._params = params
        if getattr(self, "source", None) is not None:
            arena = (params["tables"]
                     if isinstance(self.source, es.TableGroupSource)
                     else params["arena"])
            self.source = es.rebind_arena(self.source, arena)

    @property
    def cache(self) -> Optional[se.HotRowCache]:
        """The hot cache currently served (None on non-cached sources)."""
        return es.hot_cache_of(self.source)

    @property
    def cache_version(self) -> int:
        """Back-compat alias for ``source_version``."""
        return self.source_version

    def update_source(self, source: es.EmbeddingSource,
                      version: Optional[int] = None) -> None:
        """Atomically swap the served embedding source (any component:
        hot cache, quantized cold arena, full fp arena).

        The whole source pytree is replaced at once — no torn state. The
        new source must match the old one's treedef and leaf shapes /
        dtypes, which is exactly the no-recompile condition: the jit'd
        serve step sees the same compiled signature.

        Stale broadcasts are rejected: a versioned swap to anything below
        the currently served version would re-serve rows the trainer has
        since rewritten (broadcast artifacts arrive out of order across a
        fleet). Equal versions are allowed — between rebuilds the trainer
        republishes the same version with write-through-patched values.
        Hit/lookup counters reset on version bumps so the reported hit
        rate reflects the live cache, not its predecessors.
        """
        assert self.layout != "fixed", \
            ("a fixed-layout engine serves from params['arena'] and "
             "never reads engine.source — accepting this swap would "
             "bump the version while serving stale embeddings forever")
        if version is not None and version < self.source_version:
            raise ValueError(
                f"stale source broadcast: version {version} < served "
                f"version {self.source_version} — reordered artifact, "
                f"refusing to roll the serving source back")
        old_leaves, old_def = jax.tree_util.tree_flatten(self.source)
        new_leaves, new_def = jax.tree_util.tree_flatten(source)
        assert old_def == new_def, \
            ("source swap changed the pytree structure — this forces a "
             "recompile on the serving hot path", old_def, new_def)
        assert all(a.shape == b.shape and a.dtype == b.dtype
                   for a, b in zip(old_leaves, new_leaves)), \
            ("source swap changed leaf shapes/dtypes — this forces a "
             "recompile on the serving hot path; keep trainer and engine "
             "cache_k / arena shapes equal")
        new_version = (version if version is not None
                       else self.source_version + 1)
        self.source = source
        if new_version > self.source_version:
            # per-path-correct accounting: the old cache's hits must not
            # dilute the post-swap hit rate
            self._reset_hit_counters()
        self.source_version = new_version

    def update_cache(self, cache: se.HotRowCache,
                     version: Optional[int] = None) -> None:
        """Swap only the hot cache, keeping the cold source (the classic
        online-training refresh; see ``update_source`` for the rules)."""
        assert isinstance(self.source, es.CachedSource), \
            "update_cache needs a cached source"
        if version is not None and version < self.source_version:
            raise ValueError(
                f"stale cache broadcast: version {version} < served "
                f"version {self.source_version} — reordered artifact, "
                f"refusing to roll the hot arena back")
        assert cache.hot_rows.shape == self.source.hot.hot_rows.shape, \
            ("cache swap changed K/D — this forces a recompile on the "
             "serving hot path; keep trainer and engine cache_k equal",
             cache.hot_rows.shape, self.source.hot.hot_rows.shape)
        self.update_source(es.with_hot_cache(self.source, cache),
                           version=version)

    def warmup(self):
        """Compile every bucket shape off the SLA clock.

        Without this the first live request landing in each bucket pays
        that bucket's jit compile (hundreds of ms) — a p99 spike that
        would show up as an SLA violation in production.
        """
        t = self.cfg.n_tables
        l = self.cfg.lookups_per_table if self.layout == "fixed" else 0
        dummy = [RecRequest(
            rid=-1, dense=np.zeros(self.cfg.dense_features, np.float32),
            sparse_ids=[np.zeros(l, np.int32)] * t)]
        for bucket in self.buckets:
            batch = self._assemble(dummy, bucket)
            np.asarray(self._run_serve(batch))
            if self.grouped:
                h, _ = self._hit_rate(self.source, batch["indices"],
                                      batch["offsets"])
                h.block_until_ready()
            elif self.cache is not None:
                self._hit_rate(self.cache, batch["indices"],
                               batch["offsets"]).block_until_ready()

    def _run_serve(self, batch: Dict):
        if self.layout == "fixed":
            return self._serve(self.params, batch)
        return self._serve(self.params, batch, self.source)

    def retune_buckets(self, n_buckets: int = 6,
                       warmup: bool = True) -> tuple:
        """Re-pick bucket boundaries from the observed batch-size histogram
        (ROADMAP: dynamic bucket tuning) and pre-compile the new shapes."""
        self.buckets = tune_buckets(self.batch_sizes, self.max_batch,
                                    n_buckets)
        if warmup:
            self.warmup()
        return self.buckets

    # -- request plumbing ---------------------------------------------------

    def submit(self, req: RecRequest):
        assert len(req.sparse_ids) == self.cfg.n_tables, \
            (len(req.sparse_ids), self.cfg.n_tables)
        self.batcher.submit(req)

    def _assemble(self, reqs: List[RecRequest], bucket: int) -> Dict:
        """Pad a micro-batch to its bucket's static shapes."""
        t = self.cfg.n_tables
        dense = np.zeros((bucket, self.cfg.dense_features), np.float32)
        for i, r in enumerate(reqs):
            dense[i] = r.dense
        if self.layout == "fixed":
            l = self.cfg.lookups_per_table
            idx = np.zeros((bucket, t, l), np.int32)
            for i, r in enumerate(reqs):
                for j, ids in enumerate(r.sparse_ids):
                    assert len(ids) == l, \
                        "fixed path requires exact-length bags"
                    idx[i, j] = ids
            # dummy rows gather row 0 — harmless, their outputs are dropped
            return {"dense": jnp.asarray(dense), "indices": jnp.asarray(idx)}
        lens = np.zeros(bucket * t, np.int32)
        for i, r in enumerate(reqs):
            for j, ids in enumerate(r.sparse_ids):
                assert len(ids) <= self.max_l, (len(ids), self.max_l)
                lens[i * t + j] = len(ids)
        offsets = np.zeros(bucket * t + 1, np.int32)
        np.cumsum(lens, out=offsets[1:])
        flat = np.zeros(bucket * t * self.max_l, np.int32)  # static cap
        for i, r in enumerate(reqs):
            for j, ids in enumerate(r.sparse_ids):
                o = offsets[i * t + j]
                flat[o:o + len(ids)] = ids
        return {"dense": jnp.asarray(dense), "indices": jnp.asarray(flat),
                "offsets": jnp.asarray(offsets)}

    def step(self, force: bool = False) -> int:
        """Drain one micro-batch through the engine; returns #served."""
        reqs = self.batcher.take(force=force)
        if not reqs:
            return 0
        # retune BEFORE the SLA clocks start: compiling the fresh bucket
        # shapes must not land on this micro-batch's recorded latency
        if self.auto_tune_after is not None and not self._retuned \
                and len(self.batch_sizes) >= self.auto_tune_after:
            self._retuned = True
            self.retune_buckets()
        now = time.time()
        for r in reqs:
            r.started_at = now
        self.batch_sizes.append(len(reqs))
        bucket = _bucket(len(reqs), self.buckets)
        batch = self._assemble(reqs, bucket)
        probs = np.asarray(self._run_serve(batch))
        if self.grouped:
            if int(batch["offsets"][-1]):
                h, lk = self._hit_rate(self.source, batch["indices"],
                                       batch["offsets"])
                self._hits += np.asarray(h, np.int64)
                self._lookups += np.asarray(lk, np.int64)
        elif self.cache is not None:
            n = int(batch["offsets"][-1])
            if n:
                hr = float(self._hit_rate(self.cache, batch["indices"],
                                          batch["offsets"]))
                self._hits += hr * n
                self._lookups += n
        done = time.time()
        for i, r in enumerate(reqs):
            r.prob = float(probs[i])
            r.finished_at = done
            self.latencies.append(done - r.submitted_at)
        self.served += len(reqs)
        return len(reqs)

    def drain(self) -> int:
        """Serve everything still queued (end-of-stream flush)."""
        n = 0
        while len(self.batcher):
            n += self.step(force=True)
        return n

    # -- reporting ----------------------------------------------------------

    def stats(self) -> Dict[str, float]:
        if not self.latencies:
            return {"n": 0}
        arr = np.asarray(self.latencies)
        out = {"n": len(arr),
               "path": self.path,
               "source": es.describe_source(self.source),
               # nested compositions one-per-line (the compact label above
               # is unreadable for deep/grouped sources)
               "source_tree": es.describe_source(self.source,
                                                 multiline=True),
               "p50_ms": float(np.percentile(arr, 50) * 1e3),
               "p95_ms": float(np.percentile(arr, 95) * 1e3),
               "p99_ms": float(np.percentile(arr, 99) * 1e3),
               "mean_ms": float(arr.mean() * 1e3)}
        # per-path-correct: None (not a fake 0.0) when no hot cache is
        # serving, or when no lookups have hit the live cache version yet
        if self.grouped:
            # per-table mapping; None preserved for non-cached members
            out["cache_hit_rate"] = {
                t: (float(self._hits[t] / self._lookups[t])
                    if self._lookups[t] else None)
                if es.hot_cache_of(m) is not None else None
                for t, m in enumerate(self.source.members)}
            out["cache_version"] = self.source_version
        elif self.cache is None:
            out["cache_hit_rate"] = None
        else:
            out["cache_hit_rate"] = (self._hits / self._lookups
                                     if self._lookups else None)
            out["cache_version"] = self.source_version
        out["buckets"] = self.buckets
        return out


def requests_from_ragged_batch(batch: Dict[str, np.ndarray], n_tables: int,
                               rid0: int = 0) -> List[RecRequest]:
    """Explode a DLRMSynthetic.ragged_batch into individual requests."""
    off = batch["offsets"]
    b = (len(off) - 1) // n_tables
    out = []
    for i in range(b):
        ids = [batch["indices"][off[i * n_tables + j]:
                                off[i * n_tables + j + 1]]
               for j in range(n_tables)]
        out.append(RecRequest(rid=rid0 + i, dense=batch["dense"][i],
                              sparse_ids=ids))
    return out
