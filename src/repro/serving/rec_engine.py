"""Recommendation serving engine — the paper's actual deployment target.

The LM engine (``engine.py``) serves token streams; this engine serves
CTR-prediction traffic (paper Section IV-A: user-facing inference with firm
SLAs) over the ragged production sparse path:

* ``RecRequest`` — one user impression: dense features + per-table ragged
  sparse id lists (the SparseLengthsSum format of paper Fig. 2);
* ``RecBatcher`` — admission queue with (max_batch, max_wait_ms)
  micro-batching, the standard SLA/throughput knob;
* ``RecEngine`` — drains the batcher, pads each micro-batch to a static
  *bucket* shape (batch rounded up to a bucket size with empty-bag dummy
  rows, flat index stream padded to bucket*T*max_l) so every bucket
  compiles exactly once, then serves one ragged forward whose embedding
  stage is a single ``embedding_source.lookup_bags`` over the engine's
  ``EmbeddingSource`` pytree.

  WHICH source serves is a declarative plan, not a kwarg soup: the engine
  takes ``source=`` as a ``SourceSpec`` (or an already-built
  ``EmbeddingSource``), with the old path strings kept as thin aliases:

    - ``"fixed"``   — legacy fixed-L layout (regression baseline);
    - ``"ragged"``  — fp arena, row-sharded when the plan has a mesh;
    - ``"sharded"`` — ragged with the mesh *required* (a misconfigured
                      replica can never silently fall back to replicated);
    - ``"cached"``  — hot-row cache over any cold source (fp or int8,
                      replicated or sharded).

  The source is a call-time jit argument, so ``update_source`` swaps ANY
  component — hot cache, quantized cold arena, the full fp arena —
  without recompiling (same treedef + leaf shapes = compiled-cache hit),
  and stale (lower-version) swaps are rejected at this boundary.

  Telemetry is first-class (``repro.obs``): the engine takes a
  ``Telemetry`` bundle and backs everything observable with it —
  bounded-memory latency/batch-size histograms (O(1) in requests served;
  the old unbounded ``latencies``/``batch_sizes`` lists survive only as
  ring-backed compatibility properties), per-request spans through
  ``enqueue → batch → bucket_pad → forward → respond`` (or the per-stage
  split below), and a structured event log of the swap protocol with
  per-version hit-rate attribution
  (``telemetry.events.hit_rate_by_version()``).

  Hit-rate accounting never adds a device sync to the hot path: the
  per-batch probe is *dispatched* in ``step()`` but only *collected*
  (host conversion of the result futures) at ``stats()`` / ``drain()`` /
  swap boundaries, or when the pending queue hits ``PENDING_MAX``
  entries — by which point those futures completed long ago.

  ``Telemetry(device_stages=True)`` serves through separately jitted
  pipeline stages with a sync between each, attributing *device* time to
  sparse lookup vs. interaction vs. top MLP — the paper's Fig-5
  embedding-vs-MLP characterization measured live (``live_fig5()``).

  Per-request latency percentiles (p50/p95/p99) are exported by
  ``stats()`` — cumulative plus ``since_swap``/``rolling`` windows so a
  post-swap regression is visible instead of averaged away; hit-rate
  accounting is per-path-correct: a non-cached source reports
  ``cache_hit_rate=None`` (never a fake 0.0), and the counters reset on
  version bumps so the post-swap rate reflects the live cache.
"""
from __future__ import annotations

import time
import warnings
from collections import deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.configs.base import DLRMConfig
from repro.core import dlrm
from repro.core import embedding_source as es
from repro.core import sparse_engine as se
from repro.core.embedding_source import SourceSpec


@dataclass
class RecRequest:
    rid: int
    dense: np.ndarray                   # (dense_features,) float32
    sparse_ids: List[np.ndarray]        # per table: (l_t,) int32, l_t<=max_l
    # wall-clock stamps are USER-FACING only (log lines, dashboards);
    # every deadline / latency computation runs on submitted_mono — an
    # NTP step must never flush a batch early or stall it past its wait
    # budget, and must never corrupt a recorded latency
    submitted_at: float = field(default_factory=time.time)
    submitted_mono: float = field(default_factory=time.monotonic)
    started_at: Optional[float] = None
    finished_at: Optional[float] = None
    prob: Optional[float] = None        # predicted CTR, set when served
    shed: bool = False                  # dropped at admission (SLA)
    downgraded: bool = False            # served on the int8 downgrade path
    # (per_id, table) id streams for host-cold staging, extracted once at
    # admission (engine-internal; None until an engine with a host cold
    # tier touches the request)
    cold_streams: Optional[tuple] = None


class RecBatcher:
    """Admission queue: release a micro-batch when it is full or when the
    oldest request has waited max_wait_ms (the SLA knob).

    Deadline math runs on the monotonic clock (``clock`` is injectable
    for tests) against ``RecRequest.submitted_mono`` — wall clock is
    kept only for the user-facing ``submitted_at`` stamp.
    """

    def __init__(self, max_batch: int = 32, max_wait_ms: float = 2.0,
                 clock=time.monotonic):
        self.max_batch = max_batch
        self.max_wait_ms = max_wait_ms
        self._clock = clock
        self._queue: List[RecRequest] = []

    def __len__(self) -> int:
        return len(self._queue)

    def submit(self, req: RecRequest):
        self._queue.append(req)

    def take(self, force: bool = False) -> List[RecRequest]:
        if not self._queue:
            return []
        oldest = self._clock() - self._queue[0].submitted_mono
        if force or len(self._queue) >= self.max_batch \
                or oldest * 1e3 >= self.max_wait_ms:
            batch = self._queue[:self.max_batch]
            self._queue = self._queue[self.max_batch:]
            return batch
        return []


def _bucket(n: int, buckets: Sequence[int]) -> int:
    for b in buckets:
        if n <= b:
            return b
    return buckets[-1]


def tune_buckets(sizes: Sequence[int], max_batch: int,
                 n_buckets: int = 6) -> tuple:
    """Pick pad-bucket boundaries from an observed micro-batch-size
    histogram instead of fixed powers of two.

    Boundaries are the ceil-quantiles of the observed sizes (equal traffic
    mass per bucket), deduplicated, with max_batch always present as the
    catch-all. Fewer distinct observed sizes than n_buckets simply yields
    fewer buckets — each observed size then pads to itself (zero waste).
    Observed sizes above max_batch clip to it: the batcher never releases
    more than max_batch, so a larger bucket would only be compiled, never
    hit.
    """
    if len(sizes) == 0:
        return tuple(sorted({1, max_batch}))
    arr = np.sort(np.minimum(np.asarray(sizes, np.int64), max_batch))
    qs = [arr[min(len(arr) - 1, int(np.ceil((i + 1) / n_buckets * len(arr)))
                 - 1)] for i in range(n_buckets)]
    out = sorted({int(q) for q in qs if q >= 1} | {max_batch})
    return tuple(out)


@dataclass
class InflightBatch:
    """A dispatched-but-unsettled micro-batch: the device-array future
    plus just enough host context to account for it at settle time.
    Produced by ``RecEngine.dispatch``, consumed by ``RecEngine.settle``
    — the unit of continuous batching (``repro.serving.scheduler``)."""
    reqs: List[RecRequest]
    probs: object                       # device array, NOT host-converted
    bucket: int
    downgraded: bool
    dispatched_mono: float


_STAGE_NAMES = ("sparse_lookup", "interaction", "mlp")


class RecEngine:
    """Batcher-fed DLRM inference; the embedding stage is ONE
    ``lookup_bags`` over a swappable ``EmbeddingSource`` pytree.

    ``source`` accepts:
      * a path string — ``'fixed' | 'ragged' | 'sharded' | 'cached'`` —
        the thin aliases onto a ``SourceSpec`` (cache_k / quantize_cold /
        mesh feed the plan);
      * a ``SourceSpec`` — the declarative plan, built against
        ``params['arena']`` (+ ``cache_trace`` for the hot ranking); a
        plan with ``tables=`` (per-table ``TablePlan``s) builds a
        heterogeneous ``TableGroupSource`` from ``params['tables']`` and
        a *list* of per-table trace histograms, and ``stats()`` reports
        ``cache_hit_rate`` as a per-table mapping (None for members
        without a hot cache);
      * an ``EmbeddingSource`` — served as-is (ragged layout).

    ``telemetry`` is the ``repro.obs.Telemetry`` bundle (default: metrics
    on, tracing off). ``obs.Telemetry.disabled()`` serves genuinely
    uninstrumented — nothing recorded, no hit-rate probe dispatched (the
    ``obs_overhead`` benchmark baseline).
    """

    PATHS = SourceSpec.PATH_NAMES
    # pending hit-rate probes are collected (host-converted) past this
    # depth; by then the oldest futures completed many batches ago, so
    # the conversion is a read, not a stall
    PENDING_MAX = 64

    def __init__(self, cfg: DLRMConfig, params: Dict, *,
                 source: Union[str, SourceSpec, es.EmbeddingSource,
                               None] = None,
                 max_l: Optional[int] = None,
                 max_batch: int = 32, max_wait_ms: float = 2.0,
                 buckets: Sequence[int] = (1, 2, 4, 8, 16, 32),
                 cache_k: int = 0, cache_trace=None,
                 quantize_cold: bool = False,
                 auto_tune_after: Optional[int] = None,
                 mesh: Optional[jax.sharding.Mesh] = None,
                 telemetry: Optional[obs.Telemetry] = None,
                 path: Optional[str] = None):
        if path is not None:
            warnings.warn(
                "RecEngine(path=...) is deprecated; pass source=<path "
                "string | SourceSpec | EmbeddingSource> instead",
                DeprecationWarning, stacklevel=2)
            assert source is None, "pass source= OR path=, not both"
            source = path
        self.cfg = cfg
        self.source: Optional[es.EmbeddingSource] = None
        self.params = params
        self.spec = dlrm.arena_spec(cfg)
        self.max_l = max_l if max_l is not None else cfg.lookups_per_table
        self.mesh = mesh
        self.batcher = RecBatcher(max_batch, max_wait_ms)
        self.max_batch = max_batch
        self.buckets = tuple(sorted(set(buckets) | {max_batch}))
        self.auto_tune_after = auto_tune_after
        self._retuned = False
        self.served = 0
        self._hits = 0.0                     # per-table arrays for groups
        self._lookups = 0
        self._pending: List[tuple] = []      # dispatched, uncollected probes
        self.source_version = 0
        self._next_swap_kind = "source_swap"

        self.telemetry = telemetry if telemetry is not None \
            else obs.Telemetry()
        reg = self.telemetry.registry
        self._lat_hist = reg.histogram(
            "rec_request_latency_ms", "end-to-end request latency",
            lo=1e-3, hi=1e5, ring=4096)
        self._batch_hist = reg.histogram(
            "rec_batch_size", "released micro-batch sizes",
            lo=1.0, hi=4096.0, growth=1.25, ring=256)
        self._c_served = reg.counter("rec_requests_total",
                                     "requests served")
        self._c_batches = reg.counter("rec_batches_total",
                                      "micro-batches served")
        self._c_swaps = reg.counter("rec_source_swaps_total",
                                    "accepted source/cache swaps")
        self._c_stale = reg.counter("rec_stale_rejected_total",
                                    "rejected stale broadcasts")
        self._g_version = reg.gauge("rec_source_version",
                                    "currently served source version")
        self._g_queue = reg.gauge("rec_queue_depth",
                                  "admission-queue depth (set on enqueue "
                                  "and after every serve/drain)")
        self._qwait_hist = reg.histogram(
            "rec_queue_wait_ms", "admission-to-dispatch queue wait",
            lo=1e-3, hi=1e5, ring=4096)
        self._c_cold = reg.counter(
            "rec_cold_compiles_total",
            "dispatches that hit a cold (path, bucket) compile-cache "
            "entry — zero after warmup() is the warm-pool claim")
        # the warm compile-cache pool: (path kind, bucket) pairs whose
        # compiled entry has been triggered (warmup() or first dispatch)
        self._warm: set = set()
        self._down_source: Optional[es.EmbeddingSource] = None
        # auto-tune sampling is capped at auto_tune_after (satellite of
        # the unbounded-lists fix): the tuner never needs more history
        self._batch_ring: deque = deque(
            maxlen=max(1024, auto_tune_after or 0))
        self._batches_seen = 0

        if source is None:
            source = "ragged"
        if isinstance(source, (str, SourceSpec)):
            self.plan: Optional[SourceSpec] = SourceSpec.from_path(
                source, cache_k=cache_k, quantize_cold=quantize_cold,
                mesh=mesh)
            self.path = self.plan.path_name()
            # a table-group plan builds from the per-table arenas (and a
            # LIST of per-table trace histograms)
            arena = (params["tables"] if self.plan.tables is not None
                     else params["arena"])
            self.source = self.plan.build(arena, self.spec, cache_trace)
        else:
            assert isinstance(source, es.EmbeddingSource), source
            assert not cache_k and cache_trace is None \
                and not quantize_cold, \
                ("cache_k/cache_trace/quantize_cold are SourceSpec plan "
                 "inputs; a pre-built EmbeddingSource is served as-is — "
                 "compose a CachedSource/QuantizedArena yourself or pass "
                 "a SourceSpec instead of silently dropping the kwargs")
            self.plan = None
            self.path = es.describe_source(source)
            self.source = source
        self.layout = ("fixed" if self.plan is not None
                       and self.plan.layout == "fixed" else "ragged")

        if self.layout == "fixed":
            step = dlrm.make_serve_step(cfg, mesh)
            self._serve = jax.jit(step)
        else:
            # the source is a call-time pytree argument so update_source
            # can swap any component — hot cache, int8 cold arena, the
            # full fp arena — without recompiling (same treedef + leaf
            # shapes = compiled-cache hit)
            step = dlrm.make_ragged_serve_step(cfg, max_l=self.max_l,
                                               mesh=mesh)
            self._serve = jax.jit(step)
        self._staged = None
        if self.telemetry.device_stages:
            assert self.layout != "fixed", \
                ("device_stages (live Fig-5) characterizes the ragged "
                 "pipeline; the fixed layout has no staged serve path")
            sp, it, tp = dlrm.make_ragged_serve_stages(
                cfg, max_l=self.max_l, mesh=mesh)
            self._staged = (jax.jit(sp), jax.jit(it), jax.jit(tp))
        if self.grouped:
            # the whole source is the jit argument, so per-table hit
            # accounting survives every no-recompile member swap; the
            # engine's static max_l lets the counters ride the same
            # one-relayout fused dispatch as the lookup itself
            self._hit_rate = jax.jit(
                lambda s, i, o: es.group_hit_counts(s, i, o,
                                                    max_l=self.max_l))
        else:
            self._hit_rate = jax.jit(
                lambda c, i, o: se.cache_hit_rate(c, self.spec, i, o))
        self._reset_hit_counters()
        self._bind_host_stores()
        self._g_version.set(self.source_version)

    @property
    def grouped(self) -> bool:
        """Serving a heterogeneous TableGroupSource?"""
        return isinstance(self.source, es.TableGroupSource)

    # -- host cold tier: staging + prefetch ---------------------------------

    def _bind_host_stores(self) -> None:
        """Discover the host-resident cold stores (if any) behind the
        served source and adopt them into the engine's telemetry. Grouped
        sources keep the owning table alongside each store: staging wants
        per-table ids for members, flattened arena ids otherwise."""
        from repro import storage
        self._host_stores: List[tuple] = []
        if self.source is None or self.layout == "fixed":
            return
        if self.grouped:
            for t, m in enumerate(self.source.members):
                for st in storage.host_stores_of(m):
                    self._host_stores.append((st, t))
        else:
            for st in storage.host_stores_of(self.source):
                self._host_stores.append((st, None))
        for st, _ in self._host_stores:
            st.bind_telemetry(self.telemetry)
        self._stream_cache = None

    def _req_streams(self, r: RecRequest) -> tuple:
        """One request's (per_id, table) id streams, extracted once —
        ``submit`` computes this at admission so the dispatch hot path
        only concatenates (requests staged ahead through ``prefetch``
        fill theirs on first touch)."""
        s = r.cold_streams
        if s is None:
            t = self.cfg.n_tables
            lens = np.fromiter(map(len, r.sparse_ids), np.int64, count=t)
            per_id = (np.concatenate(r.sparse_ids).astype(
                np.int64, copy=False) if int(lens.sum())
                else np.zeros(0, np.int64))
            tbl = np.repeat(np.arange(t, dtype=np.int64), lens)
            s = r.cold_streams = (per_id, tbl)
        return s

    def _host_id_streams(self, reqs: List[RecRequest]):
        """The id streams the host stores need, host-side numpy only:
        per-table ids for grouped members, flattened arena ids (per-table
        id + table base) for a homogeneous source. Never reads a device
        array — staging must not sync the serve path. Per-request
        extraction happened at admission; this only concatenates."""
        empty = np.zeros(0, np.int64)
        if not reqs:
            return empty, {}
        parts = [self._req_streams(r) for r in reqs]
        per_id = np.concatenate([p[0] for p in parts])
        tbl = np.concatenate([p[1] for p in parts])
        flat = (per_id + tbl * self.spec.rows_per_table
                if any(tt is None for _, tt in self._host_stores)
                else empty)
        per_table = {j: per_id[tbl == j]
                     for j in {tt for _, tt in self._host_stores
                               if tt is not None}}
        return flat, per_table

    @staticmethod
    def _ids_for(streams, t, _empty=np.zeros(0, np.int64)):
        flat, per_table = streams
        return flat if t is None else per_table.get(t, _empty)

    def _stage_batch(self, reqs: List[RecRequest], *,
                     ahead: bool = False) -> None:
        """Residency guarantee (``ahead=False``, counted as hits/misses)
        or prefetch (``ahead=True``, uncounted) for one micro-batch's
        cold rows, then refresh the HostTier leaves in the served source.
        Same treedef and leaf shapes — no version bump, no recompile; the
        transfers are async ``device_put``s, so no host sync either.

        The batch path folds the admission queue's NEXT micro-batch into
        the same flush (one transfer + scatter per step, not two) and
        remembers its per-store cold sets — when that batch arrives, the
        extraction, the uniquify, and the transfer have all already
        happened, so it pays only the residency check. That is the
        prefetcher: misses become hits one dispatch ahead of their
        batch."""
        if not self._host_stores or not reqs:
            return
        from repro import storage
        if ahead:
            streams = self._host_id_streams(reqs)
            for st, t in self._host_stores:
                st.prefetch_arena(self._ids_for(streams, t))
            self.source = storage.refresh_host_tiers(self.source)
            return
        cache, self._stream_cache = self._stream_cache, None
        if cache is not None and cache[0] == [r.rid for r in reqs]:
            cur_cold = cache[1]
        else:
            streams = self._host_id_streams(reqs)
            cur_cold = [st.cold_ids_of(self._ids_for(streams, t))
                        for st, t in self._host_stores]
        nxt = list(self.batcher._queue[:self.max_batch])
        nxt_cold = None
        if nxt:
            nstreams = self._host_id_streams(nxt)
            nxt_cold = [st.cold_ids_of(self._ids_for(nstreams, t))
                        for st, t in self._host_stores]
        for i, (st, t) in enumerate(self._host_stores):
            st.stage(cur_cold[i],
                     ahead=None if nxt_cold is None else nxt_cold[i])
        if nxt_cold is not None:
            self._stream_cache = ([r.rid for r in nxt], nxt_cold)
        self.source = storage.refresh_host_tiers(self.source)

    def prefetch(self, reqs: List[RecRequest]) -> None:
        """Stage a future micro-batch's cold rows ahead of its dispatch
        (no hit/miss accounting — prefetched rows count as *hits* when
        their batch arrives; rows pinned by the in-flight batch are never
        evicted). The engine already prefetches the admission queue's
        next micro-batch inside every staged dispatch; this is for
        lookahead the queue can't see yet."""
        self._stage_batch(reqs, ahead=True)

    def _reset_hit_counters(self) -> None:
        if self.grouped:
            t = len(self.source.members)
            self._hits = np.zeros(t, np.int64)
            self._lookups = np.zeros(t, np.int64)
        else:
            self._hits = 0.0
            self._lookups = 0

    # -- bounded-memory compatibility views ---------------------------------

    @property
    def latencies(self) -> List[float]:
        """Most recent per-request latencies in seconds (ring-backed
        compatibility view of the old unbounded list; capped at the
        latency histogram's ring size)."""
        return [v / 1e3 for v in self._lat_hist.ring_values()]

    @property
    def batch_sizes(self) -> List[int]:
        """Most recent observed micro-batch sizes (ring-backed; capped
        at max(1024, auto_tune_after) — all the tuner ever reads)."""
        return list(self._batch_ring)

    # -- the swap boundary --------------------------------------------------

    @property
    def params(self) -> Dict:
        return self._params

    @params.setter
    def params(self, params: Dict) -> None:
        """Swapping the live params rebinds the source's fp-arena leaves,
        so 'params and cache swap together' keeps meaning one assignment
        plus one ``update_cache`` — exactly the pre-API protocol. The
        rebound source has identical leaf shapes, so no recompile."""
        self._params = params
        if getattr(self, "source", None) is not None:
            arena = (params["tables"]
                     if isinstance(self.source, es.TableGroupSource)
                     else params["arena"])
            self.source = es.rebind_arena(self.source, arena)
        if getattr(self, "_down_source", None) is not None:
            # the downgrade arena is derived from the live params, so a
            # params swap requantizes it (same shapes — no recompile)
            self._down_source = self._build_downgrade_source()

    @property
    def cache(self) -> Optional[se.HotRowCache]:
        """The hot cache currently served (None on non-cached sources)."""
        return es.hot_cache_of(self.source)

    @property
    def cache_version(self) -> int:
        """Back-compat alias for ``source_version``."""
        return self.source_version

    def _hit_snapshot(self) -> Dict:
        """Host ints/floats of the live version's hit accounting (for
        swap-event attribution). Collect pending probes first."""
        self._collect_pending()
        if self.grouped:
            return {"hits": float(np.sum(self._hits)),
                    "lookups": float(np.sum(self._lookups)),
                    "per_table": {
                        str(t): (float(self._hits[t]),
                                 float(self._lookups[t]))
                        for t in range(len(self._hits))}}
        return {"hits": float(self._hits),
                "lookups": float(self._lookups)}

    def update_source(self, source: es.EmbeddingSource,
                      version: Optional[int] = None) -> None:
        """Atomically swap the served embedding source (any component:
        hot cache, quantized cold arena, full fp arena).

        The whole source pytree is replaced at once — no torn state. The
        new source must match the old one's treedef and leaf shapes /
        dtypes, which is exactly the no-recompile condition: the jit'd
        serve step sees the same compiled signature.

        Stale broadcasts are rejected: a versioned swap to anything below
        the currently served version would re-serve rows the trainer has
        since rewritten (broadcast artifacts arrive out of order across a
        fleet). Equal versions are allowed — between rebuilds the trainer
        republishes the same version with write-through-patched values.
        Hit/lookup counters reset on version bumps so the reported hit
        rate reflects the live cache, not its predecessors — the
        outgoing version's totals are attributed to it in the swap event
        (``telemetry.events.hit_rate_by_version()``), and the
        since-swap latency window restarts.
        """
        kind = self._next_swap_kind
        self._next_swap_kind = "source_swap"
        assert self.layout != "fixed", \
            ("a fixed-layout engine serves from params['arena'] and "
             "never reads engine.source — accepting this swap would "
             "bump the version while serving stale embeddings forever")
        if version is not None and version < self.source_version:
            self._c_stale.inc()
            self.telemetry.emit("stale_rejected", version=version,
                                served_version=self.source_version,
                                swap_kind=kind)
            raise ValueError(
                f"stale source broadcast: version {version} < served "
                f"version {self.source_version} — reordered artifact, "
                f"refusing to roll the serving source back")
        old_leaves, old_def = jax.tree_util.tree_flatten(self.source)
        new_leaves, new_def = jax.tree_util.tree_flatten(source)
        assert old_def == new_def, \
            ("source swap changed the pytree structure — this forces a "
             "recompile on the serving hot path", old_def, new_def)
        assert all(a.shape == b.shape and a.dtype == b.dtype
                   for a, b in zip(old_leaves, new_leaves)), \
            ("source swap changed leaf shapes/dtypes — this forces a "
             "recompile on the serving hot path; keep trainer and engine "
             "cache_k / arena shapes equal")
        new_version = (version if version is not None
                       else self.source_version + 1)
        self.source = source
        # a pushed source may carry its own HostStore instances (same
        # structural signature, hence the treedef assert above passed) —
        # re-discover so staging/prefetch target the live stores
        self._bind_host_stores()
        if new_version > self.source_version:
            # per-path-correct accounting: the old cache's hits must not
            # dilute the post-swap hit rate — snapshot them into the
            # swap event (per-version attribution), then reset
            if self.telemetry.enabled:
                snap = self._hit_snapshot()
                self.telemetry.emit(kind, version=new_version,
                                    prev_version=self.source_version,
                                    **snap)
                self._lat_hist.reset_window()
            self._c_swaps.inc()
            self._reset_hit_counters()
        else:
            self.telemetry.emit(kind, version=new_version,
                                republish=True)
        self.source_version = new_version
        self._g_version.set(new_version)

    def update_cache(self, cache: se.HotRowCache,
                     version: Optional[int] = None) -> None:
        """Swap only the hot cache, keeping the cold source (the classic
        online-training refresh; see ``update_source`` for the rules)."""
        assert isinstance(self.source, es.CachedSource), \
            "update_cache needs a cached source"
        if version is not None and version < self.source_version:
            self._c_stale.inc()
            self.telemetry.emit("stale_rejected", version=version,
                                served_version=self.source_version,
                                swap_kind="cache_swap")
            raise ValueError(
                f"stale cache broadcast: version {version} < served "
                f"version {self.source_version} — reordered artifact, "
                f"refusing to roll the hot arena back")
        assert cache.hot_rows.shape == self.source.hot.hot_rows.shape, \
            ("cache swap changed K/D — this forces a recompile on the "
             "serving hot path; keep trainer and engine cache_k equal",
             cache.hot_rows.shape, self.source.hot.hot_rows.shape)
        self._next_swap_kind = "cache_swap"
        try:
            self.update_source(es.with_hot_cache(self.source, cache),
                               version=version)
        finally:
            self._next_swap_kind = "source_swap"

    # -- the int8 downgrade path --------------------------------------------

    @property
    def downgrade_source(self) -> Optional[es.EmbeddingSource]:
        """The int8 source overload batches serve from (None until
        ``enable_downgrade``)."""
        return self._down_source

    def enable_downgrade(self) -> es.EmbeddingSource:
        """Build (once) the int8 downgrade source the SLA scheduler
        serves from under overload.

        No second jit: the downgrade source is just another call-time
        pytree through the SAME ragged serve step, so its treedef gets
        its own compile-cache entry (pre-triggered by ``warmup()`` —
        the warm pool covers both treedefs per bucket) and per-batch
        path selection never recompiles. ``update_source``'s structural
        no-recompile assert only guards primary-source swaps.
        """
        assert self.layout != "fixed", \
            ("the downgrade path serves through the ragged lookup_bags "
             "step; the fixed layout reads params['arena'] directly")
        if self._down_source is None:
            self._down_source = self._build_downgrade_source()
        return self._down_source

    def _build_downgrade_source(self) -> es.EmbeddingSource:
        if self.grouped:
            return es.TableGroupSource(
                members=tuple(es.QuantizedArena.from_arena(a)
                              for a in self.params["tables"]),
                specs=self.source.specs)
        return es.QuantizedArena.from_arena(self.params["arena"])

    def warmup(self):
        """Compile every (path, bucket) pair off the SLA clock — the
        warm compile-cache pool.

        Without this the first live request landing in each bucket pays
        that bucket's jit compile (hundreds of ms) — a p99 spike that
        would show up as an SLA violation in production. With the
        downgrade path enabled both source treedefs are pre-compiled
        per bucket, so in-flight refill never stalls on a compile
        (``rec_cold_compiles_total`` stays zero).
        """
        t = self.cfg.n_tables
        l = self.cfg.lookups_per_table if self.layout == "fixed" else 0
        dummy = [RecRequest(
            rid=-1, dense=np.zeros(self.cfg.dense_features, np.float32),
            sparse_ids=[np.zeros(l, np.int32)] * t)]
        for st, _ in self._host_stores:
            # compile the staging scatter at every flush chunk size off
            # the SLA clock, so the first live miss (and the first
            # miss burst) pays a dispatch, not a jit
            st.warm_compile()
        for bucket in self.buckets:
            batch, _ = self._assemble(dummy, bucket)
            np.asarray(self._run_serve(batch))
            self._warm.add(("primary", bucket))
            if self._down_source is not None:
                np.asarray(self._serve(self.params, batch,
                                       self._down_source))
                self._warm.add(("downgrade", bucket))
            if self._staged is not None:
                sp, it, tp = self._staged
                emb = sp(self.params, batch, self.source)
                np.asarray(tp(self.params, it(self.params, batch, emb)))
            if not self.telemetry.enabled:
                continue            # uninstrumented: probe never runs
            if self.grouped:
                h, _ = self._hit_rate(self.source, batch["indices"],
                                      batch["offsets"])
                h.block_until_ready()
            elif self.cache is not None:
                self._hit_rate(self.cache, batch["indices"],
                               batch["offsets"]).block_until_ready()

    def _run_serve(self, batch: Dict):
        if self.layout == "fixed":
            return self._serve(self.params, batch)
        return self._serve(self.params, batch, self.source)

    def retune_buckets(self, n_buckets: int = 6,
                       warmup: bool = True) -> tuple:
        """Re-pick bucket boundaries from the observed batch-size histogram
        (ROADMAP: dynamic bucket tuning) and pre-compile the new shapes."""
        old = self.buckets
        self.buckets = tune_buckets(self.batch_sizes, self.max_batch,
                                    n_buckets)
        self.telemetry.emit("retune", version=self.source_version,
                            old_buckets=list(old),
                            new_buckets=list(self.buckets))
        if warmup:
            self.warmup()
        return self.buckets

    # -- request plumbing ---------------------------------------------------

    def submit(self, req: RecRequest):
        assert len(req.sparse_ids) == self.cfg.n_tables, \
            (len(req.sparse_ids), self.cfg.n_tables)
        with self.telemetry.span("enqueue", {"rid": req.rid}):
            if self._host_stores:
                self._req_streams(req)   # admission-time extraction
            self.batcher.submit(req)
        if self.telemetry.enabled:
            # live on enqueue, not only after a serve step — a stalled
            # serve loop must show its backlog, not the last drained value
            self._g_queue.set(len(self.batcher))

    def _assemble(self, reqs: List[RecRequest], bucket: int):
        """Pad a micro-batch to its bucket's static shapes.

        Returns ``(batch, n_valid)`` — n_valid is the real (unpadded)
        index count, computed host-side from the numpy offsets so the
        hit-rate probe never has to read a device array to learn it.
        """
        t = self.cfg.n_tables
        dense = np.zeros((bucket, self.cfg.dense_features), np.float32)
        for i, r in enumerate(reqs):
            dense[i] = r.dense
        if self.layout == "fixed":
            l = self.cfg.lookups_per_table
            idx = np.zeros((bucket, t, l), np.int32)
            for i, r in enumerate(reqs):
                for j, ids in enumerate(r.sparse_ids):
                    assert len(ids) == l, \
                        "fixed path requires exact-length bags"
                    idx[i, j] = ids
            # dummy rows gather row 0 — harmless, their outputs are dropped
            return {"dense": jnp.asarray(dense),
                    "indices": jnp.asarray(idx)}, 0
        lens = np.zeros(bucket * t, np.int32)
        for i, r in enumerate(reqs):
            for j, ids in enumerate(r.sparse_ids):
                assert len(ids) <= self.max_l, (len(ids), self.max_l)
                lens[i * t + j] = len(ids)
        offsets = np.zeros(bucket * t + 1, np.int32)
        np.cumsum(lens, out=offsets[1:])
        flat = np.zeros(bucket * t * self.max_l, np.int32)  # static cap
        for i, r in enumerate(reqs):
            for j, ids in enumerate(r.sparse_ids):
                o = offsets[i * t + j]
                flat[o:o + len(ids)] = ids
        return {"dense": jnp.asarray(dense),
                "indices": jnp.asarray(flat),
                "offsets": jnp.asarray(offsets)}, int(offsets[-1])

    def _dispatch_hit_probe(self, batch: Dict, n_valid: int) -> None:
        """Queue the per-batch hit-rate probe WITHOUT reading its result.

        The old accounting called float()/np.asarray() on the probe
        right here — a device sync on the serve hot path paid purely for
        bookkeeping. The futures now sit in ``_pending`` until a
        reporting boundary (stats / drain / swap) or the PENDING_MAX cap
        collects them, long after they completed.
        """
        if n_valid == 0:
            return
        if self.grouped:
            h, lk = self._hit_rate(self.source, batch["indices"],
                                   batch["offsets"])
            self._pending.append(("group", h, lk))
        elif self.cache is not None:
            hr = self._hit_rate(self.cache, batch["indices"],
                                batch["offsets"])
            self._pending.append(("cached", hr, n_valid))
        else:
            return
        if len(self._pending) >= self.PENDING_MAX:
            self._collect_pending()

    def _collect_pending(self) -> None:
        """Fold dispatched probe futures into the host-side counters."""
        if not self._pending:
            return
        pend, self._pending = self._pending, []
        for kind, a, b in pend:
            if kind == "group":
                self._hits += np.asarray(a, np.int64)
                self._lookups += np.asarray(b, np.int64)
            else:
                self._hits += float(a) * b
                self._lookups += b

    def _forward(self, batch: Dict, n_valid: int) -> np.ndarray:
        """One device forward; staged with per-stage device timing when
        the live Fig-5 mode is on."""
        tel = self.telemetry
        if self._staged is None:
            with tel.span("forward"):
                probs = np.asarray(self._run_serve(batch))
            if tel.enabled and self.layout != "fixed":
                self._dispatch_hit_probe(batch, n_valid)
            return probs
        sp, it, tp = self._staged
        reg = tel.registry
        with tel.span("sparse_lookup"):
            t0 = time.perf_counter()
            emb = sp(self.params, batch, self.source)
            emb.block_until_ready()
            t1 = time.perf_counter()
        with tel.span("interaction"):
            x = it(self.params, batch, emb)
            x.block_until_ready()
            t2 = time.perf_counter()
        with tel.span("mlp"):
            probs = np.asarray(tp(self.params, x))
            t3 = time.perf_counter()
        for name, dt in zip(_STAGE_NAMES, (t1 - t0, t2 - t1, t3 - t2)):
            reg.histogram("rec_stage_ms", "per-stage device time",
                          labels={"stage": name}).record(dt * 1e3)
        self._dispatch_hit_probe(batch, n_valid)
        return probs

    def step(self, force: bool = False) -> int:
        """Drain one micro-batch through the engine; returns #served."""
        tel = self.telemetry
        t_take0 = time.perf_counter()
        reqs = self.batcher.take(force=force)
        t_take1 = time.perf_counter()
        if not reqs:
            return 0
        # retune BEFORE the SLA clocks start: compiling the fresh bucket
        # shapes must not land on this micro-batch's recorded latency
        if self.auto_tune_after is not None and not self._retuned \
                and self._batches_seen >= self.auto_tune_after:
            self._retuned = True
            self.retune_buckets()
        now = time.time()
        now_m = time.monotonic()
        for r in reqs:
            r.started_at = now
            if tel.enabled:
                self._qwait_hist.record((now_m - r.submitted_mono) * 1e3)
        self._batches_seen += 1
        self._batch_ring.append(len(reqs))
        bucket = _bucket(len(reqs), self.buckets)
        self._warm.add(("primary", bucket))
        with tel.span("serve_step", {"batch_size": len(reqs),
                                     "bucket": bucket}):
            tel.tracer.record("batch", t_take0, t_take1)
            self._stage_batch(reqs)      # host-cold residency guarantee
            with tel.span("bucket_pad"):
                batch, n_valid = self._assemble(reqs, bucket)
            probs = self._forward(batch, n_valid)
            with tel.span("respond"):
                done = time.time()
                done_m = time.monotonic()
                for i, r in enumerate(reqs):
                    r.prob = float(probs[i])
                    r.finished_at = done
                    if tel.enabled:
                        # latency on the monotonic clock: an NTP step
                        # must not mint a negative (or week-long) p99
                        self._lat_hist.record((done_m - r.submitted_mono)
                                              * 1e3)
        self.served += len(reqs)
        if tel.enabled:
            self._c_served.inc(len(reqs))
            self._c_batches.inc()
            self._batch_hist.record(len(reqs))
            self._g_queue.set(len(self.batcher))
        return len(reqs)

    def drain(self) -> int:
        """Serve everything still queued (end-of-stream flush)."""
        n = 0
        while len(self.batcher):
            n += self.step(force=True)
        self._collect_pending()     # reporting boundary: settle accounting
        if self.telemetry.enabled:
            self._g_queue.set(len(self.batcher))
        self.telemetry.emit("drain", version=self.source_version,
                            served=n, queue_depth=len(self.batcher))
        return n

    # -- continuous batching: dispatch / settle -----------------------------

    def dispatch(self, reqs: List[RecRequest], *,
                 downgraded: bool = False) -> InflightBatch:
        """Assemble and dispatch one micro-batch WITHOUT settling it.

        The device-array result stays a future (no host conversion), so
        the caller can assemble the NEXT micro-batch while this one
        computes — continuous batching with in-flight refill, no wave
        barrier (``repro.serving.scheduler.SlaScheduler`` is the loop).
        ``downgraded=True`` serves from the int8 downgrade source
        (``enable_downgrade`` first) through the same jit — a different
        call-time pytree, its own warm compile-cache entry, no recompile.
        """
        assert reqs, "dispatch needs a non-empty micro-batch"
        assert self._staged is None, \
            ("device_stages (live Fig-5) syncs between stages — that "
             "defeats in-flight refill; characterize through step()")
        if downgraded:
            assert self._down_source is not None, \
                "call enable_downgrade() before dispatching a downgrade"
        tel = self.telemetry
        if self.auto_tune_after is not None and not self._retuned \
                and self._batches_seen >= self.auto_tune_after:
            self._retuned = True
            self.retune_buckets()
        now = time.time()
        now_m = time.monotonic()
        self._batches_seen += 1
        self._batch_ring.append(len(reqs))
        bucket = _bucket(len(reqs), self.buckets)
        kind = "downgrade" if downgraded else "primary"
        if tel.enabled and (kind, bucket) not in self._warm:
            self._c_cold.inc()
        self._warm.add((kind, bucket))
        for r in reqs:
            r.started_at = now
            r.downgraded = downgraded
            if tel.enabled:
                self._qwait_hist.record((now_m - r.submitted_mono) * 1e3)
        with tel.span("dispatch", {"batch_size": len(reqs),
                                   "bucket": bucket, "path": kind}):
            if not downgraded:
                self._stage_batch(reqs)  # host-cold residency guarantee
            with tel.span("bucket_pad"):
                batch, n_valid = self._assemble(reqs, bucket)
            if downgraded:
                probs = self._serve(self.params, batch, self._down_source)
            else:
                probs = self._run_serve(batch)
                if tel.enabled and self.layout != "fixed":
                    self._dispatch_hit_probe(batch, n_valid)
        return InflightBatch(reqs=reqs, probs=probs, bucket=bucket,
                             downgraded=downgraded, dispatched_mono=now_m)

    def settle(self, ib: InflightBatch) -> int:
        """Block on an in-flight batch's device result and respond.

        The ``np.asarray`` here is the ONLY host sync of the
        dispatch/settle pair — by settle time the futures of a deep
        enough pipeline completed long ago, so it is a read, not a
        stall. Records end-to-end latency (monotonic) and the
        dispatch-to-settle service time per path.
        """
        tel = self.telemetry
        with tel.span("settle", {"batch_size": len(ib.reqs)}):
            probs = np.asarray(ib.probs)
            done = time.time()
            done_m = time.monotonic()
            for i, r in enumerate(ib.reqs):
                r.prob = float(probs[i])
                r.finished_at = done
                if tel.enabled:
                    self._lat_hist.record((done_m - r.submitted_mono)
                                          * 1e3)
        self.served += len(ib.reqs)
        if tel.enabled:
            self._c_served.inc(len(ib.reqs))
            self._c_batches.inc()
            self._batch_hist.record(len(ib.reqs))
            tel.registry.histogram(
                "rec_service_ms", "dispatch-to-settle service time",
                labels={"path": "downgrade" if ib.downgraded
                        else "primary"}
            ).record((done_m - ib.dispatched_mono) * 1e3)
        return len(ib.reqs)

    # -- reporting ----------------------------------------------------------

    def live_fig5(self) -> Dict[str, float]:
        """The live Fig-5 characterization: mean per-stage device time
        and the embedding fraction, from real served traffic. Requires
        ``Telemetry(device_stages=True)``; comparable to the offline
        ``fig5_*`` rows in BENCH_paper.json."""
        assert self._staged is not None, \
            "live_fig5 needs Telemetry(device_stages=True)"
        reg = self.telemetry.registry
        means = {n: reg.histogram("rec_stage_ms",
                                  labels={"stage": n}).mean
                 for n in _STAGE_NAMES}
        total = sum(means.values())
        return {**{f"{n}_ms": means[n] for n in _STAGE_NAMES},
                "total_ms": total,
                "emb_frac": (means["sparse_lookup"] / total
                             if total else 0.0)}

    def stats(self) -> Dict[str, float]:
        self._collect_pending()     # reporting boundary: settle accounting
        h = self._lat_hist
        if h.count == 0:
            return {"n": 0}
        out = {"n": h.count,
               "path": self.path,
               "source": es.describe_source(self.source),
               # nested compositions one-per-line (the compact label above
               # is unreadable for deep/grouped sources)
               "source_tree": es.describe_source(self.source,
                                                 multiline=True),
               "p50_ms": h.percentile(50),
               "p95_ms": h.percentile(95),
               "p99_ms": h.percentile(99),
               "mean_ms": h.mean}
        # per-path-correct: None (not a fake 0.0) when no hot cache is
        # serving, or when no lookups have hit the live cache version yet
        if self.grouped:
            # per-table mapping; None preserved for non-cached members
            out["cache_hit_rate"] = {
                t: (float(self._hits[t] / self._lookups[t])
                    if self._lookups[t] else None)
                if es.hot_cache_of(m) is not None else None
                for t, m in enumerate(self.source.members)}
            out["cache_version"] = self.source_version
        elif self.cache is None:
            out["cache_hit_rate"] = None
        else:
            out["cache_hit_rate"] = (self._hits / self._lookups
                                     if self._lookups else None)
            out["cache_version"] = self.source_version
        out["buckets"] = self.buckets
        if self._host_stores:
            hs = [st.stats() for st, _ in self._host_stores]
            hits = sum(s["hits"] for s in hs)
            touches = sum(s["touches"] for s in hs)
            out["prefetch"] = {
                "hits": hits,
                "misses": sum(s["misses"] for s in hs),
                "touches": touches,
                "hit_rate": hits / touches if touches else 1.0,
                "staged_resident": sum(s["resident"] for s in hs),
                "host_bytes": sum(s["host_bytes"] for s in hs)}
        # windowed views (post-swap regressions must not average away):
        # since_swap restarts at every version bump, rolling covers the
        # last ring's worth of requests exactly
        out["since_swap"] = {"n": h.window_count,
                             "p50_ms": h.percentile(50, "window"),
                             "p95_ms": h.percentile(95, "window"),
                             "p99_ms": h.percentile(99, "window")}
        out["rolling"] = {"n": min(h.count, h.ring_size),
                          "p50_ms": h.percentile(50, "rolling"),
                          "p95_ms": h.percentile(95, "rolling"),
                          "p99_ms": h.percentile(99, "rolling")}
        if self._staged is not None:
            out["stages"] = self.live_fig5()
        return out


def requests_from_ragged_batch(batch: Dict[str, np.ndarray], n_tables: int,
                               rid0: int = 0) -> List[RecRequest]:
    """Explode a DLRMSynthetic.ragged_batch into individual requests."""
    off = batch["offsets"]
    b = (len(off) - 1) // n_tables
    out = []
    for i in range(b):
        ids = [batch["indices"][off[i * n_tables + j]:
                                off[i * n_tables + j + 1]]
               for j in range(n_tables)]
        out.append(RecRequest(rid=rid0 + i, dense=batch["dense"][i],
                              sparse_ids=ids))
    return out
