"""Serving engine: request batcher + continuous-batching decode loop.

The paper's deployment target is user-facing inference with firm SLAs
(Section IV-A); this engine is the framework's answer:

* ``Batcher`` — admission queue with (max_batch, max_wait_ms) micro-batching,
  the standard SLA/throughput knob;
* ``DecodeEngine`` — fixed slot pool with *wave* batching: a wave of
  requests is admitted together (positions stay aligned with the scalar-pos
  KV cache), decoded until every member finishes, then the slots are
  reused. Sequences that hit max_new_tokens early stop contributing to
  latency but their slots decode inertly until the wave drains — the
  aligned-position simplification vs full continuous batching (which needs
  a per-row position cache; noted as future work in DESIGN.md);
* latency stats (p50/p95/p99) per request.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import api


@dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # (S,) int32
    max_new_tokens: int = 16
    submitted_at: float = field(default_factory=time.time)
    started_at: Optional[float] = None
    finished_at: Optional[float] = None
    output: List[int] = field(default_factory=list)


class Batcher:
    def __init__(self, max_batch: int = 8, max_wait_ms: float = 5.0):
        self.max_batch = max_batch
        self.max_wait_ms = max_wait_ms
        self._queue: List[Request] = []

    def submit(self, req: Request):
        self._queue.append(req)

    def take(self) -> List[Request]:
        """Non-blocking micro-batch: whatever is queued up to max_batch,
        or everything older than max_wait_ms."""
        if not self._queue:
            return []
        oldest = time.time() - self._queue[0].submitted_at
        if len(self._queue) >= self.max_batch \
                or oldest * 1e3 >= self.max_wait_ms:
            batch, self._queue = (self._queue[:self.max_batch],
                                  self._queue[self.max_batch:])
            return batch
        return []


class DecodeEngine:
    """Slot-pooled decode over a fixed cache; CPU-runnable at smoke scale."""

    def __init__(self, cfg: ModelConfig, params, n_slots: int = 4,
                 max_len: int = 256):
        self.cfg = cfg
        self.params = params
        self.n_slots = n_slots
        self.max_len = max_len
        self.cache = api.init_cache(cfg, n_slots, max_len)
        self.slot_req: List[Optional[Request]] = [None] * n_slots
        self.slot_pos = np.zeros(n_slots, np.int32)
        self.latencies: List[float] = []
        self._decode = jax.jit(
            lambda p, c, t, pos: api.decode_step(p, cfg, c, t, pos))

    def idle(self) -> bool:
        return all(r is None for r in self.slot_req)

    def admit(self, reqs: List[Request]):
        """Admit a wave (only when idle); batched aligned prefill."""
        if not reqs or not self.idle():
            return
        reqs = reqs[:self.n_slots]
        plen = max(len(r.prompt) for r in reqs)
        # fresh cache for the wave
        self.cache = api.init_cache(self.cfg, self.n_slots, self.max_len)
        self.pos = 0
        for i, req in enumerate(reqs):
            req.started_at = time.time()
            self.slot_req[i] = req
        # aligned prefill: one batched decode step per prompt position
        # (left-pad shorter prompts with token 0)
        for t in range(plen):
            tokens = np.zeros((self.n_slots,), np.int32)
            for i, req in enumerate(reqs):
                off = plen - len(req.prompt)
                if t >= off:
                    tokens[i] = req.prompt[t - off]
            self._last_logits, self.cache = self._decode(
                self.params, self.cache, jnp.asarray(tokens),
                jnp.asarray(self.pos, jnp.int32))
            self.pos += 1

    def step(self) -> int:
        """One decode step for the wave; returns #still-active."""
        active = [i for i, r in enumerate(self.slot_req) if r is not None]
        if not active:
            return 0
        tokens = np.zeros((self.n_slots,), np.int32)
        nxt = np.asarray(jnp.argmax(self._last_logits, -1))
        for i in active:
            tokens[i] = nxt[i]
        self._last_logits, self.cache = self._decode(
            self.params, self.cache, jnp.asarray(tokens),
            jnp.asarray(self.pos, jnp.int32))
        self.pos += 1
        out = np.asarray(jnp.argmax(self._last_logits, -1))
        for i in active:
            req = self.slot_req[i]
            req.output.append(int(out[i]))
            if len(req.output) >= req.max_new_tokens \
                    or self.pos >= self.max_len - 1:
                req.finished_at = time.time()
                self.latencies.append(req.finished_at - req.submitted_at)
                self.slot_req[i] = None
        return len([r for r in self.slot_req if r is not None])

    def stats(self) -> Dict[str, float]:
        if not self.latencies:
            return {}
        arr = np.array(self.latencies)
        return {"n": len(arr),
                "p50_ms": float(np.percentile(arr, 50) * 1e3),
                "p95_ms": float(np.percentile(arr, 95) * 1e3),
                "p99_ms": float(np.percentile(arr, 99) * 1e3)}
