from repro.core.embedding_source import SourceSpec
from repro.serving.engine import Batcher, DecodeEngine, Request
from repro.serving.rec_engine import (InflightBatch, RecBatcher, RecEngine,
                                      RecRequest,
                                      requests_from_ragged_batch)
from repro.serving.scheduler import (BatchPlan, ServiceEstimator, SlaPolicy,
                                     SlaScheduler, plan_batch)

__all__ = ["BatchPlan", "Batcher", "DecodeEngine", "InflightBatch",
           "Request", "RecBatcher", "RecEngine", "RecRequest",
           "ServiceEstimator", "SlaPolicy", "SlaScheduler", "SourceSpec",
           "plan_batch", "requests_from_ragged_batch"]
