from repro.serving.engine import Batcher, DecodeEngine, Request

__all__ = ["Batcher", "DecodeEngine", "Request"]
