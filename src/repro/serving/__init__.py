from repro.serving.engine import Batcher, DecodeEngine, Request
from repro.serving.rec_engine import (RecBatcher, RecEngine, RecRequest,
                                      requests_from_ragged_batch)

__all__ = ["Batcher", "DecodeEngine", "Request", "RecBatcher", "RecEngine",
           "RecRequest", "requests_from_ragged_batch"]
