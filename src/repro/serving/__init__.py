from repro.core.embedding_source import SourceSpec
from repro.serving.engine import Batcher, DecodeEngine, Request
from repro.serving.rec_engine import (RecBatcher, RecEngine, RecRequest,
                                      requests_from_ragged_batch)

__all__ = ["Batcher", "DecodeEngine", "Request", "RecBatcher", "RecEngine",
           "RecRequest", "SourceSpec", "requests_from_ragged_batch"]
