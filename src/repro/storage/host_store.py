"""Host-resident cold tier: rows that never live in device memory.

Centaur's sparse chiplet serves gathers from *capacity* memory while the
dense chiplet computes — the point is that cold embedding rows should
occupy cheap, large storage and cross to the accelerator only when a
batch actually touches them. ``HostStore`` is that tier: the cold rows
stay as one fp32 numpy block on the host, and a small bounded **staging
arena** on device receives exactly the rows the next batches need, via
``jax.device_put`` transfers that overlap the current batch's compute.

The contract with the jitted serve path:

* the device footprint is FIXED — ``staging`` is ``(S+1, D)`` with slot S
  the always-zero null slot, ``slot_of`` maps every compact cold index to
  its staging slot (or S when not resident). Staging updates are scatter
  writes at the same shapes, so the serve executable never recompiles and
  residency changes are pure data.
* ``stage(arena_ids)`` is the synchronous-in-program-order residency
  guarantee the engine calls per batch *before* dispatch: after it
  returns, every cold row the batch touches has a staging slot and a
  pending (async) transfer — XLA's data dependency, not a host sync,
  orders the copy before the gather. A row already resident counts as a
  **hit**; a row staged on demand counts as a **miss**. The accounting
  invariant ``hits + misses == cold row touches`` (unique per batch) is
  asserted by ``bench_paper --smoke``.
* ``prefetch(arena_ids)`` stages *ahead* (the next batches' rows, peeked
  from the admission queue) without touching the hit/miss counters — it
  is how misses become hits. Rows pinned by the current batch are never
  evicted by a prefetch.

Exactness: staged rows are bit-exact fp32 copies of the host block (no
re-quantization on the way in), so a cold row served through the staging
arena equals the fp arena row exactly — the hot/cold composition law
extends to the host tier unchanged.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core import embedding_source as es
from repro.core import sparse_engine as se
from repro.kernels import ops

__all__ = ["HostStore", "HostTier"]

# issue host->device copies eagerly (device_put futures) only when a real
# accelerator is attached; the CPU backend's jit argument conversion is
# the same copy without the extra Python hop
_EXPLICIT_PUT = jax.default_backend() != "cpu"


@functools.partial(jax.jit, static_argnames=())
def _apply_stage(staging, slot_of, rows, slots, ids, evicted):
    """One fixed-shape staging scatter: evict, remap, write.

    rows (M, D) are the freshly transferred host rows for compact cold
    ids ``ids`` landing in staging ``slots``; ``evicted`` are the compact
    ids losing their slots. Padding protocol (chunks are fixed-size M so
    this never recompiles): pad ids/evicted with the compact NULL id
    (whose slot_of entry is the null slot anyway) and slots with the null
    slot (whose staging row is zero and the pad rows are zero) — every
    pad write rewrites an invariant value. NO buffer donation: in-flight
    dispatched batches hold the previous staging arrays, and immutability
    is exactly what makes asynchronous staging safe.
    """
    null_slot = staging.shape[0] - 1
    slot_of = slot_of.at[evicted].set(null_slot)
    slot_of = slot_of.at[ids].set(slots)
    staging = staging.at[slots].set(rows)
    return staging, slot_of


@es.register_source(("staging", "slot_of"), ("store",))
@dataclass(frozen=True)
class HostTier(es.EmbeddingSource):
    """The device-visible face of a ``HostStore``: the bounded staging
    arena plus the residency map, as an ``EmbeddingSource`` over compact
    cold ids (0..C-1 with C the compact null id).

    ``store`` is *ephemeral* meta (host state, like a Mesh): it keeps the
    treedef identity-stable across staging refreshes in-process, is
    dropped by the artifact serializer, and a deserialized HostTier
    (store=None) still serves exactly its staged snapshot.
    """
    staging: jax.Array                   # (S+1, D) f32, slot S zero
    slot_of: jax.Array                   # (C+1,) int32 -> slot or S
    store: Optional["HostStore"] = None

    __ephemeral_meta__ = ("store",)

    @property
    def out_dtype(self):
        return jnp.float32

    @property
    def staging_rows(self) -> int:
        return self.staging.shape[0] - 1

    def reduce_dense(self, spec, dense):
        # residency indirection then the plain fused reduce: non-resident
        # and null ids read the zero null slot — with the engine's
        # ``stage()`` residency guarantee, every *touched* cold row is
        # resident, so "non-resident" only ever describes fill slots.
        slots = jnp.take(self.slot_of, dense, axis=0)
        return ops.fused_segment_sum(self.staging, slots,
                                     null_row=self.staging_rows)

    def reduce_flat(self, spec, flat, offsets, *, max_l):
        n_bags = offsets.shape[0] - 1
        seg = se.ragged_segment_ids(offsets, flat.shape[0])
        rows = jnp.take(self.staging, jnp.take(self.slot_of, flat),
                        axis=0).astype(jnp.float32)
        return jax.ops.segment_sum(rows, seg, num_segments=n_bags)

    def _describe(self) -> str:
        return "host"

    def _describe_lines(self, depth: int) -> list:
        pad = "  " * depth
        s, d = self.staging.shape
        total = self.store.host_rows.shape[0] if self.store is not None \
            else "?"
        return [f"{pad}host tier ({total} rows on host; staging "
                f"{s - 1}x{d} f32, {es.fmt_bytes(self.device_bytes())} "
                f"on device)"]

    def device_bytes(self) -> int:
        return int(self.staging.nbytes + self.slot_of.nbytes)

    def host_bytes(self) -> int:
        return int(self.store.host_rows.nbytes) \
            if self.store is not None else 0


class HostStore:
    """Host-side owner of a cold-row block + its staging residency state.

    Identity-stable across staging refreshes (it sits in ``HostTier``'s
    meta fields, which participate in treedef equality) — the engine
    carries ONE store per tier for the life of the source and refreshes
    only the ``HostTier`` array leaves around it.
    """

    def __init__(self, host_rows: np.ndarray, *, staging_rows: int,
                 compact_of: Optional[np.ndarray] = None,
                 max_stage_per_batch: int = 64,
                 telemetry: Optional[obs.Telemetry] = None):
        host_rows = np.ascontiguousarray(host_rows, np.float32)
        c, d = host_rows.shape
        assert staging_rows >= 1, staging_rows
        self.host_rows = host_rows           # (C, D) fp32, compact ids
        self.n_cold = c
        self.null_id = c                     # compact null id
        # arena row id -> compact cold id (null_id for non-cold rows);
        # host-side numpy, zero device footprint. Identity when the store
        # is used standalone over a whole arena.
        self.compact_of = (np.asarray(compact_of, np.int64)
                           if compact_of is not None
                           else np.arange(c, dtype=np.int64))
        self.staging_rows = staging_rows
        self.max_stage = max(1, int(max_stage_per_batch))
        self.bind_telemetry(telemetry if telemetry is not None
                            else obs.Telemetry.disabled())
        # live device state (HostTier snapshots these leaves)
        self.staging = jnp.zeros((staging_rows + 1, d), jnp.float32)
        self.slot_of = jnp.full((c + 1,), staging_rows, jnp.int32)
        # residency bookkeeping, all vectorized numpy (this runs on the
        # serve hot path every batch — per-id Python loops would cost
        # more than the transfers they schedule): a host mirror of the
        # slot map, an LRU stamp per compact id, the pin mask of the
        # batch currently in flight, and the free-slot stack
        self._slot_np = np.full(c + 1, staging_rows, np.int32)
        self._stamp = np.zeros(c + 1, np.int64)
        # pin-by-epoch: a row is pinned iff its entry equals the current
        # pin epoch — re-pinning a new working set is one counter bump,
        # not a (C,) memset on the serve hot path
        self._pin_epoch = np.zeros(c + 1, np.int64)
        self._epoch = 0
        # slot -> resident compact id (null_id when free): the eviction
        # planner scans S slots for LRU candidates, not C compact ids
        self._owner = np.full(staging_rows, c, np.int32)
        self._free = np.arange(staging_rows - 1, -1, -1, np.int32)
        self._n_free = staging_rows
        self._clock = 0
        self.hits = 0
        self.misses = 0

    # The store rides in HostTier's *meta* fields, so it participates in
    # pytree-structure comparison and jit signature hashing. The jitted
    # serve path never reads the store — only the snapshot array leaves —
    # so two stores with the same structural signature are interchangeable
    # for compilation purposes. Identity equality here would make a
    # trainer-published source structurally different from the engine's
    # own and force a recompile on every sync.
    def _signature(self) -> tuple:
        return (self.host_rows.shape, self.staging_rows)

    def __eq__(self, other) -> bool:
        return isinstance(other, HostStore) \
            and self._signature() == other._signature()

    def __hash__(self) -> int:
        return hash((HostStore, self._signature()))

    def bind_telemetry(self, telemetry: obs.Telemetry) -> None:
        """Adopt a consumer's telemetry bundle (the engine rebinds the
        stores it discovers in its source; registration is idempotent)."""
        self.telemetry = telemetry
        reg = telemetry.registry
        self._c_hit = reg.counter(
            "rec_prefetch_hit",
            "cold rows already staged when their batch arrived")
        self._c_miss = reg.counter(
            "rec_prefetch_miss",
            "cold rows staged on demand at batch-stage time")

    def retarget(self, host_rows: np.ndarray,
                 compact_of: np.ndarray) -> None:
        """Adopt a new cold partition in place (tier migration): fresh
        rows and arena->compact mapping, residency reset, SAME object
        identity and array shapes — the treedef of any ``HostTier``
        snapshotted from this store is unchanged, so republication after
        a migration still hits the compiled serve path. Requires the
        partition sizes to match (fixed H/W/C is the structure-stability
        contract of ``TierPolicy``)."""
        host_rows = np.ascontiguousarray(host_rows, np.float32)
        assert host_rows.shape == self.host_rows.shape, \
            (host_rows.shape, self.host_rows.shape)
        assert compact_of.shape == self.compact_of.shape, \
            (compact_of.shape, self.compact_of.shape)
        self.host_rows = host_rows
        self.compact_of = np.asarray(compact_of, np.int64)
        self.staging = jnp.zeros_like(self.staging)
        self.slot_of = jnp.full_like(self.slot_of, self.staging_rows)
        self._slot_np[:] = self.staging_rows
        self._stamp[:] = 0
        self._pin_epoch[:] = 0
        self._epoch = 0
        self._owner[:] = self.null_id
        self._free = np.arange(self.staging_rows - 1, -1, -1, np.int32)
        self._n_free = self.staging_rows
        self._clock = 0

    # -- residency ---------------------------------------------------------

    def tier(self) -> HostTier:
        """The current device-visible snapshot of this store."""
        return HostTier(staging=self.staging, slot_of=self.slot_of,
                        store=self)

    def _unique_cold(self, arena_ids) -> np.ndarray:
        ids = np.asarray(arena_ids, np.int64).reshape(-1)
        comp = self.compact_of[ids]
        return np.unique(comp[comp < self.n_cold])

    def cold_ids_of(self, arena_ids) -> np.ndarray:
        """Raw arena row ids -> this store's unique compact cold ids (the
        form ``stage``/``prefetch`` consume). Exposed so a caller staging
        ahead can compute a future batch's cold set once and replay it
        when the batch arrives."""
        return self._unique_cold(arena_ids)

    def stage_arena(self, arena_ids) -> tuple:
        """Per-batch entry point over raw *arena* row ids: filter to this
        store's cold rows, uniquify, guarantee residency."""
        return self.stage(self._unique_cold(arena_ids))

    def prefetch_arena(self, arena_ids) -> int:
        """Prefetch entry point over raw arena row ids."""
        return self.prefetch(self._unique_cold(arena_ids))

    def stage_arena_with_prefetch(self, arena_ids, next_arena_ids) -> tuple:
        """Residency guarantee for the in-flight batch AND best-effort
        prefetch of the next batch, as ONE flush: a single transfer +
        scatter per step instead of two — the fixed per-flush costs
        (pad buffer, ``device_put`` issue, scatter dispatch) are the
        serve hot path's dominant staging expense once the hit rate is
        high. Accounting covers only the in-flight batch."""
        return self.stage(self._unique_cold(arena_ids),
                          ahead=self._unique_cold(next_arena_ids))

    def stage(self, comp_ids: np.ndarray,
              ahead: Optional[np.ndarray] = None) -> tuple:
        """Residency guarantee for one batch's unique compact cold ids.

        Returns (hits, misses) for this batch and re-pins the working
        set; call ``tier()`` (or let the engine refresh its source) to
        pick up the new leaves. ``ahead`` optionally rides best-effort
        prefetch ids (the NEXT batch's) into the same flush, uncounted.
        """
        comp_ids = np.unique(np.asarray(comp_ids, np.int64).reshape(-1))
        resident = self._slot_np[comp_ids] < self.staging_rows
        hits = int(resident.sum())
        need = comp_ids[~resident]
        self._clock += 1
        self._stamp[comp_ids] = self._clock
        # re-pin the new working set (the rows the in-flight batch reads;
        # a prefetch must never evict them from under the dispatch)
        self._epoch += 1
        self._pin_epoch[comp_ids] = self._epoch
        want = need
        if ahead is not None and len(ahead):
            self._clock += 1
            self._stamp[ahead] = self._clock
            amiss = ahead[self._slot_np[ahead] == self.staging_rows]
            if len(amiss):
                # one plan for batch + lookahead: needs first, so when
                # the arena can't fit everything the truncation drops
                # the best-effort tail, never the residency guarantee
                want = np.concatenate(
                    (need, np.setdiff1d(amiss, need, assume_unique=True)))
        self._flush(*self._plan(want, min_required=len(need)))
        self.hits += hits
        self.misses += len(need)
        if self.telemetry.enabled:
            if hits:
                self._c_hit.inc(hits)
            if len(need):
                self._c_miss.inc(len(need))
        return hits, len(need)

    def prefetch(self, comp_ids: np.ndarray) -> int:
        """Stage ahead without touching the hit/miss accounting; returns
        the number of rows actually transferred."""
        comp_ids = np.unique(np.asarray(comp_ids, np.int64).reshape(-1))
        self._clock += 1
        self._stamp[comp_ids] = self._clock
        miss = self._slot_np[comp_ids] == self.staging_rows
        return self._assign(comp_ids[miss], best_effort=True)

    def _assign(self, need: np.ndarray, best_effort: bool) -> int:
        """Plan + flush in one call (the standalone stage/prefetch
        paths)."""
        plan = self._plan(need, min_required=0 if best_effort
                          else len(need))
        self._flush(*plan)
        return len(plan[0])

    def _plan(self, need: np.ndarray, *, min_required: int) -> tuple:
        """Assign slots (free first, then LRU-evict unpinned); returns
        the transfer plan ``(ids, slots, victims)`` for ``_flush``.
        The first ``min_required`` ids are the residency guarantee — if
        they can't all get slots the batch's unique cold rows exceed the
        arena, a plan error, not a runtime to paper over; anything past
        them is best-effort lookahead, truncated when nothing more is
        evictable."""
        none = (np.zeros(0, np.int64), np.zeros(0, np.int32),
                np.zeros(0, np.int64))
        k = len(need)
        if k == 0:
            return none
        take = min(k, self._n_free)
        victims = np.empty(0, np.int64)
        if k > take:
            m = k - take
            res = self._owner[self._owner != self.null_id]
            cand = res[self._pin_epoch[res] != self._epoch]
            if len(cand) < m:
                if take + len(cand) < min_required:
                    raise ValueError(
                        f"staging arena too small: batch needs more than "
                        f"{self.staging_rows} unique cold rows "
                        f"(TierPolicy.staging_rows)")
                m = len(cand)
                k = take + m
                need = need[:k]
                if k == 0:
                    return none
            if m:
                sel = (np.argpartition(self._stamp[cand], m - 1)[:m]
                       if m < len(cand) else np.arange(len(cand)))
                victims = cand[sel]
        new_slots = np.empty(k, np.int32)
        if take:
            new_slots[:take] = self._free[self._n_free - take:self._n_free]
            self._n_free -= take
        if len(victims):
            new_slots[take:k] = self._slot_np[victims]
            self._slot_np[victims] = self.staging_rows
        self._slot_np[need] = new_slots
        self._owner[new_slots] = need
        return need, new_slots, victims

    @property
    def _chunk_sizes(self) -> tuple:
        """Fixed-shape flush chunk ladder. At a healthy hit rate a batch
        transfers a handful of rows; padding them to ``max_stage`` makes
        the pad buffer + transfer the dominant staging cost. A small
        pre-compiled chunk serves the steady state, ``max_stage`` serves
        bursts — both warmed by ``warm_compile`` so neither ever jits on
        the serve path."""
        sizes = []
        c = 32
        while c < self.max_stage:
            sizes.append(c)
            c *= 2
        return tuple(sizes) + (self.max_stage,)

    def warm_compile(self) -> None:
        """Compile the staging scatter at every flush chunk size, off the
        serve clock. All-pad flushes: every write rewrites an invariant
        value (null id -> null slot, zero rows into the null slot), so
        residency is untouched."""
        for m in self._chunk_sizes:
            rows = jax.device_put(
                np.zeros((m, self.host_rows.shape[1]), np.float32))
            pad_i = np.full(m, self.null_id, np.int32)
            pad_s = np.full(m, self.staging_rows, np.int32)
            self.staging, self.slot_of = _apply_stage(
                self.staging, self.slot_of, rows, pad_s, pad_i, pad_i)

    def _flush(self, ids, slots, evicted):
        n = max(len(ids), len(evicted))
        if n == 0:
            return
        m = next((c for c in self._chunk_sizes if n <= c),
                 self.max_stage)
        for i in range(0, n, m):
            ids_c = ids[i:i + m]
            slots_c = slots[i:i + m]
            ev_c = evicted[i:i + m]
            # fixed-shape padding (see _apply_stage): pad writes rewrite
            # invariant values, so chunking never recompiles
            rows_np = np.zeros((m, self.host_rows.shape[1]), np.float32)
            if len(ids_c):
                rows_np[:len(ids_c)] = self.host_rows[ids_c]
            ids_a = np.full(m, self.null_id, np.int32)
            ids_a[:len(ids_c)] = ids_c
            slots_a = np.full(m, self.staging_rows, np.int32)
            slots_a[:len(slots_c)] = slots_c
            ev_a = np.full(m, self.null_id, np.int32)
            ev_a[:len(ev_c)] = ev_c
            # the async transfer: on an accelerator, device_put returns
            # immediately with the H2D copy in flight, the scatter
            # consumes the future, and the serving gather orders itself
            # after it by data dependency — no host sync anywhere. On the
            # CPU backend the jit argument conversion IS that (zero-copy)
            # transfer, and an explicit device_put would only add a
            # Python round-trip to the same buffer.
            rows_dev = jax.device_put(rows_np) if _EXPLICIT_PUT \
                else rows_np
            self.staging, self.slot_of = _apply_stage(
                self.staging, self.slot_of, rows_dev,
                slots_a, ids_a, ev_a)

    # -- accounting --------------------------------------------------------

    @property
    def touches(self) -> int:
        """Unique cold rows demanded by batches so far (the invariant:
        touches == hits + misses, asserted by the bench smoke)."""
        return self.hits + self.misses

    def hit_rate(self) -> float:
        t = self.touches
        return self.hits / t if t else 1.0

    def stats(self) -> dict:
        return {"hits": self.hits, "misses": self.misses,
                "touches": self.touches, "hit_rate": self.hit_rate(),
                "resident": int(self.staging_rows - self._n_free),
                "staging_rows": self.staging_rows,
                "host_rows": self.n_cold,
                "host_bytes": int(self.host_rows.nbytes)}
