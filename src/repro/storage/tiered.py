"""Frequency-tiered embedding storage: hot fp / warm int8 / cold int4-or-host.

RecNMP's observation is that recommendation index streams are so skewed
that a small hot set absorbs most touches; MP-Rec's is that the embedding
*representation* should be a per-table plan-time decision. ``TieredSource``
is both at once: the online trainer's decayed row-frequency histogram
partitions a table's rows into

* **hot** — top rows, full-precision, bit-exact vs ``FpArena``;
* **warm** — next rows, int8 + per-row scale (4x denser);
* **cold** — the tail, either packed int4 on device (8x denser) or a
  host-resident block behind a bounded staging arena
  (``repro.storage.host_store`` — device cost is the staging arena only).

One device-side ``tier_slot`` map (arena row -> a slot in the concatenated
[hot | warm | cold] slot space) routes every gathered position to exactly
one tier; the other two tiers read their zero null slot at that position,
so the three per-tier reductions sum to the exact composition — the same
mask-free redirect protocol the hot/cold cache split uses, three ways.
Hot rows therefore agree with the fp arena bit-for-bit, warm/cold within
their quantization bounds, and grads flow to the hot rows through the
same fused VJP the fp path trains with.

Declared per table: ``TablePlan(tiers=TierPolicy(...))`` — a heterogeneous
group tiers only its huge tables while small ones stay plain fp.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import embedding_source as es
from repro.core import sparse_engine as se
from repro.kernels import ops
from repro.storage.host_store import HostStore, HostTier

__all__ = ["Int4Arena", "TierPolicy", "TieredSource", "build_tiered",
           "host_stores_of", "migrate", "refresh_host_tiers",
           "tier_bytes"]


@es.register_source(("packed", "scales"), ("dim",))
@dataclass(frozen=True)
class Int4Arena(es.EmbeddingSource):
    """Nibble-packed int4 rows + one f32 scale per row (~7.5x capacity).

    The int8 masking protocol carries through: an all-zero (null) row
    packs to zero codes with a zero scale, so every redirect stays inert.
    ``dim`` is meta (the packed axis is ceil(dim/2) bytes, so the row
    width is not recoverable from the array shape alone).
    """
    packed: jax.Array                    # (rows, ceil(dim/2)) uint8
    scales: jax.Array                    # (rows, 1) f32
    dim: int = 0

    @property
    def out_dtype(self):
        return jnp.float32

    @classmethod
    def from_arena(cls, arena: jax.Array) -> "Int4Arena":
        packed, scales = ops.int4_pack(arena.astype(jnp.float32))
        return cls(packed=packed, scales=scales, dim=int(arena.shape[1]))

    def dequantize(self) -> jax.Array:
        return ops.int4_unpack(self.packed, self.scales, self.dim)

    def reduce_dense(self, spec, dense):
        return ops.fused_int4_segment_sum(self.packed, self.scales, dense,
                                          dim=self.dim)

    def reduce_flat(self, spec, flat, offsets, *, max_l):
        dense = se.ragged_dense_ids(flat, offsets, max_l=max_l,
                                    fill=spec.null_row)
        return self.reduce_dense(spec, dense)

    def _describe(self) -> str:
        return "int4"

    def _describe_lines(self, depth: int) -> list:
        pad = "  " * depth
        r = self.packed.shape[0]
        return [f"{pad}int4 arena ({r}x{self.dim} nibble-packed + f32 "
                f"row scales, {es.fmt_bytes(self.device_bytes())})"]

    def device_bytes(self) -> int:
        return int(self.packed.nbytes + self.scales.nbytes)


@es.register_meta_type
@dataclass(frozen=True)
class TierPolicy:
    """The declarative tiering knob on a ``TablePlan``.

    ``hot``/``warm`` are row counts (the frequency ranking's top slices);
    everything else is cold. ``cold='int4'`` keeps the tail on device at
    4 bits/value; ``cold='host'`` moves it off device entirely behind a
    ``staging_rows``-slot arena fed ``max_stage_per_batch`` rows per
    transfer chunk.
    """
    hot: int
    warm: int
    cold: str = "int4"                   # 'int4' | 'host'
    staging_rows: int = 256
    max_stage_per_batch: int = 64

    def __post_init__(self):
        assert self.hot >= 0 and self.warm >= 0, (self.hot, self.warm)
        assert self.cold in ("int4", "host"), self.cold

    def partition(self, counts: np.ndarray, null_row: int):
        """Rank rows by decayed frequency (the ``build_hot_cache``
        ordering rule: stable argsort, descending) and slice into
        (hot_ids, warm_ids, cold_ids); the null row joins no tier."""
        order = np.argsort(np.asarray(counts), kind="stable")[::-1]
        order = order[order != null_row]
        h = min(self.hot, order.size)
        w = min(self.warm, order.size - h)
        return (order[:h].astype(np.int64),
                order[h:h + w].astype(np.int64),
                order[h + w:].astype(np.int64))

    def build_source(self, arena: jax.Array, spec: se.ArenaSpec,
                     counts: Optional[np.ndarray] = None, *,
                     store: Optional[HostStore] = None,
                     telemetry=None) -> "TieredSource":
        """Materialize the plan for one arena (the ``SourceSpec.build``
        hook). ``counts`` defaults to uniform; pass ``store`` to re-tier
        around an existing host store's identity (structure-stable
        republication requires the same store object in the treedef)."""
        return build_tiered(arena, spec, self, counts, store=store,
                            telemetry=telemetry)


@es.register_source(("hot_rows", "tier_slot", "hot_ids", "warm", "cold"),
                    ())
@dataclass(frozen=True)
class TieredSource(es.EmbeddingSource):
    """Three-tier composition behind the one ``reduce_dense`` hook.

    ``tier_slot[row]`` lands in exactly one of three slot ranges —
    ``[0, H)`` hot, ``[H, H+W)`` warm, ``[H+W, H+W+C]`` cold (the top
    value is the cold null) — and each tier's reduction redirects
    out-of-range positions to its own zero null slot, so
    ``hot + warm + cold`` is the exact per-position composition. The
    null arena row maps to the cold null slot (every tier reads zero).

    Structure: hot_rows (H+1, D) fp with slot H zero; warm a slot-indexed
    ``QuantizedArena`` (W+1 rows, zero-scale null); cold an ``Int4Arena``
    (C+1 compact rows) or a ``HostTier`` (staging arena over C compact
    host rows). H/W/C are fixed by the plan, so re-tiering under drift
    republishes the same treedef — the no-recompile swap contract holds
    across migrations.
    """
    hot_rows: jax.Array                  # (H+1, D) fp, slot H zero
    tier_slot: jax.Array                 # (total_rows,) int32
    hot_ids: jax.Array                   # (H,) int32 arena rows of slots
    warm: es.QuantizedArena              # (W+1, D) slot-indexed
    cold: Union[Int4Arena, HostTier]     # (C+1,) compact-slot-indexed

    @property
    def out_dtype(self):
        return jnp.float32

    @property
    def n_hot(self) -> int:
        return self.hot_rows.shape[0] - 1

    @property
    def n_warm(self) -> int:
        return self.warm.q.shape[0] - 1

    @property
    def n_cold(self) -> int:
        if isinstance(self.cold, HostTier):
            return self.cold.slot_of.shape[0] - 1
        return self.cold.packed.shape[0] - 1

    def reduce_dense(self, spec, dense):
        h, w, c = self.n_hot, self.n_warm, self.n_cold
        ts = jnp.take(self.tier_slot, dense, axis=0)
        hot_ids = jnp.where(ts < h, ts, h)
        warm_ids = jnp.where((ts >= h) & (ts < h + w), ts - h, w)
        cold_ids = jnp.where(ts >= h + w,
                             jnp.minimum(ts - (h + w), c), c)
        out = ops.fused_segment_sum(self.hot_rows, hot_ids, null_row=h)
        out = out + self.warm.reduce_dense(spec, warm_ids)
        return out + self.cold.reduce_dense(spec, cold_ids)

    def reduce_flat(self, spec, flat, offsets, *, max_l):
        dense = se.ragged_dense_ids(flat, offsets, max_l=max_l,
                                    fill=spec.null_row)
        return self.reduce_dense(spec, dense)

    def _rebind_arena(self, arena) -> "TieredSource":
        """Refresh the hot tier's fp copies from a swapped live arena
        (the ``rebind_arena`` duck hook). Warm/cold are frozen
        *representations* of an arena version — re-tier explicitly via
        the trainer's migration path."""
        d = self.hot_rows.shape[1]
        fresh = jnp.concatenate(
            [jnp.take(arena, self.hot_ids, axis=0).astype(jnp.float32),
             jnp.zeros((1, d), jnp.float32)], axis=0)
        return replace(self, hot_rows=fresh)

    def _describe(self) -> str:
        return f"tiered({self.cold._describe()})"

    def _describe_lines(self, depth: int) -> list:
        pad = "  " * depth
        b = tier_bytes(self)
        lines = [f"{pad}tiered (hot={self.n_hot} warm={self.n_warm} "
                 f"cold={self.n_cold}; "
                 f"{es.fmt_bytes(b['device_total'])} on device)"]
        lines.append(f"{pad}  hot  fp {self.hot_rows.shape[0]}x"
                     f"{self.hot_rows.shape[1]} "
                     f"({self.hot_rows.dtype}, {es.fmt_bytes(b['hot'])})")
        lines.append(f"{pad}  warm int8 {self.warm.q.shape[0]}x"
                     f"{self.warm.q.shape[1]} (+f32 scales, "
                     f"{es.fmt_bytes(b['warm'])})")
        lines += self.cold._describe_lines(depth + 1)
        return lines


def build_tiered(arena: jax.Array, spec: se.ArenaSpec,
                 policy: TierPolicy,
                 counts: Optional[np.ndarray] = None, *,
                 store: Optional[HostStore] = None,
                 telemetry=None) -> TieredSource:
    """Partition `arena` by `counts` under `policy` into a TieredSource."""
    total, d = arena.shape
    if counts is None:
        counts = np.ones(total)
    hot_ids, warm_ids, cold_ids = policy.partition(counts, spec.null_row)
    h, w, c = hot_ids.size, warm_ids.size, cold_ids.size
    a32 = jnp.asarray(arena, jnp.float32)

    hot_rows = jnp.concatenate(
        [jnp.take(a32, jnp.asarray(hot_ids), axis=0),
         jnp.zeros((1, d), jnp.float32)], axis=0)

    warm_sub = jnp.take(a32, jnp.asarray(warm_ids), axis=0)
    q, scales = se._rowwise_quantize(warm_sub)
    warm = es.QuantizedArena(
        q=jnp.concatenate([q, jnp.zeros((1, d), jnp.int8)], axis=0),
        scales=jnp.concatenate([scales, jnp.zeros((1, 1), jnp.float32)],
                               axis=0))

    tier_slot = np.full(total, h + w + c, np.int32)   # default: cold null
    tier_slot[hot_ids] = np.arange(h)
    tier_slot[warm_ids] = h + np.arange(w)
    tier_slot[cold_ids] = h + w + np.arange(c)
    tier_slot[spec.null_row] = h + w + c

    if policy.cold == "int4":
        cold_sub = jnp.concatenate(
            [jnp.take(a32, jnp.asarray(cold_ids), axis=0),
             jnp.zeros((1, d), jnp.float32)], axis=0)
        packed, cscales = ops.int4_pack(cold_sub)
        cold: es.EmbeddingSource = Int4Arena(packed=packed,
                                             scales=cscales, dim=d)
    else:
        host_rows = np.asarray(a32)[cold_ids]
        compact_of = np.full(total, c, np.int64)
        compact_of[cold_ids] = np.arange(c)
        if store is None:
            store = HostStore(host_rows,
                              staging_rows=policy.staging_rows,
                              compact_of=compact_of,
                              max_stage_per_batch=policy.max_stage_per_batch,
                              telemetry=telemetry)
        else:
            # re-tier in place: same store identity (treedef stability),
            # fresh rows/mapping/residency
            store.retarget(host_rows, compact_of)
        cold = store.tier()

    return TieredSource(hot_rows=hot_rows,
                        tier_slot=jnp.asarray(tier_slot),
                        hot_ids=jnp.asarray(hot_ids, jnp.int32),
                        warm=warm, cold=cold)


def migrate(old: TieredSource, arena: jax.Array, spec: se.ArenaSpec,
            policy: TierPolicy, counts: np.ndarray,
            dirty: Optional[np.ndarray] = None):
    """Promotion/demotion at the rebuild cadence: re-partition by the
    fresh histogram and rebuild the tiers *incrementally*.

    The dirty-row machinery from the int8 maintenance path carries over:
    a warm/cold row whose partition slot AND arena values are unchanged
    keeps its old quantized representation (a gather, not a requantize),
    so each migration costs O(moved + dirtied) quantization work instead
    of O(V). Hot rows are always refreshed from the live arena (fp copy,
    O(H)). Tier sizes are fixed by the policy, so the result has the
    treedef of ``old`` — republishing it through ``update_source`` never
    recompiles. A host cold tier is retargeted in place (same store
    identity; its staging arena resets, so post-migration batches re-warm
    via the prefetcher).

    Returns ``(new_source, stats)`` with stats carrying the promotion /
    demotion / requantization counts for the ``tier_migration`` event.
    """
    total, d = arena.shape
    if dirty is None:
        dirty = np.zeros(total, bool)
    dirty = np.asarray(dirty, bool)
    hot_ids, warm_ids, cold_ids = policy.partition(counts, spec.null_row)
    h, w, c = hot_ids.size, warm_ids.size, cold_ids.size
    assert (h, w, c) == (old.n_hot, old.n_warm, old.n_cold), \
        ((h, w, c), (old.n_hot, old.n_warm, old.n_cold),
         "tier sizes are fixed by the policy — structure stability")
    a32 = jnp.asarray(arena, jnp.float32)
    ts_old = np.asarray(old.tier_slot)

    tier_slot = np.full(total, h + w + c, np.int32)
    tier_slot[hot_ids] = np.arange(h)
    tier_slot[warm_ids] = h + np.arange(w)
    tier_slot[cold_ids] = h + w + np.arange(c)
    tier_slot[spec.null_row] = h + w + c

    hot_rows = jnp.concatenate(
        [jnp.take(a32, jnp.asarray(hot_ids), axis=0),
         jnp.zeros((1, d), jnp.float32)], axis=0)

    # warm: keep the old quantized rows that stayed warm and clean
    old_wslot = ts_old[warm_ids] - h
    stay = (old_wslot >= 0) & (old_wslot < w) & ~dirty[warm_ids]
    gather = np.where(stay, old_wslot, w)         # null slot for movers
    q = jnp.take(old.warm.q, jnp.asarray(gather), axis=0)
    sc = jnp.take(old.warm.scales, jnp.asarray(gather), axis=0)
    moved_w = np.nonzero(~stay)[0]
    if moved_w.size:
        qr, sr = se._rowwise_quantize(
            jnp.take(a32, jnp.asarray(warm_ids[moved_w]), axis=0))
        q = q.at[jnp.asarray(moved_w)].set(qr)
        sc = sc.at[jnp.asarray(moved_w)].set(sr)
    warm = es.QuantizedArena(
        q=jnp.concatenate([q, jnp.zeros((1, d), jnp.int8)], axis=0),
        scales=jnp.concatenate([sc, jnp.zeros((1, 1), jnp.float32)],
                               axis=0))

    if isinstance(old.cold, HostTier):
        host_rows = np.asarray(a32)[cold_ids]
        compact_of = np.full(total, c, np.int64)
        compact_of[cold_ids] = np.arange(c)
        store = old.cold.store
        assert store is not None, \
            "cannot migrate a deserialized HostTier without a rebound store"
        store.retarget(host_rows, compact_of)
        cold: es.EmbeddingSource = store.tier()
        requant_c = 0
    else:
        old_cslot = ts_old[cold_ids] - (h + w)
        stay_c = (old_cslot >= 0) & (old_cslot < c) & ~dirty[cold_ids]
        gather_c = np.where(stay_c, old_cslot, c)
        packed = jnp.take(old.cold.packed, jnp.asarray(gather_c), axis=0)
        csc = jnp.take(old.cold.scales, jnp.asarray(gather_c), axis=0)
        moved_c = np.nonzero(~stay_c)[0]
        if moved_c.size:
            pr, sr = ops.int4_pack(
                jnp.take(a32, jnp.asarray(cold_ids[moved_c]), axis=0))
            packed = packed.at[jnp.asarray(moved_c)].set(pr)
            csc = csc.at[jnp.asarray(moved_c)].set(sr)
        # pack the null row like build_tiered does (biased zero codes,
        # zero scale) so incremental migration == full rebuild bit-exact
        zp, zs = ops.int4_pack(jnp.zeros((1, d), jnp.float32))
        cold = Int4Arena(
            packed=jnp.concatenate([packed, zp], axis=0),
            scales=jnp.concatenate([csc, zs], axis=0),
            dim=d)
        requant_c = int(moved_c.size)

    new = TieredSource(hot_rows=hot_rows,
                       tier_slot=jnp.asarray(tier_slot),
                       hot_ids=jnp.asarray(hot_ids, jnp.int32),
                       warm=warm, cold=cold)
    old_hot = set(np.asarray(old.hot_ids).tolist())
    stats = {
        "promoted_hot": int(sum(1 for r in hot_ids if r not in old_hot)),
        "demoted_hot": int(sum(1 for r in old_hot
                               if r not in set(hot_ids.tolist()))),
        "warm_requant": int(moved_w.size),
        "cold_requant": requant_c,
    }
    return new, stats


# ---------------------------------------------------------------------------
# Source-tree walks (engine/trainer integration points)
# ---------------------------------------------------------------------------

def host_stores_of(source) -> list:
    """Every HostStore reachable from a source tree (dedup by identity,
    stable order) — what the engine stages/prefetches against."""
    out, seen = [], set()

    def walk(s):
        if isinstance(s, TieredSource):
            walk(s.cold)
        elif isinstance(s, HostTier):
            if s.store is not None and id(s.store) not in seen:
                seen.add(id(s.store))
                out.append(s.store)
        elif isinstance(s, es.TableGroupSource):
            for m in s.members:
                walk(m)
        elif isinstance(s, es.CachedSource):
            walk(s.cold)
        elif isinstance(s, es.ShardedArena):
            walk(s.inner)

    walk(source)
    return out


def refresh_host_tiers(source):
    """Re-snapshot every HostTier's array leaves from its live store —
    same treedef (store identity and shapes unchanged), fresh staging
    data. The engine calls this after ``stage()`` so the next dispatch
    serves the updated residency."""
    if isinstance(source, HostTier) and source.store is not None:
        return source.store.tier()
    if isinstance(source, TieredSource):
        return replace(source, cold=refresh_host_tiers(source.cold))
    if isinstance(source, es.TableGroupSource):
        return es.TableGroupSource(
            members=tuple(refresh_host_tiers(m) for m in source.members),
            specs=source.specs)
    if isinstance(source, es.CachedSource):
        return es.CachedSource(source.hot,
                               refresh_host_tiers(source.cold),
                               coherent=source.coherent)
    if isinstance(source, es.ShardedArena):
        return es.ShardedArena(refresh_host_tiers(source.inner),
                               source.mesh, source.axis)
    return source


def tier_bytes(source) -> dict:
    """Per-tier device byte accounting for one TieredSource (the
    ``rec_tier_bytes{tier=}`` gauge values and the bench capacity
    denominator). ``device_total`` includes the routing maps; ``host``
    counts off-device bytes only."""
    assert isinstance(source, TieredSource), type(source).__name__
    hot = int(source.hot_rows.nbytes)
    warm = int(source.warm.q.nbytes + source.warm.scales.nbytes)
    maps = int(source.tier_slot.nbytes + source.hot_ids.nbytes)
    if isinstance(source.cold, HostTier):
        cold = source.cold.device_bytes()
        host = source.cold.host_bytes()
    else:
        cold = source.cold.device_bytes()
        host = 0
    return {"hot": hot, "warm": warm, "cold": cold, "maps": maps,
            "host": host,
            "device_total": hot + warm + cold + maps}
