"""repro.storage — tiered, bigger-than-device-memory embedding storage.

The storage substrate under ROADMAP items 4 and 5: embedding tables that
do not fit device memory become a *declaratively planned* configuration
instead of a smaller model. Two modules:

* :mod:`repro.storage.tiered` — ``TieredSource``, the frequency-tiered
  three-way composition (hot fp / warm int8 / cold int4-or-host) behind
  the ordinary ``lookup_bags``/``lookup_fixed`` entry points, planned per
  table via ``TablePlan(tiers=TierPolicy(...))`` and kept current by the
  online trainer's migration pass.
* :mod:`repro.storage.host_store` — ``HostStore``/``HostTier``, the
  host-resident cold tier: rows that never enter device memory, staged
  on demand (and prefetched ahead) through a bounded, fixed-shape
  staging arena so the jitted serve path never recompiles.

Exactness is inherited from the composition laws: hot rows are bit-exact
vs the fp arena, warm/cold rows land within their per-row quantization
bound, host-staged rows are exact fp32 copies, and every tier redirect
uses the zero-null-slot protocol (no masks anywhere).
"""
from repro.storage.host_store import HostStore, HostTier
from repro.storage.tiered import (Int4Arena, TieredSource, TierPolicy,
                                  build_tiered, host_stores_of, migrate,
                                  refresh_host_tiers, tier_bytes)

__all__ = [
    "HostStore", "HostTier", "Int4Arena", "TierPolicy", "TieredSource",
    "build_tiered", "host_stores_of", "migrate", "refresh_host_tiers",
    "tier_bytes",
]
