"""Sharded-agnostic checkpointing with async save, atomic publish, keep-N.

Checkpoints store *unsharded* host arrays keyed by tree path plus a JSON
manifest (step, paths, shapes, dtypes, mesh note). Because the on-disk form
is mesh-agnostic, restore can re-place onto ANY mesh — that one property is
what makes elastic rescaling (128 -> 512 chips) and heterogeneous restart
work. ``reshard`` is just restore-with-different-shardings.

Writes go to ``<dir>/tmp.<step>`` and are atomically renamed to
``<dir>/step_<step>`` only when complete, so a killed writer never corrupts
the latest checkpoint (crash-consistent restart).

Serving-source artifacts ride the same machinery: ``save_source`` persists
a ``VersionedSource`` blob (the self-describing broadcast artifact — hot
caches, quantized arenas, table groups, tiered sources) under
``<dir>/src_<step>`` with the same tmp-then-rename crash consistency and
keep-N GC, and ``restore_source`` rebuilds the full ``EmbeddingSource``
pytree on any host — ephemeral host state (e.g. a tiered source's live
``HostStore``) is dropped by the serializer and comes back ``None``; the
restored source still serves exactly its persisted snapshot.
"""
from __future__ import annotations

import json
import shutil
import threading
from pathlib import Path
from typing import Any, Dict, List, Optional

import jax
import numpy as np


def _flatten(tree) -> List[Any]:
    leaves, _ = jax.tree_util.tree_flatten(tree)
    return leaves


def _paths(tree) -> List[str]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [jax.tree_util.keystr(p) for p, _ in flat]


class CheckpointManager:
    def __init__(self, directory, keep_n: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep_n = keep_n
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # ------------------------------------------------------------------ save
    def save(self, step: int, state, meta: Optional[Dict] = None) -> Path:
        leaves = _flatten(state)
        paths = _paths(state)
        host = [np.asarray(x) for x in leaves]

        tmp = self.dir / f"tmp.{step}"
        final = self.dir / f"step_{step}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        np.savez(tmp / "arrays.npz",
                 **{f"arr_{i}": a for i, a in enumerate(host)})
        manifest = {
            "step": int(step),
            "paths": paths,
            "shapes": [list(a.shape) for a in host],
            "dtypes": [str(a.dtype) for a in host],
            "meta": meta or {},
        }
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)                      # atomic publish
        self._gc()
        return final

    def save_async(self, step: int, state, meta: Optional[Dict] = None):
        """Snapshot to host memory synchronously, write in background."""
        self.wait()
        leaves = [np.asarray(x) for x in _flatten(state)]
        paths = _paths(state)

        def _write():
            try:
                tmp = self.dir / f"tmp.{step}"
                final = self.dir / f"step_{step}"
                if tmp.exists():
                    shutil.rmtree(tmp)
                tmp.mkdir(parents=True)
                np.savez(tmp / "arrays.npz",
                         **{f"arr_{i}": a for i, a in enumerate(leaves)})
                manifest = {
                    "step": int(step), "paths": paths,
                    "shapes": [list(a.shape) for a in leaves],
                    "dtypes": [str(a.dtype) for a in leaves],
                    "meta": meta or {},
                }
                (tmp / "manifest.json").write_text(json.dumps(manifest))
                if final.exists():
                    shutil.rmtree(final)
                tmp.rename(final)
                self._gc()
            except BaseException as e:
                self._error = e

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self):
        steps = sorted(self.steps())
        for s in steps[:-self.keep_n] if self.keep_n else []:
            shutil.rmtree(self.dir / f"step_{s}", ignore_errors=True)
        self._gc_orphans()

    def _gc_orphans(self):
        """Remove debris from writers that died mid-save. A crash between
        ``tmp.<step>`` creation and the atomic rename leaves the tmp dir
        behind forever (the next save of the SAME step would clear it, but
        steps normally only move forward) — sweep them all here so every
        completed save also cleans up any earlier torn write."""
        for p in self.dir.glob("tmp.*"):
            if p.is_dir():
                shutil.rmtree(p, ignore_errors=True)

    # ------------------------------------------------------- source artifacts
    def save_source(self, step: int, versioned,
                    meta: Optional[Dict] = None) -> Path:
        """Persist a ``VersionedSource`` serving artifact at ``step``.

        The blob is the same self-describing bytes ``publish_source``
        broadcasts, so a restart can re-adopt the last published serving
        source without replaying the trainer. Atomic tmp-then-rename like
        ``save``; GC'd under the same keep-N policy (independently of
        param checkpoints — ``src_*`` and ``step_*`` are separate
        namespaces, so a step can have either or both)."""
        from repro.core.embedding_source import VersionedSource
        assert isinstance(versioned, VersionedSource), versioned
        blob = versioned.serialize()
        tmp = self.dir / f"tmp.src.{step}"
        final = self.dir / f"src_{step}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        (tmp / "source.vsrc").write_bytes(blob)
        manifest = {"step": int(step),
                    "version": int(versioned.version),
                    "bytes": len(blob),
                    "meta": meta or {}}
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)                  # atomic publish
        self._gc_sources()
        return final

    def source_steps(self) -> List[int]:
        return sorted(int(p.name.split("_")[1])
                      for p in self.dir.glob("src_*"))

    def latest_source_step(self) -> Optional[int]:
        s = self.source_steps()
        return s[-1] if s else None

    def restore_source(self, step: Optional[int] = None):
        """Load the ``VersionedSource`` artifact at ``step`` (default:
        latest). Returns ``(VersionedSource, manifest)`` — push it into a
        replica with ``versioned.apply(engine)`` or serve
        ``versioned.source`` directly."""
        from repro.core.embedding_source import VersionedSource
        step = step if step is not None else self.latest_source_step()
        if step is None:
            raise FileNotFoundError(f"no source artifacts in {self.dir}")
        d = self.dir / f"src_{step}"
        blob = (d / "source.vsrc").read_bytes()
        manifest = json.loads((d / "manifest.json").read_text())
        return VersionedSource.deserialize(blob), manifest

    def _gc_sources(self):
        steps = self.source_steps()
        for s in steps[:-self.keep_n] if self.keep_n else []:
            shutil.rmtree(self.dir / f"src_{s}", ignore_errors=True)
        self._gc_orphans()

    # --------------------------------------------------------------- restore
    def steps(self) -> List[int]:
        return sorted(int(p.name.split("_")[1])
                      for p in self.dir.glob("step_*"))

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    def restore(self, template, step: Optional[int] = None,
                shardings=None):
        """Restore into the structure of `template` (a pytree of arrays or
        ShapeDtypeStructs). `shardings`: optional matching pytree of
        NamedShardings for elastic re-placement onto a (new) mesh."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = self.dir / f"step_{step}"
        manifest = json.loads((d / "manifest.json").read_text())
        data = np.load(d / "arrays.npz")
        leaves = [data[f"arr_{i}"] for i in range(len(manifest["paths"]))]

        t_leaves, treedef = jax.tree_util.tree_flatten(template)
        if len(t_leaves) != len(leaves):
            raise ValueError(
                f"checkpoint has {len(leaves)} leaves, template "
                f"{len(t_leaves)} — structure mismatch")
        for a, t in zip(leaves, t_leaves):
            if tuple(a.shape) != tuple(t.shape):
                raise ValueError(f"shape mismatch {a.shape} vs {t.shape}")

        if shardings is not None:
            s_leaves = jax.tree_util.tree_flatten(shardings)[0]
            placed = [jax.device_put(a.astype(t.dtype), s)
                      for a, t, s in zip(leaves, t_leaves, s_leaves)]
        else:
            placed = [jax.numpy.asarray(a.astype(t.dtype))
                      for a, t in zip(leaves, t_leaves)]
        return treedef.unflatten(placed), manifest


def reshard_checkpoint(src_dir, template, new_shardings,
                       step: Optional[int] = None):
    """Elastic rescale: load a checkpoint and place it onto a new mesh."""
    mgr = CheckpointManager(src_dir, keep_n=0)
    return mgr.restore(template, step=step, shardings=new_shardings)
