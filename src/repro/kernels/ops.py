"""jit'd public wrappers around the Pallas kernels.

Dispatch policy: on TPU the compiled Pallas kernels run; on CPU (this
container) the mathematically identical XLA path from ``ref.py`` runs so the
framework is usable end-to-end, and tests exercise the kernel bodies with
``interpret=True``. The active implementation can be forced globally:

    from repro.kernels import ops
    ops.set_impl("interpret")   # 'auto' | 'xla' | 'pallas' | 'interpret'

``embedding_bag`` carries a custom VJP: the backward of a gather-reduce is a
scatter-add into the table — the sparse engine run in reverse — implemented
with XLA scatter (segment-sum semantics), keeping training differentiable
through the kernel path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import embedding_gather as _eg
from repro.kernels import feature_interaction as _fi
from repro.kernels import fused_dispatch as _fd
from repro.kernels import gemm as _gm
from repro.kernels import ref as _ref

_IMPL = "auto"
_VALID = ("auto", "xla", "pallas", "interpret")


def set_impl(impl: str) -> None:
    global _IMPL
    if impl not in _VALID:
        raise ValueError(f"impl must be one of {_VALID}")
    _IMPL = impl


def get_impl() -> str:
    if _IMPL != "auto":
        return _IMPL
    return "pallas" if jax.default_backend() == "tpu" else "xla"


# ---------------------------------------------------------------------------
# GEMM (dense engine)
# ---------------------------------------------------------------------------

@jax.custom_vjp
def gemm(x: jax.Array, w: jax.Array) -> jax.Array:
    impl = get_impl()
    if impl == "xla":
        return _ref.gemm(x, w)
    return _gm.gemm(x, w, interpret=(impl == "interpret"))


def _gemm_fwd(x, w):
    return gemm(x, w), (x, w)


def _gemm_bwd(res, g):
    # backward-of-GEMM = two GEMMs on the same engine (dx = g w^T,
    # dw = x^T g), so training runs the dense engine end to end
    x, w = res
    dx = gemm(g, w.T).astype(x.dtype)
    dw = gemm(x.T, g).astype(w.dtype)
    return dx, dw


gemm.defvjp(_gemm_fwd, _gemm_bwd)


# ---------------------------------------------------------------------------
# Embedding bag (sparse engine) with custom VJP
# ---------------------------------------------------------------------------

import functools


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def _bag(table: jax.Array, indices: jax.Array, vocab: int,
         dtype_name: str) -> jax.Array:
    impl = get_impl()
    if impl == "xla":
        return _ref.embedding_bag(table, indices)
    return _eg.embedding_bag(table, indices, interpret=(impl == "interpret"))


def _bag_fwd(table, indices, vocab, dtype_name):
    return _bag(table, indices, vocab, dtype_name), indices


def _bag_bwd(vocab, dtype_name, indices, g):
    b, l = indices.shape
    d = g.shape[-1]
    g32 = g.astype(jnp.float32)
    g_rows = jnp.broadcast_to(g32[:, None, :], (b, l, d))
    d_table = jnp.zeros((vocab, d), jnp.float32)
    d_table = d_table.at[indices.reshape(-1)].add(g_rows.reshape(b * l, d))
    return d_table.astype(dtype_name), None


_bag.defvjp(_bag_fwd, _bag_bwd)


def embedding_bag(table: jax.Array, indices: jax.Array) -> jax.Array:
    """out[b] = sum_l table[indices[b, l]]; table (V,D), indices (B,L)."""
    return _bag(table, indices, table.shape[0], str(table.dtype))


def gather_rows(table: jax.Array, indices: jax.Array) -> jax.Array:
    """out[t] = table[indices[t]]; single-row bags (LM token embedding)."""
    return embedding_bag(table, indices[:, None])


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def _sls(table: jax.Array, indices: jax.Array, offsets: jax.Array,
         max_l: int, vocab: int, dtype_name: str) -> jax.Array:
    impl = get_impl()
    if impl == "xla":
        return _ref.sparse_lengths_sum(table, indices, offsets)
    return _eg.sparse_lengths_sum(table, indices, offsets, max_l=max_l,
                                  interpret=(impl == "interpret"))


def _sls_fwd(table, indices, offsets, max_l, vocab, dtype_name):
    return _sls(table, indices, offsets, max_l, vocab, dtype_name), \
        (indices, offsets)


def _sls_bwd(max_l, vocab, dtype_name, res, g):
    indices, offsets = res
    impl = get_impl()
    if impl == "xla":
        d_table = _ref.sls_grad_table(g, indices, offsets, vocab)
    else:
        d_table = _eg.sls_grad_table(g, indices, offsets, n_rows=vocab,
                                     interpret=(impl == "interpret"))
    return d_table.astype(dtype_name), None, None


_sls.defvjp(_sls_fwd, _sls_bwd)


def sparse_lengths_sum(table: jax.Array, indices: jax.Array,
                       offsets: jax.Array, *, max_l: int) -> jax.Array:
    """Ragged SparseLengthsSum (the paper's Fig. 2 production API).

    out[b] = sum over table[indices[offsets[b]:offsets[b+1]]]; indices may
    be padded past offsets[-1] (padded positions are ignored). `max_l` is
    the static per-bag length bound the kernel grid is sized for.

    Differentiable on every backend: the custom VJP is the fused segment
    scatter-add (the sparse engine run in reverse) — the Pallas
    `sls_grad_table` kernel on pallas/interpret, the XLA segment-sum
    reference on xla.
    """
    return _sls(table, indices, offsets, max_l, table.shape[0],
                str(table.dtype))


# ---------------------------------------------------------------------------
# Fused segmented dispatch (sparse engine, dense id-matrix form)
# ---------------------------------------------------------------------------

def _dense_offsets(dense_ids: jax.Array) -> jax.Array:
    # A dense (B, L) id matrix IS a uniform-offset ragged stream, so the
    # fused backward can reuse the proven sls_grad_table scatter-add.
    b, l = dense_ids.shape
    return jnp.arange(b + 1, dtype=jnp.int32) * l


def _dense_grad_table(g, dense_ids, vocab, null_row):
    impl = get_impl()
    offsets = _dense_offsets(dense_ids)
    if impl == "xla":
        d = _ref.sls_grad_table(g, dense_ids.reshape(-1), offsets, vocab)
    else:
        d = _eg.sls_grad_table(g, dense_ids.reshape(-1), offsets,
                               n_rows=vocab,
                               interpret=(impl == "interpret"))
    if null_row is None:
        return d
    # null_row is the always-zero sentinel every short/padded dense slot
    # points at. The ragged backward never trained it (fill lived past
    # offsets[-1] and was masked); the dense relayout moves fill INSIDE
    # the stream, so pin its gradient to zero here or the dense optimizer
    # would break the sentinel's always-zero invariant on the first step.
    return d.at[null_row].set(0.0)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4))
def _fused(table: jax.Array, dense_ids: jax.Array, vocab: int,
           dtype_name: str, null_row) -> jax.Array:
    impl = get_impl()
    if impl == "xla":
        return _ref.fused_segment_sum(table, dense_ids)
    return _fd.fused_segment_sum(table, dense_ids,
                                 interpret=(impl == "interpret"))


def _fused_fwd(table, dense_ids, vocab, dtype_name, null_row):
    return _fused(table, dense_ids, vocab, dtype_name, null_row), dense_ids


def _fused_bwd(vocab, dtype_name, null_row, dense_ids, g):
    d = _dense_grad_table(g, dense_ids, vocab, null_row)
    return d.astype(dtype_name), None


_fused.defvjp(_fused_fwd, _fused_bwd)


def fused_segment_sum(table: jax.Array, dense_ids: jax.Array, *,
                      null_row=None) -> jax.Array:
    """Segmented reduce over a dense id matrix: out[b] = sum_j table[ids[b,j]].

    ``dense_ids`` is a ``se.ragged_dense_ids`` relayout — (B, max_l) with
    short/padded slots pointing at an always-zero row — so the whole
    reduction is one gather + one per-bag sum with NO scatter in the
    forward. Returns f32 (B, D). Differentiable: the custom VJP is the
    same fused segment scatter-add backing ``sparse_lengths_sum``; pass
    ``null_row`` (the sentinel the relayout's fill slots point at) so the
    backward pins its gradient to zero like the ragged tail mask did.
    """
    return _fused(table, dense_ids, table.shape[0], str(table.dtype),
                  None if null_row is None else int(null_row))


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7, 8))
def _fused_cached(hot_rows: jax.Array, arena: jax.Array, slots: jax.Array,
                  cold_ids: jax.Array, k_slots: int, vocab: int,
                  hot_dtype: str, arena_dtype: str,
                  null_row) -> jax.Array:
    impl = get_impl()
    if impl == "xla":
        return _ref.fused_cached_segment_sum(hot_rows, arena, slots, cold_ids)
    return _fd.fused_cached_segment_sum(hot_rows, arena, slots, cold_ids,
                                        interpret=(impl == "interpret"))


def _fused_cached_fwd(hot_rows, arena, slots, cold_ids, k_slots, vocab,
                      hot_dtype, arena_dtype, null_row):
    out = _fused_cached(hot_rows, arena, slots, cold_ids, k_slots, vocab,
                        hot_dtype, arena_dtype, null_row)
    return out, (slots, cold_ids)


def _fused_cached_bwd(k_slots, vocab, hot_dtype, arena_dtype, null_row,
                      res, g):
    slots, cold_ids = res
    # the hot arena's null slot is its last row by HotRowCache
    # construction (k real slots + one zero miss slot)
    d_hot = _dense_grad_table(g, slots, k_slots,
                              k_slots - 1).astype(hot_dtype)
    d_arena = _dense_grad_table(g, cold_ids, vocab,
                                null_row).astype(arena_dtype)
    return d_hot, d_arena, None, None


_fused_cached.defvjp(_fused_cached_fwd, _fused_cached_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7, 8, 9))
def _fused_cached_coh(hot_rows, arena, dense_ids, slots, cold_ids,
                      k_slots, vocab, hot_dtype, arena_dtype, null_row):
    impl = get_impl()
    if impl == "xla":
        # Coherence law (docs/ARCHITECTURE.md §2): hot copies equal their
        # arena rows, so hot_rows[slot] + arena[cold_id] == arena[id] per
        # position. XLA's gather cost ignores locality, which makes the
        # plain arena reduction the fastest correct lowering — the hit
        # test survives only in the backward, where the hot/cold grad
        # split is real state.
        return _ref.fused_segment_sum(arena, dense_ids)
    # On the accelerator the two-table walk IS the win: hot rows live in
    # SRAM, so the in-kernel hit test turns arena HBM traffic into
    # on-chip loads for every cached row.
    return _fd.fused_cached_segment_sum(hot_rows, arena, slots, cold_ids,
                                        interpret=(impl == "interpret"))


def _fused_cached_coh_fwd(hot_rows, arena, dense_ids, slots, cold_ids,
                          k_slots, vocab, hot_dtype, arena_dtype, null_row):
    out = _fused_cached_coh(hot_rows, arena, dense_ids, slots, cold_ids,
                            k_slots, vocab, hot_dtype, arena_dtype, null_row)
    return out, (slots, cold_ids)


def _fused_cached_coh_bwd(k_slots, vocab, hot_dtype, arena_dtype, null_row,
                          res, g):
    slots, cold_ids = res
    d_hot = _dense_grad_table(g, slots, k_slots,
                              k_slots - 1).astype(hot_dtype)
    d_arena = _dense_grad_table(g, cold_ids, vocab,
                                null_row).astype(arena_dtype)
    return d_hot, d_arena, None, None, None


_fused_cached_coh.defvjp(_fused_cached_coh_fwd, _fused_cached_coh_bwd)


def fused_cached_segment_sum(hot_rows: jax.Array, arena: jax.Array,
                             slots: jax.Array, cold_ids: jax.Array, *,
                             dense_ids=None, null_row=None) -> jax.Array:
    """One-pass hot/cold segmented reduce with the hit test in the kernel.

    Per position exactly one of ``hot_rows[slots]`` (miss -> the zero
    null slot) and ``arena[cold_ids]`` (hit -> the zero null row) is
    nonzero, so accumulating their sum in a single walk equals the
    uncached reduction bit-for-bit — replacing CachedSource's two full
    passes. slots/cold_ids are (B, max_l) dense matrices over the same
    bags. Returns f32 (B, D); gradients flow to both arenas via the
    fused segment scatter-add, with the miss slot's and (when
    ``null_row`` is given) the arena sentinel's gradients pinned to zero.

    When ``dense_ids`` (the pre-split id matrix) is also given, the op
    additionally assumes the cache coherence law — hot copies equal
    their arena rows — and on the XLA substrate lowers the forward to
    the plain arena reduction (one gather, identical to the uncached
    path), since per-row gather cost there is locality-blind. The
    backward is unchanged: gradients still split onto hot slots and
    cold ids exactly. The Pallas lowering always runs the real
    two-table walk. Omit ``dense_ids`` when coherence is not
    guaranteed (e.g. deliberately stale hot rows).
    """
    if dense_ids is not None:
        return _fused_cached_coh(hot_rows, arena, dense_ids, slots,
                                 cold_ids, hot_rows.shape[0],
                                 arena.shape[0], str(hot_rows.dtype),
                                 str(arena.dtype),
                                 None if null_row is None else int(null_row))
    return _fused_cached(hot_rows, arena, slots, cold_ids,
                         hot_rows.shape[0], arena.shape[0],
                         str(hot_rows.dtype), str(arena.dtype),
                         None if null_row is None else int(null_row))


# ---------------------------------------------------------------------------
# Int4 cold tier (sparse engine, nibble-packed arena)
# ---------------------------------------------------------------------------

def int4_pack(a32: jax.Array):
    """Row-wise symmetric int4 quantize + nibble-pack.

    Per-row scale = amax/7 (the int8 rule at 4 bits), all-zero rows get a
    zero scale — the null-row masking protocol carries straight through.
    Returns (packed uint8 (R, ceil(D/2)), scales f32 (R, 1)).
    """
    return _ref.int4_pack(a32)


def int4_unpack(packed: jax.Array, scales: jax.Array, dim: int) -> jax.Array:
    """Dequantize an ``int4_pack`` arena back to f32 (R, dim)."""
    return _ref.int4_unpack(packed, scales, dim)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def _fused_int4(packed: jax.Array, scales: jax.Array, dense_ids: jax.Array,
                dim: int) -> jax.Array:
    impl = get_impl()
    if impl == "xla":
        return _ref.fused_int4_segment_sum(packed, scales, dense_ids, dim)
    return _fd.fused_int4_segment_sum(packed, scales, dense_ids, dim=dim,
                                      interpret=(impl == "interpret"))


def _fused_int4_fwd(packed, scales, dense_ids, dim):
    return _fused_int4(packed, scales, dense_ids, dim), \
        (packed, dense_ids)


def _fused_int4_bwd(dim, res, g):
    packed, dense_ids = res
    # out[b] = sum_j codes[ids[b,j]] * scales[ids[b,j]], so the only
    # trainable leaf is scales: d_scales[r] = sum over positions p with
    # id_p == r of <g[bag(p)], codes[r]>. The packed codes are integers
    # (None cotangent, like every integer arg in this module), and a null
    # row's codes are all zero so its scale gradient is automatically
    # zero — no sentinel pinning needed.
    b, max_l = dense_ids.shape
    g32 = g.astype(jnp.float32)                              # (B, dim)
    codes = _ref._int4_codes(packed[dense_ids], dim)         # (B, L, dim)
    per_pos = jnp.einsum("bld,bd->bl", codes.astype(jnp.float32), g32)
    d_scales = jnp.zeros((packed.shape[0], 1), jnp.float32)
    d_scales = d_scales.at[dense_ids.reshape(-1), 0].add(per_pos.reshape(-1))
    return None, d_scales, None


_fused_int4.defvjp(_fused_int4_fwd, _fused_int4_bwd)


def fused_int4_segment_sum(packed: jax.Array, scales: jax.Array,
                           dense_ids: jax.Array, *, dim: int) -> jax.Array:
    """Fused int4 dequantize-in-the-gather reduce over a dense id matrix.

    packed (V, ceil(dim/2)) uint8 + scales (V, 1) f32 from ``int4_pack``;
    dense_ids (B, max_l) with fill slots pointing at a zero-scale row.
    Returns f32 (B, dim) at an eighth of the fp32 gather bytes.
    Differentiable in ``scales`` only (the codes are frozen integers) —
    enough for the tiered property suite; cold-tier rows are trained via
    the fp shadow in the online trainer, not through this op.
    """
    return _fused_int4(packed, scales, dense_ids, int(dim))


# ---------------------------------------------------------------------------
# Feature interaction (dense engine, batched GEMM)
# ---------------------------------------------------------------------------

@jax.custom_vjp
def interaction(x: jax.Array) -> jax.Array:
    impl = get_impl()
    if impl == "xla":
        return _ref.interaction(x)
    return _fi.interaction(x, interpret=(impl == "interpret"))


def _interaction_fwd(x):
    return interaction(x), x


def _interaction_bwd(x, g):
    # z = X X^T per sample => dX = (G + G^T) X
    g32 = g.astype(jnp.float32)
    sym = g32 + jnp.swapaxes(g32, -1, -2)
    dx = jnp.einsum("bfg,bgd->bfd", sym, x.astype(jnp.float32),
                    preferred_element_type=jnp.float32)
    return (dx.astype(x.dtype),)


interaction.defvjp(_interaction_fwd, _interaction_bwd)


def interaction_tril(x: jax.Array) -> jax.Array:
    """DLRM interaction: lower-triangle (offset -1) of X X^T, flattened."""
    z = interaction(x)
    f = x.shape[1]
    li, lj = jnp.tril_indices(f, k=-1)
    return z[:, li, lj]
