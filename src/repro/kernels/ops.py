"""jit'd public wrappers around the Pallas kernels.

Dispatch policy: on TPU the compiled Pallas kernels run; on CPU (this
container) the mathematically identical XLA path from ``ref.py`` runs so the
framework is usable end-to-end, and tests exercise the kernel bodies with
``interpret=True``. The active implementation can be forced globally:

    from repro.kernels import ops
    ops.set_impl("interpret")   # 'auto' | 'xla' | 'pallas' | 'interpret'

``embedding_bag`` carries a custom VJP: the backward of a gather-reduce is a
scatter-add into the table — the sparse engine run in reverse — implemented
with XLA scatter (segment-sum semantics), keeping training differentiable
through the kernel path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import embedding_gather as _eg
from repro.kernels import feature_interaction as _fi
from repro.kernels import gemm as _gm
from repro.kernels import ref as _ref

_IMPL = "auto"
_VALID = ("auto", "xla", "pallas", "interpret")


def set_impl(impl: str) -> None:
    global _IMPL
    if impl not in _VALID:
        raise ValueError(f"impl must be one of {_VALID}")
    _IMPL = impl


def get_impl() -> str:
    if _IMPL != "auto":
        return _IMPL
    return "pallas" if jax.default_backend() == "tpu" else "xla"


# ---------------------------------------------------------------------------
# GEMM (dense engine)
# ---------------------------------------------------------------------------

@jax.custom_vjp
def gemm(x: jax.Array, w: jax.Array) -> jax.Array:
    impl = get_impl()
    if impl == "xla":
        return _ref.gemm(x, w)
    return _gm.gemm(x, w, interpret=(impl == "interpret"))


def _gemm_fwd(x, w):
    return gemm(x, w), (x, w)


def _gemm_bwd(res, g):
    # backward-of-GEMM = two GEMMs on the same engine (dx = g w^T,
    # dw = x^T g), so training runs the dense engine end to end
    x, w = res
    dx = gemm(g, w.T).astype(x.dtype)
    dw = gemm(x.T, g).astype(w.dtype)
    return dx, dw


gemm.defvjp(_gemm_fwd, _gemm_bwd)


# ---------------------------------------------------------------------------
# Embedding bag (sparse engine) with custom VJP
# ---------------------------------------------------------------------------

import functools


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def _bag(table: jax.Array, indices: jax.Array, vocab: int,
         dtype_name: str) -> jax.Array:
    impl = get_impl()
    if impl == "xla":
        return _ref.embedding_bag(table, indices)
    return _eg.embedding_bag(table, indices, interpret=(impl == "interpret"))


def _bag_fwd(table, indices, vocab, dtype_name):
    return _bag(table, indices, vocab, dtype_name), indices


def _bag_bwd(vocab, dtype_name, indices, g):
    b, l = indices.shape
    d = g.shape[-1]
    g32 = g.astype(jnp.float32)
    g_rows = jnp.broadcast_to(g32[:, None, :], (b, l, d))
    d_table = jnp.zeros((vocab, d), jnp.float32)
    d_table = d_table.at[indices.reshape(-1)].add(g_rows.reshape(b * l, d))
    return d_table.astype(dtype_name), None


_bag.defvjp(_bag_fwd, _bag_bwd)


def embedding_bag(table: jax.Array, indices: jax.Array) -> jax.Array:
    """out[b] = sum_l table[indices[b, l]]; table (V,D), indices (B,L)."""
    return _bag(table, indices, table.shape[0], str(table.dtype))


def gather_rows(table: jax.Array, indices: jax.Array) -> jax.Array:
    """out[t] = table[indices[t]]; single-row bags (LM token embedding)."""
    return embedding_bag(table, indices[:, None])


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def _sls(table: jax.Array, indices: jax.Array, offsets: jax.Array,
         max_l: int, vocab: int, dtype_name: str) -> jax.Array:
    impl = get_impl()
    if impl == "xla":
        return _ref.sparse_lengths_sum(table, indices, offsets)
    return _eg.sparse_lengths_sum(table, indices, offsets, max_l=max_l,
                                  interpret=(impl == "interpret"))


def _sls_fwd(table, indices, offsets, max_l, vocab, dtype_name):
    return _sls(table, indices, offsets, max_l, vocab, dtype_name), \
        (indices, offsets)


def _sls_bwd(max_l, vocab, dtype_name, res, g):
    indices, offsets = res
    impl = get_impl()
    if impl == "xla":
        d_table = _ref.sls_grad_table(g, indices, offsets, vocab)
    else:
        d_table = _eg.sls_grad_table(g, indices, offsets, n_rows=vocab,
                                     interpret=(impl == "interpret"))
    return d_table.astype(dtype_name), None, None


_sls.defvjp(_sls_fwd, _sls_bwd)


def sparse_lengths_sum(table: jax.Array, indices: jax.Array,
                       offsets: jax.Array, *, max_l: int) -> jax.Array:
    """Ragged SparseLengthsSum (the paper's Fig. 2 production API).

    out[b] = sum over table[indices[offsets[b]:offsets[b+1]]]; indices may
    be padded past offsets[-1] (padded positions are ignored). `max_l` is
    the static per-bag length bound the kernel grid is sized for.

    Differentiable on every backend: the custom VJP is the fused segment
    scatter-add (the sparse engine run in reverse) — the Pallas
    `sls_grad_table` kernel on pallas/interpret, the XLA segment-sum
    reference on xla.
    """
    return _sls(table, indices, offsets, max_l, table.shape[0],
                str(table.dtype))


# ---------------------------------------------------------------------------
# Feature interaction (dense engine, batched GEMM)
# ---------------------------------------------------------------------------

@jax.custom_vjp
def interaction(x: jax.Array) -> jax.Array:
    impl = get_impl()
    if impl == "xla":
        return _ref.interaction(x)
    return _fi.interaction(x, interpret=(impl == "interpret"))


def _interaction_fwd(x):
    return interaction(x), x


def _interaction_bwd(x, g):
    # z = X X^T per sample => dX = (G + G^T) X
    g32 = g.astype(jnp.float32)
    sym = g32 + jnp.swapaxes(g32, -1, -2)
    dx = jnp.einsum("bfg,bgd->bfd", sym, x.astype(jnp.float32),
                    preferred_element_type=jnp.float32)
    return (dx.astype(x.dtype),)


interaction.defvjp(_interaction_fwd, _interaction_bwd)


def interaction_tril(x: jax.Array) -> jax.Array:
    """DLRM interaction: lower-triangle (offset -1) of X X^T, flattened."""
    z = interaction(x)
    f = x.shape[1]
    li, lj = jnp.tril_indices(f, k=-1)
    return z[:, li, lj]
