"""Fused embedding gather + on-the-fly reduce — the Centaur *sparse engine*.

TPU adaptation of EB-Streamer (Fig. 10). The mapping is exact in spirit:

  SRAM_sparseID  -> scalar-prefetch operand: the whole index array lands in
                    SMEM *before* the grid starts, so the grid's BlockSpec
                    index_map can address arbitrary table rows, driving the
                    double-buffered HBM->VMEM row DMA pipeline (the hardware
                    gather unit EB-GU becomes the Pallas pipeline engine);
  EB-RU          -> rows are accumulated into a VMEM fp32 accumulator as
                    they arrive (reduction happens on the fly; gathered rows
                    are never materialized to HBM);
  BPregs         -> the table Ref itself (base pointer + strides).

Unlike the CPU baseline (jnp take -> materialize (B, L, D) -> sum), this
kernel reads exactly L*D useful bytes per bag and writes D — the paper's
"effective memory throughput" definition (Section III-C) counts exactly
these bytes.

Training runs the same engine in reverse: ``sls_grad_table`` is the fused
segment *scatter-add* — the VJP of ``sparse_lengths_sum`` — streaming one
upstream bag-gradient row per grid step into the destination table row.
Positions are pre-sorted by destination so every output row is visited in
exactly one contiguous run (accumulate in VMEM, flush once), which is both
the output-stationary optimum and the only revisit pattern that is safe
under the TPU output-pipeline's deferred write-back.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat


def _bag_kernel(idx_ref, table_ref, o_ref, acc_ref, *, n_l: int):
    l = pl.program_id(2)

    @pl.when(l == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # One gathered row arrives per grid step (streamed HBM->VMEM by the
    # pipeline using the prefetched index); reduce it immediately.
    acc_ref[...] += table_ref[...].astype(jnp.float32)

    @pl.when(l == n_l - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bd", "interpret"))
def embedding_bag(table: jax.Array, indices: jax.Array, *, bd: int = 2048,
                  interpret: bool = False) -> jax.Array:
    """Fixed-lookup SparseLengthsSum: out[b] = sum_l table[idx[b, l]].

    table: (V, D), indices: (B, L) int32 -> (B, D).
    Grid: (bags, d-blocks, lookups); lookups innermost so the fp32
    accumulator tile is revisited on consecutive steps (output-stationary).
    """
    v, d = table.shape
    b, l = indices.shape
    bd = min(bd, d)
    grid = (b, pl.cdiv(d, bd), l)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            # One table row block per step, row chosen by the prefetched
            # sparse index — the EB-GU address generator.
            pl.BlockSpec((1, bd), lambda bb, dd, ll, idx: (idx[bb, ll], dd)),
        ],
        out_specs=pl.BlockSpec((1, bd), lambda bb, dd, ll, idx: (bb, dd)),
        scratch_shapes=[pltpu.VMEM((1, bd), jnp.float32)],
    )
    fn = pl.pallas_call(
        functools.partial(_bag_kernel, n_l=l),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, d), table.dtype),
        compiler_params=compat.CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary", "arbitrary")),
        interpret=interpret,
    )
    return fn(indices, table)


@functools.partial(jax.jit, static_argnames=("bd", "interpret"))
def gather_rows(table: jax.Array, indices: jax.Array, *, bd: int = 2048,
                interpret: bool = False) -> jax.Array:
    """Plain row gather (L=1 bags): out[t] = table[indices[t]].

    Used for LM vocab-embedding lookup (single-row 'bags'); same streaming
    engine without the reduction stage.
    """
    return embedding_bag(table, indices[:, None], bd=bd, interpret=interpret)


def _ragged_kernel(idx_ref, off_ref, table_ref, o_ref, acc_ref, *,
                   max_l: int):
    l = pl.program_id(2)
    b = pl.program_id(0)

    @pl.when(l == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # Ragged bags: lookup j of bag b is valid iff off[b]+j < off[b+1].
    # Invalid steps were routed to row 0 by the index_map; mask them here
    # (the EB-GU issuing a no-op gather — the pipeline still double-buffers).
    valid = off_ref[b] + l < off_ref[b + 1]
    row = table_ref[...].astype(jnp.float32)
    acc_ref[...] += jnp.where(valid, row, 0.0)

    @pl.when(l == max_l - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("max_l", "interpret"))
def sparse_lengths_sum(table: jax.Array, indices: jax.Array,
                       offsets: jax.Array, *, max_l: int,
                       interpret: bool = False) -> jax.Array:
    """Ragged SparseLengthsSum — the paper's Fig. 2 API, in one kernel.

    table (V, D); indices (L,) int32; offsets (B+1,) int32 (bag b reads
    indices[offsets[b]:offsets[b+1]]); max_l = static max bag length.
    Both scalar arrays are prefetched to SMEM (SRAM_sparseID + the offset
    half of BPregs); the gather address is computed per grid step as
    idx[off[b] + l] with out-of-bag steps masked in the reduction.
    """
    v, d = table.shape
    b = offsets.shape[0] - 1
    grid = (b, 1, max_l)

    def table_map(bb, dd, ll, idx, off):
        pos = off[bb] + ll
        safe = jnp.minimum(pos, idx.shape[0] - 1)
        return (jnp.where(pos < off[bb + 1], idx[safe], 0), dd)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[pl.BlockSpec((1, d), table_map)],
        out_specs=pl.BlockSpec((1, d),
                               lambda bb, dd, ll, idx, off: (bb, dd)),
        scratch_shapes=[pltpu.VMEM((1, d), jnp.float32)],
    )
    fn = pl.pallas_call(
        functools.partial(_ragged_kernel, max_l=max_l),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, d), table.dtype),
        compiler_params=compat.CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary", "arbitrary")),
        interpret=interpret,
    )
    return fn(indices, offsets, table)


def _grad_kernel(dst_ref, bag_ref, val_ref, g_ref, z_ref, o_ref, acc_ref, *,
                 n: int):
    p = pl.program_id(0)
    prev = dst_ref[jnp.maximum(p - 1, 0)]
    first = (p == 0) | (prev != dst_ref[p])

    @pl.when(first)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # One upstream bag-gradient row arrives per step (streamed by the
    # pipeline via the prefetched bag id); out-of-bag padding adds zero.
    g = g_ref[...].astype(jnp.float32)
    acc_ref[...] += jnp.where(val_ref[p] > 0, g, 0.0)

    nxt = dst_ref[jnp.minimum(p + 1, n - 1)]
    last = (p == n - 1) | (nxt != dst_ref[p])

    @pl.when(last)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("n_rows", "interpret"))
def sls_grad_table(g: jax.Array, indices: jax.Array, offsets: jax.Array, *,
                   n_rows: int, interpret: bool = False) -> jax.Array:
    """Fused segment scatter-add: the VJP of ``sparse_lengths_sum``.

    g (B, D) upstream bag gradients; indices (N,) destination rows (may be
    padded past offsets[-1]); offsets (B+1,). Returns d_table (n_rows, D):
    ``d_table[r] = sum over valid positions p with indices[p] == r of
    g[bag(p)]``.

    Positions are argsorted by destination row, so duplicate targets form
    one contiguous run per row: the run accumulates in a VMEM register and
    flushes exactly once. Untouched rows come from a zero table aliased
    onto the output buffer (``input_output_aliases``) — the kernel writes
    only the rows a run visits, everything else stays zero without a
    separate (n_rows, D) clearing pass.
    """
    n = indices.shape[0]
    n_bags = offsets.shape[0] - 1
    d = g.shape[-1]
    if n == 0:
        return jnp.zeros((n_rows, d), g.dtype)
    pos = jnp.arange(n, dtype=offsets.dtype)
    seg = jnp.searchsorted(offsets[1:], pos, side="right")
    valid = (pos < offsets[-1]).astype(jnp.int32)
    order = jnp.argsort(indices)
    dst = indices[order].astype(jnp.int32)
    bag = jnp.minimum(seg, n_bags - 1)[order].astype(jnp.int32)
    val = valid[order]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(n,),
        in_specs=[
            pl.BlockSpec((1, d), lambda p, dst, bag, val: (bag[p], 0)),
            pl.BlockSpec((1, d), lambda p, dst, bag, val: (dst[p], 0)),
        ],
        out_specs=pl.BlockSpec((1, d), lambda p, dst, bag, val: (dst[p], 0)),
        scratch_shapes=[pltpu.VMEM((1, d), jnp.float32)],
    )
    zeros = jnp.zeros((n_rows, d), g.dtype)
    fn = pl.pallas_call(
        functools.partial(_grad_kernel, n=n),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_rows, d), g.dtype),
        # operand 4 = zeros (after 3 scalar-prefetch operands and g)
        input_output_aliases={4: 0},
        compiler_params=compat.CompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )
    return fn(dst, bag, val, g, zeros)
