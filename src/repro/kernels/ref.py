"""Pure-jnp oracles for every Pallas kernel (the ground truth in tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def gemm(x: jax.Array, w: jax.Array) -> jax.Array:
    """x:(M,K) @ w:(K,N) with fp32 accumulation, result in x.dtype."""
    return jnp.dot(x, w, preferred_element_type=jnp.float32).astype(x.dtype)


def embedding_bag(table: jax.Array, indices: jax.Array) -> jax.Array:
    """Fixed-lookup SparseLengthsSum: out[b] = sum_l table[indices[b, l]].

    table: (V, D); indices: (B, L) int32 -> (B, D), fp32 accumulation.
    """
    rows = table[indices]                       # (B, L, D)
    return rows.astype(jnp.float32).sum(axis=1).astype(table.dtype)


def sparse_lengths_sum(table: jax.Array, indices: jax.Array,
                       offsets: jax.Array) -> jax.Array:
    """Ragged SparseLengthsSum (paper Fig. 2): offsets (B+1,), indices (L,)."""
    n_bags = offsets.shape[0] - 1
    segment_ids = jnp.searchsorted(offsets[1:], jnp.arange(indices.shape[0]),
                                   side="right")
    rows = table[indices].astype(jnp.float32)
    out = jax.ops.segment_sum(rows, segment_ids, num_segments=n_bags)
    return out.astype(table.dtype)


def sls_grad_table(g: jax.Array, indices: jax.Array, offsets: jax.Array,
                   n_rows: int) -> jax.Array:
    """VJP of ragged SparseLengthsSum w.r.t. the table: segment scatter-add.

    d_table[r] = sum over valid positions p with indices[p] == r of
    g[bag(p)]; padded positions (>= offsets[-1]) contribute nothing.
    """
    n = indices.shape[0]
    n_bags = offsets.shape[0] - 1
    pos = jnp.arange(n, dtype=offsets.dtype)
    seg = jnp.searchsorted(offsets[1:], pos, side="right")
    rows = jnp.take(g.astype(jnp.float32), jnp.minimum(seg, n_bags - 1),
                    axis=0)
    rows = jnp.where((pos < offsets[-1])[:, None], rows, 0.0)
    out = jax.ops.segment_sum(rows, indices, num_segments=n_rows)
    return out.astype(g.dtype)


def fused_segment_sum(table: jax.Array, dense_ids: jax.Array) -> jax.Array:
    """Fused segmented reduce over a pre-relayouted id matrix.

    dense_ids (B, max_l) holds each bag's row ids with padding/short slots
    pointing at an always-zero row (``se.ragged_dense_ids``); the result
    is one gather + one per-bag sum — the scatter-free form of
    ``sparse_lengths_sum``. Returns f32 (B, D).
    """
    return table[dense_ids].astype(jnp.float32).sum(axis=1)


def fused_cached_segment_sum(hot_rows: jax.Array, arena: jax.Array,
                             slots: jax.Array,
                             cold_ids: jax.Array) -> jax.Array:
    """One-pass hot/cold reduce: the in-kernel hit test as XLA.

    Per position, exactly one of ``hot_rows[slots]`` (miss -> zero null
    slot) and ``arena[cold_ids]`` (hit -> zero null row) is nonzero, so
    their sum is bit-for-bit the uncached row and ONE reduction covers
    both passes. slots/cold_ids are (B, max_l) dense matrices over the
    same bags. Returns f32 (B, D).
    """
    rows = hot_rows[slots].astype(jnp.float32) \
        + arena[cold_ids].astype(jnp.float32)
    return rows.sum(axis=1)


def int4_pack(a32: jax.Array):
    """Row-wise symmetric int4 quantize + nibble-pack (the cold tier).

    Mirrors the int8 rule (``se._rowwise_quantize``) at 4 bits: per-row
    scale = amax/7, values rounded into [-7, 7], an all-zero row gets a
    zero scale (the null-row masking protocol at int4). Codes are stored
    biased (+8, so 8 encodes zero) with two values per byte: column 2j in
    the low nibble, 2j+1 in the high nibble; odd dims pad one zero-code
    column. Returns (packed uint8 (R, ceil(D/2)), scales f32 (R, 1)).
    """
    a32 = a32.astype(jnp.float32)
    amax = jnp.max(jnp.abs(a32), axis=-1, keepdims=True)
    scales = amax / 7.0
    q = jnp.where(scales > 0,
                  jnp.clip(jnp.round(a32 / jnp.maximum(scales, 1e-30)),
                           -7, 7), 0).astype(jnp.int32)
    d = q.shape[-1]
    if d % 2:
        q = jnp.pad(q, ((0, 0), (0, 1)))
    code = (q + 8).astype(jnp.uint8)             # 1..15, 8 == zero
    packed = code[:, 0::2] | (code[:, 1::2] << 4)
    return packed, scales


def int4_unpack(packed: jax.Array, scales: jax.Array,
                dim: int) -> jax.Array:
    """Dequantize an ``int4_pack`` arena back to f32 (R, dim)."""
    return _int4_codes(packed, dim).astype(jnp.float32) * scales


def _int4_codes(packed: jax.Array, dim: int) -> jax.Array:
    """Unbiased integer codes in [-7, 7]: (..., P) uint8 -> (..., dim)."""
    p = packed.astype(jnp.int32)
    lo = (p & 0xF) - 8
    hi = (p >> 4) - 8
    return jnp.stack([lo, hi], axis=-1).reshape(*p.shape[:-1],
                                                2 * p.shape[-1])[..., :dim]


def fused_int4_segment_sum(packed: jax.Array, scales: jax.Array,
                           dense_ids: jax.Array, dim: int) -> jax.Array:
    """Fused int4 dequantize-in-the-gather segmented reduce.

    packed (R, ceil(dim/2)) uint8 + scales (R, 1) f32 from ``int4_pack``;
    dense_ids (B, max_l) with short/padded slots pointing at a row whose
    scale is zero. Returns f32 (B, dim):
    ``out[b] = sum_j unpack(packed)[ids[b, j]]``.
    """
    codes = _int4_codes(packed[dense_ids], dim).astype(jnp.float32)
    return (codes * scales[dense_ids]).sum(axis=1)


def interaction(x: jax.Array) -> jax.Array:
    """Pairwise dot products: x (B, F, D) -> (B, F, F) = X X^T per sample."""
    out = jnp.einsum("bfd,bgd->bfg", x, x,
                     preferred_element_type=jnp.float32)
    return out.astype(x.dtype)


def interaction_tril(x: jax.Array) -> jax.Array:
    """DLRM feature interaction output: lower triangle (offset -1) flattened."""
    z = interaction(x)
    f = x.shape[1]
    li, lj = jnp.tril_indices(f, k=-1)
    return z[:, li, lj]


def mlp(x: jax.Array, ws, bs, act=jax.nn.relu) -> jax.Array:
    """Reference MLP: relu between layers, last layer linear."""
    h = x
    for i, (w, b) in enumerate(zip(ws, bs)):
        h = gemm(h, w) + b
        if i < len(ws) - 1:
            h = act(h)
    return h
