"""Pure-jnp oracles for every Pallas kernel (the ground truth in tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def gemm(x: jax.Array, w: jax.Array) -> jax.Array:
    """x:(M,K) @ w:(K,N) with fp32 accumulation, result in x.dtype."""
    return jnp.dot(x, w, preferred_element_type=jnp.float32).astype(x.dtype)


def embedding_bag(table: jax.Array, indices: jax.Array) -> jax.Array:
    """Fixed-lookup SparseLengthsSum: out[b] = sum_l table[indices[b, l]].

    table: (V, D); indices: (B, L) int32 -> (B, D), fp32 accumulation.
    """
    rows = table[indices]                       # (B, L, D)
    return rows.astype(jnp.float32).sum(axis=1).astype(table.dtype)


def sparse_lengths_sum(table: jax.Array, indices: jax.Array,
                       offsets: jax.Array) -> jax.Array:
    """Ragged SparseLengthsSum (paper Fig. 2): offsets (B+1,), indices (L,)."""
    n_bags = offsets.shape[0] - 1
    segment_ids = jnp.searchsorted(offsets[1:], jnp.arange(indices.shape[0]),
                                   side="right")
    rows = table[indices].astype(jnp.float32)
    out = jax.ops.segment_sum(rows, segment_ids, num_segments=n_bags)
    return out.astype(table.dtype)


def sls_grad_table(g: jax.Array, indices: jax.Array, offsets: jax.Array,
                   n_rows: int) -> jax.Array:
    """VJP of ragged SparseLengthsSum w.r.t. the table: segment scatter-add.

    d_table[r] = sum over valid positions p with indices[p] == r of
    g[bag(p)]; padded positions (>= offsets[-1]) contribute nothing.
    """
    n = indices.shape[0]
    n_bags = offsets.shape[0] - 1
    pos = jnp.arange(n, dtype=offsets.dtype)
    seg = jnp.searchsorted(offsets[1:], pos, side="right")
    rows = jnp.take(g.astype(jnp.float32), jnp.minimum(seg, n_bags - 1),
                    axis=0)
    rows = jnp.where((pos < offsets[-1])[:, None], rows, 0.0)
    out = jax.ops.segment_sum(rows, indices, num_segments=n_rows)
    return out.astype(g.dtype)


def fused_segment_sum(table: jax.Array, dense_ids: jax.Array) -> jax.Array:
    """Fused segmented reduce over a pre-relayouted id matrix.

    dense_ids (B, max_l) holds each bag's row ids with padding/short slots
    pointing at an always-zero row (``se.ragged_dense_ids``); the result
    is one gather + one per-bag sum — the scatter-free form of
    ``sparse_lengths_sum``. Returns f32 (B, D).
    """
    return table[dense_ids].astype(jnp.float32).sum(axis=1)


def fused_cached_segment_sum(hot_rows: jax.Array, arena: jax.Array,
                             slots: jax.Array,
                             cold_ids: jax.Array) -> jax.Array:
    """One-pass hot/cold reduce: the in-kernel hit test as XLA.

    Per position, exactly one of ``hot_rows[slots]`` (miss -> zero null
    slot) and ``arena[cold_ids]`` (hit -> zero null row) is nonzero, so
    their sum is bit-for-bit the uncached row and ONE reduction covers
    both passes. slots/cold_ids are (B, max_l) dense matrices over the
    same bags. Returns f32 (B, D).
    """
    rows = hot_rows[slots].astype(jnp.float32) \
        + arena[cold_ids].astype(jnp.float32)
    return rows.sum(axis=1)


def interaction(x: jax.Array) -> jax.Array:
    """Pairwise dot products: x (B, F, D) -> (B, F, F) = X X^T per sample."""
    out = jnp.einsum("bfd,bgd->bfg", x, x,
                     preferred_element_type=jnp.float32)
    return out.astype(x.dtype)


def interaction_tril(x: jax.Array) -> jax.Array:
    """DLRM feature interaction output: lower triangle (offset -1) flattened."""
    z = interaction(x)
    f = x.shape[1]
    li, lj = jnp.tril_indices(f, k=-1)
    return z[:, li, lj]


def mlp(x: jax.Array, ws, bs, act=jax.nn.relu) -> jax.Array:
    """Reference MLP: relu between layers, last layer linear."""
    h = x
    for i, (w, b) in enumerate(zip(ws, bs)):
        h = gemm(h, w) + b
        if i < len(ws) - 1:
            h = act(h)
    return h
