"""Output-stationary blocked matmul — the Centaur *dense accelerator*.

TPU adaptation of the paper's 4x4 PE array of 32x32 FP_MATRIX_MULT blocks
(Fig. 11/12): the output-stationary dataflow survives — an fp32 accumulator
tile stays resident in VMEM while weight/input tiles stream through the MXU —
but the tile size is re-chosen for TPU hardware (128-aligned MXU tiles,
VMEM-sized working set) instead of the FPGA's 32x32 DSP granularity.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat


def _gemm_kernel(x_ref, w_ref, o_ref, acc_ref, *, n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # MXU matmul on the current (bm, bk) x (bk, bn) tile pair; partial sums
    # accumulate output-stationary in VMEM scratch (the per-PE SRAM analogue).
    acc_ref[...] += jnp.dot(x_ref[...], w_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def gemm(x: jax.Array, w: jax.Array, *, bm: int = 128, bn: int = 128,
         bk: int = 128, interpret: bool = False) -> jax.Array:
    """x:(M,K) @ w:(K,N) -> (M,N) in x.dtype with fp32 accumulation."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    bm, bn = min(bm, m), min(bn, n)
    # K is the contraction dim: a padded tail block would feed undefined
    # values into the accumulator, so snap bk to a divisor of K. (Padded
    # tails along M/N only touch discarded output rows/cols — safe.)
    bk = min(bk, k)
    while k % bk:
        bk -= 1
    grid = (pl.cdiv(m, bm), pl.cdiv(n, bn), pl.cdiv(k, bk))
    return pl.pallas_call(
        functools.partial(_gemm_kernel, n_k=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=compat.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, w)
