"""Fused segmented dispatch — ONE kernel for grouped / cached / sharded
lookups.

The flexible sparse paths (heterogeneous table groups, the hot-row cache,
the row-sharded cold pass) all used to re-walk the full interleaved index
stream once per component: T full-stream reductions for a T-table group,
two full passes for a hot/cold split. This module is the kernel half of
the fix: the stream is relayouted ONCE into a dense (n_bags, max_l) id
matrix (``se.ragged_dense_ids`` — position j of bag b, short/padded slots
pointing at an always-zero row), and each consumer walks only its own
bags' rows, accumulating every bag's reduction in a VMEM register tile.

Two kernels:

* ``fused_segment_sum`` — the segmented gather-reduce over a dense id
  matrix. Per-table base offsets are already folded into the ids (the
  BPregs add happens at relayout time), so a table group runs one of
  these per member over a (B, max_l) *slice* of the shared matrix
  instead of a full-stream reduction each.
* ``fused_cached_segment_sum`` — the same walk with the hot/cold hit
  test *inside* the kernel: each step gathers the hot slot row (miss ->
  zero null slot) and the cold arena row (hit -> zero null row) and
  accumulates their sum, so hot + cold costs ONE pass and equals the
  uncached reduction bit-for-bit (exactly one term per step is nonzero).

The custom VJP lives in ``ops``: a dense id matrix is a uniform-offset
ragged stream, so the backward IS the existing ``sls_grad_table`` fused
segment scatter-add — training through the fused path reuses the proven
gradient kernel unchanged.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat


def _fused_kernel(ids_ref, table_ref, o_ref, acc_ref, *, max_l: int):
    l = pl.program_id(2)

    @pl.when(l == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # One gathered row per grid step, row chosen by the prefetched dense
    # id; fill slots point at the always-zero null row, so the reduction
    # needs no validity mask at all.
    acc_ref[...] += table_ref[...].astype(jnp.float32)

    @pl.when(l == max_l - 1)
    def _flush():
        o_ref[...] = acc_ref[...]


@functools.partial(jax.jit, static_argnames=("interpret",))
def fused_segment_sum(table: jax.Array, dense_ids: jax.Array, *,
                      interpret: bool = False) -> jax.Array:
    """Segmented gather-reduce over a ``ragged_dense_ids`` matrix.

    table (V, D); dense_ids (B, max_l) int32 with short/padded slots
    pointing at an always-zero row. Returns f32 (B, D):
    ``out[b] = sum_j table[dense_ids[b, j]]``.
    """
    v, d = table.shape
    b, max_l = dense_ids.shape
    if max_l == 0:
        return jnp.zeros((b, d), jnp.float32)
    grid = (b, 1, max_l)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, d), lambda bb, dd, ll, ids: (ids[bb, ll], dd)),
        ],
        out_specs=pl.BlockSpec((1, d), lambda bb, dd, ll, ids: (bb, dd)),
        scratch_shapes=[pltpu.VMEM((1, d), jnp.float32)],
    )
    fn = pl.pallas_call(
        functools.partial(_fused_kernel, max_l=max_l),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, d), jnp.float32),
        compiler_params=compat.CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary", "arbitrary")),
        interpret=interpret,
    )
    return fn(dense_ids, table)


def _cached_kernel(slots_ref, cold_ref, hot_ref, arena_ref, o_ref, acc_ref,
                   *, max_l: int):
    l = pl.program_id(2)

    @pl.when(l == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # The in-kernel hit test: per step exactly one of the two gathered
    # rows is nonzero (a miss reads the hot arena's zero null slot, a hit
    # reads the cold arena's zero null row), so accumulating their sum is
    # bit-for-bit the uncached reduction — in ONE pass.
    acc_ref[...] += hot_ref[...].astype(jnp.float32) \
        + arena_ref[...].astype(jnp.float32)

    @pl.when(l == max_l - 1)
    def _flush():
        o_ref[...] = acc_ref[...]


@functools.partial(jax.jit, static_argnames=("interpret",))
def fused_cached_segment_sum(hot_rows: jax.Array, arena: jax.Array,
                             slots: jax.Array, cold_ids: jax.Array, *,
                             interpret: bool = False) -> jax.Array:
    """One-pass hot/cold segmented reduce (the in-kernel hit test).

    hot_rows (K+1, D) with slot K always zero; arena (V, D) with the null
    row always zero; slots/cold_ids (B, max_l) the dense hot-slot and
    redirected cold-row matrices of the same bags. Returns f32 (B, D)
    equal to ``fused_segment_sum(hot_rows, slots) +
    fused_segment_sum(arena, cold_ids)`` computed in a single walk.
    """
    d = arena.shape[1]
    b, max_l = slots.shape
    assert cold_ids.shape == slots.shape, (cold_ids.shape, slots.shape)
    assert hot_rows.shape[1] == d, (hot_rows.shape, arena.shape)
    if max_l == 0:
        return jnp.zeros((b, d), jnp.float32)
    grid = (b, 1, max_l)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, d),
                         lambda bb, dd, ll, sl, co: (sl[bb, ll], dd)),
            pl.BlockSpec((1, d),
                         lambda bb, dd, ll, sl, co: (co[bb, ll], dd)),
        ],
        out_specs=pl.BlockSpec((1, d),
                               lambda bb, dd, ll, sl, co: (bb, dd)),
        scratch_shapes=[pltpu.VMEM((1, d), jnp.float32)],
    )
    fn = pl.pallas_call(
        functools.partial(_cached_kernel, max_l=max_l),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, d), jnp.float32),
        compiler_params=compat.CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary", "arbitrary")),
        interpret=interpret,
    )
    return fn(slots, cold_ids, hot_rows, arena)


def _int4_kernel(ids_ref, packed_ref, scales_ref, o_ref, acc_ref, *,
                 max_l: int, dim: int):
    l = pl.program_id(2)

    @pl.when(l == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # Unpack the gathered row's nibbles in-register: biased codes (q+8,
    # 8 == zero) interleaved two per byte. A null row's scale is zero, so
    # fill slots contribute nothing — same masking-free walk as the fp
    # kernel, at an eighth of the gather bytes.
    p = packed_ref[...].astype(jnp.int32)        # (1, P)
    lo = (p & 0xF) - 8
    hi = (p >> 4) - 8
    codes = jnp.stack([lo, hi], axis=-1).reshape(1, 2 * p.shape[-1])
    acc_ref[...] += codes[:, :dim].astype(jnp.float32) * scales_ref[0, 0]

    @pl.when(l == max_l - 1)
    def _flush():
        o_ref[...] = acc_ref[...]


@functools.partial(jax.jit, static_argnames=("dim", "interpret"))
def fused_int4_segment_sum(packed: jax.Array, scales: jax.Array,
                           dense_ids: jax.Array, *, dim: int,
                           interpret: bool = False) -> jax.Array:
    """Fused int4 dequantize-in-the-gather segmented reduce.

    packed (V, ceil(dim/2)) uint8 nibble pairs + scales (V, 1) f32 from
    ``ref.int4_pack``; dense_ids (B, max_l) with short/padded slots
    pointing at a zero-scale row. Returns f32 (B, dim):
    ``out[b] = sum_j unpack(packed)[dense_ids[b, j]]``.
    """
    v, p = packed.shape
    assert scales.shape == (v, 1), (scales.shape, packed.shape)
    assert p * 2 >= dim > (p - 1) * 2, (p, dim)
    b, max_l = dense_ids.shape
    if max_l == 0:
        return jnp.zeros((b, dim), jnp.float32)
    grid = (b, 1, max_l)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, p), lambda bb, dd, ll, ids: (ids[bb, ll], dd)),
            pl.BlockSpec((1, 1), lambda bb, dd, ll, ids: (ids[bb, ll], dd)),
        ],
        out_specs=pl.BlockSpec((1, dim), lambda bb, dd, ll, ids: (bb, dd)),
        scratch_shapes=[pltpu.VMEM((1, dim), jnp.float32)],
    )
    fn = pl.pallas_call(
        functools.partial(_int4_kernel, max_l=max_l, dim=dim),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, dim), jnp.float32),
        compiler_params=compat.CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary", "arbitrary")),
        interpret=interpret,
    )
    return fn(dense_ids, packed, scales)
