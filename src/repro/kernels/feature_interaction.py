"""Batched pairwise-dot feature interaction — paper Fig. 3 / Fig. 11.

Computes Z = X X^T per sample on the MXU (the batched-GEMM the paper's
feature-interaction unit runs on four FP_MATRIX_MULT PEs). The
lower-triangle extraction is done outside the kernel in ops.py (cheap,
bandwidth-trivial); the kernel owns the compute-heavy GEMM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat


def _interact_kernel(x_ref, o_ref):
    x = x_ref[...]
    o_ref[...] = jax.lax.dot_general(
        x, x,
        dimension_numbers=(((2,), (2,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bb", "interpret"))
def interaction(x: jax.Array, *, bb: int = 64,
                interpret: bool = False) -> jax.Array:
    """x: (B, F, D) -> (B, F, F) pairwise dots per sample."""
    b, f, d = x.shape
    bb = min(bb, b)
    grid = (pl.cdiv(b, bb),)
    return pl.pallas_call(
        _interact_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((bb, f, d), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((bb, f, f), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, f, f), x.dtype),
        compiler_params=compat.CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(x)
