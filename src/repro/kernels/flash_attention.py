"""Flash attention (fwd) — the memory-term fix for the attention baseline.

The dry-run showed the pure-JAX chunked attention materializes O(S^2·H) of
f32 score traffic through HBM (5.7 TB/device/step on smollm train_4k —
dominant roofline term). This kernel keeps the online-softmax state (acc,
m, l) resident in VMEM across kv blocks, so HBM traffic drops to the
Q/K/V/O streams: O(S·d) per pass — the classic flash-attention bound,
expressed TPU-natively (MXU-aligned q/kv tiles, fp32 VMEM accumulators,
grid = (batch*heads, q blocks, kv blocks) with the kv dim 'arbitrary' so
the accumulator tile is revisited in place).

Causal/windowed masks are applied in-kernel from program ids; fully-masked
kv blocks still issue (static grid) — the §Perf log covers the skip
optimization separately. Backward runs through the XLA fallback (recompute);
a fused bwd kernel is future work.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  scale: float, causal: bool, window, bq: int, bk: int,
                  n_k: int):
    kblk = pl.program_id(2)

    @pl.when(kblk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0]                                   # (bq, d)
    k = k_ref[0]                                   # (bk, d)
    v = v_ref[0]
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale   # (bq, bk)

    qpos = (pl.program_id(1) * bq
            + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0))
    kpos = kblk * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                            # (bq, 1)
    m_new = jnp.maximum(m_prev, s.max(-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(kblk == n_k - 1)
    def _done():
        o_ref[0] = (acc_ref[...]
                    / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "bq", "bk",
                                             "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window=None, bq: int = 512,
                    bk: int = 512, interpret: bool = False) -> jax.Array:
    """q, k, v: (BH, S, d) — one row per (batch x head); GQA callers repeat
    or tile kv heads in the wrapper. Returns (BH, S, d) in q.dtype."""
    bh, s, d = q.shape
    bq = min(bq, s)
    while s % bq:
        bq -= 1
    bk = min(bk, s)
    while s % bk:
        bk -= 1
    grid = (bh, s // bq, s // bk)
    scale = d ** -0.5
    return pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale, causal=causal,
                          window=window, bq=bq, bk=bk, n_k=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, kk: (b, i, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, kk: (b, kk, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, kk: (b, kk, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i, kk: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, d), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        compiler_params=compat.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)


def flash_attention_gqa(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True, window=None,
                        interpret: bool = False) -> jax.Array:
    """GQA wrapper: q (B,S,H,hd), k/v (B,S,KH,hd) -> (B,S,H,hd)."""
    b, s, h, hd = q.shape
    kh = k.shape[2]
    g = h // kh
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, s, hd)
    kf = jnp.repeat(k.transpose(0, 2, 1, 3), g, axis=1).reshape(b * h, s, hd)
    vf = jnp.repeat(v.transpose(0, 2, 1, 3), g, axis=1).reshape(b * h, s, hd)
    out = flash_attention(qf, kf, vf, causal=causal, window=window,
                          interpret=interpret)
    return out.reshape(b, h, s, hd).transpose(0, 2, 1, 3)
