"""Deterministic fleet scenarios for the versioned broadcast protocol.

``repro.fleet`` wires the previously dormant fault-tolerance substrates
(``repro.distributed.fault_tolerance``, ``repro.checkpoint``) into the
live serving + online-training loop:

* ``FaultPlan`` / ``ChaosChannel`` — seeded drop/duplicate/delay/reorder
  of ``VersionedSource`` broadcast blobs between
  ``OnlineGroupTrainer.publish_source`` and replica
  ``RecEngine.update_source``. No wall-clock randomness: every scenario
  replays bit-for-bit from its recorded seed.
* ``Replica`` / ``FleetRunner`` — one trainer, N replicas, two DLRM
  variants A/B-routed over one shared ``TableGroupSource``, per-model
  per-version hit-rate attribution through each engine's event log, and
  crash/recovery scenarios (replica restart from ``restore_source``,
  trainer resume via ``ResilientTrainer``) asserted on hit-rate AND
  bit-exactness recovery within K version bumps with zero recompiles.
"""
from repro.fleet.chaos import CLEAN, ChaosChannel, FaultPlan
from repro.fleet.runner import FleetRunner, Replica

__all__ = ["CLEAN", "ChaosChannel", "FaultPlan", "FleetRunner",
           "Replica"]
