"""Fleet runner: one trainer, N chaos-fed replicas, A/B model variants.

The topology the Centaur broadcast protocol was built for, finally run
end to end:

* ONE ``OnlineGroupTrainer`` owns the shared sparse state (a
  heterogeneous ``TableGroupSource``) and trains variant A's dense head
  alongside it;
* TWO DLRM variants (A = the trained head, B = a frozen candidate head)
  serve over that one shared group — MP-Rec's co-located-models sharing.
  Each variant gets its own ``RecEngine`` per replica with its own
  telemetry, so per-version hit-rate attribution
  (``telemetry.events.hit_rate_by_version()``) is per-model by
  construction;
* every broadcast is a ``VersionedSource`` blob carrying the dense head
  (``include_head=True``) — a replica adopts EVERYTHING it serves from
  the blob, no in-process parameter sharing — pushed through one seeded
  ``ChaosChannel`` per replica (drop/duplicate/delay/reorder);
* crash scenarios ride the dormant substrates: a replica restarts from
  ``CheckpointManager.restore_source`` (``replica_restore`` event), the
  trainer crashes and resumes via ``ResilientTrainer`` with data-skip
  determinism (``trainer_resume`` event).

Recovery is asserted on *exactness*, not liveness: after ≤ K clean
version bumps every replica's serving output for a fixed probe batch is
bit-for-bit equal to a trainer-synced reference engine's, with zero new
compile-cache entries on the recovery path (treedef-stable swaps).
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional, Tuple

import jax
import numpy as np

from repro import obs
from repro.configs.base import DLRMConfig
from repro.configs.dlrm import DLRM_HET_SMOKE
from repro.core import dlrm
from repro.core import embedding_source as es
from repro.distributed.fault_tolerance import ResilientTrainer
from repro.fleet.chaos import CLEAN, ChaosChannel, FaultPlan
from repro.serving.rec_engine import RecEngine, requests_from_ragged_batch
from repro.training.online import (OnlineGroupTrainer, _dense_head,
                                   make_drifting_zipf)

__all__ = ["FleetRunner", "Replica"]

MODELS = ("a", "b")        # A = trained head, B = frozen candidate head


def _serve_batch(engine: RecEngine, cfg: DLRMConfig,
                 batch: Dict, rid0: int = 0) -> List[float]:
    """Run one ragged batch through an engine's dispatch/settle path and
    return the served probabilities (fresh request objects every call —
    requests are mutated in place by settle)."""
    reqs = requests_from_ragged_batch(batch, cfg.n_tables, rid0=rid0)
    ib = engine.dispatch(reqs)
    engine.settle(ib)
    return [r.prob for r in reqs]


class Replica:
    """One serving host: a per-variant ``RecEngine`` pair fed from one
    chaos channel. Delivery is version-gated per engine — stale
    artifacts (reordered past a newer applied version) go through the
    engine's raising ``update_source`` path so the rejection is counted
    and evented on BOTH sides: ``stale_injected`` here, the
    ``stale_rejected`` event + ``rec_stale_rejected_total`` counter in
    the engine. The chaos property tests assert the two agree."""

    def __init__(self, name: str, cfg: DLRMConfig,
                 bootstrap: es.VersionedSource, channel: ChaosChannel, *,
                 max_l: int, batch_size: int, heads: Dict[str, Dict],
                 params_seed: int = 0, mesh=None, shards: int = 1):
        self.name = name
        self.cfg = cfg
        self.channel = channel
        self.max_l = max_l
        self.mesh = mesh
        self.engines: Dict[str, RecEngine] = {}
        # variant A adopts the broadcast head; every other variant keeps
        # its frozen candidate head (the A/B story: only A retrains)
        self.adopt_head = {m: (m == "a") for m in heads}
        self.stale_injected = 0
        self.applied = 0
        for i, (model, head) in enumerate(sorted(heads.items())):
            # a cold remote host: params start from a LOCAL init (never
            # the trainer's arrays) and the dense head comes from the
            # bootstrap artifact / the frozen candidate — the only
            # sparse state ever served is the broadcast source itself.
            # ``shards`` is the publisher's arena row-padding layout: the
            # placeholder arena must match the broadcast leaf shapes or a
            # head adoption's arena rebind would break the fixed layout
            base = dlrm.init(
                jax.random.PRNGKey(params_seed * 31 + i + 11), cfg, shards)
            params = {**base, **head}
            eng = RecEngine(cfg, params, source=bootstrap.source,
                            max_l=max_l, max_batch=batch_size,
                            buckets=(batch_size,), mesh=mesh,
                            telemetry=obs.Telemetry())
            eng.update_source(bootstrap.source, version=bootstrap.version)
            eng.warmup()
            self.engines[model] = eng
        # the zero-recompile baseline: compile-cache size after warmup;
        # every subsequent swap/serve must leave it unchanged
        self.compile_baseline = {m: self._cache_size(e)
                                 for m, e in self.engines.items()}

    @staticmethod
    def _cache_size(engine: RecEngine) -> Optional[int]:
        serve = engine._serve
        return (serve._cache_size()
                if hasattr(serve, "_cache_size") else None)

    def recompiles(self) -> Dict[str, Optional[int]]:
        """New compile-cache entries per model since the warmup baseline
        (must be 0 on the recovery path)."""
        out = {}
        for m, e in self.engines.items():
            now, base = self._cache_size(e), self.compile_baseline[m]
            out[m] = None if now is None or base is None else now - base
        return out

    def deliver(self, version: int, blob: bytes) -> str:
        """Apply one artifact to every variant engine; returns the
        outcome ('applied' | 'republish' | 'stale')."""
        vs = es.VersionedSource.deserialize(blob)
        outcome = "applied"
        for model, eng in self.engines.items():
            if vs.version < eng.source_version:
                self.stale_injected += 1
                eng.telemetry.emit(
                    "broadcast_reordered", version=vs.version,
                    served_version=eng.source_version,
                    model=model, replica=self.name)
                try:
                    eng.update_source(vs.source, version=vs.version)
                except ValueError:
                    pass        # counted by the engine's stale gate
                outcome = "stale"
                continue
            if vs.head is not None and self.adopt_head.get(model):
                # head first, then source: the params setter rebinds the
                # OLD source's arena leaves (values unchanged), then the
                # versioned swap replaces the whole source — the pair
                # lands as one version adoption, never torn
                eng.params = {**eng.params, **vs.head}
            if vs.version == eng.source_version:
                outcome = "republish"
            else:
                self.applied += 1
            eng.update_source(vs.source, version=vs.version)
        return outcome

    def pump(self) -> Dict[str, int]:
        """Deliver everything the channel has made deliverable."""
        stats = {"applied": 0, "republish": 0, "stale": 0}
        for version, blob in self.channel.poll():
            stats[self.deliver(version, blob)] += 1
        return stats

    def stale_rejections(self) -> int:
        """Engine-side count of stale-swap rejections across variants
        (from the event log — the independent witness the chaos suite
        compares against ``stale_injected``)."""
        return sum(len(e.telemetry.events.query("stale_rejected"))
                   for e in self.engines.values())

    def versions(self) -> Dict[str, int]:
        return {m: e.source_version for m, e in self.engines.items()}

    def hit_rate_by_version(self, model: str) -> Dict[int, Optional[float]]:
        """Per-version hit-rate attribution for one model variant."""
        return self.engines[model].telemetry.events.hit_rate_by_version()


class FleetRunner:
    """Hosts the trainer, the reference engines, and N chaos-fed
    replicas; drives rounds of (train -> rebuild -> broadcast -> pump ->
    serve) and the crash/recovery scenarios."""

    def __init__(self, cfg: Optional[DLRMConfig] = None, *,
                 n_replicas: int = 2, plan: FaultPlan = CLEAN,
                 seed: int = 0, cache_k: int = 64, refresh_every: int = 4,
                 batch_size: int = 8, max_l: int = 4, mean_l: int = 2,
                 drift_per_batch: int = 64, alpha: float = 1.05,
                 ckpt_dir=None, keep_n: int = 3):
        from repro.checkpoint import CheckpointManager
        cfg = cfg if cfg is not None else DLRM_HET_SMOKE
        assert cfg.heterogeneous, \
            "the fleet topology shares one TableGroupSource (MP-Rec)"
        self.cfg = cfg
        self.seed = seed
        self.plan = plan
        self.max_l = max_l
        self.batch_size = batch_size
        self.trainer = OnlineGroupTrainer(
            cfg, dlrm.init(jax.random.PRNGKey(seed), cfg), max_l=max_l,
            plans=dlrm.table_plans(cfg, cache_k=cache_k),
            refresh_every=refresh_every)
        self.ckpt = (CheckpointManager(ckpt_dir, keep_n=keep_n)
                     if ckpt_dir is not None else None)
        # variant B: a frozen candidate dense head, derived from a fixed
        # key so every B engine (replicas + reference) serves the same
        # model — the A/B pair shares ONLY the sparse TableGroupSource
        self.head_b = _dense_head(
            dlrm.init(jax.random.PRNGKey(seed + 7), cfg))
        self._gen = make_drifting_zipf(
            cfg, batch_size=batch_size, mean_l=mean_l, max_l=max_l,
            drift_per_batch=drift_per_batch, alpha=alpha, seed=seed)
        self._batches: List[Dict] = []
        self.probe_batch = self.batch_fn(0)
        self.next_step = 0
        self.rounds = 0
        self._restarts = [0] * n_replicas

        # one clean bootstrap bump so every engine starts aligned on v1
        self._train_one_refresh()
        self._bootstrap = self.artifact()
        if self.ckpt is not None:
            self.ckpt.save_source(self.trainer.steps, self._bootstrap)
        self.ref = self._make_reference(self._bootstrap)
        self.replicas = [self._make_replica(i, self._bootstrap)
                         for i in range(n_replicas)]

    # -- data (step-seeded: data-skip determinism for resumes) -------------

    def batch_fn(self, step: int) -> Dict:
        """The batch consumed at optimizer step ``step`` — memoized from
        one seeded generator, so a resumed trainer replays exactly the
        batches it would have consumed."""
        while len(self._batches) <= step:
            self._batches.append(next(self._gen))
        return self._batches[step]

    # -- trainer side ------------------------------------------------------

    def _train_one_refresh(self) -> None:
        """Exactly refresh_every steps = exactly one version bump."""
        for _ in range(self.trainer.refresh_every):
            self.trainer.train_step(self.batch_fn(self.next_step))
            self.next_step += 1

    def artifact(self) -> es.VersionedSource:
        """The current broadcast artifact: full serving source + the
        trained dense head, under the trainer's version."""
        return es.VersionedSource(source=self.trainer.serving_source(),
                                  version=self.trainer.version,
                                  head=_dense_head(self.trainer.params))

    def _make_reference(self, vs: es.VersionedSource
                        ) -> Dict[str, RecEngine]:
        """Trainer-side reference engines, one per variant, always
        synced directly (no chaos) — the bit-exactness oracle."""
        ref = {}
        for i, (model, head) in enumerate(
                sorted({"a": _dense_head(self.trainer.params),
                        "b": self.head_b}.items())):
            base = dlrm.init(jax.random.PRNGKey(self.seed * 17 + 5 + i),
                             self.cfg)
            eng = RecEngine(self.cfg, {**base, **head},
                            source=vs.source, max_l=self.max_l,
                            max_batch=self.batch_size,
                            buckets=(self.batch_size,),
                            telemetry=obs.Telemetry())
            eng.update_source(vs.source, version=vs.version)
            eng.warmup()
            ref[model] = eng
        return ref

    def _sync_reference(self) -> None:
        vs = self.artifact()
        for model, eng in self.ref.items():
            if vs.version <= eng.source_version:
                continue
            if model == "a":
                eng.params = {**eng.params, **vs.head}
            eng.update_source(vs.source, version=vs.version)

    def _make_replica(self, i: int,
                      bootstrap: es.VersionedSource) -> Replica:
        chan_seed = self.plan.seed + 101 * (i + 1) \
            + 100_000 * self._restarts[i]
        channel = ChaosChannel(self.plan.with_seed(chan_seed),
                               name=f"replica{i}")
        return Replica(
            f"replica{i}", self.cfg, bootstrap, channel,
            max_l=self.max_l, batch_size=self.batch_size,
            heads={"a": dict(bootstrap.head), "b": self.head_b},
            params_seed=self.seed * 13 + i)

    # -- the round loop ----------------------------------------------------

    def round(self, *, chaos: bool = True, serve: bool = True) -> Dict:
        """One fleet round: train one refresh interval (one version
        bump), broadcast through each replica's channel (or perfectly,
        when ``chaos=False``), pump deliveries, serve the round's live
        traffic on every engine (reference + replicas) so hit-rate
        attribution accrues per version and per model."""
        self._train_one_refresh()
        vs = self.artifact()
        blob = vs.serialize()
        if self.ckpt is not None:
            self.ckpt.save_source(self.trainer.steps, vs)
        self._sync_reference()
        stats = {"version": self.trainer.version, "replicas": []}
        for rep in self.replicas:
            if chaos:
                rep.channel.send(blob, self.trainer.version)
                s = rep.pump()
            else:
                s = {"applied": 0, "republish": 0, "stale": 0}
                s[rep.deliver(self.trainer.version, blob)] += 1
            stats["replicas"].append(s)
        if serve:
            self.serve_round()
        self.rounds += 1
        return stats

    def serve_round(self) -> None:
        """Serve the freshest drift window through every engine — the
        traffic that makes per-version hit rates meaningful (a replica
        stuck on an old version misses the drifted hot set)."""
        batch = self.batch_fn(self.next_step - 1)
        for eng in self.ref.values():
            _serve_batch(eng, self.cfg, batch)
        for rep in self.replicas:
            for eng in rep.engines.values():
                _serve_batch(eng, self.cfg, batch)

    # -- exactness + recovery ----------------------------------------------

    def exactness(self) -> Dict[str, List[bool]]:
        """Per-model, per-replica: is the replica's serving output for
        the fixed probe batch bit-for-bit equal to the trainer-synced
        reference engine's?"""
        out: Dict[str, List[bool]] = {}
        for model in MODELS:
            want = _serve_batch(self.ref[model], self.cfg,
                                self.probe_batch)
            out[model] = [
                _serve_batch(rep.engines[model], self.cfg,
                             self.probe_batch) == want
                for rep in self.replicas]
        return out

    def all_exact(self) -> bool:
        return all(all(v) for v in self.exactness().values())

    def recover(self, k: int = 3) -> Dict:
        """Clean recovery: drain every channel's in-flight artifacts,
        then run perfect-delivery rounds until all replicas serve
        bit-exact — within ``k`` version bumps. Returns the bump count,
        the final exactness map, and per-replica recompile counts (the
        zero-recompile claim for the whole recovery path)."""
        for rep in self.replicas:
            for v, blob in rep.channel.flush():
                rep.deliver(v, blob)
        bumps = 0
        while not self.all_exact() and bumps < k:
            self.round(chaos=False)
            bumps += 1
        return {"bumps": bumps, "exact": self.exactness(),
                "recompiles": [rep.recompiles() for rep in self.replicas]}

    # -- crash scenarios ---------------------------------------------------

    def crash_replica(self, i: int) -> Replica:
        """Kill replica ``i`` and cold-restart it from the latest
        checkpointed source artifact (``restore_source``) — its channel
        state and engines are lost, its replacement bootstraps from disk
        with a fresh (recorded) chaos seed."""
        assert self.ckpt is not None, "replica restart needs a ckpt_dir"
        vs, manifest = self.ckpt.restore_source()
        self._restarts[i] += 1
        rep = self._make_replica(i, vs)
        for model, eng in rep.engines.items():
            eng.telemetry.emit("replica_restore", version=vs.version,
                               step=manifest["step"], model=model,
                               replica=rep.name)
        self.replicas[i] = rep
        return rep

    def run_trainer_with_crash(self, *, extra_steps: int,
                               fail_after: int, ckpt_every: int = 4
                               ) -> Dict:
        """Advance the trainer ``extra_steps`` optimizer steps under
        ``ResilientTrainer``, crashing once ``fail_after`` steps in and
        resuming from the latest checkpoint with step-seeded batches
        (data-skip determinism). The trainer's version stays monotone
        through the crash, so replicas never see a rollback; emits
        ``trainer_resume`` on the restore."""
        t = self.trainer
        start = self.next_step
        # a real resume starts from disk: seed the checkpoint chain with
        # the current state so ResilientTrainer restores to *now*, not
        # to step 0
        assert self.ckpt is not None, "trainer resume needs a ckpt_dir"
        self.ckpt.save(start - 1, (t.params, t.opt_state))

        def step_fn(params, opt_state, batch):
            t.params, t.opt_state = params, opt_state
            loss = t.train_step(batch)
            return t.params, t.opt_state, loss

        def on_resume(step: int) -> None:
            t.telemetry.emit("trainer_resume", version=t.version,
                             step=step, restarts=rt.restarts)

        rt = ResilientTrainer(step_fn, self.ckpt, ckpt_every=ckpt_every,
                              on_resume=on_resume)
        state = (t.params, t.opt_state)
        t0 = time.perf_counter()
        state, _ = rt.run(state, self.batch_fn, start + extra_steps,
                          fail_at=start + fail_after)
        t.params, t.opt_state = state
        self.next_step = start + extra_steps
        return {"restarts": rt.restarts,
                "resume_events": len(t.telemetry.events.query(
                    "trainer_resume")),
                "wall_s": time.perf_counter() - t0,
                "version": t.version}
