"""Deterministic fault injection for the versioned broadcast path.

The trainer publishes ``VersionedSource`` blobs; a fleet transport can
drop them, deliver them twice, or deliver them late (after a newer
version already landed — reordering). ``ChaosChannel`` models exactly
that, between ``publish_source`` and a replica's ``update_source``, with
every decision drawn from one seeded generator:

* no wall-clock randomness anywhere — a ``FaultPlan`` seed fully
  determines the schedule, so any scenario replays bit-for-bit from its
  recorded seed (``ChaosChannel.schedule`` is the decision transcript);
* "time" is the send index, not seconds: a delayed artifact becomes
  deliverable ``d`` *sends* later, which is what makes delay produce
  genuine reordering (the newer versions published in between are
  applied first, so the late artifact arrives stale and the engine's
  version gate rejects it — countable on both sides of the channel).

The channel is transport only: it never touches an engine. Delivery
(deserialize + version-gated adoption, per model variant) lives in
``repro.fleet.runner.Replica``.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro import obs

__all__ = ["CLEAN", "ChaosChannel", "FaultPlan"]


@dataclass(frozen=True)
class FaultPlan:
    """Seeded fault schedule for one broadcast channel.

    Probabilities are per-send; ``max_delay`` bounds how many future
    sends a delayed artifact waits for. ``CLEAN`` (all zeros) is the
    perfect-transport plan the recovery phases use.
    """
    seed: int = 0
    drop: float = 0.0        # P(artifact lost)
    dup: float = 0.0         # P(artifact delivered twice)
    delay: float = 0.0       # P(held for 1..max_delay future sends)
    max_delay: int = 2

    def with_seed(self, seed: int) -> "FaultPlan":
        """Same fault mix, different (recorded) schedule — per-replica
        channels derive their seeds this way so replicas see independent
        but individually replayable schedules."""
        return dataclasses.replace(self, seed=seed)


CLEAN = FaultPlan()


class ChaosChannel:
    """A lossy, duplicating, reordering broadcast transport.

    ``send(blob, version)`` draws this send's fate (the same three
    uniforms plus one delay draw are consumed on EVERY send, so the
    schedule depends only on ``plan.seed`` and the send count — never on
    which fates were taken); ``poll()`` returns the artifacts that have
    become deliverable, oldest first. ``schedule`` records one dict per
    send: the full transcript needed to replay or audit a scenario.
    """

    def __init__(self, plan: FaultPlan, *,
                 telemetry: Optional[obs.Telemetry] = None,
                 name: str = "chan0"):
        self.plan = plan
        self.name = name
        self.telemetry = telemetry if telemetry is not None \
            else obs.Telemetry()
        self._rng = np.random.default_rng(plan.seed)
        self._queue: List[Tuple[int, int, int, bytes]] = []
        self._seq = 0
        self.sends = 0
        self.dropped = 0
        self.duplicated = 0
        self.delayed = 0
        self.schedule: List[Dict] = []

    def send(self, blob: bytes, version: int) -> Dict:
        """Trainer-side publish into the channel; returns this send's
        recorded fate."""
        u_drop, u_dup, u_delay = self._rng.uniform(size=3)
        d = int(self._rng.integers(1, max(self.plan.max_delay, 1) + 1))
        self.sends += 1
        fate = {"send": self.sends, "version": int(version),
                "dropped": bool(u_drop < self.plan.drop),
                "duplicated": bool(u_dup < self.plan.dup),
                "delay": d if u_delay < self.plan.delay else 0}
        self.schedule.append(fate)
        if fate["dropped"]:
            self.dropped += 1
            self.telemetry.emit("broadcast_dropped", version=version,
                                channel=self.name, send=self.sends)
            return fate
        due = self.sends + fate["delay"]
        if fate["delay"]:
            self.delayed += 1
        copies = 2 if fate["duplicated"] else 1
        if fate["duplicated"]:
            self.duplicated += 1
        for _ in range(copies):
            self._queue.append((due, self._seq, int(version), blob))
            self._seq += 1
        return fate

    def poll(self) -> List[Tuple[int, bytes]]:
        """Artifacts deliverable now (due at or before the current send
        index), in (due, send) order — a delayed artifact surfaces after
        the newer versions published while it was in flight."""
        ready = sorted(e for e in self._queue if e[0] <= self.sends)
        self._queue = [e for e in self._queue if e[0] > self.sends]
        return [(v, blob) for _, _, v, blob in ready]

    def flush(self) -> List[Tuple[int, bytes]]:
        """Everything still in flight, delays waived (end-of-scenario
        drain; dropped artifacts stay dropped)."""
        ready = sorted(self._queue)
        self._queue = []
        return [(v, blob) for _, _, v, blob in ready]

    @property
    def in_flight(self) -> int:
        return len(self._queue)
