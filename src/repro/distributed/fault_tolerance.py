"""Fault tolerance for long multi-pod runs.

On a synchronous SPMD fleet the realistic levers are:

* **checkpoint/restart** — periodic async checkpoints + resume-from-latest
  (``ResilientTrainer``); a dead node means the job scheduler re-provisions
  and every worker restarts from step N (tested by killing a run mid-stream);
* **straggler detection** — per-step wall-time EWMA; a step slower than
  ``threshold x`` the running median flags the slowest host for replacement
  (in this container we *simulate* the replacement callback);
* **data-skip determinism** — the data generator is seeded by step number, so
  a restarted run consumes exactly the batches it would have.
"""
from __future__ import annotations

import time
from collections import deque
from typing import Callable, Dict, List, Optional

from repro.checkpoint import CheckpointManager


class SimulatedFailure(Exception):
    """Raised by tests/examples to model a node loss mid-run."""


class StragglerMonitor:
    def __init__(self, threshold: float = 2.0, window: int = 32,
                 on_straggler: Optional[Callable[[int, float], None]] = None):
        self.threshold = threshold
        self.durations: deque = deque(maxlen=window)
        self.on_straggler = on_straggler or (lambda step, dt: None)
        self.events: List[Dict] = []

    def record(self, step: int, duration_s: float) -> bool:
        """Returns True if this step was flagged as a straggler."""
        flagged = False
        if len(self.durations) >= 8:
            med = sorted(self.durations)[len(self.durations) // 2]
            if duration_s > self.threshold * med:
                flagged = True
                self.events.append({"step": step, "duration": duration_s,
                                    "median": med})
                self.on_straggler(step, duration_s)
        if not flagged:
            # flagged outliers stay out of the window: a straggler that
            # polluted the median would raise the bar enough to mask an
            # immediately following straggler of the same magnitude
            self.durations.append(duration_s)
        return flagged


class ResilientTrainer:
    """Checkpoint/restart wrapper around a jitted train step.

    run() executes steps [resume..total); any exception triggers a restore
    from the latest checkpoint and continuation, up to max_restarts.
    """

    def __init__(self, step_fn, ckpt: CheckpointManager,
                 ckpt_every: int = 50, max_restarts: int = 3,
                 straggler: Optional[StragglerMonitor] = None,
                 on_resume: Optional[Callable[[int], None]] = None):
        self.step_fn = step_fn
        self.ckpt = ckpt
        self.ckpt_every = ckpt_every
        self.max_restarts = max_restarts
        self.straggler = straggler or StragglerMonitor()
        self.on_resume = on_resume
        self.restarts = 0

    def run(self, state, batch_fn, total_steps: int,
            fail_at: Optional[int] = None):
        """state: (params, opt_state); batch_fn(step) -> batch.

        fail_at: step at which to raise SimulatedFailure once (tests).
        """
        start = 0
        latest = self.ckpt.latest_step()
        if latest is not None:
            state, _ = self.ckpt.restore(state, step=latest)
            start = latest + 1

        step = start
        metrics = None
        while step < total_steps:
            try:
                t0 = time.time()
                if fail_at is not None and step == fail_at \
                        and self.restarts == 0:
                    raise SimulatedFailure(f"node lost at step {step}")
                params, opt_state, metrics = self.step_fn(
                    state[0], state[1], batch_fn(step))
                state = (params, opt_state)
                self.straggler.record(step, time.time() - t0)
                if (step + 1) % self.ckpt_every == 0:
                    self.ckpt.save(step, state)
                step += 1
            except SimulatedFailure:
                self.restarts += 1
                if self.restarts > self.max_restarts:
                    raise
                latest = self.ckpt.latest_step()
                if latest is None:
                    step = 0
                    if self.on_resume is not None:
                        self.on_resume(step)
                    continue
                state, _ = self.ckpt.restore(state, step=latest)
                step = latest + 1
                if self.on_resume is not None:
                    self.on_resume(step)
        self.ckpt.wait()
        return state, metrics
