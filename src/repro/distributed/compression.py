"""Gradient compression for the data-parallel sync path.

Two mechanisms, composable:

* **bf16 wire sync** — gradients are cast to bf16 before the DP
  all-reduce (2x ICI traffic cut). Under pjit the all-reduce is implicit, so
  the cast is applied to the loss's gradient outputs inside the step; XLA
  then reduces in bf16.
* **int8 error-feedback quantization** — classic 1-bit-Adam-style residual
  carry: q_t = Q(g_t + e_t), e_{t+1} = (g_t + e_t) - q_t. The quantized
  tensor (int8 + per-row f32 scale) is what a custom int8 collective would
  move (4x cut); we model the *numerics* end-to-end (the error-feedback
  state is part of the training state) and document the wire saving in the
  roofline's collective term.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Row-wise symmetric int8. Returns (q int8, scale f32)."""
    x32 = x.astype(jnp.float32)
    if x.ndim == 0:
        scale = jnp.maximum(jnp.abs(x32), 1e-12) / 127.0
        return jnp.round(x32 / scale).astype(jnp.int8), scale
    amax = jnp.max(jnp.abs(x32), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x32 / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array,
                    dtype=jnp.float32) -> jax.Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def init_error_feedback(params):
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_grads(grads, error_state):
    """Returns (decompressed grads as seen post-wire, new error state)."""
    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        q, s = quantize_int8(corrected)
        deq = dequantize_int8(q, s)
        return deq.astype(g.dtype), corrected - deq
    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = treedef.flatten_up_to(error_state)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (treedef.unflatten([o[0] for o in out]),
            treedef.unflatten([o[1] for o in out]))


def bf16_cast_grads(grads):
    return jax.tree_util.tree_map(
        lambda g: g.astype(jnp.bfloat16), grads)


def wire_bytes(params, scheme: str) -> int:
    """Collective bytes per DP sync under each scheme (for the roofline)."""
    import numpy as np
    n = sum(int(np.prod(p.shape)) for p in jax.tree_util.tree_leaves(params))
    return {"f32": 4 * n, "bf16": 2 * n, "int8": n}[scheme]
