"""Logical-axis sharding: one vocabulary, every mesh.

Models annotate activations/params with *logical* axes; this module maps them
to mesh axes at trace time. The mapping:

    'batch'  -> every mesh axis except 'model'  (DP: ('pod','data') or ('data',))
    'model'  -> 'model'                          (TP/EP/vocab rows)
    'fsdp'   -> 'data'                           (param sharding, ZeRO-3 style)
    'expert' -> 'model'                          (MoE expert dim)
    None     -> replicated

Under no active mesh (smoke tests, laptop runs) every helper is an identity,
so the same model code runs on one CPU device and on a 512-chip mesh.
"""
from __future__ import annotations

import contextlib
from typing import Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

_ACTIVE_MESH: Optional[Mesh] = None


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh]):
    """Activate a mesh for `constrain` calls during tracing."""
    global _ACTIVE_MESH
    prev = _ACTIVE_MESH
    _ACTIVE_MESH = mesh
    try:
        yield mesh
    finally:
        _ACTIVE_MESH = prev


def active_mesh() -> Optional[Mesh]:
    return _ACTIVE_MESH


def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a != "model")


def resolve(mesh: Mesh, logical: Sequence[Optional[str]]) -> P:
    """Map a tuple of logical axis names to a PartitionSpec."""
    out = []
    for ax in logical:
        if ax is None:
            out.append(None)
        elif ax == "batch":
            ba = batch_axes(mesh)
            out.append(ba if len(ba) > 1 else (ba[0] if ba else None))
        elif ax in ("model", "expert", "vocab", "heads", "ff"):
            out.append("model" if "model" in mesh.axis_names else None)
        elif ax == "fsdp":
            # ZeRO-3 shards over every DP axis (pod AND data on the
            # multi-pod mesh), else params replicate across pods
            ba = batch_axes(mesh)
            out.append(ba if len(ba) > 1 else (ba[0] if ba else None))
        else:
            raise ValueError(f"unknown logical axis {ax!r}")
    return P(*out)


def constrain(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """with_sharding_constraint against the active mesh (identity if none)."""
    mesh = _ACTIVE_MESH
    if mesh is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, resolve(mesh, logical)))


def sharding_for(mesh: Optional[Mesh],
                 logical: Sequence[Optional[str]]):
    if mesh is None:
        return None
    return NamedSharding(mesh, resolve(mesh, logical))


def place_row_sharded(x: jax.Array, mesh: Optional[Mesh],
                      axis: str = "model") -> jax.Array:
    """Materialize a (rows, D) array row-sharded over `axis` (the embedding
    arena's resident layout for the sharded sparse paths). Identity when no
    mesh / no axis — the same call site works on a laptop and a pod. The
    row count must divide the axis (ArenaSpec.padded_rows guarantees it).
    """
    if mesh is None or axis not in mesh.axis_names:
        return x
    assert x.shape[0] % mesh.shape[axis] == 0, \
        (x.shape, axis, mesh.shape[axis])
    return jax.device_put(x, NamedSharding(mesh, P(axis, None)))


def spec_tree_to_shardings(mesh: Optional[Mesh], spec_tree):
    """Map a pytree of logical tuples to NamedShardings (or None mesh-less)."""
    if mesh is None:
        return jax.tree_util.tree_map(
            lambda _: None, spec_tree,
            is_leaf=lambda x: isinstance(x, tuple))
    return jax.tree_util.tree_map(
        lambda logical: NamedSharding(mesh, resolve(mesh, logical)),
        spec_tree, is_leaf=lambda x: isinstance(x, tuple) and all(
            v is None or isinstance(v, str) for v in x))
