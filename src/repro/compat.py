"""jax version-compatibility shims (single import point).

The codebase targets current jax naming; older runtimes (e.g. 0.4.x, the
CPU container image) keep the same semantics under earlier names:

* ``pltpu.CompilerParams``            -> ``pltpu.TPUCompilerParams``
* ``jax.shard_map(..., check_vma=)``  -> ``jax.experimental.shard_map.shard_map(..., check_rep=)``
* ``jax.make_mesh(..., axis_types=)`` -> ``jax.make_mesh(...)`` (no axis_types kwarg)

Every kernel / mesh / shard_map call site imports from here so the rest of
the tree reads as if only the modern API existed.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax

from jax.experimental.pallas import tpu as _pltpu

# Pallas TPU compiler-params dataclass (renamed from TPUCompilerParams).
CompilerParams = getattr(_pltpu, "CompilerParams", None) \
    or _pltpu.TPUCompilerParams


def shard_map(f, *, mesh, in_specs, out_specs, check: bool = False):
    """Per-shard mapping with replication checking off by default."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=check)


def axis_size(name: str):
    """Size of a named mapped axis (jax.lax.axis_size is a recent addition;
    psum of the literal 1 is the classic equivalent and constant-folds)."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(name)
    return jax.lax.psum(1, name)


def make_mesh(shape: Sequence[int], axes: Sequence[str],
              devices: Optional[Sequence] = None) -> jax.sharding.Mesh:
    kwargs = {}
    if hasattr(jax.sharding, "AxisType"):
        kwargs["axis_types"] = (jax.sharding.AxisType.Auto,) * len(axes)
    return jax.make_mesh(tuple(shape), tuple(axes), devices=devices,
                         **kwargs)
