"""Expert-parallel MoE — the Centaur sparse engine generalized.

MoE dispatch is a sparse gather/scatter over a parameter store far too big
for one chip — exactly the paper's embedding-table problem. The same design
answers it: shard the store (experts) over the 'model' axis, stream tokens to
the owning chip with a *fixed-capacity* all-to-all (static shapes = the
SRAM_sparseID prefetch buffer), compute locally, stream back, and reduce
(combine) on the fly at the source.

Token dim is temporarily sharded over **all** mesh axes inside the block
("EP borrows the TP axis"), so dispatch buffers scale 1/n_devices; with
top-8 and cf=1.25 the per-chip buffer stays ~10x the local token bytes
regardless of pod size.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.configs.base import MoEConfig
from repro.distributed.sharding import active_mesh
from repro.models.params import Builder


def init_moe(b: Builder, mcfg: MoEConfig, d: int):
    """Expert weights are sharded over BOTH the 'model' axis (expert dim,
    EP) and the 'data' axis (hidden dim, ZeRO-3/FSDP): a 1T-param MoE's
    expert block is 2 TB in bf16 — EP alone leaves 125 GB/chip on a 16-way
    model axis. The FSDP shard is re-gathered per layer inside the MoE
    shard_map (bf16 all-gather over 'data'), and its gradient reduce-
    scatters back automatically through autodiff."""
    e, ff = mcfg.n_experts, mcfg.expert_ff
    return {
        "wr": b.normal((d, e), (None, None), dtype=jnp.float32),
        "wg": b.normal((e, d, ff), ("expert", "fsdp", None)),
        "wu": b.normal((e, d, ff), ("expert", "fsdp", None)),
        "wd": b.normal((e, ff, d), ("expert", "fsdp", None)),
    }


def _capacity(t_local: int, mcfg: MoEConfig, ep: int) -> int:
    c = int(np.ceil(t_local * mcfg.top_k * mcfg.capacity_factor
                    / mcfg.n_experts))
    return max(8, ((c + 7) // 8) * 8)


def _route(xf32, wr, mcfg: MoEConfig):
    """Returns (weights (T,k), idx (T,k), probs (T,E))."""
    logits = xf32 @ wr
    probs = jax.nn.softmax(logits, axis=-1)
    _, idx = jax.lax.top_k(probs, mcfg.top_k)
    w = jnp.take_along_axis(probs, idx, axis=-1)
    w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)   # renormalize
    return w, idx, probs


def _slots(idx, n_experts: int, capacity: int):
    """Per-choice dispatch slot = expert*C + rank-within-expert; OOB drops."""
    flat_e = idx.reshape(-1)                                # (T*k,)
    oh = jax.nn.one_hot(flat_e, n_experts, dtype=jnp.int32)
    pos = (jnp.cumsum(oh, axis=0) * oh).sum(-1) - 1         # rank in expert
    valid = pos < capacity
    slot = jnp.where(valid, flat_e * capacity + pos, n_experts * capacity)
    return slot, valid


def _expert_ffn(x, wg, wu, wd):
    """x: (E_loc, C', d) bf16; experts stacked on dim 0."""
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", x, wg)) \
        * jnp.einsum("ecd,edf->ecf", x, wu)
    return jnp.einsum("ecf,efd->ecd", h, wd)


def _aux_loss(probs, idx, mcfg: MoEConfig):
    """Switch-style load-balance loss (local shard contribution)."""
    e = mcfg.n_experts
    frac = jax.nn.one_hot(idx.reshape(-1), e).mean(0)       # routed fraction
    imp = probs.mean(0)                                     # router mass
    return e * jnp.sum(frac * imp)


def _moe_shard(xl, wr, wg, wu, wd, *, mcfg: MoEConfig, ep_axis: str,
               all_axes: Tuple[str, ...], fsdp_axis: Optional[str] = None):
    """Runs inside shard_map. xl: (B_loc, S_loc, d) local tokens.

    The token flatten happens HERE (locally): flattening (B,S) -> (B*S) at
    the jax level merges two dims sharded on different mesh axes, whose flat
    index blocks are non-contiguous — GSPMD resolves that with a full
    rematerialization (measured: 3x 30 GB all-gathers of the GLOBAL
    activation per layer on the multi-pod kimi cell). A local reshape is
    free."""
    ep = compat.axis_size(ep_axis)
    e_loc = mcfg.n_experts // ep
    b_loc, s_loc, d = xl.shape
    xl = xl.reshape(b_loc * s_loc, d)
    t_loc = b_loc * s_loc
    cap = _capacity(t_loc, mcfg, ep)

    if fsdp_axis:
        # ZeRO-3: re-materialize this shard's expert weights (bf16 gather
        # over the DP axes); grads reduce-scatter back via autodiff.
        wg = jax.lax.all_gather(wg, fsdp_axis, axis=1, tiled=True)
        wu = jax.lax.all_gather(wu, fsdp_axis, axis=1, tiled=True)
        wd = jax.lax.all_gather(wd, fsdp_axis, axis=1, tiled=True)

    w, idx, probs = _route(xl.astype(jnp.float32), wr, mcfg)
    slot, valid = _slots(idx, mcfg.n_experts, cap)

    xrep = jnp.repeat(xl, mcfg.top_k, axis=0)               # (T*k, d)
    disp = jnp.zeros((mcfg.n_experts * cap, d), xl.dtype)
    disp = disp.at[slot].set(xrep, mode="drop")
    disp = disp.reshape(ep, e_loc * cap, d)

    # --- stream tokens to expert owners (fixed-capacity a2a) ---
    recv = jax.lax.all_to_all(disp, ep_axis, split_axis=0, concat_axis=0,
                              tiled=True)                   # (ep, E_loc*C, d)
    recv = recv.reshape(ep, e_loc, cap, d).transpose(1, 0, 2, 3) \
               .reshape(e_loc, ep * cap, d)

    y = _expert_ffn(recv, wg, wu, wd)

    # --- stream results back ---
    y = y.reshape(e_loc, ep, cap, d).transpose(1, 0, 2, 3) \
         .reshape(ep, e_loc * cap, d)
    back = jax.lax.all_to_all(y, ep_axis, split_axis=0, concat_axis=0,
                              tiled=True)
    back = back.reshape(mcfg.n_experts * cap, d)

    # --- on-the-fly combine (weighted reduce at the source) ---
    rows = jnp.take(back, jnp.minimum(slot, back.shape[0] - 1), axis=0)
    rows = jnp.where(valid[:, None], rows, 0)
    y_tok = (rows.reshape(t_loc, mcfg.top_k, d)
             * w[..., None].astype(rows.dtype)).sum(1)

    aux = _aux_loss(probs, idx, mcfg)
    aux = jax.lax.pmean(aux, all_axes)
    return y_tok.reshape(b_loc, s_loc, d).astype(xl.dtype), aux


def _moe_local(xf, p, mcfg: MoEConfig):
    """Single-shard path (no mesh): same math, ep=1, no collectives."""
    t, d = xf.shape
    cap = _capacity(t, mcfg, 1)
    w, idx, probs = _route(xf.astype(jnp.float32), p["wr"], mcfg)
    slot, valid = _slots(idx, mcfg.n_experts, cap)
    xrep = jnp.repeat(xf, mcfg.top_k, axis=0)
    disp = jnp.zeros((mcfg.n_experts * cap, d), xf.dtype)
    disp = disp.at[slot].set(xrep, mode="drop")
    y = _expert_ffn(disp.reshape(mcfg.n_experts, cap, d),
                    p["wg"], p["wu"], p["wd"])
    back = y.reshape(mcfg.n_experts * cap, d)
    rows = jnp.take(back, jnp.minimum(slot, back.shape[0] - 1), axis=0)
    rows = jnp.where(valid[:, None], rows, 0)
    y_tok = (rows.reshape(t, mcfg.top_k, d)
             * w[..., None].astype(rows.dtype)).sum(1)
    return y_tok.astype(xf.dtype), _aux_loss(probs, idx, mcfg)


def apply_moe(p, mcfg: MoEConfig, x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, D) -> (y (B, S, D), aux_loss scalar).

    Tokens enter the shard_map 3D (B over the DP axes, S over 'model' — the
    SP layout) and are flattened locally inside; see _moe_shard."""
    b, s, d = x.shape
    mesh = active_mesh()
    if mesh is not None and "model" in mesh.axis_names \
            and mesh.shape["model"] > 1:
        dp_axes = tuple(a for a in mesh.axis_names if a != "model")
        n_dp = int(np.prod([mesh.shape[a] for a in dp_axes])) if dp_axes \
            else 1
        tp = mesh.shape["model"]
        if (b % n_dp == 0 and s % tp == 0
                and mcfg.n_experts % tp == 0):
            axes = tuple(mesh.axis_names)
            bspec = dp_axes if len(dp_axes) > 1 else (
                dp_axes[0] if dp_axes else None)
            # FSDP the expert hidden dims over every DP axis when divisible
            fsdp = (dp_axes if dp_axes and d % n_dp == 0
                    and mcfg.expert_ff % n_dp == 0 else ())
            wspec = P("model", fsdp if fsdp else None, None)
            fn = compat.shard_map(
                functools.partial(_moe_shard, mcfg=mcfg, ep_axis="model",
                                  all_axes=axes, fsdp_axis=fsdp),
                mesh=mesh,
                in_specs=(P(bspec, "model", None), P(None, None),
                          wspec, wspec, wspec),
                out_specs=(P(bspec, "model", None), P()))
            return fn(x, p["wr"], p["wg"], p["wu"], p["wd"])
    y, aux = _moe_local(x.reshape(b * s, d), p, mcfg)
    return y.reshape(b, s, d), aux
