"""Shared transformer building blocks (norms, RoPE, GQA attention, MLPs).

Attention has three execution paths:
  * direct — materializes (S, S) scores; used for short sequences;
  * chunked — online-softmax over q/kv chunks (Rabe–Staats), memory
    O(chunk^2); the default for long sequences, remat'd scan body;
  * decode — one query token against a (possibly ring-buffered) KV cache.

All activations carry logical sharding constraints ('batch' = every mesh axis
but 'model'; heads/ffn sharded on 'model'). GSPMD handles non-divisible head
counts by padding; shard_map paths (embedding, MoE) require exact divisibility
and pad explicitly at init.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import AttentionConfig
from repro.distributed.sharding import constrain
from repro.models.params import Builder

# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def init_norm(b: Builder, d: int, kind: str):
    if kind == "rmsnorm":
        return {"w": b.ones((d,), (None,), dtype=jnp.float32)}
    return {"w": b.ones((d,), (None,), dtype=jnp.float32),
            "b": b.zeros((d,), (None,), dtype=jnp.float32)}


def apply_norm(p, x: jax.Array, kind: str, eps: float = 1e-6) -> jax.Array:
    x32 = x.astype(jnp.float32)
    if kind == "rmsnorm":
        scale = jax.lax.rsqrt(jnp.mean(jnp.square(x32), -1, keepdims=True) + eps)
        return (x32 * scale * p["w"]).astype(x.dtype)
    mu = x32.mean(-1, keepdims=True)
    var = jnp.mean(jnp.square(x32 - mu), -1, keepdims=True)
    return ((x32 - mu) * jax.lax.rsqrt(var + eps) * p["w"] + p["b"]).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, n_heads, head_dim); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    half = hd // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq      # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]                           # (..., S, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP blocks
# ---------------------------------------------------------------------------

def init_mlp(b: Builder, d: int, dff: int, act: str):
    if act in ("swiglu", "geglu"):
        return {"wg": b.normal((d, dff), (None, "model")),
                "wu": b.normal((d, dff), (None, "model")),
                "wd": b.normal((dff, d), ("model", None))}
    return {"wi": b.normal((d, dff), (None, "model")),
            "wd": b.normal((dff, d), ("model", None))}


def apply_mlp(p, x: jax.Array, act: str) -> jax.Array:
    if act in ("swiglu", "geglu"):
        gate_fn = jax.nn.silu if act == "swiglu" else jax.nn.gelu
        g = gate_fn(x @ p["wg"]) * (x @ p["wu"])
        g = constrain(g, "batch", None, "model")
        return g @ p["wd"]
    act_fn = jax.nn.gelu if act == "gelu" else jax.nn.relu
    h = act_fn(x @ p["wi"])
    h = constrain(h, "batch", None, "model")
    return h @ p["wd"]


# ---------------------------------------------------------------------------
# GQA attention
# ---------------------------------------------------------------------------

def init_attention(b: Builder, acfg: AttentionConfig, d: int):
    hd = acfg.resolved_head_dim(d)
    h, k = acfg.n_heads, acfg.n_kv_heads
    p = {"wq": b.normal((d, h * hd), (None, "model")),
         "wk": b.normal((d, k * hd), (None, "model")),
         "wv": b.normal((d, k * hd), (None, "model")),
         "wo": b.normal((h * hd, d), ("model", None))}
    if acfg.qkv_bias:
        p["bq"] = b.zeros((h * hd,), ("model",))
        p["bk"] = b.zeros((k * hd,), ("model",))
        p["bv"] = b.zeros((k * hd,), ("model",))
    return p


def head_constrain(x: jax.Array, n_heads: int, head_axis: int = 2):
    """Shard the head dim over 'model' — with a measured policy.

    A/B'd on the 512-dev dry-run (EXPERIMENTS.md §Perf):

    * heads >= TP (q heads, 15..64 here): FORCE the constraint even when
      uneven — padding waste is <= ceil/floor ~ 1.07-1.6x, and without it
      GSPMD replicates attention across 'model' (smollm: 4x flops+bytes).
    * heads < TP (kv heads 1..8 under TP=16): do NOT constrain — forcing a
      padded 16-way form conflicts with GSPMD's natural [heads x head_dim]
      split of the flat projection and triggers 'involuntary full
      rematerialization' (arctic: ~1e13 collective bytes/step, 40x the
      model's real traffic). Propagation keeps the split consistent.
    """
    from repro.distributed.sharding import active_mesh
    mesh = active_mesh()
    if mesh is None or "model" not in mesh.axis_names:
        return x
    # Measured boundary (TP=16): kv=8 forced -> involuntary-remat disaster
    # (arctic, 40x traffic) because 8 divides 16 and GSPMD's natural [8,2]
    # split must not be fought; q=15 / kv=5 propagated -> 4x flops+bytes
    # (smollm) because no clean split exists and GSPMD replicates instead.
    # Rule: propagate only the clean-division case (heads < TP dividing TP).
    tp = mesh.shape["model"]
    if n_heads < tp and tp % n_heads == 0:
        return x
    spec = [None] * x.ndim
    spec[0] = "batch"
    spec[head_axis] = "model"
    return constrain(x, *spec)


def _project_qkv(p, acfg: AttentionConfig, x: jax.Array, d: int):
    b_, s, _ = x.shape
    hd = acfg.resolved_head_dim(d)
    h, k = acfg.n_heads, acfg.n_kv_heads
    q = constrain(x @ p["wq"], "batch", None, "model")   # flat: divisible
    kk = constrain(x @ p["wk"], "batch", None, "model")
    v = constrain(x @ p["wv"], "batch", None, "model")
    if acfg.qkv_bias:
        q, kk, v = q + p["bq"], kk + p["bk"], v + p["bv"]
    q = head_constrain(q.reshape(b_, s, h, hd), h)
    kk = head_constrain(kk.reshape(b_, s, k, hd), k)
    v = head_constrain(v.reshape(b_, s, k, hd), k)
    return q, kk, v


def _mask(qpos, kpos, causal: bool, window: Optional[int]):
    """qpos: (..., Sq), kpos: (..., Sk) -> bool (..., Sq, Sk); True=keep."""
    m = jnp.ones(qpos.shape + kpos.shape[-1:], bool)
    if causal:
        m &= kpos[..., None, :] <= qpos[..., None]
    if window is not None:
        m &= kpos[..., None, :] > qpos[..., None] - window
    return m


def _sdpa_direct(q, k, v, qpos, kpos, causal, window):
    """q: (B,Sq,K,G,h); k,v: (B,Sk,K,h)."""
    scale = q.shape[-1] ** -0.5
    s = jnp.einsum("bqkgh,bckh->bkgqc", q, k,
                   preferred_element_type=jnp.float32) * scale
    mask = _mask(qpos, kpos, causal, window)              # (Sq, Sk)
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqc,bckh->bqkgh", p.astype(v.dtype), v)
    return out


def _sdpa_chunked(q, k, v, qpos, kpos, causal, window,
                  q_chunk: int, kv_chunk: int):
    """Online-softmax attention; same signature as _sdpa_direct."""
    b_, sq, kh, g, hd = q.shape
    hv = v.shape[-1]                      # v head dim may differ (MLA)
    sk = k.shape[1]
    q_chunk = min(q_chunk, sq)
    kv_chunk = min(kv_chunk, sk)
    nq, nk = sq // q_chunk, sk // kv_chunk
    assert sq % q_chunk == 0 and sk % kv_chunk == 0, (sq, sk)
    scale = hd ** -0.5

    q_r = q.reshape(b_, nq, q_chunk, kh, g, hd)
    qpos_r = qpos.reshape(nq, q_chunk)
    k_r = k.reshape(b_, nk, kv_chunk, kh, hd)
    v_r = v.reshape(b_, nk, kv_chunk, kh, hv)
    kpos_r = kpos.reshape(nk, kv_chunk)

    def one_q_chunk(qc, qp):
        # qc: (B, qc, K, G, h); qp: (qc,)
        def kv_step(carry, xs):
            acc, m, l = carry
            kc, vc, kp = xs
            s = jnp.einsum("bqkgh,bckh->bkgqc", qc, kc,
                           preferred_element_type=jnp.float32) * scale
            mask = _mask(qp, kp, causal, window)
            s = jnp.where(mask[None, None, None], s, -1e30)
            m_new = jnp.maximum(m, s.max(-1))
            corr = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new[..., None])
            l_new = l * corr + p.sum(-1)
            pv = jnp.einsum("bkgqc,bckh->bkgqh", p.astype(vc.dtype), vc)
            acc_new = acc * corr[..., None] + pv.astype(jnp.float32)
            return (acc_new, m_new, l_new), None

        acc0 = jnp.zeros((b_, kh, g, q_chunk, hv), jnp.float32)
        m0 = jnp.full((b_, kh, g, q_chunk), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((b_, kh, g, q_chunk), jnp.float32)
        (acc, m, l), _ = jax.lax.scan(
            jax.checkpoint(kv_step),
            (acc0, m0, l0),
            (k_r.swapaxes(0, 1), v_r.swapaxes(0, 1), kpos_r))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return jnp.einsum("bkgqh->bqkgh", out).astype(q.dtype)

    outs = jax.lax.map(lambda xs: one_q_chunk(*xs),
                       (q_r.swapaxes(0, 1), qpos_r))      # (nq, B, qc, K, G, hv)
    return outs.swapaxes(0, 1).reshape(b_, sq, kh, g, hv)


# Sequences at or beyond this length use the chunked path.
CHUNKED_THRESHOLD = 2048
Q_CHUNK = 1024
KV_CHUNK = 1024


def pick_chunk(s: int, target: int) -> int:
    """Largest divisor of s not exceeding target (chunked-path block size)."""
    for c in range(min(target, s), 0, -1):
        if s % c == 0:
            return c
    return s


def attention_full(p, acfg: AttentionConfig, x: jax.Array,
                   positions: jax.Array, d: int, return_kv: bool = False):
    """Full-sequence self-attention (train / prefill)."""
    b_, s, _ = x.shape
    hd = acfg.resolved_head_dim(d)
    h, kh = acfg.n_heads, acfg.n_kv_heads
    g = h // kh
    q, k, v = _project_qkv(p, acfg, x, d)
    q = rope(q, positions, acfg.rope_theta)
    k = rope(k, positions, acfg.rope_theta)
    from repro.kernels import ops as kops
    if s >= CHUNKED_THRESHOLD and kops.get_impl() == "pallas":
        # TPU: fused flash kernel — online-softmax state stays in VMEM,
        # no O(S^2 H) score traffic through HBM (see kernels/flash_attention)
        from repro.kernels.flash_attention import flash_attention_gqa
        out = flash_attention_gqa(q, k, v, causal=acfg.causal,
                                  window=acfg.window)
    elif s >= CHUNKED_THRESHOLD:
        qg = q.reshape(b_, s, kh, g, hd)
        out = _sdpa_chunked(qg, k, v, positions, positions, acfg.causal,
                            acfg.window, pick_chunk(s, Q_CHUNK),
                            pick_chunk(s, KV_CHUNK))
    else:
        qg = q.reshape(b_, s, kh, g, hd)
        out = _sdpa_direct(qg, k, v, positions, positions, acfg.causal,
                           acfg.window)
    out = out.reshape(b_, s, h * hd).astype(x.dtype)
    out = constrain(out, "batch", None, "model")
    out = out @ p["wo"]
    if return_kv:
        return out, (k, v)
    return out


def cross_attention_full(p, acfg: AttentionConfig, x: jax.Array,
                         memory_kv: Tuple[jax.Array, jax.Array],
                         d: int) -> jax.Array:
    """Cross-attention against precomputed (K, V) memory (enc-dec)."""
    b_, s, _ = x.shape
    hd = acfg.resolved_head_dim(d)
    h, kh = acfg.n_heads, acfg.n_kv_heads
    g = h // kh
    q = (x @ p["wq"]).reshape(b_, s, h, hd)
    if acfg.qkv_bias:
        q = q + p["bq"].reshape(h, hd)
    k, v = memory_kv
    sk = k.shape[1]
    qg = q.reshape(b_, s, kh, g, hd)
    qpos = jnp.arange(s)
    kpos = jnp.arange(sk)
    if s >= CHUNKED_THRESHOLD:
        out = _sdpa_chunked(qg, k, v, qpos, kpos, causal=False, window=None,
                            q_chunk=pick_chunk(s, Q_CHUNK),
                            kv_chunk=pick_chunk(sk, KV_CHUNK))
    else:
        out = _sdpa_direct(qg, k, v, qpos, kpos, causal=False, window=None)
    out = out.reshape(b_, s, h * hd).astype(x.dtype)
    return out @ p["wo"]


def memory_kv(p, acfg: AttentionConfig, memory: jax.Array, d: int):
    """Precompute cross-attention K/V from encoder output."""
    b_, sk, _ = memory.shape
    hd = acfg.resolved_head_dim(d)
    kh = acfg.n_kv_heads
    k = (memory @ p["wk"]).reshape(b_, sk, kh, hd)
    v = (memory @ p["wv"]).reshape(b_, sk, kh, hd)
    if acfg.qkv_bias:
        k = k + p["bk"].reshape(kh, hd)
        v = v + p["bv"].reshape(kh, hd)
    return k, v


# ---------------------------------------------------------------------------
# Decode (KV cache) path
# ---------------------------------------------------------------------------

def init_kv_cache(acfg: AttentionConfig, d: int, batch: int, max_len: int,
                  dtype=jnp.bfloat16, ring: bool = False):
    """Cache pytree for one attention layer.

    ring=True bounds the buffer at `window` slots (SWA long-context decode);
    slot_pos records the absolute position stored in each slot (-1 = empty).
    """
    hd = acfg.resolved_head_dim(d)
    kh = acfg.n_kv_heads
    size = min(max_len, acfg.window) if (ring and acfg.window) else max_len
    return {
        "k": jnp.zeros((batch, size, kh, hd), dtype),
        "v": jnp.zeros((batch, size, kh, hd), dtype),
        "slot_pos": jnp.full((size,), -1, jnp.int32),
    }


def cache_from_kv(acfg: AttentionConfig, k: jax.Array, v: jax.Array,
                  max_len: int, dtype=jnp.bfloat16, ring: bool = False):
    """Build a decode cache from prefill K/V. k/v: (B, S, KV, hd)."""
    b_, s, kh, hd = k.shape
    size = min(max_len, acfg.window) if (ring and acfg.window) else max_len
    cache = {"k": jnp.zeros((b_, size, kh, hd), dtype),
             "v": jnp.zeros((b_, size, kh, hd), dtype),
             "slot_pos": jnp.full((size,), -1, jnp.int32)}
    keep = min(s, size)
    positions = jnp.arange(s - keep, s)
    slots = jnp.mod(positions, size)
    cache["k"] = cache["k"].at[:, slots].set(k[:, -keep:].astype(dtype))
    cache["v"] = cache["v"].at[:, slots].set(v[:, -keep:].astype(dtype))
    cache["slot_pos"] = cache["slot_pos"].at[slots].set(positions)
    return cache


def attention_decode(p, acfg: AttentionConfig, x: jax.Array, pos: jax.Array,
                     cache, d: int, cross_kv=None):
    """One-token attention step. x: (B, 1, D); pos: scalar int32.

    Returns (out (B,1,D), new_cache). Works for both linear caches
    (size >= max position) and ring buffers (size == window).
    """
    b_, _, _ = x.shape
    hd = acfg.resolved_head_dim(d)
    h, kh = acfg.n_heads, acfg.n_kv_heads
    g = h // kh
    q, k_new, v_new = _project_qkv(p, acfg, x, d)
    posb = jnp.full((b_, 1), pos)
    q = rope(q, posb, acfg.rope_theta)
    k_new = rope(k_new, posb, acfg.rope_theta)

    size = cache["k"].shape[1]
    slot = jnp.mod(pos, size)
    k = jax.lax.dynamic_update_slice(cache["k"], k_new.astype(cache["k"].dtype),
                                     (0, slot, 0, 0))
    v = jax.lax.dynamic_update_slice(cache["v"], v_new.astype(cache["v"].dtype),
                                     (0, slot, 0, 0))
    slot_pos = cache["slot_pos"].at[slot].set(pos)

    qg = q.reshape(b_, 1, kh, g, hd)
    scale = hd ** -0.5
    s = jnp.einsum("bqkgh,bckh->bkgqc", qg, k,
                   preferred_element_type=jnp.float32) * scale
    keep = (slot_pos >= 0) & (slot_pos <= pos)
    if acfg.window is not None:
        keep &= slot_pos > pos - acfg.window
    s = jnp.where(keep[None, None, None, None, :], s, -1e30)
    prob = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqc,bckh->bqkgh", prob.astype(v.dtype), v)
    out = out.reshape(b_, 1, h * hd).astype(x.dtype)
    out = out @ p["wo"]
    return out, {"k": k, "v": v, "slot_pos": slot_pos}


def cross_attention_decode(p, acfg: AttentionConfig, x: jax.Array,
                           cross_kv, d: int):
    """One-token cross-attention against fixed memory K/V."""
    b_ = x.shape[0]
    hd = acfg.resolved_head_dim(d)
    h, kh = acfg.n_heads, acfg.n_kv_heads
    g = h // kh
    q = (x @ p["wq"]).reshape(b_, 1, h, hd)
    if acfg.qkv_bias:
        q = q + p["bq"].reshape(h, hd)
    k, v = cross_kv
    qg = q.reshape(b_, 1, kh, g, hd)
    out = _sdpa_direct(qg, k, v, jnp.zeros((1,), jnp.int32),
                       jnp.zeros((k.shape[1],), jnp.int32),
                       causal=False, window=None)
    out = out.reshape(b_, 1, h * hd).astype(x.dtype)
    return out @ p["wo"]
