"""Encoder-decoder assembly (seamless-m4t): stub audio frontend -> encoder
self-attention stack -> decoder with causal self-attention + cross-attention.

Per the assignment the modality frontend is a STUB: the encoder consumes
precomputed frame embeddings (B, S_src, d_model) from ``input_specs()``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain
from repro.models import embedding as emb
from repro.models import layers
from repro.models.params import Builder, split, stack_layers


def _enc_attn_cfg(cfg: ModelConfig):
    return dataclasses.replace(cfg.attention, causal=False)


def _init_enc_block(b: Builder, cfg: ModelConfig):
    return {"ln1": layers.init_norm(b, cfg.d_model, cfg.norm),
            "attn": layers.init_attention(b, cfg.attention, cfg.d_model),
            "ln2": layers.init_norm(b, cfg.d_model, cfg.norm),
            "mlp": layers.init_mlp(b, cfg.d_model, cfg.d_ff, cfg.act)}


def _init_dec_block(b: Builder, cfg: ModelConfig):
    return {"ln1": layers.init_norm(b, cfg.d_model, cfg.norm),
            "self": layers.init_attention(b, cfg.attention, cfg.d_model),
            "lnx": layers.init_norm(b, cfg.d_model, cfg.norm),
            "cross": layers.init_attention(b, cfg.attention, cfg.d_model),
            "ln2": layers.init_norm(b, cfg.d_model, cfg.norm),
            "mlp": layers.init_mlp(b, cfg.d_model, cfg.d_ff, cfg.act)}


def init(key: jax.Array, cfg: ModelConfig):
    dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    b = Builder(key, dtype=dtype)
    tree = {
        "embed": emb.init_table(b, cfg.vocab_size, cfg.d_model),
        "enc": stack_layers([_init_enc_block(b, cfg)
                             for _ in range(cfg.enc_layers)]),
        "enc_ln_f": layers.init_norm(b, cfg.d_model, cfg.norm),
        "dec": stack_layers([_init_dec_block(b, cfg)
                             for _ in range(cfg.dec_layers)]),
        "ln_f": layers.init_norm(b, cfg.d_model, cfg.norm),
    }
    if not cfg.tie_embeddings:
        tree["unembed"] = emb.init_unembed(b, cfg.vocab_size, cfg.d_model)
    return split(tree)


def encode(params, cfg: ModelConfig, frames: jax.Array,
           remat: bool = True) -> jax.Array:
    """frames: (B, S_src, D) stub embeddings -> encoder memory."""
    x = constrain(frames, "batch", None, None)
    s = x.shape[1]
    positions = jnp.arange(s)
    acfg = _enc_attn_cfg(cfg)

    x = constrain(x, "batch", "model", None)          # SP residual stream

    def body(x, p_l):
        h = layers.apply_norm(p_l["ln1"], x, cfg.norm)
        h = constrain(h, "batch", None, None)
        a = layers.attention_full(p_l["attn"], acfg, h, positions,
                                  cfg.d_model)
        x = x + constrain(a, "batch", "model", None)
        h = layers.apply_norm(p_l["ln2"], x, cfg.norm)
        h = constrain(h, "batch", None, None)
        y = layers.apply_mlp(p_l["mlp"], h, cfg.act)
        return x + constrain(y, "batch", "model", None), None

    body_fn = jax.checkpoint(body) if remat else body
    x, _ = jax.lax.scan(body_fn, x, params["enc"])
    x = constrain(x, "batch", None, None)
    return layers.apply_norm(params["enc_ln_f"], x, cfg.norm)


def _dec_block_full(p, cfg: ModelConfig, x, positions, memory):
    h = layers.apply_norm(p["ln1"], x, cfg.norm)
    h = constrain(h, "batch", None, None)
    a = layers.attention_full(p["self"], cfg.attention, h, positions,
                              cfg.d_model)
    x = x + constrain(a, "batch", "model", None)
    h = layers.apply_norm(p["lnx"], x, cfg.norm)
    h = constrain(h, "batch", None, None)
    kv = layers.memory_kv(p["cross"], cfg.attention, memory, cfg.d_model)
    a = layers.cross_attention_full(p["cross"], cfg.attention, h, kv,
                                    cfg.d_model)
    x = x + constrain(a, "batch", "model", None)
    h = layers.apply_norm(p["ln2"], x, cfg.norm)
    h = constrain(h, "batch", None, None)
    y = layers.apply_mlp(p["mlp"], h, cfg.act)
    return x + constrain(y, "batch", "model", None)


def forward(params, cfg: ModelConfig, batch: Dict[str, jax.Array],
            remat: bool = True):
    """batch: {'frames': (B,S_src,D), 'tokens': (B,S_tgt)} -> (logits, 0.0)."""
    memory = encode(params, cfg, batch["frames"], remat)
    x = emb.embed_tokens(params["embed"], batch["tokens"])
    x = constrain(x, "batch", "model", None)          # SP residual stream
    positions = jnp.arange(x.shape[1])

    def body(x, p_l):
        return _dec_block_full(p_l, cfg, x, positions, memory), None

    body_fn = jax.checkpoint(body) if remat else body
    x, _ = jax.lax.scan(body_fn, x, params["dec"])
    x = constrain(x, "batch", None, None)
    x = layers.apply_norm(params["ln_f"], x, cfg.norm)
    if cfg.tie_embeddings:
        logits = emb.lm_head(x, params["embed"], cfg.vocab_size)
    else:
        logits = emb.lm_head_untied(x, params["unembed"], cfg.vocab_size)
    return logits, 0.0


def loss_fn(params, cfg: ModelConfig, batch, remat: bool = True):
    logits, _ = forward(params, cfg, batch, remat)
    labels = batch["tokens"][:, 1:]
    mask = jnp.ones(labels.shape, jnp.float32)
    return emb.cross_entropy(logits[:, :-1], labels, mask)


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16):
    """Self-attn caches (max_len) + cross K/V (enc_memory_len) per layer."""
    hd = cfg.attention.resolved_head_dim(cfg.d_model)
    kh = cfg.attention.n_kv_heads

    def stack(trees):
        return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *trees)

    self_caches = stack([
        layers.init_kv_cache(cfg.attention, cfg.d_model, batch, max_len,
                             dtype) for _ in range(cfg.dec_layers)])
    return {
        "self": self_caches,
        "cross_k": jnp.zeros((cfg.dec_layers, batch, cfg.enc_memory_len,
                              kh, hd), dtype),
        "cross_v": jnp.zeros((cfg.dec_layers, batch, cfg.enc_memory_len,
                              kh, hd), dtype),
    }


def prefill(params, cfg: ModelConfig, batch, max_len: int,
            dtype=jnp.bfloat16, remat: bool = True):
    """Encode + teacher-forced decoder pass building all caches."""
    memory = encode(params, cfg, batch["frames"], remat)
    x = emb.embed_tokens(params["embed"], batch["tokens"])
    x = constrain(x, "batch", "model", None)          # SP residual stream
    positions = jnp.arange(x.shape[1])

    def body(x, p_l):
        h = layers.apply_norm(p_l["ln1"], x, cfg.norm)
        h = constrain(h, "batch", None, None)
        a, (k, v) = layers.attention_full(p_l["self"], cfg.attention, h,
                                          positions, cfg.d_model,
                                          return_kv=True)
        x = x + constrain(a, "batch", "model", None)
        entry = layers.cache_from_kv(cfg.attention, k, v, max_len, dtype)
        h = layers.apply_norm(p_l["lnx"], x, cfg.norm)
        h = constrain(h, "batch", None, None)
        kv = layers.memory_kv(p_l["cross"], cfg.attention, memory,
                              cfg.d_model)
        a = layers.cross_attention_full(p_l["cross"], cfg.attention, h,
                                        kv, cfg.d_model)
        x = x + constrain(a, "batch", "model", None)
        h = layers.apply_norm(p_l["ln2"], x, cfg.norm)
        h = constrain(h, "batch", None, None)
        y = layers.apply_mlp(p_l["mlp"], h, cfg.act)
        x = x + constrain(y, "batch", "model", None)
        return x, (entry, kv[0].astype(dtype), kv[1].astype(dtype))

    body_fn = jax.checkpoint(body) if remat else body
    x, (entries, cross_k, cross_v) = jax.lax.scan(body_fn, x, params["dec"])
    x = constrain(x, "batch", None, None)
    x = layers.apply_norm(params["ln_f"], x[:, -1:], cfg.norm)
    if cfg.tie_embeddings:
        logits = emb.lm_head(x, params["embed"], cfg.vocab_size)
    else:
        logits = emb.lm_head_untied(x, params["unembed"], cfg.vocab_size)
    cache = {"self": entries, "cross_k": cross_k, "cross_v": cross_v}
    return logits[:, 0], cache


def decode_step(params, cfg: ModelConfig, cache, tokens: jax.Array,
                pos: jax.Array):
    """One decoder token against self cache + fixed cross memory."""
    x = emb.embed_tokens(params["embed"], tokens[:, None])

    def body(x, xs):
        p_l, c_l, ck, cv = xs
        h = layers.apply_norm(p_l["ln1"], x, cfg.norm)
        a, c_new = layers.attention_decode(p_l["self"], cfg.attention, h,
                                           pos, c_l, cfg.d_model)
        x = x + a
        h = layers.apply_norm(p_l["lnx"], x, cfg.norm)
        x = x + layers.cross_attention_decode(p_l["cross"], cfg.attention,
                                              h, (ck, cv), cfg.d_model)
        h = layers.apply_norm(p_l["ln2"], x, cfg.norm)
        x = x + layers.apply_mlp(p_l["mlp"], h, cfg.act)
        return x, c_new

    x, new_self = jax.lax.scan(
        body, x, (params["dec"], cache["self"], cache["cross_k"],
                  cache["cross_v"]))
    x = layers.apply_norm(params["ln_f"], x, cfg.norm)
    if cfg.tie_embeddings:
        logits = emb.lm_head(x, params["embed"], cfg.vocab_size)
    else:
        logits = emb.lm_head_untied(x, params["unembed"], cfg.vocab_size)
    return logits[:, 0], {"self": new_self, "cross_k": cache["cross_k"],
                          "cross_v": cache["cross_v"]}
