"""Decoder-only model assembly for the arch zoo (decoder / vlm / ssm / hybrid).

Layers are scanned (``lax.scan`` over stacked per-layer params) to keep the
HLO small enough to SPMD-partition 512 ways; the scan body is remat'd.
Heterogeneous-block archs (recurrentgemma's rec/rec/attn pattern) scan over
*groups* with leftover tail blocks unrolled.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain
from repro.models import embedding as emb
from repro.models import layers, mla, moe, rglru, rwkv6
from repro.models.params import Builder, Param, split, stack_layers

# ---------------------------------------------------------------------------
# Block init
# ---------------------------------------------------------------------------

def _init_attn_block(b: Builder, cfg: ModelConfig):
    p = {"ln1": layers.init_norm(b, cfg.d_model, cfg.norm),
         "ln2": layers.init_norm(b, cfg.d_model, cfg.norm)}
    if cfg.attention.kind == "mla":
        p["mla"] = mla.init_mla(b, cfg.attention, cfg.d_model)
    else:
        p["attn"] = layers.init_attention(b, cfg.attention, cfg.d_model)
    if cfg.moe is not None:
        p["moe"] = moe.init_moe(b, cfg.moe, cfg.d_model)
        if cfg.moe.dense_residual_ff:
            p["res_mlp"] = layers.init_mlp(b, cfg.d_model,
                                           cfg.moe.dense_residual_ff, cfg.act)
    else:
        p["mlp"] = layers.init_mlp(b, cfg.d_model, cfg.d_ff, cfg.act)
    return p


def _init_rwkv_block(b: Builder, cfg: ModelConfig):
    return {"ln1": layers.init_norm(b, cfg.d_model, cfg.norm),
            "tm": rwkv6.init_time_mix(b, cfg.rwkv, cfg.d_model),
            "ln2": layers.init_norm(b, cfg.d_model, cfg.norm),
            "cm": rwkv6.init_channel_mix(b, cfg.d_model, cfg.d_ff)}


def _init_rec_block(b: Builder, cfg: ModelConfig):
    return {"ln1": layers.init_norm(b, cfg.d_model, cfg.norm),
            "rec": rglru.init_rec(b, cfg.rglru, cfg.d_model),
            "ln2": layers.init_norm(b, cfg.d_model, cfg.norm),
            "mlp": layers.init_mlp(b, cfg.d_model, cfg.d_ff, cfg.act)}


def _hybrid_layout(cfg: ModelConfig) -> Tuple[int, Tuple[str, ...]]:
    """(n_full_groups, tail kinds) for the block pattern over n_layers."""
    pat = cfg.rglru.block_pattern
    n_groups = cfg.n_layers // len(pat)
    tail = tuple(pat[i] for i in range(cfg.n_layers - n_groups * len(pat)))
    return n_groups, tail


def init(key: jax.Array, cfg: ModelConfig):
    """Returns (param values, logical spec tree)."""
    dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    b = Builder(key, dtype=dtype)
    tree: Dict[str, Any] = {"embed": emb.init_table(b, cfg.vocab_size,
                                                    cfg.d_model)}
    if cfg.family in ("decoder", "vlm"):
        blocks = [_init_attn_block(b, cfg) for _ in range(cfg.n_layers)]
        tree["layers"] = stack_layers(blocks)
    elif cfg.family == "ssm":
        blocks = [_init_rwkv_block(b, cfg) for _ in range(cfg.n_layers)]
        tree["layers"] = stack_layers(blocks)
    elif cfg.family == "hybrid":
        n_groups, tail = _hybrid_layout(cfg)
        groups = []
        for _ in range(n_groups):
            g = {}
            for j, kind in enumerate(cfg.rglru.block_pattern):
                g[f"b{j}"] = (_init_rec_block(b, cfg) if kind == "rec"
                              else _init_attn_block(b, cfg))
            groups.append(g)
        tree["groups"] = stack_layers(groups)
        tree["tail"] = [(_init_rec_block(b, cfg) if kind == "rec"
                         else _init_attn_block(b, cfg)) for kind in tail]
    else:
        raise ValueError(cfg.family)
    tree["ln_f"] = layers.init_norm(b, cfg.d_model, cfg.norm)
    if not cfg.tie_embeddings:
        tree["unembed"] = emb.init_unembed(b, cfg.vocab_size, cfg.d_model)
    return split(tree)


# ---------------------------------------------------------------------------
# Full-sequence block application
# ---------------------------------------------------------------------------

def _attn_block_full(p, cfg: ModelConfig, x, positions):
    """One block with Megatron-style sequence parallelism: the residual
    stream x stays S-sharded over 'model' between blocks (so the per-layer
    activations saved by the scan's autodiff are 1/TP the size); attention
    and MLP gather the sequence at entry and reduce-scatter their output."""
    h = layers.apply_norm(p["ln1"], x, cfg.norm)
    h = constrain(h, "batch", None, None)            # SP all-gather
    if cfg.attention.kind == "mla":
        a = mla.mla_full(p["mla"], cfg.attention, h, positions, cfg.d_model)
    else:
        a = layers.attention_full(p["attn"], cfg.attention, h, positions,
                                  cfg.d_model)
    x = x + constrain(a, "batch", "model", None)     # SP reduce-scatter
    h = layers.apply_norm(p["ln2"], x, cfg.norm)
    if cfg.moe is not None:
        # MoE is tokenwise: consume the S-sharded stream directly (tokens
        # already sharded over every axis — no gather needed).
        y, aux = moe.apply_moe(p["moe"], cfg.moe, h)
        if cfg.moe.dense_residual_ff:
            hg = constrain(h, "batch", None, None)
            y = y + layers.apply_mlp(p["res_mlp"], hg, cfg.act)
    else:
        hg = constrain(h, "batch", None, None)
        y, aux = layers.apply_mlp(p["mlp"], hg, cfg.act), 0.0
    return x + constrain(y, "batch", "model", None), aux


def _rwkv_block_full(p, cfg: ModelConfig, x, state=None, chunked=False):
    h = layers.apply_norm(p["ln1"], x, cfg.norm)
    h = constrain(h, "batch", None, None)            # SP gather (time scan)
    a, tm_state = rwkv6.time_mix_full(
        p["tm"], cfg.rwkv, h,
        None if state is None else state["tm"], chunked=chunked)
    x = x + constrain(a, "batch", "model", None)
    h = layers.apply_norm(p["ln2"], x, cfg.norm)
    h = constrain(h, "batch", None, None)
    y, cm_state = rwkv6.channel_mix_full(
        p["cm"], h, None if state is None else state["cm"])
    return (x + constrain(y, "batch", "model", None),
            {"tm": tm_state, "cm": cm_state})


def _rec_block_full(p, cfg: ModelConfig, x):
    h = layers.apply_norm(p["ln1"], x, cfg.norm)
    h = constrain(h, "batch", None, None)            # SP gather (time scan)
    a, h_last = rglru.rec_full(p["rec"], cfg.rglru, h)
    x = x + constrain(a, "batch", "model", None)
    h = layers.apply_norm(p["ln2"], x, cfg.norm)
    h = constrain(h, "batch", None, None)
    y = layers.apply_mlp(p["mlp"], h, cfg.act)
    return x + constrain(y, "batch", "model", None), h_last


def _embed_input(params, cfg: ModelConfig, batch: Dict[str, jax.Array],
                 seq_shard: bool = True):
    """Tokens (+ modality-frontend stub embeddings) -> (B, S, D).

    The residual stream leaves here S-sharded over 'model' (sequence
    parallelism) for full-sequence paths."""
    x = emb.embed_tokens(params["embed"], batch["tokens"])
    if cfg.family == "vlm":
        x = jnp.concatenate([batch["patches"].astype(x.dtype), x], axis=1)
    if seq_shard:
        x = constrain(x, "batch", "model", None)
    else:
        x = constrain(x, "batch", None, None)
    return x


def _head(params, cfg: ModelConfig, x):
    x = constrain(x, "batch", None, None)            # gather S for the head
    x = layers.apply_norm(params["ln_f"], x, cfg.norm)
    if cfg.tie_embeddings:
        return emb.lm_head(x, params["embed"], cfg.vocab_size)
    return emb.lm_head_untied(x, params["unembed"], cfg.vocab_size)


def forward(params, cfg: ModelConfig, batch: Dict[str, jax.Array],
            remat: bool = True, rwkv_chunked: bool = True):
    """Teacher-forced forward -> (logits (B,S,Vpad) f32, aux scalar)."""
    x = _embed_input(params, cfg, batch)
    s = x.shape[1]
    positions = jnp.arange(s)

    if cfg.family in ("decoder", "vlm"):
        def body(carry, p_l):
            x, aux = carry
            x, a = _attn_block_full(p_l, cfg, x, positions)
            return (x, aux + jnp.asarray(a, jnp.float32)), None
        body_fn = jax.checkpoint(body) if remat else body
        (x, aux), _ = jax.lax.scan(body_fn, (x, jnp.zeros((), jnp.float32)),
                                   params["layers"])
    elif cfg.family == "ssm":
        def body(x, p_l):
            x, _ = _rwkv_block_full(p_l, cfg, x, chunked=rwkv_chunked)
            return x, None
        body_fn = jax.checkpoint(body) if remat else body
        x, _ = jax.lax.scan(body_fn, x, params["layers"])
        aux = 0.0
    elif cfg.family == "hybrid":
        pat = cfg.rglru.block_pattern

        def body(x, p_g):
            for j, kind in enumerate(pat):
                if kind == "rec":
                    x, _ = _rec_block_full(p_g[f"b{j}"], cfg, x)
                else:
                    x, _ = _attn_block_full(p_g[f"b{j}"], cfg, x, positions)
            return x, None
        body_fn = jax.checkpoint(body) if remat else body
        x, _ = jax.lax.scan(body_fn, x, params["groups"])
        _, tail = _hybrid_layout(cfg)
        for p_t, kind in zip(params["tail"], tail):
            if kind == "rec":
                x, _ = _rec_block_full(p_t, cfg, x)
            else:
                x, _ = _attn_block_full(p_t, cfg, x, positions)
        aux = 0.0
    else:
        raise ValueError(cfg.family)

    return _head(params, cfg, x), aux


def loss_fn(params, cfg: ModelConfig, batch, remat: bool = True):
    logits, aux = forward(params, cfg, batch, remat=remat)
    tokens = batch["tokens"]
    if cfg.family == "vlm":
        # predictions over the text region only
        p = batch["patches"].shape[1]
        logits = logits[:, p:]
    labels = tokens[:, 1:]
    lg = logits[:, :-1]
    mask = jnp.ones(labels.shape, jnp.float32)
    ce = emb.cross_entropy(lg, labels, mask)
    coef = cfg.moe.aux_loss_coef if cfg.moe is not None else 0.0
    return ce + coef * aux


# ---------------------------------------------------------------------------
# Serving: cache init / prefill / decode
# ---------------------------------------------------------------------------

def _attn_block_prefill(p, cfg: ModelConfig, x, positions, max_len,
                        dtype=jnp.bfloat16):
    ring = (cfg.attention.window is not None
            and max_len > cfg.attention.window)
    h = layers.apply_norm(p["ln1"], x, cfg.norm)
    h = constrain(h, "batch", None, None)            # SP all-gather
    if cfg.attention.kind == "mla":
        a, (c_kv, k_rope) = mla.mla_full(p["mla"], cfg.attention, h,
                                         positions, cfg.d_model,
                                         return_latent=True)
        entry = mla.cache_from_latent(cfg.attention, c_kv, k_rope, max_len,
                                      dtype)
    else:
        a, (k, v) = layers.attention_full(p["attn"], cfg.attention, h,
                                          positions, cfg.d_model,
                                          return_kv=True)
        entry = layers.cache_from_kv(cfg.attention, k, v, max_len, dtype,
                                     ring=ring)
    x = x + constrain(a, "batch", "model", None)     # SP reduce-scatter
    h = layers.apply_norm(p["ln2"], x, cfg.norm)
    if cfg.moe is not None:
        y, _ = moe.apply_moe(p["moe"], cfg.moe, h)
        if cfg.moe.dense_residual_ff:
            hg = constrain(h, "batch", None, None)
            y = y + layers.apply_mlp(p["res_mlp"], hg, cfg.act)
    else:
        hg = constrain(h, "batch", None, None)
        y = layers.apply_mlp(p["mlp"], hg, cfg.act)
    return x + constrain(y, "batch", "model", None), entry


def prefill(params, cfg: ModelConfig, batch: Dict[str, jax.Array],
            max_len: int, dtype=jnp.bfloat16, remat: bool = True):
    """Run the prompt through the model, building the decode cache.

    Returns (last-position logits (B, Vpad) f32, cache pytree).
    """
    x = _embed_input(params, cfg, batch)
    s = x.shape[1]
    positions = jnp.arange(s)

    if cfg.family in ("decoder", "vlm"):
        def body(x, p_l):
            x, entry = _attn_block_prefill(p_l, cfg, x, positions, max_len,
                                           dtype)
            return x, entry
        body_fn = jax.checkpoint(body) if remat else body
        x, entries = jax.lax.scan(body_fn, x, params["layers"])
        cache = {"layers": entries}
    elif cfg.family == "ssm":
        def body(x, p_l):
            x, state = _rwkv_block_full(p_l, cfg, x, chunked=True)
            return x, state
        body_fn = jax.checkpoint(body) if remat else body
        x, states = jax.lax.scan(body_fn, x, params["layers"])
        cache = {"layers": states}
    elif cfg.family == "hybrid":
        pat = cfg.rglru.block_pattern

        def body(x, p_g):
            entries = {}
            for j, kind in enumerate(pat):
                if kind == "rec":
                    h = layers.apply_norm(p_g[f"b{j}"]["ln1"], x, cfg.norm)
                    a, st = rglru.rec_full(p_g[f"b{j}"]["rec"], cfg.rglru, h)
                    x = x + a
                    h = layers.apply_norm(p_g[f"b{j}"]["ln2"], x, cfg.norm)
                    x = x + layers.apply_mlp(p_g[f"b{j}"]["mlp"], h, cfg.act)
                    entries[f"b{j}"] = st
                else:
                    x, entries[f"b{j}"] = _attn_block_prefill(
                        p_g[f"b{j}"], cfg, x, positions, max_len, dtype)
            return x, entries
        body_fn = jax.checkpoint(body) if remat else body
        x, group_entries = jax.lax.scan(body_fn, x, params["groups"])
        _, tail = _hybrid_layout(cfg)
        tail_entries = []
        for p_t, kind in zip(params["tail"], tail):
            if kind == "rec":
                h = layers.apply_norm(p_t["ln1"], x, cfg.norm)
                a, st = rglru.rec_full(p_t["rec"], cfg.rglru, h)
                x = x + a
                h = layers.apply_norm(p_t["ln2"], x, cfg.norm)
                x = x + layers.apply_mlp(p_t["mlp"], h, cfg.act)
                tail_entries.append(st)
            else:
                x, entry = _attn_block_prefill(p_t, cfg, x, positions,
                                               max_len, dtype)
                tail_entries.append(entry)
        cache = {"groups": group_entries, "tail": tail_entries}
    else:
        raise ValueError(cfg.family)

    logits = _head(params, cfg, x[:, -1:])
    return logits[:, 0], cache

def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16):
    """Stacked per-layer cache pytree sized for `max_len` positions."""
    ring = cfg.attention.window is not None and max_len > cfg.attention.window

    def one_attn():
        if cfg.attention.kind == "mla":
            return mla.init_mla_cache(cfg.attention, batch, max_len, dtype)
        return layers.init_kv_cache(cfg.attention, cfg.d_model, batch,
                                    max_len, dtype, ring=ring)

    def stack(trees):
        return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *trees)

    if cfg.family in ("decoder", "vlm"):
        return {"layers": stack([one_attn() for _ in range(cfg.n_layers)])}
    if cfg.family == "ssm":
        one = lambda: {"tm": rwkv6.init_tm_state(cfg.rwkv, cfg.d_model,
                                                 batch, dtype),
                       "cm": rwkv6.init_cm_state(cfg.d_model, batch, dtype)}
        return {"layers": stack([one() for _ in range(cfg.n_layers)])}
    if cfg.family == "hybrid":
        n_groups, tail = _hybrid_layout(cfg)

        def one_group():
            g = {}
            for j, kind in enumerate(cfg.rglru.block_pattern):
                g[f"b{j}"] = (rglru.init_rec_state(cfg.rglru, cfg.d_model,
                                                   batch, dtype)
                              if kind == "rec" else one_attn())
            return g
        return {"groups": stack([one_group() for _ in range(n_groups)]),
                "tail": [(rglru.init_rec_state(cfg.rglru, cfg.d_model,
                                               batch, dtype)
                          if kind == "rec" else one_attn())
                         for kind in tail]}
    raise ValueError(cfg.family)


def _attn_block_decode(p, cfg: ModelConfig, x, pos, cache):
    h = layers.apply_norm(p["ln1"], x, cfg.norm)
    if cfg.attention.kind == "mla":
        a, cache = mla.mla_decode(p["mla"], cfg.attention, h, pos, cache,
                                  cfg.d_model)
    else:
        a, cache = layers.attention_decode(p["attn"], cfg.attention, h, pos,
                                           cache, cfg.d_model)
    x = x + a
    h = layers.apply_norm(p["ln2"], x, cfg.norm)
    if cfg.moe is not None:
        y, _ = moe.apply_moe(p["moe"], cfg.moe, h)
        if cfg.moe.dense_residual_ff:
            y = y + layers.apply_mlp(p["res_mlp"], h, cfg.act)
    else:
        y = layers.apply_mlp(p["mlp"], h, cfg.act)
    return x + y, cache


def _rwkv_block_decode(p, cfg: ModelConfig, x, state):
    h = layers.apply_norm(p["ln1"], x, cfg.norm)
    a, tm = rwkv6.time_mix_full(p["tm"], cfg.rwkv, h, state["tm"])
    x = x + a
    h = layers.apply_norm(p["ln2"], x, cfg.norm)
    y, cm = rwkv6.channel_mix_full(p["cm"], h, state["cm"])
    return x + y, {"tm": tm, "cm": cm}


def _rec_block_decode(p, cfg: ModelConfig, x, state):
    h = layers.apply_norm(p["ln1"], x, cfg.norm)
    a, state = rglru.rec_step(p["rec"], cfg.rglru, h, state)
    x = x + a
    h = layers.apply_norm(p["ln2"], x, cfg.norm)
    return x + layers.apply_mlp(p["mlp"], h, cfg.act), state


def decode_step(params, cfg: ModelConfig, cache, tokens: jax.Array,
                pos: jax.Array):
    """One decode step. tokens: (B,) int32; pos: scalar int32 (next slot).

    Returns (logits (B, Vpad) f32, new cache).
    """
    x = emb.embed_tokens(params["embed"], tokens[:, None])
    x = constrain(x, "batch", None, None)

    if cfg.family in ("decoder", "vlm"):
        def body(x, xs):
            p_l, c_l = xs
            x, c_new = _attn_block_decode(p_l, cfg, x, pos, c_l)
            return x, c_new
        x, new_layers = jax.lax.scan(body, x,
                                     (params["layers"], cache["layers"]))
        new_cache = {"layers": new_layers}
    elif cfg.family == "ssm":
        def body(x, xs):
            p_l, c_l = xs
            x, c_new = _rwkv_block_decode(p_l, cfg, x, c_l)
            return x, c_new
        x, new_layers = jax.lax.scan(body, x,
                                     (params["layers"], cache["layers"]))
        new_cache = {"layers": new_layers}
    elif cfg.family == "hybrid":
        pat = cfg.rglru.block_pattern

        def body(x, xs):
            p_g, c_g = xs
            c_new = {}
            for j, kind in enumerate(pat):
                if kind == "rec":
                    x, c_new[f"b{j}"] = _rec_block_decode(
                        p_g[f"b{j}"], cfg, x, c_g[f"b{j}"])
                else:
                    x, c_new[f"b{j}"] = _attn_block_decode(
                        p_g[f"b{j}"], cfg, x, pos, c_g[f"b{j}"])
            return x, c_new
        x, new_groups = jax.lax.scan(body, x,
                                     (params["groups"], cache["groups"]))
        _, tail = _hybrid_layout(cfg)
        new_tail = []
        for p_t, c_t, kind in zip(params["tail"], cache["tail"], tail):
            if kind == "rec":
                x, c_new = _rec_block_decode(p_t, cfg, x, c_t)
            else:
                x, c_new = _attn_block_decode(p_t, cfg, x, pos, c_t)
            new_tail.append(c_new)
        new_cache = {"groups": new_groups, "tail": new_tail}
    else:
        raise ValueError(cfg.family)

    logits = _head(params, cfg, x)
    return logits[:, 0], new_cache
