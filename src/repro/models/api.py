"""Unified model API over the architecture zoo.

Everything the launcher, dry-run, tests and benchmarks need:

    init(key, cfg)                  -> (params, logical spec tree)
    loss(params, cfg, batch)        -> scalar
    make_train_step(cfg, ...)       -> (optimizer, step fn)
    prefill / decode_step / init_cache
    input_specs(cfg, shape, mesh)   -> ShapeDtypeStruct batch stand-ins
    train_state_specs(...)          -> shardings for params + opt state
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.distributed.sharding import (active_mesh, batch_axes, resolve,
                                        use_mesh)
from repro.models import encdec, transformer
from repro.optim import optimizers as optim_lib


def _impl(cfg: ModelConfig):
    return encdec if cfg.is_encdec else transformer


def init(key: jax.Array, cfg: ModelConfig):
    return _impl(cfg).init(key, cfg)


def forward(params, cfg: ModelConfig, batch):
    return _impl(cfg).forward(params, cfg, batch)


def loss(params, cfg: ModelConfig, batch, remat: bool = True):
    return _impl(cfg).loss_fn(params, cfg, batch, remat=remat)


def prefill(params, cfg: ModelConfig, batch, max_len: int):
    return _impl(cfg).prefill(params, cfg, batch, max_len)


def decode_step(params, cfg: ModelConfig, cache, tokens, pos):
    return _impl(cfg).decode_step(params, cfg, cache, tokens, pos)


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16):
    return _impl(cfg).init_cache(cfg, batch, max_len, dtype)


def default_optimizer(cfg: ModelConfig) -> Tuple[str, Any]:
    """Adafactor for the >100B MoE archs (state must stay O(P/d)), else
    AdamW; both wrapped layerwise so update temporaries are bounded to one
    layer of the stacked params. Returns (name, optimizer)."""
    if cfg.moe is not None and cfg.d_model >= 4096:
        return "adafactor", optim_lib.layerwise(optim_lib.adafactor(1e-4))
    return "adamw", optim_lib.layerwise(optim_lib.adamw(3e-4))


def make_train_step(cfg: ModelConfig, optimizer=None,
                    mesh: Optional[jax.sharding.Mesh] = None,
                    grad_clip: float = 1.0, microbatches: int = 1):
    """Returns (opt_name, optimizer, train_step).

    train_step(params, opt_state, batch) -> (params, opt_state, metrics)

    microbatches > 1 = gradient accumulation: the global batch is split and
    scanned, so activation memory scales 1/n while the (FSDP-sharded) grad
    accumulator costs one param-sized buffer — the standard fit-the-big-MoE
    lever (kimi train_4k cannot hold a full 1M-token step's activations).
    """
    if optimizer is None:
        opt_name, opt = default_optimizer(cfg)
    else:
        opt_name, opt = optimizer

    def train_step(params, opt_state, batch):
        with use_mesh(mesh):
            if microbatches == 1:
                loss_val, grads = jax.value_and_grad(loss)(params, cfg,
                                                           batch)
            else:
                mb_batch = jax.tree_util.tree_map(
                    lambda x: x.reshape((microbatches,
                                         x.shape[0] // microbatches)
                                        + x.shape[1:]), batch)

                def body(acc, mb):
                    l, g = jax.value_and_grad(loss)(params, cfg, mb)
                    acc = jax.tree_util.tree_map(jnp.add, acc, g)
                    return acc, l

                zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
                grads, losses = jax.lax.scan(body, zeros, mb_batch)
                grads = jax.tree_util.tree_map(
                    lambda g: g / microbatches, grads)
                loss_val = losses.mean()
            grads, gnorm = optim_lib.clip_by_global_norm(grads, grad_clip)
            new_params, new_state = opt.update(grads, opt_state, params)
        return new_params, new_state, {"loss": loss_val, "grad_norm": gnorm}

    return opt_name, opt, train_step


def make_prefill_step(cfg: ModelConfig, max_len: int,
                      mesh: Optional[jax.sharding.Mesh] = None):
    def prefill_step(params, batch):
        with use_mesh(mesh):
            return prefill(params, cfg, batch, max_len)
    return prefill_step


def make_decode_fn(cfg: ModelConfig,
                   mesh: Optional[jax.sharding.Mesh] = None):
    def serve_step(params, cache, batch):
        with use_mesh(mesh):
            return decode_step(params, cfg, cache, batch["tokens"],
                               batch["pos"])
    return serve_step


# ---------------------------------------------------------------------------
# Dry-run stand-ins
# ---------------------------------------------------------------------------

def _sds(shape, dtype, mesh, logical):
    sharding = None
    if mesh is not None:
        sharding = jax.sharding.NamedSharding(mesh, resolve(mesh, logical))
    return jax.ShapeDtypeStruct(shape, dtype, sharding=sharding)


def input_specs(cfg: ModelConfig, shape: ShapeConfig,
                mesh=None) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input of a cell.

    Shapes follow the assignment: LM shapes are seq_len x global_batch;
    decode shapes are one new token against a seq_len cache. Modality
    frontends are stubs: precomputed patch/frame embeddings.
    """
    b, s = shape.global_batch, shape.seq_len
    small_batch = mesh is not None and b < _n_batch_shards(mesh)
    bspec = (None,) if small_batch else ("batch",)

    if shape.kind == "decode":
        return {"tokens": _sds((b,), jnp.int32, mesh, bspec),
                "pos": _sds((), jnp.int32, mesh, ())}

    if cfg.is_encdec:
        return {
            "frames": _sds((b, cfg.enc_memory_len, cfg.d_model),
                           jnp.bfloat16, mesh, bspec + (None, None)),
            "tokens": _sds((b, s), jnp.int32, mesh, bspec + (None,)),
        }
    if cfg.family == "vlm":
        p = cfg.n_frontend_tokens
        return {
            "patches": _sds((b, p, cfg.d_model), jnp.bfloat16, mesh,
                            bspec + (None, None)),
            "tokens": _sds((b, s - p), jnp.int32, mesh, bspec + (None,)),
        }
    return {"tokens": _sds((b, s), jnp.int32, mesh, bspec + (None,))}


def _n_batch_shards(mesh) -> int:
    import numpy as np
    ba = batch_axes(mesh)
    return int(np.prod([mesh.shape[a] for a in ba])) if ba else 1


def _attach(mesh, spec_tree, shape_tree):
    """Attach shardings (from logical specs) to a ShapeDtypeStruct tree."""
    def leaf(spec, sds):
        return _sds(sds.shape, sds.dtype, mesh, spec)
    return jax.tree_util.tree_map(
        leaf, spec_tree, shape_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            v is None or isinstance(v, str) for v in x))


def train_state_specs(cfg: ModelConfig, opt_name: str, opt, mesh):
    """(params SDS tree, opt-state SDS tree, logical spec trees).

    Built via eval_shape — no parameter allocation (dry-run safe).
    """
    cell = {}

    def _init_values(k):
        vals, specs = init(k, cfg)
        cell["specs"] = specs          # static side-channel (no tracing)
        return vals

    params_shapes = jax.eval_shape(_init_values,
                                   jax.ShapeDtypeStruct((2,), jnp.uint32))
    specs = cell["specs"]
    params_sds = _attach(mesh, specs, params_shapes)

    shapes_tree = jax.tree_util.tree_map(lambda x: x.shape, params_shapes)
    opt_specs = optim_lib.state_logical_specs(opt_name, specs, shapes_tree)
    opt_shapes = jax.eval_shape(opt.init, params_shapes)
    opt_sds = _attach(mesh, opt_specs, opt_shapes)
    return params_sds, opt_sds, specs


def cache_specs(cfg: ModelConfig, batch: int, max_len: int, mesh):
    """Decode-cache ShapeDtypeStructs with batch/model sharding attached."""
    shapes = jax.eval_shape(
        functools.partial(init_cache, cfg, batch, max_len))

    small_batch = mesh is not None and batch < _n_batch_shards(mesh)
    tp = mesh.shape["model"] if (mesh is not None
                                 and "model" in mesh.axis_names) else 1

    def leaf(sds):
        # Dim order (L?, B, S, ...). Shard batch over the DP axes and the
        # cache *position* dim over 'model' (split-KV decode: each model
        # shard scores its cache slice, psum combines — without this a
        # 32k x 128 cache replicates 16x and decode becomes all-gather
        # bound; measured on qwen decode_32k: 139 GB collective/token).
        logical = [None] * len(sds.shape)
        for i, d in enumerate(sds.shape):
            if d == batch and i <= 1 and not small_batch:
                logical[i] = "batch"
                break
        for i, d in enumerate(sds.shape):
            if d == max_len and logical[i] is None and d % max(tp, 1) == 0:
                logical[i] = "model"
                break
        return _sds(sds.shape, sds.dtype, mesh, tuple(logical))

    return jax.tree_util.tree_map(leaf, shapes)
