"""Token embedding + LM head over a row-sharded vocab table.

This is the Centaur sparse engine applied to LMs: the vocab table (up to
256 k rows here) is the "embedding table in CPU DIMMs"; rows are sharded
across the 'model' axis and each chip gathers the rows it owns (masked
local gather -> psum), so only (tokens x d_model) activations ever cross
chips — never table rows. The LM head needs no gather at all: the matmul
against the row-sharded table contracts d_model locally and leaves logits
vocab-sharded.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.distributed.sharding import active_mesh, batch_axes, constrain
from repro.models.params import Builder

VOCAB_PAD = 128


def padded_vocab(v: int) -> int:
    return ((v + VOCAB_PAD - 1) // VOCAB_PAD) * VOCAB_PAD


def init_table(b: Builder, vocab: int, d: int):
    vpad = padded_vocab(vocab)
    p = b.normal((vpad, d), ("model", None), scale=0.02)
    # zero the padding rows so tied logits for pad ids stay inert
    p.value = p.value.at[vocab:].set(0)
    return p


def _local_gather(table_shard, tokens, axis: str):
    """Masked local gather + psum — EB-Streamer over the pod HBM pool."""
    my = jax.lax.axis_index(axis)
    vloc = table_shard.shape[0]
    lo = my * vloc
    rel = tokens - lo
    ok = (rel >= 0) & (rel < vloc)
    rows = jnp.take(table_shard, jnp.where(ok, rel, 0), axis=0)
    rows = jnp.where(ok[..., None], rows, 0)
    return jax.lax.psum(rows, axis)


def embed_tokens(table: jax.Array, tokens: jax.Array) -> jax.Array:
    """tokens (B, S) -> (B, S, D)."""
    mesh = active_mesh()
    if mesh is not None and "model" in mesh.axis_names \
            and mesh.shape["model"] > 1:
        ba = batch_axes(mesh)
        n_batch_shards = int(np.prod([mesh.shape[a] for a in ba])) if ba else 1
        if tokens.shape[0] % n_batch_shards == 0:
            bspec = ba if len(ba) > 1 else (ba[0] if ba else None)
            fn = compat.shard_map(
                lambda t, tok: _local_gather(t, tok, "model"),
                mesh=mesh,
                in_specs=(P("model", None), P(bspec, None)),
                out_specs=P(bspec, None, None))
            return fn(table, tokens)
    # Fallback (no mesh / tiny batch): direct gather; GSPMD partitions it.
    return jnp.take(table, tokens, axis=0)


def lm_head(x: jax.Array, table: jax.Array, vocab: int) -> jax.Array:
    """x (B, S, D) @ table.T -> vocab-sharded logits with pads masked."""
    logits = jnp.einsum("bsd,vd->bsv", x, table,
                        preferred_element_type=jnp.float32)
    logits = constrain(logits, "batch", None, "model")
    vpad = table.shape[0]
    if vpad != vocab:
        mask = (jnp.arange(vpad) < vocab)
        logits = jnp.where(mask, logits, -1e30)
    return logits


def init_unembed(b: Builder, vocab: int, d: int):
    vpad = padded_vocab(vocab)
    return b.normal((d, vpad), (None, "model"), scale=0.02)


def lm_head_untied(x: jax.Array, w: jax.Array, vocab: int) -> jax.Array:
    logits = jnp.einsum("bsd,dv->bsv", x, w,
                        preferred_element_type=jnp.float32)
    logits = constrain(logits, "batch", None, "model")
    vpad = w.shape[1]
    if vpad != vocab:
        mask = (jnp.arange(vpad) < vocab)
        logits = jnp.where(mask, logits, -1e30)
    return logits


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  mask: jax.Array) -> jax.Array:
    """Mean masked next-token CE. logits (B,S,V) f32, labels (B,S) int.

    Written gather-free along the vocab axis: a take_along_axis over the
    sharded V dim makes GSPMD all-gather the logits (12+ GB/device at 4k x
    49k); the one-hot masked sum below reduces over the sharded dim locally
    and only all-reduces (B, S) scalars.
    """
    logits = constrain(logits, "batch", None, "model")
    m = jax.lax.stop_gradient(logits.max(axis=-1, keepdims=True))
    shifted = logits - m
    logz = m[..., 0] + jnp.log(jnp.sum(jnp.exp(shifted), axis=-1))
    vocab_iota = jnp.arange(logits.shape[-1])
    gold = jnp.sum(jnp.where(vocab_iota == labels[..., None], logits, 0.0),
                   axis=-1)
    nll = (logz - gold) * mask
    return nll.sum() / jnp.maximum(mask.sum(), 1.0)
