"""Parameter trees that carry their sharding.

Init functions build nested dicts whose leaves are ``Param(value, spec)``;
``split`` separates them into a plain value tree (fed to apply fns / the
optimizer) and a logical-spec tree (fed to the dry-run in_shardings and the
checkpoint resharder). Only dicts/lists are used as containers so the spec
tree is unambiguous.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp


class Param:
    __slots__ = ("value", "spec")

    def __init__(self, value, spec: Tuple[Optional[str], ...]):
        assert len(spec) == value.ndim, (spec, value.shape)
        self.value = value
        self.spec = spec


def split(tree) -> Tuple[Any, Any]:
    """Param-leaf tree -> (value tree, logical spec tree)."""
    if isinstance(tree, Param):
        return tree.value, tree.spec
    if isinstance(tree, dict):
        vals, specs = {}, {}
        for k, v in tree.items():
            vals[k], specs[k] = split(v)
        return vals, specs
    if isinstance(tree, (list, tuple)):
        pairs = [split(v) for v in tree]
        ctor = type(tree)
        return ctor(p[0] for p in pairs), ctor(p[1] for p in pairs)
    raise TypeError(f"unexpected node {type(tree)}")


class Builder:
    """Stateful PRNG-splitting param factory."""

    def __init__(self, key: jax.Array, dtype=jnp.bfloat16):
        self.key = key
        self.dtype = dtype

    def _next(self) -> jax.Array:
        self.key, k = jax.random.split(self.key)
        return k

    def normal(self, shape, spec, scale: Optional[float] = None,
               dtype=None) -> Param:
        if scale is None:
            fan_in = shape[0] if len(shape) > 1 else shape[-1]
            scale = fan_in ** -0.5
        v = scale * jax.random.normal(self._next(), shape, jnp.float32)
        return Param(v.astype(dtype or self.dtype), spec)

    def zeros(self, shape, spec, dtype=None) -> Param:
        return Param(jnp.zeros(shape, dtype or self.dtype), spec)

    def ones(self, shape, spec, dtype=None) -> Param:
        return Param(jnp.ones(shape, dtype or self.dtype), spec)

    def const(self, value, spec, dtype=None) -> Param:
        return Param(jnp.asarray(value, dtype or self.dtype), spec)


def stack_layers(trees):
    """Stack per-layer Param trees along a new leading axis for lax.scan."""
    def stack_leaf(*leaves):
        vals = jnp.stack([l.value for l in leaves])
        return Param(vals, (None,) + leaves[0].spec)
    return jax.tree_util.tree_map(
        stack_leaf, *trees, is_leaf=lambda x: isinstance(x, Param))
