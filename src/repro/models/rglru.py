"""RG-LRU recurrent block (RecurrentGemma / Griffin).

Recurrence: h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)
with a_t = exp(-c * softplus(Lambda) * r_t), r/i input-dependent gates.

The diagonal linear recurrence is computed with ``jax.lax.associative_scan``
(log-depth on TPU) instead of a sequential loop — the TPU-native counterpart
of the paper's streaming reduction: the state combine (a2*a1, a2*b1+b2) is
an on-the-fly reduction over the time axis.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import RGLRUConfig
from repro.distributed.sharding import constrain
from repro.models.params import Builder

_C = 8.0


def init_rec(b: Builder, rcfg: RGLRUConfig, d: int):
    w = rcfg.lru_width or d
    return {
        "wx": b.normal((d, w), (None, "model")),
        "wgate": b.normal((d, w), (None, "model")),
        "conv_w": b.normal((rcfg.conv_width, w), (None, "model"), scale=0.1),
        "conv_b": b.zeros((w,), ("model",)),
        "wa": b.normal((w, w), (None, "model"), scale=0.01),
        "ba": b.const(jnp.zeros((w,)) - 1.0, ("model",)),
        "wi": b.normal((w, w), (None, "model"), scale=0.01),
        "bi": b.zeros((w,), ("model",)),
        # Lambda init so a ~ U[0.9, 0.999] at r=1 (Griffin appendix)
        "lam": b.const(jnp.log(jnp.expm1(-jnp.log(
            jnp.linspace(0.9, 0.999, w)) / _C)), (None,), dtype=jnp.float32),
        "wo": b.normal((w, d), ("model", None)),
    }


def _gates(p, xc):
    r = jax.nn.sigmoid(xc @ p["wa"] + p["ba"])
    i = jax.nn.sigmoid(xc @ p["wi"] + p["bi"])
    log_a = -_C * jax.nn.softplus(p["lam"]) * r.astype(jnp.float32)
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    return a, beta * (i * xc).astype(jnp.float32)


def _conv_full(p, xb, conv_w: int, state=None):
    """Causal depthwise conv over S. state: (B, conv_w-1, W) history."""
    if state is None:
        pad = jnp.zeros(xb.shape[:1] + (conv_w - 1,) + xb.shape[2:], xb.dtype)
    else:
        pad = state.astype(xb.dtype)
    xp = jnp.concatenate([pad, xb], axis=1)
    out = sum(xp[:, i:i + xb.shape[1]] * p["conv_w"][i]
              for i in range(conv_w))
    new_state = xp[:, -(conv_w - 1):]
    return out + p["conv_b"], new_state


def rec_full(p, rcfg: RGLRUConfig, x: jax.Array,
             h0=None) -> Tuple[jax.Array, dict]:
    """x: (B,S,D) -> (y (B,S,D), state {'h','conv'}). Full-sequence scan."""
    xb = x @ p["wx"]
    xb = constrain(xb, "batch", None, "model")
    gate = jax.nn.gelu(x @ p["wgate"])
    xc, conv_state = _conv_full(p, xb, rcfg.conv_width)
    a, b_term = _gates(p, xc)
    if h0 is not None:
        # fold the carried state into step 0: b_0 += a_0 * h0
        b_term = b_term.at[:, 0].add(a[:, 0] * h0.astype(jnp.float32))

    def combine(lhs, rhs):
        a1, b1 = lhs
        a2, b2 = rhs
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b_term), axis=1)
    y = (h.astype(x.dtype) * gate) @ p["wo"]
    return y, {"h": h[:, -1], "conv": conv_state}


def init_rec_state(rcfg: RGLRUConfig, d: int, batch: int,
                   dtype=jnp.float32):
    w = rcfg.lru_width or d
    return {"h": jnp.zeros((batch, w), jnp.float32),
            "conv": jnp.zeros((batch, rcfg.conv_width - 1, w), dtype)}


def rec_step(p, rcfg: RGLRUConfig, x: jax.Array, state):
    """One-token step. x: (B,1,D)."""
    xb = x @ p["wx"]
    gate = jax.nn.gelu(x @ p["wgate"])
    xc, conv_state = _conv_full(p, xb, rcfg.conv_width, state["conv"])
    a, b_term = _gates(p, xc)
    h = a[:, 0] * state["h"] + b_term[:, 0]
    y = (h[:, None].astype(x.dtype) * gate) @ p["wo"]
    return y, {"h": h, "conv": conv_state}
