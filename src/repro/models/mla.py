"""Multi-head latent attention (MiniCPM3 / DeepSeek-V2 family).

Queries go through a low-rank bottleneck; keys/values are reconstructed from
a compressed latent ``c_kv`` (kv_lora_rank) plus one shared RoPE key head.
The decode cache stores only ``(c_kv, k_rope)`` — (256+32) floats/token here
vs n_heads*(nope+v) = 5120 for an equivalent MHA cache.

Two decode paths, numerically identical (tested):
  * naive   — reconstruct K/V for the whole cache each step (baseline);
  * absorbed — fold W_uk into the query and W_uv into the output so scores
    and values are computed directly in the latent space; per-step cost drops
    from O(S * r * H * (nope+v)) to O(S * H * (r + rope)). This is the §Perf
    optimization for decode cells.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import AttentionConfig
from repro.distributed.sharding import constrain
from repro.models import layers
from repro.models.params import Builder


def init_mla(b: Builder, acfg: AttentionConfig, d: int):
    m = acfg.mla
    h = acfg.n_heads
    qk = m.qk_nope_head_dim + m.qk_rope_head_dim
    return {
        "wq_a": b.normal((d, m.q_lora_rank), (None, None)),
        "q_norm": layers.init_norm(b, m.q_lora_rank, "rmsnorm"),
        "wq_b": b.normal((m.q_lora_rank, h * qk), (None, "model")),
        "wkv_a": b.normal((d, m.kv_lora_rank + m.qk_rope_head_dim),
                          (None, None)),
        "kv_norm": layers.init_norm(b, m.kv_lora_rank, "rmsnorm"),
        "wk_b": b.normal((m.kv_lora_rank, h * m.qk_nope_head_dim),
                         (None, "model")),
        "wv_b": b.normal((m.kv_lora_rank, h * m.v_head_dim),
                         (None, "model")),
        "wo": b.normal((h * m.v_head_dim, d), ("model", None)),
    }


def _latent(p, acfg: AttentionConfig, x: jax.Array):
    """x (B,S,D) -> (c_kv normed (B,S,r), k_rope (B,S,1,rope))."""
    m = acfg.mla
    kv_a = x @ p["wkv_a"]
    c_kv, k_rope = kv_a[..., :m.kv_lora_rank], kv_a[..., m.kv_lora_rank:]
    c_kv = layers.apply_norm(p["kv_norm"], c_kv, "rmsnorm")
    return c_kv, k_rope[..., None, :]


def _queries(p, acfg: AttentionConfig, x: jax.Array, positions):
    m = acfg.mla
    h = acfg.n_heads
    b_, s, _ = x.shape
    q = layers.apply_norm(p["q_norm"], x @ p["wq_a"], "rmsnorm") @ p["wq_b"]
    q = q.reshape(b_, s, h, m.qk_nope_head_dim + m.qk_rope_head_dim)
    q_nope, q_rope = (q[..., :m.qk_nope_head_dim],
                      q[..., m.qk_nope_head_dim:])
    q_rope = layers.rope(q_rope, positions, acfg.rope_theta)
    return q_nope, q_rope


def mla_full(p, acfg: AttentionConfig, x: jax.Array,
             positions: jax.Array, d: int, return_latent: bool = False):
    """Train / prefill path: reconstruct K/V, run standard (chunked) SDPA."""
    m = acfg.mla
    h = acfg.n_heads
    b_, s, _ = x.shape
    q_nope, q_rope = _queries(p, acfg, x, positions)
    c_kv, k_rope = _latent(p, acfg, x)
    k_rope = layers.rope(k_rope, positions, acfg.rope_theta)

    k_nope = (c_kv @ p["wk_b"]).reshape(b_, s, h, m.qk_nope_head_dim)
    v = (c_kv @ p["wv_b"]).reshape(b_, s, h, m.v_head_dim)
    q = jnp.concatenate([q_nope, q_rope], -1)
    k = jnp.concatenate([k_nope,
                         jnp.broadcast_to(k_rope, k_rope.shape[:2] + (h,)
                                          + k_rope.shape[3:])], -1)
    q = layers.head_constrain(q, h)
    k = layers.head_constrain(k, h)
    qg = q[:, :, :, None, :]                    # (B,S,H,1,qk) — MHA: G=1
    if s >= layers.CHUNKED_THRESHOLD:
        out = layers._sdpa_chunked(qg, k, v, positions, positions,
                                   acfg.causal, acfg.window,
                                   layers.pick_chunk(s, layers.Q_CHUNK),
                                   layers.pick_chunk(s, layers.KV_CHUNK))
    else:
        out = layers._sdpa_direct(qg, k, v, positions, positions,
                                  acfg.causal, acfg.window)
    out = out.reshape(b_, s, h * m.v_head_dim).astype(x.dtype)
    out = constrain(out, "batch", None, "model")
    out = out @ p["wo"]
    if return_latent:
        return out, (c_kv, k_rope[:, :, 0])
    return out


def cache_from_latent(acfg: AttentionConfig, c_kv: jax.Array,
                      k_rope: jax.Array, max_len: int, dtype=jnp.bfloat16):
    """Build a decode cache from prefill latents. c_kv: (B,S,r)."""
    b_, s, _ = c_kv.shape
    cache = init_mla_cache(acfg, b_, max_len, dtype)
    keep = min(s, max_len)
    positions = jnp.arange(s - keep, s)
    slots = jnp.mod(positions, max_len)
    cache["c_kv"] = cache["c_kv"].at[:, slots].set(
        c_kv[:, -keep:].astype(dtype))
    cache["k_rope"] = cache["k_rope"].at[:, slots].set(
        k_rope[:, -keep:].astype(dtype))
    cache["slot_pos"] = cache["slot_pos"].at[slots].set(positions)
    return cache


def init_mla_cache(acfg: AttentionConfig, batch: int, max_len: int,
                   dtype=jnp.bfloat16):
    m = acfg.mla
    return {
        "c_kv": jnp.zeros((batch, max_len, m.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, max_len, m.qk_rope_head_dim), dtype),
        "slot_pos": jnp.full((max_len,), -1, jnp.int32),
    }


def mla_decode(p, acfg: AttentionConfig, x: jax.Array, pos: jax.Array,
               cache, d: int, absorbed: bool = True):
    """One-token MLA step against the compressed cache."""
    m = acfg.mla
    h = acfg.n_heads
    b_ = x.shape[0]
    posb = jnp.full((b_, 1), pos)
    q_nope, q_rope = _queries(p, acfg, x, posb)    # (B,1,H,·)
    c_new, k_rope_new = _latent(p, acfg, x)
    k_rope_new = layers.rope(k_rope_new, posb, acfg.rope_theta)

    size = cache["c_kv"].shape[1]
    slot = jnp.mod(pos, size)
    c_kv = jax.lax.dynamic_update_slice(
        cache["c_kv"], c_new.astype(cache["c_kv"].dtype), (0, slot, 0))
    k_rope = jax.lax.dynamic_update_slice(
        cache["k_rope"], k_rope_new[:, :, 0].astype(cache["k_rope"].dtype),
        (0, slot, 0))
    slot_pos = cache["slot_pos"].at[slot].set(pos)
    keep = (slot_pos >= 0) & (slot_pos <= pos)

    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    if absorbed:
        # score_nope = q_nope^T W_uk c = (W_uk^T q_nope)^T c  — latent space
        wk = p["wk_b"].reshape(m.kv_lora_rank, h, m.qk_nope_head_dim)
        q_lat = jnp.einsum("bqhn,rhn->bqhr", q_nope, wk)       # (B,1,H,r)
        s_nope = jnp.einsum("bqhr,bcr->bhqc", q_lat.astype(jnp.float32),
                            c_kv.astype(jnp.float32))
        s_rope = jnp.einsum("bqhn,bcn->bhqc", q_rope.astype(jnp.float32),
                            k_rope.astype(jnp.float32))
        s = (s_nope + s_rope) * scale
        s = jnp.where(keep[None, None, None, :], s, -1e30)
        prob = jax.nn.softmax(s, axis=-1)
        # out = prob · V = prob · (c W_uv): contract cache first (latent)
        ctx = jnp.einsum("bhqc,bcr->bqhr", prob,
                         c_kv.astype(jnp.float32))              # (B,1,H,r)
        wv = p["wv_b"].reshape(m.kv_lora_rank, h, m.v_head_dim)
        out = jnp.einsum("bqhr,rhv->bqhv", ctx, wv)
    else:
        k_nope = (c_kv @ p["wk_b"]).reshape(b_, size, h, m.qk_nope_head_dim)
        v = (c_kv @ p["wv_b"]).reshape(b_, size, h, m.v_head_dim)
        s_nope = jnp.einsum("bqhn,bchn->bhqc", q_nope.astype(jnp.float32),
                            k_nope.astype(jnp.float32))
        s_rope = jnp.einsum("bqhn,bcn->bhqc", q_rope.astype(jnp.float32),
                            k_rope.astype(jnp.float32))
        s = (s_nope + s_rope) * scale
        s = jnp.where(keep[None, None, None, :], s, -1e30)
        prob = jax.nn.softmax(s, axis=-1)
        out = jnp.einsum("bhqc,bchv->bqhv", prob, v.astype(jnp.float32))

    out = out.reshape(b_, 1, h * m.v_head_dim).astype(x.dtype)
    out = out @ p["wo"]
    return out, {"c_kv": c_kv, "k_rope": k_rope, "slot_pos": slot_pos}
