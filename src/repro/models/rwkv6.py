"""RWKV-6 "Finch" block: data-dependent token-shift + decay linear attention.

State per head is a (head_dim x head_dim) matrix updated as
    S_t = diag(w_t) S_{t-1} + k_t^T v_t,
    out_t = r_t (S_{t-1} + diag(u) k_t^T v_t)
with w_t a *data-dependent* per-channel decay (the Finch contribution).
Attention-free: decode state is O(1) in context length, so this arch runs
the 524k long-context shape.

Baseline sequential scan over time; ``time_mix_chunked`` (same math, chunk
matmul form) is the §Perf variant for train/prefill.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import RWKVConfig
from repro.distributed.sharding import constrain
from repro.models.params import Builder

_COMPONENTS = 5   # r, k, v, w, g


def init_time_mix(b: Builder, rcfg: RWKVConfig, d: int):
    h = d // rcfg.head_dim
    ts = rcfg.token_shift_lora
    return {
        "mu_x": b.normal((d,), (None,), scale=0.1),
        "mu": b.normal((_COMPONENTS, d), (None, None), scale=0.1),
        "lora_a": b.normal((d, _COMPONENTS * ts), (None, None), scale=0.01),
        "lora_b": b.normal((_COMPONENTS, ts, d), (None, None, None),
                           scale=0.01),
        "wr": b.normal((d, d), (None, "model")),
        "wk": b.normal((d, d), (None, "model")),
        "wv": b.normal((d, d), (None, "model")),
        "wg": b.normal((d, d), (None, "model")),
        "w_base": b.const(-6.0 * jnp.ones((d,)), (None,), dtype=jnp.float32),
        "w_lora_a": b.normal((d, rcfg.decay_lora), (None, None), scale=0.01),
        "w_lora_b": b.normal((rcfg.decay_lora, d), (None, None), scale=0.01),
        "u": b.normal((h, rcfg.head_dim), ("model", None), scale=0.1),
        "ln_w": b.ones((d,), (None,), dtype=jnp.float32),
        "wo": b.normal((d, d), ("model", None)),
    }


def _shifted(x, x_prev):
    """Token shift: prepend carry (B,1,D) (zeros at seq start)."""
    return jnp.concatenate([x_prev, x[:, :-1]], axis=1)


def _mix_inputs(p, x, xs):
    """Data-dependent lerp between x and shifted x for the 5 components."""
    dx = xs - x
    xxx = x + dx * p["mu_x"]
    lora = jnp.tanh(xxx @ p["lora_a"])
    b_, s, _ = x.shape
    ts = p["lora_b"].shape[1]
    lora = lora.reshape(b_, s, _COMPONENTS, ts)
    adj = jnp.einsum("bsft,ftd->bsfd", lora, p["lora_b"])
    mixed = x[:, :, None] + dx[:, :, None] * (p["mu"] + adj)
    return [mixed[:, :, i] for i in range(_COMPONENTS)]


def _rkvwg(p, rcfg: RWKVConfig, x, xs):
    x_r, x_k, x_v, x_w, x_g = _mix_inputs(p, x, xs)
    b_, s, d = x.shape
    h, hd = d // rcfg.head_dim, rcfg.head_dim
    r = (x_r @ p["wr"]).reshape(b_, s, h, hd)
    k = (x_k @ p["wk"]).reshape(b_, s, h, hd)
    v = (x_v @ p["wv"]).reshape(b_, s, h, hd)
    g = jax.nn.silu(x_g @ p["wg"])
    w_log = p["w_base"] + jnp.tanh(x_w @ p["w_lora_a"]) @ p["w_lora_b"]
    w = jnp.exp(-jnp.exp(w_log.astype(jnp.float32))).reshape(b_, s, h, hd)
    from repro.models.layers import head_constrain
    r = head_constrain(r, h)
    k = head_constrain(k, h)
    v = head_constrain(v, h)
    return r, k, v, w, g


def _wkv_scan(r, k, v, w, u, s0):
    """Sequential WKV recurrence. r/k/v/w: (B,S,H,hd); s0: (B,H,hd,hd)."""
    def step(s_state, xs):
        r_t, k_t, v_t, w_t = xs                    # (B,H,hd)
        kv = k_t[..., :, None] * v_t[..., None, :]  # (B,H,hd,hd)
        out = jnp.einsum("bhi,bhij->bhj",
                         r_t, s_state + u[..., :, None] * kv)
        s_new = w_t[..., :, None] * s_state + kv
        return s_new, out

    xs = tuple(a.swapaxes(0, 1).astype(jnp.float32) for a in (r, k, v, w))
    s_last, outs = jax.lax.scan(jax.checkpoint(step), s0, xs)
    return outs.swapaxes(0, 1), s_last            # (B,S,H,hd), (B,H,hd,hd)


def _wkv_chunked(r, k, v, w, u, s0, chunk: int):
    """Chunk-parallel WKV: intra-chunk attention matmul + inter-chunk state.

    Identical math to _wkv_scan (tested); turns S sequential steps into
    S/chunk steps of MXU-friendly matmuls.
    """
    b_, s, h, hd = r.shape
    assert s % chunk == 0, (s, chunk)
    n = s // chunk
    rc, kc, vc, wc = (a.reshape(b_, n, chunk, h, hd)
                       .swapaxes(0, 1).astype(jnp.float32)
                      for a in (r, k, v, w))

    def chunk_step(s_state, xs):
        r_, k_, v_, w_ = xs                        # (B,c,H,hd)
        logw = jnp.log(jnp.maximum(w_, 1e-38))
        cum = jnp.cumsum(logw, axis=1)             # prod of decays up to t
        # contribution of the carried state: r_t * (prod_{<=t-1} w) * S
        decay_in = jnp.exp(cum - logw)             # prod_{j<t} w_j
        out_state = jnp.einsum("bchi,bhij->bchj", r_ * decay_in, s_state)
        # intra-chunk pairwise: sum_{j<t} r_t (prod_{j<m<t} w_m) k_j v_j.
        # The decay between j and t is channel-dependent, so fold it into
        # the operands: r~_t = r_t * exp(cum_{t-1}), k~_j = k_j * exp(-cum_j)
        # => scores[t,j] = <r~_t, k~_j> (strict lower triangle).
        r_tilde = r_ * jnp.exp(cum - logw)
        k_tilde = k_ * jnp.exp(-cum)
        scores = jnp.einsum("bchi,bdhi->bhcd", r_tilde, k_tilde)
        mask = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)
        scores = jnp.where(mask[None, None], scores, 0.0)
        out_intra = jnp.einsum("bhcd,bdhj->bchj", scores, v_)
        # current-token bonus: r_t · (diag(u) k_t^T v_t)
        out_bonus = (r_ * (u[None, None] * k_)).sum(-1, keepdims=True) * v_
        # state update to end of chunk:
        #   S' = diag(prod w) S + sum_j (prod_{j<m} w) k_j v_j
        decay_all = jnp.exp(cum[:, -1])            # (B,H,hd)
        k_fold = k_ * jnp.exp(cum[:, -1:] - cum)   # prod_{m>j} w
        s_new = decay_all[..., None] * s_state \
            + jnp.einsum("bchi,bchj->bhij", k_fold, v_)
        return s_new, out_state + out_intra + out_bonus

    s_last, outs = jax.lax.scan(jax.checkpoint(chunk_step), s0,
                                (rc, kc, vc, wc))
    return (outs.swapaxes(0, 1).reshape(b_, s, h, hd), s_last)


def time_mix_full(p, rcfg: RWKVConfig, x: jax.Array, state=None,
                  chunked: bool = False):
    """x: (B,S,D) -> (y, new_state). state: {'x_prev','S'} or None."""
    b_, s, d = x.shape
    h, hd = d // rcfg.head_dim, rcfg.head_dim
    x_prev = (state["x_prev"][:, None] if state is not None
              else jnp.zeros((b_, 1, d), x.dtype))
    xs = _shifted(x, x_prev)
    r, k, v, w, g = _rkvwg(p, rcfg, x, xs)
    s0 = (state["S"] if state is not None
          else jnp.zeros((b_, h, hd, hd), jnp.float32))
    if chunked and s % rcfg.chunk_size == 0 and s > 1:
        out, s_last = _wkv_chunked(r, k, v, w,
                                   p["u"].astype(jnp.float32), s0,
                                   rcfg.chunk_size)
    else:
        out, s_last = _wkv_scan(r, k, v, w, p["u"].astype(jnp.float32), s0)
    out = out.reshape(b_, s, d)
    # per-head norm then gate
    out = out.reshape(b_, s, h, hd)
    rms = jax.lax.rsqrt(jnp.mean(jnp.square(out), -1, keepdims=True) + 1e-6)
    out = (out * rms).reshape(b_, s, d) * p["ln_w"]
    y = (out.astype(x.dtype) * g) @ p["wo"]
    return y, {"x_prev": x[:, -1], "S": s_last}


def init_channel_mix(b: Builder, d: int, dff: int):
    return {
        "mu_k": b.normal((d,), (None,), scale=0.1),
        "mu_r": b.normal((d,), (None,), scale=0.1),
        "wk": b.normal((d, dff), (None, "model")),
        "wv": b.normal((dff, d), ("model", None)),
        "wr": b.normal((d, d), (None, None)),
    }


def channel_mix_full(p, x: jax.Array, state=None):
    b_, s, d = x.shape
    x_prev = (state["x_prev"][:, None] if state is not None
              else jnp.zeros((b_, 1, d), x.dtype))
    xs = _shifted(x, x_prev)
    dx = xs - x
    x_k = x + dx * p["mu_k"]
    x_r = x + dx * p["mu_r"]
    k = jnp.square(jax.nn.relu(x_k @ p["wk"]))
    k = constrain(k, "batch", None, "model")
    y = jax.nn.sigmoid(x_r @ p["wr"]) * (k @ p["wv"])
    return y, {"x_prev": x[:, -1]}


def init_tm_state(rcfg: RWKVConfig, d: int, batch: int, dtype=jnp.bfloat16):
    h = d // rcfg.head_dim
    return {"x_prev": jnp.zeros((batch, d), dtype),
            "S": jnp.zeros((batch, h, rcfg.head_dim, rcfg.head_dim),
                           jnp.float32)}


def init_cm_state(d: int, batch: int, dtype=jnp.bfloat16):
    return {"x_prev": jnp.zeros((batch, d), dtype)}
