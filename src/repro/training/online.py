"""Online trainer: ragged training loop + live hot-cache refresh.

See the package docstring (repro.training) for the versioned swap protocol
and its exactness invariant. The trainer owns three pieces of state:

* model/optimizer state, advanced by ``dlrm.make_train_step_ragged``;
* a host-side exponentially *decayed* row-frequency histogram of the live
  index stream (``hist = decay * hist + batch_counts`` each step) — the
  online replacement for the offline trace histogram, so the ranking
  follows drift instead of averaging over all of history;
* the current ``VersionedHotCache``, rebuilt every ``refresh_every`` steps
  and write-through-patched after every optimizer step in between.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.configs.base import DLRMConfig
from repro.core import dlrm
from repro.core import embedding_source as es
from repro.core import sparse_engine as se
from repro.core.embedding_source import VersionedSource


def _dense_head(params: Dict) -> Optional[Dict]:
    """The dense-stage parameters a broadcast artifact ships alongside
    the sparse source: the bottom/top MLPs plus the per-table projections
    of a heterogeneous model. Container types are preserved verbatim by
    the artifact codec, so adopting the decoded head keeps the params
    treedef (no recompile)."""
    head = {k: params[k] for k in ("bottom", "top", "proj") if k in params}
    return head or None


@dataclass(frozen=True)
class OnlineCacheConfig:
    k: int                       # hot rows pinned per rebuild
    refresh_every: int = 50      # steps between re-rank + rebuild
    decay: float = 0.98          # per-step histogram decay
    quantize_cold: bool = False  # maintain an int8 cold arena alongside
    #                              the fp one, re-quantizing only the rows
    #                              touched since the last rebuild
    tiers: Optional[object] = None   # storage.TierPolicy: maintain a
    #                              frequency-tiered serving source instead
    #                              of the hot-cache/cold-arena pair; the
    #                              rebuild cadence becomes the tier-
    #                              migration cadence (k is ignored)

    def __post_init__(self):
        if self.tiers is not None and (self.k or self.quantize_cold):
            raise ValueError(
                "a tiered maintenance plan replaces the hot cache and "
                "the int8 mirror (TierPolicy.hot is the hot set; the "
                "warm/cold tiers are the quantized story) — set k=0 and "
                "quantize_cold=False")


@dataclass(frozen=True)
class VersionedHotCache:
    """A hot cache plus the monotone version of the rebuild that made it.

    Also the fleet *broadcast artifact*: ``serialize`` flattens the pair
    into one self-describing byte blob the trainer can put on any
    transport (object store, pub/sub, NFS), ``deserialize`` reconstructs
    it on a serving host, and ``apply`` adopts it into a ``RecEngine``
    atomically — the engine either serves its old version or the new one,
    never a torn mix, and stale (lower-version) artifacts are rejected at
    the engine boundary, so out-of-order delivery is safe.
    """
    cache: se.HotRowCache
    version: int

    MAGIC = b"CHC1"          # Centaur hot-cache artifact, format v1

    def serialize(self) -> bytes:
        """Flatten (cache, version) into a byte blob (npz container)."""
        import io

        buf = io.BytesIO()
        np.savez(buf,
                 magic=np.frombuffer(self.MAGIC, np.uint8),
                 version=np.asarray(self.version, np.int64),
                 hot_rows=np.asarray(self.cache.hot_rows),
                 slot_of=np.asarray(self.cache.slot_of),
                 hot_ids=np.asarray(self.cache.hot_ids))
        return buf.getvalue()

    @staticmethod
    def deserialize(blob: bytes) -> "VersionedHotCache":
        import io

        try:
            with np.load(io.BytesIO(blob)) as z:
                if z["magic"].tobytes() != VersionedHotCache.MAGIC:
                    raise ValueError("bad magic")
                cache = se.HotRowCache(
                    hot_rows=jnp.asarray(z["hot_rows"]),
                    slot_of=jnp.asarray(z["slot_of"]),
                    hot_ids=jnp.asarray(z["hot_ids"]))
                return VersionedHotCache(cache=cache,
                                         version=int(z["version"]))
        except Exception as e:
            raise ValueError(
                f"not a hot-cache broadcast artifact: {e}") from e

    def apply(self, engine) -> bool:
        """Adopt this artifact into a RecEngine iff it is strictly newer.

        Returns True when the engine swapped. Same-version re-delivery is
        a no-op (idempotent broadcast); an older version raises inside
        ``update_cache`` only on a direct call — here it is absorbed, so
        replicas can consume a reordered stream without try/except at
        every site.
        """
        if engine.cache_version >= self.version:
            return False
        engine.update_cache(self.cache, version=self.version)
        return True


def _patch_tiered_hot(tiered, arena: jax.Array, null_row: int,
                      rows: jax.Array):
    """Write-through invalidation for a TieredSource's fp hot tier: the
    rows just trained refresh their hot copies; warm/cold rows route to
    the hot null slot, whose source is forced to the always-zero null
    arena row — the same only-zeros-can-write-the-null-slot invariant
    ``_patch_hot_rows`` keeps."""
    import dataclasses as _dc
    h = tiered.hot_rows.shape[0] - 1
    ts = jnp.take(tiered.tier_slot, rows)
    slots = jnp.where(ts < h, ts, h)
    src = jnp.where(ts < h, rows, null_row)
    fresh = jnp.take(arena, src, axis=0).astype(tiered.hot_rows.dtype)
    return _dc.replace(tiered,
                       hot_rows=tiered.hot_rows.at[slots].set(fresh))


def _patch_hot_rows(cache: se.HotRowCache, arena: jax.Array,
                    null_row: int, rows: jax.Array) -> se.HotRowCache:
    """Write-through invalidation: refresh the hot copies of `rows`.

    Rows that are not pinned map to the null slot, whose *source* is forced
    to the always-zero null arena row — the null slot can only ever be
    rewritten with zeros, so the mask-free hot pass stays exact.
    """
    k = cache.hot_rows.shape[0] - 1
    slots = jnp.take(cache.slot_of, rows)
    src = jnp.where(slots < k, rows, null_row)
    fresh = jnp.take(arena, src, axis=0).astype(cache.hot_rows.dtype)
    return se.HotRowCache(hot_rows=cache.hot_rows.at[slots].set(fresh),
                          slot_of=cache.slot_of, hot_ids=cache.hot_ids)


class OnlineTrainer:
    """Consume ragged batches; keep the serving hot cache live and exact."""

    def __init__(self, cfg: DLRMConfig, params: Dict, *, max_l: int,
                 lr: float = 1e-3, sparse: bool = True,
                 cache_cfg: Optional[OnlineCacheConfig] = None,
                 mesh: Optional[jax.sharding.Mesh] = None,
                 telemetry: Optional[obs.Telemetry] = None):
        self.telemetry = telemetry if telemetry is not None \
            else obs.Telemetry()
        reg = self.telemetry.registry
        self._g_loss = reg.gauge("train_loss", "last optimizer-step loss")
        self._g_version = reg.gauge("train_cache_version",
                                    "last published rebuild version")
        self._g_hot_k = reg.gauge("train_rebuild_hot_k",
                                  "hot rows pinned by the last rebuild")
        self._g_requant = reg.gauge(
            "train_requant_rows",
            "rows re-quantized by the last incremental refresh")
        self._c_steps = reg.counter("train_steps_total",
                                    "optimizer steps taken")
        self._c_rebuilds = reg.counter("train_rebuilds_total",
                                       "hot-cache rebuilds")
        self.cfg = cfg
        self.spec = dlrm.arena_spec(cfg)
        self.params = params
        self.max_l = max_l
        self.cache_cfg = cache_cfg
        self.mesh = mesh
        opt, step = dlrm.make_train_step_ragged(cfg, max_l=max_l, lr=lr,
                                                sparse=sparse, mesh=mesh)
        self.opt_state = opt.init(params)
        # donate opt_state so its (V, 1) accumulator updates in place;
        # params CANNOT be donated — sync_engine publishes the live arrays
        # to serving engines by reference, and donation would free them
        self._step = jax.jit(step, donate_argnums=(1,))
        self._patch = jax.jit(_patch_hot_rows, static_argnums=(2,))
        self.hist = np.zeros(self.spec.total_rows, np.float64)
        self.steps = 0
        self.version = 0
        self.cache: Optional[se.HotRowCache] = None
        self.losses: list = []
        # incremental quantized-cold maintenance (ROADMAP): keep an int8
        # mirror of the arena and the set of rows dirtied since the last
        # requant, so each rebuild patches O(touched) rows instead of
        # re-quantizing the whole (V, D) arena
        self.cold_q: Optional[es.QuantizedArena] = None
        self._dirty_q = None
        if cache_cfg is not None and cache_cfg.quantize_cold:
            self.cold_q = es.QuantizedArena.from_arena(params["arena"])
            self._dirty_q = np.zeros(params["arena"].shape[0], bool)
        # tiered maintenance: materialize the TieredSource at construction
        # (uniform histogram) so the treedef is stable from step 0, and
        # track dirtied rows for the incremental migration requant
        self.tiered = None
        self._patch_t = None
        if cache_cfg is not None and cache_cfg.tiers is not None:
            self.tiered = cache_cfg.tiers.build_source(
                params["arena"], self.spec, None, telemetry=self.telemetry)
            self._dirty_q = np.zeros(params["arena"].shape[0], bool)
            self._patch_t = jax.jit(_patch_tiered_hot, static_argnums=(2,))
            self._g_tier_bytes = {
                tier: reg.gauge("rec_tier_bytes",
                                "device bytes held by this storage tier",
                                labels={"tier": tier})
                for tier in ("hot", "warm", "cold", "maps", "host")}
            self._set_tier_gauges()

    def _set_tier_gauges(self):
        from repro import storage
        for tier, nb in storage.tier_bytes(self.tiered).items():
            if tier in self._g_tier_bytes:
                self._g_tier_bytes[tier].set(nb)

    # -- histogram ---------------------------------------------------------

    def observe(self, batch: Dict) -> None:
        """Fold one batch's index stream into the decayed histogram.

        No-op without a ``cache_cfg``: the histogram exists to rank
        rebuilds (hot caches, tier migrations), so an uncached trainer
        skips the host-side row counting entirely instead of silently
        burning a full-arena bincount per batch."""
        if self.cache_cfg is None:
            return
        counts = se.trace_row_counts(self.spec, np.asarray(batch["indices"]),
                                     np.asarray(batch["offsets"]))
        self.hist = self.cache_cfg.decay * self.hist + counts

    # -- training ----------------------------------------------------------

    def train_step(self, batch: Dict) -> float:
        """One optimizer step; maintains the cache protocol as a side effect."""
        if self.cache_cfg is not None:   # the histogram only feeds rebuilds
            self.observe(batch)
        batch_dev = {k: jnp.asarray(v) for k, v in batch.items()
                     if k in ("dense", "indices", "offsets", "labels")}
        self.params, self.opt_state, loss, rows = self._step(
            self.params, self.opt_state, batch_dev)
        self.steps += 1
        if self._dirty_q is not None:
            # the null/fill rows ride along harmlessly: re-quantizing an
            # all-zero row is an exact no-op
            self._dirty_q[np.asarray(rows)] = True
        if self.cache is not None:
            # step 1 of the protocol: values must never go stale
            self.cache = self._patch(self.cache, self.params["arena"],
                                     self.spec.null_row, rows)
        if self.tiered is not None:
            # same step-1 obligation for the tiered hot tier: the fp hot
            # copies refresh every step, warm/cold rows wait (dirty-masked)
            # for the migration pass
            self.tiered = self._patch_t(self.tiered, self.params["arena"],
                                        self.spec.null_row, rows)
        if self.cache_cfg is not None \
                and self.steps % self.cache_cfg.refresh_every == 0:
            self.rebuild_cache()
        loss = float(loss)
        self.losses.append(loss)
        if self.telemetry.enabled:
            self._c_steps.inc()
            self._g_loss.set(loss)
        return loss

    def train(self, batches: Iterable[Dict]) -> list:
        for batch in batches:
            self.train_step(batch)
        return self.losses

    # -- cache publication -------------------------------------------------

    def rebuild_cache(self) -> VersionedHotCache:
        """Step 2 of the protocol: re-rank from the decayed histogram and
        publish a fresh cache under a bumped version. When quantized-cold
        maintenance is on, the int8 arena is patched in the same version
        (only the rows dirtied since the last rebuild are re-quantized)."""
        assert self.cache_cfg is not None, "no cache_cfg configured"
        if self.tiered is not None:
            self.version += 1
            self._c_rebuilds.inc()
            self._g_version.set(self.version)
            self.retier()
            return self.snapshot()   # None: tiered serving has no hot-cache
            #                          artifact; publish_source() is the blob
        self.cache = se.build_hot_cache(self.params["arena"], self.spec,
                                        self.hist, self.cache_cfg.k)
        if self.cold_q is not None:
            self.refresh_quantized()
        self.version += 1
        self._c_rebuilds.inc()
        self._g_version.set(self.version)
        self._g_hot_k.set(self.cache_cfg.k)
        self.telemetry.emit("hot_cache_rebuild", version=self.version,
                            step=self.steps, k=self.cache_cfg.k)
        return self.snapshot()

    def refresh_quantized(self) -> es.QuantizedArena:
        """Incremental quantized-cold maintenance: re-quantize exactly the
        rows dirtied since the last refresh (O(touched), not O(V)); the
        result is bit-identical to a full ``QuantizedArena.from_arena``
        rebuild because row-wise quantization has no cross-row state."""
        assert self.cold_q is not None, \
            "no quantized cold arena maintained (cache_cfg.quantize_cold)"
        rows = np.nonzero(self._dirty_q)[0]
        if rows.size:
            self.cold_q = self.cold_q.quantize_rows(
                self.params["arena"], jnp.asarray(rows, jnp.int32))
            self._dirty_q[:] = False
        self._g_requant.set(int(rows.size))
        self.telemetry.emit("quantized_refresh", version=self.version,
                            step=self.steps, rows=int(rows.size))
        return self.cold_q

    def retier(self):
        """Tier-migration maintenance (step 6 of the swap protocol): re-rank
        from the decayed histogram and migrate rows across the fixed-size
        hot/warm/cold tiers. Incremental like ``refresh_quantized`` — rows
        that stayed in tier and were not dirtied keep their old quantized
        values; only movers and dirtied rows re-quantize."""
        from repro import storage
        assert self.tiered is not None, \
            "no tiered source maintained (cache_cfg.tiers)"
        self.tiered, stats = storage.migrate(
            self.tiered, self.params["arena"], self.spec,
            self.cache_cfg.tiers, self.hist, self._dirty_q)
        self._dirty_q[:] = False
        self._set_tier_gauges()
        self._g_requant.set(stats["warm_requant"] + stats["cold_requant"])
        self.telemetry.emit("tier_migration", version=self.version,
                            step=self.steps, **stats)
        return self.tiered

    def snapshot(self) -> Optional[VersionedHotCache]:
        if self.cache is None:
            return None
        return VersionedHotCache(cache=self.cache, version=self.version)

    def serving_source(self) -> es.EmbeddingSource:
        """The source a replica should serve right now: the live hot cache
        over the maintained cold arena (int8 when quantize_cold, else the
        fp arena), row-sharded when the trainer runs on a mesh — the same
        composition a ``RecEngine(source='cached', mesh=...)`` serves, so
        the artifact's structure matches sharded replicas too (a
        replicated consumer simply deserializes without a mesh and the
        ShardedArena wrapper unwraps). Structure-stable across versions,
        so pushing it through ``RecEngine.update_source`` never
        recompiles."""
        if self.tiered is not None:
            return self.tiered
        cold = (self.cold_q if self.cold_q is not None
                else es.FpArena(self.params["arena"]))
        if se.mesh_shards(self.mesh) > 1:
            cold = es.ShardedArena(cold, self.mesh)
        if self.cache is None:
            return cold
        # published at a write-through/rebuild boundary, where the hot
        # copies equal their arena rows by protocol — declare coherence
        # so replicas serve with the fast lowering
        return es.CachedSource(hot=self.cache, cold=cold, coherent=True)

    def publish_source(self, include_head: bool = False) -> Optional[bytes]:
        """Serialize the full serving source as a ``VersionedSource``
        broadcast artifact — the arena-broadcast-for-params item: unlike
        ``publish()`` (hot rows only, params shared by reference), this
        blob carries every sparse-stage parameter a remote replica needs
        (hot rows + the entire cold arena). None before the first rebuild.
        For a tiered trainer the blob carries the whole ``TieredSource``
        (a host-cold tier ships its staged snapshot; the live ``HostStore``
        is process-local and marked ephemeral in the blob).

        ``include_head=True`` additionally ships the dense MLP head
        (bottom/top, plus per-table projections when present), closing
        the last in-process sharing: a remote replica adopts serving
        params AND source from the one blob (``VersionedSource.apply``).
        """
        if self.cache is None and self.tiered is None:
            return None
        blob = VersionedSource(source=self.serving_source(),
                               version=self.version,
                               head=(_dense_head(self.params)
                                     if include_head else None)).serialize()
        self.telemetry.emit("publish", version=self.version,
                            artifact="source", bytes=len(blob))
        return blob

    def publish(self) -> Optional[bytes]:
        """Serialize the current snapshot as a fleet broadcast artifact
        (None before the first rebuild). One blob, N consumers: every
        serving replica calls ``VersionedHotCache.deserialize(blob)
        .apply(engine)`` and adopts version k atomically — no recompile
        (K is unchanged), no per-replica rebuild."""
        snap = self.snapshot()
        if snap is None:
            return None
        blob = snap.serialize()
        self.telemetry.emit("publish", version=snap.version,
                            artifact="hot_cache", bytes=len(blob))
        return blob

    def sync_engine(self, engine) -> bool:
        """Publish the trained state into a RecEngine if it is behind;
        returns True when a swap happened.

        Params and cache swap *together*: hot-row copies are snapshots of
        arena rows, so publishing one without the other would serve a
        hybrid of two arena versions — exactness requires the pair. The
        gate is the trainer *step*, not just the rebuild version: between
        rebuilds every optimizer step advances (params, patched cache) as
        a consistent pair, and serving should track it.

        The push goes through ``update_source`` with a source rebuilt to
        the engine's own structure: the fp cold leaf rebinds to the live
        arena, an int8 cold leaf swaps to the trainer-maintained
        ``cold_q`` (incremental requant) — one atomic swap, no recompile.
        """
        if self.tiered is not None:
            # the tiered trainer has no hot-cache artifact; the pair that
            # must swap together is (params, TieredSource) — same step gate
            if getattr(engine, "_trainer_step", -1) >= self.steps \
                    and getattr(engine, "source_version", -1) >= self.version:
                return False
            engine.params = self.params
            engine.update_source(self.tiered, version=self.version)
            engine._trainer_step = self.steps
            return True
        snap = self.snapshot()
        if snap is None:
            return False
        if getattr(engine, "_trainer_step", -1) >= self.steps \
                and getattr(engine, "cache_version", -1) >= snap.version:
            return False
        engine.params = self.params          # MLPs + fp-arena leaf rebind
        new_source = self._match_structure(engine.source, snap.cache)
        engine.update_source(new_source, version=snap.version)
        engine._trainer_step = self.steps
        return True

    def _match_structure(self, engine_source,
                         cache: se.HotRowCache) -> es.EmbeddingSource:
        """Rebuild the engine's source shape from live trainer state."""
        def cold_like(c):
            if isinstance(c, es.ShardedArena):
                return es.ShardedArena(cold_like(c.inner), c.mesh, c.axis)
            if isinstance(c, es.QuantizedArena):
                assert self.cold_q is not None, \
                    ("the engine serves an int8 cold arena but the "
                     "trainer maintains none — set "
                     "OnlineCacheConfig(quantize_cold=True)")
                return self.cold_q
            if isinstance(c, es.FpArena):
                return es.FpArena(self.params["arena"])
            raise TypeError(f"cannot sync cold source {type(c).__name__}")
        if isinstance(engine_source, es.CachedSource):
            # mirror the engine's coherence declaration: the flag is
            # pytree structure, and a structure mismatch would recompile
            return es.CachedSource(hot=cache,
                                   cold=cold_like(engine_source.cold),
                                   coherent=engine_source.coherent)
        return cold_like(engine_source)


class OnlineGroupTrainer:
    """Per-table online trainer for heterogeneous table groups.

    The group sibling of ``OnlineTrainer``: every piece of protocol state
    goes per-table — one decayed row-frequency histogram, one hot cache
    (only for the tables whose ``TablePlan.cache_k`` > 0: hot-caching a
    near-uniform table buys nothing), one optional int8 mirror (only for
    ``TablePlan.quantize`` tables), and one Adagrad accumulator per
    member arena (inside the group train step). Publication is ONE
    ``VersionedSource`` carrying the whole ``TableGroupSource``, so a
    replica adopts every table's refresh in a single atomic, versioned,
    no-recompile swap — the swap protocol of ``repro.training`` step 4,
    unchanged, just over a bigger pytree.

    Structure stability: caches and int8 mirrors are materialized at
    construction (uniform histogram) rather than at the first rebuild, so
    ``serving_source()`` has the same treedef from step 0 and every
    ``sync_engine`` push hits the engine's compiled executable.
    """

    def __init__(self, cfg: DLRMConfig, params: Dict, *, max_l: int,
                 plans, lr: float = 1e-3, refresh_every: int = 50,
                 decay: float = 0.98,
                 telemetry: Optional[obs.Telemetry] = None):
        assert cfg.heterogeneous, \
            "OnlineGroupTrainer needs a heterogeneous config"
        assert len(plans) == cfg.n_tables, (len(plans), cfg.n_tables)
        self.telemetry = telemetry if telemetry is not None \
            else obs.Telemetry()
        reg = self.telemetry.registry
        self._g_loss = reg.gauge("train_loss", "last optimizer-step loss")
        self._g_version = reg.gauge("train_cache_version",
                                    "last published rebuild version")
        self._c_steps = reg.counter("train_steps_total",
                                    "optimizer steps taken")
        self._c_rebuilds = reg.counter("train_rebuilds_total",
                                       "hot-cache rebuilds")
        self.cfg = cfg
        self.spec = dlrm.arena_spec(cfg)
        self.specs = dlrm.member_specs(cfg)
        self.plans = tuple(plans)
        self.params = params
        self.max_l = max_l
        self.refresh_every = refresh_every
        self.decay = decay
        opt, step = dlrm.make_train_step_ragged(cfg, max_l=max_l, lr=lr,
                                                sparse=True)
        self.opt_state = opt.init(params)
        self._step = jax.jit(step, donate_argnums=(1,))
        self._patch = jax.jit(_patch_hot_rows, static_argnums=(2,))
        self._patch_t = jax.jit(_patch_tiered_hot, static_argnums=(2,))
        self.hists = [np.zeros(sp.total_rows, np.float64)
                      for sp in self.specs]
        self.steps = 0
        self.version = 0
        self.losses: list = []
        self.caches = []
        self.cold_q = []
        self.tiered = []
        self._dirty_q = []
        for plan, sp, arena in zip(self.plans, self.specs,
                                   params["tables"]):
            self.caches.append(
                se.build_hot_cache(arena, sp, np.ones(sp.total_rows),
                                   plan.cache_k)
                if plan.cache_k > 0 else None)
            self.cold_q.append(es.QuantizedArena.from_arena(arena)
                               if plan.quantize else None)
            self.tiered.append(
                plan.tiers.build_source(arena, sp, None,
                                        telemetry=self.telemetry)
                if getattr(plan, "tiers", None) is not None else None)
            self._dirty_q.append(
                np.zeros(arena.shape[0], bool)
                if (plan.quantize or self.tiered[-1] is not None) else None)

    # -- histogram ---------------------------------------------------------

    def observe(self, batch: Dict) -> None:
        """Fold one interleaved batch into the per-table histograms."""
        counts = es.group_trace_counts(self.specs, batch["indices"],
                                       batch["offsets"])
        for t, c in enumerate(counts):
            self.hists[t] = self.decay * self.hists[t] + c

    # -- training ----------------------------------------------------------

    def train_step(self, batch: Dict) -> float:
        """One optimizer step; per-table write-through patch rides along."""
        self.observe(batch)
        batch_dev = {k: jnp.asarray(v) for k, v in batch.items()
                     if k in ("dense", "indices", "offsets", "labels")}
        self.params, self.opt_state, loss, touched = self._step(
            self.params, self.opt_state, batch_dev)
        self.steps += 1
        for t, rows in enumerate(touched):
            if self._dirty_q[t] is not None:
                self._dirty_q[t][np.asarray(rows)] = True
            if self.caches[t] is not None:
                self.caches[t] = self._patch(
                    self.caches[t], self.params["tables"][t],
                    self.specs[t].null_row, rows)
            if self.tiered[t] is not None:
                self.tiered[t] = self._patch_t(
                    self.tiered[t], self.params["tables"][t],
                    self.specs[t].null_row, rows)
        if self.steps % self.refresh_every == 0:
            self.rebuild()
        loss = float(loss)
        self.losses.append(loss)
        if self.telemetry.enabled:
            self._c_steps.inc()
            self._g_loss.set(loss)
        return loss

    def train(self, batches: Iterable[Dict]) -> list:
        for batch in batches:
            self.train_step(batch)
        return self.losses

    # -- publication -------------------------------------------------------

    def rebuild(self) -> int:
        """Re-rank every cached table from its decayed histogram, patch
        every int8 mirror (only the rows dirtied since the last rebuild),
        and bump ONE version for the whole group — tables refresh
        together or not at all, so a replica can never serve a torn mix
        of table versions."""
        from repro import storage
        requant = {}
        migrated = {}
        for t, (plan, sp) in enumerate(zip(self.plans, self.specs)):
            if plan.cache_k > 0:
                self.caches[t] = se.build_hot_cache(
                    self.params["tables"][t], sp, self.hists[t],
                    plan.cache_k)
            if self.cold_q[t] is not None:
                rows = np.nonzero(self._dirty_q[t])[0]
                requant[str(t)] = int(rows.size)
                if rows.size:
                    self.cold_q[t] = self.cold_q[t].quantize_rows(
                        self.params["tables"][t],
                        jnp.asarray(rows, jnp.int32))
                    self._dirty_q[t][:] = False
            if self.tiered[t] is not None:
                self.tiered[t], stats = storage.migrate(
                    self.tiered[t], self.params["tables"][t], sp,
                    plan.tiers, self.hists[t], self._dirty_q[t])
                self._dirty_q[t][:] = False
                migrated[str(t)] = stats
        self.version += 1
        self._c_rebuilds.inc()
        self._g_version.set(self.version)
        self.telemetry.emit(
            "hot_cache_rebuild", version=self.version, step=self.steps,
            cached_tables=[t for t, c in enumerate(self.caches)
                           if c is not None],
            requant_rows=requant)
        if migrated:
            self.telemetry.emit("tier_migration", version=self.version,
                                step=self.steps, tables=migrated)
        return self.version

    def serving_source(self) -> es.TableGroupSource:
        """The group a replica should serve right now (same structure at
        every step — see the class docstring)."""
        members = []
        for t, plan in enumerate(self.plans):
            if self.tiered[t] is not None:
                members.append(self.tiered[t])
                continue
            cold = (self.cold_q[t] if self.cold_q[t] is not None
                    else es.FpArena(self.params["tables"][t]))
            members.append(es.CachedSource(hot=self.caches[t], cold=cold,
                                           coherent=True)
                           if self.caches[t] is not None else cold)
        return es.TableGroupSource(members=tuple(members),
                                   specs=self.specs)

    def publish_source(self, include_head: bool = False) -> bytes:
        """One ``VersionedSource`` blob carrying every table's sparse
        params (hot rows + cold arenas) under the group's single
        version; ``include_head=True`` adds the dense MLP head so remote
        replicas need no in-process parameter sharing."""
        blob = es.VersionedSource(source=self.serving_source(),
                                  version=self.version,
                                  head=(_dense_head(self.params)
                                        if include_head else None)
                                  ).serialize()
        self.telemetry.emit("publish", version=self.version,
                            artifact="group_source", bytes=len(blob))
        return blob

    def sync_engine(self, engine) -> bool:
        """Push the live group into a RecEngine if it is behind (same
        step-gate as ``OnlineTrainer.sync_engine``; params and source
        swap together)."""
        if getattr(engine, "_trainer_step", -1) >= self.steps \
                and engine.source_version >= self.version:
            return False
        engine.params = self.params
        engine.update_source(self.serving_source(), version=self.version)
        engine._trainer_step = self.steps
        return True


def make_drifting_zipf(cfg: DLRMConfig, *, batch_size: int, mean_l: int,
                       max_l: int, drift_per_batch: int = 0,
                       alpha: float = 1.05, seed: int = 0):
    """Ragged-batch generator whose hot set rotates over time.

    Zipf rank r maps to row (r + t * drift_per_batch) % rows at batch t, so
    the most popular rows shift by `drift_per_batch` every batch — the
    RecNMP drift scenario an offline-built cache cannot follow. Yields
    batches shaped exactly like DLRMSynthetic.ragged_batch, padded to a
    static stream length so every batch hits one compiled shape.
    """
    rng = np.random.RandomState(seed)
    w = rng.randn(cfg.dense_features).astype(np.float32)
    n_bags = batch_size * cfg.n_tables
    pad_to = n_bags * max_l
    t = 0
    while True:
        lens = np.clip(rng.poisson(mean_l, n_bags), 0, max_l).astype(np.int32)
        offsets = np.zeros(n_bags + 1, np.int32)
        np.cumsum(lens, out=offsets[1:])
        n = int(offsets[-1])
        raw = rng.zipf(alpha, size=n)
        shifted = (raw - 1) + t * drift_per_batch
        if cfg.heterogeneous:
            # fold each position into its own table's vocab (per-table
            # skew comes from table_alphas at generation time elsewhere;
            # here the drift scenario keeps one shared alpha)
            seg = np.searchsorted(offsets[1:], np.arange(n), side="right")
            rows = np.asarray(cfg.resolved_table_rows)
            indices = (shifted % rows[seg % cfg.n_tables]).astype(np.int32)
        else:
            indices = (shifted % cfg.rows_per_table).astype(np.int32)
        indices = np.concatenate([indices, np.zeros(pad_to - n, np.int32)])
        dense = rng.randn(batch_size, cfg.dense_features).astype(np.float32)
        logit = dense @ w * 0.5
        labels = (rng.rand(batch_size)
                  < 1.0 / (1.0 + np.exp(-logit))).astype(np.float32)
        yield {"dense": dense, "indices": indices, "offsets": offsets,
               "lengths": lens, "labels": labels, "max_l": max_l}
        t += 1
