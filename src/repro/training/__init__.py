"""Online ragged-training subsystem for DLRM.

Production recommenders never stop training: the serving fleet and the
trainer share one embedding state, and the Zipfian skew the hot-row cache
exploits drifts as traffic shifts (RecNMP's trace analysis). This package
closes the training half of the loop on top of the serving-side sparse
engine:

* ``sparse_optim`` — row-wise sparse optimizer: the embedding gradient of a
  ragged batch touches at most N rows (N = index-stream length), so the
  update gathers/updates/scatters exactly those rows instead of
  materializing a dense (V, D) gradient. Bit-exact against dense row-wise
  Adagrad (untouched rows receive a zero update there too).
* ``online`` — ``OnlineTrainer``: consumes ragged batches, keeps a decayed
  row-frequency histogram of the live index stream, and periodically
  rebuilds the serving hot-row cache from it.

README — versioned hot-arena swap protocol
------------------------------------------

The hot cache is a *copy* of the top-K arena rows, so online training makes
it stale twice over: (1) every optimizer step rewrites arena rows whose hot
copies then diverge, and (2) traffic drift changes *which* rows deserve
pinning. The protocol keeps the serving path exact at all times:

1. **Write-through invalidation (every step).** After the optimizer applies
   a batch's row updates, the trainer rewrites the hot copies of every
   *touched hot* row from the new arena (``slot_of`` maps rows to slots;
   misses are routed to the null slot whose source is the always-zero null
   arena row, so it can never be corrupted). This preserves the exactness
   invariant — ``hot_pass(slots) + cold_pass(redirected) == uncached
   lookup`` — because the identity only needs hot copies to equal their
   arena rows; which rows are pinned is a pure performance choice.
2. **Versioned rebuild (every ``refresh_every`` steps).** The decayed
   histogram re-ranks rows; ``build_hot_cache`` produces a fresh arena copy
   and the trainer bumps a monotonically increasing **version**. A serving
   engine holding version v swaps atomically to v+1 via
   ``RecEngine.update_cache`` (the cache is a jit *argument*, not a closure
   constant, so a swap never recompiles as long as K is unchanged).
   Between rebuilds the engine's cache is stale only in *ranking* — never
   in *values* — so serving results equal the uncached lookup at every
   version.

Consumers that cannot tolerate torn reads across the (hot_rows, slot_of)
pair must swap the whole ``HotRowCache`` object at once — both the trainer
and the engine do; neither ever mutates a published cache in place.

3. **Versioned broadcast (trainer -> fleet).** Multi-host serving extends
   the same protocol across processes: ``OnlineTrainer.publish()``
   serializes the current ``VersionedHotCache`` into one self-describing
   byte artifact (``serialize``/``deserialize`` round-trip, any
   transport), and every serving replica adopts it with
   ``VersionedHotCache.apply(engine)``. Adoption keeps all single-process
   guarantees: the whole (hot_rows, slot_of, version) triple swaps
   atomically, K is unchanged so no replica recompiles, and the version
   gate makes delivery *order-free* — ``apply`` absorbs same-or-older
   artifacts (idempotent re-delivery), while a direct
   ``RecEngine.update_cache`` call with a lower version raises, so a
   reordered transport can never roll a replica's hot arena back. Values
   stay exact for the params the artifact was built from; replicas must
   therefore swap params and cache as a pair, exactly like step 2's
   single-process rule (``examples/serve_recommender.py --replicas N``
   demonstrates the full trainer -> N-replica loop).

4. **Generalized source swap (``VersionedSource``).** With the unified
   ``EmbeddingSource`` API the cache-swap protocol is a special case of
   a *source* swap: the serving engine holds one source pytree as a
   call-time jit argument, and ``RecEngine.update_source`` atomically
   replaces ANY component — the hot cache, the int8 cold arena
   (``QuantizedArena``), or the full fp arena — under the same version
   gate. The no-recompile condition is structural (same treedef + leaf
   shapes/dtypes) and is asserted at the swap boundary.
   ``VersionedSource`` is the broadcast artifact for the general case:
   it serializes the *entire* source (hot rows + the whole cold arena),
   so ``OnlineTrainer.publish_source()`` is full param publication for
   the sparse stage — a cold remote replica needs no by-reference param
   sharing to serve exactly (``serve_recommender.py --replicas N`` ends
   with this demonstration). A recorded ``ShardedArena`` rebinds to the
   consumer's own mesh at ``deserialize(blob, mesh=...)`` (meshes are
   host topology, not state), or unwraps to its replicated inner source
   when no mesh is given. Step-version semantics are unchanged:
   strictly-newer adopts, same-or-older is absorbed.

Quantized-cold maintenance note: when ``OnlineCacheConfig(quantize_cold=
True)``, the trainer keeps an int8 mirror of the arena and re-quantizes
ONLY the rows touched since the last rebuild (``QuantizedArena.
quantize_rows``; exact vs a full requantization because row-wise
quantization has no cross-row state); the patched mirror rides in the
same version as the rebuilt hot cache, so ``sync_engine`` pushes (hot,
int8 cold) as one consistent swap.

5. **Per-table refresh under ONE version (``OnlineGroupTrainer``).** A
   heterogeneous ``TableGroupSource`` multiplies the protocol state per
   table — per-table decayed histograms, per-table hot caches (only the
   skewed tables carry one), per-table int8 mirrors with per-table dirty
   masks — but NOT the version: every rebuild re-ranks all cached tables
   and bumps one group-wide version, and ``publish_source()`` ships the
   whole group in one ``VersionedSource`` blob. Tables therefore refresh
   atomically together; a replica can never serve table 0 at version k
   next to table 1 at version k+1. All step-1..4 guarantees apply member-
   wise (write-through patches each table's hot copies from ITS arena;
   the swap is still one structural-equality-checked pytree replace).

Sharding note: all steps are unchanged by the row-sharded arena — the
hot cache is a *replicated* copy of top-K rows wherever the cold rows
live, and the sharded train step returns the same global touched-row ids
the write-through patch consumes (``make_train_step_ragged(sharded=True)``
updates each arena shard locally; see ``sparse_optim.shard_local_rows``).
"""
from repro.core.embedding_source import VersionedSource
from repro.training.online import (OnlineCacheConfig, OnlineGroupTrainer,
                                   OnlineTrainer, VersionedHotCache,
                                   make_drifting_zipf)
from repro.training.sparse_optim import (SparseOptimizer, group_row_grads,
                                         group_rowwise_adagrad,
                                         ragged_row_grads,
                                         source_row_grads,
                                         sparse_rowwise_adagrad)

__all__ = ["OnlineCacheConfig", "OnlineGroupTrainer", "OnlineTrainer",
           "SparseOptimizer", "VersionedHotCache", "VersionedSource",
           "group_row_grads", "group_rowwise_adagrad",
           "make_drifting_zipf", "ragged_row_grads", "source_row_grads",
           "sparse_rowwise_adagrad"]
