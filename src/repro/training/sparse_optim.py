"""Row-wise sparse optimizer for the embedding arena.

A ragged batch with an N-position index stream touches at most N of the
arena's V rows (V is 10^5..10^7; N is 10^3). The dense training path
nevertheless materializes a (V, D) gradient — Tensor Casting's observation
that the training bottleneck is exactly the gather/scatter pair. This
module keeps the update O(N): gather the touched rows' optimizer state,
apply the row-wise Adagrad rule to those rows only, scatter back.

The sparse update is *exact* vs dense ``optim.rowwise_adagrad``: untouched
rows there see g = 0, which adds 0 to the accumulator and 0 to the row —
the same as not visiting them at all.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core import sparse_engine as se


class SparseOptimizer(NamedTuple):
    """Like optim.Optimizer but updates (rows, row_grads) slices.

    init(arena) -> state
    update(arena, state, rows, row_grads) -> (new_arena, new_state)
    """
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any, Any], Any]


def ragged_row_grads(d_bags: jax.Array, indices: jax.Array,
                     offsets: jax.Array, *,
                     fill_row: int) -> Tuple[jax.Array, jax.Array]:
    """Upstream bag gradients -> (touched rows, per-row gradients).

    d_bags (B, D): d loss / d bag-sum; indices (N,) destination rows
    (padded tail allowed); offsets (B+1,). Returns rows (N,) int32 and
    grads (N, D) f32 where grads[i] is the summed gradient of row rows[i];
    unused slots are filled with `fill_row` and a zero gradient (static
    shapes, so the consumer stays jittable). Pass the arena null row as
    `fill_row`: its gradient is forced to zero even when indices target it
    *validly* (dummy bags, pipeline tail streams) — the null row is an
    engine sentinel whose always-zero invariant every padded lookup and
    the cache null slot depend on, never a trainable parameter. This is
    also what keeps the replicated and shard-local updates identical: the
    sharded path excludes the null row by construction.

    Duplicate indices within and across bags are summed (the VJP of a
    gather is a scatter-*add*), which is what makes the later unique-row
    scatter exact.
    """
    n = indices.shape[0]
    n_bags = offsets.shape[0] - 1
    seg = se.ragged_segment_ids(offsets, n)
    valid = jnp.arange(n, dtype=offsets.dtype) < offsets[-1]
    per_pos = jnp.take(d_bags.astype(jnp.float32),
                       jnp.minimum(seg, n_bags - 1), axis=0)
    per_pos = jnp.where(valid[:, None], per_pos, 0.0)
    rows, inv = jnp.unique(jnp.where(valid, indices, fill_row), size=n,
                           fill_value=fill_row, return_inverse=True)
    grads = jax.ops.segment_sum(per_pos, inv.reshape(-1), num_segments=n)
    grads = jnp.where(rows[:, None] == fill_row, 0.0, grads)
    return rows.astype(jnp.int32), grads


def source_row_grads(spec, d_bags: jax.Array, indices: jax.Array,
                     offsets: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Row gradients of ``lookup_bags(FpArena(arena), spec, …)`` w.r.t.
    the arena, restricted to the touched rows.

    This is the sparse-optimizer half of the source API's gradient
    contract: ``jax.grad`` through ``lookup_bags`` routes into the
    source's fp leaves via the kernel custom VJPs and materializes a
    dense (V, D) scatter; this helper produces the *same* gradient as the
    O(index-stream) pair (rows, row_grads) — the equivalence is pinned by
    the source suite (tests/test_embedding_source.py). `indices`/`offsets`
    are the per-table ragged batch exactly as passed to ``lookup_bags``.
    """
    flat = se.flatten_ragged_indices(spec, indices, offsets)
    return ragged_row_grads(d_bags, flat, offsets,
                            fill_row=spec.null_row)


def group_row_grads(specs, d_bags: jax.Array, indices: jax.Array,
                    offsets: jax.Array, *, max_l=None):
    """Per-table row gradients of a ``TableGroupSource`` lookup.

    The group sibling of ``source_row_grads``: `specs` are the group's
    per-table ArenaSpecs, `d_bags` (n_bags, dmax) is d loss / d padded
    bag output, `indices`/`offsets` the interleaved ragged batch exactly
    as passed to ``lookup_bags``. Returns a list of per-table
    (rows, grads (rows.shape + (dim_t,))) pairs — table t's touched rows
    in ITS OWN arena and their summed gradients (only the leading dim_t
    lanes of `d_bags` reach table t; the padded tail's cotangent is
    structurally zero). Fill slots are routed to table t's null row,
    whose gradient ``ragged_row_grads`` forces to zero — so each pair
    equals the row grads of that member's own per-table-stream lookup
    exactly.

    With ``max_l`` (the same static bound the lookup used), the stream
    is relayouted ONCE into the dense (n_bags, max_l) matrix of the
    fused dispatch and each table walks only its own (B, max_l) bag
    slice — rows are (B*max_l,) per table instead of T walks over the
    full N-position stream. Without it, the legacy full-stream walk runs
    (rows are (N,) per table).
    """
    t_count = len(specs)
    if max_l is None:
        table, valid = se.ragged_position_tables(offsets,
                                                 indices.shape[0],
                                                 t_count)
        out = []
        for t, sp in enumerate(specs):
            mine = valid & (table == t)
            idx_t = jnp.where(mine, indices,
                              jnp.asarray(sp.null_row, indices.dtype))
            rows, grads = ragged_row_grads(d_bags[:, :sp.dim], idx_t,
                                           offsets, fill_row=sp.null_row)
            out.append((rows, grads))
        return out
    n_bags = offsets.shape[0] - 1
    b = n_bags // t_count
    dense = se.ragged_dense_ids(indices, offsets, max_l=max_l, fill=-1)
    dense = dense.reshape(b, t_count, max_l)
    uni = jnp.arange(b + 1, dtype=jnp.int32) * max_l
    out = []
    for t, sp in enumerate(specs):
        ids_t = dense[:, t, :]
        ids_t = jnp.where(ids_t >= 0, ids_t,
                          jnp.asarray(sp.null_row, ids_t.dtype))
        # bag (s, t) sits at row s*t_count + t of the interleaved batch
        rows, grads = ragged_row_grads(d_bags[t::t_count, :sp.dim],
                                       ids_t.reshape(-1), uni,
                                       fill_row=sp.null_row)
        out.append((rows, grads))
    return out


def group_rowwise_adagrad(lr, eps: float = 1e-8) -> SparseOptimizer:
    """``sparse_rowwise_adagrad`` over a tuple of per-table arenas: one
    independent accumulator per table, updates applied per (rows_t,
    grads_t) pair from ``group_row_grads``. Exact per table vs the
    single-arena sparse optimizer by construction (it IS that optimizer,
    applied per member)."""
    leaf = sparse_rowwise_adagrad(lr, eps)

    def init(arenas):
        return tuple(leaf.init(a) for a in arenas)

    def update(arenas, states, per_table):
        new_arenas, new_states = [], []
        for a, s, (rows, grads) in zip(arenas, states, per_table):
            na, ns = leaf.update(a, s, rows, grads)
            new_arenas.append(na)
            new_states.append(ns)
        return tuple(new_arenas), tuple(new_states)

    return SparseOptimizer(init, update)


def shard_local_rows(rows: jax.Array, row_grads: jax.Array, *, lo,
                     vlocal: int, null_row: int
                     ) -> Tuple[jax.Array, jax.Array]:
    """Project a global (rows, row_grads) update onto one arena row-shard.

    For use inside shard_map: `lo` is the first global row this shard owns,
    `vlocal` its row count. Rows the shard does not own — and the null row,
    whose always-zero invariant must survive training — are redirected to
    local row 0 with a zero gradient: under `sparse_rowwise_adagrad` a zero
    gradient is an exact no-op (zero accumulator add, zero delta), so the
    redirect target is never perturbed. Each shard therefore applies
    exactly the updates of the rows it owns and nothing else; the union
    over shards is the replicated update.
    """
    rel = rows - lo
    own = (rel >= 0) & (rel < vlocal) & (rows != null_row)
    local = jnp.where(own, rel, 0).astype(jnp.int32)
    grads = jnp.where(own[:, None], row_grads, 0.0)
    return local, grads


def sparse_rowwise_adagrad(lr, eps: float = 1e-8) -> SparseOptimizer:
    """Row-wise Adagrad over touched rows only (state: one scalar per row).

    Matches optim.rowwise_adagrad exactly on the touched rows and leaves
    the rest of the arena and accumulator untouched.
    """
    sched = lr if callable(lr) else (lambda step: jnp.asarray(lr, jnp.float32))

    def init(arena):
        return {"acc": jnp.zeros(arena.shape[:-1] + (1,), jnp.float32),
                "step": jnp.zeros((), jnp.int32)}

    def update(arena, state, rows, row_grads):
        step = state["step"] + 1
        lr_t = sched(step)
        g32 = row_grads.astype(jnp.float32)            # (N, D)
        g2 = jnp.mean(jnp.square(g32), axis=-1, keepdims=True)
        # `rows` are unique apart from fill duplicates whose grads are
        # zero, so scatter-add == set for every real row and a no-op for
        # the fill row.
        acc = state["acc"].at[rows].add(g2)
        a_new = jnp.take(acc, rows, axis=0)            # (N, 1)
        delta = -lr_t * g32 / (jnp.sqrt(a_new) + eps)
        new_arena = arena.astype(jnp.float32).at[rows].add(delta)
        return new_arena.astype(arena.dtype), {"acc": acc, "step": step}

    return SparseOptimizer(init, update)
