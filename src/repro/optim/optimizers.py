"""Minimal optax-style optimizer substrate (no external deps).

An optimizer is a pair of pure functions:
    init(params) -> state
    update(grads, state, params) -> (new_params, new_state)

Provided: sgd (momentum), adamw, adafactor (factored second moment — the only
optimizer whose state fits a trillion-param MoE on v5e), rowwise_adagrad (the
standard DLRM embedding-table optimizer: one adaptive scalar per row, which
keeps optimizer state at 1/D of the table), global-norm clipping, and
warmup-cosine schedules.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], Any]   # (grads, state, params) -> (params, state)


def _tree_map(f, *trees, **kw):
    return jax.tree_util.tree_map(f, *trees, **kw)


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return _tree_map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                     grads), norm


# ---------------------------------------------------------------------------
# Schedules
# ---------------------------------------------------------------------------

def warmup_cosine(base_lr: float, warmup_steps: int, total_steps: int,
                  min_ratio: float = 0.1) -> Callable[[jax.Array], jax.Array]:
    def schedule(step):
        step = jnp.asarray(step, jnp.float32)
        warm = step / jnp.maximum(1.0, warmup_steps)
        prog = (step - warmup_steps) / jnp.maximum(
            1.0, total_steps - warmup_steps)
        prog = jnp.clip(prog, 0.0, 1.0)
        cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return base_lr * jnp.where(step < warmup_steps, warm, cos)
    return schedule


def _as_schedule(lr):
    return lr if callable(lr) else (lambda step: jnp.asarray(lr, jnp.float32))


# ---------------------------------------------------------------------------
# SGD + momentum
# ---------------------------------------------------------------------------

def sgd(lr, momentum: float = 0.9) -> Optimizer:
    sched = _as_schedule(lr)

    def init(params):
        return {"mu": _tree_map(lambda p: jnp.zeros_like(p, jnp.float32), params),
                "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        step = state["step"] + 1
        lr_t = sched(step)
        mu = _tree_map(lambda m, g: momentum * m + g.astype(jnp.float32),
                       state["mu"], grads)
        new_params = _tree_map(
            lambda p, m: (p.astype(jnp.float32) - lr_t * m).astype(p.dtype),
            params, mu)
        return new_params, {"mu": mu, "step": step}

    return Optimizer(init, update)


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

def adamw(lr, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.01) -> Optimizer:
    sched = _as_schedule(lr)

    def init(params):
        z = lambda p: jnp.zeros_like(p, jnp.float32)
        return {"m": _tree_map(z, params), "v": _tree_map(z, params),
                "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        step = state["step"] + 1
        lr_t = sched(step)
        t = step.astype(jnp.float32)
        c1 = 1 - b1 ** t
        c2 = 1 - b2 ** t
        m = _tree_map(lambda m_, g: b1 * m_ + (1 - b1) * g.astype(jnp.float32),
                      state["m"], grads)
        v = _tree_map(lambda v_, g: b2 * v_ + (1 - b2)
                      * jnp.square(g.astype(jnp.float32)),
                      state["v"], grads)

        def upd(p, m_, v_):
            mh = m_ / c1
            vh = v_ / c2
            step_ = mh / (jnp.sqrt(vh) + eps) + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr_t * step_).astype(p.dtype)

        return _tree_map(upd, params, m, v), {"m": m, "v": v, "step": step}

    return Optimizer(init, update)


# ---------------------------------------------------------------------------
# Adafactor (factored second moments; state ~ O(P/D) for matrices)
# ---------------------------------------------------------------------------

def adafactor(lr, decay: float = 0.8, eps: float = 1e-30,
              clip_threshold: float = 1.0) -> Optimizer:
    sched = _as_schedule(lr)

    def _factored(shape):
        return len(shape) >= 2

    def init(params):
        def per_leaf(p):
            if _factored(p.shape):
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
            return {"v": jnp.zeros_like(p, jnp.float32)}
        return {"fac": _tree_map(per_leaf, params,
                                 is_leaf=lambda x: isinstance(x, jax.Array)),
                "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        step = state["step"] + 1
        lr_t = sched(step)
        t = step.astype(jnp.float32)
        beta = 1.0 - t ** (-decay)

        def per_leaf(g, st, p):
            g32 = g.astype(jnp.float32)
            g2 = jnp.square(g32) + eps
            if _factored(p.shape):
                vr = beta * st["vr"] + (1 - beta) * g2.mean(axis=-1)
                vc = beta * st["vc"] + (1 - beta) * g2.mean(axis=-2)
                denom = (vr[..., None] * vc[..., None, :]
                         / jnp.maximum(vr.mean(axis=-1, keepdims=True)[..., None], eps))
                upd = g32 / jnp.sqrt(denom + eps)
                new_st = {"vr": vr, "vc": vc}
            else:
                v = beta * st["v"] + (1 - beta) * g2
                upd = g32 / jnp.sqrt(v + eps)
                new_st = {"v": v}
            rms = jnp.sqrt(jnp.mean(jnp.square(upd)) + eps)
            upd = upd / jnp.maximum(1.0, rms / clip_threshold)
            return (p.astype(jnp.float32) - lr_t * upd).astype(p.dtype), new_st

        flat_p, treedef = jax.tree_util.tree_flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_s = treedef.flatten_up_to(state["fac"])
        out = [per_leaf(g, s, p) for g, s, p in zip(flat_g, flat_s, flat_p)]
        new_params = treedef.unflatten([o[0] for o in out])
        new_fac = treedef.unflatten([o[1] for o in out])
        return new_params, {"fac": new_fac, "step": step}

    return Optimizer(init, update)


# ---------------------------------------------------------------------------
# Row-wise Adagrad (DLRM embedding tables)
# ---------------------------------------------------------------------------

def rowwise_adagrad(lr, eps: float = 1e-8) -> Optimizer:
    """One adaptive accumulator scalar per table *row* (paper-standard for
    embedding tables: state is rows x 1 instead of rows x dim)."""
    sched = _as_schedule(lr)

    def init(params):
        return {"acc": _tree_map(
            lambda p: jnp.zeros(p.shape[:-1] + (1,), jnp.float32), params),
            "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        step = state["step"] + 1
        lr_t = sched(step)

        def upd(p, g, a):
            g32 = g.astype(jnp.float32)
            a_new = a + jnp.mean(jnp.square(g32), axis=-1, keepdims=True)
            p_new = p.astype(jnp.float32) - lr_t * g32 / (jnp.sqrt(a_new) + eps)
            return p_new.astype(p.dtype), a_new

        flat_p, treedef = jax.tree_util.tree_flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_a = treedef.flatten_up_to(state["acc"])
        out = [upd(p, g, a) for p, g, a in zip(flat_p, flat_g, flat_a)]
        return (treedef.unflatten([o[0] for o in out]),
                {"acc": treedef.unflatten([o[1] for o in out]), "step": step})

    return Optimizer(init, update)


# ---------------------------------------------------------------------------
# Partitioned optimizer (different rules per subtree, e.g. DLRM)
# ---------------------------------------------------------------------------

def partitioned(rules: dict, default: Optimizer) -> Optimizer:
    """Apply a different optimizer to top-level keys named in `rules`.

    Params must be a dict at the top level; e.g. DLRM uses
    ``partitioned({'arena': rowwise_adagrad(...)}, adamw(...))``.
    """
    def pick(key):
        return rules.get(key, default)

    def init(params):
        return {k: pick(k).init(v) for k, v in params.items()}

    def update(grads, state, params):
        new_p, new_s = {}, {}
        for k, p in params.items():
            np_, ns_ = pick(k).update(grads[k], state[k], p)
            new_p[k], new_s[k] = np_, ns_
        return new_p, new_s

    return Optimizer(init, update)


def layerwise(opt: Optimizer, min_layers: int = 8) -> Optimizer:
    """Apply `opt`'s update via lax.scan over stacked-layer subtrees.

    A fused elementwise update over a scan-stacked (L, ...) parameter tensor
    materializes f32 temporaries of the WHOLE stack (measured: ~53 GB of
    optimizer temp on the 1T-param MoE). Scanning the update over the layer
    dim bounds temporaries to one layer. Top-level subtrees whose leaves all
    share a leading dim >= min_layers are scanned; the rest update directly.
    Leaf-wise optimizers only (adamw/sgd/adafactor/rowwise — all are).
    """
    def _stacked_dim(subtree):
        # A layer stack is a MULTI-leaf subtree whose leaves all share a
        # small leading dim (the layer count). Requiring >= 2 leaves and
        # dim <= 256 excludes single big arrays: without that, the vocab
        # embedding (152k, d) was scanned row-by-row — a 151936-trip
        # update loop (caught by the dry-run trip-count audit).
        leaves = jax.tree_util.tree_leaves(subtree)
        if len(leaves) < 2:
            return None
        dims = {x.shape[0] if getattr(x, "ndim", 0) > 0 else None
                for x in leaves}
        d = dims.pop() if len(dims) == 1 else None
        return d if (d is not None and min_layers <= d <= 256) else None

    def init(params):
        return opt.init(params)

    def update(grads, state, params):
        if not isinstance(params, dict):
            return opt.update(grads, state, params)
        step = state.get("step")
        new_p, new_s = {}, {}
        # state trees mirror params one level down inside each state field
        state_fields = [k for k in state if k != "step"]

        for key, p_sub in params.items():
            g_sub = grads[key]
            s_sub = {f: state[f][key] for f in state_fields}
            n = _stacked_dim(p_sub)
            if n is not None and _stacked_dim(g_sub) == n and all(
                    _stacked_dim(s_sub[f]) == n for f in state_fields):
                def body(_, xs):
                    p_l, g_l, s_l = xs
                    st_l = dict(s_l)
                    st_l["step"] = step
                    p_new, st_new = opt.update(g_l, st_l, p_l)
                    return None, (p_new,
                                  {f: st_new[f] for f in state_fields})
                _, (p_new, s_new) = jax.lax.scan(
                    body, None, (p_sub, g_sub, s_sub))
            else:
                st = dict(s_sub)
                st["step"] = step
                p_new, st_new = opt.update(g_sub, st, p_sub)
                s_new = {f: st_new[f] for f in state_fields}
            new_p[key] = p_new
            for f in state_fields:
                new_s.setdefault(f, {})[key] = s_new[f]
        new_s["step"] = step + 1
        return new_p, new_s

    return Optimizer(init, update)


def state_logical_specs(name: str, param_specs, param_shapes):
    """Logical sharding specs for an optimizer's state, mirroring the rules
    used for params (needed to attach shardings to dry-run ShapeDtypeStructs).

    param_specs / param_shapes: pytrees with tuple leaves (specs) and tuple
    leaves (shapes) of identical structure.
    """
    scalar = ()

    def map2(f):
        return jax.tree_util.tree_map(
            f, param_specs, param_shapes,
            is_leaf=lambda x: isinstance(x, tuple) and all(
                v is None or isinstance(v, (str, int)) for v in x))

    if name == "adamw":
        full = map2(lambda s, _: s)
        return {"m": full, "v": full, "step": scalar}
    if name == "sgd":
        return {"mu": map2(lambda s, _: s), "step": scalar}
    if name == "rowwise_adagrad":
        return {"acc": map2(lambda s, _: s[:-1] + (None,)), "step": scalar}
    if name == "adafactor":
        def fac(s, shape):
            if len(shape) >= 2:
                return {"vr": s[:-1], "vc": s[:-2] + s[-1:]}
            return {"v": s}
        return {"fac": map2(fac), "step": scalar}
    raise ValueError(name)


def from_config(cfg) -> Optimizer:
    """Build from configs.base.OptimizerConfig."""
    if cfg.name == "sgd":
        return sgd(cfg.lr)
    if cfg.name == "adamw":
        return adamw(cfg.lr, cfg.beta1, cfg.beta2, cfg.eps, cfg.weight_decay)
    if cfg.name == "adafactor":
        return adafactor(cfg.lr)
    raise ValueError(f"unknown optimizer {cfg.name!r}")
