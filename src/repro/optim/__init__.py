from repro.optim import optimizers
from repro.optim.optimizers import (Optimizer, adafactor, adamw,
                                    clip_by_global_norm, from_config,
                                    global_norm, layerwise, partitioned,
                                    rowwise_adagrad, sgd, warmup_cosine)

__all__ = ["Optimizer", "adafactor", "adamw", "clip_by_global_norm",
           "from_config", "global_norm", "layerwise", "optimizers",
           "partitioned", "rowwise_adagrad", "sgd", "warmup_cosine"]
