"""HybridSparseDense — the Centaur orchestration layer.

Three execution strategies over the same parameters:

* ``baseline_forward`` — the paper's **CPU-only baseline**: naive
  gather-materialize-reduce (``table[idx]`` then ``sum``) and plain jnp
  matmuls. This is the reproduction floor every speedup is measured against.
* ``forward`` (in ``dlrm.py``) — sparse engine + dense engine, concurrent by
  graph structure (single batch).
* ``pipelined_forward`` — microbatch software pipeline: while the dense
  engine runs interaction+MLPs for microbatch *i*, the sparse engine streams
  gathers for microbatch *i+1* (paper Section IV-D: "the entire dense GEMM
  computation is orchestrated seamlessly with the sparse accelerator").
  Expressed as a stage-skewed ``lax.scan``: the gather for the next
  microbatch and the dense math for the current one live in the same scan
  body with no data dependence, so the TPU scheduler overlaps DMA/collective
  traffic with MXU work.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import DLRMConfig
from repro.core import dense_engine as de
from repro.core import dlrm as dlrm_mod
from repro.core import embedding_source as es
from repro.core import sparse_engine as se
from repro.kernels import ref as kref


# ---------------------------------------------------------------------------
# CPU-only baseline (paper Section III)
# ---------------------------------------------------------------------------

def baseline_forward(params: Dict, cfg: DLRMConfig, dense: jax.Array,
                     indices: jax.Array) -> jax.Array:
    """Naive path: materialize gathered rows, reduce, jnp matmul MLPs."""
    spec = dlrm_mod.arena_spec(cfg)
    flat = se.flatten_indices(spec, indices)               # (B*T, L)
    rows = params["arena"][flat]                           # materialized!
    emb = rows.astype(jnp.float32).sum(axis=1)
    emb = emb.reshape(indices.shape[0], spec.n_tables, spec.dim)
    emb = emb.astype(params["arena"].dtype)

    bot = kref.mlp(dense, [w for w, _ in params["bottom"]],
                   [b for _, b in params["bottom"]])
    feats = jnp.concatenate([bot[:, None, :], emb], axis=1)
    pairs = kref.interaction_tril(feats)
    x = jnp.concatenate([bot, pairs], axis=-1)
    logit = kref.mlp(x, [w for w, _ in params["top"]],
                     [b for _, b in params["top"]])
    return logit[:, 0]


# ---------------------------------------------------------------------------
# Microbatch-pipelined hybrid execution
# ---------------------------------------------------------------------------

def pipelined_forward(params: Dict, cfg: DLRMConfig, dense: jax.Array,
                      indices: jax.Array, n_micro: int = 4,
                      mesh: Optional[jax.sharding.Mesh] = None) -> jax.Array:
    """Stage-skewed pipeline over n_micro microbatches."""
    spec = dlrm_mod.arena_spec(cfg)
    b = dense.shape[0]
    assert b % n_micro == 0, (b, n_micro)
    mb = b // n_micro
    dense_s = dense.reshape(n_micro, mb, -1)
    idx_s = indices.reshape(n_micro, mb, spec.n_tables, -1)

    # Prologue: gather microbatch 0's embeddings.
    src = es.resolve_source(params["arena"], mesh)
    emb0 = es.lookup_fixed(src, spec, idx_s[0])
    # Next-microbatch index stream. The last microbatch has no successor:
    # its "next" gather used to wrap around to microbatch 0 and be
    # discarded — a full wasted EB-Streamer pass. Feed all-null-row
    # indices instead: the gather degenerates to reducing one always-zero
    # (hence cache-resident) row, costing no real row traffic.
    dummy = se.null_indices(spec, (1,) + idx_s.shape[1:])
    idx_next = jnp.concatenate([idx_s[1:], dummy], axis=0)

    def body(emb_i, xs):
        dense_i, idx_n = xs
        # dense stage for microbatch i ...
        bot = de.mlp_apply(params["bottom"], dense_i)
        x, _ = de.feature_interaction(bot, emb_i)
        logit = de.mlp_apply(params["top"], x)[:, 0]
        # ... overlapped with the sparse stage for microbatch i+1
        emb_n = es.lookup_fixed(src, spec, idx_n)
        return emb_n, logit

    _, logits = jax.lax.scan(body, emb0, (dense_s, idx_next))
    return logits.reshape(b)


def make_pipelined_serve_step(cfg: DLRMConfig, n_micro: int = 4,
                              mesh: Optional[jax.sharding.Mesh] = None):
    def serve_step(params, batch):
        return jax.nn.sigmoid(pipelined_forward(
            params, cfg, batch["dense"], batch["indices"], n_micro, mesh))
    return serve_step


# ---------------------------------------------------------------------------
# Ragged microbatch pipeline (per-microbatch offsets)
# ---------------------------------------------------------------------------

def split_ragged_microbatches(indices: jax.Array, offsets: jax.Array,
                              n_micro: int, max_l: int):
    """Slice one ragged batch into n_micro static-shape ragged streams.

    indices (N,) flat per-table ids (padding allowed); offsets (B*T+1,)
    with B*T divisible by n_micro. Each microbatch i gets its bag range
    re-based to local offsets and its index slice padded to the static cap
    bags_per_micro * max_l (pad positions sit past the local offsets[-1],
    so every ragged consumer ignores them). Pure static slices + gathers —
    jit/scan-safe even though bag boundaries are data-dependent.
    """
    n_bags = offsets.shape[0] - 1
    assert n_bags % n_micro == 0, (n_bags, n_micro)
    per = n_bags // n_micro
    cap = per * max_l
    ar = jnp.arange(cap)
    idx_list, off_list = [], []
    for i in range(n_micro):
        base = offsets[i * per]
        off_list.append(offsets[i * per:(i + 1) * per + 1] - base)
        pos = jnp.minimum(base + ar, indices.shape[0] - 1)
        idx_list.append(jnp.take(indices, pos))
    return jnp.stack(idx_list), jnp.stack(off_list)


def pipelined_forward_ragged(params: Dict, cfg: DLRMConfig,
                             dense: jax.Array, indices: jax.Array,
                             offsets: jax.Array, *, max_l: int,
                             n_micro: int = 4,
                             mesh: Optional[jax.sharding.Mesh] = None
                             ) -> jax.Array:
    """Stage-skewed pipeline over ragged microbatches.

    Same overlap structure as `pipelined_forward`, but the sparse stage is
    the ragged production path: each scan step reduces microbatch i's
    dense math while streaming microbatch i+1's ragged gathers. The tail
    dummy is a stream of all-empty bags (offsets all zero) — the cheapest
    possible no-op pass.
    """
    spec = dlrm_mod.arena_spec(cfg)
    b = dense.shape[0]
    assert b % n_micro == 0, (b, n_micro)
    mb = b // n_micro
    assert offsets.shape[0] - 1 == b * spec.n_tables
    dense_s = dense.reshape(n_micro, mb, -1)
    idx_s, off_s = split_ragged_microbatches(indices, offsets, n_micro,
                                             max_l)

    src = es.resolve_source(params["arena"], mesh)
    emb0 = es.lookup_bags(src, spec, idx_s[0], off_s[0], max_l=max_l)
    idx_next = jnp.concatenate([idx_s[1:], jnp.zeros_like(idx_s[:1])], 0)
    off_next = jnp.concatenate([off_s[1:], jnp.zeros_like(off_s[:1])], 0)

    def body(emb_i, xs):
        dense_i, idx_n, off_n = xs
        bot = de.mlp_apply(params["bottom"], dense_i)
        x, _ = de.feature_interaction(bot, emb_i.astype(bot.dtype))
        logit = de.mlp_apply(params["top"], x)[:, 0]
        emb_n = es.lookup_bags(src, spec, idx_n, off_n, max_l=max_l)
        return emb_n, logit

    _, logits = jax.lax.scan(body, emb0, (dense_s, idx_next, off_next))
    return logits.reshape(b)
