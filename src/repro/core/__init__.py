# The paper's primary contribution: the hybrid sparse-dense engine.
from repro.core import dense_engine, dlrm, hybrid, sparse_engine

__all__ = ["dense_engine", "dlrm", "hybrid", "sparse_engine"]
