# The paper's primary contribution: the hybrid sparse-dense engine.
# `embedding_source` is the unified sparse-path API (one lookup entry
# point over pytree-swappable sources); `sparse_engine` keeps the arena
# layout, shard-local protocol, and hot-cache structures underneath it.
from repro.core import (dense_engine, dlrm, embedding_source, hybrid,
                        sparse_engine)

__all__ = ["dense_engine", "dlrm", "embedding_source", "hybrid",
           "sparse_engine"]
