"""The Centaur dense engine: tiled GEMM executor for MLPs + interaction.

Wraps the output-stationary Pallas GEMM (``repro.kernels.gemm``) into the two
dense stages of the paper's pipeline (Fig. 11): the MLP unit (bottom/top
MLPs) and the feature-interaction unit (batched X X^T + lower-tri concat).
"""
from __future__ import annotations

from typing import Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops


def init_mlp(key: jax.Array, dims: Sequence[int], dtype=jnp.float32):
    """dims = (in, h1, ..., out); returns list of (w, b)."""
    params = []
    for i in range(len(dims) - 1):
        key, k = jax.random.split(key)
        scale = (2.0 / dims[i]) ** 0.5
        w = scale * jax.random.normal(k, (dims[i], dims[i + 1]), jnp.float32)
        b = jnp.zeros((dims[i + 1],), jnp.float32)
        params.append((w.astype(dtype), b.astype(dtype)))
    return params


def mlp_apply(params, x: jax.Array, act=jax.nn.relu,
              final_act=None) -> jax.Array:
    """Run the MLP unit: GEMM per layer on the dense engine."""
    h = x
    for i, (w, b) in enumerate(params):
        h = ops.gemm(h, w) + b
        if i < len(params) - 1:
            h = act(h)
        elif final_act is not None:
            h = final_act(h)
    return h


def feature_interaction(bottom_out: jax.Array,
                        reduced_embs: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Paper Fig. 3: concat bottom-MLP vector with reduced embeddings, take
    all pairwise dots (lower triangle), concat with bottom-MLP output.

    bottom_out: (B, D); reduced_embs: (B, T, D) -> interaction input (B, F*D')
    """
    feats = jnp.concatenate([bottom_out[:, None, :], reduced_embs], axis=1)
    pairs = ops.interaction_tril(feats)            # (B, F(F-1)/2)
    return jnp.concatenate([bottom_out, pairs], axis=-1), feats
