"""DLRM — the paper's model (Fig. 1/3), built on the sparse + dense engines.

Topology: dense features -> bottom MLP ─┐
          sparse indices -> embedding    ├─> feature interaction -> top MLP
          gather+reduce (sparse engine) ─┘         -> sigmoid -> CTR

Training uses row-wise Adagrad on the embedding arena (sparse engine state)
and AdamW on the MLPs, matching production DLRM practice.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import DLRMConfig
from repro.core import dense_engine as de
from repro.core import sparse_engine as se
from repro.optim import adamw, partitioned, rowwise_adagrad


def arena_spec(cfg: DLRMConfig) -> se.ArenaSpec:
    return se.ArenaSpec(cfg.n_tables, cfg.rows_per_table, cfg.emb_dim,
                        cfg.dtype)


def top_mlp_in_dim(cfg: DLRMConfig) -> int:
    f = cfg.n_interact_features
    return cfg.emb_dim + f * (f - 1) // 2


def init(key: jax.Array, cfg: DLRMConfig, shards: int = 1) -> Dict:
    k_arena, k_bot, k_top = jax.random.split(key, 3)
    spec = arena_spec(cfg)
    assert cfg.bottom_mlp[-1] == cfg.emb_dim, (
        "bottom MLP must end at emb_dim so its output joins the interaction")
    return {
        "arena": se.init_arena(k_arena, spec, shards),
        "bottom": de.init_mlp(k_bot, (cfg.dense_features,) + cfg.bottom_mlp),
        "top": de.init_mlp(k_top, (top_mlp_in_dim(cfg),) + cfg.top_mlp),
    }


def forward(params: Dict, cfg: DLRMConfig, dense: jax.Array,
            indices: jax.Array,
            mesh: Optional[jax.sharding.Mesh] = None) -> jax.Array:
    """dense: (B, dense_features); indices: (B, T, L) -> logits (B,).

    The graph is deliberately structured so the sparse stage (gather+psum)
    and the bottom-MLP GEMMs have no data dependence: on TPU the async
    collective combine of embedding shards overlaps the dense compute —
    the Centaur sparse/dense concurrency, expressed at the XLA level.
    """
    spec = arena_spec(cfg)
    emb = se.lookup_auto(params["arena"], spec, indices, mesh)  # sparse stage
    bot = de.mlp_apply(params["bottom"], dense)                 # dense stage
    x, _ = de.feature_interaction(bot, emb)
    logit = de.mlp_apply(params["top"], x)
    return logit[:, 0]


def forward_ragged(params: Dict, cfg: DLRMConfig, dense: jax.Array,
                   indices: jax.Array, offsets: jax.Array, *, max_l: int,
                   mesh: Optional[jax.sharding.Mesh] = None,
                   cache: Optional[se.HotRowCache] = None,
                   quantized=None) -> jax.Array:
    """Ragged-bag forward: the production SparseLengthsSum path.

    dense: (B, dense_features); indices: flat per-table row-id stream (N,),
    possibly padded; offsets: (B*T+1,) ragged bag boundaries in (sample,
    table) row-major order; max_l: static per-bag length bound.

    Embedding source selection (serving-time path selection, MP-Rec-style):
      * cache=None, quantized=None — sharded/replicated fp arena;
      * cache set                  — hot-row cache + fp cold arena (exact);
      * cache + quantized=(q, s)   — hot rows fp, cold rows int8.
    """
    spec = arena_spec(cfg)
    if cache is not None and quantized is not None:
        emb = se.lookup_ragged_cached_q(cache, quantized[0], quantized[1],
                                        spec, indices, offsets, max_l=max_l)
    elif cache is not None:
        emb = se.lookup_ragged_cached(cache, params["arena"], spec, indices,
                                      offsets, max_l=max_l)
    else:
        emb = se.lookup_ragged_auto(params["arena"], spec, indices, offsets,
                                    max_l=max_l, mesh=mesh)
    bot = de.mlp_apply(params["bottom"], dense)
    x, _ = de.feature_interaction(bot, emb.astype(bot.dtype))
    logit = de.mlp_apply(params["top"], x)
    return logit[:, 0]


def loss_fn(params: Dict, cfg: DLRMConfig, dense: jax.Array,
            indices: jax.Array, labels: jax.Array,
            mesh: Optional[jax.sharding.Mesh] = None) -> jax.Array:
    """Binary cross-entropy on click labels."""
    logits = forward(params, cfg, dense, indices, mesh)
    logp = jax.nn.log_sigmoid(logits)
    lognp = jax.nn.log_sigmoid(-logits)
    return -(labels * logp + (1 - labels) * lognp).mean()


def make_optimizer(cfg: DLRMConfig, lr: float = 1e-3):
    return partitioned({"arena": rowwise_adagrad(lr * 10)}, adamw(lr))


def make_train_step(cfg: DLRMConfig, optimizer=None,
                    mesh: Optional[jax.sharding.Mesh] = None):
    opt = optimizer or make_optimizer(cfg)

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(
            params, cfg, batch["dense"], batch["indices"], batch["labels"],
            mesh)
        new_params, new_state = opt.update(grads, opt_state, params)
        return new_params, new_state, loss

    return opt, train_step


def make_serve_step(cfg: DLRMConfig,
                    mesh: Optional[jax.sharding.Mesh] = None):
    def serve_step(params, batch):
        return jax.nn.sigmoid(
            forward(params, cfg, batch["dense"], batch["indices"], mesh))
    return serve_step


def make_ragged_serve_step(cfg: DLRMConfig, *, max_l: int,
                           mesh: Optional[jax.sharding.Mesh] = None,
                           cache: Optional[se.HotRowCache] = None,
                           quantized=None):
    """Serve step over ragged batches ({dense, indices, offsets} -> CTR)."""
    def serve_step(params, batch):
        return jax.nn.sigmoid(forward_ragged(
            params, cfg, batch["dense"], batch["indices"],
            batch["offsets"], max_l=max_l, mesh=mesh, cache=cache,
            quantized=quantized))
    return serve_step
