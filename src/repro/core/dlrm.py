"""DLRM — the paper's model (Fig. 1/3), built on the sparse + dense engines.

Topology: dense features -> bottom MLP ─┐
          sparse indices -> embedding    ├─> feature interaction -> top MLP
          gather+reduce (sparse engine) ─┘         -> sigmoid -> CTR

Training uses row-wise Adagrad on the embedding arena (sparse engine state)
and AdamW on the MLPs, matching production DLRM practice.
"""
from __future__ import annotations

import warnings
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro import compat
from repro.configs.base import DLRMConfig
from repro.core import dense_engine as de
from repro.core import embedding_source as es
from repro.core import sparse_engine as se
from repro.optim import Optimizer, adamw, partitioned, rowwise_adagrad


def arena_spec(cfg: DLRMConfig) -> se.ArenaSpec:
    return se.ArenaSpec(cfg.n_tables, cfg.rows_per_table, cfg.emb_dim,
                        cfg.dtype)


def top_mlp_in_dim(cfg: DLRMConfig) -> int:
    f = cfg.n_interact_features
    return cfg.emb_dim + f * (f - 1) // 2


def init(key: jax.Array, cfg: DLRMConfig, shards: int = 1) -> Dict:
    k_arena, k_bot, k_top = jax.random.split(key, 3)
    spec = arena_spec(cfg)
    assert cfg.bottom_mlp[-1] == cfg.emb_dim, (
        "bottom MLP must end at emb_dim so its output joins the interaction")
    return {
        "arena": se.init_arena(k_arena, spec, shards),
        "bottom": de.init_mlp(k_bot, (cfg.dense_features,) + cfg.bottom_mlp),
        "top": de.init_mlp(k_top, (top_mlp_in_dim(cfg),) + cfg.top_mlp),
    }


def head_logits(mlp_params: Dict, dense: jax.Array,
                emb: jax.Array) -> jax.Array:
    """The DLRM head shared by every forward AND training path: reduced
    embeddings (B, T, D) + dense features -> logits (B,). One definition,
    so the trained network and the served network cannot drift apart."""
    bot = de.mlp_apply(mlp_params["bottom"], dense)
    x, _ = de.feature_interaction(bot, emb.astype(bot.dtype))
    return de.mlp_apply(mlp_params["top"], x)[:, 0]


def _legacy_source(params: Dict, mesh, cache, quantized,
                   axis: str = "model") -> es.EmbeddingSource:
    """Map the deprecated (mesh, cache, quantized) kwarg soup onto an
    EmbeddingSource (cache/quantized warn; mesh alone is the default
    sharded construction, not deprecated)."""
    if cache is not None or quantized is not None:
        warnings.warn(
            "dlrm forward kwargs cache=/quantized= are deprecated; pass "
            "source=<EmbeddingSource> instead (see the README migration "
            "table)", DeprecationWarning, stacklevel=3)
    return _compose_legacy(params, mesh, cache, quantized, axis)


def _compose_legacy(params: Dict, mesh, cache, quantized,
                    axis: str = "model") -> es.EmbeddingSource:
    # legacy contract: quantized only ever applied to the CACHED cold
    # pass; without a cache it was ignored (fp arena served)
    if cache is not None and quantized is not None:
        cold: es.EmbeddingSource = es.QuantizedArena(q=quantized[0],
                                                     scales=quantized[1])
        if se.mesh_shards(mesh, axis) > 1:
            cold = es.ShardedArena(cold, mesh, axis)
    else:
        cold = es.resolve_source(params["arena"], mesh, axis)
    return cold if cache is None else es.CachedSource(hot=cache, cold=cold)


def forward(params: Dict, cfg: DLRMConfig, dense: jax.Array,
            indices: jax.Array,
            mesh: Optional[jax.sharding.Mesh] = None, *,
            source: Optional[es.EmbeddingSource] = None) -> jax.Array:
    """dense: (B, dense_features); indices: (B, T, L) -> logits (B,).

    The sparse stage is ``embedding_source.lookup_fixed`` over `source`
    (default: the fp arena in `params`, row-sharded when a mesh is given).
    The graph is deliberately structured so the sparse stage (gather+psum)
    and the bottom-MLP GEMMs have no data dependence: on TPU the async
    collective combine of embedding shards overlaps the dense compute —
    the Centaur sparse/dense concurrency, expressed at the XLA level.
    """
    spec = arena_spec(cfg)
    if source is None:
        source = es.resolve_source(params["arena"], mesh)
    emb = es.lookup_fixed(source, spec, indices)      # sparse stage
    return head_logits(params, dense, emb)            # dense stage


def forward_ragged(params: Dict, cfg: DLRMConfig, dense: jax.Array,
                   indices: jax.Array, offsets: jax.Array, *, max_l: int,
                   source: Optional[es.EmbeddingSource] = None,
                   mesh: Optional[jax.sharding.Mesh] = None,
                   cache: Optional[se.HotRowCache] = None,
                   quantized=None) -> jax.Array:
    """Ragged-bag forward: the production SparseLengthsSum path.

    dense: (B, dense_features); indices: flat per-table row-id stream (N,),
    possibly padded; offsets: (B*T+1,) ragged bag boundaries in (sample,
    table) row-major order; max_l: static per-bag length bound.

    The embedding stage is ``embedding_source.lookup_bags`` over `source`
    — ANY composition (fp / int8 / sharded / hot-cached) through the one
    entry point; serving-time path selection (MP-Rec-style) is the choice
    of source *value*, not of function. source=None defaults to the fp
    arena in `params`, row-sharded over the mesh's 'model' axis when a
    mesh is given. The legacy cache=/quantized= kwargs are deprecated
    shims onto the equivalent CachedSource/QuantizedArena.
    """
    spec = arena_spec(cfg)
    if source is None:
        source = _legacy_source(params, mesh, cache, quantized)
    elif cache is not None or quantized is not None:
        raise ValueError(
            "forward_ragged got BOTH source= and the deprecated cache=/"
            "quantized= kwargs — the legacy kwargs would be silently "
            "ignored; compose them into the source instead")
    emb = es.lookup_bags(source, spec, indices, offsets, max_l=max_l)
    return head_logits(params, dense, emb)


def _bce(logits: jax.Array, labels: jax.Array) -> jax.Array:
    logp = jax.nn.log_sigmoid(logits)
    lognp = jax.nn.log_sigmoid(-logits)
    return -(labels * logp + (1 - labels) * lognp).mean()


def loss_fn(params: Dict, cfg: DLRMConfig, dense: jax.Array,
            indices: jax.Array, labels: jax.Array,
            mesh: Optional[jax.sharding.Mesh] = None) -> jax.Array:
    """Binary cross-entropy on click labels."""
    return _bce(forward(params, cfg, dense, indices, mesh), labels)


def loss_ragged(params: Dict, cfg: DLRMConfig, dense: jax.Array,
                indices: jax.Array, offsets: jax.Array, labels: jax.Array,
                *, max_l: int,
                mesh: Optional[jax.sharding.Mesh] = None) -> jax.Array:
    """BCE over the ragged production path — differentiable on every
    kernel backend via the sparse_lengths_sum custom VJP."""
    logits = forward_ragged(params, cfg, dense, indices, offsets,
                            max_l=max_l, mesh=mesh)
    return _bce(logits, labels)


def make_optimizer(cfg: DLRMConfig, lr: float = 1e-3):
    return partitioned({"arena": rowwise_adagrad(lr * 10)}, adamw(lr))


def make_train_step(cfg: DLRMConfig, optimizer=None,
                    mesh: Optional[jax.sharding.Mesh] = None):
    opt = optimizer or make_optimizer(cfg)

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(
            params, cfg, batch["dense"], batch["indices"], batch["labels"],
            mesh)
        new_params, new_state = opt.update(grads, opt_state, params)
        return new_params, new_state, loss

    return opt, train_step


def make_train_step_ragged(cfg: DLRMConfig, *, max_l: int, lr: float = 1e-3,
                           sparse: bool = True,
                           mesh: Optional[jax.sharding.Mesh] = None,
                           sharded: Optional[bool] = None,
                           axis: str = "model"):
    """Train step over ragged batches {dense, indices, offsets, labels}.

    Returns (opt_like, step) where step(params, opt_state, batch) ->
    (new_params, new_opt_state, loss, touched_rows); touched_rows (N,) are
    the unique arena rows the batch updated (fill = null row), which the
    online trainer feeds to the hot-cache write-through invalidation.

    sparse=True composes the row-wise *sparse* optimizer on the arena
    (update cost O(N) in the index-stream length, no densified (V, D)
    gradient) with AdamW on the MLPs; sparse=False is the dense-gradient
    baseline (jax.grad through the whole model + partitioned row-wise
    Adagrad), kept for the bench comparison.

    sharded=True (the default whenever sparse=True and `mesh` has a >1
    `axis`) runs the whole sparse step inside shard_map: the arena and its
    Adagrad accumulator live row-sharded over `axis`, the forward reduces
    shard-local partial bags (one psum of reduced D-vectors, never raw
    rows), each shard applies exactly the row updates it owns (null row
    excluded), and MLP grads are psum-combined so every replica steps in
    lockstep. Exact vs the replicated sparse step and the dense-grad
    baseline.
    """
    from repro.training import sparse_optim as so

    spec = arena_spec(cfg)
    if sharded is None:
        sharded = sparse and se.mesh_shards(mesh, axis) > 1
    if sharded:
        if not sparse:
            raise ValueError("sharded=True is the sparse-optimizer path; "
                             "the dense-grad baseline threads the mesh "
                             "through the default sharded source instead")
        if mesh is None or axis not in mesh.axis_names:
            raise ValueError(f"sharded=True needs a mesh with axis "
                             f"{axis!r}")
        return _make_train_step_ragged_sharded(cfg, spec, max_l=max_l,
                                               lr=lr, mesh=mesh, axis=axis)
    if sparse and mesh is not None and se.mesh_shards(mesh, axis) > 1:
        raise ValueError(
            "sparse ragged training on a mesh must be sharded — the "
            "replicated sparse branch would silently train a per-device "
            "arena copy; pass sharded=True (or leave sharded=None)")
    if not sparse:
        opt = make_optimizer(cfg, lr)

        def dense_step(params, opt_state, batch):
            loss, grads = jax.value_and_grad(loss_ragged)(
                params, cfg, batch["dense"], batch["indices"],
                batch["offsets"], batch["labels"], max_l=max_l, mesh=mesh)
            new_params, new_state = opt.update(grads, opt_state, params)
            flat = se.flatten_ragged_indices(spec, batch["indices"],
                                             batch["offsets"])
            rows, _ = jnp.unique(flat, size=flat.shape[0],
                                 fill_value=spec.null_row,
                                 return_inverse=True)
            return new_params, new_state, loss, rows.astype(jnp.int32)

        return opt, dense_step

    arena_opt = so.sparse_rowwise_adagrad(lr * 10)
    mlp_opt = adamw(lr)

    def init(params):
        return {"arena": arena_opt.init(params["arena"]),
                "mlp": mlp_opt.init({k: v for k, v in params.items()
                                     if k != "arena"})}

    def step(params, opt_state, batch):
        n_bags = batch["offsets"].shape[0] - 1
        # Forward the sparse stage once through the unified entry point;
        # its VJP w.r.t. the arena is a pure scatter of the bag gradients,
        # which the row-wise path applies directly — the arena never
        # enters autodiff (stop_gradient), so the update stays O(N).
        emb = es.lookup_bags(
            es.FpArena(jax.lax.stop_gradient(params["arena"])), spec,
            batch["indices"], batch["offsets"], max_l=max_l)

        def head(mlp_params, emb):
            return _bce(head_logits(mlp_params, batch["dense"], emb),
                        batch["labels"])

        mlp_params = {k: v for k, v in params.items() if k != "arena"}
        loss, (d_mlp, d_emb) = jax.value_and_grad(head, argnums=(0, 1))(
            mlp_params, emb)

        d_bags = d_emb.reshape(n_bags, spec.dim)
        rows, row_g = so.source_row_grads(spec, d_bags, batch["indices"],
                                          batch["offsets"])
        new_arena, arena_state = arena_opt.update(
            params["arena"], opt_state["arena"], rows, row_g)
        new_mlp, mlp_state = mlp_opt.update(d_mlp, opt_state["mlp"],
                                            mlp_params)
        new_params = dict(new_mlp)
        new_params["arena"] = new_arena
        return new_params, {"arena": arena_state, "mlp": mlp_state}, \
            loss, rows

    return Optimizer(init, None), step


def _make_train_step_ragged_sharded(cfg: DLRMConfig, spec: se.ArenaSpec, *,
                                    max_l: int, lr: float,
                                    mesh: jax.sharding.Mesh, axis: str):
    """Row-sharded sparse train step (see make_train_step_ragged).

    Everything runs per-shard inside one shard_map: the only cross-chip
    traffic per step is the psum of reduced bag partials (forward) and the
    psum of MLP grads (backward) — row gradients never leave the shard
    that owns the rows, which is what keeps the update O(index stream)
    at any shard count.
    """
    from jax.sharding import PartitionSpec as P

    from repro.training import sparse_optim as so

    arena_opt = so.sparse_rowwise_adagrad(lr * 10)
    mlp_opt = adamw(lr)
    null = spec.null_row
    arena_state_spec = {"acc": P(axis, None), "step": P()}

    def init(params):
        return {"arena": arena_opt.init(params["arena"]),
                "mlp": mlp_opt.init({k: v for k, v in params.items()
                                     if k != "arena"})}

    def local_step(arena_shard, arena_state, mlp_params, mlp_state, batch):
        lo, vlocal = se.shard_row_range(arena_shard, axis)
        flat = se.flatten_ragged_indices(spec, batch["indices"],
                                         batch["offsets"])
        n_bags = batch["offsets"].shape[0] - 1
        b = n_bags // spec.n_tables
        emb = se.ragged_partial_reduce(jax.lax.stop_gradient(arena_shard),
                                       flat, batch["offsets"], axis)
        emb = emb.reshape(b, spec.n_tables, spec.dim) \
            .astype(arena_shard.dtype)

        def head(mlp_params, emb):
            return _bce(head_logits(mlp_params, batch["dense"], emb),
                        batch["labels"])

        loss, (d_mlp, d_emb) = jax.value_and_grad(head, argnums=(0, 1))(
            mlp_params, emb)
        # the batch is replicated over the model axis, so per-shard MLP
        # grads are already equal; the psum/N keeps replicas in lockstep
        # under non-deterministic reductions and is where a data-parallel
        # batch axis would combine partials
        d_mlp = jax.tree_util.tree_map(
            lambda g: jax.lax.pmean(g, axis), d_mlp)

        d_bags = d_emb.reshape(n_bags, spec.dim)
        rows, row_g = so.ragged_row_grads(d_bags, flat, batch["offsets"],
                                          fill_row=null)
        lrows, lg = so.shard_local_rows(rows, row_g, lo=lo, vlocal=vlocal,
                                        null_row=null)
        new_shard, new_arena_state = arena_opt.update(
            arena_shard, arena_state, lrows, lg)
        new_mlp, new_mlp_state = mlp_opt.update(d_mlp, mlp_state,
                                                mlp_params)
        return new_shard, new_arena_state, new_mlp, new_mlp_state, loss, \
            rows

    fn = compat.shard_map(
        local_step, mesh=mesh,
        in_specs=(P(axis, None), arena_state_spec, P(), P(), P()),
        out_specs=(P(axis, None), arena_state_spec, P(), P(), P(), P()),
    )

    def step(params, opt_state, batch):
        mlp_params = {k: v for k, v in params.items() if k != "arena"}
        new_arena, arena_state, new_mlp, mlp_state, loss, rows = fn(
            params["arena"], opt_state["arena"], mlp_params,
            opt_state["mlp"], batch)
        new_params = dict(new_mlp)
        new_params["arena"] = new_arena
        return new_params, {"arena": arena_state, "mlp": mlp_state}, \
            loss, rows

    return Optimizer(init, None), step


def make_serve_step(cfg: DLRMConfig,
                    mesh: Optional[jax.sharding.Mesh] = None):
    def serve_step(params, batch):
        return jax.nn.sigmoid(
            forward(params, cfg, batch["dense"], batch["indices"], mesh))
    return serve_step


def make_ragged_serve_step(cfg: DLRMConfig, *, max_l: int,
                           mesh: Optional[jax.sharding.Mesh] = None,
                           cache: Optional[se.HotRowCache] = None,
                           quantized=None):
    """Serve step over ragged batches ({dense, indices, offsets} -> CTR).

    The embedding source is a call-time pytree argument — that is how the
    serving engine swaps in a new version of ANY source component (hot
    cache, quantized cold arena, the full fp arena) without recompiling:
    same treedef + same leaf shapes = same compiled executable. With
    source=None the fp arena in `params` serves (mesh-sharded when given).

    Back-compat shims (both warn): the legacy build-time cache=/quantized=
    kwargs, and a bare HotRowCache passed as the per-call third argument
    (the pre-API calling convention) — each is composed into the
    equivalent CachedSource.
    """
    if cache is not None or quantized is not None:
        warnings.warn(
            "make_ragged_serve_step kwargs cache=/quantized= are "
            "deprecated; pass source=<EmbeddingSource> per call instead",
            DeprecationWarning, stacklevel=2)
    default_cache, default_q = cache, quantized

    def serve_step(params, batch, source=None):
        if source is None and default_cache is not None:
            source = _legacy_source(params, mesh, default_cache,
                                    default_q)
        elif isinstance(source, se.HotRowCache):
            warnings.warn(
                "passing a bare HotRowCache to the serve step is "
                "deprecated; pass a CachedSource (or any "
                "EmbeddingSource) instead", DeprecationWarning,
                stacklevel=2)
            # honor the build-time quantized= arena exactly like the
            # legacy cached_q path did for per-call cache swaps
            source = _compose_legacy(params, mesh, source, default_q)
        return jax.nn.sigmoid(forward_ragged(
            params, cfg, batch["dense"], batch["indices"],
            batch["offsets"], max_l=max_l, mesh=mesh, source=source))
    return serve_step
