"""DLRM — the paper's model (Fig. 1/3), built on the sparse + dense engines.

Topology: dense features -> bottom MLP ─┐
          sparse indices -> embedding    ├─> feature interaction -> top MLP
          gather+reduce (sparse engine) ─┘         -> sigmoid -> CTR

Training uses row-wise Adagrad on the embedding arena (sparse engine state)
and AdamW on the MLPs, matching production DLRM practice.
"""
from __future__ import annotations

import warnings
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro import compat
from repro.configs.base import DLRMConfig
from repro.core import dense_engine as de
from repro.core import embedding_source as es
from repro.core import sparse_engine as se
from repro.obs.tracing import stage as obs_stage
from repro.optim import Optimizer, adamw, partitioned, rowwise_adagrad


def arena_spec(cfg: DLRMConfig) -> se.ArenaSpec:
    """The uniform ArenaSpec, or — for a heterogeneous config — the
    group's *envelope* (n_tables, max vocab, max dim): the entry points
    consume only its n_tables/dim fields for a table group (a group never
    flattens into one shared arena)."""
    if cfg.heterogeneous:
        return se.ArenaSpec(cfg.n_tables, max(cfg.table_rows),
                            max(cfg.table_dims), cfg.dtype)
    return se.ArenaSpec(cfg.n_tables, cfg.rows_per_table, cfg.emb_dim,
                        cfg.dtype)


def member_specs(cfg: DLRMConfig):
    """Per-table single-table ArenaSpecs of a heterogeneous config."""
    return tuple(se.ArenaSpec(1, r, d, cfg.dtype)
                 for r, d in zip(cfg.resolved_table_rows,
                                 cfg.resolved_table_dims))


def table_plans(cfg: DLRMConfig, *, cache_k=0,
                quantize_rows_above: Optional[int] = None):
    """The declarative per-table composition for a heterogeneous config:
    ``cache_k`` (int or per-table sequence; 0 = no hot cache for that
    table) pins the skewed tables, ``quantize_rows_above`` int8-quantizes
    every table whose vocab exceeds the threshold (the huge tables whose
    fp32 rows blow the capacity budget). Returns the TablePlan tuple a
    ``SourceSpec(tables=...)`` consumes."""
    rows = cfg.resolved_table_rows
    dims = cfg.resolved_table_dims
    if not isinstance(cache_k, (tuple, list)):
        cache_k = (cache_k,) * cfg.n_tables
    return tuple(es.TablePlan(
        rows=r, dim=d, cache_k=int(k),
        quantize=(quantize_rows_above is not None
                  and r > quantize_rows_above))
        for r, d, k in zip(rows, dims, cache_k))


def top_mlp_in_dim(cfg: DLRMConfig) -> int:
    f = cfg.n_interact_features
    return cfg.emb_dim + f * (f - 1) // 2


def init(key: jax.Array, cfg: DLRMConfig, shards: int = 1) -> Dict:
    k_arena, k_bot, k_top = jax.random.split(key, 3)
    assert cfg.bottom_mlp[-1] == cfg.emb_dim, (
        "bottom MLP must end at emb_dim so its output joins the interaction")
    params = {
        "bottom": de.init_mlp(k_bot, (cfg.dense_features,) + cfg.bottom_mlp),
        "top": de.init_mlp(k_top, (top_mlp_in_dim(cfg),) + cfg.top_mlp),
    }
    if cfg.heterogeneous:
        specs = member_specs(cfg)
        keys = jax.random.split(k_arena, 2 * cfg.n_tables)
        params["tables"] = tuple(
            se.init_arena(keys[t], sp, shards)
            for t, sp in enumerate(specs))
        # per-table projection into the shared interaction width: table
        # t's reduced (dim_t,) bag joins the feature interaction as a
        # (emb_dim,) vector
        params["proj"] = tuple(
            (jax.random.normal(keys[cfg.n_tables + t],
                               (sp.dim, cfg.emb_dim), jnp.float32)
             / jnp.sqrt(sp.dim)).astype(cfg.dtype)
            for t, sp in enumerate(specs))
    else:
        params["arena"] = se.init_arena(k_arena, arena_spec(cfg), shards)
    return params


def group_source(params: Dict, cfg: DLRMConfig,
                 mesh: Optional[jax.sharding.Mesh] = None,
                 axis: str = "model") -> es.TableGroupSource:
    """The default serving group of a heterogeneous config: one fp member
    per table arena, row-sharded when a mesh with a >1 axis is given."""
    assert cfg.heterogeneous, "group_source needs a heterogeneous config"
    return es.TableGroupSource.from_arenas(params["tables"],
                                           member_specs(cfg), mesh, axis)


def project_tables(proj, emb: jax.Array) -> jax.Array:
    """Per-table output projections: (B, T, dmax) padded group embeddings
    -> (B, T, emb_dim) interaction features. Table t consumes only its
    own leading dim_t lanes (the zero-padded tail contributes nothing and
    its projection rows receive zero gradient)."""
    cols = [emb[:, t, :p.shape[0]].astype(p.dtype) @ p
            for t, p in enumerate(proj)]
    return jnp.stack(cols, axis=1)


def head_logits(mlp_params: Dict, dense: jax.Array,
                emb: jax.Array) -> jax.Array:
    """The DLRM head shared by every forward AND training path: reduced
    embeddings (B, T, D) + dense features -> logits (B,). One definition,
    so the trained network and the served network cannot drift apart.

    The ``obs_stage`` scopes are metadata-only (jax.named_scope +
    profiler TraceAnnotation when enabled, a shared null context when
    not) — the compiled HLO is identical either way, pinned by
    tests/test_obs.py."""
    with obs_stage("interaction"):
        bot = de.mlp_apply(mlp_params["bottom"], dense)
        x, _ = de.feature_interaction(bot, emb.astype(bot.dtype))
    with obs_stage("mlp"):
        return de.mlp_apply(mlp_params["top"], x)[:, 0]


def _legacy_source(params: Dict, mesh, cache, quantized,
                   axis: str = "model") -> es.EmbeddingSource:
    """Map the deprecated (mesh, cache, quantized) kwarg soup onto an
    EmbeddingSource (cache/quantized warn; mesh alone is the default
    sharded construction, not deprecated)."""
    if cache is not None or quantized is not None:
        warnings.warn(
            "dlrm forward kwargs cache=/quantized= are deprecated; pass "
            "source=<EmbeddingSource> instead (see the README migration "
            "table)", DeprecationWarning, stacklevel=3)
    return _compose_legacy(params, mesh, cache, quantized, axis)


def _compose_legacy(params: Dict, mesh, cache, quantized,
                    axis: str = "model") -> es.EmbeddingSource:
    assert "arena" in params, (
        "the legacy cache=/quantized= kwargs only compose over the "
        "uniform params['arena']; heterogeneous (table-group) params "
        "take source=<TableGroupSource>")
    # legacy contract: quantized only ever applied to the CACHED cold
    # pass; without a cache it was ignored (fp arena served)
    if cache is not None and quantized is not None:
        cold: es.EmbeddingSource = es.QuantizedArena(q=quantized[0],
                                                     scales=quantized[1])
        if se.mesh_shards(mesh, axis) > 1:
            cold = es.ShardedArena(cold, mesh, axis)
    else:
        cold = es.resolve_source(params["arena"], mesh, axis)
    return cold if cache is None else es.CachedSource(hot=cache, cold=cold)


def forward(params: Dict, cfg: DLRMConfig, dense: jax.Array,
            indices: jax.Array,
            mesh: Optional[jax.sharding.Mesh] = None, *,
            source: Optional[es.EmbeddingSource] = None) -> jax.Array:
    """dense: (B, dense_features); indices: (B, T, L) -> logits (B,).

    The sparse stage is ``embedding_source.lookup_fixed`` over `source`
    (default: the fp arena in `params`, row-sharded when a mesh is given).
    The graph is deliberately structured so the sparse stage (gather+psum)
    and the bottom-MLP GEMMs have no data dependence: on TPU the async
    collective combine of embedding shards overlaps the dense compute —
    the Centaur sparse/dense concurrency, expressed at the XLA level.
    """
    spec = arena_spec(cfg)
    if source is None:
        source = (group_source(params, cfg, mesh) if cfg.heterogeneous
                  else es.resolve_source(params["arena"], mesh))
    with obs_stage("sparse_lookup"):
        emb = es.lookup_fixed(source, spec, indices)  # sparse stage
        if cfg.heterogeneous:
            emb = project_tables(params["proj"], emb)
    return head_logits(params, dense, emb)            # dense stage


def forward_ragged(params: Dict, cfg: DLRMConfig, dense: jax.Array,
                   indices: jax.Array, offsets: jax.Array, *, max_l: int,
                   source: Optional[es.EmbeddingSource] = None,
                   mesh: Optional[jax.sharding.Mesh] = None,
                   cache: Optional[se.HotRowCache] = None,
                   quantized=None) -> jax.Array:
    """Ragged-bag forward: the production SparseLengthsSum path.

    dense: (B, dense_features); indices: flat per-table row-id stream (N,),
    possibly padded; offsets: (B*T+1,) ragged bag boundaries in (sample,
    table) row-major order; max_l: static per-bag length bound.

    The embedding stage is ``embedding_source.lookup_bags`` over `source`
    — ANY composition (fp / int8 / sharded / hot-cached / table-grouped)
    through the one entry point; serving-time path selection
    (MP-Rec-style) is the choice of source *value*, not of function.
    source=None defaults to the fp arena in `params` (or, on a
    heterogeneous config, the group over ``params['tables']``),
    row-sharded over the mesh's 'model' axis when a mesh is given. The
    legacy cache=/quantized= kwargs are deprecated shims onto the
    equivalent CachedSource/QuantizedArena.

    Per-table streams: with a ``TableGroupSource``, `indices`/`offsets`
    may instead be *sequences* — table t's own flat stream and (B+1,)
    offsets (each table keeps its own padding budget; `max_l` may be
    per-table too). Heterogeneous configs additionally project each
    table's reduced bag into the shared interaction width through
    ``params['proj']``.
    """
    spec = arena_spec(cfg)
    per_table = isinstance(indices, (tuple, list))
    if source is None:
        if cfg.heterogeneous:
            if cache is not None or quantized is not None:
                raise ValueError(
                    "the legacy cache=/quantized= kwargs cannot express "
                    "per-table composition — pass source=<TableGroup"
                    "Source> (see dlrm.table_plans / SourceSpec.tables)")
            source = group_source(params, cfg, mesh)
        else:
            source = _legacy_source(params, mesh, cache, quantized)
    elif cache is not None or quantized is not None:
        raise ValueError(
            "forward_ragged got BOTH source= and the deprecated cache=/"
            "quantized= kwargs — the legacy kwargs would be silently "
            "ignored; compose them into the source instead")
    with obs_stage("sparse_lookup"):
        if per_table:
            assert isinstance(source, es.TableGroupSource), (
                "per-table index/offset streams are the table-group "
                f"layout; got a {type(source).__name__} source")
            emb = es.lookup_bags_per_table(source, indices, offsets,
                                           max_l=max_l)
        else:
            emb = es.lookup_bags(source, spec, indices, offsets,
                                 max_l=max_l)
        if cfg.heterogeneous:
            emb = project_tables(params["proj"], emb)
    return head_logits(params, dense, emb)


def _bce(logits: jax.Array, labels: jax.Array) -> jax.Array:
    logp = jax.nn.log_sigmoid(logits)
    lognp = jax.nn.log_sigmoid(-logits)
    return -(labels * logp + (1 - labels) * lognp).mean()


def loss_fn(params: Dict, cfg: DLRMConfig, dense: jax.Array,
            indices: jax.Array, labels: jax.Array,
            mesh: Optional[jax.sharding.Mesh] = None) -> jax.Array:
    """Binary cross-entropy on click labels."""
    return _bce(forward(params, cfg, dense, indices, mesh), labels)


def loss_ragged(params: Dict, cfg: DLRMConfig, dense: jax.Array,
                indices: jax.Array, offsets: jax.Array, labels: jax.Array,
                *, max_l: int,
                mesh: Optional[jax.sharding.Mesh] = None) -> jax.Array:
    """BCE over the ragged production path — differentiable on every
    kernel backend via the sparse_lengths_sum custom VJP."""
    logits = forward_ragged(params, cfg, dense, indices, offsets,
                            max_l=max_l, mesh=mesh)
    return _bce(logits, labels)


def make_optimizer(cfg: DLRMConfig, lr: float = 1e-3):
    if cfg.heterogeneous:
        return partitioned({"tables": rowwise_adagrad(lr * 10)}, adamw(lr))
    return partitioned({"arena": rowwise_adagrad(lr * 10)}, adamw(lr))


def make_train_step(cfg: DLRMConfig, optimizer=None,
                    mesh: Optional[jax.sharding.Mesh] = None):
    opt = optimizer or make_optimizer(cfg)

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(
            params, cfg, batch["dense"], batch["indices"], batch["labels"],
            mesh)
        new_params, new_state = opt.update(grads, opt_state, params)
        return new_params, new_state, loss

    return opt, train_step


def make_train_step_ragged(cfg: DLRMConfig, *, max_l: int, lr: float = 1e-3,
                           sparse: bool = True,
                           mesh: Optional[jax.sharding.Mesh] = None,
                           sharded: Optional[bool] = None,
                           axis: str = "model"):
    """Train step over ragged batches {dense, indices, offsets, labels}.

    Returns (opt_like, step) where step(params, opt_state, batch) ->
    (new_params, new_opt_state, loss, touched_rows); touched_rows (N,) are
    the unique arena rows the batch updated (fill = null row), which the
    online trainer feeds to the hot-cache write-through invalidation.

    sparse=True composes the row-wise *sparse* optimizer on the arena
    (update cost O(N) in the index-stream length, no densified (V, D)
    gradient) with AdamW on the MLPs; sparse=False is the dense-gradient
    baseline (jax.grad through the whole model + partitioned row-wise
    Adagrad), kept for the bench comparison.

    sharded=True (the default whenever sparse=True and `mesh` has a >1
    `axis`) runs the whole sparse step inside shard_map: the arena and its
    Adagrad accumulator live row-sharded over `axis`, the forward reduces
    shard-local partial bags (one psum of reduced D-vectors, never raw
    rows), each shard applies exactly the row updates it owns (null row
    excluded), and MLP grads are psum-combined so every replica steps in
    lockstep. Exact vs the replicated sparse step and the dense-grad
    baseline.
    """
    from repro.training import sparse_optim as so

    spec = arena_spec(cfg)
    if cfg.heterogeneous:
        if sharded or se.mesh_shards(mesh, axis) > 1:
            raise ValueError(
                "sharded TRAINING of a heterogeneous table group is not "
                "supported yet — serve groups sharded (ShardedArena "
                "members) and train replicated")
        return _make_train_step_group(cfg, spec, max_l=max_l, lr=lr,
                                      sparse=sparse)
    if sharded is None:
        sharded = sparse and se.mesh_shards(mesh, axis) > 1
    if sharded:
        if not sparse:
            raise ValueError("sharded=True is the sparse-optimizer path; "
                             "the dense-grad baseline threads the mesh "
                             "through the default sharded source instead")
        if mesh is None or axis not in mesh.axis_names:
            raise ValueError(f"sharded=True needs a mesh with axis "
                             f"{axis!r}")
        return _make_train_step_ragged_sharded(cfg, spec, max_l=max_l,
                                               lr=lr, mesh=mesh, axis=axis)
    if sparse and mesh is not None and se.mesh_shards(mesh, axis) > 1:
        raise ValueError(
            "sparse ragged training on a mesh must be sharded — the "
            "replicated sparse branch would silently train a per-device "
            "arena copy; pass sharded=True (or leave sharded=None)")
    if not sparse:
        opt = make_optimizer(cfg, lr)

        def dense_step(params, opt_state, batch):
            loss, grads = jax.value_and_grad(loss_ragged)(
                params, cfg, batch["dense"], batch["indices"],
                batch["offsets"], batch["labels"], max_l=max_l, mesh=mesh)
            new_params, new_state = opt.update(grads, opt_state, params)
            flat = se.flatten_ragged_indices(spec, batch["indices"],
                                             batch["offsets"])
            rows, _ = jnp.unique(flat, size=flat.shape[0],
                                 fill_value=spec.null_row,
                                 return_inverse=True)
            return new_params, new_state, loss, rows.astype(jnp.int32)

        return opt, dense_step

    arena_opt = so.sparse_rowwise_adagrad(lr * 10)
    mlp_opt = adamw(lr)

    def init(params):
        return {"arena": arena_opt.init(params["arena"]),
                "mlp": mlp_opt.init({k: v for k, v in params.items()
                                     if k != "arena"})}

    def step(params, opt_state, batch):
        n_bags = batch["offsets"].shape[0] - 1
        # Forward the sparse stage once through the unified entry point;
        # its VJP w.r.t. the arena is a pure scatter of the bag gradients,
        # which the row-wise path applies directly — the arena never
        # enters autodiff (stop_gradient), so the update stays O(N).
        emb = es.lookup_bags(
            es.FpArena(jax.lax.stop_gradient(params["arena"])), spec,
            batch["indices"], batch["offsets"], max_l=max_l)

        def head(mlp_params, emb):
            return _bce(head_logits(mlp_params, batch["dense"], emb),
                        batch["labels"])

        mlp_params = {k: v for k, v in params.items() if k != "arena"}
        loss, (d_mlp, d_emb) = jax.value_and_grad(head, argnums=(0, 1))(
            mlp_params, emb)

        d_bags = d_emb.reshape(n_bags, spec.dim)
        rows, row_g = so.source_row_grads(spec, d_bags, batch["indices"],
                                          batch["offsets"])
        new_arena, arena_state = arena_opt.update(
            params["arena"], opt_state["arena"], rows, row_g)
        new_mlp, mlp_state = mlp_opt.update(d_mlp, opt_state["mlp"],
                                            mlp_params)
        new_params = dict(new_mlp)
        new_params["arena"] = new_arena
        return new_params, {"arena": arena_state, "mlp": mlp_state}, \
            loss, rows

    return Optimizer(init, None), step


def _make_train_step_ragged_sharded(cfg: DLRMConfig, spec: se.ArenaSpec, *,
                                    max_l: int, lr: float,
                                    mesh: jax.sharding.Mesh, axis: str):
    """Row-sharded sparse train step (see make_train_step_ragged).

    Everything runs per-shard inside one shard_map: the only cross-chip
    traffic per step is the psum of reduced bag partials (forward) and the
    psum of MLP grads (backward) — row gradients never leave the shard
    that owns the rows, which is what keeps the update O(index stream)
    at any shard count.
    """
    from jax.sharding import PartitionSpec as P

    from repro.training import sparse_optim as so

    arena_opt = so.sparse_rowwise_adagrad(lr * 10)
    mlp_opt = adamw(lr)
    null = spec.null_row
    arena_state_spec = {"acc": P(axis, None), "step": P()}

    def init(params):
        return {"arena": arena_opt.init(params["arena"]),
                "mlp": mlp_opt.init({k: v for k, v in params.items()
                                     if k != "arena"})}

    def local_step(arena_shard, arena_state, mlp_params, mlp_state, batch):
        lo, vlocal = se.shard_row_range(arena_shard, axis)
        flat = se.flatten_ragged_indices(spec, batch["indices"],
                                         batch["offsets"])
        n_bags = batch["offsets"].shape[0] - 1
        b = n_bags // spec.n_tables
        emb = se.ragged_partial_reduce(jax.lax.stop_gradient(arena_shard),
                                       flat, batch["offsets"], axis)
        emb = emb.reshape(b, spec.n_tables, spec.dim) \
            .astype(arena_shard.dtype)

        def head(mlp_params, emb):
            return _bce(head_logits(mlp_params, batch["dense"], emb),
                        batch["labels"])

        loss, (d_mlp, d_emb) = jax.value_and_grad(head, argnums=(0, 1))(
            mlp_params, emb)
        # the batch is replicated over the model axis, so per-shard MLP
        # grads are already equal; the psum/N keeps replicas in lockstep
        # under non-deterministic reductions and is where a data-parallel
        # batch axis would combine partials
        d_mlp = jax.tree_util.tree_map(
            lambda g: jax.lax.pmean(g, axis), d_mlp)

        d_bags = d_emb.reshape(n_bags, spec.dim)
        rows, row_g = so.ragged_row_grads(d_bags, flat, batch["offsets"],
                                          fill_row=null)
        lrows, lg = so.shard_local_rows(rows, row_g, lo=lo, vlocal=vlocal,
                                        null_row=null)
        new_shard, new_arena_state = arena_opt.update(
            arena_shard, arena_state, lrows, lg)
        new_mlp, new_mlp_state = mlp_opt.update(d_mlp, mlp_state,
                                                mlp_params)
        return new_shard, new_arena_state, new_mlp, new_mlp_state, loss, \
            rows

    fn = compat.shard_map(
        local_step, mesh=mesh,
        in_specs=(P(axis, None), arena_state_spec, P(), P(), P()),
        out_specs=(P(axis, None), arena_state_spec, P(), P(), P(), P()),
    )

    def step(params, opt_state, batch):
        mlp_params = {k: v for k, v in params.items() if k != "arena"}
        new_arena, arena_state, new_mlp, mlp_state, loss, rows = fn(
            params["arena"], opt_state["arena"], mlp_params,
            opt_state["mlp"], batch)
        new_params = dict(new_mlp)
        new_params["arena"] = new_arena
        return new_params, {"arena": arena_state, "mlp": mlp_state}, \
            loss, rows

    return Optimizer(init, None), step


def _make_train_step_group(cfg: DLRMConfig, spec: se.ArenaSpec, *,
                           max_l: int, lr: float, sparse: bool):
    """Heterogeneous (table-group) ragged train step.

    sparse=True: the per-table row-wise path — the group lookup runs over
    stop-gradient arenas, the head (projections + MLPs) backprops
    normally, and ``sparse_optim.group_row_grads`` turns the padded bag
    gradient into per-table (rows, grads) pairs that per-table Adagrad
    accumulators apply in O(index stream) per table. sparse=False is the
    dense-grad baseline: autodiff straight through the group source
    (every member arena gets a densified gradient) + partitioned
    row-wise Adagrad — kept for the exactness comparison.

    step(params, opt_state, batch) -> (new_params, new_opt_state, loss,
    touched) where `touched` is the per-table tuple of touched-row arrays
    (fill = that table's null row), feeding per-table hot-cache
    write-through.
    """
    from repro.training import sparse_optim as so

    specs = member_specs(cfg)

    def touched_rows(batch):
        n = batch["indices"].shape[0]
        table, valid = se.ragged_position_tables(batch["offsets"], n,
                                                 cfg.n_tables)
        out = []
        for t, sp in enumerate(specs):
            idx_t = jnp.where(valid & (table == t), batch["indices"],
                              jnp.asarray(sp.null_row,
                                          batch["indices"].dtype))
            rows, _ = jnp.unique(idx_t, size=n, fill_value=sp.null_row,
                                 return_inverse=True)
            out.append(rows.astype(jnp.int32))
        return tuple(out)

    if not sparse:
        opt = make_optimizer(cfg, lr)

        def dense_step(params, opt_state, batch):
            loss, grads = jax.value_and_grad(loss_ragged)(
                params, cfg, batch["dense"], batch["indices"],
                batch["offsets"], batch["labels"], max_l=max_l)
            new_params, new_state = opt.update(grads, opt_state, params)
            return new_params, new_state, loss, touched_rows(batch)

        return opt, dense_step

    arena_opt = so.group_rowwise_adagrad(lr * 10)
    mlp_opt = adamw(lr)

    def init(params):
        return {"tables": arena_opt.init(params["tables"]),
                "mlp": mlp_opt.init({k: v for k, v in params.items()
                                     if k != "tables"})}

    def step(params, opt_state, batch):
        n_bags = batch["offsets"].shape[0] - 1
        group = es.TableGroupSource(
            members=tuple(es.FpArena(jax.lax.stop_gradient(a))
                          for a in params["tables"]),
            specs=specs)
        emb = es.lookup_bags(group, spec, batch["indices"],
                             batch["offsets"], max_l=max_l)

        def head(head_params, emb):
            proj_emb = project_tables(head_params["proj"], emb)
            return _bce(head_logits(head_params, batch["dense"],
                                    proj_emb), batch["labels"])

        head_params = {k: v for k, v in params.items() if k != "tables"}
        loss, (d_head, d_emb) = jax.value_and_grad(head, argnums=(0, 1))(
            head_params, emb)

        d_bags = d_emb.reshape(n_bags, spec.dim)
        per_table = so.group_row_grads(specs, d_bags, batch["indices"],
                                       batch["offsets"], max_l=max_l)
        new_tables, tables_state = arena_opt.update(
            params["tables"], opt_state["tables"], per_table)
        new_head, mlp_state = mlp_opt.update(d_head, opt_state["mlp"],
                                             head_params)
        new_params = dict(new_head)
        new_params["tables"] = new_tables
        return new_params, {"tables": tables_state, "mlp": mlp_state}, \
            loss, tuple(rows for rows, _ in per_table)

    return Optimizer(init, None), step


def make_serve_step(cfg: DLRMConfig,
                    mesh: Optional[jax.sharding.Mesh] = None):
    def serve_step(params, batch):
        return jax.nn.sigmoid(
            forward(params, cfg, batch["dense"], batch["indices"], mesh))
    return serve_step


def make_ragged_serve_step(cfg: DLRMConfig, *, max_l: int,
                           mesh: Optional[jax.sharding.Mesh] = None,
                           cache: Optional[se.HotRowCache] = None,
                           quantized=None):
    """Serve step over ragged batches ({dense, indices, offsets} -> CTR).

    The embedding source is a call-time pytree argument — that is how the
    serving engine swaps in a new version of ANY source component (hot
    cache, quantized cold arena, the full fp arena) without recompiling:
    same treedef + same leaf shapes = same compiled executable. With
    source=None the fp arena in `params` serves (mesh-sharded when given).

    Back-compat shims (both warn): the legacy build-time cache=/quantized=
    kwargs, and a bare HotRowCache passed as the per-call third argument
    (the pre-API calling convention) — each is composed into the
    equivalent CachedSource.
    """
    if cache is not None or quantized is not None:
        warnings.warn(
            "make_ragged_serve_step kwargs cache=/quantized= are "
            "deprecated; pass source=<EmbeddingSource> per call instead",
            DeprecationWarning, stacklevel=2)
    default_cache, default_q = cache, quantized

    def serve_step(params, batch, source=None):
        if source is None and default_cache is not None:
            source = _legacy_source(params, mesh, default_cache,
                                    default_q)
        elif isinstance(source, se.HotRowCache):
            warnings.warn(
                "passing a bare HotRowCache to the serve step is "
                "deprecated; pass a CachedSource (or any "
                "EmbeddingSource) instead", DeprecationWarning,
                stacklevel=2)
            # honor the build-time quantized= arena exactly like the
            # legacy cached_q path did for per-call cache swaps
            source = _compose_legacy(params, mesh, source, default_q)
        return jax.nn.sigmoid(forward_ragged(
            params, cfg, batch["dense"], batch["indices"],
            batch["offsets"], max_l=max_l, mesh=mesh, source=source))
    return serve_step


def make_ragged_serve_stages(cfg: DLRMConfig, *, max_l: int,
                             mesh: Optional[jax.sharding.Mesh] = None):
    """The serve step split at its pipeline-stage boundaries — the live
    Fig-5 characterization mode.

    Returns ``(sparse_stage, interact_stage, top_stage)``; composed they
    compute exactly what ``make_ragged_serve_step`` computes (pinned by
    tests/test_obs.py), but jitting each separately lets the serving
    engine sync between stages and attribute *device* time to the
    embedding stage vs. the dense stages — the paper's Fig-5
    embedding-vs-MLP split, measured on live traffic instead of offline
    microbenchmarks:

      * ``sparse_stage(params, batch, source)`` -> (B, T, D) reduced
        bags (plus the per-table projections on heterogeneous configs —
        the same scope ``obs_stage('sparse_lookup')`` covers in the
        fused step);
      * ``interact_stage(params, batch, emb)`` -> interaction features
        (bottom MLP + feature interaction);
      * ``top_stage(params, x)`` -> CTR probabilities (top MLP +
        sigmoid).

    ``mesh`` is accepted for signature symmetry with
    ``make_ragged_serve_step``; the source is always explicit here so it
    never feeds a default-source resolution.
    """
    del mesh
    spec = arena_spec(cfg)

    def sparse_stage(params, batch, source):
        with obs_stage("sparse_lookup"):
            emb = es.lookup_bags(source, spec, batch["indices"],
                                 batch["offsets"], max_l=max_l)
            if cfg.heterogeneous:
                emb = project_tables(params["proj"], emb)
        return emb

    def interact_stage(params, batch, emb):
        with obs_stage("interaction"):
            bot = de.mlp_apply(params["bottom"], batch["dense"])
            x, _ = de.feature_interaction(bot, emb.astype(bot.dtype))
        return x

    def top_stage(params, x):
        with obs_stage("mlp"):
            return jax.nn.sigmoid(de.mlp_apply(params["top"], x)[:, 0])

    return sparse_stage, interact_stage, top_stage
