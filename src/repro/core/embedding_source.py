"""First-class embedding sources: one lookup entry point, swappable backends.

Centaur's core idea is ONE sparse stage with interchangeable
implementations (sparse chiplet vs CPU gather); MP-Rec generalizes that to
runtime selection among embedding-representation paths. This module is
that idea as an API: every way of materializing a reduced embedding bag is
an ``EmbeddingSource`` — a small pytree-registered dataclass — and every
consumer calls exactly one of two entry points:

* ``lookup_bags(source, spec, indices, offsets, *, max_l)`` — the ragged
  production path (paper Fig. 2 SparseLengthsSum), (N,) flat per-table
  ids + (B*T+1,) offsets -> (B, T, D);
* ``lookup_fixed(source, spec, indices)`` — the legacy fixed-L path,
  (B, T, L) -> (B, T, D).

Source taxonomy (composition, not configuration)::

    FpArena(arena)                      full-precision row arena
    QuantizedArena(q, scales)           int8 rows + per-row f32 scale
    ShardedArena(inner, mesh, axis)     row-shard any leaf source's arrays
                                        over a mesh axis (shard_map; one
                                        psum of reduced D-vectors)
    CachedSource(hot, cold)             replicated top-K hot rows + ANY
                                        cold source for the tail
    TableGroupSource(members, specs)    heterogeneous per-table members
                                        (own vocab + dim each), composed
                                        declaratively per table

Composition laws are preserved bit-for-bit vs the pre-API engine:

* hot + cold exactness — ``CachedSource`` reduces cache slots (misses hit
  the zero null slot) and redirects hits to the arena null row before the
  cold pass, so hot_pass + cold_pass == uncached lookup exactly;
* sharded == replicated — ``ShardedArena`` gathers foreign rows as local
  row 0 zero-masked, reduces shard-local partial bags, psums once, and
  rounds the result through the inner source's dtype exactly like the
  replicated kernel does;
* int8 masking — the quantized null row carries a zero scale, so every
  redirect stays inert without masks.

Because sources are pytrees, the *whole source* is a call-time jit
argument: swapping a hot cache, a quantized cold arena, or the full fp
arena on a live engine hits the same compiled executable (same treedef,
same leaf shapes). ``VersionedSource`` wraps any source plus a monotone
version into a self-describing broadcast artifact — the generalization of
the hot-arena artifact to full param publication.

Adding the next source (quantized-hot, two-level cache) is one new
dataclass implementing ``reduce_flat`` — not six new functions.
``TableGroupSource`` closes the per-table-arenas item: every *member* is
itself any of the sources above, so per-table composition (hot-cache only
the skewed tables, int8-quantize only the huge ones) is a value, declared
per table through ``TablePlan``/``SourceSpec.tables``.
"""
from __future__ import annotations

import dataclasses
import io
import json
from dataclasses import dataclass
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat
from repro.core import sparse_engine as se
from repro.kernels import ops
from repro.obs.tracing import stage as obs_stage

__all__ = [
    "CachedSource", "EmbeddingSource", "FpArena", "QuantizedArena",
    "ShardedArena", "SourceSpec", "TableGroupSource", "TablePlan",
    "VersionedSource", "describe_source", "fmt_bytes",
    "group_hit_counts", "group_trace_counts", "hot_cache_of",
    "lookup_bags", "lookup_bags_per_table", "lookup_fixed",
    "rebind_arena", "register_meta_type", "register_source",
    "replace_member", "resolve_source", "source_bytes",
    "with_hot_cache",
]

# name -> (cls, data_fields, meta_fields): drives pytree registration,
# artifact (de)serialization, and the API-surface snapshot.
_SOURCE_REGISTRY = {}


def register_source(data_fields: Tuple[str, ...],
                    meta_fields: Tuple[str, ...] = ()):
    """Class decorator: pytree-register a source dataclass and add it to
    the artifact registry. THE extension point — a new source is one
    decorated dataclass implementing ``reduce_flat`` (and optionally the
    fixed / shard-local hooks), nothing else."""
    def deco(cls):
        jax.tree_util.register_dataclass(
            cls, data_fields=list(data_fields),
            meta_fields=list(meta_fields))
        _SOURCE_REGISTRY[cls.__name__] = (cls, tuple(data_fields),
                                          tuple(meta_fields))
        return cls
    return deco


# HotRowCache predates this module but is a serializable component of
# CachedSource artifacts; register it for encode/decode only (it is
# already a pytree).
_SOURCE_REGISTRY["HotRowCache"] = (
    se.HotRowCache, ("hot_rows", "slot_of", "hot_ids"), ())


class EmbeddingSource:
    """Base protocol for embedding sources.

    Subclasses implement ``reduce_flat`` (ragged reduction over
    pre-flattened arena row ids -> f32 partial bags) and ``out_dtype``.
    The production entry points route through ``reduce_dense``: the
    ragged stream is relayouted ONCE into a static (n_bags, max_l) id
    matrix (``se.ragged_dense_ids``) and reduced in a single fused
    gather + per-bag sum — the fused segmented dispatch that keeps every
    flexible path (grouped, cached, sharded) on one pass over the batch.
    ``reduce_dense`` has a default that falls back to ``reduce_flat``
    with uniform offsets, so a new source is still ONE dataclass
    implementing ``reduce_flat``; the built-in sources override it with
    their fused forms. The shard-local hooks (``shard_reduce_flat`` /
    ``shard_reduce_fixed``) are only required of sources that can sit
    inside ``ShardedArena``. ``reduce_bags`` / ``reduce_fixed_ids`` are
    the per-table-id halves of the two entry points; their defaults
    flatten against the uniform arena layout, and only
    ``TableGroupSource`` (whose tables have no shared layout to flatten
    into) overrides them.
    """

    @property
    def out_dtype(self):
        raise NotImplementedError

    def reduce_bags(self, spec: se.ArenaSpec, indices: jax.Array,
                    offsets: jax.Array, *, max_l: int) -> jax.Array:
        """(N,) per-table row ids + (n_bags+1,) offsets -> f32
        (n_bags, D). Default: flatten into the uniform arena layout,
        relayout once, reduce fused."""
        flat = se.flatten_ragged_indices(spec, indices, offsets)
        dense = se.ragged_dense_ids(flat, offsets, max_l=max_l,
                                    fill=spec.null_row)
        return self.reduce_dense(spec, dense)

    def reduce_fixed_ids(self, spec: se.ArenaSpec,
                         indices: jax.Array) -> jax.Array:
        """(B, T, L) per-table row ids -> f32 (B*T, D)."""
        return self.reduce_fixed(spec, se.flatten_indices(spec, indices))

    def reduce_flat(self, spec: se.ArenaSpec, flat: jax.Array,
                    offsets: jax.Array, *, max_l: int) -> jax.Array:
        """(N,) arena row ids + (n_bags+1,) offsets -> f32 (n_bags, D)."""
        raise NotImplementedError

    def reduce_dense(self, spec: se.ArenaSpec,
                     dense: jax.Array) -> jax.Array:
        """(n_bags, max_l) arena row ids (``se.ragged_dense_ids``
        relayout; short/padded slots point at the zero null row) -> f32
        (n_bags, D). THE fused hook. Default: fall back to the ragged
        reduction with uniform offsets, so reduce_flat-only sources keep
        working unchanged."""
        n_bags, l = dense.shape
        offsets = (jnp.arange(n_bags + 1, dtype=jnp.int32) * l)
        return self.reduce_flat(spec, dense.reshape(-1), offsets, max_l=l)

    def reduce_fixed(self, spec: se.ArenaSpec,
                     flat: jax.Array) -> jax.Array:
        """(B*T, L) arena row ids -> f32 (B*T, D). A fixed-L batch IS
        already a dense id matrix, so this routes straight through the
        fused hook."""
        return self.reduce_dense(spec, flat)

    def shard_reduce_flat(self, spec: se.ArenaSpec, flat: jax.Array,
                          offsets: jax.Array, axis: str) -> jax.Array:
        """Shard-local half of ``reduce_flat`` for use inside shard_map
        (arrays hold this shard's rows); returns psum'd f32 partials."""
        raise NotImplementedError(
            f"{type(self).__name__} cannot be row-sharded; wrap a leaf "
            f"source (FpArena / QuantizedArena) in ShardedArena instead")

    def shard_reduce_fixed(self, spec: se.ArenaSpec, flat: jax.Array,
                           axis: str) -> jax.Array:
        raise NotImplementedError(
            f"{type(self).__name__} cannot be row-sharded; wrap a leaf "
            f"source (FpArena / QuantizedArena) in ShardedArena instead")


@register_source(("arena",))
@dataclass(frozen=True)
class FpArena(EmbeddingSource):
    """The plain full-precision row arena — the reference source every
    other composition must agree with."""
    arena: jax.Array                     # (rows, D)

    @property
    def out_dtype(self):
        return self.arena.dtype

    def reduce_flat(self, spec, flat, offsets, *, max_l):
        return ops.sparse_lengths_sum(
            self.arena, flat, offsets, max_l=max_l).astype(jnp.float32)

    def reduce_dense(self, spec, dense):
        return ops.fused_segment_sum(self.arena, dense,
                                     null_row=spec.null_row)

    def reduce_fixed(self, spec, flat):
        # fused EB-Streamer pass (one kernel over all tables)
        return ops.embedding_bag(self.arena, flat).astype(jnp.float32)

    def shard_reduce_flat(self, spec, flat, offsets, axis):
        return se.ragged_partial_reduce(self.arena, flat, offsets, axis)

    def shard_reduce_fixed(self, spec, flat, axis):
        return se.dense_partial_reduce(self.arena, flat, axis,
                                       null_row=spec.null_row)


@register_source(("q", "scales"))
@dataclass(frozen=True)
class QuantizedArena(EmbeddingSource):
    """int8 rows + one f32 scale per row (3.9x capacity); dequantized on
    the fly inside the reduction. The null row's zero scale keeps every
    redirect inert — the int8 masking protocol."""
    q: jax.Array                         # (rows, D) int8
    scales: jax.Array                    # (rows, 1) f32

    @property
    def out_dtype(self):
        return jnp.float32

    @classmethod
    def from_arena(cls, arena: jax.Array) -> "QuantizedArena":
        q, scales = se.quantize_arena(arena)
        return cls(q=q, scales=scales)

    def quantize_rows(self, arena: jax.Array,
                      rows: jax.Array) -> "QuantizedArena":
        """Re-quantize only `rows` from `arena` — the incremental
        maintenance patch. Exact vs a full ``from_arena`` rebuild when
        only `rows` changed (row-wise quantization has no cross-row
        state). Duplicate row ids are harmless (idempotent set)."""
        sub = jnp.take(arena, rows, axis=0).astype(jnp.float32)
        qr, scales = se._rowwise_quantize(sub)   # same rule as from_arena
        return QuantizedArena(q=self.q.at[rows].set(qr),
                              scales=self.scales.at[rows].set(scales))

    def reduce_flat(self, spec, flat, offsets, *, max_l):
        n_bags = offsets.shape[0] - 1
        seg = se.ragged_segment_ids(offsets, flat.shape[0])
        rows = jnp.take(self.q, flat, axis=0).astype(jnp.float32) \
            * jnp.take(self.scales, flat, axis=0)
        return jax.ops.segment_sum(rows, seg, num_segments=n_bags)

    def reduce_dense(self, spec, dense):
        # dequantize-in-the-gather, one per-bag sum, no scatter (the
        # null row's zero scale keeps fill slots inert)
        rows = jnp.take(self.q, dense, axis=0).astype(jnp.float32)
        s = jnp.take(self.scales, dense, axis=0)
        return (rows * s).sum(axis=1)

    def reduce_fixed(self, spec, flat):
        return self.reduce_dense(spec, flat)

    def shard_reduce_flat(self, spec, flat, offsets, axis):
        return se.ragged_partial_reduce_q(self.q, self.scales, flat,
                                          offsets, axis)

    def shard_reduce_fixed(self, spec, flat, axis):
        lo, vlocal = se.shard_row_range(self.q, axis)
        return se._masked_fixed_partial_reduce(
            lambda safe: jnp.take(self.q, safe, axis=0)
            .astype(jnp.float32)
            * jnp.take(self.scales, safe, axis=0), lo, vlocal, flat,
            axis, null_row=spec.null_row)


@register_source(("inner",), ("mesh", "axis"))
@dataclass(frozen=True)
class ShardedArena(EmbeddingSource):
    """Row-shard any leaf source over `axis` of `mesh` (shard_map).

    The ownership protocol every sharded path shares: foreign rows are
    gathered as local row 0 and zero-masked, partial bags are reduced
    shard-locally, one psum combines them — only reduced (n_bags, D)
    partials ever cross chips, never raw rows (Centaur streams reductions
    for the same reason). The psum'd f32 result is rounded through the
    inner source's dtype so sharded and replicated stay bit-comparable on
    low-precision arenas too.
    """
    inner: EmbeddingSource
    mesh: jax.sharding.Mesh
    axis: str = "model"

    @property
    def out_dtype(self):
        return self.inner.out_dtype

    @property
    def n_shards(self) -> int:
        return se.mesh_shards(self.mesh, self.axis)

    def _shard_map(self, local_fn, batch_args, batch_specs, out_spec):
        """shard_map `local_fn(inner_local, *batch_args)` with the inner
        source's leaves row-sharded over `axis` and the given batch /
        output partitioning. Generic over the inner structure, so any
        leaf source gains the sharded composition for free."""
        from jax.sharding import PartitionSpec as P
        leaves, treedef = jax.tree_util.tree_flatten(self.inner)

        def body(*args):
            ls, rest = args[:len(leaves)], args[len(leaves):]
            return local_fn(jax.tree_util.tree_unflatten(treedef, ls),
                            *rest)

        fn = compat.shard_map(
            body, mesh=self.mesh,
            in_specs=tuple(P(self.axis, None) for _ in leaves)
            + tuple(batch_specs),
            out_specs=out_spec)
        return fn(*leaves, *batch_args)

    def _data_axes(self):
        """The non-row mesh axes: the fixed-path batch partitions over
        them (each data-group reduces only its own samples)."""
        return tuple(a for a in self.mesh.axis_names if a != self.axis)

    def reduce_flat(self, spec, flat, offsets, *, max_l):
        from jax.sharding import PartitionSpec as P
        if self.n_shards == 1:
            return self.inner.reduce_flat(spec, flat, offsets,
                                          max_l=max_l)
        # the ragged stream cannot split over a data axis (offsets are
        # global bag boundaries): batch args stay replicated, one psum
        # of reduced partials over the row axis
        part = self._shard_map(
            lambda src, f, o: src.shard_reduce_flat(spec, f, o,
                                                    self.axis),
            (flat, offsets), (P(None), P(None)), P(None, None))
        # round through the inner dtype exactly like the replicated
        # kernel does, so both partitions stay bit-comparable
        return part.astype(self.inner.out_dtype).astype(jnp.float32)

    def reduce_dense(self, spec, dense):
        from jax.sharding import PartitionSpec as P
        if self.n_shards == 1:
            return self.inner.reduce_dense(spec, dense)
        # the fused sharded cold pass: the gather happens INSIDE
        # shard_map (each shard gathers only the rows it owns, masked,
        # and reduces its partial bags in the same op) — no per-shard
        # ragged partials are ever materialized, one psum of reduced
        # (n_bags, D) vectors crosses chips
        part = self._shard_map(
            lambda src, d: src.shard_reduce_fixed(spec, d, self.axis),
            (dense,), (P(None, None),), P(None, None))
        return part.astype(self.inner.out_dtype).astype(jnp.float32)

    def reduce_fixed(self, spec, flat):
        from jax.sharding import PartitionSpec as P
        if self.n_shards == 1:
            return self.inner.reduce_fixed(spec, flat)
        # fixed-L bags are independent rows of (B*T, L): partition them
        # over the remaining (data) mesh axes so each data-group gathers
        # and reduces only its own samples
        other = self._data_axes()
        batch_spec = P(other if other else None)
        out_spec = P(other if other else None, None)
        part = self._shard_map(
            lambda src, f: src.shard_reduce_fixed(spec, f, self.axis),
            (flat,), (batch_spec,), out_spec)
        return part.astype(self.inner.out_dtype).astype(jnp.float32)


@register_source(("hot", "cold"), ("coherent",))
@dataclass(frozen=True)
class CachedSource(EmbeddingSource):
    """Replicated top-K hot rows + ANY cold source for the tail.

    The shared hot/cold protocol: the hot pass reduces cache slots
    (misses hit the zero null slot), and the cold indices redirect cached
    rows to the arena null row, so any cold reduction over them is
    exactly the complement — hot + cold == uncached, for every cold
    source. Cold may itself be sharded or quantized (or, later, another
    CachedSource — a two-level cache is this dataclass nested).

    ``coherent=True`` is the construction site's declaration that the
    hot copies equal their cold arena rows at serve time (§2 law 1 held
    as an invariant, e.g. a plan built from the live arena). It licenses
    the XLA lowering to serve an FpArena cold straight from the arena —
    one gather, the uncached op histogram — while gradients keep the
    exact hot/cold split and the Pallas kernel keeps the two-table walk.
    Leave it False (the default) when staleness between the hot copies
    and the arena must be observable, i.e. the write-through
    invalidation protocol between an arena update and its hot patch.
    """
    hot: se.HotRowCache
    cold: EmbeddingSource
    coherent: bool = False

    @property
    def out_dtype(self):
        return self.cold.out_dtype

    @property
    def k(self) -> int:
        return self.hot.hot_rows.shape[0] - 1

    def reduce_flat(self, spec, flat, offsets, *, max_l):
        hot, cold_idx = se.cache_split_flat(self.hot, spec.null_row,
                                            flat, offsets, max_l)
        return hot + self.cold.reduce_flat(spec, cold_idx, offsets,
                                           max_l=max_l)

    def reduce_dense(self, spec, dense):
        # ONE pass with the hit test folded into the walk: per position
        # exactly one of hot_rows[slot] (miss -> zero null slot) and
        # cold[cold_id] (hit -> zero null row) is nonzero, so a single
        # merged reduction equals the uncached lookup bit-for-bit —
        # replacing the old hot pass + full cold pass.
        slots = jnp.take(self.hot.slot_of, dense, axis=0)
        cold_ids = jnp.where(slots < self.k,
                             jnp.asarray(spec.null_row, dense.dtype),
                             dense)
        cold = self.cold
        if isinstance(cold, FpArena):
            # dense_ids= opts into the coherence-law lowering (see the
            # class docstring): on XLA the forward collapses to the
            # plain arena reduction, while the backward keeps the exact
            # hot/cold grad split and Pallas keeps the two-table walk.
            return ops.fused_cached_segment_sum(
                self.hot.hot_rows, cold.arena, slots, cold_ids,
                dense_ids=dense if self.coherent else None,
                null_row=spec.null_row)
        if isinstance(cold, QuantizedArena):
            rows = jnp.take(self.hot.hot_rows, slots, axis=0) \
                .astype(jnp.float32) \
                + jnp.take(cold.q, cold_ids, axis=0).astype(jnp.float32) \
                * jnp.take(cold.scales, cold_ids, axis=0)
            return rows.sum(axis=1)
        # sharded (or any other) cold source: fused hot pass + the cold
        # source's own fused pass over the redirected ids
        hot = ops.fused_segment_sum(self.hot.hot_rows, slots,
                                    null_row=self.k)
        return hot + cold.reduce_dense(spec, cold_ids)


@register_source(("members",), ("specs",))
@dataclass(frozen=True)
class TableGroupSource(EmbeddingSource):
    """Heterogeneous per-table embedding sources behind the ONE entry
    point — the workload Centaur characterizes: vocab sizes and access
    skew vary wildly per table, so each table is its own gather-reduce
    stream over its own arena.

    ``members[t]`` is ANY source (``FpArena`` / ``QuantizedArena`` /
    ``CachedSource`` / ``ShardedArena``) over table t's private arena
    ``(vocab_t + 1, dim_t)`` (own trailing null row); ``specs[t]`` is its
    single-table ``ArenaSpec(1, vocab_t, dim_t)``. Per-table composition
    is therefore declarative: hot-cache only the skewed tables, int8 only
    the huge ones (``TablePlan`` / ``SourceSpec.tables``).

    The grouped reduction routes the ONE interleaved (sample, table)
    row-major stream to every member with foreign positions redirected to
    that member's always-zero null row — the same mask-free redirect
    protocol the hot/cold split uses — so each member reduces exactly its
    own bags and contributes exact zeros elsewhere. Outputs are padded to
    ``dmax = max(dim_t)``; table t's slice ``[:, t, :dim_t]`` is
    bit-for-bit the member's own lookup (the composition law pinned by
    ``tests/test_table_group.py``). ``lookup_bags_per_table`` is the
    per-table-stream sibling for callers that keep one stream per table.
    """
    members: Tuple[EmbeddingSource, ...]
    specs: Tuple[se.ArenaSpec, ...]

    @property
    def n_tables(self) -> int:
        return len(self.members)

    @property
    def dmax(self) -> int:
        return max(sp.dim for sp in self.specs)

    @property
    def out_dtype(self):
        return jnp.result_type(*[m.out_dtype for m in self.members])

    @property
    def envelope_spec(self) -> se.ArenaSpec:
        """The uniform ArenaSpec a group serves under: n_tables tables,
        the max vocab, the max dim (only n_tables/dim are consumed by the
        entry points — a group never flattens into a shared arena)."""
        return se.ArenaSpec(len(self.members),
                            max(sp.rows_per_table for sp in self.specs),
                            self.dmax)

    @classmethod
    def from_arenas(cls, arenas: Sequence[jax.Array],
                    specs: Sequence[se.ArenaSpec],
                    mesh: Optional[jax.sharding.Mesh] = None,
                    axis: str = "model") -> "TableGroupSource":
        """The default group for raw per-table arenas: replicated fp
        members, row-sharded when a mesh with a >1 axis is given."""
        assert len(arenas) == len(specs), (len(arenas), len(specs))
        return cls(members=tuple(resolve_source(a, mesh, axis)
                                 for a in arenas),
                   specs=tuple(specs))

    def _position_tables(self, indices, offsets):
        """(table id, validity) per stream position."""
        return se.ragged_position_tables(offsets, indices.shape[0],
                                         len(self.members))

    def reduce_bags(self, spec, indices, offsets, *, max_l):
        t_count = len(self.members)
        assert spec.n_tables == t_count, (spec.n_tables, t_count)
        assert spec.dim == self.dmax, (spec.dim, self.dmax)
        n_bags = offsets.shape[0] - 1
        if n_bags % t_count:
            raise ValueError(
                f"lookup_bags over a TableGroupSource needs the bag "
                f"count to cover whole (sample, table) rows: got "
                f"n_bags={n_bags} bags for t_count={t_count} tables "
                f"(n_bags % t_count == {n_bags % t_count}). Pass "
                f"offsets with B*t_count+1 entries (one bag per sample "
                f"per table, row-major).")
        b = n_bags // t_count
        # ONE relayout of the interleaved stream, then each member
        # reduces only its own (B, max_l) bag slice — total work is N
        # positions, not T*N (the old per-member full-stream walk). -1
        # marks short/padded slots so each table can redirect them to
        # its OWN always-zero null row below.
        dense = se.ragged_dense_ids(indices, offsets, max_l=max_l,
                                    fill=-1)
        dense = dense.reshape(b, t_count, max_l)
        cols = []
        for t, (m, sp) in enumerate(zip(self.members, self.specs)):
            ids_t = dense[:, t, :]
            ids_t = jnp.where(ids_t >= 0, ids_t,
                              jnp.asarray(sp.null_row, ids_t.dtype))
            red = m.reduce_dense(sp, ids_t)
            # round through the member dtype exactly like the member's
            # own lookup_bags does, so grouped dispatch stays bit-equal
            # to the per-table loop on low-precision members too
            red = red.astype(m.out_dtype).astype(jnp.float32)
            if sp.dim < spec.dim:
                red = jnp.pad(red, ((0, 0), (0, spec.dim - sp.dim)))
            cols.append(red)
        return jnp.stack(cols, axis=1).reshape(n_bags, spec.dim)

    def reduce_fixed_ids(self, spec, indices):
        b, t, l = indices.shape
        offsets = jnp.arange(b * t + 1, dtype=jnp.int32) * l
        return self.reduce_bags(spec, indices.reshape(-1), offsets,
                                max_l=l)

    def reduce_flat(self, spec, flat, offsets, *, max_l):
        raise TypeError(
            "TableGroupSource has no shared arena layout to reduce over "
            "— call lookup_bags / lookup_fixed (per-table ids) or "
            "lookup_bags_per_table (per-table streams) instead")

    def reduce_dense(self, spec, dense):
        raise TypeError(
            "TableGroupSource has no shared arena layout to reduce over "
            "— call lookup_bags / lookup_fixed (per-table ids) or "
            "lookup_bags_per_table (per-table streams) instead")


# ---------------------------------------------------------------------------
# The two entry points
# ---------------------------------------------------------------------------

def lookup_bags(source: EmbeddingSource, spec: se.ArenaSpec,
                indices: jax.Array, offsets: jax.Array, *,
                max_l: int) -> jax.Array:
    """THE ragged sparse stage: flat per-table ids + offsets -> (B, T, D).

    Subsumes lookup_ragged / _sharded / _auto / _quantized / _cached /
    _cached_q: the composition lives in the `source` pytree, not in the
    function name. Differentiable w.r.t. the source's fp leaves on every
    backend (``jax.grad`` routes through the kernel custom VJPs). For a
    ``TableGroupSource``, D is the group's ``dmax`` and table t's slice
    ``[..., :dim_t]`` carries its reduced bags (the tail is zero).
    """
    with obs_stage("emb_lookup"):
        n_bags = offsets.shape[0] - 1
        out = source.reduce_bags(spec, indices, offsets, max_l=max_l)
        return out.reshape(n_bags // spec.n_tables, spec.n_tables,
                           spec.dim).astype(source.out_dtype)


def lookup_fixed(source: EmbeddingSource, spec: se.ArenaSpec,
                 indices: jax.Array) -> jax.Array:
    """The legacy fixed-L sparse stage: (B, T, L) ids -> (B, T, D).

    Subsumes lookup / lookup_sharded / lookup_auto / lookup_quantized.
    """
    with obs_stage("emb_lookup"):
        b, t, _ = indices.shape
        out = source.reduce_fixed_ids(spec, indices)
        return out.reshape(b, t, spec.dim).astype(source.out_dtype)


def lookup_bags_per_table(source: TableGroupSource,
                          indices: Sequence[jax.Array],
                          offsets: Sequence[jax.Array], *,
                          max_l) -> jax.Array:
    """Per-table-stream sibling of ``lookup_bags`` for table groups.

    ``indices[t]`` / ``offsets[t]`` are table t's own flat id stream and
    (B+1,) bag boundaries — the layout a feature-log pipeline naturally
    produces, and the one that lets each table carry its own padding
    budget (``max_l`` may be one int or a per-table sequence). Returns
    (B, T, dmax) bit-for-bit equal to ``lookup_bags`` over the
    interleaved stream of the same bags: each member reduces exactly the
    same per-bag id runs in the same order either way.
    """
    assert isinstance(source, TableGroupSource), type(source).__name__
    t_count = len(source.members)
    assert len(indices) == t_count and len(offsets) == t_count, \
        (len(indices), len(offsets), t_count)
    if not isinstance(max_l, (tuple, list)):
        max_l = (max_l,) * t_count
    dmax = source.dmax
    cols = []
    for t, (m, sp) in enumerate(zip(source.members, source.specs)):
        out = lookup_bags(m, sp, indices[t], offsets[t], max_l=max_l[t])
        out = out.reshape(-1, sp.dim).astype(jnp.float32)
        if sp.dim < dmax:
            out = jnp.pad(out, ((0, 0), (0, dmax - sp.dim)))
        cols.append(out)
    return jnp.stack(cols, axis=1).astype(source.out_dtype)


# ---------------------------------------------------------------------------
# Construction helpers
# ---------------------------------------------------------------------------

def resolve_source(arena: jax.Array,
                   mesh: Optional[jax.sharding.Mesh] = None,
                   axis: str = "model") -> EmbeddingSource:
    """The default source for a raw arena: replicated fp, row-sharded
    over `axis` when a mesh with a >1 axis is given (the pre-API
    ``lookup_auto`` behavior as a value instead of a function)."""
    src: EmbeddingSource = FpArena(arena)
    if se.mesh_shards(mesh, axis) > 1:
        src = ShardedArena(src, mesh, axis)
    return src


def hot_cache_of(source) -> Optional[se.HotRowCache]:
    """The hot cache a source serves from, or None (non-cached source)."""
    return source.hot if isinstance(source, CachedSource) else None


def with_hot_cache(source: CachedSource,
                   cache: se.HotRowCache) -> CachedSource:
    """Same cold source, new hot cache — the write-through/rebuild swap."""
    assert isinstance(source, CachedSource), source
    return CachedSource(hot=cache, cold=source.cold,
                        coherent=source.coherent)


def replace_member(source: TableGroupSource, t: int,
                   member: EmbeddingSource) -> TableGroupSource:
    """Same group, one member swapped — the per-table component refresh
    (a new hot cache for one skewed table, a re-quantized cold arena for
    one huge table). Structure-preserving when `member` matches the old
    one's treedef, so pushing the result through
    ``RecEngine.update_source`` never recompiles."""
    members = list(source.members)
    members[t] = member
    return TableGroupSource(members=tuple(members), specs=source.specs)


def rebind_arena(source: EmbeddingSource,
                 arena) -> EmbeddingSource:
    """Return `source` with every fp-arena leaf replaced by `arena`
    (quantized arenas are a frozen *representation* of some arena version
    and are left alone — rebuild them explicitly via ``quantize_rows`` /
    ``from_arena``). For a ``TableGroupSource`` pass the sequence of
    per-table arenas. Used to keep a serving source in lockstep when the
    live params object is swapped."""
    if isinstance(source, TableGroupSource):
        assert len(arena) == len(source.members), \
            (len(arena), len(source.members))
        return TableGroupSource(
            members=tuple(rebind_arena(m, a)
                          for m, a in zip(source.members, arena)),
            specs=source.specs)
    if isinstance(source, FpArena):
        return FpArena(arena)
    if isinstance(source, ShardedArena):
        return ShardedArena(rebind_arena(source.inner, arena),
                            source.mesh, source.axis)
    if isinstance(source, CachedSource):
        return CachedSource(source.hot, rebind_arena(source.cold, arena),
                            coherent=source.coherent)
    if hasattr(source, "_rebind_arena"):
        # extension hook (repro.storage.TieredSource refreshes its fp hot
        # tier; frozen quantized tiers stay put like QuantizedArena does)
        return source._rebind_arena(arena)
    return source


def fmt_bytes(n: int) -> str:
    """Human byte label for describe/stats lines: 512 B, 4.0 KB, 5.1 MB."""
    n = float(n)
    for unit in ("B", "KB", "MB", "GB"):
        if n < 1024 or unit == "GB":
            return f"{n:.1f} {unit}" if unit != "B" else f"{int(n)} B"
        n /= 1024
    return f"{n:.1f} GB"


def source_bytes(source) -> int:
    """Total device bytes of a source's array leaves (slot maps, scales
    and all) — the denominator of every capacity-multiplier claim.
    Sources backed by off-device state (host tiers) count only their
    device-resident arrays; see their own accounting for host bytes."""
    if hasattr(source, "device_bytes"):
        return int(source.device_bytes())
    leaves = jax.tree_util.tree_leaves(source)
    return int(sum(getattr(x, "nbytes", 0) for x in leaves))


def describe_source(source, *, multiline: bool = False) -> str:
    """Human/stats label: 'fp', 'int8', 'int4', 'sharded(4,fp)',
    'cached(fp)', 'tiered(host)', 'group[...]'… With ``multiline=True``
    every nested source renders one-per-line (indented tree; groups get
    one line per table with that member's vocab/dim, and every member
    line carries its dtype/tier and device byte size — the REPL view of
    a capacity claim) instead of one unreadable nested line."""
    if multiline:
        return "\n".join(_describe_lines(source, 0))
    if isinstance(source, FpArena):
        return "fp"
    if isinstance(source, QuantizedArena):
        return "int8"
    if isinstance(source, ShardedArena):
        return f"sharded({source.n_shards},{describe_source(source.inner)})"
    if isinstance(source, CachedSource):
        return f"cached({describe_source(source.cold)})"
    if isinstance(source, TableGroupSource):
        inner = ",".join(describe_source(m) for m in source.members)
        return f"group[{inner}]"
    if hasattr(source, "_describe"):
        # the extension hook sources outside this module implement
        # (repro.storage: 'int4', 'host', 'tiered(...)')
        return source._describe()
    return type(source).__name__


def _describe_lines(source, depth: int) -> list:
    pad = "  " * depth
    if isinstance(source, FpArena):
        r, d = source.arena.shape
        return [f"{pad}fp arena ({r}x{d}, {source.arena.dtype}, "
                f"{fmt_bytes(source.arena.nbytes)})"]
    if isinstance(source, QuantizedArena):
        r, d = source.q.shape
        nb = source.q.nbytes + source.scales.nbytes
        return [f"{pad}int8 arena ({r}x{d} + f32 row scales, "
                f"{fmt_bytes(nb)})"]
    if isinstance(source, ShardedArena):
        return [f"{pad}sharded over {source.n_shards} x "
                f"'{source.axis}'"] \
            + _describe_lines(source.inner, depth + 1)
    if isinstance(source, CachedSource):
        nb = source.hot.hot_rows.nbytes + source.hot.slot_of.nbytes \
            + source.hot.hot_ids.nbytes
        return [f"{pad}cached (k={source.k} hot rows, "
                f"{source.hot.hot_rows.dtype}, {fmt_bytes(nb)})"] \
            + _describe_lines(source.cold, depth + 1)
    if isinstance(source, TableGroupSource):
        lines = [f"{pad}group ({len(source.members)} tables, "
                 f"dmax={source.dmax}, "
                 f"{fmt_bytes(source_bytes(source))} on device)"]
        for t, (m, sp) in enumerate(zip(source.members, source.specs)):
            lines.append(f"{pad}  table[{t}] vocab={sp.rows_per_table} "
                         f"dim={sp.dim}")
            lines += _describe_lines(m, depth + 2)
        return lines
    if hasattr(source, "_describe_lines"):
        return source._describe_lines(depth)
    return [f"{pad}{type(source).__name__}"]


# ---------------------------------------------------------------------------
# Group accounting helpers (per-table hit rates / trace histograms)
# ---------------------------------------------------------------------------

def group_hit_counts(source: TableGroupSource, indices: jax.Array,
                     offsets: jax.Array, *, max_l: Optional[int] = None):
    """Per-table (hits, lookups) over one interleaved ragged batch.

    Returns two (T,) int32 arrays; a table whose member serves no hot
    cache reports 0 hits (the consumer maps it to None — membership is
    static structure, not data). Jit-friendly: the member walk happens at
    trace time. With ``max_l`` (the lookup's static bound) the stream is
    relayouted once and each table scans only its own (B, max_l) bag
    slice — the same fused dispatch the lookup itself uses — instead of
    T full-stream walks."""
    t_count = len(source.members)
    if max_l is not None:
        n_bags = offsets.shape[0] - 1
        dense = se.ragged_dense_ids(indices, offsets, max_l=max_l,
                                    fill=-1)
        dense = dense.reshape(n_bags // t_count, t_count, max_l)
        hits, looks = [], []
        for t, m in enumerate(source.members):
            ids_t = dense[:, t, :]
            mine = ids_t >= 0
            looks.append(jnp.sum(mine.astype(jnp.int32)))
            cache = hot_cache_of(m)
            if cache is None:
                hits.append(jnp.zeros((), jnp.int32))
            else:
                slots = jnp.take(cache.slot_of,
                                 jnp.where(mine, ids_t, 0))
                hits.append(jnp.sum((mine & (slots < cache.k))
                                    .astype(jnp.int32)))
        return jnp.stack(hits), jnp.stack(looks)
    table, valid = source._position_tables(indices, offsets)
    hits, looks = [], []
    for t, m in enumerate(source.members):
        mine = valid & (table == t)
        looks.append(jnp.sum(mine.astype(jnp.int32)))
        cache = hot_cache_of(m)
        if cache is None:
            hits.append(jnp.zeros((), jnp.int32))
        else:
            slots = jnp.take(cache.slot_of, jnp.where(mine, indices, 0))
            hits.append(jnp.sum((mine & (slots < cache.k))
                                .astype(jnp.int32)))
    return jnp.stack(hits), jnp.stack(looks)


def group_trace_counts(specs: Sequence[se.ArenaSpec], indices,
                       offsets) -> list:
    """Per-table row-touch histograms from an interleaved ragged trace
    (host-side; the group sibling of ``se.trace_row_counts``). Feeds the
    per-table hot rankings of a group plan."""
    idx = np.asarray(indices)
    off = np.asarray(offsets)
    t_count = len(specs)
    n_valid = int(off[-1])
    seg = np.searchsorted(off[1:], np.arange(n_valid), side="right")
    table = seg % t_count
    return [np.bincount(idx[:n_valid][table == t],
                        minlength=sp.total_rows)
            for t, sp in enumerate(specs)]


@dataclass(frozen=True)
class TablePlan:
    """Per-table slice of a group plan: the table's shape plus its OWN
    composition knobs — hot-cache only the skewed tables (``cache_k``),
    int8-quantize only the huge ones (``quantize``), frequency-tier the
    bigger-than-memory ones (``tiers``, a ``repro.storage.TierPolicy``).
    A tuple of these in ``SourceSpec.tables`` is the declarative form of
    a ``TableGroupSource``."""
    rows: int                            # vocab (real rows, null excluded)
    dim: int
    cache_k: int = 0                     # >0: pin this table's top-K hot
    quantize: bool = False               # int8 this table's (cold) arena
    tiers: Optional[object] = None       # storage.TierPolicy: hot/warm/cold

    def __post_init__(self):
        if self.tiers is not None and (self.cache_k or self.quantize):
            raise ValueError(
                "a tiered table IS its own caching/quantization story — "
                "TierPolicy.hot replaces cache_k and the warm/cold tiers "
                "replace quantize; drop cache_k/quantize on this "
                "TablePlan")

    @property
    def arena_spec(self) -> se.ArenaSpec:
        return se.ArenaSpec(1, self.rows, self.dim)


@dataclass(frozen=True)
class SourceSpec:
    """Declarative serving plan: WHICH source to build, not how.

    Replaces the (path string x cache_k x quantize_cold x mesh) kwarg
    cross-product: a RecEngine (or any consumer) takes one SourceSpec and
    calls ``build(arena, spec, counts)``. String shorthands map 1:1 onto
    the old path names via ``from_path`` ('fixed' | 'ragged' | 'cached'
    | 'sharded'). With ``tables`` set (a tuple of ``TablePlan``) the plan
    is a heterogeneous table group: ``build`` takes the *sequence* of
    per-table arenas (and per-table trace histograms) and composes each
    member independently.
    """
    layout: str = "ragged"               # 'ragged' | 'fixed' batch layout
    cache_k: int = 0                     # >0: pin top-K rows hot
    quantize_cold: bool = False          # int8 cold/uncached arena
    mesh: Optional[jax.sharding.Mesh] = None
    axis: str = "model"
    require_mesh: bool = False           # 'sharded': no silent fallback
    tables: Optional[Tuple[TablePlan, ...]] = None   # heterogeneous group
    tiers: Optional[object] = None       # storage.TierPolicy (single table)

    PATH_NAMES = ("fixed", "ragged", "cached", "sharded")

    def __post_init__(self):
        assert self.layout in ("ragged", "fixed"), self.layout
        if self.require_mesh and se.mesh_shards(self.mesh, self.axis) < 2:
            raise ValueError(
                "require_mesh=True (path 'sharded') needs a mesh with a "
                f">1 {self.axis!r} axis — a misconfigured replica must "
                "not silently fall back to the replicated arena")
        if self.layout == "fixed" and (self.cache_k or self.quantize_cold
                                       or self.tables is not None
                                       or self.tiers is not None):
            raise ValueError(
                "layout='fixed' serves through the legacy fixed-L step "
                "and cannot consume a cached/quantized/grouped/tiered "
                "source — drop cache_k/quantize_cold/tables/tiers or "
                "use the ragged layout")
        if self.tables is not None and (self.cache_k or self.quantize_cold
                                        or self.tiers is not None):
            raise ValueError(
                "a table-group plan carries cache_k/quantize/tiers per "
                "TablePlan — the top-level knobs would silently apply "
                "to no table")
        if self.tiers is not None and (self.cache_k or self.quantize_cold):
            raise ValueError(
                "a tiered plan IS its own caching/quantization story — "
                "drop cache_k/quantize_cold")
        if self.tiers is not None \
                and se.mesh_shards(self.mesh, self.axis) > 1:
            raise ValueError(
                "TieredSource does not row-shard (the staging/slot "
                "protocol is replicated-only for now) — drop the mesh "
                "or the tiers")

    @staticmethod
    def from_path(path: Union[str, "SourceSpec"], *, cache_k: int = 0,
                  quantize_cold: bool = False,
                  mesh: Optional[jax.sharding.Mesh] = None,
                  axis: str = "model") -> "SourceSpec":
        """String shorthand -> plan ('cached' consumes cache_k etc.)."""
        if isinstance(path, SourceSpec):
            return path
        assert path in SourceSpec.PATH_NAMES, \
            (path, SourceSpec.PATH_NAMES)
        if path != "cached":
            # refuse to silently drop cache/int8 configuration — an
            # operator who asked for them must pick the 'cached' path
            # (or pass a full SourceSpec) to get them
            assert not cache_k and not quantize_cold, \
                (f"path {path!r} ignores cache_k/quantize_cold; use "
                 f"path 'cached' or a SourceSpec to configure them")
        if path == "fixed":
            return SourceSpec(layout="fixed", mesh=mesh, axis=axis)
        if path == "ragged":
            return SourceSpec(mesh=mesh, axis=axis)
        if path == "sharded":
            return SourceSpec(mesh=mesh, axis=axis, require_mesh=True)
        assert cache_k > 0, "cached path needs cache_k > 0"
        return SourceSpec(cache_k=cache_k, quantize_cold=quantize_cold,
                          mesh=mesh, axis=axis)

    @property
    def cached(self) -> bool:
        if self.tables is not None:
            return any(tp.cache_k > 0 for tp in self.tables)
        return self.cache_k > 0

    def path_name(self) -> str:
        """The nearest legacy shorthand (for stats/back-compat labels)."""
        if self.tables is not None:
            return "grouped"
        if self.tiers is not None:
            return "tiered"
        if self.layout == "fixed":
            return "fixed"
        if self.cached:
            return "cached"
        if self.require_mesh:
            return "sharded"
        return "ragged"

    def build(self, arena, spec: se.ArenaSpec,
              counts=None) -> EmbeddingSource:
        """Materialize the plan for an arena (counts: trace histogram for
        the hot ranking; uniform when omitted). A table-group plan takes
        the sequence of per-table arenas and the list of per-table
        histograms instead."""
        if self.tables is not None:
            return self._build_group(arena, counts)
        if self.tiers is not None:
            return self.tiers.build_source(arena, spec, counts)
        cold: EmbeddingSource = (QuantizedArena.from_arena(arena)
                                 if self.quantize_cold else FpArena(arena))
        if se.mesh_shards(self.mesh, self.axis) > 1:
            cold = ShardedArena(cold, self.mesh, self.axis)
        if not self.cached:
            return cold
        if counts is None:
            counts = np.ones(spec.total_rows)
        hot = se.build_hot_cache(arena, spec, counts, self.cache_k)
        # the hot cache is built from the live arena right here, so the
        # plan declares coherence — serving gets the fast lowering
        return CachedSource(hot=hot, cold=cold, coherent=True)

    def _build_group(self, arenas, counts=None) -> "TableGroupSource":
        assert len(arenas) == len(self.tables), \
            (len(arenas), len(self.tables))
        if counts is None:
            counts = [None] * len(self.tables)
        sharded = se.mesh_shards(self.mesh, self.axis) > 1
        members, specs = [], []
        for tp, arena, c in zip(self.tables, arenas, counts):
            sp = tp.arena_spec
            if tp.tiers is not None:
                if sharded:
                    raise ValueError(
                        "TieredSource does not row-shard — drop the "
                        "mesh or this table's tiers")
                members.append(tp.tiers.build_source(arena, sp, c))
                specs.append(sp)
                continue
            member: EmbeddingSource = (QuantizedArena.from_arena(arena)
                                       if tp.quantize else FpArena(arena))
            if sharded:
                member = ShardedArena(member, self.mesh, self.axis)
            if tp.cache_k > 0:
                if c is None:
                    c = np.ones(sp.total_rows)
                hot = se.build_hot_cache(arena, sp, c, tp.cache_k)
                member = CachedSource(hot=hot, cold=member, coherent=True)
            members.append(member)
            specs.append(sp)
        return TableGroupSource(members=tuple(members),
                                specs=tuple(specs))


# ---------------------------------------------------------------------------
# Versioned broadcast artifact — any source + a monotone version
# ---------------------------------------------------------------------------

# meta-field dataclass types the artifact codec can round-trip by name;
# extension modules add theirs via register_meta_type (repro.storage
# registers TierPolicy on import)
_META_TYPES = {}


def register_meta_type(cls):
    """Register a (plain, frozen) dataclass so it can appear inside a
    source's meta fields and still round-trip through the artifact
    serializer. Fields are encoded recursively, so registered types may
    nest (TablePlan carries a TierPolicy)."""
    _META_TYPES[cls.__name__] = cls
    return cls


def _encode_meta(v):
    """JSON-encode a meta-field value (plain scalars pass through;
    dataclasses and nested tuples get self-describing wrappers, encoded
    per-field so nested meta dataclasses survive the round trip)."""
    if isinstance(v, se.ArenaSpec):
        return {"__arena_spec__": dataclasses.asdict(v)}
    if isinstance(v, TablePlan):
        return {"__table_plan__": {f.name: _encode_meta(getattr(v, f.name))
                                   for f in dataclasses.fields(v)}}
    if type(v).__name__ in _META_TYPES:
        return {"__meta_dc__": type(v).__name__,
                "fields": {f.name: _encode_meta(getattr(v, f.name))
                           for f in dataclasses.fields(v)}}
    if isinstance(v, (tuple, list)):
        return {"__seq__": [_encode_meta(x) for x in v]}
    return v


def _decode_meta(v):
    if isinstance(v, dict) and "__arena_spec__" in v:
        return se.ArenaSpec(**v["__arena_spec__"])
    if isinstance(v, dict) and "__table_plan__" in v:
        return TablePlan(**{k: _decode_meta(x)
                            for k, x in v["__table_plan__"].items()})
    if isinstance(v, dict) and "__meta_dc__" in v:
        name = v["__meta_dc__"]
        if name not in _META_TYPES:
            import repro.storage  # noqa: F401  (registers its types)
        return _META_TYPES[name](**{k: _decode_meta(x)
                                    for k, x in v["fields"].items()})
    if isinstance(v, dict) and "__seq__" in v:
        return tuple(_decode_meta(x) for x in v["__seq__"])
    return v


def _encode(obj, arrays: dict, counter: list):
    if isinstance(obj, (jax.Array, np.ndarray)):
        key = f"a{counter[0]}"
        counter[0] += 1
        arrays[key] = np.asarray(obj)
        return {"kind": "array", "key": key}
    if isinstance(obj, (tuple, list)):
        # the per-table member tuple of a TableGroupSource (and any
        # future source holding a sequence of sub-sources); lists keep
        # their list-ness so a decoded dense head has the same treedef
        # as the params it replaces (list vs tuple is a treedef change,
        # i.e. a recompile on the serving hot path)
        node = {"kind": "seq",
                "items": [_encode(x, arrays, counter) for x in obj]}
        if isinstance(obj, list):
            node["list"] = True
        return node
    if isinstance(obj, dict):
        # the dense-head payload of a VersionedSource ({"bottom": ...,
        # "top": ..., "proj": ...}) — string-keyed pytrees of arrays
        return {"kind": "dict",
                "items": {k: _encode(v, arrays, counter)
                          for k, v in obj.items()}}
    if obj is None:
        return {"kind": "none"}
    name = type(obj).__name__
    if name not in _SOURCE_REGISTRY:
        raise TypeError(f"cannot serialize {name}: not a registered "
                        f"source type ({sorted(_SOURCE_REGISTRY)})")
    _, data_fields, meta_fields = _SOURCE_REGISTRY[name]
    node = {"kind": "node", "type": name, "fields": {}}
    for f in data_fields:
        node["fields"][f] = _encode(getattr(obj, f), arrays, counter)
    for f in meta_fields:
        v = getattr(obj, f)
        if isinstance(v, jax.sharding.Mesh):
            # meshes are host topology, not state: the consumer rebinds
            # its own at deserialize time
            node["fields"][f] = {"kind": "mesh"}
        elif f in getattr(obj, "__ephemeral_meta__", ()):
            # host-process state (a HostStore's residency bookkeeping):
            # like a mesh, the consumer rebinds its own — the decoded
            # source serves exactly the staged snapshot meanwhile
            node["fields"][f] = {"kind": "ephemeral"}
        else:
            node["fields"][f] = {"kind": "meta",
                                 "value": _encode_meta(v)}
    return node


def _decode(node, z, mesh):
    if node["kind"] == "array":
        return jnp.asarray(z[node["key"]])
    if node["kind"] == "seq":
        items = [_decode(x, z, mesh) for x in node["items"]]
        return items if node.get("list") else tuple(items)
    if node["kind"] == "dict":
        return {k: _decode(v, z, mesh) for k, v in node["items"].items()}
    if node["kind"] == "none":
        return None
    assert node["kind"] == "node", node
    if node["type"] not in _SOURCE_REGISTRY:
        # storage sources register on import; an artifact written by a
        # producer that used them must not require the consumer to have
        # imported the package first
        import repro.storage  # noqa: F401
    cls, data_fields, meta_fields = _SOURCE_REGISTRY[node["type"]]
    kw = {}
    for f in data_fields + meta_fields:
        sub = node["fields"][f]
        if sub["kind"] == "mesh":
            kw[f] = mesh
        elif sub["kind"] == "ephemeral":
            kw[f] = None
        elif sub["kind"] == "meta":
            kw[f] = _decode_meta(sub["value"])
        else:
            kw[f] = _decode(sub, z, mesh)
    if cls is ShardedArena and mesh is None:
        # no mesh on the consumer: serve the inner source replicated
        return kw["inner"]
    return cls(**kw)


@dataclass(frozen=True)
class VersionedSource:
    """Any EmbeddingSource plus the monotone version that produced it —
    the fleet broadcast artifact, generalizing the hot-arena-only
    artifact to quantized cold arenas and full fp arenas (param
    broadcast). ``serialize``/``deserialize`` round-trip through one
    self-describing byte blob; ``apply`` adopts it into an engine
    atomically iff strictly newer (idempotent, order-free delivery).

    ``head`` optionally carries the dense MLP parameters ({"bottom",
    "top", and "proj" when heterogeneous}) alongside the sparse source,
    so a cold remote replica adopts *everything* it serves from one blob
    — no in-process parameter sharing with the trainer at all. The head
    rides the same array codec (dicts/lists keep their exact container
    types, so adopting it is treedef-stable: zero recompiles).
    """
    source: EmbeddingSource
    version: int
    head: Optional[Dict] = None

    MAGIC = b"CSA1"              # Centaur source artifact, format v1

    def serialize(self) -> bytes:
        arrays, counter = {}, [0]
        tree = _encode(self.source, arrays, counter)
        extra = {}
        if self.head is not None:
            head_tree = _encode(dict(self.head), arrays, counter)
            extra["head_structure"] = np.frombuffer(
                json.dumps(head_tree).encode(), np.uint8)
        buf = io.BytesIO()
        np.savez(buf,
                 magic=np.frombuffer(self.MAGIC, np.uint8),
                 version=np.asarray(self.version, np.int64),
                 structure=np.frombuffer(
                     json.dumps(tree).encode(), np.uint8),
                 **extra, **arrays)
        return buf.getvalue()

    @staticmethod
    def deserialize(blob: bytes,
                    mesh: Optional[jax.sharding.Mesh] = None
                    ) -> "VersionedSource":
        """Reconstruct; a recorded ShardedArena rebinds to `mesh`, or
        unwraps to its (replicated) inner source when mesh is None."""
        try:
            with np.load(io.BytesIO(blob)) as z:
                if z["magic"].tobytes() != VersionedSource.MAGIC:
                    raise ValueError("bad magic")
                tree = json.loads(z["structure"].tobytes().decode())
                source = _decode(tree, z, mesh)
                head = None
                if "head_structure" in z:
                    head_tree = json.loads(
                        z["head_structure"].tobytes().decode())
                    head = _decode(head_tree, z, mesh)
                return VersionedSource(source=source,
                                       version=int(z["version"]),
                                       head=head)
        except Exception as e:
            raise ValueError(
                f"not a versioned-source artifact: {e}") from e

    def apply(self, engine) -> bool:
        """Adopt into a RecEngine iff strictly newer; same-or-older
        artifacts are absorbed (reordered transport is safe). A carried
        dense head lands *before* the source swap (params first, then
        source — the setter rebinds the old source's arena leaves to the
        unchanged sparse params, so nothing tears), making the pair
        (dense head, sparse source) one atomic version adoption."""
        if engine.source_version >= self.version:
            return False
        if self.head is not None:
            engine.params = {**engine.params, **self.head}
        engine.update_source(self.source, version=self.version)
        return True
