"""The Centaur sparse engine: multi-table embedding gather/reduce.

The paper's EB-Streamer (Fig. 10) is reproduced structurally:

* **BPregs** — every embedding table lives at a base offset inside one flat
  row *arena* ``(total_rows + 1, D)``; the engine's address generator turns a
  (table, row) pair into ``base[t] + row`` exactly like the paper's
  base-pointer + offset logic. The final arena row is an always-zero row used
  as the null target for masked / out-of-shard lookups, which keeps the
  *fused on-the-fly reduction* kernel applicable even on the sharded path.
* **SRAM_sparseID / EB-GU / EB-RU** — the Pallas kernel in
  ``repro.kernels.embedding_gather`` (scalar-prefetched indices driving
  streaming row DMAs with in-VMEM reduction).
* **Shared-memory direct access** — on a pod, the "CPU DIMMs holding the
  tables" become the pod-wide HBM pool: the arena is **row-sharded across the
  'model' mesh axis**; each chip reduces the rows it owns and a single psum
  combines partial bags. Only reduced D-vectors ever cross chips (the same
  reason Centaur streams reductions instead of raw gathered rows).

NOTE: the lookup entry points that used to live here (``lookup``,
``lookup_sharded``, ``lookup_auto``, ``lookup_quantized``, and the six
``lookup_ragged*`` variants) are deprecation shims now — the unified API
is ``repro.core.embedding_source``: one ``lookup_bags`` / ``lookup_fixed``
pair dispatching over pytree-registered ``EmbeddingSource`` values. This
module keeps the arena layout (ArenaSpec / flatten), the shard-local
reduction protocol, and the hot-row cache data structures those sources
are built from.
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops


def _deprecated(name: str, repl: str) -> None:
    warnings.warn(
        f"repro.core.sparse_engine.{name} is deprecated; use "
        f"repro.core.embedding_source.{repl} over an EmbeddingSource "
        f"(see the README migration table)",
        DeprecationWarning, stacklevel=3)


@dataclass(frozen=True)
class ArenaSpec:
    """Static description of the embedding arena (the BPregs contents)."""
    n_tables: int
    rows_per_table: int
    dim: int
    dtype: str = "float32"

    @property
    def total_rows(self) -> int:
        # +1: trailing always-zero null row for masked lookups
        return self.n_tables * self.rows_per_table + 1

    @property
    def null_row(self) -> int:
        return self.n_tables * self.rows_per_table

    def padded_rows(self, shards: int) -> int:
        """Arena rows padded so the row dim divides the model axis."""
        r = self.total_rows
        return ((r + shards - 1) // shards) * shards


def init_arena(key: jax.Array, spec: ArenaSpec, shards: int = 1,
               scale: float = 0.01) -> jax.Array:
    """Arena of all tables, null row zeroed, padded for `shards` row-shards."""
    rows = spec.padded_rows(shards)
    arena = scale * jax.random.normal(key, (rows, spec.dim), jnp.float32)
    arena = arena.at[spec.null_row:].set(0.0)
    return arena.astype(spec.dtype)


def flatten_indices(spec: ArenaSpec, indices: jax.Array) -> jax.Array:
    """(B, T, L) per-table row ids -> (B*T, L) arena row ids (base + offset)."""
    b, t, l = indices.shape
    base = (jnp.arange(t, dtype=indices.dtype) * spec.rows_per_table)
    flat = indices + base[None, :, None]
    return flat.reshape(b * t, l)


def lookup(arena: jax.Array, spec: ArenaSpec, indices: jax.Array) -> jax.Array:
    """DEPRECATED shim: use ``lookup_fixed(FpArena(arena), spec, indices)``."""
    from repro.core import embedding_source as es
    _deprecated("lookup", "lookup_fixed(FpArena(arena), ...)")
    return es.lookup_fixed(es.FpArena(arena), spec, indices)


def lookup_sharded(arena_shard: jax.Array, spec: ArenaSpec,
                   indices: jax.Array, axis: str) -> jax.Array:
    """DEPRECATED shim: shard-local fixed reduce, now
    ``FpArena(arena_shard).shard_reduce_fixed`` (inside shard_map)."""
    from repro.core import embedding_source as es
    _deprecated("lookup_sharded",
                "ShardedArena(FpArena(arena), mesh) with lookup_fixed")
    b, t, _ = indices.shape
    flat = flatten_indices(spec, indices)
    part = es.FpArena(arena_shard).shard_reduce_fixed(spec, flat, axis)
    return part.reshape(b, t, spec.dim).astype(arena_shard.dtype)


def lookup_auto(arena: jax.Array, spec: ArenaSpec, indices: jax.Array,
                mesh: Optional[jax.sharding.Mesh] = None,
                axis: str = "model") -> jax.Array:
    """DEPRECATED shim: use ``lookup_fixed(resolve_source(arena, mesh))``."""
    from repro.core import embedding_source as es
    _deprecated("lookup_auto", "lookup_fixed(resolve_source(arena, mesh))")
    return es.lookup_fixed(es.resolve_source(arena, mesh, axis), spec,
                           indices)


def quantize_arena(arena: jax.Array):
    """Row-wise symmetric int8 quantization of the embedding arena.

    The paper's core capacity constraint (tables of 100s of GB must live in
    commodity memory) motivates this beyond-paper lever: int8 rows + one f32
    scale per row = 3.9x capacity, dequantized on the fly inside the gather
    (the EB-RU reduces dequantized rows; a zero scale keeps the null row
    inert). Returns (q int8 (R, D), scales f32 (R, 1)).
    """
    return _rowwise_quantize(arena.astype(jnp.float32))


def _rowwise_quantize(a32: jax.Array):
    """THE row-wise symmetric int8 rule — the single definition shared by
    the full-arena build and the incremental `quantize_rows` patch, so
    the patch stays bit-identical to a full rebuild by construction."""
    amax = jnp.max(jnp.abs(a32), axis=-1, keepdims=True)
    scales = amax / 127.0
    q = jnp.where(scales > 0,
                  jnp.clip(jnp.round(a32 / jnp.maximum(scales, 1e-30)),
                           -127, 127), 0).astype(jnp.int8)
    return q, scales


def lookup_quantized(q: jax.Array, scales: jax.Array, spec: ArenaSpec,
                     indices: jax.Array) -> jax.Array:
    """DEPRECATED shim: use ``lookup_fixed(QuantizedArena(q, scales), …)``."""
    from repro.core import embedding_source as es
    _deprecated("lookup_quantized",
                "lookup_fixed(QuantizedArena(q, scales), ...)")
    return es.lookup_fixed(es.QuantizedArena(q, scales), spec, indices)


# ---------------------------------------------------------------------------
# Ragged production path (paper Fig. 2: SparseLengthsSum over ragged bags)
#
# Batch layout: bags are ordered (sample, table) row-major — bag k holds
# sample k // n_tables, table k % n_tables. `indices` is the flat stream of
# per-table row ids for all bags concatenated, possibly padded past
# offsets[-1] (padding is inert); `offsets` has B*T+1 entries.
# ---------------------------------------------------------------------------

def ragged_segment_ids(offsets: jax.Array, n: int) -> jax.Array:
    """Bag id per index position; positions >= offsets[-1] get n_bags."""
    return jnp.searchsorted(offsets[1:], jnp.arange(n, dtype=offsets.dtype),
                            side="right")


def ragged_position_tables(offsets: jax.Array, n: int, n_tables: int):
    """(owning table, validity) per flat stream position — THE single
    encoding of the (sample, table) row-major bag convention. Every
    consumer that routes stream positions to tables (the grouped lookup,
    its per-table row grads, touched-row accounting for cache patching)
    must share this one definition or they silently desync."""
    n_bags = offsets.shape[0] - 1
    seg = ragged_segment_ids(offsets, n)
    table = jnp.minimum(seg, n_bags - 1) % n_tables
    return table, seg < n_bags


def ragged_dense_ids(indices: jax.Array, offsets: jax.Array, *,
                     max_l: int, fill) -> jax.Array:
    """Relayout a ragged id stream into a static (n_bags, max_l) matrix.

    ``dense[b, j] = indices[offsets[b] + j]`` for j inside bag b, `fill`
    elsewhere (short bags and the padded tail). This is THE layout step of
    the fused segmented dispatch: done once per batch, it turns every
    downstream reduction into a mask-free gather + per-bag sum — no
    scatter ever appears in the forward HLO, and the hot/cold and grouped
    paths all consume the same matrix. `max_l` must bound every bag's
    length (the same contract the Pallas grid already imposes); with
    `fill` pointing at an always-zero row the result needs no masking.
    """
    n = indices.shape[0]
    n_bags = offsets.shape[0] - 1
    if n == 0 or max_l == 0:
        return jnp.full((n_bags, max_l), fill, indices.dtype)
    pos = offsets[:-1, None] + jnp.arange(max_l, dtype=offsets.dtype)
    valid = pos < offsets[1:, None]
    safe = jnp.minimum(jnp.where(valid, pos, 0), n - 1)
    dense = jnp.take(indices, safe, axis=0)
    return jnp.where(valid, dense, jnp.asarray(fill, indices.dtype))


def dense_partial_reduce(arena_shard: jax.Array, dense: jax.Array,
                         axis: str, *, null_row=None) -> jax.Array:
    """Shard-local half of the fused dense reduce (inside shard_map):
    gather the owned rows of a ``ragged_dense_ids`` matrix, zero-mask the
    foreign ones, one per-bag sum, one psum — the sharded cold pass
    without per-shard segment scatters. Returns f32 (n_bags, D).

    Pass ``null_row`` so the always-zero sentinel the relayout's fill
    slots point at is masked like a foreign row: the forward is unchanged
    (the row is zero) but autodiff then gives it zero gradient, matching
    the ragged path where fill lived past offsets[-1]."""
    lo, vlocal = shard_row_range(arena_shard, axis)
    return _masked_fixed_partial_reduce(
        lambda safe: jnp.take(arena_shard, safe, axis=0)
        .astype(jnp.float32), lo, vlocal, dense, axis,
        null_row=null_row)


def flatten_ragged_indices(spec: ArenaSpec, indices: jax.Array,
                           offsets: jax.Array) -> jax.Array:
    """Per-table row ids (N,) -> arena row ids (N,) (base + offset).

    The owning table of each position follows from its bag id; padded tail
    positions are routed to the always-zero null row so every downstream
    consumer (kernel, cache, quantized reduce) stays mask-free.
    """
    table, valid = ragged_position_tables(offsets, indices.shape[0],
                                          spec.n_tables)
    flat = indices + table.astype(indices.dtype) * spec.rows_per_table
    return jnp.where(valid, flat,
                     jnp.asarray(spec.null_row, indices.dtype))


def lookup_ragged(arena: jax.Array, spec: ArenaSpec, indices: jax.Array,
                  offsets: jax.Array, *, max_l: int) -> jax.Array:
    """DEPRECATED shim: use ``lookup_bags(FpArena(arena), ...)``."""
    from repro.core import embedding_source as es
    _deprecated("lookup_ragged", "lookup_bags(FpArena(arena), ...)")
    return es.lookup_bags(es.FpArena(arena), spec, indices, offsets,
                          max_l=max_l)


def shard_row_range(arena_shard: jax.Array, axis: str):
    """(lo, vlocal) of the contiguous row block this shard owns."""
    vlocal = arena_shard.shape[0]
    return jax.lax.axis_index(axis) * vlocal, vlocal


def _masked_partial_reduce(gather_f32, lo, vlocal: int, flat: jax.Array,
                           offsets: jax.Array, axis: str) -> jax.Array:
    """The ownership protocol every sharded sparse path shares: foreign
    rows are gathered as local row 0 and zero-masked, partial bags are
    segment-reduced locally, one psum combines them — only reduced
    (n_bags, D) partials ever cross chips. `gather_f32(local_rows)` loads
    shard rows as f32 (plain take, or dequantize-on-load). One body, so
    the fp and int8 sharded paths can never diverge on the masking edge.
    """
    n = flat.shape[0]
    n_bags = offsets.shape[0] - 1
    seg = ragged_segment_ids(offsets, n)
    rel = flat - lo
    mine = (rel >= 0) & (rel < vlocal) & (seg < n_bags)
    safe = jnp.where(mine, rel, 0)
    rows = jnp.where(mine[..., None], gather_f32(safe), 0)   # (N, D)
    part = jax.ops.segment_sum(rows, jnp.minimum(seg, n_bags - 1),
                               num_segments=n_bags)
    return jax.lax.psum(part, axis)


def _masked_fixed_partial_reduce(gather_f32, lo, vlocal: int,
                                 flat: jax.Array, axis: str, *,
                                 null_row=None) -> jax.Array:
    """Fixed-L sibling of ``_masked_partial_reduce`` — the same ownership
    protocol over (B*T, L) row blocks: foreign rows gathered as local row
    0 and zero-masked, per-bag sum, one psum. One body, so the fp and
    int8 fixed-path shard reduces can never diverge on the masking edge
    either. When ``null_row`` is given, references to that always-zero
    sentinel are masked too (same forward, no gradient leaks into the
    sentinel on the shard that owns it). Returns f32 (B*T, D)."""
    rel = flat - lo
    mine = (rel >= 0) & (rel < vlocal)
    if null_row is not None:
        mine = mine & (flat != null_row)
    safe = jnp.where(mine, rel, 0)
    rows = jnp.where(mine[..., None], gather_f32(safe), 0)
    return jax.lax.psum(rows.sum(axis=1), axis)


def ragged_partial_reduce(arena_shard: jax.Array, flat: jax.Array,
                          offsets: jax.Array, axis: str) -> jax.Array:
    """Shard-local half of a ragged reduce over pre-flattened arena rows.
    Must run inside shard_map (or a vmap with a named axis). Returns f32
    (n_bags, D)."""
    lo, vlocal = shard_row_range(arena_shard, axis)
    return _masked_partial_reduce(
        lambda safe: jnp.take(arena_shard, safe, axis=0)
        .astype(jnp.float32), lo, vlocal, flat, offsets, axis)


def ragged_partial_reduce_q(q_shard: jax.Array, scales_shard: jax.Array,
                            flat: jax.Array, offsets: jax.Array,
                            axis: str) -> jax.Array:
    """`ragged_partial_reduce` over a row-sharded int8 arena: owned rows are
    dequantized locally (rows * per-row scale) before the masked segment
    reduce, so raw int8 rows never cross chips either."""
    lo, vlocal = shard_row_range(q_shard, axis)
    return _masked_partial_reduce(
        lambda safe: jnp.take(q_shard, safe, axis=0).astype(jnp.float32)
        * jnp.take(scales_shard, safe, axis=0),
        lo, vlocal, flat, offsets, axis)


def lookup_ragged_sharded(arena_shard: jax.Array, spec: ArenaSpec,
                          indices: jax.Array, offsets: jax.Array,
                          axis: str) -> jax.Array:
    """DEPRECATED shim: shard-local ragged reduce, now
    ``FpArena(arena_shard).shard_reduce_flat`` (inside shard_map)."""
    from repro.core import embedding_source as es
    _deprecated("lookup_ragged_sharded",
                "ShardedArena(FpArena(arena), mesh) with lookup_bags")
    n_bags = offsets.shape[0] - 1
    flat = flatten_ragged_indices(spec, indices, offsets)
    out = es.FpArena(arena_shard).shard_reduce_flat(spec, flat, offsets,
                                                    axis)
    return out.reshape(n_bags // spec.n_tables, spec.n_tables,
                       spec.dim).astype(arena_shard.dtype)


def mesh_shards(mesh: Optional[jax.sharding.Mesh],
                axis: str = "model") -> int:
    """Number of row shards a (mesh, axis) pair implies (1 = replicated)."""
    if mesh is None or axis not in mesh.axis_names:
        return 1
    return mesh.shape[axis]


def lookup_ragged_auto(arena: jax.Array, spec: ArenaSpec,
                       indices: jax.Array, offsets: jax.Array, *,
                       max_l: int,
                       mesh: Optional[jax.sharding.Mesh] = None,
                       axis: str = "model") -> jax.Array:
    """DEPRECATED shim: use ``lookup_bags(resolve_source(arena, mesh))``."""
    from repro.core import embedding_source as es
    _deprecated("lookup_ragged_auto",
                "lookup_bags(resolve_source(arena, mesh))")
    return es.lookup_bags(es.resolve_source(arena, mesh, axis), spec,
                          indices, offsets, max_l=max_l)


def lookup_ragged_quantized(q: jax.Array, scales: jax.Array,
                            spec: ArenaSpec, indices: jax.Array,
                            offsets: jax.Array) -> jax.Array:
    """DEPRECATED shim: use ``lookup_bags(QuantizedArena(q, scales), …)``."""
    from repro.core import embedding_source as es
    _deprecated("lookup_ragged_quantized",
                "lookup_bags(QuantizedArena(q, scales), ...)")
    # this shim predates max_l; the stream length is the safe static bound
    return es.lookup_bags(es.QuantizedArena(q, scales), spec, indices,
                          offsets, max_l=int(indices.shape[0]))


def null_indices(spec: ArenaSpec, shape) -> jax.Array:
    """Per-table ids of given (..., T, L) shape that all flatten to the
    null (always-zero) arena row: id (T - t)*rows_per_table for table t.

    Gathering them is a zero-contribution reduction over one hot-in-cache
    row — the zero-cost dummy stream for pipeline tails.
    """
    assert shape[-2] == spec.n_tables, (shape, spec.n_tables)
    ids = (spec.n_tables - jnp.arange(spec.n_tables, dtype=jnp.int32)) \
        * spec.rows_per_table
    return jnp.broadcast_to(ids[:, None], shape)


# ---------------------------------------------------------------------------
# Hot-row cache (beyond-paper: RecNMP-style exploitation of Zipfian skew)
#
# Production embedding traces are heavily skewed: a few thousand rows absorb
# most lookups. The top-K rows by trace frequency are pinned in a small
# replicated "hot" arena (K+1 rows, slot K the zero null slot); cold rows
# stay in the big sharded / quantized arena. A lookup splits into two
# mask-free fused passes — hot slots (misses -> null slot) + cold rows
# (hits -> null row) — and their sum is exactly the uncached result.
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class HotRowCache:
    hot_rows: jax.Array      # (K+1, D), slot K always zero
    slot_of: jax.Array       # (arena_rows,) int32: slot, or K when cold
    hot_ids: jax.Array       # (K,) int32 pinned arena rows (stats/debug)

    @property
    def k(self) -> int:
        return self.hot_rows.shape[0] - 1


jax.tree_util.register_dataclass(
    HotRowCache, data_fields=("hot_rows", "slot_of", "hot_ids"),
    meta_fields=())


def trace_row_counts(spec: ArenaSpec, indices, offsets=None,
                     rows: Optional[int] = None) -> np.ndarray:
    """Arena-row touch histogram from an access trace (host-side).

    indices: fixed-shape (B, T, L) per-table ids, or — with `offsets` —
    the flat ragged stream (padded tail ignored).
    """
    rows = rows or spec.total_rows
    if offsets is None:
        flat = np.asarray(flatten_indices(spec, jnp.asarray(indices)))
        flat = flat.ravel()
    else:
        idx = np.asarray(indices)
        off = np.asarray(offsets)
        n_valid = int(off[-1])
        seg = np.searchsorted(off[1:], np.arange(n_valid), side="right")
        flat = idx[:n_valid] + (seg % spec.n_tables) * spec.rows_per_table
    return np.bincount(flat, minlength=rows)


def build_hot_cache(arena: jax.Array, spec: ArenaSpec, counts,
                    k: int) -> HotRowCache:
    """Pin the top-k arena rows by trace frequency (host-side build)."""
    counts = np.asarray(counts)[:spec.null_row]     # real rows only
    k = int(min(k, counts.size))
    hot_ids = np.argsort(counts, kind="stable")[::-1][:k].astype(np.int32)
    slot_of = np.full((arena.shape[0],), k, np.int32)
    slot_of[hot_ids] = np.arange(k, dtype=np.int32)
    hot_rows = jnp.concatenate(
        [jnp.take(arena, jnp.asarray(hot_ids), axis=0),
         jnp.zeros((1, arena.shape[1]), arena.dtype)], axis=0)
    return HotRowCache(hot_rows=hot_rows, slot_of=jnp.asarray(slot_of),
                       hot_ids=jnp.asarray(hot_ids))


def cache_split_flat(cache: HotRowCache, null_row: int, flat: jax.Array,
                     offsets: jax.Array, max_l: int):
    """THE hot/cold split over pre-flattened arena row ids — the single
    definition of the exactness-critical protocol (``CachedSource`` and
    the legacy-shaped ``cache_split`` both call it): the hot pass reduces
    cache slots (misses hit the zero null slot), and cold_idx redirects
    cached rows to the arena null row so any cold reduction over it is
    exactly the complement. Returns (hot_sum (n_bags, D) f32,
    cold_idx (N,))."""
    k = cache.hot_rows.shape[0] - 1
    slots = jnp.take(cache.slot_of, flat)
    hot = ops.sparse_lengths_sum(cache.hot_rows, slots, offsets,
                                 max_l=max_l).astype(jnp.float32)
    cold_idx = jnp.where(slots < k, jnp.asarray(null_row, flat.dtype),
                         flat)
    return hot, cold_idx


def cache_split(cache: HotRowCache, spec: ArenaSpec, indices: jax.Array,
                offsets: jax.Array, max_l: int):
    """``cache_split_flat`` over per-table ids (flattens first). Returns
    (hot_sum (n_bags, D) f32, cold_idx (N,), n_bags). Public: benches and
    shard-emulation tests compose custom cold passes from it."""
    n_bags = offsets.shape[0] - 1
    flat = flatten_ragged_indices(spec, indices, offsets)
    hot, cold_idx = cache_split_flat(cache, spec.null_row, flat, offsets,
                                     max_l)
    return hot, cold_idx, n_bags


def lookup_ragged_cached(cache: HotRowCache, arena: jax.Array,
                         spec: ArenaSpec, indices: jax.Array,
                         offsets: jax.Array, *, max_l: int,
                         mesh: Optional[jax.sharding.Mesh] = None,
                         axis: str = "model") -> jax.Array:
    """DEPRECATED shim: use
    ``lookup_bags(CachedSource(cache, resolve_source(arena, mesh)), …)``."""
    from repro.core import embedding_source as es
    _deprecated("lookup_ragged_cached",
                "lookup_bags(CachedSource(cache, <cold source>), ...)")
    src = es.CachedSource(hot=cache,
                          cold=es.resolve_source(arena, mesh, axis))
    return es.lookup_bags(src, spec, indices, offsets, max_l=max_l)


def lookup_ragged_cached_q(cache: HotRowCache, q: jax.Array,
                           scales: jax.Array, spec: ArenaSpec,
                           indices: jax.Array, offsets: jax.Array, *,
                           max_l: int,
                           mesh: Optional[jax.sharding.Mesh] = None,
                           axis: str = "model") -> jax.Array:
    """DEPRECATED shim: use
    ``lookup_bags(CachedSource(cache, QuantizedArena(q, scales)), …)``."""
    from repro.core import embedding_source as es
    _deprecated("lookup_ragged_cached_q",
                "lookup_bags(CachedSource(cache, QuantizedArena(...)), ...)")
    cold = es.QuantizedArena(q=q, scales=scales)
    if mesh_shards(mesh, axis) > 1:
        cold = es.ShardedArena(cold, mesh, axis)
    return es.lookup_bags(es.CachedSource(hot=cache, cold=cold), spec,
                          indices, offsets, max_l=max_l)


def cache_hit_rate(cache: HotRowCache, spec: ArenaSpec, indices: jax.Array,
                   offsets: jax.Array) -> jax.Array:
    """Fraction of (valid) lookups served from the hot arena."""
    k = cache.hot_rows.shape[0] - 1
    flat = flatten_ragged_indices(spec, indices, offsets)
    slots = jnp.take(cache.slot_of, flat)
    n = indices.shape[0]
    valid = jnp.arange(n) < offsets[-1]
    hits = jnp.sum(jnp.where(valid & (slots < k), 1, 0))
    return hits / jnp.maximum(offsets[-1], 1)


def make_zipf_indices(rng: np.random.RandomState, spec: ArenaSpec,
                      batch: int, lookups: int, alpha: float = 1.05) -> np.ndarray:
    """Zipfian sparse-index generator (production access skew), (B, T, L)."""
    raw = rng.zipf(alpha, size=(batch, spec.n_tables, lookups))
    return ((raw - 1) % spec.rows_per_table).astype(np.int32)
