"""The Centaur sparse engine: multi-table embedding gather/reduce.

The paper's EB-Streamer (Fig. 10) is reproduced structurally:

* **BPregs** — every embedding table lives at a base offset inside one flat
  row *arena* ``(total_rows + 1, D)``; the engine's address generator turns a
  (table, row) pair into ``base[t] + row`` exactly like the paper's
  base-pointer + offset logic. The final arena row is an always-zero row used
  as the null target for masked / out-of-shard lookups, which keeps the
  *fused on-the-fly reduction* kernel applicable even on the sharded path.
* **SRAM_sparseID / EB-GU / EB-RU** — the Pallas kernel in
  ``repro.kernels.embedding_gather`` (scalar-prefetched indices driving
  streaming row DMAs with in-VMEM reduction).
* **Shared-memory direct access** — on a pod, the "CPU DIMMs holding the
  tables" become the pod-wide HBM pool: the arena is **row-sharded across the
  'model' mesh axis**; each chip reduces the rows it owns and a single psum
  combines partial bags. Only reduced D-vectors ever cross chips (the same
  reason Centaur streams reductions instead of raw gathered rows).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops


@dataclass(frozen=True)
class ArenaSpec:
    """Static description of the embedding arena (the BPregs contents)."""
    n_tables: int
    rows_per_table: int
    dim: int
    dtype: str = "float32"

    @property
    def total_rows(self) -> int:
        # +1: trailing always-zero null row for masked lookups
        return self.n_tables * self.rows_per_table + 1

    @property
    def null_row(self) -> int:
        return self.n_tables * self.rows_per_table

    def padded_rows(self, shards: int) -> int:
        """Arena rows padded so the row dim divides the model axis."""
        r = self.total_rows
        return ((r + shards - 1) // shards) * shards


def init_arena(key: jax.Array, spec: ArenaSpec, shards: int = 1,
               scale: float = 0.01) -> jax.Array:
    """Arena of all tables, null row zeroed, padded for `shards` row-shards."""
    rows = spec.padded_rows(shards)
    arena = scale * jax.random.normal(key, (rows, spec.dim), jnp.float32)
    arena = arena.at[spec.null_row:].set(0.0)
    return arena.astype(spec.dtype)


def flatten_indices(spec: ArenaSpec, indices: jax.Array) -> jax.Array:
    """(B, T, L) per-table row ids -> (B*T, L) arena row ids (base + offset)."""
    b, t, l = indices.shape
    base = (jnp.arange(t, dtype=indices.dtype) * spec.rows_per_table)
    flat = indices + base[None, :, None]
    return flat.reshape(b * t, l)


def lookup(arena: jax.Array, spec: ArenaSpec, indices: jax.Array) -> jax.Array:
    """Replicated-arena gather+reduce: (B, T, L) -> (B, T, D).

    Single fused kernel call across *all* tables (one EB-Streamer pass).
    """
    b, t, l = indices.shape
    flat = flatten_indices(spec, indices)
    out = ops.embedding_bag(arena, flat)          # (B*T, D)
    return out.reshape(b, t, spec.dim)


def lookup_sharded(arena_shard: jax.Array, spec: ArenaSpec,
                   indices: jax.Array, axis: str) -> jax.Array:
    """Row-sharded gather+reduce for use inside shard_map.

    arena_shard: (rows/n_shards, D) local rows (contiguous row-block shard);
    indices: (B, T, L) replicated. Out-of-shard rows are routed to the null
    row trick *relative to the shard*: rows this chip does not own are
    redirected to a clipped in-range row and zero-masked via a weight of 0 in
    the reduction — implemented by gathering and masking before the local
    reduce, then psum over `axis` combines partial bags.
    """
    n_shards = jax.lax.axis_size(axis)
    my = jax.lax.axis_index(axis)
    vlocal = arena_shard.shape[0]
    lo = my * vlocal

    b, t, l = indices.shape
    flat = flatten_indices(spec, indices)          # (B*T, L) global rows
    rel = flat - lo
    mine = (rel >= 0) & (rel < vlocal)
    # Redirect foreign rows to local row 0 and mask their contribution.
    safe = jnp.where(mine, rel, 0)
    rows = jnp.take(arena_shard, safe, axis=0)     # (B*T, L, D)
    rows = jnp.where(mine[..., None], rows, 0)
    part = rows.astype(jnp.float32).sum(axis=1)    # local partial reduction
    out = jax.lax.psum(part, axis)                 # combine partial bags
    return out.reshape(b, t, spec.dim).astype(arena_shard.dtype)


def lookup_auto(arena: jax.Array, spec: ArenaSpec, indices: jax.Array,
                mesh: Optional[jax.sharding.Mesh] = None,
                axis: str = "model") -> jax.Array:
    """pjit-level entry: row-shard the arena over `axis` when a mesh is given.

    The shard_map below is the production path: it guarantees that only
    reduced (B,T,D) partials cross chips (one psum), never raw gathered rows.
    """
    if mesh is None or axis not in mesh.axis_names or mesh.shape[axis] == 1:
        return lookup(arena, spec, indices)
    from jax.sharding import PartitionSpec as P
    other = tuple(a for a in mesh.axis_names if a != axis)
    batch_spec = P(other if other else None)
    fn = jax.shard_map(
        lambda a, i: lookup_sharded(a, spec, i, axis),
        mesh=mesh,
        in_specs=(P(axis, None), batch_spec),
        out_specs=batch_spec,
        check_vma=False,
    )
    return fn(arena, indices)


def quantize_arena(arena: jax.Array):
    """Row-wise symmetric int8 quantization of the embedding arena.

    The paper's core capacity constraint (tables of 100s of GB must live in
    commodity memory) motivates this beyond-paper lever: int8 rows + one f32
    scale per row = 3.9x capacity, dequantized on the fly inside the gather
    (the EB-RU reduces dequantized rows; a zero scale keeps the null row
    inert). Returns (q int8 (R, D), scales f32 (R, 1)).
    """
    a32 = arena.astype(jnp.float32)
    amax = jnp.max(jnp.abs(a32), axis=-1, keepdims=True)
    scales = amax / 127.0
    q = jnp.where(scales > 0,
                  jnp.clip(jnp.round(a32 / jnp.maximum(scales, 1e-30)),
                           -127, 127), 0).astype(jnp.int8)
    return q, scales


def lookup_quantized(q: jax.Array, scales: jax.Array, spec: ArenaSpec,
                     indices: jax.Array) -> jax.Array:
    """Gather+reduce over an int8 arena: dequantize-per-row then reduce."""
    b, t, l = indices.shape
    flat = flatten_indices(spec, indices)            # (B*T, L)
    rows = jnp.take(q, flat, axis=0).astype(jnp.float32)
    s = jnp.take(scales, flat, axis=0)               # (B*T, L, 1)
    out = (rows * s).sum(axis=1)
    return out.reshape(b, t, spec.dim)


def make_zipf_indices(rng: np.random.RandomState, spec: ArenaSpec,
                      batch: int, lookups: int, alpha: float = 1.05) -> np.ndarray:
    """Zipfian sparse-index generator (production access skew), (B, T, L)."""
    raw = rng.zipf(alpha, size=(batch, spec.n_tables, lookups))
    return ((raw - 1) % spec.rows_per_table).astype(np.int32)
