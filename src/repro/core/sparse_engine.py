"""The Centaur sparse engine: multi-table embedding gather/reduce.

The paper's EB-Streamer (Fig. 10) is reproduced structurally:

* **BPregs** — every embedding table lives at a base offset inside one flat
  row *arena* ``(total_rows + 1, D)``; the engine's address generator turns a
  (table, row) pair into ``base[t] + row`` exactly like the paper's
  base-pointer + offset logic. The final arena row is an always-zero row used
  as the null target for masked / out-of-shard lookups, which keeps the
  *fused on-the-fly reduction* kernel applicable even on the sharded path.
* **SRAM_sparseID / EB-GU / EB-RU** — the Pallas kernel in
  ``repro.kernels.embedding_gather`` (scalar-prefetched indices driving
  streaming row DMAs with in-VMEM reduction).
* **Shared-memory direct access** — on a pod, the "CPU DIMMs holding the
  tables" become the pod-wide HBM pool: the arena is **row-sharded across the
  'model' mesh axis**; each chip reduces the rows it owns and a single psum
  combines partial bags. Only reduced D-vectors ever cross chips (the same
  reason Centaur streams reductions instead of raw gathered rows).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat
from repro.kernels import ops


@dataclass(frozen=True)
class ArenaSpec:
    """Static description of the embedding arena (the BPregs contents)."""
    n_tables: int
    rows_per_table: int
    dim: int
    dtype: str = "float32"

    @property
    def total_rows(self) -> int:
        # +1: trailing always-zero null row for masked lookups
        return self.n_tables * self.rows_per_table + 1

    @property
    def null_row(self) -> int:
        return self.n_tables * self.rows_per_table

    def padded_rows(self, shards: int) -> int:
        """Arena rows padded so the row dim divides the model axis."""
        r = self.total_rows
        return ((r + shards - 1) // shards) * shards


def init_arena(key: jax.Array, spec: ArenaSpec, shards: int = 1,
               scale: float = 0.01) -> jax.Array:
    """Arena of all tables, null row zeroed, padded for `shards` row-shards."""
    rows = spec.padded_rows(shards)
    arena = scale * jax.random.normal(key, (rows, spec.dim), jnp.float32)
    arena = arena.at[spec.null_row:].set(0.0)
    return arena.astype(spec.dtype)


def flatten_indices(spec: ArenaSpec, indices: jax.Array) -> jax.Array:
    """(B, T, L) per-table row ids -> (B*T, L) arena row ids (base + offset)."""
    b, t, l = indices.shape
    base = (jnp.arange(t, dtype=indices.dtype) * spec.rows_per_table)
    flat = indices + base[None, :, None]
    return flat.reshape(b * t, l)


def lookup(arena: jax.Array, spec: ArenaSpec, indices: jax.Array) -> jax.Array:
    """Replicated-arena gather+reduce: (B, T, L) -> (B, T, D).

    Single fused kernel call across *all* tables (one EB-Streamer pass).
    """
    b, t, l = indices.shape
    flat = flatten_indices(spec, indices)
    out = ops.embedding_bag(arena, flat)          # (B*T, D)
    return out.reshape(b, t, spec.dim)


def lookup_sharded(arena_shard: jax.Array, spec: ArenaSpec,
                   indices: jax.Array, axis: str) -> jax.Array:
    """Row-sharded gather+reduce for use inside shard_map.

    arena_shard: (rows/n_shards, D) local rows (contiguous row-block shard);
    indices: (B, T, L) replicated. Out-of-shard rows are routed to the null
    row trick *relative to the shard*: rows this chip does not own are
    redirected to a clipped in-range row and zero-masked via a weight of 0 in
    the reduction — implemented by gathering and masking before the local
    reduce, then psum over `axis` combines partial bags.
    """
    n_shards = compat.axis_size(axis)
    my = jax.lax.axis_index(axis)
    vlocal = arena_shard.shape[0]
    lo = my * vlocal

    b, t, l = indices.shape
    flat = flatten_indices(spec, indices)          # (B*T, L) global rows
    rel = flat - lo
    mine = (rel >= 0) & (rel < vlocal)
    # Redirect foreign rows to local row 0 and mask their contribution.
    safe = jnp.where(mine, rel, 0)
    rows = jnp.take(arena_shard, safe, axis=0)     # (B*T, L, D)
    rows = jnp.where(mine[..., None], rows, 0)
    part = rows.astype(jnp.float32).sum(axis=1)    # local partial reduction
    out = jax.lax.psum(part, axis)                 # combine partial bags
    return out.reshape(b, t, spec.dim).astype(arena_shard.dtype)


def lookup_auto(arena: jax.Array, spec: ArenaSpec, indices: jax.Array,
                mesh: Optional[jax.sharding.Mesh] = None,
                axis: str = "model") -> jax.Array:
    """pjit-level entry: row-shard the arena over `axis` when a mesh is given.

    The shard_map below is the production path: it guarantees that only
    reduced (B,T,D) partials cross chips (one psum), never raw gathered rows.
    """
    if mesh is None or axis not in mesh.axis_names or mesh.shape[axis] == 1:
        return lookup(arena, spec, indices)
    from jax.sharding import PartitionSpec as P
    other = tuple(a for a in mesh.axis_names if a != axis)
    batch_spec = P(other if other else None)
    fn = compat.shard_map(
        lambda a, i: lookup_sharded(a, spec, i, axis),
        mesh=mesh,
        in_specs=(P(axis, None), batch_spec),
        out_specs=batch_spec,
    )
    return fn(arena, indices)


def quantize_arena(arena: jax.Array):
    """Row-wise symmetric int8 quantization of the embedding arena.

    The paper's core capacity constraint (tables of 100s of GB must live in
    commodity memory) motivates this beyond-paper lever: int8 rows + one f32
    scale per row = 3.9x capacity, dequantized on the fly inside the gather
    (the EB-RU reduces dequantized rows; a zero scale keeps the null row
    inert). Returns (q int8 (R, D), scales f32 (R, 1)).
    """
    a32 = arena.astype(jnp.float32)
    amax = jnp.max(jnp.abs(a32), axis=-1, keepdims=True)
    scales = amax / 127.0
    q = jnp.where(scales > 0,
                  jnp.clip(jnp.round(a32 / jnp.maximum(scales, 1e-30)),
                           -127, 127), 0).astype(jnp.int8)
    return q, scales


def lookup_quantized(q: jax.Array, scales: jax.Array, spec: ArenaSpec,
                     indices: jax.Array) -> jax.Array:
    """Gather+reduce over an int8 arena: dequantize-per-row then reduce."""
    b, t, l = indices.shape
    flat = flatten_indices(spec, indices)            # (B*T, L)
    rows = jnp.take(q, flat, axis=0).astype(jnp.float32)
    s = jnp.take(scales, flat, axis=0)               # (B*T, L, 1)
    out = (rows * s).sum(axis=1)
    return out.reshape(b, t, spec.dim)


# ---------------------------------------------------------------------------
# Ragged production path (paper Fig. 2: SparseLengthsSum over ragged bags)
#
# Batch layout: bags are ordered (sample, table) row-major — bag k holds
# sample k // n_tables, table k % n_tables. `indices` is the flat stream of
# per-table row ids for all bags concatenated, possibly padded past
# offsets[-1] (padding is inert); `offsets` has B*T+1 entries.
# ---------------------------------------------------------------------------

def ragged_segment_ids(offsets: jax.Array, n: int) -> jax.Array:
    """Bag id per index position; positions >= offsets[-1] get n_bags."""
    return jnp.searchsorted(offsets[1:], jnp.arange(n, dtype=offsets.dtype),
                            side="right")


def flatten_ragged_indices(spec: ArenaSpec, indices: jax.Array,
                           offsets: jax.Array) -> jax.Array:
    """Per-table row ids (N,) -> arena row ids (N,) (base + offset).

    The owning table of each position follows from its bag id; padded tail
    positions are routed to the always-zero null row so every downstream
    consumer (kernel, cache, quantized reduce) stays mask-free.
    """
    n = indices.shape[0]
    n_bags = offsets.shape[0] - 1
    seg = ragged_segment_ids(offsets, n)
    table = jnp.minimum(seg, n_bags - 1) % spec.n_tables
    flat = indices + table.astype(indices.dtype) * spec.rows_per_table
    return jnp.where(seg < n_bags, flat,
                     jnp.asarray(spec.null_row, indices.dtype))


def lookup_ragged(arena: jax.Array, spec: ArenaSpec, indices: jax.Array,
                  offsets: jax.Array, *, max_l: int) -> jax.Array:
    """Ragged gather+reduce: flat per-table ids + offsets -> (B, T, D).

    One fused sparse_lengths_sum kernel pass across all tables — the
    production replacement for fixed-L `lookup`.
    """
    n_bags = offsets.shape[0] - 1
    b = n_bags // spec.n_tables
    flat = flatten_ragged_indices(spec, indices, offsets)
    out = ops.sparse_lengths_sum(arena, flat, offsets, max_l=max_l)
    return out.reshape(b, spec.n_tables, spec.dim)


def shard_row_range(arena_shard: jax.Array, axis: str):
    """(lo, vlocal) of the contiguous row block this shard owns."""
    vlocal = arena_shard.shape[0]
    return jax.lax.axis_index(axis) * vlocal, vlocal


def _masked_partial_reduce(gather_f32, lo, vlocal: int, flat: jax.Array,
                           offsets: jax.Array, axis: str) -> jax.Array:
    """The ownership protocol every sharded sparse path shares: foreign
    rows are gathered as local row 0 and zero-masked, partial bags are
    segment-reduced locally, one psum combines them — only reduced
    (n_bags, D) partials ever cross chips. `gather_f32(local_rows)` loads
    shard rows as f32 (plain take, or dequantize-on-load). One body, so
    the fp and int8 sharded paths can never diverge on the masking edge.
    """
    n = flat.shape[0]
    n_bags = offsets.shape[0] - 1
    seg = ragged_segment_ids(offsets, n)
    rel = flat - lo
    mine = (rel >= 0) & (rel < vlocal) & (seg < n_bags)
    safe = jnp.where(mine, rel, 0)
    rows = jnp.where(mine[..., None], gather_f32(safe), 0)   # (N, D)
    part = jax.ops.segment_sum(rows, jnp.minimum(seg, n_bags - 1),
                               num_segments=n_bags)
    return jax.lax.psum(part, axis)


def ragged_partial_reduce(arena_shard: jax.Array, flat: jax.Array,
                          offsets: jax.Array, axis: str) -> jax.Array:
    """Shard-local half of a ragged reduce over pre-flattened arena rows.
    Must run inside shard_map (or a vmap with a named axis). Returns f32
    (n_bags, D)."""
    lo, vlocal = shard_row_range(arena_shard, axis)
    return _masked_partial_reduce(
        lambda safe: jnp.take(arena_shard, safe, axis=0)
        .astype(jnp.float32), lo, vlocal, flat, offsets, axis)


def ragged_partial_reduce_q(q_shard: jax.Array, scales_shard: jax.Array,
                            flat: jax.Array, offsets: jax.Array,
                            axis: str) -> jax.Array:
    """`ragged_partial_reduce` over a row-sharded int8 arena: owned rows are
    dequantized locally (rows * per-row scale) before the masked segment
    reduce, so raw int8 rows never cross chips either."""
    lo, vlocal = shard_row_range(q_shard, axis)
    return _masked_partial_reduce(
        lambda safe: jnp.take(q_shard, safe, axis=0).astype(jnp.float32)
        * jnp.take(scales_shard, safe, axis=0),
        lo, vlocal, flat, offsets, axis)


def lookup_ragged_sharded(arena_shard: jax.Array, spec: ArenaSpec,
                          indices: jax.Array, offsets: jax.Array,
                          axis: str) -> jax.Array:
    """Row-sharded ragged gather+reduce for use inside shard_map.

    Same ownership protocol as `lookup_sharded`: foreign rows are gathered
    as local row 0 and zero-masked, partial bags are segment-reduced
    locally, one psum combines them — only reduced (B,T,D) partials cross
    chips.
    """
    n_bags = offsets.shape[0] - 1
    flat = flatten_ragged_indices(spec, indices, offsets)
    out = ragged_partial_reduce(arena_shard, flat, offsets, axis)
    return out.reshape(n_bags // spec.n_tables, spec.n_tables,
                       spec.dim).astype(arena_shard.dtype)


def mesh_shards(mesh: Optional[jax.sharding.Mesh],
                axis: str = "model") -> int:
    """Number of row shards a (mesh, axis) pair implies (1 = replicated)."""
    if mesh is None or axis not in mesh.axis_names:
        return 1
    return mesh.shape[axis]


def lookup_ragged_auto(arena: jax.Array, spec: ArenaSpec,
                       indices: jax.Array, offsets: jax.Array, *,
                       max_l: int,
                       mesh: Optional[jax.sharding.Mesh] = None,
                       axis: str = "model") -> jax.Array:
    """pjit-level ragged entry: row-shard the arena over `axis` on a mesh."""
    if mesh_shards(mesh, axis) == 1:
        return lookup_ragged(arena, spec, indices, offsets, max_l=max_l)
    from jax.sharding import PartitionSpec as P
    fn = compat.shard_map(
        lambda a, i, o: lookup_ragged_sharded(a, spec, i, o, axis),
        mesh=mesh,
        in_specs=(P(axis, None), P(None), P(None)),
        out_specs=P(None, None, None),
    )
    return fn(arena, indices, offsets)


def lookup_ragged_quantized(q: jax.Array, scales: jax.Array,
                            spec: ArenaSpec, indices: jax.Array,
                            offsets: jax.Array) -> jax.Array:
    """Ragged gather+reduce over the int8 arena (dequantize per row)."""
    n_bags = offsets.shape[0] - 1
    flat = flatten_ragged_indices(spec, indices, offsets)
    out = _ragged_reduce_q(q, scales, flat, offsets, n_bags)
    return out.reshape(n_bags // spec.n_tables, spec.n_tables, spec.dim)


def _ragged_reduce_q(q: jax.Array, scales: jax.Array, flat: jax.Array,
                     offsets: jax.Array, n_bags: int) -> jax.Array:
    seg = ragged_segment_ids(offsets, flat.shape[0])
    rows = jnp.take(q, flat, axis=0).astype(jnp.float32) \
        * jnp.take(scales, flat, axis=0)
    return jax.ops.segment_sum(rows, seg, num_segments=n_bags)


def null_indices(spec: ArenaSpec, shape) -> jax.Array:
    """Per-table ids of given (..., T, L) shape that all flatten to the
    null (always-zero) arena row: id (T - t)*rows_per_table for table t.

    Gathering them is a zero-contribution reduction over one hot-in-cache
    row — the zero-cost dummy stream for pipeline tails.
    """
    assert shape[-2] == spec.n_tables, (shape, spec.n_tables)
    ids = (spec.n_tables - jnp.arange(spec.n_tables, dtype=jnp.int32)) \
        * spec.rows_per_table
    return jnp.broadcast_to(ids[:, None], shape)


# ---------------------------------------------------------------------------
# Hot-row cache (beyond-paper: RecNMP-style exploitation of Zipfian skew)
#
# Production embedding traces are heavily skewed: a few thousand rows absorb
# most lookups. The top-K rows by trace frequency are pinned in a small
# replicated "hot" arena (K+1 rows, slot K the zero null slot); cold rows
# stay in the big sharded / quantized arena. A lookup splits into two
# mask-free fused passes — hot slots (misses -> null slot) + cold rows
# (hits -> null row) — and their sum is exactly the uncached result.
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class HotRowCache:
    hot_rows: jax.Array      # (K+1, D), slot K always zero
    slot_of: jax.Array       # (arena_rows,) int32: slot, or K when cold
    hot_ids: jax.Array       # (K,) int32 pinned arena rows (stats/debug)

    @property
    def k(self) -> int:
        return self.hot_rows.shape[0] - 1


jax.tree_util.register_dataclass(
    HotRowCache, data_fields=("hot_rows", "slot_of", "hot_ids"),
    meta_fields=())


def trace_row_counts(spec: ArenaSpec, indices, offsets=None,
                     rows: Optional[int] = None) -> np.ndarray:
    """Arena-row touch histogram from an access trace (host-side).

    indices: fixed-shape (B, T, L) per-table ids, or — with `offsets` —
    the flat ragged stream (padded tail ignored).
    """
    rows = rows or spec.total_rows
    if offsets is None:
        flat = np.asarray(flatten_indices(spec, jnp.asarray(indices)))
        flat = flat.ravel()
    else:
        idx = np.asarray(indices)
        off = np.asarray(offsets)
        n_valid = int(off[-1])
        seg = np.searchsorted(off[1:], np.arange(n_valid), side="right")
        flat = idx[:n_valid] + (seg % spec.n_tables) * spec.rows_per_table
    return np.bincount(flat, minlength=rows)


def build_hot_cache(arena: jax.Array, spec: ArenaSpec, counts,
                    k: int) -> HotRowCache:
    """Pin the top-k arena rows by trace frequency (host-side build)."""
    counts = np.asarray(counts)[:spec.null_row]     # real rows only
    k = int(min(k, counts.size))
    hot_ids = np.argsort(counts, kind="stable")[::-1][:k].astype(np.int32)
    slot_of = np.full((arena.shape[0],), k, np.int32)
    slot_of[hot_ids] = np.arange(k, dtype=np.int32)
    hot_rows = jnp.concatenate(
        [jnp.take(arena, jnp.asarray(hot_ids), axis=0),
         jnp.zeros((1, arena.shape[1]), arena.dtype)], axis=0)
    return HotRowCache(hot_rows=hot_rows, slot_of=jnp.asarray(slot_of),
                       hot_ids=jnp.asarray(hot_ids))


def cache_split(cache: HotRowCache, spec: ArenaSpec, indices: jax.Array,
                offsets: jax.Array, max_l: int):
    """Shared hot/cold protocol: the hot pass reduces cache slots (misses
    hit the zero null slot), and cold_idx redirects cached rows to the
    arena null row so any cold reduction over it is exactly the complement.
    Returns (hot_sum (n_bags, D) f32, cold_idx (N,), n_bags). Public:
    benches and shard-emulation tests compose custom cold passes from it.
    """
    n_bags = offsets.shape[0] - 1
    k = cache.hot_rows.shape[0] - 1
    flat = flatten_ragged_indices(spec, indices, offsets)
    slots = jnp.take(cache.slot_of, flat)
    hot = ops.sparse_lengths_sum(cache.hot_rows, slots, offsets,
                                 max_l=max_l).astype(jnp.float32)
    cold_idx = jnp.where(slots < k,
                         jnp.asarray(spec.null_row, flat.dtype), flat)
    return hot, cold_idx, n_bags


def lookup_ragged_cached(cache: HotRowCache, arena: jax.Array,
                         spec: ArenaSpec, indices: jax.Array,
                         offsets: jax.Array, *, max_l: int,
                         mesh: Optional[jax.sharding.Mesh] = None,
                         axis: str = "model") -> jax.Array:
    """Hot-row-cached ragged lookup, exact vs `lookup_ragged`.

    With a mesh the cold pass runs through the row-sharded arena inside
    shard_map — the Centaur composition: the hot arena stays replicated
    (it is small and absorbs most traffic), cold rows stay shard-resident,
    and only reduced cold partials cross chips. The hot+cold sum is the
    same exact decomposition either way.
    """
    hot, cold_idx, n_bags = cache_split(cache, spec, indices, offsets,
                                        max_l)
    if mesh_shards(mesh, axis) == 1:
        cold = ops.sparse_lengths_sum(arena, cold_idx, offsets,
                                      max_l=max_l).astype(jnp.float32)
    else:
        from jax.sharding import PartitionSpec as P
        fn = compat.shard_map(
            lambda a, f, o: ragged_partial_reduce(a, f, o, axis),
            mesh=mesh,
            in_specs=(P(axis, None), P(None), P(None)),
            out_specs=P(None, None),
        )
        # round through the arena dtype exactly like the replicated cold
        # kernel does, so replicated and sharded stay bit-comparable on
        # low-precision (e.g. bf16) arenas too
        cold = fn(arena, cold_idx, offsets).astype(arena.dtype) \
            .astype(jnp.float32)
    out = hot + cold
    return out.reshape(n_bags // spec.n_tables, spec.n_tables,
                       spec.dim).astype(arena.dtype)


def lookup_ragged_cached_q(cache: HotRowCache, q: jax.Array,
                           scales: jax.Array, spec: ArenaSpec,
                           indices: jax.Array, offsets: jax.Array, *,
                           max_l: int,
                           mesh: Optional[jax.sharding.Mesh] = None,
                           axis: str = "model") -> jax.Array:
    """Hot rows exact (fp replicated arena), cold rows from the int8 arena
    — the capacity configuration: hot working set at full precision, the
    long tail at 3.9x density. With a mesh the int8 cold arena is
    row-sharded like the fp one (scales shard with their rows)."""
    hot, cold_idx, n_bags = cache_split(cache, spec, indices, offsets,
                                        max_l)
    if mesh_shards(mesh, axis) == 1:
        cold = _ragged_reduce_q(q, scales, cold_idx, offsets, n_bags)
    else:
        from jax.sharding import PartitionSpec as P
        fn = compat.shard_map(
            lambda qq, ss, f, o: ragged_partial_reduce_q(qq, ss, f, o,
                                                         axis),
            mesh=mesh,
            in_specs=(P(axis, None), P(axis, None), P(None), P(None)),
            out_specs=P(None, None),
        )
        cold = fn(q, scales, cold_idx, offsets)
    return (hot + cold).reshape(n_bags // spec.n_tables, spec.n_tables,
                                spec.dim)


def cache_hit_rate(cache: HotRowCache, spec: ArenaSpec, indices: jax.Array,
                   offsets: jax.Array) -> jax.Array:
    """Fraction of (valid) lookups served from the hot arena."""
    k = cache.hot_rows.shape[0] - 1
    flat = flatten_ragged_indices(spec, indices, offsets)
    slots = jnp.take(cache.slot_of, flat)
    n = indices.shape[0]
    valid = jnp.arange(n) < offsets[-1]
    hits = jnp.sum(jnp.where(valid & (slots < k), 1, 0))
    return hits / jnp.maximum(offsets[-1], 1)


def make_zipf_indices(rng: np.random.RandomState, spec: ArenaSpec,
                      batch: int, lookups: int, alpha: float = 1.05) -> np.ndarray:
    """Zipfian sparse-index generator (production access skew), (B, T, L)."""
    raw = rng.zipf(alpha, size=(batch, spec.n_tables, lookups))
    return ((raw - 1) % spec.rows_per_table).astype(np.int32)
