"""Host-side input pipeline: background prefetch + device placement.

A small but real pipeline: a worker thread generates/loads batches ahead of
the training step (the host analogue of the EB-Streamer's index prefetch),
double-buffered through a bounded queue, with optional sharded device
placement so each step consumes an already-resident global batch.
"""
from __future__ import annotations

import queue
import threading
from typing import Callable, Dict, Iterator, Optional

import jax
import numpy as np


class Prefetcher:
    """Wrap a host batch iterator with N-deep background prefetch."""

    def __init__(self, it: Iterator[Dict[str, np.ndarray]], depth: int = 2,
                 place: Optional[Callable] = None):
        self._it = it
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._place = place or (lambda x: x)
        self._stop = threading.Event()
        self._exc: Optional[BaseException] = None
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        try:
            for batch in self._it:
                if self._stop.is_set():
                    return
                self._q.put(self._place(batch))
            self._q.put(None)         # end-of-stream sentinel
        except BaseException as e:   # surfaced on next __next__
            self._exc = e
            self._q.put(None)

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is None:
            if self._exc is not None:
                raise self._exc
            raise StopIteration
        return item

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass


def make_placer(mesh: Optional[jax.sharding.Mesh], batch_specs: Dict):
    """Returns fn placing a host batch onto the mesh with given specs."""
    if mesh is None:
        return lambda batch: {k: jax.numpy.asarray(v)
                              for k, v in batch.items()}
    from jax.sharding import NamedSharding

    def place(batch):
        out = {}
        for k, v in batch.items():
            sharding = NamedSharding(mesh, batch_specs[k])
            out[k] = jax.device_put(v, sharding)
        return out
    return place
