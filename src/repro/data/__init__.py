from repro.data.pipeline import Prefetcher, make_placer
from repro.data.synthetic import DLRMSynthetic, LMSynthetic

__all__ = ["DLRMSynthetic", "LMSynthetic", "Prefetcher", "make_placer"]
