"""Synthetic data generators (deterministic, seeded).

DLRM: zipfian sparse index streams (production embedding access skew),
gaussian dense features, bernoulli click labels correlated with a hidden
linear model so training has signal.

LM: token streams with a power-law unigram distribution plus a repeated
n-gram structure so cross-entropy actually falls during the example runs.
"""
from __future__ import annotations

from typing import Dict, Iterator, Optional

import numpy as np

from repro.configs.base import DLRMConfig, ModelConfig


class DLRMSynthetic:
    def __init__(self, cfg: DLRMConfig, seed: int = 0, alpha: float = 1.05):
        self.cfg = cfg
        self.alpha = alpha
        self.rng = np.random.RandomState(seed)
        # hidden ground-truth model for label signal
        self._w = self.rng.randn(cfg.dense_features).astype(np.float32)

    def batch(self, batch_size: int) -> Dict[str, np.ndarray]:
        c = self.cfg
        dense = self.rng.randn(batch_size, c.dense_features).astype(np.float32)
        if c.heterogeneous:
            # per-table vocab and skew: table t draws Zipf(alpha_t) ids
            # folded into its own [0, rows_t) range
            indices = np.empty((batch_size, c.n_tables,
                                c.lookups_per_table), np.int32)
            for t in range(c.n_tables):
                raw = self.rng.zipf(self._alpha_of(t),
                                    size=(batch_size, c.lookups_per_table))
                indices[:, t, :] = (raw - 1) % c.resolved_table_rows[t]
        else:
            raw = self.rng.zipf(self.alpha,
                                size=(batch_size, c.n_tables,
                                      c.lookups_per_table))
            indices = ((raw - 1) % c.rows_per_table).astype(np.int32)
        logit = dense @ self._w * 0.5
        labels = (self.rng.rand(batch_size)
                  < 1.0 / (1.0 + np.exp(-logit))).astype(np.float32)
        return {"dense": dense, "indices": indices, "labels": labels}

    def _alpha_of(self, t: int) -> float:
        alphas = self.cfg.table_alphas
        return self.alpha if alphas is None else alphas[t]

    def stream(self, batch_size: int) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            yield self.batch(batch_size)

    def ragged_batch(self, batch_size: int, dist: str = "poisson",
                     mean_l: Optional[int] = None,
                     max_l: Optional[int] = None,
                     pad_to: Optional[int] = None) -> Dict[str, np.ndarray]:
        """Variable bag-length batch — the ragged production format.

        Per-(sample, table) bag lengths are drawn from `dist`:
          * 'fixed'   — every bag has mean_l lookups (ragged encoding of
                        the fixed path; used for equivalence tests);
          * 'uniform' — lengths uniform on [0, max_l] (empty bags happen,
                        as in production when a user has no history for a
                        feature);
          * 'poisson' — lengths ~ Poisson(mean_l) clipped to [0, max_l].

        Returns {dense, indices (flat per-table ids), offsets (B*T+1,),
        lengths, labels, max_l}. `pad_to` pads the flat index stream with
        zeros past offsets[-1] to a static size (serving bucket shapes);
        padded positions are inert in every ragged consumer.
        """
        c = self.cfg
        mean_l = mean_l if mean_l is not None else c.lookups_per_table
        n_bags = batch_size * c.n_tables
        if dist == "fixed":
            max_l = max_l if max_l is not None else mean_l
            lens = np.full(n_bags, mean_l, np.int32)
        elif dist == "uniform":
            max_l = max_l if max_l is not None else 2 * mean_l
            lens = self.rng.randint(0, max_l + 1, n_bags).astype(np.int32)
        elif dist == "poisson":
            max_l = max_l if max_l is not None else 2 * mean_l
            lens = np.clip(self.rng.poisson(mean_l, n_bags),
                           0, max_l).astype(np.int32)
        else:
            raise ValueError(f"unknown length distribution: {dist}")
        assert mean_l <= max_l, (mean_l, max_l)

        offsets = np.zeros(n_bags + 1, np.int32)
        np.cumsum(lens, out=offsets[1:])
        n = int(offsets[-1])
        if c.heterogeneous:
            # bags are (sample, table) row-major: position p belongs to
            # table seg(p) % T and draws from that table's Zipf + vocab
            seg = np.searchsorted(offsets[1:], np.arange(n), side="right")
            table = seg % c.n_tables
            indices = np.empty(n, np.int32)
            for t in range(c.n_tables):
                m = table == t
                raw = self.rng.zipf(self._alpha_of(t), size=int(m.sum()))
                indices[m] = (raw - 1) % c.resolved_table_rows[t]
        else:
            raw = self.rng.zipf(self.alpha, size=n)
            indices = ((raw - 1) % c.rows_per_table).astype(np.int32)
        if pad_to is not None:
            assert pad_to >= n, (pad_to, n)
            indices = np.concatenate(
                [indices, np.zeros(pad_to - n, np.int32)])

        dense = self.rng.randn(batch_size,
                               c.dense_features).astype(np.float32)
        logit = dense @ self._w * 0.5
        labels = (self.rng.rand(batch_size)
                  < 1.0 / (1.0 + np.exp(-logit))).astype(np.float32)
        return {"dense": dense, "indices": indices, "offsets": offsets,
                "lengths": lens, "labels": labels, "max_l": max_l}

    @staticmethod
    def ragged_per_table(batch: Dict[str, np.ndarray], n_tables: int,
                         pad_to=None):
        """Split one interleaved ragged batch into per-table streams.

        Returns (indices_list, offsets_list): table t's flat id stream
        (its bags concatenated in sample order) and its own (B+1,)
        offsets — the layout ``lookup_bags_per_table`` and the per-table
        ``forward_ragged`` path consume. `pad_to` (int or per-table list)
        pads each table's stream with zeros to a static size.
        """
        off = batch["offsets"]
        idx = batch["indices"]
        n_bags = len(off) - 1
        idx_t, off_t = [], []
        for t in range(n_tables):
            bags = [idx[off[k]:off[k + 1]]
                    for k in range(t, n_bags, n_tables)]
            o = np.zeros(len(bags) + 1, np.int32)
            np.cumsum([len(x) for x in bags], out=o[1:])
            stream = (np.concatenate(bags).astype(np.int32) if o[-1]
                      else np.zeros(0, np.int32))
            if pad_to is not None:
                p = pad_to[t] if isinstance(pad_to, (tuple, list)) \
                    else pad_to
                assert p >= o[-1], (t, p, int(o[-1]))
                stream = np.concatenate(
                    [stream, np.zeros(p - len(stream), np.int32)])
            idx_t.append(stream)
            off_t.append(o)
        return idx_t, off_t

    @staticmethod
    def ragged_to_fixed(batch: Dict[str, np.ndarray],
                        n_tables: int) -> np.ndarray:
        """Equal-length ragged batch -> (B, T, L) fixed indices."""
        lens = np.diff(batch["offsets"])
        l = int(lens[0])
        assert (lens == l).all(), "ragged_to_fixed needs equal-length bags"
        n = int(batch["offsets"][-1])
        b = len(lens) // n_tables
        return batch["indices"][:n].reshape(b, n_tables, l)


class LMSynthetic:
    def __init__(self, cfg: ModelConfig, seed: int = 0):
        self.cfg = cfg
        self.rng = np.random.RandomState(seed)
        v = cfg.vocab_size
        # power-law unigram distribution
        p = 1.0 / np.arange(1, v + 1) ** 1.1
        self._p = p / p.sum()
        # a small bank of "phrases" injected for learnable structure
        self._phrases = [
            self.rng.choice(v, size=8, p=self._p) for _ in range(32)]

    def tokens(self, batch: int, seq: int) -> np.ndarray:
        out = self.rng.choice(self.cfg.vocab_size, size=(batch, seq),
                              p=self._p)
        # inject phrases at random offsets (~25% of tokens)
        n_inject = max(1, seq // 32)
        for b in range(batch):
            for _ in range(n_inject):
                ph = self._phrases[self.rng.randint(len(self._phrases))]
                off = self.rng.randint(0, max(1, seq - len(ph)))
                out[b, off:off + len(ph)] = ph
        return out.astype(np.int32)

    def batch(self, batch: int, seq: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        if cfg.is_encdec:
            return {
                "frames": self.rng.randn(batch, cfg.enc_memory_len,
                                         cfg.d_model).astype(np.float32),
                "tokens": self.tokens(batch, seq),
            }
        if cfg.family == "vlm":
            p = cfg.n_frontend_tokens
            return {
                "patches": self.rng.randn(batch, p, cfg.d_model)
                .astype(np.float32),
                "tokens": self.tokens(batch, max(2, seq - p)),
            }
        return {"tokens": self.tokens(batch, seq)}
