"""Synthetic data generators (deterministic, seeded).

DLRM: zipfian sparse index streams (production embedding access skew),
gaussian dense features, bernoulli click labels correlated with a hidden
linear model so training has signal.

LM: token streams with a power-law unigram distribution plus a repeated
n-gram structure so cross-entropy actually falls during the example runs.
"""
from __future__ import annotations

from typing import Dict, Iterator, Optional

import numpy as np

from repro.configs.base import DLRMConfig, ModelConfig


class DLRMSynthetic:
    def __init__(self, cfg: DLRMConfig, seed: int = 0, alpha: float = 1.05):
        self.cfg = cfg
        self.alpha = alpha
        self.rng = np.random.RandomState(seed)
        # hidden ground-truth model for label signal
        self._w = self.rng.randn(cfg.dense_features).astype(np.float32)

    def batch(self, batch_size: int) -> Dict[str, np.ndarray]:
        c = self.cfg
        dense = self.rng.randn(batch_size, c.dense_features).astype(np.float32)
        raw = self.rng.zipf(self.alpha,
                            size=(batch_size, c.n_tables,
                                  c.lookups_per_table))
        indices = ((raw - 1) % c.rows_per_table).astype(np.int32)
        logit = dense @ self._w * 0.5
        labels = (self.rng.rand(batch_size)
                  < 1.0 / (1.0 + np.exp(-logit))).astype(np.float32)
        return {"dense": dense, "indices": indices, "labels": labels}

    def stream(self, batch_size: int) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            yield self.batch(batch_size)


class LMSynthetic:
    def __init__(self, cfg: ModelConfig, seed: int = 0):
        self.cfg = cfg
        self.rng = np.random.RandomState(seed)
        v = cfg.vocab_size
        # power-law unigram distribution
        p = 1.0 / np.arange(1, v + 1) ** 1.1
        self._p = p / p.sum()
        # a small bank of "phrases" injected for learnable structure
        self._phrases = [
            self.rng.choice(v, size=8, p=self._p) for _ in range(32)]

    def tokens(self, batch: int, seq: int) -> np.ndarray:
        out = self.rng.choice(self.cfg.vocab_size, size=(batch, seq),
                              p=self._p)
        # inject phrases at random offsets (~25% of tokens)
        n_inject = max(1, seq // 32)
        for b in range(batch):
            for _ in range(n_inject):
                ph = self._phrases[self.rng.randint(len(self._phrases))]
                off = self.rng.randint(0, max(1, seq - len(ph)))
                out[b, off:off + len(ph)] = ph
        return out.astype(np.int32)

    def batch(self, batch: int, seq: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        if cfg.is_encdec:
            return {
                "frames": self.rng.randn(batch, cfg.enc_memory_len,
                                         cfg.d_model).astype(np.float32),
                "tokens": self.tokens(batch, seq),
            }
        if cfg.family == "vlm":
            p = cfg.n_frontend_tokens
            return {
                "patches": self.rng.randn(batch, p, cfg.d_model)
                .astype(np.float32),
                "tokens": self.tokens(batch, max(2, seq - p)),
            }
        return {"tokens": self.tokens(batch, seq)}
