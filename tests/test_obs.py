"""Telemetry subsystem: bounded streaming metrics, span tracing, the
swap event log, and their wiring through the serving engine.

The contracts pinned here are the ones the obs redesign sold:

* histogram percentiles are EXACT (vs np.percentile) while the stream
  fits the raw ring, and bucket-bounded afterwards;
* stats() keeps its pre-obs keys and the None-not-0.0 hit-rate rule,
  now from O(1)-memory instruments;
* the hit-rate probe never syncs on the serve hot path — futures are
  converted only at reporting boundaries (pinned with a conversion-spy
  proxy);
* disabled telemetry is genuinely free: the stage hooks return one
  shared null context and the compiled HLO is op-for-op identical with
  annotations on vs off;
* swap events attribute the outgoing version's hit rate
  (hit_rate_by_version), and the since-swap latency window restarts.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs
from repro.configs.dlrm import DLRM_SMOKE
from repro.core import dlrm
from repro.core import embedding_source as es
from repro.core import sparse_engine as se
from repro.data import DLRMSynthetic
from repro.launch import hlo_analysis
from repro.obs.metrics import Histogram, MetricsRegistry
from repro.obs.tracing import Tracer, _NULL
from repro.serving import RecEngine, requests_from_ragged_batch

MAX_L = 6


@pytest.fixture
def setup():
    cfg = DLRM_SMOKE
    params = dlrm.init(jax.random.PRNGKey(0), cfg)
    data = DLRMSynthetic(cfg, seed=9)
    return cfg, params, data


def _make_engine(cfg, params, data, *, source="cached", telemetry=None):
    rb = data.ragged_batch(8, dist="poisson", mean_l=3, max_l=MAX_L)
    spec = dlrm.arena_spec(cfg)
    counts = se.trace_row_counts(spec, rb["indices"], rb["offsets"])
    kw = ({"cache_k": 32, "cache_trace": counts}
          if source == "cached" else {})
    return RecEngine(cfg, params, source=source, max_l=MAX_L,
                     max_batch=4, max_wait_ms=0.0, buckets=(4,),
                     telemetry=telemetry, **kw)


def _serve(engine, data, n=8, seed=None):
    d = data if seed is None else DLRMSynthetic(engine.cfg, seed=seed)
    rb = d.ragged_batch(n, dist="poisson", mean_l=3, max_l=MAX_L)
    reqs = requests_from_ragged_batch(rb, engine.cfg.n_tables,
                                      rid0=engine.served)
    for r in reqs:
        engine.submit(r)
    engine.drain()
    return reqs


# ---------------------------------------------------------------------------
# histograms: exact-while-small, bounded-error forever
# ---------------------------------------------------------------------------

def test_histogram_exact_percentiles_while_stream_fits_ring():
    rng = np.random.default_rng(0)
    vals = rng.lognormal(mean=1.0, sigma=1.0, size=500)
    h = Histogram("t", ring=2048)
    for v in vals:
        h.record(v)
    for q in (50, 90, 95, 99):
        assert h.percentile(q) == pytest.approx(
            float(np.percentile(vals, q)), rel=0, abs=0)
    assert h.count == 500
    assert h.total == pytest.approx(vals.sum())


def test_histogram_bucket_estimate_error_bounded_by_growth():
    rng = np.random.default_rng(1)
    vals = rng.lognormal(mean=1.5, sigma=0.8, size=5000)
    h = Histogram("t", growth=1.08, ring=64)     # 5000 >> ring: estimates
    for v in vals:
        h.record(v)
    for q in (50, 95, 99):
        true = float(np.percentile(vals, q))
        est = h.percentile(q)
        assert abs(est - true) / true <= 0.09, (q, est, true)


def test_histogram_window_and_rolling_views():
    h = Histogram("t", growth=1.08, ring=32)
    for v in np.linspace(1.0, 2.0, 100):
        h.record(v)
    h.reset_window()
    assert h.window_count == 0
    fast = np.linspace(10.0, 20.0, 50)
    for v in fast:
        h.record(v)
    # window sees ONLY the post-reset (10x slower) samples
    assert h.percentile(50, "window") > 5.0
    assert h.window_count == 50
    # rolling = exact over the last ring-full of raw samples
    assert h.percentile(50, "rolling") == pytest.approx(
        float(np.percentile(fast[-32:], 50)))
    # cumulative keeps everything
    assert h.count == 150


def test_histogram_out_of_range_clamps_instead_of_growing():
    h = Histogram("t", lo=1.0, hi=100.0, ring=8)
    for v in (1e-9, 0.5, 1e6):
        h.record(v)
    assert h.count == 3
    assert h._counts.sum() == 3          # every sample landed in a bucket


def test_histogram_fraction_leq_matches_empirical():
    rng = np.random.default_rng(2)
    vals = rng.uniform(1.0, 10.0, size=200)
    h = Histogram("t", ring=2048)
    for v in vals:
        h.record(v)
    for cut in (2.0, 5.0, 9.0):
        assert h.fraction_leq(cut) == pytest.approx(
            float(np.mean(vals <= cut)))


def test_counter_monotone_and_gauge():
    reg = MetricsRegistry()
    c = reg.counter("c")
    c.inc()
    c.inc(2.5)
    assert c.value == 3.5
    with pytest.raises(AssertionError):
        c.inc(-1)
    g = reg.gauge("g")
    g.set(7)
    g.set(3)
    assert g.value == 3.0


def test_registry_get_or_create_and_label_families():
    reg = MetricsRegistry()
    a = reg.histogram("stage_ms", labels={"stage": "emb"})
    b = reg.histogram("stage_ms", labels={"stage": "emb"})
    c = reg.histogram("stage_ms", labels={"stage": "mlp"})
    assert a is b and a is not c
    fam = reg.histograms("stage_ms")
    assert set(fam) == {'stage_ms{stage="emb"}', 'stage_ms{stage="mlp"}'}


def test_exposition_golden():
    reg = MetricsRegistry()
    reg.counter("req_total", "requests", {"path": "cached"}).inc(3)
    reg.gauge("ver", "version").set(2)
    h = reg.histogram("lat_ms", "latency", lo=1.0, hi=100.0, growth=2.0,
                      ring=8)
    for v in (1.0, 2.0, 4.0):
        h.record(v)
    assert reg.exposition() == """\
# HELP req_total requests
# TYPE req_total counter
req_total{path="cached"} 3
# HELP ver version
# TYPE ver gauge
ver 2
# HELP lat_ms latency
# TYPE lat_ms summary
lat_ms{quantile="0.5"} 2
lat_ms{quantile="0.95"} 3.8
lat_ms{quantile="0.99"} 3.96
lat_ms_sum 7
lat_ms_count 3
"""


def test_registry_snapshot_is_jsonable():
    reg = MetricsRegistry()
    reg.counter("c").inc()
    reg.histogram("h").record(1.5)
    snap = json.loads(json.dumps(reg.snapshot()))
    assert snap["counters"]["c"] == 1
    assert snap["histograms"]["h"]["count"] == 1


# ---------------------------------------------------------------------------
# tracing: nesting, bounds, the free disabled path
# ---------------------------------------------------------------------------

def test_span_nesting_assigns_parent_and_trace():
    tr = Tracer()
    with tr.span("outer"):
        with tr.span("inner"):
            pass
        with tr.span("inner2"):
            pass
    inner, inner2, outer = tr.spans()
    assert [s.name for s in (inner, inner2, outer)] == \
        ["inner", "inner2", "outer"]         # children finish first
    assert inner.parent_id == outer.span_id
    assert inner2.parent_id == outer.span_id
    assert inner.trace_id == inner2.trace_id == outer.trace_id
    assert outer.parent_id is None
    with tr.span("next"):
        pass
    assert tr.spans("next")[0].trace_id != outer.trace_id


def test_tracer_record_nests_pretimed_span_under_open_span():
    tr = Tracer()
    with tr.span("step"):
        s = tr.record("pre", 1.0, 2.0)
    step = tr.spans("step")[0]
    assert s.parent_id == step.span_id
    assert s.duration_ms == pytest.approx(1000.0)


def test_tracer_memory_is_bounded():
    tr = Tracer(max_spans=8)
    for _ in range(40):
        with tr.span("s"):
            pass
    assert len(tr.spans()) == 8


def test_disabled_tracer_and_stage_return_shared_null():
    tr = Tracer(enabled=False)
    assert tr.span("x") is tr.span("y") is _NULL
    assert tr.record("x", 0.0, 1.0) is None and not tr.spans()
    # the jit-side hook: one module-level singleton, no allocation
    assert not obs.stage_annotations_enabled()
    assert obs.stage("sparse_lookup") is obs.stage("mlp") is _NULL
    assert obs.step_annotation(3) is _NULL


def test_stage_annotations_leave_compiled_ops_identical(setup):
    """Flipping annotations on must change metadata only: the op
    histogram of the compiled ragged serve step is identical."""
    cfg, params, data = setup
    rb = data.ragged_batch(4, dist="poisson", mean_l=3, max_l=MAX_L,
                           pad_to=4 * cfg.n_tables * MAX_L)
    batch = {"dense": jnp.asarray(rb["dense"]),
             "indices": jnp.asarray(rb["indices"]),
             "offsets": jnp.asarray(rb["offsets"])}
    src = es.FpArena(params["arena"])
    step = dlrm.make_ragged_serve_step(cfg, max_l=MAX_L)

    def op_hist():
        return hlo_analysis.count_ops(
            jax.jit(step).lower(params, batch, src).compile().as_text())

    assert not obs.stage_annotations_enabled()
    off = op_hist()
    obs.enable_stage_annotations(True)
    try:
        on = op_hist()
    finally:
        obs.enable_stage_annotations(False)
    assert on == off


# ---------------------------------------------------------------------------
# event log
# ---------------------------------------------------------------------------

def test_event_log_hit_rate_attribution():
    log = obs.EventLog()
    log.emit("source_swap", version=2, prev_version=1, hits=30.0,
             lookups=40.0)
    log.emit("cache_swap", version=3, prev_version=2, hits=0.0,
             lookups=0.0)                      # served no traffic
    log.emit("hot_cache_rebuild", version=3, k=64)   # not a swap: ignored
    rates = log.hit_rate_by_version()
    assert rates == {1: 0.75, 2: None}
    assert len(log.query("cache_swap")) == 1
    assert log.query(version=3)[0].kind == "cache_swap"
    for line in log.to_jsonl().splitlines():
        json.loads(line)


def test_event_log_is_bounded():
    log = obs.EventLog(max_events=4)
    for i in range(10):
        log.emit("publish", version=i)
    assert len(log) == 4
    assert [e.version for e in log.events] == [6, 7, 8, 9]


# ---------------------------------------------------------------------------
# engine integration
# ---------------------------------------------------------------------------

def test_engine_stats_keeps_compat_keys_and_adds_windows(setup):
    cfg, params, data = setup
    engine = _make_engine(cfg, params, data)
    _serve(engine, data)
    st = engine.stats()
    # the pre-obs surface, unchanged
    for key in ("n", "path", "source", "p50_ms", "p95_ms", "p99_ms",
                "mean_ms", "cache_hit_rate", "cache_version", "buckets"):
        assert key in st, key
    assert st["n"] == 8
    assert 0.0 <= st["cache_hit_rate"] <= 1.0
    assert st["p50_ms"] <= st["p95_ms"] <= st["p99_ms"]
    # exact percentiles while the ring holds the stream: the histogram
    # must agree with the raw per-request latencies
    lats_ms = np.asarray(engine.latencies) * 1e3
    assert st["p50_ms"] == pytest.approx(float(np.percentile(lats_ms, 50)))
    # the new windowed views
    assert st["since_swap"]["n"] == 8
    assert st["rolling"]["n"] == 8
    # ring-backed compatibility properties stay lists
    assert len(engine.latencies) == 8
    assert engine.batch_sizes == [4, 4]


def test_engine_hit_rate_is_none_not_zero_without_cache(setup):
    cfg, params, data = setup
    engine = _make_engine(cfg, params, data, source="ragged")
    _serve(engine, data)
    assert engine.stats()["cache_hit_rate"] is None


def test_engine_swap_attributes_outgoing_version_and_resets_window(setup):
    cfg, params, data = setup
    engine = _make_engine(cfg, params, data)
    spec = dlrm.arena_spec(cfg)
    _serve(engine, data)
    rb = data.ragged_batch(8, dist="poisson", mean_l=3, max_l=MAX_L)
    counts = se.trace_row_counts(spec, rb["indices"], rb["offsets"])
    fresh = se.build_hot_cache(params["arena"], spec, counts, 32)
    engine.update_cache(fresh, version=1)

    (ev,) = engine.telemetry.events.query("cache_swap")
    assert ev.version == 1 and ev.attrs["prev_version"] == 0
    assert ev.attrs["lookups"] > 0
    rate = engine.telemetry.events.hit_rate_by_version()[0]
    assert rate is not None and 0.0 <= rate <= 1.0
    # counters reset with the version; the since-swap window restarts
    # while cumulative history stays
    assert engine._lookups == 0
    st = engine.stats()
    assert st["since_swap"]["n"] == 0 and st["n"] == 8
    assert st["cache_hit_rate"] is None        # no lookups on v1 yet
    # the --metrics-json body carries it all, JSON-able
    snap = json.loads(json.dumps(engine.telemetry.snapshot(), default=str))
    assert snap["hit_rate_by_version"]["0"] == pytest.approx(rate)
    assert any(e["kind"] == "cache_swap" for e in snap["events"])


def test_engine_stale_swap_rejected_with_event(setup):
    cfg, params, data = setup
    engine = _make_engine(cfg, params, data)
    spec = dlrm.arena_spec(cfg)
    rb = data.ragged_batch(4, dist="poisson", mean_l=3, max_l=MAX_L)
    counts = se.trace_row_counts(spec, rb["indices"], rb["offsets"])
    fresh = se.build_hot_cache(params["arena"], spec, counts, 32)
    engine.update_cache(fresh, version=5)
    with pytest.raises(ValueError, match="stale"):
        engine.update_cache(fresh, version=3)
    (ev,) = engine.telemetry.events.query("stale_rejected")
    assert ev.version == 3 and ev.attrs["served_version"] == 5
    reg = engine.telemetry.registry
    assert reg.counter("rec_stale_rejected_total").value == 1
    assert reg.gauge("rec_source_version").value == 5


class _ConversionSpy:
    """Stands in for the hit-rate probe's device future: records whether
    anything host-converted it (the sync the hot path must not pay)."""

    def __init__(self):
        self.converted = False

    def __float__(self):
        self.converted = True
        return 0.5


def test_hit_probe_defers_host_conversion_to_reporting_boundary(setup):
    cfg, params, data = setup
    engine = _make_engine(cfg, params, data)
    spies = []

    def fake_probe(cache, idx, off):
        spies.append(_ConversionSpy())
        return spies[-1]

    engine._hit_rate = fake_probe
    _serve(engine, data)                      # 2 micro-batches
    assert len(spies) == 2
    assert len(engine._pending) == 0          # drain() is a boundary
    assert all(s.converted for s in spies)

    # steps alone (no boundary) must NOT convert
    rb = data.ragged_batch(4, dist="poisson", mean_l=3, max_l=MAX_L)
    for r in requests_from_ragged_batch(rb, cfg.n_tables, rid0=100):
        engine.submit(r)
    engine.step(force=True)
    assert not spies[-1].converted and len(engine._pending) == 1
    engine.stats()                            # reporting boundary
    assert spies[-1].converted and not engine._pending


def test_hit_probe_pending_cap_collects_in_bulk(setup):
    cfg, params, data = setup
    engine = _make_engine(cfg, params, data)
    engine.PENDING_MAX = 3
    spies = []

    def fake_probe(cache, idx, off):
        spies.append(_ConversionSpy())
        return spies[-1]

    engine._hit_rate = fake_probe
    for i in range(3):
        rb = data.ragged_batch(4, dist="poisson", mean_l=3, max_l=MAX_L)
        for r in requests_from_ragged_batch(rb, cfg.n_tables,
                                            rid0=100 * i):
            engine.submit(r)
        engine.step(force=True)
    # third dispatch hit the cap: everything collected, queue empty
    assert all(s.converted for s in spies) and not engine._pending


def test_disabled_telemetry_serves_uninstrumented(setup):
    cfg, params, data = setup
    engine = _make_engine(cfg, params, data,
                          telemetry=obs.Telemetry.disabled())
    reqs = _serve(engine, data)
    assert all(r.prob is not None for r in reqs)     # still serves
    assert engine.stats() == {"n": 0}
    assert engine.latencies == []
    assert engine._lookups == 0 and not engine._pending
    assert not engine.telemetry.tracer.spans()
    spec = dlrm.arena_spec(cfg)
    rb = data.ragged_batch(4, dist="poisson", mean_l=3, max_l=MAX_L)
    counts = se.trace_row_counts(spec, rb["indices"], rb["offsets"])
    engine.update_cache(
        se.build_hot_cache(params["arena"], spec, counts, 32), version=1)
    assert len(engine.telemetry.events) == 0         # emit is a no-op


def test_engine_spans_cover_the_serving_pipeline(setup):
    cfg, params, data = setup
    engine = _make_engine(cfg, params, data,
                          telemetry=obs.Telemetry(tracing=True))
    _serve(engine, data, n=4)                        # one micro-batch
    tr = engine.telemetry.tracer
    (step,) = tr.spans("serve_step")
    assert step.attrs == {"batch_size": 4, "bucket": 4}
    children = {s.name for s in tr.spans()
                if s.parent_id == step.span_id}
    assert children == {"batch", "bucket_pad", "forward", "respond"}
    assert len(tr.spans("enqueue")) == 4             # one per submit


def test_retune_emits_event_and_batch_ring_is_bounded(setup):
    cfg, params, data = setup
    engine = _make_engine(cfg, params, data)
    assert engine._batch_ring.maxlen == 1024         # auto-tune cap
    _serve(engine, data)
    engine.retune_buckets(warmup=False)
    (ev,) = engine.telemetry.events.query("retune")
    assert ev.attrs["old_buckets"] == [4]
    assert ev.attrs["new_buckets"] == list(engine.buckets)


def test_device_stages_match_fused_and_report_live_fig5(setup):
    cfg, params, data = setup
    fused = _make_engine(cfg, params, data)
    staged = _make_engine(cfg, params, data,
                          telemetry=obs.Telemetry(device_stages=True))
    r_f = _serve(fused, data, seed=21)
    r_s = _serve(staged, data, seed=21)
    np.testing.assert_allclose([r.prob for r in r_s],
                               [r.prob for r in r_f], rtol=1e-5,
                               atol=1e-6)
    fig5 = staged.live_fig5()
    assert set(fig5) == {"sparse_lookup_ms", "interaction_ms", "mlp_ms",
                         "total_ms", "emb_frac"}
    assert 0.0 < fig5["emb_frac"] < 1.0
    assert fig5["total_ms"] == pytest.approx(
        fig5["sparse_lookup_ms"] + fig5["interaction_ms"]
        + fig5["mlp_ms"])
    assert staged.stats()["stages"] == staged.live_fig5()
    # 3 labeled stage histograms, 2 batches each
    fam = staged.telemetry.registry.histograms("rec_stage_ms")
    assert len(fam) == 3
    assert all(h.count == 2 for h in fam.values())


def test_fixed_layout_rejects_device_stages(setup):
    cfg, params, _ = setup
    with pytest.raises(AssertionError, match="device_stages"):
        RecEngine(cfg, params, source="fixed",
                  telemetry=obs.Telemetry(device_stages=True))


# ---------------------------------------------------------------------------
# trainer events
# ---------------------------------------------------------------------------

def test_online_trainer_emits_rebuild_events_and_metrics():
    from repro.training import OnlineCacheConfig, OnlineTrainer

    cfg = DLRM_SMOKE
    params = dlrm.init(jax.random.PRNGKey(0), cfg)
    data = DLRMSynthetic(cfg, seed=3)
    tel = obs.Telemetry()
    trainer = OnlineTrainer(cfg, params, max_l=MAX_L, lr=1e-2,
                            cache_cfg=OnlineCacheConfig(k=32,
                                                        refresh_every=4),
                            telemetry=tel)
    pad = 16 * cfg.n_tables * MAX_L
    for _ in range(9):
        trainer.train_step(data.ragged_batch(16, dist="poisson", mean_l=3,
                                             max_l=MAX_L, pad_to=pad))
    rebuilds = tel.events.query("hot_cache_rebuild")
    assert len(rebuilds) == 2                        # steps 4 and 8
    assert rebuilds[-1].version == trainer.version
    assert rebuilds[-1].attrs["k"] == 32
    reg = tel.registry
    assert reg.counter("train_steps_total").value == 9
    assert reg.counter("train_rebuilds_total").value == 2
    assert reg.gauge("train_cache_version").value == trainer.version
    assert reg.gauge("train_loss").value == pytest.approx(
        trainer.losses[-1])
