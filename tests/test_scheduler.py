"""Batcher/scheduler admission invariants (the serving plane).

Property tests (hypothesis) for the open-loop scheduling machinery:

* the batcher's wait deadline runs on the MONOTONIC clock — no request
  waits past its deadline, and a wall-clock (NTP) step can neither
  flush a batch early nor stall it;
* ``plan_batch`` is deterministic given (queue state, SLA) and its
  shed/serve/downgrade split respects FIFO and the deadline;
* the scheduler's ledger balances at every step — submitted ==
  served + shed + queued + in-flight, drained count equals enqueued
  count, and every shed request is accounted for by exactly one event;
* FIFO order within a bucket is preserved across in-flight refills.

The properties run against a deterministic fake engine + fake clock
(no device, no wall time); a small end-to-end section exercises the
real ``RecEngine`` dispatch/settle path, the int8 downgrade source,
and the warm compile-cache pool.
"""
import time

import jax
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro import obs
from repro.configs.dlrm import DLRM_SMOKE
from repro.core import dlrm
from repro.data import DLRMSynthetic
from repro.serving import (InflightBatch, RecBatcher, RecEngine,
                           RecRequest, ServiceEstimator, SlaPolicy,
                           SlaScheduler, plan_batch,
                           requests_from_ragged_batch)
from repro.serving.rec_engine import _bucket


class FakeClock:
    """A monotonic clock the test advances by hand (seconds)."""

    def __init__(self):
        self.t = 1000.0

    def __call__(self):
        return self.t

    def advance(self, s):
        self.t += s


def _req(rid, clock, n_tables=2):
    return RecRequest(rid=rid,
                      dense=np.zeros(2, np.float32),
                      sparse_ids=[np.zeros(1, np.int32)] * n_tables,
                      submitted_mono=clock())


class FakeEngine:
    """The narrow engine surface ``SlaScheduler`` drives, with service
    time modeled on the fake clock: ``settle`` advances it by
    ``service_s`` (the device 'finishing' the batch)."""

    layout = "ragged"

    def __init__(self, clock, service_s=0.004, max_batch=8,
                 buckets=(2, 8), telemetry=None):
        self.clock = clock
        self.service_s = service_s
        self.max_batch = max_batch
        self.buckets = tuple(buckets)
        self.telemetry = telemetry if telemetry is not None \
            else obs.Telemetry()
        self.source_version = 0
        self.downgrade_source = None
        self.dispatched = []            # [(rids tuple, downgraded)]

    def enable_downgrade(self):
        self.downgrade_source = object()
        return self.downgrade_source

    def dispatch(self, reqs, *, downgraded=False):
        self.dispatched.append((tuple(r.rid for r in reqs), downgraded))
        for r in reqs:
            r.downgraded = downgraded
        return InflightBatch(reqs=list(reqs), probs=None,
                             bucket=_bucket(len(reqs), self.buckets),
                             downgraded=downgraded,
                             dispatched_mono=self.clock())

    def settle(self, ib):
        done = max(ib.dispatched_mono + self.service_s, self.clock())
        self.clock.t = done
        for r in ib.reqs:
            r.prob = 0.5
            r.finished_at = time.time()
        return len(ib.reqs)

    def _collect_pending(self):
        pass


def make_sched(clock, policy, **engine_kw):
    eng = FakeEngine(clock, **engine_kw)
    return eng, SlaScheduler(eng, policy, clock=clock)


# ---------------------------------------------------------------------------
# RecBatcher: monotonic wait deadlines
# ---------------------------------------------------------------------------

@settings(deadline=None, max_examples=20)
@given(st.integers(1, 50), st.integers(1, 5))
def test_batcher_deadline_on_monotonic_clock(wait_ms, n):
    """No queued request waits past max_wait_ms on the monotonic clock:
    the batch is held strictly inside the budget and released the
    instant the oldest request's wait reaches it."""
    clock = FakeClock()
    b = RecBatcher(max_batch=100, max_wait_ms=wait_ms, clock=clock)
    for i in range(n):
        b.submit(_req(i, clock))
    t0 = clock()
    clock.advance(wait_ms * 1e-3 * 0.99)
    assert b.take() == []            # inside the budget: held
    clock.t = t0 + wait_ms * 1e-3 * 1.001
    out = b.take()
    assert [r.rid for r in out] == list(range(n))   # at the deadline
    assert len(b) == 0


def test_batcher_immune_to_wall_clock_steps(monkeypatch):
    """An NTP wall-clock step must neither flush a batch early nor
    stall it past max_wait_ms (the old deadline math ran on
    time.time() against submitted_at and did both)."""
    clock = FakeClock()
    b = RecBatcher(max_batch=100, max_wait_ms=10.0, clock=clock)
    req = _req(0, clock)
    req.submitted_at = time.time()
    b.submit(req)
    # wall clock leaps a day forward: still inside the monotonic budget
    monkeypatch.setattr(time, "time", lambda: req.submitted_at + 86400.0)
    assert b.take() == []
    # wall clock leaps backward, monotonic deadline passes: released
    monkeypatch.setattr(time, "time", lambda: req.submitted_at - 86400.0)
    clock.advance(0.011)
    assert [r.rid for r in b.take()] == [0]


def test_batcher_releases_full_batch_regardless_of_clock():
    clock = FakeClock()
    b = RecBatcher(max_batch=2, max_wait_ms=1e9, clock=clock)
    b.submit(_req(0, clock))
    assert b.take() == []
    b.submit(_req(1, clock))
    assert len(b.take()) == 2        # full batch: no wait needed


# ---------------------------------------------------------------------------
# plan_batch: pure, deterministic, FIFO- and deadline-respecting
# ---------------------------------------------------------------------------

_POLICY_STRATEGY = dict(
    sla=st.integers(1, 100),
    shed_margin=st.sampled_from([1.0, 1.5]),
    downgrade_margin=st.sampled_from([0.5, 1.0]),
    allow_shed=st.booleans(),
    allow_downgrade=st.booleans(),
    est_full=st.integers(1, 50),
    est_cheap=st.integers(1, 50),
    inflight=st.integers(0, 100),
)


@settings(deadline=None, max_examples=60)
@given(waits=st.lists(st.integers(0, 200), min_size=0, max_size=12),
       slots=st.integers(1, 8), **_POLICY_STRATEGY)
def test_plan_batch_deterministic_and_invariant(
        waits, slots, sla, shed_margin, downgrade_margin, allow_shed,
        allow_downgrade, est_full, est_cheap, inflight):
    """Shed/downgrade decisions are a deterministic function of (queue
    state, SLA): same inputs -> same plan; sheds are exactly the
    hopeless FIFO prefix; the admitted head makes the shed deadline."""
    waits = sorted([float(w) for w in waits], reverse=True)  # FIFO: head oldest
    policy = SlaPolicy(sla_ms=float(sla), shed_margin=shed_margin,
                       downgrade_margin=downgrade_margin,
                       allow_shed=allow_shed,
                       allow_downgrade=allow_downgrade)
    kw = dict(slots=slots, policy=policy, est_full_ms=float(est_full),
              est_cheap_ms=float(est_cheap), inflight_ms=float(inflight))
    plan = plan_batch(waits, **kw)
    assert plan == plan_batch(waits, **kw)          # deterministic
    assert 0 <= plan.shed <= len(waits)
    assert 0 <= plan.serve <= min(slots, len(waits) - plan.shed)
    assert plan.shed + plan.serve <= len(waits)
    if not allow_shed:
        assert plan.shed == 0
    if not allow_downgrade:
        assert not plan.downgraded
    deadline = policy.sla_ms * policy.shed_margin
    cheapest = (min(est_full, est_cheap) if allow_downgrade else est_full)
    # sheds are exactly the hopeless prefix — FIFO is never reordered
    for i in range(plan.shed):
        assert waits[i] + inflight + cheapest > deadline
    if allow_shed and plan.serve > 0:
        assert waits[plan.shed] + inflight + cheapest <= deadline
        # the admitted head's prediction makes the deadline (guaranteed
        # when the downgrade escape hatch sits below the shed margin)
        if downgrade_margin <= shed_margin:
            assert plan.predicted_ms <= deadline + 1e-9


def test_plan_batch_downgrades_only_when_cheaper():
    policy = SlaPolicy(sla_ms=10.0, downgrade_margin=0.5)
    kw = dict(slots=4, policy=policy, inflight_ms=0.0)
    # full path would cross the margin and int8 is cheaper: downgrade
    plan = plan_batch([2.0], est_full_ms=8.0, est_cheap_ms=4.0, **kw)
    assert plan.downgraded and plan.predicted_ms == 6.0
    # int8 not actually cheaper (CPU-style estimate): never downgrade
    plan = plan_batch([2.0], est_full_ms=8.0, est_cheap_ms=8.0, **kw)
    assert not plan.downgraded
    # comfortably under the margin: serve full precision
    plan = plan_batch([0.0], est_full_ms=3.0, est_cheap_ms=1.0, **kw)
    assert not plan.downgraded


def test_service_estimator_is_deterministic_and_falls_back():
    a, b = ServiceEstimator(default_ms=7.0), ServiceEstimator(default_ms=7.0)
    assert a.estimate("primary", 8) == 7.0          # cold prior
    assert a.estimate("downgrade", 8) == 7.0        # borrows primary
    for est in (a, b):
        est.observe("primary", 8, 4.0)
        est.observe("primary", 8, 2.0)
        est.observe("downgrade", 2, 1.0)
    assert a.estimate("primary", 8) == b.estimate("primary", 8)
    assert a.estimate("primary", 2) == a.estimate("primary", 8)  # nearest
    assert a.estimate("downgrade", 8) == 1.0        # nearest observed


# ---------------------------------------------------------------------------
# SlaScheduler: ledger balance, FIFO across refills, shed accounting
# ---------------------------------------------------------------------------

@settings(deadline=None, max_examples=25)
@given(bursts=st.lists(st.integers(0, 6), min_size=1, max_size=8),
       sla=st.sampled_from([2, 20, 1000]),
       allow_downgrade=st.booleans())
def test_scheduler_ledger_balances_at_every_step(bursts, sla,
                                                 allow_downgrade):
    """submitted == served + shed + queued + inflight at every point;
    after drain the queue and pipeline are empty and the drained count
    equals the enqueued count (minus accounted sheds)."""
    clock = FakeClock()
    eng, sched = make_sched(clock, SlaPolicy(
        sla_ms=float(sla), allow_downgrade=allow_downgrade,
        max_queue=16, default_service_ms=4.0))
    rid = 0

    def balanced():
        assert sched.submitted == (sched.served + sched.shed
                                   + len(sched._queue) + sched.inflight)

    for burst in bursts:
        for _ in range(burst):
            sched.submit(_req(rid, clock))
            rid += 1
            clock.advance(0.001)
            balanced()
        sched.pump()
        balanced()
    drained = sched.drain()
    balanced()
    assert len(sched._queue) == 0 and sched.inflight == 0
    assert sched.submitted == rid
    assert sched.served + sched.shed == rid        # drained == enqueued
    assert drained <= sched.served
    # every shed request carries exactly one shed event + the flag
    shed_events = [e for e in sched.telemetry.events.events
                   if e.kind == "shed"]
    assert len(shed_events) == sched.shed
    assert int(sched._c_shed.value) == sched.shed
    # and the final drain event closes the ledger
    drain_ev = [e for e in sched.telemetry.events.events
                if e.kind == "drain"][-1]
    assert drain_ev.attrs["served"] == sched.served
    assert drain_ev.attrs["shed"] == sched.shed


def test_scheduler_fifo_preserved_across_refills():
    """Requests are dispatched in strict rid order even while earlier
    batches are still in flight (refill never reorders the queue)."""
    clock = FakeClock()
    eng, sched = make_sched(clock, SlaPolicy(
        sla_ms=1e6, allow_shed=False, allow_downgrade=False),
        max_batch=4)
    rid = 0
    for _ in range(6):                  # bursts interleaved with pumps
        for _ in range(3):
            sched.submit(_req(rid, clock))
            rid += 1
        clock.advance(0.002)
        sched.pump()
    sched.drain()
    order = [r for rids, _ in eng.dispatched for r in rids]
    assert order == sorted(order) == list(range(rid))
    assert sched.served == rid and sched.shed == 0
    # refills actually happened (batches dispatched behind in-flight ones)
    assert int(sched._c_refill.value) > 0


def test_scheduler_sheds_hopeless_and_downgrades_under_pressure():
    clock = FakeClock()
    eng, sched = make_sched(clock, SlaPolicy(
        sla_ms=10.0, downgrade_margin=0.5, default_service_ms=4.0))
    # teach the estimator the int8 path is cheaper (as calibration would)
    sched.estimator.observe("primary", 8, 4.0)
    sched.estimator.observe("downgrade", 8, 2.0)
    sched.estimator.observe("primary", 2, 4.0)
    sched.estimator.observe("downgrade", 2, 2.0)
    stale = _req(0, clock)
    sched.submit(stale)
    clock.advance(0.020)                # 20ms > sla: hopeless
    fresh = [_req(i, clock) for i in range(1, 9)]
    for r in fresh:
        sched.submit(r)
    clock.advance(0.004)                # 4ms + full 4ms > 5ms margin
    sched.pump()
    sched.drain()
    assert stale.shed and stale.prob is None
    assert sched.shed == 1 and sched.served == 8
    assert sched.downgraded == 8       # pressure picked the int8 path
    assert all(r.downgraded for r in fresh)
    kinds = [e.kind for e in sched.telemetry.events.events]
    assert kinds.count("shed") == 1 and "downgrade" in kinds


def test_scheduler_hard_queue_cap_sheds_at_submit():
    clock = FakeClock()
    eng, sched = make_sched(clock, SlaPolicy(sla_ms=1e6, max_queue=4))
    accepted = [sched.submit(_req(i, clock)) for i in range(7)]
    assert accepted == [True] * 4 + [False] * 3
    assert sched.shed == 3
    reasons = [e.attrs["reason"] for e in sched.telemetry.events.events
               if e.kind == "shed"]
    assert reasons == ["queue_full"] * 3
    sched.drain()
    assert sched.served == 4


# ---------------------------------------------------------------------------
# Real engine end-to-end: dispatch/settle, downgrade source, warm pool
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def served_engine():
    cfg = DLRM_SMOKE
    params = dlrm.init(jax.random.PRNGKey(0), cfg)
    data = DLRMSynthetic(cfg, seed=9)
    rb = data.ragged_batch(24, dist="poisson", mean_l=3, max_l=6)
    reqs = requests_from_ragged_batch(rb, cfg.n_tables)
    eng = RecEngine(cfg, params, source="ragged", max_l=6, max_batch=8,
                    buckets=(2, 8), telemetry=obs.Telemetry())
    return cfg, eng, reqs


def test_engine_dispatch_settle_matches_step_path(served_engine):
    cfg, eng, reqs = served_engine
    eng.enable_downgrade()
    eng.warmup()
    assert eng._c_cold.value == 0
    batch = reqs[:8]
    ib = eng.dispatch(batch)
    assert [r.rid for r in ib.reqs] == [r.rid for r in batch]
    assert eng.settle(ib) == 8
    full = [r.prob for r in batch]
    assert all(p is not None for p in full)
    # the downgrade path serves the same requests within int8 error,
    # through the SAME jit (different call-time pytree)
    ib = eng.dispatch(batch, downgraded=True)
    assert eng.settle(ib) == 8
    down = [r.prob for r in batch]
    assert all(r.downgraded for r in batch)
    np.testing.assert_allclose(down, full, atol=0.05)
    # warm pool: both paths on both buckets were compiled by warmup,
    # so no dispatch above paid a cold compile
    assert {("primary", 2), ("primary", 8),
            ("downgrade", 2), ("downgrade", 8)} <= eng._warm
    assert eng._c_cold.value == 0
    # latency and queue-wait are recorded per request, on monotonic time
    assert eng._qwait_hist.count >= 16
    assert all(v >= 0 for v in eng._lat_hist.ring_values())


def test_cold_compile_counter_trips_without_warmup():
    cfg = DLRM_SMOKE
    params = dlrm.init(jax.random.PRNGKey(0), cfg)
    eng = RecEngine(cfg, params, source="ragged", max_l=6, max_batch=8,
                    buckets=(8,))
    data = DLRMSynthetic(cfg, seed=9)
    reqs = requests_from_ragged_batch(
        data.ragged_batch(8, dist="poisson", mean_l=3, max_l=6),
        cfg.n_tables)
    eng.settle(eng.dispatch(reqs))
    assert eng._c_cold.value == 1      # unwarmed bucket paid its compile


def test_queue_depth_gauge_live_and_drain_event(served_engine):
    cfg, _, reqs = served_engine
    params = dlrm.init(jax.random.PRNGKey(0), cfg)
    eng = RecEngine(cfg, params, source="ragged", max_l=6, max_batch=8,
                    buckets=(8,))
    for i, r in enumerate(reqs[:5]):
        eng.submit(r)
        assert eng._g_queue.value == i + 1      # live on enqueue
    eng.drain()
    assert eng._g_queue.value == 0              # true depth after drain
    drain_ev = [e for e in eng.telemetry.events.events
                if e.kind == "drain"]
    assert drain_ev and drain_ev[-1].attrs["served"] == 5
    assert drain_ev[-1].attrs["queue_depth"] == 0


def test_scheduler_end_to_end_on_real_engine(served_engine):
    cfg, _, _ = served_engine
    params = dlrm.init(jax.random.PRNGKey(0), cfg)
    data = DLRMSynthetic(cfg, seed=3)
    reqs = requests_from_ragged_batch(
        data.ragged_batch(32, dist="poisson", mean_l=3, max_l=6),
        cfg.n_tables)
    eng = RecEngine(cfg, params, source="ragged", max_l=6, max_batch=8,
                    buckets=(2, 8))
    sched = SlaScheduler(eng, SlaPolicy(sla_ms=250.0, max_queue=64))
    sched.warmup()
    for r in reqs:
        r.submitted_mono = time.monotonic()
        sched.submit(r)
        sched.pump()
    sched.drain()
    assert sched.submitted == 32
    assert sched.served + sched.shed == 32
    for r in reqs:
        assert (r.prob is not None) != r.shed    # served XOR shed
    shed_events = [e for e in eng.telemetry.events.events
                   if e.kind == "shed"]
    assert len(shed_events) == sched.shed
    s = sched.stats()
    assert s["submitted"] == 32 and 0.0 <= s["shed_frac"] <= 1.0
    if sched.served:
        assert s["n"] == sched.served
