import os
import sys

# NOTE: do NOT set --xla_force_host_platform_device_count here — smoke tests
# and benches must see 1 device (the dry-run sets 512 itself, in-process).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402
import pytest  # noqa: E402


@pytest.fixture
def rng():
    return np.random.RandomState(0)
