import os
import sys

# NOTE: do NOT set --xla_force_host_platform_device_count here — smoke tests
# and benches must see 1 device (the dry-run sets 512 itself, in-process).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402
import pytest  # noqa: E402

# ---------------------------------------------------------------------------
# Hypothesis guard: tier-1 must collect and run everywhere, including
# containers without `hypothesis` installed (pip install is unavailable).
# When the real package is absent we register a minimal deterministic
# stand-in under the same module names: `@given` runs the test body over a
# small fixed grid of boundary examples per strategy instead of random
# search. Property tests therefore still *execute* (weaker search, same
# oracle) rather than erroring at collection or silently skipping. With
# `pip install -r requirements-dev.txt` (e.g. in CI) the real hypothesis
# takes over untouched.
# ---------------------------------------------------------------------------
try:
    import hypothesis  # noqa: F401
except ImportError:
    import functools
    import inspect
    import itertools
    import types

    _MAX_COMBOS = 16

    class _Strategy:
        """A strategy reduced to an explicit list of boundary examples."""

        def __init__(self, examples):
            self.examples = list(examples)

        def map(self, fn):
            return _Strategy([fn(e) for e in self.examples])

        def filter(self, pred):
            kept = [e for e in self.examples if pred(e)]
            return _Strategy(kept or self.examples[:1])

    def _integers(min_value, max_value):
        span = max_value - min_value
        vals = {min_value, max_value,
                min_value + span // 2, min_value + span // 3}
        return _Strategy(sorted(vals))

    def _sampled_from(seq):
        return _Strategy(list(seq))

    def _booleans():
        return _Strategy([False, True])

    def _lists(elem, min_size=0, max_size=None):
        if max_size is None:
            max_size = min_size + 3
        ex = elem.examples
        out = []
        for size in {min_size, max_size, (min_size + max_size) // 2}:
            out.append([ex[i % len(ex)] for i in range(size)])
            out.append([ex[(i + 1) % len(ex)] for i in range(size)])
        return _Strategy(out)

    def _tuples(*strats):
        return _Strategy(list(itertools.islice(
            itertools.product(*[s.examples for s in strats]), _MAX_COMBOS)))

    def _just(v):
        return _Strategy([v])

    def _given(*strats, **kw_strats):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                pools = [s.examples for s in strats]
                kw_names = list(kw_strats)
                pools += [kw_strats[k].examples for k in kw_names]
                combos = itertools.islice(itertools.product(*pools),
                                          _MAX_COMBOS)
                for combo in combos:
                    pos = combo[:len(strats)]
                    kws = dict(zip(kw_names, combo[len(strats):]))
                    fn(*args, *pos, **kws, **kwargs)

            # Hide the strategy-filled parameters from pytest's fixture
            # resolution (real hypothesis does the same): positional
            # strategies bind to the *rightmost* parameters, keyword
            # strategies to their names; anything left is a fixture.
            sig = inspect.signature(fn)
            params = list(sig.parameters.values())
            keep = params[:len(params) - len(strats)] if strats else params
            keep = [p for p in keep if p.name not in kw_strats]
            wrapper.__signature__ = sig.replace(parameters=keep)
            return wrapper
        return deco

    def _settings(*a, **kw):
        def deco(fn):
            return fn
        return deco

    _hyp = types.ModuleType("hypothesis")
    _hyp.given = _given
    _hyp.settings = _settings
    _hyp.assume = lambda cond: None
    _hyp.HealthCheck = types.SimpleNamespace(too_slow=None, data_too_large=None)
    _hyp.__is_repro_fallback__ = True

    _st = types.ModuleType("hypothesis.strategies")
    _st.integers = _integers
    _st.sampled_from = _sampled_from
    _st.booleans = _booleans
    _st.lists = _lists
    _st.tuples = _tuples
    _st.just = _just

    _hyp.strategies = _st
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st


@pytest.fixture
def rng():
    return np.random.RandomState(0)
