"""Online ragged-training subsystem tests.

Gradient correctness: the Pallas-path sparse_lengths_sum VJP (fused segment
scatter-add kernel) against the XLA autodiff reference over ragged cases —
empty bags, duplicate indices, padded tails. (The quantized-cold serving
path is excluded: int8 rows are a serving capacity lever, not a training
target.) Optimizer: the row-wise sparse update is exact vs the dense
row-wise Adagrad. System: the online trainer reduces loss with cache
refresh enabled, keeps hot+cold composition exact under updates, and its
refreshed cache sustains a hit rate >= an offline-built cache on a
drifting Zipf trace.
"""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs.dlrm import DLRM_SMOKE
from repro.core import dlrm
from repro.core import embedding_source as es
from repro.core import sparse_engine as se
from repro.kernels import embedding_gather as eg
from repro.kernels import ops
from repro.kernels import ref as kref
from repro.optim import rowwise_adagrad
from repro.training import (OnlineCacheConfig, OnlineTrainer,
                            make_drifting_zipf, ragged_row_grads,
                            sparse_rowwise_adagrad)
from repro.training.online import _patch_hot_rows


def _ragged_case(rng, v, n_bags, max_l, pad=0, dup=True):
    """Random ragged case with an empty bag, a full bag, duplicate indices
    and a padded tail forced in."""
    lens = rng.randint(0, max_l + 1, n_bags).astype(np.int32)
    if n_bags > 1:
        lens[0] = 0
        lens[-1] = max_l
    off = np.zeros(n_bags + 1, np.int32)
    np.cumsum(lens, out=off[1:])
    n = int(off[-1])
    idx = rng.randint(0, v, max(n, 1) + pad).astype(np.int32)
    if dup and n >= 2:
        idx[1] = idx[0]           # duplicate within/across bags
    return jnp.asarray(idx), jnp.asarray(off)


def _manual_grad(g, idx, off, v):
    idx, off, g = np.asarray(idx), np.asarray(off), np.asarray(g)
    seg = np.searchsorted(off[1:], np.arange(len(idx)), side="right")
    out = np.zeros((v, g.shape[-1]), np.float32)
    for p in range(int(off[-1])):
        out[idx[p]] += g[seg[p]]
    return out


# ---------------------------------------------------------------------------
# kernel-level: fused scatter-add vs XLA reference vs python loop
# ---------------------------------------------------------------------------

@settings(deadline=None, max_examples=10)
@given(st.integers(1, 5), st.integers(1, 5), st.integers(0, 2**31 - 1))
def test_sls_grad_kernel_vs_ref_property(n_bags, max_l, seed):
    rng = np.random.RandomState(seed % (2**32 - 1))
    v, d = 19, 8
    idx, off = _ragged_case(rng, v, n_bags, max_l, pad=rng.randint(0, 4))
    g = jnp.asarray(rng.randn(n_bags, d), jnp.float32)
    got = eg.sls_grad_table(g, idx, off, n_rows=v, interpret=True)
    want = kref.sls_grad_table(g, idx, off, v)
    manual = _manual_grad(g, idx, off, v)
    np.testing.assert_allclose(np.asarray(got), manual, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(want), manual, rtol=1e-5,
                               atol=1e-5)


# ---------------------------------------------------------------------------
# op-level: Pallas-path VJP vs XLA autodiff of the pure reference
# ---------------------------------------------------------------------------

@settings(deadline=None, max_examples=8)
@given(st.integers(1, 4), st.integers(1, 4), st.integers(0, 2**31 - 1))
def test_sls_vjp_vs_xla_autodiff_property(n_bags, max_l, seed):
    rng = np.random.RandomState(seed % (2**32 - 1))
    v, d = 23, 8
    table = jnp.asarray(rng.randn(v, d), jnp.float32)
    idx, off = _ragged_case(rng, v, n_bags, max_l, pad=2)
    w = jnp.asarray(rng.randn(n_bags, d), jnp.float32)

    # pure-XLA autodiff through the un-wrapped reference (no custom VJP)
    want = jax.grad(
        lambda t: jnp.sum(kref.sparse_lengths_sum(t, idx, off) * w))(table)

    ops.set_impl("interpret")
    try:
        got = jax.grad(lambda t: jnp.sum(
            ops.sparse_lengths_sum(t, idx, off, max_l=max_l) * w))(table)
    finally:
        ops.set_impl("auto")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_sls_vjp_duplicate_and_empty():
    table = jnp.asarray(np.arange(24, dtype=np.float32).reshape(6, 4))
    idx = jnp.asarray([5, 5, 5, 2], jnp.int32)
    off = jnp.asarray([0, 0, 3, 4], jnp.int32)    # bag 0 empty
    for impl in ("xla", "interpret"):
        ops.set_impl(impl)
        try:
            g = jax.grad(lambda t: ops.sparse_lengths_sum(
                t, idx, off, max_l=3).sum())(table)
        finally:
            ops.set_impl("auto")
        assert float(g[5, 0]) == 3.0, impl     # summed duplicates
        assert float(g[2, 0]) == 1.0, impl
        assert float(jnp.abs(g[0]).max()) == 0.0, impl


# ---------------------------------------------------------------------------
# model-level: jax.grad through forward_ragged, pallas vs xla (acceptance)
# ---------------------------------------------------------------------------

def test_grad_forward_ragged_pallas_matches_xla(rng):
    cfg = DLRM_SMOKE
    params = dlrm.init(jax.random.PRNGKey(0), cfg)
    max_l = 5
    gen = make_drifting_zipf(cfg, batch_size=6, mean_l=3, max_l=max_l,
                             seed=3)
    b = next(gen)
    args = (jnp.asarray(b["dense"]), jnp.asarray(b["indices"]),
            jnp.asarray(b["offsets"]), jnp.asarray(b["labels"]))

    def grads(impl):
        ops.set_impl(impl)
        try:
            return jax.grad(lambda p: dlrm.loss_ragged(
                p, cfg, *args[:3], args[3], max_l=max_l))(params)
        finally:
            ops.set_impl("auto")

    gx, gp = grads("xla"), grads("interpret")
    jax.tree_util.tree_map(
        lambda a, c: np.testing.assert_allclose(np.asarray(a), np.asarray(c),
                                                atol=1e-4), gx, gp)


# ---------------------------------------------------------------------------
# row-wise sparse optimizer: exact vs dense row-wise Adagrad
# ---------------------------------------------------------------------------

def test_sparse_optimizer_matches_dense_rowwise_adagrad(rng):
    v, d, n_bags, max_l = 40, 8, 6, 4
    arena = jnp.asarray(rng.randn(v, d), jnp.float32)
    idx, off = _ragged_case(rng, v - 1, n_bags, max_l, pad=3)
    d_bags = jnp.asarray(rng.randn(n_bags, d), jnp.float32)
    null_row = v - 1

    dense_grad = jnp.asarray(_manual_grad(d_bags, idx, off, v))
    dense_opt = rowwise_adagrad(0.1)
    dstate = dense_opt.init(arena)
    want_arena, _ = dense_opt.update(dense_grad, dstate, arena)

    sp = sparse_rowwise_adagrad(0.1)
    sstate = sp.init(arena)
    rows, row_g = ragged_row_grads(d_bags, idx, off, fill_row=null_row)
    got_arena, sstate2 = sp.update(arena, sstate, rows, row_g)

    np.testing.assert_allclose(np.asarray(got_arena), np.asarray(want_arena),
                               rtol=1e-5, atol=1e-6)
    # second step still matches (accumulator state carried correctly)
    want2, _ = dense_opt.update(dense_grad,
                                {"acc": jnp.mean(jnp.square(dense_grad),
                                                 -1, keepdims=True),
                                 "step": jnp.ones((), jnp.int32)},
                                want_arena)
    got2, _ = sp.update(got_arena, sstate2, rows, row_g)
    np.testing.assert_allclose(np.asarray(got2), np.asarray(want2),
                               rtol=1e-5, atol=1e-6)
    # untouched rows stayed bit-identical
    touched = set(np.asarray(rows).tolist())
    for r in range(v):
        if r not in touched:
            np.testing.assert_array_equal(np.asarray(got_arena[r]),
                                          np.asarray(arena[r]))


def test_ragged_row_grads_sums_duplicates(rng):
    d_bags = jnp.asarray([[1.0, 2.0], [10.0, 20.0]], jnp.float32)
    idx = jnp.asarray([7, 7, 3, 0], jnp.int32)     # 7 twice in bag 0
    off = jnp.asarray([0, 3, 3], jnp.int32)        # bag 1 empty; pos 3 pad
    rows, g = ragged_row_grads(d_bags, idx, off, fill_row=9)
    lut = {int(r): np.asarray(gr) for r, gr in zip(rows, g)}
    np.testing.assert_allclose(lut[7], [2.0, 4.0])
    np.testing.assert_allclose(lut[3], [1.0, 2.0])
    assert 0 not in lut or np.abs(lut[0]).max() == 0.0   # pad position inert
    np.testing.assert_allclose(lut[9], [0.0, 0.0])       # fill row zero-grad


# ---------------------------------------------------------------------------
# hot-cache write-through patch: exactness invariant under arena updates
# ---------------------------------------------------------------------------

def test_patch_hot_rows_keeps_composition_exact(rng):
    spec = se.ArenaSpec(2, 20, 8)
    arena = se.init_arena(jax.random.PRNGKey(0), spec)
    idx, off = _ragged_case(rng, spec.rows_per_table, 4, 3)
    counts = se.trace_row_counts(spec, idx, off)
    cache = se.build_hot_cache(arena, spec, counts, k=6)

    # "train": perturb a mix of rows guaranteed to include looked-up hot
    # rows (hot_ids are the trace's most frequent rows) plus cold rows
    hot_set = set(np.asarray(cache.hot_ids).tolist())
    cold = [r for r in range(spec.null_row) if r not in hot_set][:2]
    touched = jnp.concatenate([cache.hot_ids[:2],
                               jnp.asarray(cold + [spec.null_row],
                                           jnp.int32)])
    arena2 = arena.at[touched[:-1]].add(1.5)
    stale = es.lookup_bags(es.CachedSource(cache, es.FpArena(arena2)),
                           spec, idx, off, max_l=3)
    patched = _patch_hot_rows(cache, arena2, spec.null_row, touched)
    got = es.lookup_bags(es.CachedSource(patched, es.FpArena(arena2)),
                         spec, idx, off, max_l=3)
    want = es.lookup_bags(es.FpArena(arena2), spec, idx, off, max_l=3)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    # the un-patched cache must actually have been wrong (test has teeth)
    assert not np.allclose(np.asarray(stale), np.asarray(want))
    # the null slot survives patching as all-zeros
    assert float(jnp.abs(patched.hot_rows[-1]).max()) == 0.0


# ---------------------------------------------------------------------------
# online trainer e2e: loss falls; live cache >= offline cache under drift
# ---------------------------------------------------------------------------

def test_online_trainer_loss_goes_down_with_cache_refresh():
    cfg = DLRM_SMOKE
    params = dlrm.init(jax.random.PRNGKey(0), cfg)
    max_l = 6
    trainer = OnlineTrainer(cfg, params, max_l=max_l, lr=1e-2,
                            cache_cfg=OnlineCacheConfig(k=64,
                                                        refresh_every=8,
                                                        decay=0.9))
    gen = make_drifting_zipf(cfg, batch_size=16, mean_l=3, max_l=max_l,
                             drift_per_batch=2, alpha=1.2, seed=0)
    for _ in range(40):
        trainer.train_step(next(gen))
    assert trainer.version >= 4                       # rebuilds happened
    assert np.mean(trainer.losses[-8:]) < np.mean(trainer.losses[:8])

    # serving stays exact against the live (trained + patched) state
    b = next(gen)
    trainer.train_step(b)
    idx, off = jnp.asarray(b["indices"]), jnp.asarray(b["offsets"])
    got = es.lookup_bags(
        es.CachedSource(trainer.cache,
                        es.FpArena(trainer.params["arena"])),
        trainer.spec, idx, off, max_l=max_l)
    want = es.lookup_bags(es.FpArena(trainer.params["arena"]),
                          trainer.spec, idx, off, max_l=max_l)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-5)


def test_online_cache_hit_rate_beats_offline_on_drift():
    cfg = DLRM_SMOKE
    params = dlrm.init(jax.random.PRNGKey(1), cfg)
    max_l = 6
    # drift 1 row/batch with refresh every 3: staleness stays inside the
    # pinned neighborhood, so the live cache tracks the moving head while
    # the frozen offline cache falls ~50 rows behind by the end
    trainer = OnlineTrainer(cfg, params, max_l=max_l, lr=1e-3,
                            cache_cfg=OnlineCacheConfig(k=48,
                                                        refresh_every=3,
                                                        decay=0.8))
    gen = make_drifting_zipf(cfg, batch_size=16, mean_l=4, max_l=max_l,
                             drift_per_batch=1, alpha=1.3, seed=5)
    offline = None
    for _ in range(50):
        trainer.train_step(next(gen))
        if offline is None and trainer.cache is not None:
            offline = trainer.cache               # frozen first build
    live_hr, off_hr = [], []
    for _ in range(5):
        b = next(gen)
        idx, off = jnp.asarray(b["indices"]), jnp.asarray(b["offsets"])
        live_hr.append(float(se.cache_hit_rate(trainer.cache, trainer.spec,
                                               idx, off)))
        off_hr.append(float(se.cache_hit_rate(offline, trainer.spec, idx,
                                              off)))
    assert np.mean(live_hr) >= np.mean(off_hr), (live_hr, off_hr)
    assert np.mean(live_hr) > 0.1                 # and it actually caches


def test_dense_grad_baseline_step(rng):
    """The sparse=False path trains too and reports touched rows."""
    cfg = DLRM_SMOKE
    params = dlrm.init(jax.random.PRNGKey(0), cfg)
    max_l = 5
    gen = make_drifting_zipf(cfg, batch_size=32, mean_l=3, max_l=max_l,
                             seed=2)
    opt, step = dlrm.make_train_step_ragged(cfg, max_l=max_l, lr=1e-2,
                                            sparse=False)
    state = opt.init(params)
    step = jax.jit(step)
    losses = []
    for _ in range(15):
        b = next(gen)
        batch = {k: jnp.asarray(b[k])
                 for k in ("dense", "indices", "offsets", "labels")}
        params, state, loss, rows = step(params, state, batch)
        losses.append(float(loss))
    assert np.mean(losses[-5:]) < np.mean(losses[:5])
    assert rows.shape == batch["indices"].shape


def test_sync_engine_publishes_every_step():
    """Between rebuilds, every train step publishes the (params, patched
    cache) pair — the serving engine never lags more than one step."""
    from repro.serving.rec_engine import RecEngine

    cfg = DLRM_SMOKE
    params = dlrm.init(jax.random.PRNGKey(2), cfg)
    max_l = 5
    trainer = OnlineTrainer(cfg, params, max_l=max_l, lr=1e-2,
                            cache_cfg=OnlineCacheConfig(k=32,
                                                        refresh_every=4))
    gen = make_drifting_zipf(cfg, batch_size=8, mean_l=3, max_l=max_l,
                             seed=7)
    engine = RecEngine(cfg, params, source="cached", max_l=max_l,
                       max_batch=8, cache_k=32,
                       cache_trace=np.ones(trainer.spec.total_rows))
    assert not trainer.sync_engine(engine)        # nothing built yet
    synced = 0
    for step in range(8):
        trainer.train_step(next(gen))
        if trainer.sync_engine(engine):
            synced += 1
            assert engine.params is trainer.params
            assert engine.cache is trainer.cache
        assert not trainer.sync_engine(engine)    # idempotent per step
    # first rebuild at step 4 -> steps 4..8 all publish (5 total)
    assert synced == 5
    assert engine.cache_version == trainer.version
