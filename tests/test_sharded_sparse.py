"""Sharded sparse subsystem, end to end — the multi-device lockdown suite.

Three layers, matching how the subsystem composes:

* **shard-local math, in-process** (hypothesis property tests): the
  ownership protocol every sharded path shares is exercised by vmapping
  over the shard axis with a named axis — `axis_index` / `psum` behave
  exactly as under shard_map, so the masked-gather + segment-reduce +
  psum composition and the shard-local optimizer projection run on a
  1-device CPU. Edges forced into every random case: vocab sizes that do
  NOT divide the shard count (padded-rows edge), empty bags, duplicate
  indices, and all-null-index bags.
* **shard_map on a real mesh** (subprocess with 8 fake host devices, the
  test_distributed.py pattern): `lookup_bags` over
  `CachedSource(..., ShardedArena(...))` compositions,
  `RecEngine(source='sharded'|'cached', mesh=...)`, and
  `make_train_step_ragged(sharded=True)` — the exact production entry
  points.
* **exactness acceptance**: sharded-cold cached == replicated cached ==
  plain `lookup_ragged`; 3 sharded optimizer steps == 3 dense-grad steps
  within 1e-4.

The same file is what CI's simulated-multi-device job runs under
``XLA_FLAGS=--xla_force_host_platform_device_count=8``.
"""
import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import embedding_source as es
from repro.core import sparse_engine as se
from repro.training import sparse_optim as so

SRC = str(Path(__file__).resolve().parents[1] / "src")

SHARD_COUNTS = (1, 2, 4, 8)
# rows_per_table choices whose total_rows (3*r + 1) never divide 8 — the
# padded trailing rows are therefore always in play at shards > 1
UNEVEN_ROWS = (29, 30, 37)


def _ragged_case(rng, spec, b, max_l, pad=0):
    """Random ragged batch with every hard edge forced in: an empty bag, a
    full-length bag, a duplicated index, an all-null-index bag, and (via
    `pad`) a padded tail."""
    n_bags = b * spec.n_tables
    lens = rng.randint(0, max_l + 1, n_bags).astype(np.int32)
    lens[0] = 0                      # empty bag
    lens[-1] = max_l                 # full bag
    lens[1] = max(lens[1], 1)        # the all-null bag must have positions
    off = np.zeros(n_bags + 1, np.int32)
    np.cumsum(lens, out=off[1:])
    n = int(off[-1])
    idx = rng.randint(0, spec.rows_per_table, n + pad).astype(np.int32)
    if n >= 2:
        idx[off[-2]] = idx[0] if lens[0] else idx[n - 1]   # duplicate
    # bag 1 belongs to table 1 % n_tables: per-table ids that flatten to
    # the always-zero null arena row (the pipeline dummy-stream shape)
    t1 = 1 % spec.n_tables
    idx[off[1]:off[2]] = spec.null_row - t1 * spec.rows_per_table
    return jnp.asarray(idx), jnp.asarray(off)


def _shard_view(x, shards):
    assert x.shape[0] % shards == 0, (x.shape, shards)
    return x.reshape(shards, -1, *x.shape[1:])


# ---------------------------------------------------------------------------
# property: sharded-cold cached == replicated cached == plain lookup_ragged
# ---------------------------------------------------------------------------

@settings(deadline=None, max_examples=12)
@given(st.sampled_from(SHARD_COUNTS), st.sampled_from(UNEVEN_ROWS),
       st.integers(0, 2**31 - 1))
def test_sharded_cold_cached_matches_replicated_and_plain(shards, rpt,
                                                          seed):
    rng = np.random.RandomState(seed % (2**32 - 1))
    spec = se.ArenaSpec(3, rpt, 8)
    arena = se.init_arena(jax.random.PRNGKey(seed % 997), spec, shards)
    max_l = 5
    idx, off = _ragged_case(rng, spec, b=3, max_l=max_l, pad=4)
    counts = se.trace_row_counts(spec, idx, off)
    cache = se.build_hot_cache(arena, spec, counts, k=8)

    plain = np.asarray(es.lookup_bags(es.FpArena(arena), spec, idx, off,
                                      max_l=max_l))
    repl = np.asarray(es.lookup_bags(
        es.CachedSource(cache, es.FpArena(arena)), spec, idx, off,
        max_l=max_l))
    np.testing.assert_allclose(repl, plain, rtol=1e-5, atol=1e-6)

    # the exact shard-local composition shard_map runs: replicated hot
    # pass + per-shard masked cold reduce, psum-combined
    hot, cold_idx, n_bags = se.cache_split(cache, spec, idx, off, max_l)
    colds = jax.vmap(
        lambda a: se.ragged_partial_reduce(a, cold_idx, off, "x"),
        axis_name="x")(_shard_view(arena, shards))
    for s in range(shards):
        got = np.asarray((hot + colds[s]).reshape(
            n_bags // spec.n_tables, spec.n_tables,
            spec.dim).astype(arena.dtype))
        np.testing.assert_allclose(got, plain, rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(got, repl, rtol=1e-5, atol=1e-6)


@settings(deadline=None, max_examples=8)
@given(st.sampled_from(SHARD_COUNTS), st.integers(0, 2**31 - 1))
def test_sharded_cold_cached_q_matches_replicated(shards, seed):
    """int8 cold arena: the sharded dequantize-reduce equals the
    replicated one (bitwise-same math, different partition)."""
    rng = np.random.RandomState(seed % (2**32 - 1))
    spec = se.ArenaSpec(3, 30, 8)
    arena = se.init_arena(jax.random.PRNGKey(seed % 997), spec, shards,
                          scale=1.0)
    q, scales = se.quantize_arena(arena)
    max_l = 4
    idx, off = _ragged_case(rng, spec, b=2, max_l=max_l, pad=3)
    counts = se.trace_row_counts(spec, idx, off)
    cache = se.build_hot_cache(arena, spec, counts, k=8)

    repl = np.asarray(es.lookup_bags(
        es.CachedSource(cache, es.QuantizedArena(q, scales)), spec, idx,
        off, max_l=max_l))
    hot, cold_idx, n_bags = se.cache_split(cache, spec, idx, off, max_l)
    colds = jax.vmap(
        lambda qq, ss: se.ragged_partial_reduce_q(qq, ss, cold_idx, off,
                                                  "x"),
        axis_name="x")(_shard_view(q, shards), _shard_view(scales, shards))
    for s in range(shards):
        got = np.asarray((hot + colds[s]).reshape(
            n_bags // spec.n_tables, spec.n_tables, spec.dim))
        np.testing.assert_allclose(got, repl, rtol=1e-5, atol=1e-6)


@settings(deadline=None, max_examples=8)
@given(st.sampled_from(SHARD_COUNTS), st.sampled_from(UNEVEN_ROWS),
       st.integers(0, 2**31 - 1))
def test_lookup_ragged_sharded_uneven_vocab(shards, rpt, seed):
    """The uncached sharded path over non-dividing vocab sizes — the
    padded zero rows at the arena tail must stay inert at every shard
    count."""
    rng = np.random.RandomState(seed % (2**32 - 1))
    spec = se.ArenaSpec(3, rpt, 8)
    arena = se.init_arena(jax.random.PRNGKey(seed % 997), spec, shards)
    idx, off = _ragged_case(rng, spec, b=2, max_l=4, pad=2)
    want = np.asarray(es.lookup_bags(es.FpArena(arena), spec, idx, off,
                                     max_l=4))
    flat = se.flatten_ragged_indices(spec, idx, off)
    n_bags = off.shape[0] - 1
    outs = jax.vmap(
        lambda a: es.FpArena(a).shard_reduce_flat(spec, flat, off, "x")
        .reshape(n_bags // spec.n_tables, spec.n_tables, spec.dim)
        .astype(arena.dtype), axis_name="x")(_shard_view(arena, shards))
    for s in range(shards):
        np.testing.assert_allclose(np.asarray(outs[s]), want, rtol=1e-5,
                                   atol=1e-6)


# ---------------------------------------------------------------------------
# property: shard-local row updates == replicated sparse optimizer
# ---------------------------------------------------------------------------

@settings(deadline=None, max_examples=10)
@given(st.sampled_from(SHARD_COUNTS), st.integers(1, 3),
       st.integers(0, 2**31 - 1))
def test_shard_local_rowwise_update_matches_replicated(shards, steps,
                                                       seed):
    """Applying each shard's owned slice of (rows, row_grads) — null row
    excluded, foreign rows projected to a zero-grad no-op — reassembles
    to exactly the replicated sparse_rowwise_adagrad result, arena and
    accumulator both, across multiple accumulating steps."""
    rng = np.random.RandomState(seed % (2**32 - 1))
    spec = se.ArenaSpec(2, 13, 4)        # 27 rows: pads at 2/4/8 shards
    arena = se.init_arena(jax.random.PRNGKey(seed % 997), spec, shards)
    opt = so.sparse_rowwise_adagrad(0.1)
    vlocal = arena.shape[0] // shards

    repl = arena
    repl_state = opt.init(arena)
    shard_arenas = [arena[s * vlocal:(s + 1) * vlocal]
                    for s in range(shards)]
    shard_states = [{"acc": repl_state["acc"][s * vlocal:(s + 1) * vlocal],
                     "step": repl_state["step"]} for s in range(shards)]

    for _ in range(steps):
        idx, off = _ragged_case(rng, spec, b=2, max_l=4, pad=2)
        flat = se.flatten_ragged_indices(spec, idx, off)
        d_bags = jnp.asarray(rng.randn(off.shape[0] - 1, spec.dim),
                             jnp.float32)
        rows, row_g = so.ragged_row_grads(d_bags, flat, off,
                                          fill_row=spec.null_row)
        repl, repl_state = opt.update(repl, repl_state, rows, row_g)
        for s in range(shards):
            lrows, lg = so.shard_local_rows(rows, row_g, lo=s * vlocal,
                                            vlocal=vlocal,
                                            null_row=spec.null_row)
            shard_arenas[s], shard_states[s] = opt.update(
                shard_arenas[s], shard_states[s], lrows, lg)

    got = np.concatenate([np.asarray(a) for a in shard_arenas])
    np.testing.assert_allclose(got, np.asarray(repl), rtol=1e-6,
                               atol=1e-7)
    got_acc = np.concatenate([np.asarray(s["acc"]) for s in shard_states])
    np.testing.assert_allclose(got_acc, np.asarray(repl_state["acc"]),
                               rtol=1e-6, atol=1e-7)
    # the null row's always-zero invariant survives sharded training
    null_shard, null_rel = divmod(spec.null_row, vlocal)
    assert float(np.abs(np.asarray(
        shard_arenas[null_shard])[null_rel]).max()) == 0.0


def test_shard_local_rows_projection():
    """Unit anchor for the projection: ownership window, null exclusion,
    zero-grad redirect."""
    rows = jnp.asarray([3, 7, 10, 12, 26], jnp.int32)    # 26 = null row
    g = jnp.ones((5, 2), jnp.float32)
    lrows, lg = so.shard_local_rows(rows, g, lo=7, vlocal=7, null_row=26)
    np.testing.assert_array_equal(np.asarray(lrows), [0, 0, 3, 5, 0])
    np.testing.assert_array_equal(np.asarray(lg[:, 0]), [0, 1, 1, 1, 0])
    # shard that owns the null row: still excluded
    lrows, lg = so.shard_local_rows(rows, g, lo=21, vlocal=7, null_row=26)
    np.testing.assert_array_equal(np.asarray(lrows), [0, 0, 0, 0, 0])
    assert float(jnp.abs(lg).max()) == 0.0


# ---------------------------------------------------------------------------
# shard_map on a real mesh (subprocess, 8 fake host devices)
# ---------------------------------------------------------------------------

def run_with_devices(code: str, n: int = 8, timeout: int = 480) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
    env["PYTHONPATH"] = SRC
    env.setdefault("JAX_PLATFORMS", "cpu")
    prelude = textwrap.dedent("""
        import json, jax, jax.numpy as jnp, numpy as np
        from repro.configs.dlrm import DLRM_SMOKE
        from repro.core import dlrm
        from repro.core import embedding_source as es
        from repro.core import sparse_engine as se
        from repro.launch.mesh import make_mesh
    """)
    out = subprocess.run([sys.executable, "-c", prelude + code],
                         capture_output=True, text=True, env=env,
                         timeout=timeout)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_cached_lookup_sharded_cold_shard_map():
    """lookup_ragged_cached(mesh=...) on 2/4/8-way meshes == replicated
    cached == plain, through the real shard_map entry point."""
    r = run_with_devices("""
from repro.data import DLRMSynthetic
cfg = DLRM_SMOKE
spec = dlrm.arena_spec(cfg)
errs = {}
for shards in (2, 4, 8):
    mesh = make_mesh((shards,), ("model",))
    arena = se.init_arena(jax.random.PRNGKey(0), spec, shards)
    data = DLRMSynthetic(cfg, seed=5)
    rb = data.ragged_batch(8, mean_l=3, max_l=6)
    idx, off = jnp.asarray(rb["indices"]), jnp.asarray(rb["offsets"])
    counts = se.trace_row_counts(spec, rb["indices"], rb["offsets"])
    cache = se.build_hot_cache(arena, spec, counts, k=64)
    fp = es.FpArena(arena)
    qa = es.QuantizedArena.from_arena(arena)
    plain = es.lookup_bags(fp, spec, idx, off, max_l=6)
    repl = es.lookup_bags(es.CachedSource(cache, fp), spec, idx, off,
                          max_l=6)
    shrd = es.lookup_bags(
        es.CachedSource(cache, es.ShardedArena(fp, mesh)), spec, idx,
        off, max_l=6)
    q_repl = es.lookup_bags(es.CachedSource(cache, qa), spec, idx, off,
                            max_l=6)
    q_shrd = es.lookup_bags(
        es.CachedSource(cache, es.ShardedArena(qa, mesh)), spec, idx,
        off, max_l=6)
    errs[shards] = [float(jnp.abs(shrd - plain).max()),
                    float(jnp.abs(shrd - repl).max()),
                    float(jnp.abs(q_shrd - q_repl).max())]
print(json.dumps({"errs": {str(k): v for k, v in errs.items()}}))
""")
    for shards, (vs_plain, vs_repl, vs_q) in r["errs"].items():
        assert vs_plain < 1e-5, (shards, vs_plain)
        assert vs_repl < 1e-5, (shards, vs_repl)
        assert vs_q < 1e-5, (shards, vs_q)


def test_rec_engine_sharded_paths_shard_map():
    """RecEngine path='sharded' and path='cached'+mesh on an 8-way mesh
    serve the same CTRs as the 1-device ragged engine."""
    r = run_with_devices("""
from repro.data import DLRMSynthetic
from repro.serving import RecEngine, requests_from_ragged_batch
cfg = DLRM_SMOKE
spec = dlrm.arena_spec(cfg)
mesh = make_mesh((8,), ("model",))
params = dlrm.init(jax.random.PRNGKey(0), cfg, 8)
data = DLRMSynthetic(cfg, seed=13)
rb = data.ragged_batch(6, mean_l=3, max_l=6)
counts = se.trace_row_counts(spec, rb["indices"], rb["offsets"])
probs = {}
for name, kw in (
    ("ragged", dict(source="ragged")),
    ("sharded", dict(source="sharded", mesh=mesh)),
    ("cached_sharded", dict(source="cached", mesh=mesh, cache_k=32,
                            cache_trace=counts)),
):
    eng = RecEngine(cfg, params, max_l=6, max_batch=8, max_wait_ms=0.0,
                    **kw)
    reqs = requests_from_ragged_batch(rb, cfg.n_tables)
    for req in reqs:
        eng.submit(req)
    eng.step(force=True)
    eng.drain()
    probs[name] = [r.prob for r in reqs]
base = np.asarray(probs["ragged"])
print(json.dumps({
    "sharded_err": float(np.abs(np.asarray(probs["sharded"]) - base).max()),
    "cached_err": float(np.abs(np.asarray(probs["cached_sharded"])
                               - base).max())}))
""")
    assert r["sharded_err"] < 1e-5
    assert r["cached_err"] < 1e-5


def test_sharded_training_matches_dense_grad_3_steps():
    """make_train_step_ragged(sharded=True) on 2- and 8-way meshes tracks
    the dense-gradient reference within 1e-4 after 3 optimizer steps —
    the acceptance sweep (sharded sparse == replicated sparse == dense)."""
    r = run_with_devices("""
from repro.data import DLRMSynthetic
cfg = DLRM_SMOKE
max_l = 6
errs = {}
for shards in (2, 8):
    mesh = make_mesh((shards,), ("model",))
    key = jax.random.PRNGKey(1)
    p_dense = dlrm.init(key, cfg, shards)
    p_shard = dlrm.init(key, cfg, shards)
    opt_d, step_d = dlrm.make_train_step_ragged(cfg, max_l=max_l,
                                                sparse=False)
    opt_s, step_s = dlrm.make_train_step_ragged(cfg, max_l=max_l,
                                                mesh=mesh, sharded=True)
    st_d, st_s = opt_d.init(p_dense), opt_s.init(p_shard)
    sd, ss = jax.jit(step_d), jax.jit(step_s)
    data = DLRMSynthetic(cfg, seed=3)
    losses = []
    for _ in range(3):
        b = data.ragged_batch(8, mean_l=3, max_l=max_l,
                              pad_to=8 * cfg.n_tables * max_l)
        bd = {k: jnp.asarray(b[k])
              for k in ("dense", "indices", "offsets", "labels")}
        p_dense, st_d, l_d, rows_d = sd(p_dense, st_d, bd)
        p_shard, st_s, l_s, rows_s = ss(p_shard, st_s, bd)
        losses.append([float(l_d), float(l_s)])
        assert (np.asarray(rows_d) == np.asarray(rows_s)).all()
    err = max(float(jnp.abs(a - b).max()) for a, b in
              zip(jax.tree_util.tree_leaves(p_dense),
                  jax.tree_util.tree_leaves(p_shard)))
    errs[shards] = {"param_err": err, "losses": losses}
print(json.dumps({str(k): v for k, v in errs.items()}))
""")
    for shards, res in r.items():
        assert res["param_err"] < 1e-4, (shards, res)
        for l_d, l_s in res["losses"]:
            assert abs(l_d - l_s) < 1e-4, (shards, res["losses"])


def test_sharded_training_feeds_live_cache_shard_map():
    """OnlineTrainer on a 4-way mesh: the sharded sparse step trains, the
    write-through patch keeps the cached serving path exact against the
    uncached lookup over the sharded-trained arena."""
    r = run_with_devices("""
from repro.data import DLRMSynthetic
from repro.training import OnlineCacheConfig, OnlineTrainer
cfg = DLRM_SMOKE
spec = dlrm.arena_spec(cfg)
mesh = make_mesh((4,), ("model",))
max_l = 6
params = dlrm.init(jax.random.PRNGKey(0), cfg, 4)
trainer = OnlineTrainer(cfg, params, max_l=max_l, mesh=mesh,
                        cache_cfg=OnlineCacheConfig(k=64, refresh_every=4))
data = DLRMSynthetic(cfg, seed=17)
for _ in range(6):
    b = data.ragged_batch(8, mean_l=3, max_l=max_l,
                          pad_to=8 * cfg.n_tables * max_l)
    trainer.train_step(b)
rb = data.ragged_batch(4, mean_l=3, max_l=max_l)
idx, off = jnp.asarray(rb["indices"]), jnp.asarray(rb["offsets"])
plain = es.lookup_bags(es.FpArena(trainer.params["arena"]), spec, idx,
                       off, max_l=max_l)
cached = es.lookup_bags(
    es.CachedSource(trainer.cache, es.ShardedArena(
        es.FpArena(trainer.params["arena"]), mesh)), spec, idx, off,
    max_l=max_l)
# a sharded trainer publishes a SHARDED-structured artifact: a sharded
# replica adopts it (mesh rebind, same treedef -> no recompile), and a
# replicated consumer deserializes without a mesh and gets the unwrapped
# inner source
from repro.serving import RecEngine
src = trainer.serving_source()
sharded_structure = int(isinstance(src.cold, es.ShardedArena))
blob = trainer.publish_source()
eng = RecEngine(cfg, trainer.params, source="cached", mesh=mesh,
                cache_k=64, cache_trace=trainer.hist, max_l=max_l,
                max_batch=4)
art = es.VersionedSource.deserialize(blob, mesh=mesh)
adopted = int(art.apply(eng))
repl = es.VersionedSource.deserialize(blob)          # no mesh: unwraps
repl_ok = int(isinstance(repl.source.cold, es.FpArena))
print(json.dumps({"err": float(jnp.abs(cached - plain).max()),
                  "version": trainer.version,
                  "loss0": trainer.losses[0],
                  "lossN": trainer.losses[-1],
                  "sharded_structure": sharded_structure,
                  "adopted": adopted, "repl_ok": repl_ok}))
""")
    assert r["err"] < 1e-5
    assert r["version"] >= 1
    assert r["sharded_structure"] == 1
    assert r["adopted"] == 1
    assert r["repl_ok"] == 1
