"""Ragged DLRM forward, pipelined ragged execution, and the rec serving
engine end-to-end (submit -> batch -> predict -> latency stats)."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs.dlrm import DLRM_SMOKE
from repro.core import dlrm, hybrid
from repro.core import embedding_source as es
from repro.core import sparse_engine as se
from repro.data import DLRMSynthetic
from repro.serving import (RecBatcher, RecEngine, RecRequest,
                           requests_from_ragged_batch)


@pytest.fixture
def setup():
    cfg = DLRM_SMOKE
    params = dlrm.init(jax.random.PRNGKey(0), cfg)
    data = DLRMSynthetic(cfg, seed=9)
    return cfg, params, data


# ---------------------------------------------------------------------------
# ragged DLRM forward
# ---------------------------------------------------------------------------

def test_ragged_forward_matches_fixed_on_equal_lengths(setup):
    cfg, params, data = setup
    rb = data.ragged_batch(8, dist="fixed")
    fx = jnp.asarray(DLRMSynthetic.ragged_to_fixed(rb, cfg.n_tables))
    f_fixed = dlrm.forward(params, cfg, jnp.asarray(rb["dense"]), fx)
    f_ragged = dlrm.forward_ragged(
        params, cfg, jnp.asarray(rb["dense"]), jnp.asarray(rb["indices"]),
        jnp.asarray(rb["offsets"]), max_l=int(rb["max_l"]))
    np.testing.assert_allclose(np.asarray(f_fixed), np.asarray(f_ragged),
                               rtol=1e-4, atol=1e-4)


@settings(deadline=None, max_examples=6)
@given(st.sampled_from(["uniform", "poisson"]), st.integers(0, 2**31 - 1))
def test_pipelined_ragged_matches_single_shot(dist, seed):
    """Property: the ragged microbatch pipeline (per-microbatch offsets)
    computes the same logits as single-shot forward_ragged."""
    cfg = DLRM_SMOKE
    params = dlrm.init(jax.random.PRNGKey(seed % 1000), cfg)
    data = DLRMSynthetic(cfg, seed=seed % (2**32 - 1))
    b, max_l = 8, 6
    rb = data.ragged_batch(b, dist=dist, mean_l=3, max_l=max_l,
                           pad_to=b * cfg.n_tables * max_l)
    args = (jnp.asarray(rb["dense"]), jnp.asarray(rb["indices"]),
            jnp.asarray(rb["offsets"]))
    f = dlrm.forward_ragged(params, cfg, *args, max_l=max_l)
    for n_micro in (1, 2, 4):
        p = hybrid.pipelined_forward_ragged(params, cfg, *args,
                                            max_l=max_l, n_micro=n_micro)
        np.testing.assert_allclose(np.asarray(f), np.asarray(p),
                                   rtol=1e-3, atol=1e-3)


def test_cached_forward_matches_uncached(setup):
    cfg, params, data = setup
    rb = data.ragged_batch(8, dist="poisson", mean_l=3, max_l=6)
    spec = dlrm.arena_spec(cfg)
    counts = se.trace_row_counts(spec, rb["indices"], rb["offsets"])
    cache = se.build_hot_cache(params["arena"], spec, counts, k=32)
    args = (jnp.asarray(rb["dense"]), jnp.asarray(rb["indices"]),
            jnp.asarray(rb["offsets"]))
    f = dlrm.forward_ragged(params, cfg, *args, max_l=6)
    c = dlrm.forward_ragged(
        params, cfg, *args, max_l=6,
        source=es.CachedSource(cache, es.FpArena(params["arena"])))
    np.testing.assert_allclose(np.asarray(f), np.asarray(c), rtol=1e-4,
                               atol=1e-4)


# ---------------------------------------------------------------------------
# batcher
# ---------------------------------------------------------------------------

def _req(rid, cfg, data, n_ids=3):
    rb = data.ragged_batch(1, dist="uniform", mean_l=n_ids, max_l=n_ids)
    return requests_from_ragged_batch(rb, cfg.n_tables, rid0=rid)[0]


def test_batcher_releases_on_full_batch(setup):
    cfg, _, data = setup
    b = RecBatcher(max_batch=4, max_wait_ms=1e9)
    for i in range(3):
        b.submit(_req(i, cfg, data))
    assert b.take() == []                    # not full, not old
    b.submit(_req(3, cfg, data))
    out = b.take()
    assert [r.rid for r in out] == [0, 1, 2, 3]
    assert len(b) == 0


def test_batcher_releases_on_timeout(setup):
    cfg, _, data = setup
    b = RecBatcher(max_batch=64, max_wait_ms=5.0)
    b.submit(_req(0, cfg, data))
    assert b.take() == []
    time.sleep(0.01)
    assert len(b.take()) == 1


# ---------------------------------------------------------------------------
# engine end-to-end
# ---------------------------------------------------------------------------

def _run_requests(engine, reqs):
    for r in reqs:
        engine.submit(r)
        engine.step()
    engine.drain()


def test_rec_engine_end_to_end_ragged(setup):
    cfg, params, data = setup
    engine = RecEngine(cfg, params, source="ragged", max_l=6,
                       max_batch=8, max_wait_ms=0.0, buckets=(2, 4, 8))
    rb = data.ragged_batch(13, dist="poisson", mean_l=3, max_l=6)
    reqs = requests_from_ragged_batch(rb, cfg.n_tables)
    _run_requests(engine, reqs)
    assert engine.served == 13
    for r in reqs:
        assert r.prob is not None and 0.0 < r.prob < 1.0
        assert r.finished_at >= r.submitted_at
    s = engine.stats()
    assert s["n"] == 13
    assert 0 < s["p50_ms"] <= s["p95_ms"] <= s["p99_ms"]


def test_rec_engine_paths_agree(setup):
    """fixed, ragged and cached engines produce identical predictions for
    the same fixed-length request stream."""
    cfg, params, data = setup
    l = cfg.lookups_per_table
    rb = data.ragged_batch(6, dist="fixed")
    spec = dlrm.arena_spec(cfg)
    counts = se.trace_row_counts(spec, rb["indices"], rb["offsets"])

    probs = {}
    for path in ("fixed", "ragged", "cached"):   # 'sharded' needs a mesh —
        # covered in test_sharded_sparse.py under fake devices
        engine = RecEngine(cfg, params, source=path, max_l=l, max_batch=8,
                           max_wait_ms=0.0,
                           cache_k=16 if path == "cached" else 0,
                           cache_trace=counts)
        reqs = requests_from_ragged_batch(rb, cfg.n_tables)
        _run_requests(engine, reqs)
        probs[path] = [r.prob for r in reqs]
        if path == "cached":
            assert engine.stats()["cache_hit_rate"] > 0
    np.testing.assert_allclose(probs["fixed"], probs["ragged"], rtol=1e-4,
                               atol=1e-5)
    np.testing.assert_allclose(probs["ragged"], probs["cached"], rtol=1e-4,
                               atol=1e-5)


def test_rec_engine_bucket_padding_is_inert(setup):
    """A lone request must predict the same CTR whatever bucket it pads
    to (dummy rows with empty bags cannot perturb real rows)."""
    cfg, params, data = setup
    rb = data.ragged_batch(1, dist="poisson", mean_l=3, max_l=6)
    got = []
    for buckets in ((1,), (4,), (16,)):
        engine = RecEngine(cfg, params, source="ragged", max_l=6,
                           max_batch=max(buckets), max_wait_ms=0.0,
                           buckets=buckets)
        reqs = requests_from_ragged_batch(rb, cfg.n_tables)
        _run_requests(engine, reqs)
        got.append(reqs[0].prob)
    np.testing.assert_allclose(got[0], got[1], rtol=1e-5)
    np.testing.assert_allclose(got[0], got[2], rtol=1e-5)


def test_rec_engine_quantized_cold_close(setup):
    cfg, params, data = setup
    rb = data.ragged_batch(6, dist="poisson", mean_l=3, max_l=6)
    spec = dlrm.arena_spec(cfg)
    counts = se.trace_row_counts(spec, rb["indices"], rb["offsets"])
    ref_engine = RecEngine(cfg, params, source="ragged", max_l=6,
                           max_batch=8, max_wait_ms=0.0)
    q_engine = RecEngine(cfg, params, source="cached", max_l=6, max_batch=8,
                         max_wait_ms=0.0, cache_k=32, cache_trace=counts,
                         quantize_cold=True)
    reqs_a = requests_from_ragged_batch(rb, cfg.n_tables)
    reqs_b = requests_from_ragged_batch(rb, cfg.n_tables)
    _run_requests(ref_engine, reqs_a)
    _run_requests(q_engine, reqs_b)
    a = np.asarray([r.prob for r in reqs_a])
    b = np.asarray([r.prob for r in reqs_b])
    assert np.abs(a - b).max() < 0.05       # int8 tail, fp hot rows


# ---------------------------------------------------------------------------
# dynamic bucket tuning + live cache swap (online-training integration)
# ---------------------------------------------------------------------------

def test_tune_buckets_from_histogram():
    from repro.serving.rec_engine import tune_buckets
    # skewed traffic: nearly everything arrives in micro-batches of 3 or 7
    sizes = [3] * 40 + [7] * 40 + [12] * 3
    buckets = tune_buckets(sizes, max_batch=32, n_buckets=4)
    assert 3 in buckets and 7 in buckets       # observed modes become exact
    assert buckets[-1] == 32                   # catch-all always present
    assert buckets == tuple(sorted(buckets))
    # no observations -> sane default
    assert tune_buckets([], max_batch=16) == (1, 16)


def test_tune_buckets_degenerate_inputs():
    from repro.serving.rec_engine import tune_buckets
    # empty histogram: the sane default, whatever n_buckets asks for
    assert tune_buckets([], max_batch=8, n_buckets=1) == (1, 8)
    # a single observed size collapses to {size, catch-all} — that size
    # then pads to itself (zero waste), everything else to max_batch
    assert tune_buckets([5] * 100, max_batch=32) == (5, 32)
    # single observed size == max_batch: one bucket, no duplicates
    assert tune_buckets([16] * 10, max_batch=16) == (16,)
    # observations above max_batch (replayed traces from a bigger engine)
    # clip: the batcher never releases more than max_batch, so a larger
    # bucket would be compiled but never hit
    buckets = tune_buckets([40] * 50 + [64] * 50, max_batch=32)
    assert buckets == (32,)
    assert max(tune_buckets([2, 40, 70], max_batch=32)) == 32


def test_rec_engine_retune_with_no_observations(setup):
    """retune_buckets before any traffic must not crash and must keep the
    engine serviceable (empty histogram -> default buckets)."""
    cfg, params, data = setup
    engine = RecEngine(cfg, params, source="ragged", max_l=6, max_batch=8,
                       max_wait_ms=0.0)
    buckets = engine.retune_buckets(warmup=False)
    assert buckets == (1, 8)
    reqs = requests_from_ragged_batch(
        data.ragged_batch(3, dist="poisson", mean_l=3, max_l=6),
        cfg.n_tables)
    _run_requests(engine, reqs)
    assert all(r.prob is not None for r in reqs)


def test_rec_engine_retune_preserves_predictions(setup):
    """Auto-retuned buckets change padding only — never predictions."""
    cfg, params, data = setup
    rb = data.ragged_batch(24, dist="poisson", mean_l=3, max_l=6)

    ref = RecEngine(cfg, params, source="ragged", max_l=6, max_batch=8,
                    max_wait_ms=0.0)
    tuned = RecEngine(cfg, params, source="ragged", max_l=6, max_batch=8,
                      max_wait_ms=0.0, auto_tune_after=4)
    probs = []
    for engine in (ref, tuned):
        reqs = requests_from_ragged_batch(rb, cfg.n_tables)
        # submit in bursts of 3 so every micro-batch has size 3
        for j in range(0, len(reqs), 3):
            for r in reqs[j:j + 3]:
                engine.submit(r)
            engine.step(force=True)
        assert all(r.prob is not None for r in reqs)
        probs.append(np.asarray([r.prob for r in reqs]))
    assert 3 in tuned.buckets                  # tuned to the burst size
    assert tuned.buckets != ref.buckets        # retune actually fired
    np.testing.assert_allclose(probs[0], probs[1], rtol=1e-5, atol=1e-6)


def test_rec_engine_update_cache_swaps_without_staleness(setup):
    """Serving results track arena updates through a versioned cache swap
    — the online-training refresh protocol at the engine boundary."""
    cfg, params, data = setup
    spec = dlrm.arena_spec(cfg)
    rb = data.ragged_batch(6, dist="poisson", mean_l=3, max_l=6)
    counts = se.trace_row_counts(spec, rb["indices"], rb["offsets"])
    engine = RecEngine(cfg, params, source="cached", max_l=6, max_batch=8,
                       max_wait_ms=0.0, cache_k=16, cache_trace=counts)
    assert engine.cache_version == 0

    # "online training" rewrites the arena; rebuild + swap a new version
    new_params = dict(params)
    # perturb real rows only — the null row's always-zero invariant is
    # load-bearing for the cached path's hot/cold redirect
    new_params["arena"] = (params["arena"] + 0.25) \
        .at[spec.null_row:].set(0.0)
    new_cache = se.build_hot_cache(new_params["arena"], spec, counts, 16)
    engine.params = new_params
    engine.update_cache(new_cache, version=7)
    assert engine.cache_version == 7

    reqs = requests_from_ragged_batch(rb, cfg.n_tables)
    _run_requests(engine, reqs)
    got = np.asarray([r.prob for r in reqs])

    want = np.asarray(jax.nn.sigmoid(dlrm.forward_ragged(
        new_params, cfg, jnp.asarray(rb["dense"]),
        jnp.asarray(rb["indices"]), jnp.asarray(rb["offsets"]), max_l=6)))
    np.testing.assert_allclose(got, want[:len(got)], rtol=1e-4, atol=1e-5)


def test_rec_engine_rejects_stale_cache_version(setup):
    """Regression: a lower-version swap (reordered broadcast artifact)
    must be rejected, and the served cache must be left untouched."""
    cfg, params, data = setup
    spec = dlrm.arena_spec(cfg)
    rb = data.ragged_batch(4, dist="poisson", mean_l=3, max_l=6)
    counts = se.trace_row_counts(spec, rb["indices"], rb["offsets"])
    engine = RecEngine(cfg, params, source="cached", max_l=6, max_batch=8,
                       max_wait_ms=0.0, cache_k=16, cache_trace=counts)
    fresh = se.build_hot_cache(params["arena"], spec, counts, 16)
    engine.update_cache(fresh, version=5)
    served = engine.cache
    stale = se.build_hot_cache(jnp.zeros_like(params["arena"]), spec,
                               counts, 16)
    with pytest.raises(ValueError, match="stale"):
        engine.update_cache(stale, version=3)
    assert engine.cache is served and engine.cache_version == 5
    # equal version is allowed: between rebuilds the trainer republishes
    # the same version with write-through-patched hot values
    engine.update_cache(fresh, version=5)
    assert engine.cache_version == 5


# ---------------------------------------------------------------------------
# versioned hot-arena broadcast: trainer -> N replicas
# ---------------------------------------------------------------------------

def test_versioned_cache_broadcast_roundtrip_and_apply(setup):
    """serialize -> deserialize is lossless; apply() adopts strictly-newer
    artifacts only; two replicas fed the same blob serve identically."""
    from repro.training import VersionedHotCache
    cfg, params, data = setup
    spec = dlrm.arena_spec(cfg)
    rb = data.ragged_batch(6, dist="poisson", mean_l=3, max_l=6)
    counts = se.trace_row_counts(spec, rb["indices"], rb["offsets"])
    cache = se.build_hot_cache(params["arena"], spec, counts, 16)
    art = VersionedHotCache(cache=cache, version=3)

    blob = art.serialize()
    back = VersionedHotCache.deserialize(blob)
    assert back.version == 3
    np.testing.assert_array_equal(np.asarray(back.cache.hot_rows),
                                  np.asarray(cache.hot_rows))
    np.testing.assert_array_equal(np.asarray(back.cache.slot_of),
                                  np.asarray(cache.slot_of))
    np.testing.assert_array_equal(np.asarray(back.cache.hot_ids),
                                  np.asarray(cache.hot_ids))
    with pytest.raises(ValueError, match="artifact"):
        VersionedHotCache.deserialize(b"not an artifact")

    replicas = [RecEngine(cfg, params, source="cached", max_l=6, max_batch=8,
                          max_wait_ms=0.0, cache_k=16, cache_trace=counts)
                for _ in range(2)]
    for eng in replicas:
        assert back.apply(eng)                  # 3 > 0: adopted
        assert eng.cache_version == 3
        assert not back.apply(eng)              # idempotent re-delivery
        stale = VersionedHotCache(cache=cache, version=1)
        assert not stale.apply(eng)             # reordered: absorbed
        assert eng.cache_version == 3

    probs = []
    for eng in replicas:
        reqs = requests_from_ragged_batch(rb, cfg.n_tables)
        _run_requests(eng, reqs)
        probs.append(np.asarray([r.prob for r in reqs]))
    np.testing.assert_array_equal(probs[0], probs[1])
    want = np.asarray(jax.nn.sigmoid(dlrm.forward_ragged(
        params, cfg, jnp.asarray(rb["dense"]), jnp.asarray(rb["indices"]),
        jnp.asarray(rb["offsets"]), max_l=6)))
    np.testing.assert_allclose(probs[0], want[:len(probs[0])], rtol=1e-4,
                               atol=1e-5)
