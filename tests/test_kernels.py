"""Per-kernel validation: Pallas (interpret=True) vs pure-jnp oracles,
swept over shapes and dtypes, plus hypothesis property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.kernels import embedding_gather, feature_interaction, gemm, ops, ref

jax.config.update("jax_enable_x64", False)


def _close(a, b, tol=2e-2):
    a = np.asarray(a, np.float32)
    b = np.asarray(b, np.float32)
    np.testing.assert_allclose(a, b, rtol=tol, atol=tol)


# ---------------------------------------------------------------------------
# GEMM (dense engine)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,k,n", [(8, 16, 8), (128, 128, 128),
                                   (130, 70, 150), (256, 33, 64),
                                   (1, 512, 1)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gemm_matches_oracle(rng, m, k, n, dtype):
    x = jnp.asarray(rng.randn(m, k), dtype)
    w = jnp.asarray(rng.randn(k, n), dtype)
    got = gemm.gemm(x, w, interpret=True)
    want = ref.gemm(x, w)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    _close(got, want, tol)


@pytest.mark.parametrize("bm,bn,bk", [(32, 32, 32), (128, 128, 64)])
def test_gemm_block_shapes(rng, bm, bn, bk):
    x = jnp.asarray(rng.randn(96, 80), jnp.float32)
    w = jnp.asarray(rng.randn(80, 112), jnp.float32)
    got = gemm.gemm(x, w, bm=bm, bn=bn, bk=bk, interpret=True)
    _close(got, ref.gemm(x, w), 1e-5)


# ---------------------------------------------------------------------------
# Embedding gather-reduce (sparse engine)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("v,d,b,l", [(100, 32, 4, 1), (1000, 32, 16, 20),
                                     (512, 128, 8, 80), (64, 48, 3, 5)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_embedding_bag_matches_oracle(rng, v, d, b, l, dtype):
    table = jnp.asarray(rng.randn(v, d), dtype)
    idx = jnp.asarray(rng.randint(0, v, (b, l)), jnp.int32)
    got = embedding_gather.embedding_bag(table, idx, interpret=True)
    want = ref.embedding_bag(table, idx)
    _close(got, want, 1e-5 if dtype == jnp.float32 else 5e-2)


def test_embedding_bag_d_blocking(rng):
    table = jnp.asarray(rng.randn(256, 96), jnp.float32)
    idx = jnp.asarray(rng.randint(0, 256, (4, 7)), jnp.int32)
    got = embedding_gather.embedding_bag(table, idx, bd=32, interpret=True)
    _close(got, ref.embedding_bag(table, idx), 1e-5)


def test_gather_rows(rng):
    table = jnp.asarray(rng.randn(128, 16), jnp.float32)
    idx = jnp.asarray(rng.randint(0, 128, (9,)), jnp.int32)
    got = embedding_gather.gather_rows(table, idx, interpret=True)
    _close(got, table[idx], 1e-6)


@settings(deadline=None, max_examples=20)
@given(st.integers(2, 50), st.integers(1, 16), st.integers(1, 12),
       st.integers(0, 2**31 - 1))
def test_embedding_bag_property(v, b, l, seed):
    """Property: gather-reduce is linear in the table and permutation-
    invariant in the lookup order."""
    r = np.random.RandomState(seed % (2**32 - 1))
    table = jnp.asarray(r.randn(v, 8), jnp.float32)
    idx = r.randint(0, v, (b, l)).astype(np.int32)
    out1 = ops.embedding_bag(table, jnp.asarray(idx))
    # permutation invariance
    perm = np.stack([r.permutation(row) for row in idx.reshape(b, l)])
    out2 = ops.embedding_bag(table, jnp.asarray(perm))
    _close(out1, out2, 1e-4)
    # linearity: bag(2*table) == 2*bag(table)
    out3 = ops.embedding_bag(2.0 * table, jnp.asarray(idx))
    _close(out3, 2.0 * np.asarray(out1), 1e-4)


def test_sparse_lengths_sum_ragged(rng):
    """Paper Fig. 2 semantics with ragged offsets."""
    table = jnp.asarray(rng.randn(50, 8), jnp.float32)
    indices = jnp.asarray(rng.randint(0, 50, (10,)), jnp.int32)
    offsets = jnp.asarray([0, 3, 3, 7, 10], jnp.int32)
    out = ref.sparse_lengths_sum(table, indices, offsets)
    for b in range(4):
        lo, hi = int(offsets[b]), int(offsets[b + 1])
        want = np.asarray(table)[np.asarray(indices[lo:hi])].sum(0) \
            if hi > lo else np.zeros(8)
        _close(out[b], want, 1e-5)


def test_embedding_bag_grad_is_scatter_add(rng):
    table = jnp.asarray(rng.randn(64, 8), jnp.float32)
    idx = jnp.asarray(rng.randint(0, 64, (5, 3)), jnp.int32)
    g = jax.grad(lambda t: ops.embedding_bag(t, idx).sum())(table)
    counts = np.zeros(64)
    for i in np.asarray(idx).reshape(-1):
        counts[i] += 1
    _close(np.asarray(g)[:, 0], counts, 1e-5)


# ---------------------------------------------------------------------------
# Feature interaction (dense engine)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,f,d", [(4, 6, 32), (9, 27, 16), (64, 6, 32),
                                   (1, 51, 32)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_interaction_matches_oracle(rng, b, f, d, dtype):
    x = jnp.asarray(rng.randn(b, f, d), dtype)
    got = feature_interaction.interaction(x, interpret=True)
    want = ref.interaction(x)
    _close(got, want, 1e-4 if dtype == jnp.float32 else 1e-1)


def test_interaction_tril_shape_and_symmetry(rng):
    x = jnp.asarray(rng.randn(3, 6, 8), jnp.float32)
    z = ref.interaction(x)
    # symmetry
    _close(z, np.swapaxes(np.asarray(z), 1, 2), 1e-5)
    tril = ops.interaction_tril(x)
    assert tril.shape == (3, 15)


@settings(deadline=None, max_examples=15)
@given(st.integers(1, 8), st.integers(2, 10), st.integers(1, 16),
       st.integers(0, 2**31 - 1))
def test_interaction_property_diag_is_norm(b, f, d, seed):
    """Property: diagonal of X X^T equals squared row norms."""
    r = np.random.RandomState(seed % (2**32 - 1))
    x = jnp.asarray(r.randn(b, f, d), jnp.float32)
    z = np.asarray(ref.interaction(x))
    norms = (np.asarray(x) ** 2).sum(-1)
    _close(np.diagonal(z, axis1=1, axis2=2), norms, 1e-4)


# ---------------------------------------------------------------------------
# Flash attention (memory-term kernel)
# ---------------------------------------------------------------------------

def _ref_attn(q, k, v, causal, window):
    s = jnp.einsum("bqd,bkd->bqk", q, k).astype(jnp.float32) \
        * (q.shape[-1] ** -0.5)
    S = q.shape[1]
    qp = jnp.arange(S)[:, None]
    kp = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= kp <= qp
    if window:
        mask &= kp > qp - window
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, -1)
    return jnp.einsum("bqk,bkd->bqd", p.astype(v.dtype), v)


@pytest.mark.parametrize("s,d,causal,window,bq,bk",
                         [(128, 64, True, None, 64, 64),
                          (96, 32, False, None, 32, 32),
                          (128, 64, True, 32, 64, 32),
                          (100, 16, True, None, 64, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_oracle(rng, s, d, causal, window, bq, bk,
                                        dtype):
    from repro.kernels.flash_attention import flash_attention
    q = jnp.asarray(rng.randn(2, s, d), dtype)
    k = jnp.asarray(rng.randn(2, s, d), dtype)
    v = jnp.asarray(rng.randn(2, s, d), dtype)
    got = flash_attention(q, k, v, causal=causal, window=window, bq=bq,
                          bk=bk, interpret=True)
    want = _ref_attn(q, k, v, causal, window)
    tol = 2e-5 if dtype == jnp.float32 else 5e-2
    _close(got, want, tol)


def test_flash_attention_gqa_matches_repeat(rng):
    from repro.kernels.flash_attention import flash_attention_gqa
    q = jnp.asarray(rng.randn(2, 64, 8, 32), jnp.float32)
    k = jnp.asarray(rng.randn(2, 64, 2, 32), jnp.float32)
    v = jnp.asarray(rng.randn(2, 64, 2, 32), jnp.float32)
    got = flash_attention_gqa(q, k, v, interpret=True)
    # reference: repeat kv to full heads, per-head attention
    kk = jnp.repeat(k, 4, axis=2)
    vv = jnp.repeat(v, 4, axis=2)
    for h in range(8):
        want = _ref_attn(q[:, :, h], kk[:, :, h], vv[:, :, h], True, None)
        _close(got[:, :, h], want, 1e-4)
