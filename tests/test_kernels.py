"""Per-kernel validation: Pallas (interpret=True) vs pure-jnp oracles,
swept over shapes and dtypes, plus hypothesis property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.kernels import embedding_gather, feature_interaction, gemm, ops, ref

jax.config.update("jax_enable_x64", False)


def _close(a, b, tol=2e-2):
    a = np.asarray(a, np.float32)
    b = np.asarray(b, np.float32)
    np.testing.assert_allclose(a, b, rtol=tol, atol=tol)


# ---------------------------------------------------------------------------
# GEMM (dense engine)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,k,n", [(8, 16, 8), (128, 128, 128),
                                   (130, 70, 150), (256, 33, 64),
                                   (1, 512, 1)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gemm_matches_oracle(rng, m, k, n, dtype):
    x = jnp.asarray(rng.randn(m, k), dtype)
    w = jnp.asarray(rng.randn(k, n), dtype)
    got = gemm.gemm(x, w, interpret=True)
    want = ref.gemm(x, w)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    _close(got, want, tol)


@pytest.mark.parametrize("bm,bn,bk", [(32, 32, 32), (128, 128, 64)])
def test_gemm_block_shapes(rng, bm, bn, bk):
    x = jnp.asarray(rng.randn(96, 80), jnp.float32)
    w = jnp.asarray(rng.randn(80, 112), jnp.float32)
    got = gemm.gemm(x, w, bm=bm, bn=bn, bk=bk, interpret=True)
    _close(got, ref.gemm(x, w), 1e-5)


# ---------------------------------------------------------------------------
# Embedding gather-reduce (sparse engine)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("v,d,b,l", [(100, 32, 4, 1), (1000, 32, 16, 20),
                                     (512, 128, 8, 80), (64, 48, 3, 5)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_embedding_bag_matches_oracle(rng, v, d, b, l, dtype):
    table = jnp.asarray(rng.randn(v, d), dtype)
    idx = jnp.asarray(rng.randint(0, v, (b, l)), jnp.int32)
    got = embedding_gather.embedding_bag(table, idx, interpret=True)
    want = ref.embedding_bag(table, idx)
    _close(got, want, 1e-5 if dtype == jnp.float32 else 5e-2)


def test_embedding_bag_d_blocking(rng):
    table = jnp.asarray(rng.randn(256, 96), jnp.float32)
    idx = jnp.asarray(rng.randint(0, 256, (4, 7)), jnp.int32)
    got = embedding_gather.embedding_bag(table, idx, bd=32, interpret=True)
    _close(got, ref.embedding_bag(table, idx), 1e-5)


def test_gather_rows(rng):
    table = jnp.asarray(rng.randn(128, 16), jnp.float32)
    idx = jnp.asarray(rng.randint(0, 128, (9,)), jnp.int32)
    got = embedding_gather.gather_rows(table, idx, interpret=True)
    _close(got, table[idx], 1e-6)


@settings(deadline=None, max_examples=20)
@given(st.integers(2, 50), st.integers(1, 16), st.integers(1, 12),
       st.integers(0, 2**31 - 1))
def test_embedding_bag_property(v, b, l, seed):
    """Property: gather-reduce is linear in the table and permutation-
    invariant in the lookup order."""
    r = np.random.RandomState(seed % (2**32 - 1))
    table = jnp.asarray(r.randn(v, 8), jnp.float32)
    idx = r.randint(0, v, (b, l)).astype(np.int32)
    out1 = ops.embedding_bag(table, jnp.asarray(idx))
    # permutation invariance
    perm = np.stack([r.permutation(row) for row in idx.reshape(b, l)])
    out2 = ops.embedding_bag(table, jnp.asarray(perm))
    _close(out1, out2, 1e-4)
    # linearity: bag(2*table) == 2*bag(table)
    out3 = ops.embedding_bag(2.0 * table, jnp.asarray(idx))
    _close(out3, 2.0 * np.asarray(out1), 1e-4)


def test_sparse_lengths_sum_ragged(rng):
    """Paper Fig. 2 semantics with ragged offsets."""
    table = jnp.asarray(rng.randn(50, 8), jnp.float32)
    indices = jnp.asarray(rng.randint(0, 50, (10,)), jnp.int32)
    offsets = jnp.asarray([0, 3, 3, 7, 10], jnp.int32)
    out = ref.sparse_lengths_sum(table, indices, offsets)
    for b in range(4):
        lo, hi = int(offsets[b]), int(offsets[b + 1])
        want = np.asarray(table)[np.asarray(indices[lo:hi])].sum(0) \
            if hi > lo else np.zeros(8)
        _close(out[b], want, 1e-5)


def test_embedding_bag_grad_is_scatter_add(rng):
    table = jnp.asarray(rng.randn(64, 8), jnp.float32)
    idx = jnp.asarray(rng.randint(0, 64, (5, 3)), jnp.int32)
    g = jax.grad(lambda t: ops.embedding_bag(t, idx).sum())(table)
    counts = np.zeros(64)
    for i in np.asarray(idx).reshape(-1):
        counts[i] += 1
    _close(np.asarray(g)[:, 0], counts, 1e-5)


# ---------------------------------------------------------------------------
# Feature interaction (dense engine)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,f,d", [(4, 6, 32), (9, 27, 16), (64, 6, 32),
                                   (1, 51, 32)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_interaction_matches_oracle(rng, b, f, d, dtype):
    x = jnp.asarray(rng.randn(b, f, d), dtype)
    got = feature_interaction.interaction(x, interpret=True)
    want = ref.interaction(x)
    _close(got, want, 1e-4 if dtype == jnp.float32 else 1e-1)


def test_interaction_tril_shape_and_symmetry(rng):
    x = jnp.asarray(rng.randn(3, 6, 8), jnp.float32)
    z = ref.interaction(x)
    # symmetry
    _close(z, np.swapaxes(np.asarray(z), 1, 2), 1e-5)
    tril = ops.interaction_tril(x)
    assert tril.shape == (3, 15)


@settings(deadline=None, max_examples=15)
@given(st.integers(1, 8), st.integers(2, 10), st.integers(1, 16),
       st.integers(0, 2**31 - 1))
def test_interaction_property_diag_is_norm(b, f, d, seed):
    """Property: diagonal of X X^T equals squared row norms."""
    r = np.random.RandomState(seed % (2**32 - 1))
    x = jnp.asarray(r.randn(b, f, d), jnp.float32)
    z = np.asarray(ref.interaction(x))
    norms = (np.asarray(x) ** 2).sum(-1)
    _close(np.diagonal(z, axis1=1, axis2=2), norms, 1e-4)


# ---------------------------------------------------------------------------
# Flash attention (memory-term kernel)
# ---------------------------------------------------------------------------

def _ref_attn(q, k, v, causal, window):
    s = jnp.einsum("bqd,bkd->bqk", q, k).astype(jnp.float32) \
        * (q.shape[-1] ** -0.5)
    S = q.shape[1]
    qp = jnp.arange(S)[:, None]
    kp = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= kp <= qp
    if window:
        mask &= kp > qp - window
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, -1)
    return jnp.einsum("bqk,bkd->bqd", p.astype(v.dtype), v)


@pytest.mark.parametrize("s,d,causal,window,bq,bk",
                         [(128, 64, True, None, 64, 64),
                          (96, 32, False, None, 32, 32),
                          (128, 64, True, 32, 64, 32),
                          (100, 16, True, None, 64, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_oracle(rng, s, d, causal, window, bq, bk,
                                        dtype):
    from repro.kernels.flash_attention import flash_attention
    q = jnp.asarray(rng.randn(2, s, d), dtype)
    k = jnp.asarray(rng.randn(2, s, d), dtype)
    v = jnp.asarray(rng.randn(2, s, d), dtype)
    got = flash_attention(q, k, v, causal=causal, window=window, bq=bq,
                          bk=bk, interpret=True)
    want = _ref_attn(q, k, v, causal, window)
    tol = 2e-5 if dtype == jnp.float32 else 5e-2
    _close(got, want, tol)


def test_flash_attention_gqa_matches_repeat(rng):
    from repro.kernels.flash_attention import flash_attention_gqa
    q = jnp.asarray(rng.randn(2, 64, 8, 32), jnp.float32)
    k = jnp.asarray(rng.randn(2, 64, 2, 32), jnp.float32)
    v = jnp.asarray(rng.randn(2, 64, 2, 32), jnp.float32)
    got = flash_attention_gqa(q, k, v, interpret=True)
    # reference: repeat kv to full heads, per-head attention
    kk = jnp.repeat(k, 4, axis=2)
    vv = jnp.repeat(v, 4, axis=2)
    for h in range(8):
        want = _ref_attn(q[:, :, h], kk[:, :, h], vv[:, :, h], True, None)
        _close(got[:, :, h], want, 1e-4)


# ---------------------------------------------------------------------------
# Fused segmented dispatch (the one-walk grouped/cached/sharded kernel)
# ---------------------------------------------------------------------------

from repro.kernels import fused_dispatch  # noqa: E402


def _dense_case(rng, v, b, l, null=None):
    """A dense (b, l) id matrix with ragged structure baked in: each bag
    is cut short at a random length, fill slots pointing at `null`."""
    ids = rng.randint(0, v, (b, l))
    if null is not None:
        lens = rng.randint(0, l + 1, b)
        for i in range(b):
            ids[i, lens[i]:] = null
    return jnp.asarray(ids, jnp.int32)


@pytest.mark.parametrize("v,d,b,l", [(100, 32, 4, 1), (257, 16, 8, 6),
                                     (64, 128, 3, 9), (1, 1, 2, 3),
                                     (50, 1, 5, 4), (1, 48, 4, 2)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_segment_sum_matches_oracle(rng, v, d, b, l, dtype):
    table = jnp.asarray(rng.randn(v, d), dtype)
    ids = _dense_case(rng, v, b, l, null=v - 1)
    got = fused_dispatch.fused_segment_sum(table, ids, interpret=True)
    want = ref.fused_segment_sum(table, ids)
    _close(got, want, 1e-5 if dtype == jnp.float32 else 5e-2)


@pytest.mark.parametrize("v,k,d,b,l", [(120, 9, 8, 4, 5), (64, 1, 16, 3, 3),
                                       (256, 33, 32, 6, 7)])
def test_fused_cached_segment_sum_matches_oracle(rng, v, k, d, b, l):
    arena = jnp.asarray(rng.randn(v, d), jnp.float32)
    hot = jnp.asarray(rng.randn(k + 1, d), jnp.float32)
    slots = _dense_case(rng, k + 1, b, l)
    cold = _dense_case(rng, v, b, l)
    got = fused_dispatch.fused_cached_segment_sum(hot, arena, slots, cold,
                                                  interpret=True)
    want = ref.fused_cached_segment_sum(hot, arena, slots, cold)
    _close(got, want, 1e-5)


def test_fused_ops_pallas_equals_xla_lookup_and_grad(rng):
    """ops.fused_segment_sum / fused_cached_segment_sum agree between the
    Pallas kernel body (interpret) and the XLA reference — outputs AND
    the custom-VJP gradients, including the pinned-to-zero null rows."""
    v, d, b, l, k, null = 90, 16, 6, 5, 12, 89
    table = jnp.asarray(rng.randn(v, d), jnp.float32)
    ids = _dense_case(rng, v, b, l, null=null)
    hot = jnp.asarray(rng.randn(k + 1, d), jnp.float32).at[k].set(0.0)
    slots = _dense_case(rng, k + 1, b, l, null=k)
    cold = _dense_case(rng, v, b, l, null=null)
    outs, grads = [], []
    for impl in ("xla", "interpret"):
        ops.set_impl(impl)
        try:
            f = lambda t: ops.fused_segment_sum(t, ids, null_row=null)
            outs.append(np.asarray(f(table)))
            g = jax.grad(lambda t: f(t).sum())(table)
            fc = lambda h, a: ops.fused_cached_segment_sum(
                h, a, slots, cold, null_row=null)
            outs.append(np.asarray(fc(hot, table)))
            gh, ga = jax.grad(lambda h, a: fc(h, a).sum(),
                              argnums=(0, 1))(hot, table)
            grads.append((np.asarray(g), np.asarray(gh), np.asarray(ga)))
        finally:
            ops.set_impl("auto")
    _close(outs[0], outs[2], 1e-5)
    _close(outs[1], outs[3], 1e-5)
    for a, bb in zip(grads[0], grads[1]):
        _close(a, bb, 1e-5)
    # the sentinel rows never receive gradient (the ragged tail-mask law)
    g, gh, ga = grads[0]
    assert (g[null] == 0).all() and (gh[k] == 0).all() \
        and (ga[null] == 0).all()


def test_fused_degenerate_bags(rng):
    """Degenerate shapes the relayout must survive: empty bags,
    all-duplicate bags, all-null bags, vocab-1/dim-1 tables, max_l=0."""
    d = 8
    table = jnp.asarray(rng.randn(40, d), jnp.float32).at[39].set(0.0)
    null = 39
    # empty bags: every slot is fill -> exact zeros
    empty = jnp.full((3, 4), null, jnp.int32)
    assert (np.asarray(ops.fused_segment_sum(table, empty)) == 0).all()
    # all-duplicate bag: L * row, bit-for-bit against the closed form
    dup = jnp.full((1, 6), 7, jnp.int32)
    got = np.asarray(ops.fused_segment_sum(table, dup))
    want = np.asarray(table[7], np.float32)[None, :] * 6.0
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)
    # max_l == 0: (B, 0) ids -> zeros, on both backends
    zero_ids = jnp.zeros((4, 0), jnp.int32)
    assert ops.fused_segment_sum(table, zero_ids).shape == (4, d)
    assert (np.asarray(
        fused_dispatch.fused_segment_sum(table, zero_ids,
                                         interpret=True)) == 0).all()
    # vocab-1 / dim-1
    t1 = jnp.asarray(rng.randn(1, 1), jnp.float32)
    ids1 = jnp.zeros((2, 3), jnp.int32)
    got1 = np.asarray(ops.fused_segment_sum(t1, ids1))
    np.testing.assert_allclose(got1, np.full((2, 1), 3 * float(t1[0, 0]),
                                             np.float32), rtol=1e-6)
    # all-null bags still take zero gradient on the sentinel
    g = jax.grad(lambda t: ops.fused_segment_sum(
        t, empty, null_row=null).sum())(table)
    assert (np.asarray(g) == 0).all()


def test_fused_cached_one_pass_equals_uncached_bitwise(rng):
    """The in-kernel hit-test law: splitting any dense id matrix into
    (hot slots, cold redirects) and running the one-pass cached reduce is
    BIT-FOR-BIT the uncached reduce, and the hot/cold gradients recombine
    to exactly the uncached gradient."""
    v, d, b, l, k, null = 80, 8, 5, 6, 10, 79
    table = jnp.asarray(rng.randn(v, d), jnp.float32).at[null].set(0.0)
    ids = _dense_case(rng, v, b, l, null=null)
    # hot set: the k most frequent ids (never the sentinel, matching
    # build_hot_cache); hot_rows copies arena rows
    counts = np.bincount(np.asarray(ids).ravel(), minlength=v)
    counts[null] = -1
    hot_ids = np.argsort(counts)[-k:]
    slot_of = np.full(v, k, np.int32)
    slot_of[hot_ids] = np.arange(k)
    slot_of = jnp.asarray(slot_of)
    hot_rows = jnp.concatenate([table[jnp.asarray(hot_ids)],
                                jnp.zeros((1, d), jnp.float32)])
    slots = jnp.take(slot_of, ids)
    cold = jnp.where(slots < k, jnp.asarray(null, ids.dtype), ids)
    got = np.asarray(ops.fused_cached_segment_sum(hot_rows, table, slots,
                                                  cold, null_row=null))
    want = np.asarray(ops.fused_segment_sum(table, ids, null_row=null))
    np.testing.assert_array_equal(got, want)
    # gradient law: scatter d_hot back onto its arena rows + d_arena
    # == the uncached arena gradient, exactly
    g_un = jax.grad(lambda t: ops.fused_segment_sum(
        t, ids, null_row=null).sum())(table)
    gh, ga = jax.grad(
        lambda h, a: ops.fused_cached_segment_sum(
            h, a, slots, cold, null_row=null).sum(),
        argnums=(0, 1))(hot_rows, table)
    recomb = np.array(ga)
    recomb[hot_ids] += np.asarray(gh)[:k]
    np.testing.assert_array_equal(recomb, np.asarray(g_un))


def test_fused_cached_coherent_lowering_same_value_same_split(rng):
    """Passing dense_ids= opts into the coherence-law lowering: the
    forward equals both the uncached reduce (bitwise, on xla) and the
    two-table walk (which it replaces on xla but not on the kernel
    path), while the gradients still split onto hot slots / cold ids
    exactly as the explicit two-pass op's do."""
    v, d, b, l, k, null = 70, 8, 5, 6, 9, 69
    table = jnp.asarray(rng.randn(v, d), jnp.float32).at[null].set(0.0)
    ids = _dense_case(rng, v, b, l, null=null)
    counts = np.bincount(np.asarray(ids).ravel(), minlength=v)
    counts[null] = -1
    hot_ids = np.argsort(counts)[-k:]
    slot_of = np.full(v, k, np.int32)
    slot_of[hot_ids] = np.arange(k)
    slots = jnp.take(jnp.asarray(slot_of), ids)
    cold = jnp.where(slots < k, jnp.asarray(null, ids.dtype), ids)
    hot_rows = jnp.concatenate([table[jnp.asarray(hot_ids)],
                                jnp.zeros((1, d), jnp.float32)])
    for impl in ("xla", "interpret"):
        ops.set_impl(impl)
        try:
            coh = lambda h, a: ops.fused_cached_segment_sum(
                h, a, slots, cold, dense_ids=ids, null_row=null)
            split = lambda h, a: ops.fused_cached_segment_sum(
                h, a, slots, cold, null_row=null)
            got = np.asarray(coh(hot_rows, table))
            np.testing.assert_allclose(
                got, np.asarray(split(hot_rows, table)), rtol=1e-5,
                atol=1e-6)
            if impl == "xla":
                np.testing.assert_array_equal(
                    got, np.asarray(ops.fused_segment_sum(
                        table, ids, null_row=null)))
            g_coh = jax.grad(lambda h, a: coh(h, a).sum(),
                             argnums=(0, 1))(hot_rows, table)
            g_split = jax.grad(lambda h, a: split(h, a).sum(),
                               argnums=(0, 1))(hot_rows, table)
            for a, bb in zip(g_coh, g_split):
                np.testing.assert_array_equal(np.asarray(a),
                                              np.asarray(bb))
            assert np.abs(np.asarray(g_coh[0])[:-1]).max() > 0
        finally:
            ops.set_impl("auto")


@pytest.mark.parametrize("shards", [1, 2, 4, 8])
def test_fused_sharded_partial_equals_replicated(rng, shards):
    """The sharded law over the dense id matrix: every shard's masked
    partial reduce psums back to the replicated fused reduce (vmap-
    emulated mesh), for shard counts {1, 2, 4, 8}."""
    from repro.core import sparse_engine as se
    v, d, b, l = 8 * 13, 16, 6, 5
    null = v - 1
    table = jnp.asarray(rng.randn(v, d), jnp.float32).at[null].set(0.0)
    ids = _dense_case(rng, v, b, l, null=null)
    want = np.asarray(ops.fused_segment_sum(table, ids, null_row=null))
    outs = jax.vmap(
        lambda a: se.dense_partial_reduce(a, ids, "x", null_row=null),
        axis_name="x")(table.reshape(shards, -1, d))
    for s in range(shards):
        np.testing.assert_allclose(np.asarray(outs[s]), want, rtol=1e-5,
                                   atol=1e-5)
