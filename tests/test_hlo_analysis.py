"""Trip-count-aware HLO analyzer tests against exactly-known programs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import hlo_analysis, roofline


def _compile(f, *specs):
    return jax.jit(f).lower(*specs).compile()


def test_flops_plain_matmul():
    co = _compile(lambda x, w: x @ w,
                  jax.ShapeDtypeStruct((128, 128), jnp.float32),
                  jax.ShapeDtypeStruct((128, 128), jnp.float32))
    r = hlo_analysis.analyze(co.as_text())
    assert r["flops"] == pytest.approx(2 * 128 ** 3, rel=0.01)


def test_flops_scan_multiplies_trip_count():
    def f(x, ws):
        return jax.lax.scan(lambda c, w: (c @ w, None), x, ws)[0]
    co = _compile(f, jax.ShapeDtypeStruct((128, 128), jnp.float32),
                  jax.ShapeDtypeStruct((10, 128, 128), jnp.float32))
    r = hlo_analysis.analyze(co.as_text())
    assert r["flops"] == pytest.approx(2 * 128 ** 3 * 10, rel=0.01)
    # XLA's own counter misses the loop — documents why we parse ourselves
    ca = co.cost_analysis()
    if isinstance(ca, list):     # older jax returns one dict per program
        ca = ca[0]
    assert ca["flops"] < r["flops"] / 5


def test_flops_nested_scan():
    def g(x, ws):
        def outer(c, w3):
            return jax.lax.scan(lambda c2, w: (c2 @ w, None), c, w3)[0], None
        return jax.lax.scan(outer, x, ws)[0]
    co = _compile(g, jax.ShapeDtypeStruct((64, 64), jnp.float32),
                  jax.ShapeDtypeStruct((5, 4, 64, 64), jnp.float32))
    r = hlo_analysis.analyze(co.as_text())
    assert r["flops"] == pytest.approx(2 * 64 ** 3 * 20, rel=0.01)


def test_bytes_dominated_by_real_traffic():
    """An elementwise op on N floats should cost ~2*4N bytes, not more
    than a few times that."""
    n = 1 << 20
    co = _compile(lambda x: x * 2.0 + 1.0,
                  jax.ShapeDtypeStruct((n,), jnp.float32))
    r = hlo_analysis.analyze(co.as_text())
    assert 8 * n <= r["bytes"] <= 32 * n


def test_shape_parsing():
    assert hlo_analysis._shape_elems_bytes("f32[16,24]{1,0}") == (384, 1536)
    assert hlo_analysis._shape_elems_bytes("bf16[8]")[1] == 16
    e, b = hlo_analysis._shape_elems_bytes("(f32[4], s32[2])")
    assert (e, b) == (6, 24)
    assert hlo_analysis._shape_elems_bytes("pred[]")[1] == 1


def test_roofline_terms_and_dominance():
    rep = roofline.analyze(flops_per_dev=197e12, bytes_per_dev=0.0,
                           coll_bytes_per_dev=0.0, model_flops=197e12 * 256,
                           chips=256)
    assert rep.t_compute == pytest.approx(1.0)
    assert rep.dominant == "compute"
    assert rep.roofline_fraction == pytest.approx(1.0)
    rep2 = roofline.analyze(1e12, 819e9 * 2, 0.0, 1e12 * 256, 256)
    assert rep2.dominant == "memory"


def test_model_flops_moe_uses_active_params():
    from repro.configs.registry import ARCHS
    from repro.configs.base import TRAIN_4K
    from repro.models import api
    cfg = ARCHS["kimi-k2-1t-a32b"]
    cell = {}

    def f(k):
        vals, specs = api.init(k, cfg)
        cell["specs"] = specs
        return vals
    shapes = jax.eval_shape(f, jax.ShapeDtypeStruct((2,), jnp.uint32))
    total, active = roofline.count_params(shapes, cfg)
    assert total > 0.9e12            # ~1T total
    assert active < 0.05 * total     # top-8 of 384 experts
