"""Trip-count-aware HLO analyzer tests against exactly-known programs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import hlo_analysis, roofline


def _compile(f, *specs):
    return jax.jit(f).lower(*specs).compile()


def test_flops_plain_matmul():
    co = _compile(lambda x, w: x @ w,
                  jax.ShapeDtypeStruct((128, 128), jnp.float32),
                  jax.ShapeDtypeStruct((128, 128), jnp.float32))
    r = hlo_analysis.analyze(co.as_text())
    assert r["flops"] == pytest.approx(2 * 128 ** 3, rel=0.01)


def test_flops_scan_multiplies_trip_count():
    def f(x, ws):
        return jax.lax.scan(lambda c, w: (c @ w, None), x, ws)[0]
    co = _compile(f, jax.ShapeDtypeStruct((128, 128), jnp.float32),
                  jax.ShapeDtypeStruct((10, 128, 128), jnp.float32))
    r = hlo_analysis.analyze(co.as_text())
    assert r["flops"] == pytest.approx(2 * 128 ** 3 * 10, rel=0.01)
    # XLA's own counter misses the loop — documents why we parse ourselves
    ca = co.cost_analysis()
    if isinstance(ca, list):     # older jax returns one dict per program
        ca = ca[0]
    assert ca["flops"] < r["flops"] / 5


def test_flops_nested_scan():
    def g(x, ws):
        def outer(c, w3):
            return jax.lax.scan(lambda c2, w: (c2 @ w, None), c, w3)[0], None
        return jax.lax.scan(outer, x, ws)[0]
    co = _compile(g, jax.ShapeDtypeStruct((64, 64), jnp.float32),
                  jax.ShapeDtypeStruct((5, 4, 64, 64), jnp.float32))
    r = hlo_analysis.analyze(co.as_text())
    assert r["flops"] == pytest.approx(2 * 64 ** 3 * 20, rel=0.01)


def test_bytes_dominated_by_real_traffic():
    """An elementwise op on N floats should cost ~2*4N bytes, not more
    than a few times that."""
    n = 1 << 20
    co = _compile(lambda x: x * 2.0 + 1.0,
                  jax.ShapeDtypeStruct((n,), jnp.float32))
    r = hlo_analysis.analyze(co.as_text())
    assert 8 * n <= r["bytes"] <= 32 * n


def test_shape_parsing():
    assert hlo_analysis._shape_elems_bytes("f32[16,24]{1,0}") == (384, 1536)
    assert hlo_analysis._shape_elems_bytes("bf16[8]")[1] == 16
    e, b = hlo_analysis._shape_elems_bytes("(f32[4], s32[2])")
    assert (e, b) == (6, 24)
    assert hlo_analysis._shape_elems_bytes("pred[]")[1] == 1


def test_roofline_terms_and_dominance():
    rep = roofline.analyze(flops_per_dev=197e12, bytes_per_dev=0.0,
                           coll_bytes_per_dev=0.0, model_flops=197e12 * 256,
                           chips=256)
    assert rep.t_compute == pytest.approx(1.0)
    assert rep.dominant == "compute"
    assert rep.roofline_fraction == pytest.approx(1.0)
    rep2 = roofline.analyze(1e12, 819e9 * 2, 0.0, 1e12 * 256, 256)
    assert rep2.dominant == "memory"


def test_model_flops_moe_uses_active_params():
    from repro.configs.registry import ARCHS
    from repro.configs.base import TRAIN_4K
    from repro.models import api
    cfg = ARCHS["kimi-k2-1t-a32b"]
    cell = {}

    def f(k):
        vals, specs = api.init(k, cfg)
        cell["specs"] = specs
        return vals
    shapes = jax.eval_shape(f, jax.ShapeDtypeStruct((2,), jnp.uint32))
    total, active = roofline.count_params(shapes, cfg)
    assert total > 0.9e12            # ~1T total
    assert active < 0.05 * total     # top-8 of 384 experts


# ---------------------------------------------------------------------------
# timing-free perf gates for the fused segmented dispatch
# ---------------------------------------------------------------------------

def _sparse_fixture():
    from repro.core import embedding_source as es
    from repro.core import sparse_engine as se
    t, rpt, d, b, max_l = 4, 50, 8, 6, 4
    spec = se.ArenaSpec(t, rpt, d)
    arena = se.init_arena(jax.random.PRNGKey(0), spec, scale=1.0)
    rng = np.random.RandomState(0)
    n = b * t * 3
    idx = jnp.asarray(rng.randint(0, rpt, n), jnp.int32)
    lens = np.minimum(rng.randint(0, max_l + 1, b * t), 3)
    off = jnp.asarray(np.r_[0, np.cumsum(lens)].clip(0, n), jnp.int32)
    return es, se, spec, arena, idx, off, max_l


def test_hlo_gate_fused_forwards_are_scatter_free_one_pass():
    """The structural contract behind the bench numbers, asserted on the
    compiled HLO so it cannot rot into a timing flake:

    - every fused forward (plain / cached / grouped) lowers with ZERO
      scatter ops — the dense relayout replaced the per-table full-stream
      segment scatters;
    - the cached forward is ONE pass: with coherence declared
      (``CachedSource(coherent=True)``, the serving-plan default) the
      XLA lowering collapses to the plain arena reduction, so it
      compiles to the SAME op histogram as the uncached forward — the
      hit test survives only in the backward, where the hot/cold grad
      split is real state;
    - the grouped forward runs one small dense reduction per member (T
      reduces over (B, max_l) blocks) with no dynamic loop, instead of T
      reductions over the full interleaved stream."""
    import dataclasses
    es, se, spec, arena, idx, off, max_l = _sparse_fixture()
    t = spec.n_tables

    def fp(a, i, o):
        return es.lookup_bags(es.FpArena(a), spec, i, o, max_l=max_l)

    c_fp = hlo_analysis.count_ops(
        jax.jit(fp).lower(arena, idx, off).compile().as_text())

    counts = se.trace_row_counts(spec, idx, off)
    cache = se.build_hot_cache(arena, spec, counts, k=16)

    def cached(hr, so, a, i, o):
        c2 = dataclasses.replace(cache, hot_rows=hr, slot_of=so)
        return es.lookup_bags(es.CachedSource(c2, es.FpArena(a),
                                              coherent=True), spec,
                              i, o, max_l=max_l)

    c_c = hlo_analysis.count_ops(
        jax.jit(cached).lower(cache.hot_rows, cache.slot_of, arena, idx,
                              off).compile().as_text())

    specs = [se.ArenaSpec(1, spec.rows_per_table, spec.dim)
             for _ in range(t)]
    arenas = [se.init_arena(jax.random.PRNGKey(i + 1), sp, scale=1.0)
              for i, sp in enumerate(specs)]

    def grouped(ars, i, o):
        g = es.TableGroupSource(
            members=tuple(es.FpArena(a) for a in ars),
            specs=tuple(specs))
        return es.lookup_bags(g, g.envelope_spec, i, o, max_l=max_l)

    c_g = hlo_analysis.count_ops(
        jax.jit(grouped).lower(arenas, idx, off).compile().as_text())

    # scatter-free: neither a literal scatter op nor XLA:CPU's serialized
    # lowering of one (a while loop around dynamic-update-slice)
    for name, c in (("fp", c_fp), ("cached", c_c), ("grouped", c_g)):
        assert c.get("scatter", 0) == 0, (name, c)
        assert c.get("dynamic-update-slice", 0) == 0, (name, c)
    # cached == one pass: the coherence-law lowering makes the cached
    # forward compile to the same single reduction and gather count as
    # the uncached forward (the slot translate / hot load are dead code
    # outside the backward and get DCE'd)
    assert c_c.get("reduce", 0) == c_fp.get("reduce", 0) == 1, (c_fp, c_c)
    assert c_c.get("gather", 0) == c_fp.get("gather", 0), (c_fp, c_c)
    # grouped: per-member dense reductions, no dynamic loop over the
    # stream (the T-full-walk shape lowered with while/scatter)
    assert c_g.get("reduce", 0) == t, c_g
    assert c_g.get("while", 0) == 0, c_g


def test_hlo_gate_backward_still_scatters():
    """Sanity inverse of the forward gate: the training backward IS the
    segment scatter-add (the sparse engine run in reverse), so scatters
    must appear there — proving the forward gate isn't vacuous."""
    es, se, spec, arena, idx, off, max_l = _sparse_fixture()

    def loss(a, i, o):
        return es.lookup_bags(es.FpArena(a), spec, i, o,
                              max_l=max_l).sum()

    co = jax.jit(jax.grad(loss)).lower(arena, idx, off).compile()
    c = hlo_analysis.count_ops(co.as_text())
    # XLA:CPU serializes the scatter-add into a while loop of
    # dynamic-update-slice row updates; either form counts
    assert c.get("scatter", 0) + c.get("dynamic-update-slice", 0) >= 1, c
