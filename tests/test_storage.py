"""Property suite for repro.storage — tiered, bigger-than-memory serving.

The laws this file pins:

* **composition** — a ``TieredSource`` lookup is hot + warm + cold with
  hot rows bit-exact vs the fp arena, warm/cold within their per-row
  quantization bounds, and host-staged cold rows exact fp32 copies;
  gradients flow to the hot tier through the same fused VJP.
* **grouped == per-table** — a ``TableGroupSource`` with a tiered member
  still equals the per-table loop of its members' own lookups.
* **migration** — ``migrate`` with a correct dirty mask is bit-identical
  to a full ``build_tiered`` rebuild, and republishing the migrated
  source under a bumped version never recompiles the serve path.
* **staging residency** — ``HostStore.stage`` guarantees residency for
  the in-flight batch (hits + misses == touches), never evicts pinned
  rows for lookahead, truncates best-effort prefetch before the
  guarantee, and raises (then recovers) when a batch exceeds the arena.
* **artifacts** — the checkpoint manager round-trips ``VersionedSource``
  blobs (tiered and grouped included; a host tier's live store is
  ephemeral and comes back ``None`` still serving its staged snapshot).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import storage
from repro.checkpoint import CheckpointManager
from repro.configs.base import DLRMConfig
from repro.core import dlrm
from repro.core import embedding_source as es
from repro.core import sparse_engine as se
from repro.kernels import ops

CFG = DLRMConfig(name="dlrm_storage", n_tables=2, rows_per_table=200,
                 emb_dim=8, lookups_per_table=4,
                 bottom_mlp=(16, 8), top_mlp=(16, 1))


def _arena(spec, seed=0, scale=1.0):
    return se.init_arena(jax.random.PRNGKey(seed), spec, scale=scale)


def _ragged(rng, spec, n_bags, max_l):
    lens = rng.randint(0, max_l + 1, n_bags).astype(np.int32)
    off = np.zeros(n_bags + 1, np.int32)
    np.cumsum(lens, out=off[1:])
    idx = rng.randint(0, spec.total_rows - 1, off[-1]).astype(np.int32)
    return jnp.asarray(idx), jnp.asarray(off)


def _policy(cold, spec, hot=20, warm=80, staging_rows=64, max_stage=32):
    return storage.TierPolicy(hot=hot, warm=warm, cold=cold,
                              staging_rows=staging_rows,
                              max_stage_per_batch=max_stage)


def _stage_all(tiered, idx):
    """Guarantee residency for every cold row `idx` touches, then
    snapshot the refreshed tier (what RecEngine does per batch)."""
    for st in storage.host_stores_of(tiered):
        st.stage_arena(np.asarray(idx))
    return storage.refresh_host_tiers(tiered)


# ---------------------------------------------------------------------------
# int4 pack/unpack and quantize_rows (the representation primitives)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dim", [1, 7, 8])
def test_int4_round_trip_within_bound(dim):
    rng = np.random.RandomState(3)
    a = jnp.asarray(rng.randn(40, dim).astype(np.float32))
    packed, scales = ops.int4_pack(a)
    assert packed.shape == (40, (dim + 1) // 2) and packed.dtype == jnp.uint8
    back = ops.int4_unpack(packed, scales, dim)
    # symmetric round-to-nearest at 4 bits: |err| <= scale/2 = amax/14
    bound = np.asarray(jnp.abs(a).max(axis=1)) / 14.0 + 1e-6
    err = np.abs(np.asarray(back) - np.asarray(a)).max(axis=1)
    assert (err <= bound).all(), (err, bound)


def test_int4_zero_row_is_exact_and_inert():
    a = jnp.zeros((3, 6), jnp.float32)
    packed, scales = ops.int4_pack(a)
    assert float(jnp.abs(scales).max()) == 0.0
    np.testing.assert_array_equal(
        np.asarray(ops.int4_unpack(packed, scales, 6)), np.zeros((3, 6)))


def test_quantize_rows_degenerate_inputs():
    """Empty row sets, duplicate ids, and all-zero rows: the incremental
    patch stays bit-identical to a full rebuild."""
    rng = np.random.RandomState(7)
    arena = jnp.asarray(rng.randn(30, 5).astype(np.float32))
    arena = arena.at[4].set(0.0)                    # an all-zero row
    full = es.QuantizedArena.from_arena(arena)

    # empty patch: a no-op
    same = full.quantize_rows(arena, jnp.zeros(0, jnp.int32))
    np.testing.assert_array_equal(np.asarray(same.q), np.asarray(full.q))

    # duplicate ids are an idempotent set; zero row keeps its zero scale
    stale = es.QuantizedArena(q=jnp.zeros_like(full.q),
                              scales=jnp.zeros_like(full.scales))
    rows = jnp.asarray([4, 9, 9, 4, 12], jnp.int32)
    patched = stale.quantize_rows(arena, rows)
    for r in (4, 9, 12):
        np.testing.assert_array_equal(np.asarray(patched.q[r]),
                                      np.asarray(full.q[r]))
    assert float(patched.scales[4, 0]) == 0.0
    assert float(jnp.abs(patched.q[0]).max()) == 0.0   # untouched row


# ---------------------------------------------------------------------------
# the composition law: hot bit-exact, warm/cold bounded, grads flow
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("cold", ["int4", "host"])
def test_tiered_lookup_composition(cold):
    spec = se.ArenaSpec(1, 150, 8)
    arena = _arena(spec, seed=1)
    rng = np.random.RandomState(11)
    counts = rng.rand(spec.total_rows)
    pol = _policy(cold, spec, hot=15, warm=60)
    tiered = storage.build_tiered(arena, spec, pol, counts)
    idx, off = _ragged(rng, spec, n_bags=12, max_l=5)
    tiered = _stage_all(tiered, idx)

    got = np.asarray(es.lookup_bags(tiered, spec, idx, off, max_l=5))
    want = np.asarray(es.lookup_bags(es.FpArena(arena), spec, idx, off,
                                     max_l=5))
    # per-bag bound: each warm row errs <= amax/254, each int4 cold row
    # <= amax/14, host-staged rows are exact — sum over <= max_l rows
    amax = float(jnp.abs(arena).max())
    per_row = amax / 254.0 + (amax / 14.0 if cold == "int4" else 0.0)
    assert np.abs(got - want).max() <= 5 * per_row + 1e-5

    # hot rows alone: bit-exact (bags touching only hot arena ids)
    hot_ids = np.asarray(tiered.hot_ids)
    hidx = jnp.asarray(hot_ids[:10], jnp.int32)
    hoff = jnp.asarray(np.arange(0, 11, 1, np.int32))
    np.testing.assert_array_equal(
        np.asarray(es.lookup_bags(tiered, spec, hidx, hoff, max_l=5)),
        np.asarray(es.lookup_bags(es.FpArena(arena), spec, hidx, hoff,
                                  max_l=5)))


def test_host_staged_rows_serve_exact_fp32():
    """A cold row served through the staging arena equals the fp arena
    row exactly — the composition law extends to the host tier."""
    spec = se.ArenaSpec(1, 100, 4)
    arena = _arena(spec, seed=2)
    pol = _policy("host", spec, hot=5, warm=10, staging_rows=32)
    tiered = storage.build_tiered(arena, spec, pol,
                                  np.arange(spec.total_rows)[::-1])
    cold_arena_ids = np.nonzero(
        np.asarray(tiered.tier_slot) >= tiered.n_hot + tiered.n_warm)[0]
    cold_arena_ids = cold_arena_ids[cold_arena_ids != spec.null_row][:16]
    idx = jnp.asarray(cold_arena_ids, jnp.int32)
    off = jnp.asarray(np.arange(len(cold_arena_ids) + 1, dtype=np.int32))
    tiered = _stage_all(tiered, idx)
    np.testing.assert_array_equal(
        np.asarray(es.lookup_bags(tiered, spec, idx, off, max_l=4)),
        np.asarray(es.lookup_bags(es.FpArena(arena), spec, idx, off,
                                  max_l=4)))


def test_tiered_grads_flow_to_hot_tier():
    """d(lookup)/d(hot_rows) through the fused VJP: nonzero exactly on
    the touched hot slots, zero on untouched slots and the null slot."""
    spec = se.ArenaSpec(1, 80, 6)
    arena = _arena(spec, seed=3)
    pol = _policy("int4", spec, hot=10, warm=30)
    tiered = storage.build_tiered(arena, spec, pol,
                                  np.arange(spec.total_rows)[::-1])
    hot_ids = np.asarray(tiered.hot_ids)
    idx = jnp.asarray(hot_ids[:4], jnp.int32)      # touch 4 hot rows
    off = jnp.asarray([0, 2, 4], jnp.int32)

    def loss(hot_rows):
        src = dataclasses.replace(tiered, hot_rows=hot_rows)
        return es.lookup_bags(src, spec, idx, off, max_l=4).sum()

    g = np.asarray(jax.grad(loss)(tiered.hot_rows))
    assert (np.abs(g[:4]).sum(axis=1) > 0).all()   # touched slots
    assert np.abs(g[4:]).max() == 0.0              # untouched + null


def test_grouped_equals_per_table_with_tiered_member():
    """A group mixing a tiered member (host cold) with a plain fp member
    still satisfies grouped == per-table, bit for bit."""
    vocabs, dims = (60, 40), (8, 4)
    plans = (es.TablePlan(rows=60, dim=8,
                          tiers=_policy("host", None, hot=6, warm=20,
                                        staging_rows=40)),
             es.TablePlan(rows=40, dim=4))
    specs = tuple(tp.arena_spec for tp in plans)
    arenas = [_arena(sp, seed=10 + t) for t, sp in enumerate(specs)]
    group = es.SourceSpec(tables=plans).build(arenas, None)
    assert isinstance(group.members[0], storage.TieredSource)

    rng = np.random.RandomState(5)
    b, max_l, t_count = 6, 4, 2
    lens = rng.randint(0, max_l + 1, b * t_count).astype(np.int32)
    off = np.zeros(b * t_count + 1, np.int32)
    np.cumsum(lens, out=off[1:])
    idx = np.concatenate([
        rng.randint(0, vocabs[i % t_count], lens[i]).astype(np.int32)
        for i in range(b * t_count)]) if off[-1] else np.zeros(0, np.int32)

    # stage the tiered member's cold rows for table 0's stream
    idx_t, off_t = [], []
    for t in range(t_count):
        bags = [idx[off[i]:off[i + 1]]
                for i in range(t, b * t_count, t_count)]
        idx_t.append(jnp.asarray(np.concatenate(bags)
                                 if bags else np.zeros(0, np.int32)))
        off_t.append(jnp.asarray(np.cumsum(
            [0] + [len(x) for x in bags]).astype(np.int32)))
    for st in storage.host_stores_of(group):
        st.stage_arena(np.asarray(idx_t[0]))
    group = storage.refresh_host_tiers(group)

    got = np.asarray(es.lookup_bags(group, group.envelope_spec,
                                    jnp.asarray(idx), jnp.asarray(off),
                                    max_l=max_l))
    for t, (m, sp) in enumerate(zip(group.members, group.specs)):
        own = np.asarray(es.lookup_bags(m, sp, idx_t[t], off_t[t],
                                        max_l=max_l))[:, 0, :]
        np.testing.assert_array_equal(got[:, t, :sp.dim],
                                      own.astype(got.dtype))
        assert (got[:, t, sp.dim:] == 0).all()


# ---------------------------------------------------------------------------
# migration: incremental == full rebuild; structure stability
# ---------------------------------------------------------------------------

def test_migrate_incremental_equals_full_rebuild():
    spec = se.ArenaSpec(1, 120, 6)
    arena0 = _arena(spec, seed=4)
    rng = np.random.RandomState(9)
    pol = _policy("int4", spec, hot=12, warm=50)
    t0 = storage.build_tiered(arena0, spec, pol, rng.rand(spec.total_rows))

    # train-like drift: some rows change values (dirty), ranks reshuffle
    touched = rng.choice(spec.total_rows - 1, 20, replace=False)
    arena1 = arena0.at[jnp.asarray(touched)].add(0.5)
    dirty = np.zeros(spec.total_rows, bool)
    dirty[touched] = True
    counts1 = rng.rand(spec.total_rows)

    mig, stats = storage.migrate(t0, arena1, spec, pol, counts1, dirty)
    full = storage.build_tiered(arena1, spec, pol, counts1)
    for f in ("hot_rows", "tier_slot", "hot_ids"):
        np.testing.assert_array_equal(np.asarray(getattr(mig, f)),
                                      np.asarray(getattr(full, f)), f)
    np.testing.assert_array_equal(np.asarray(mig.warm.q),
                                  np.asarray(full.warm.q))
    np.testing.assert_array_equal(np.asarray(mig.warm.scales),
                                  np.asarray(full.warm.scales))
    np.testing.assert_array_equal(np.asarray(mig.cold.packed),
                                  np.asarray(full.cold.packed))
    np.testing.assert_array_equal(np.asarray(mig.cold.scales),
                                  np.asarray(full.cold.scales))
    assert stats["promoted_hot"] == stats["demoted_hot"]   # fixed H
    assert stats["warm_requant"] <= spec.total_rows


def test_migrate_host_cold_retargets_in_place():
    """A host cold tier migrates by retargeting the SAME store object
    (treedef stability) and resets residency."""
    spec = se.ArenaSpec(1, 90, 4)
    arena = _arena(spec, seed=6)
    pol = _policy("host", spec, hot=8, warm=20, staging_rows=64)
    rng = np.random.RandomState(2)
    t0 = storage.build_tiered(arena, spec, pol, rng.rand(spec.total_rows))
    store = t0.cold.store
    store.stage_arena(np.arange(50))
    assert store.stats()["resident"] > 0
    mig, _ = storage.migrate(t0, arena, spec, pol,
                             rng.rand(spec.total_rows))
    assert mig.cold.store is store                 # same identity
    assert store.stats()["resident"] == 0          # residency reset
    assert (jax.tree_util.tree_structure(mig)
            == jax.tree_util.tree_structure(t0))


# ---------------------------------------------------------------------------
# HostStore residency semantics
# ---------------------------------------------------------------------------

def _store(c=40, d=4, s=16, max_stage=8):
    rows = np.arange(c * d, dtype=np.float32).reshape(c, d) + 1.0
    return storage.HostStore(rows, staging_rows=s,
                             max_stage_per_batch=max_stage), rows


def test_stage_accounting_and_bit_exact_rows():
    st, rows = _store()
    hits, misses = st.stage(np.array([3, 7, 7, 11]))
    assert (hits, misses) == (0, 3)                # unique ids
    hits, misses = st.stage(np.array([3, 7, 11, 20]))
    assert (hits, misses) == (3, 1)
    assert st.touches == st.hits + st.misses == 7
    tier = st.tier()
    slot = np.asarray(tier.slot_of)
    for i in (3, 7, 11, 20):
        np.testing.assert_array_equal(np.asarray(tier.staging[slot[i]]),
                                      rows[i])
    # non-resident ids point at the zero null slot
    assert slot[30] == st.staging_rows
    assert float(jnp.abs(tier.staging[-1]).max()) == 0.0


def test_stage_with_ahead_merges_one_plan():
    """Lookahead rides the same flush uncounted, then arrives as hits;
    need∩ahead overlap never double-assigns a slot."""
    st, _ = _store(s=16)
    cur, nxt = np.array([0, 1, 2]), np.array([2, 3, 4])   # overlap on 2
    hits, misses = st.stage(cur, ahead=nxt)
    assert (hits, misses) == (0, 3)                # only cur counted
    hits, misses = st.stage(nxt)
    assert (hits, misses) == (3, 0)                # lookahead landed
    # owner/slot maps agree: every resident id owns exactly one slot
    res = np.nonzero(st._slot_np[:-1] < st.staging_rows)[0]
    slots = st._slot_np[res]
    assert len(np.unique(slots)) == len(res)
    np.testing.assert_array_equal(st._owner[slots], res)


def test_pinned_rows_never_evicted_by_prefetch():
    st, _ = _store(c=40, s=8)
    st.stage(np.arange(8))                         # pin the full arena
    assert st.prefetch(np.arange(8, 20)) == 0      # nothing evictable
    assert (st._slot_np[np.arange(8)] < st.staging_rows).all()
    # next batch unpins: now the prefetch can evict LRU rows
    st.stage(np.array([0, 1]))
    assert st.prefetch(np.arange(8, 12)) == 4
    assert (st._slot_np[[0, 1]] < st.staging_rows).all()   # still pinned


def test_staging_too_small_raises_then_recovers():
    st, rows = _store(c=40, s=8)
    with pytest.raises(ValueError, match="staging arena too small"):
        st.stage(np.arange(12))                    # 12 > 8 slots
    hits, misses = st.stage(np.array([1, 2]))      # still functional
    assert misses == 2
    tier = st.tier()
    np.testing.assert_array_equal(
        np.asarray(tier.staging[np.asarray(tier.slot_of)[1]]), rows[1])


def test_lru_eviction_prefers_oldest_unpinned():
    st, _ = _store(c=40, s=8, max_stage=8)
    st.stage(np.arange(0, 4))                      # oldest
    st.stage(np.arange(4, 8))                      # arena now full
    st.stage(np.arange(8, 11))                     # must evict 3 of 0..3
    assert (st._slot_np[8:11] < st.staging_rows).all()
    assert (st._slot_np[4:8] < st.staging_rows).all()      # pinned batch
    evicted = (st._slot_np[0:4] == st.staging_rows).sum()
    assert evicted == 3


def test_warm_compile_does_not_disturb_residency():
    st, rows = _store()
    st.stage(np.array([5, 6]))
    before = np.asarray(st.tier().slot_of).copy()
    st.warm_compile()
    np.testing.assert_array_equal(np.asarray(st.tier().slot_of), before)
    tier = st.tier()
    np.testing.assert_array_equal(np.asarray(tier.staging[before[5]]),
                                  rows[5])


def test_store_structural_equality_for_jit_signatures():
    a, _ = _store(c=40, s=16)
    b, _ = _store(c=40, s=16)
    c, _ = _store(c=40, s=8)
    assert a == b and hash(a) == hash(b)           # interchangeable
    assert a != c


# ---------------------------------------------------------------------------
# engine: tiered serving, zero recompiles across version bumps
# ---------------------------------------------------------------------------

def test_engine_serves_tiered_with_zero_recompiles_across_migrations():
    from repro.serving import RecEngine
    from repro.serving.rec_engine import requests_from_ragged_batch
    from repro.training import make_drifting_zipf

    cfg = CFG
    spec = dlrm.arena_spec(cfg)
    params = dlrm.init(jax.random.PRNGKey(0), cfg)
    pol = storage.TierPolicy(hot=20, warm=150, cold="host",
                             staging_rows=128, max_stage_per_batch=32)
    eng = RecEngine(cfg, params, source=es.SourceSpec(tiers=pol),
                    max_l=6, max_batch=8, max_wait_ms=0.0, buckets=(8,))
    eng.warmup()
    compiled = eng._serve._cache_size()
    gen = make_drifting_zipf(cfg, batch_size=8, mean_l=3, max_l=6,
                             drift_per_batch=2, alpha=1.3, seed=1)

    def drive(n):
        for _ in range(n):
            for r in requests_from_ragged_batch(next(gen), cfg.n_tables):
                eng.submit(r)
            eng.step(force=True)
        eng.drain()

    drive(4)
    assert eng.stats()["path"] == "tiered"
    store = eng._host_stores[0][0]
    s = store.stats()
    assert s["hits"] + s["misses"] == s["touches"]

    # three migration republishes under bumped versions: same executable
    for _ in range(3):
        hist = np.zeros(spec.total_rows)
        b = next(gen)
        hist += se.trace_row_counts(spec, b["indices"], b["offsets"])
        migrated, _ = storage.migrate(eng.source, params["arena"], spec,
                                      pol, hist)
        eng.update_source(migrated, version=eng.source_version + 1)
        drive(2)
    assert eng._serve._cache_size() == compiled, \
        "tier migration republish recompiled the serve path"
    s = store.stats()
    assert s["hits"] + s["misses"] == s["touches"]


# ---------------------------------------------------------------------------
# trainer maintenance: tiered hot tier stays write-through fresh
# ---------------------------------------------------------------------------

def test_online_trainer_maintains_tiered_source():
    from repro.training import (OnlineCacheConfig, OnlineTrainer,
                                make_drifting_zipf)

    cfg = CFG
    params = dlrm.init(jax.random.PRNGKey(1), cfg)
    pol = storage.TierPolicy(hot=16, warm=100, cold="int4")
    trainer = OnlineTrainer(cfg, params, max_l=6, lr=1e-2,
                            cache_cfg=OnlineCacheConfig(
                                k=0, refresh_every=5, tiers=pol))
    assert isinstance(trainer.tiered, storage.TieredSource)
    gen = make_drifting_zipf(cfg, batch_size=8, mean_l=3, max_l=6,
                             drift_per_batch=2, alpha=1.2, seed=3)
    for _ in range(12):
        trainer.train_step(next(gen))
    assert trainer.version >= 2                    # migrations happened
    # write-through law: the fp hot tier equals the live arena bit-exact
    hot = np.asarray(trainer.tiered.hot_rows[:-1])
    want = np.asarray(jnp.take(trainer.params["arena"],
                               trainer.tiered.hot_ids, axis=0))
    np.testing.assert_array_equal(hot, want)
    assert trainer.serving_source() is trainer.tiered
    blob = trainer.publish_source()
    v = es.VersionedSource.deserialize(blob)
    assert v.version == trainer.version
    assert isinstance(v.source, storage.TieredSource)


def test_observe_is_a_noop_without_cache_cfg(monkeypatch):
    """No histogram consumer, no histogram cost: observe must early-return
    before touching the trace-count path."""
    from repro.training import OnlineTrainer
    from repro.training import online as online_mod

    cfg = CFG
    params = dlrm.init(jax.random.PRNGKey(2), cfg)
    trainer = OnlineTrainer(cfg, params, max_l=6, lr=1e-2)

    def boom(*a, **k):
        raise AssertionError("observe touched trace_row_counts "
                             "without a cache_cfg")

    monkeypatch.setattr(online_mod.se, "trace_row_counts", boom)
    trainer.observe({"indices": np.zeros(4, np.int32),
                     "offsets": np.zeros(5, np.int32)})


# ---------------------------------------------------------------------------
# artifacts: describe, tier_bytes, serializer + checkpoint round trips
# ---------------------------------------------------------------------------

def test_describe_source_reports_tiers_and_bytes():
    spec = se.ArenaSpec(1, 100, 8)
    arena = _arena(spec, seed=8)
    for cold, label in (("int4", "tiered(int4)"), ("host", "tiered(host)")):
        t = storage.build_tiered(arena, spec,
                                 _policy(cold, spec, hot=10, warm=40),
                                 np.arange(spec.total_rows))
        assert es.describe_source(t) == label
        ml = es.describe_source(t, multiline=True)
        assert "hot  fp" in ml and "warm int8" in ml
        assert ("int4 arena" in ml) == (cold == "int4")
        assert ("host tier" in ml) == (cold == "host")
        assert " B" in ml or " KB" in ml           # byte sizes rendered


def test_tier_bytes_accounting_sums():
    spec = se.ArenaSpec(1, 100, 8)
    arena = _arena(spec, seed=8)
    t = storage.build_tiered(arena, spec,
                             _policy("host", spec, hot=10, warm=40,
                                     staging_rows=16),
                             np.arange(spec.total_rows))
    b = storage.tier_bytes(t)
    assert b["device_total"] == b["hot"] + b["warm"] + b["cold"] + b["maps"]
    assert b["host"] == t.n_cold * spec.dim * 4    # fp32 host block
    assert b["cold"] == (16 + 1) * spec.dim * 4 + (t.n_cold + 1) * 4


@pytest.mark.parametrize("cold", ["int4", "host"])
def test_versioned_source_round_trips_tiered(cold):
    spec = se.ArenaSpec(1, 80, 4)
    arena = _arena(spec, seed=9)
    rng = np.random.RandomState(4)
    t = storage.build_tiered(arena, spec,
                             _policy(cold, spec, hot=8, warm=30,
                                     staging_rows=32),
                             rng.rand(spec.total_rows))
    idx, off = _ragged(rng, spec, n_bags=10, max_l=4)
    t = _stage_all(t, idx)
    blob = es.VersionedSource(source=t, version=7).serialize()
    v = es.VersionedSource.deserialize(blob)
    assert v.version == 7
    if cold == "host":
        assert v.source.cold.store is None         # ephemeral dropped
    np.testing.assert_array_equal(
        np.asarray(es.lookup_bags(v.source, spec, idx, off, max_l=4)),
        np.asarray(es.lookup_bags(t, spec, idx, off, max_l=4)))


def test_checkpoint_manager_round_trips_sources(tmp_path):
    """save_source/restore_source: tmp-then-rename publish, keep-N GC in
    its own src_* namespace, and a grouped source with a tiered member
    (host cold) restores to a blob that serves its staged snapshot."""
    plans = (es.TablePlan(rows=60, dim=8,
                          tiers=_policy("host", None, hot=6, warm=20,
                                        staging_rows=40)),
             es.TablePlan(rows=40, dim=4))
    specs = tuple(tp.arena_spec for tp in plans)
    arenas = [_arena(sp, seed=20 + t) for t, sp in enumerate(specs)]
    group = es.SourceSpec(tables=plans).build(arenas, None)
    for st in storage.host_stores_of(group):
        st.stage_arena(np.arange(60))
    group = storage.refresh_host_tiers(group)

    mgr = CheckpointManager(tmp_path, keep_n=2)
    for step, src in ((1, es.FpArena(arenas[1])), (2, group),
                      (3, group)):
        mgr.save_source(step, es.VersionedSource(source=src,
                                                 version=step))
    assert mgr.source_steps() == [2, 3]            # keep-N applied
    assert mgr.latest_source_step() == 3
    mgr.save(4, {"w": arenas[1]})                  # param namespace
    assert mgr.source_steps() == [2, 3]            # unaffected

    restored, manifest = mgr.restore_source()
    assert manifest["step"] == 3 and restored.version == 3
    assert isinstance(restored.source, es.TableGroupSource)
    assert restored.source.members[0].cold.store is None
    rng = np.random.RandomState(6)
    idx = jnp.asarray(rng.randint(0, 40, 12).astype(np.int32))
    off = jnp.asarray(np.linspace(0, 12, 7).astype(np.int32))
    np.testing.assert_array_equal(
        np.asarray(es.lookup_bags(restored.source,
                                  restored.source.envelope_spec,
                                  idx, off, max_l=4)),
        np.asarray(es.lookup_bags(group, group.envelope_spec,
                                  idx, off, max_l=4)))
    with pytest.raises(FileNotFoundError):
        CheckpointManager(tmp_path / "empty").restore_source()


def test_plan_validation_rejects_conflicting_knobs():
    pol = _policy("int4", None)
    with pytest.raises(ValueError, match="caching/quantization"):
        es.TablePlan(rows=10, dim=4, cache_k=5, tiers=pol)
    with pytest.raises(ValueError):
        es.SourceSpec(cache_k=8, tiers=pol)
    with pytest.raises(ValueError):
        es.SourceSpec(layout="fixed", tiers=pol)
    with pytest.raises(AssertionError):
        storage.TierPolicy(hot=4, warm=4, cold="float8")
