"""Source-equivalence property suite for the unified EmbeddingSource API.

ONE suite replaces the per-function equivalence copies that used to ride
with each `lookup*` variant: every composition of
{FpArena, QuantizedArena, ShardedArena, CachedSource} must agree with the
plain FpArena reference on the same ragged bags — exactly for fp
compositions, within the per-bag quantization bound for int8 cold rows —
over the hard edges (empty bags, duplicate indices, all-null bags, padded
tails, uneven vocab) and shard counts {1, 2, 4, 8}.

Sharding is vmap-emulated in-process (axis_index/psum behave exactly as
under shard_map) and exercised through the REAL shard_map entry point
(`ShardedArena` on 2/4/8-way meshes) in a subprocess with fake host
devices. Also locked down here: gradient routing through the source's fp
leaves, the no-recompile source-swap contract, the incremental
`quantize_rows` patch, the `VersionedSource` artifact, `SourceSpec`
plans, and the deprecation shims (value-preserving + warning).
"""
import json
import os
import subprocess
import sys
import textwrap
import warnings
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import dlrm
from repro.core import embedding_source as es
from repro.core import sparse_engine as se
from repro.training import source_row_grads

SRC = str(Path(__file__).resolve().parents[1] / "src")

SHARD_COUNTS = (1, 2, 4, 8)
# rows_per_table whose total_rows (3*r + 1) never divide 8: the padded
# trailing arena rows are in play at every shard count > 1
UNEVEN_ROWS = (29, 30, 37)


def _ragged_case(rng, spec, b, max_l, pad=0):
    """Random ragged batch with every hard edge forced in: an empty bag,
    a full bag, a duplicated index, an all-null-index bag, a padded
    tail."""
    n_bags = b * spec.n_tables
    lens = rng.randint(0, max_l + 1, n_bags).astype(np.int32)
    lens[0] = 0
    lens[-1] = max_l
    lens[1] = max(lens[1], 1)
    off = np.zeros(n_bags + 1, np.int32)
    np.cumsum(lens, out=off[1:])
    n = int(off[-1])
    idx = rng.randint(0, spec.rows_per_table, n + pad).astype(np.int32)
    if n >= 2:
        idx[off[-2]] = idx[0] if lens[0] else idx[n - 1]
    t1 = 1 % spec.n_tables
    idx[off[1]:off[2]] = spec.null_row - t1 * spec.rows_per_table
    return jnp.asarray(idx), jnp.asarray(off)


def _emulate_sharded(source, shards, spec, idx, off, max_l):
    """lookup_bags over ShardedArena(source), with the shard axis
    vmap-emulated (no mesh needed): every shard must reproduce the full
    result after the psum."""
    n_bags = off.shape[0] - 1
    flat = se.flatten_ragged_indices(spec, idx, off)
    leaves, treedef = jax.tree_util.tree_flatten(source)
    shard_leaves = [x.reshape(shards, -1, *x.shape[1:]) for x in leaves]

    def local(*ls):
        src = jax.tree_util.tree_unflatten(treedef, ls)
        return src.shard_reduce_flat(spec, flat, off, "x")

    outs = jax.vmap(local, axis_name="x")(*shard_leaves)
    outs = outs.astype(source.out_dtype).astype(jnp.float32)
    return [o.reshape(n_bags // spec.n_tables, spec.n_tables, spec.dim)
            .astype(source.out_dtype) for o in outs]


# ---------------------------------------------------------------------------
# the core equivalence property: every composition == FpArena reference
# ---------------------------------------------------------------------------

@settings(deadline=None, max_examples=10)
@given(st.sampled_from(SHARD_COUNTS), st.sampled_from(UNEVEN_ROWS),
       st.integers(0, 2**31 - 1))
def test_all_source_compositions_agree(shards, rpt, seed):
    rng = np.random.RandomState(seed % (2**32 - 1))
    spec = se.ArenaSpec(3, rpt, 8)
    arena = se.init_arena(jax.random.PRNGKey(seed % 997), spec, shards,
                          scale=1.0)
    max_l = 5
    idx, off = _ragged_case(rng, spec, b=3, max_l=max_l, pad=4)
    counts = se.trace_row_counts(spec, idx, off)
    cache = se.build_hot_cache(arena, spec, counts, k=8)
    fp = es.FpArena(arena)
    q = es.QuantizedArena.from_arena(arena)
    q_bound = max_l * float(np.asarray(q.scales).max()) + 1e-6

    want = np.asarray(es.lookup_bags(fp, spec, idx, off, max_l=max_l))

    # exact fp compositions
    got_c = np.asarray(es.lookup_bags(es.CachedSource(cache, fp), spec,
                                      idx, off, max_l=max_l))
    np.testing.assert_allclose(got_c, want, rtol=1e-5, atol=1e-5)

    # int8 compositions within the per-bag dequantization bound
    got_q = np.asarray(es.lookup_bags(q, spec, idx, off, max_l=max_l))
    assert np.abs(got_q - want).max() <= q_bound
    got_cq = np.asarray(es.lookup_bags(es.CachedSource(cache, q), spec,
                                       idx, off, max_l=max_l))
    assert np.abs(got_cq - want).max() <= q_bound

    # sharded (vmap-emulated) == replicated, for fp and int8 cold
    for src, ref, tol in ((fp, want, 1e-5), (q, got_q, 1e-5)):
        for out in _emulate_sharded(src, shards, spec, idx, off, max_l):
            np.testing.assert_allclose(np.asarray(out), ref, rtol=1e-5,
                                       atol=tol)

    # cached with a sharded cold pass: hot stays replicated, every shard
    # reconstructs the exact replicated cached result (the shard-local
    # composition shard_map runs, vmap-emulated here)
    flat = se.flatten_ragged_indices(spec, idx, off)
    slots = jnp.take(cache.slot_of, flat)
    k = cache.hot_rows.shape[0] - 1
    cold_idx = jnp.where(slots < k,
                         jnp.asarray(spec.null_row, flat.dtype), flat)
    from repro.kernels import ops
    hot = ops.sparse_lengths_sum(cache.hot_rows, slots, off,
                                 max_l=max_l).astype(jnp.float32)
    colds = jax.vmap(
        lambda a: es.FpArena(a).shard_reduce_flat(spec, cold_idx, off,
                                                  "x"),
        axis_name="x")(arena.reshape(shards, -1, spec.dim))
    for s in range(shards):
        got = np.asarray((hot + colds[s]).reshape(
            (off.shape[0] - 1) // spec.n_tables, spec.n_tables,
            spec.dim).astype(arena.dtype))
        np.testing.assert_allclose(got, got_c, rtol=1e-5, atol=1e-5)


def test_fixed_layout_sources_agree(rng):
    """lookup_fixed over every source == lookup_bags over the equivalent
    uniform ragged batch (the fixed path is one reshape away)."""
    spec = se.ArenaSpec(3, 30, 8)
    arena = se.init_arena(jax.random.PRNGKey(2), spec, scale=1.0)
    idx = jnp.asarray(rng.randint(0, 30, (4, 3, 5)), jnp.int32)
    b, t, l = idx.shape
    flat_stream = se.flatten_indices(spec, idx).reshape(-1)
    # undo table bases to get the per-table ragged stream
    tables = jnp.tile(jnp.repeat(jnp.arange(t), l), b)
    ragged_idx = flat_stream - tables * spec.rows_per_table
    off = jnp.asarray(np.arange(b * t + 1, dtype=np.int32) * l)
    counts = se.trace_row_counts(spec, ragged_idx, off)
    cache = se.build_hot_cache(arena, spec, counts, k=8)
    q = es.QuantizedArena.from_arena(arena)
    for src, tol in ((es.FpArena(arena), 1e-5), (q, 1e-5),
                     (es.CachedSource(cache, es.FpArena(arena)), 1e-5),
                     (es.CachedSource(cache, q), 1e-5)):
        fixed = np.asarray(es.lookup_fixed(src, spec, idx))
        ragged = np.asarray(es.lookup_bags(src, spec, ragged_idx, off,
                                           max_l=l))
        np.testing.assert_allclose(fixed, ragged, rtol=1e-5, atol=tol)


# ---------------------------------------------------------------------------
# gradients route through the source's fp leaves
# ---------------------------------------------------------------------------

def test_grad_through_source_matches_row_grads(rng):
    """jax.grad of a loss through lookup_bags(FpArena) == the scatter of
    sparse_optim.source_row_grads — the O(N) training contract."""
    spec = se.ArenaSpec(2, 20, 4)
    arena = se.init_arena(jax.random.PRNGKey(1), spec)
    idx, off = _ragged_case(np.random.RandomState(3), spec, b=3, max_l=4,
                            pad=3)
    n_bags = off.shape[0] - 1
    w = jnp.asarray(rng.randn(n_bags // spec.n_tables, spec.n_tables,
                              spec.dim), jnp.float32)

    def loss(src):
        return jnp.sum(es.lookup_bags(src, spec, idx, off, max_l=4) * w)

    g = jax.grad(loss)(es.FpArena(arena)).arena     # dense (V, D) scatter

    rows, row_g = source_row_grads(spec, w.reshape(n_bags, spec.dim), idx,
                                   off)
    dense = np.zeros(arena.shape, np.float32)
    for r, gr in zip(np.asarray(rows), np.asarray(row_g)):
        if r != spec.null_row:
            dense[r] += gr
    got = np.asarray(g).copy()
    got[spec.null_row] = 0.0     # row-wise path pins the null row at zero
    np.testing.assert_allclose(got, dense, rtol=1e-5, atol=1e-5)


def test_cached_coherent_flag_semantics(rng):
    """The coherence declaration is a real fork in serving semantics:
    with the default ``coherent=False`` a stale hot copy is SERVED (the
    write-through protocol's observability requirement), while
    ``coherent=True`` licenses serving straight from the arena — fresh
    values, identical op histogram to uncached. Gradients split hot/cold
    the same way under both."""
    spec = se.ArenaSpec(2, 15, 4)
    arena = se.init_arena(jax.random.PRNGKey(3), spec)
    idx, off = _ragged_case(np.random.RandomState(4), spec, b=3, max_l=3)
    counts = se.trace_row_counts(spec, idx, off)
    cache = se.build_hot_cache(arena, spec, counts, k=4)
    # "train" the arena under the cache without patching it
    arena2 = arena.at[:spec.null_row].add(0.5)
    fresh = es.lookup_bags(es.FpArena(arena2), spec, idx, off, max_l=3)
    stale = es.lookup_bags(es.CachedSource(cache, es.FpArena(arena2)),
                           spec, idx, off, max_l=3)
    coh = es.lookup_bags(
        es.CachedSource(cache, es.FpArena(arena2), coherent=True),
        spec, idx, off, max_l=3)
    assert not np.allclose(np.asarray(stale), np.asarray(fresh))
    np.testing.assert_array_equal(np.asarray(coh), np.asarray(fresh))
    for flag in (False, True):
        src = es.CachedSource(cache, es.FpArena(arena2), coherent=flag)
        g = jax.grad(
            lambda s: jnp.sum(es.lookup_bags(s, spec, idx, off, max_l=3)),
            allow_int=True)(src)
        assert np.abs(np.asarray(g.hot.hot_rows)[:-1]).max() > 0
        assert np.abs(np.asarray(g.cold.arena)[:spec.null_row]).max() > 0


def test_grad_through_cached_source_splits_hot_cold(rng):
    """Grads through a CachedSource land on the hot rows AND the cold
    arena leaves — the whole source is differentiable state."""
    spec = se.ArenaSpec(2, 15, 4)
    arena = se.init_arena(jax.random.PRNGKey(5), spec)
    idx, off = _ragged_case(np.random.RandomState(6), spec, b=2, max_l=3)
    counts = se.trace_row_counts(spec, idx, off)
    cache = se.build_hot_cache(arena, spec, counts, k=4)
    src = es.CachedSource(cache, es.FpArena(arena))

    def loss(s):
        return jnp.sum(es.lookup_bags(s, spec, idx, off, max_l=3))

    g = jax.grad(loss, allow_int=True)(src)   # slot_of/hot_ids are int32
    g_hot = np.asarray(g.hot.hot_rows)
    g_cold = np.asarray(g.cold.arena)
    assert np.abs(g_hot[:-1]).max() > 0          # hot rows receive grads
    # hot-slot grads + cold-arena grads partition the uncached arena grad
    # exactly on every REAL row (miss positions park their grads on the
    # zero null slot / null row, which the optimizers never train — the
    # same sentinel contract as the forward)
    g_ref = np.asarray(jax.grad(
        lambda a: jnp.sum(es.lookup_bags(es.FpArena(a), spec, idx, off,
                                         max_l=3)))(arena))
    recomposed = g_cold.copy()
    hot_ids = np.asarray(cache.hot_ids)
    recomposed[hot_ids] += g_hot[:-1]
    real = [r for r in range(spec.null_row)]
    np.testing.assert_allclose(recomposed[real], g_ref[real], rtol=1e-5,
                               atol=1e-6)


# ---------------------------------------------------------------------------
# incremental quantized maintenance
# ---------------------------------------------------------------------------

@settings(deadline=None, max_examples=8)
@given(st.integers(1, 30), st.integers(0, 2**31 - 1))
def test_quantize_rows_patch_exact_vs_full_rebuild(n_touched, seed):
    rng = np.random.RandomState(seed % (2**32 - 1))
    spec = se.ArenaSpec(2, 25, 8)
    arena = se.init_arena(jax.random.PRNGKey(seed % 997), spec, scale=1.0)
    cold_q = es.QuantizedArena.from_arena(arena)
    rows = np.unique(rng.randint(0, spec.null_row, n_touched))
    rows = np.concatenate([rows, rows[:1], [spec.null_row]])  # dup + null
    arena2 = arena.at[jnp.asarray(rows[:-1])].add(
        jnp.asarray(rng.randn(rows.size - 1, spec.dim), jnp.float32))
    arena2 = arena2.at[spec.null_row:].set(0.0)
    patched = cold_q.quantize_rows(arena2, jnp.asarray(rows, jnp.int32))
    full = es.QuantizedArena.from_arena(arena2)
    np.testing.assert_array_equal(np.asarray(patched.q),
                                  np.asarray(full.q))
    np.testing.assert_array_equal(np.asarray(patched.scales),
                                  np.asarray(full.scales))


def test_online_trainer_incremental_quantized_cold():
    """OnlineTrainer(quantize_cold=True): at every rebuild the maintained
    int8 arena equals a from-scratch requantization, touching only the
    dirtied rows."""
    from repro.configs.dlrm import DLRM_SMOKE
    from repro.training import (OnlineCacheConfig, OnlineTrainer,
                                make_drifting_zipf)
    cfg = DLRM_SMOKE
    params = dlrm.init(jax.random.PRNGKey(0), cfg)
    trainer = OnlineTrainer(
        cfg, params, max_l=6, lr=1e-2,
        cache_cfg=OnlineCacheConfig(k=32, refresh_every=4,
                                    quantize_cold=True))
    gen = make_drifting_zipf(cfg, batch_size=8, mean_l=3, max_l=6, seed=7)
    for step in range(8):
        trainer.train_step(next(gen))
        if (step + 1) % 4 == 0:      # a rebuild just ran
            full = es.QuantizedArena.from_arena(trainer.params["arena"])
            np.testing.assert_array_equal(np.asarray(trainer.cold_q.q),
                                          np.asarray(full.q))
            np.testing.assert_array_equal(
                np.asarray(trainer.cold_q.scales),
                np.asarray(full.scales))
            assert not trainer._dirty_q.any()
    trainer.train_step(next(gen))    # a step after the rebuild...
    assert trainer._dirty_q.any()    # ...dirties rows again
    # the serving source carries the maintained int8 cold arena
    src = trainer.serving_source()
    assert isinstance(src, es.CachedSource)
    assert src.cold is trainer.cold_q


# ---------------------------------------------------------------------------
# SourceSpec plans and the no-recompile swap contract
# ---------------------------------------------------------------------------

def test_source_spec_from_path_mappings():
    spec = se.ArenaSpec(2, 10, 4)
    arena = se.init_arena(jax.random.PRNGKey(0), spec)
    assert es.SourceSpec.from_path("ragged").build(arena, spec) \
        == es.FpArena(arena)
    assert es.SourceSpec.from_path("fixed").layout == "fixed"
    cached = es.SourceSpec.from_path("cached", cache_k=4,
                                     quantize_cold=True)
    src = cached.build(arena, spec)
    assert isinstance(src, es.CachedSource)
    assert isinstance(src.cold, es.QuantizedArena)
    assert cached.path_name() == "cached"
    with pytest.raises(ValueError, match="sharded"):
        es.SourceSpec.from_path("sharded", mesh=None)
    with pytest.raises(AssertionError):
        es.SourceSpec.from_path("cached", cache_k=0)


def test_engine_source_swaps_never_recompile():
    """Acceptance: swapping ANY versioned source component (hot cache,
    quantized cold arena, full fp arena) on a live RecEngine hits the
    same compiled executable, and stale versions are rejected."""
    from repro.configs.dlrm import DLRM_SMOKE
    from repro.data import DLRMSynthetic
    from repro.serving import RecEngine, requests_from_ragged_batch
    cfg = DLRM_SMOKE
    spec = dlrm.arena_spec(cfg)
    params = dlrm.init(jax.random.PRNGKey(0), cfg)
    data = DLRMSynthetic(cfg, seed=3)
    rb = data.ragged_batch(4, dist="poisson", mean_l=3, max_l=6)
    counts = se.trace_row_counts(spec, rb["indices"], rb["offsets"])
    eng = RecEngine(cfg, params, source="cached", cache_k=16,
                    quantize_cold=True, cache_trace=counts, max_l=6,
                    max_batch=8, max_wait_ms=0.0, buckets=(4, 8))
    eng.warmup()
    if not hasattr(eng._serve, "_cache_size"):
        pytest.skip("jit cache introspection unavailable")
    compiled = eng._serve._cache_size()

    def serve_round():
        reqs = requests_from_ragged_batch(rb, cfg.n_tables)
        for r in reqs:
            eng.submit(r)
        eng.step(force=True)
        eng.drain()
        return np.asarray([r.prob for r in reqs])

    serve_round()
    old = eng.source
    # 1) hot-cache swap
    eng.update_cache(se.build_hot_cache(params["arena"], spec, counts,
                                        16), version=2)
    serve_round()
    # 2) quantized-cold swap
    new_q = es.QuantizedArena.from_arena(params["arena"])
    eng.update_source(es.CachedSource(eng.source.hot, new_q,
                                      coherent=eng.source.coherent),
                      version=3)
    serve_round()
    # 3) full fp-arena swap (via a rebuilt source of the same structure
    # — incl. the coherence flag, which is pytree structure)
    eng.update_source(es.CachedSource(
        old.hot, es.QuantizedArena(new_q.q, new_q.scales),
        coherent=old.coherent), version=4)
    probs = serve_round()
    assert eng._serve._cache_size() == compiled, "a source swap recompiled"
    assert np.isfinite(probs).all()
    # stale-version rejection still holds after all that
    with pytest.raises(ValueError, match="stale"):
        eng.update_source(eng.source, version=1)
    # structure changes are refused (they would force a recompile)
    with pytest.raises(AssertionError):
        eng.update_source(es.FpArena(params["arena"]), version=9)

    # 4) the FULL FP-ARENA swap on a cached-fp engine (the acceptance
    # case the int8 engine above cannot express: its source holds no
    # fp-arena leaf)
    fp_eng = RecEngine(cfg, params, source="cached", cache_k=16,
                       cache_trace=counts, max_l=6, max_batch=8,
                       max_wait_ms=0.0, buckets=(4, 8))
    fp_eng.warmup()
    compiled_fp = fp_eng._serve._cache_size()
    new_arena = (params["arena"] + 0.125).at[spec.null_row:].set(0.0)
    new_hot = se.build_hot_cache(new_arena, spec, counts, 16)
    fp_eng.update_source(es.CachedSource(new_hot, es.FpArena(new_arena),
                                         coherent=fp_eng.source.coherent),
                         version=2)
    reqs = requests_from_ragged_batch(rb, cfg.n_tables)
    for r in reqs:
        fp_eng.submit(r)
    fp_eng.step(force=True)
    fp_eng.drain()
    assert fp_eng._serve._cache_size() == compiled_fp, \
        "the full fp-arena swap recompiled"
    # and the swap actually took effect: serving matches the new arena
    want = np.asarray(jax.nn.sigmoid(dlrm.forward_ragged(
        dict(params, arena=new_arena), cfg, jnp.asarray(rb["dense"]),
        jnp.asarray(rb["indices"]), jnp.asarray(rb["offsets"]),
        max_l=6)))
    got = np.asarray([r.prob for r in reqs])
    np.testing.assert_allclose(got, want[:len(got)], rtol=1e-4,
                               atol=1e-5)


def test_hit_rate_accounting_per_path():
    """stats()['cache_hit_rate'] is None on non-cached sources and resets
    on version bumps (post-swap rate reflects the live cache only)."""
    from repro.configs.dlrm import DLRM_SMOKE
    from repro.data import DLRMSynthetic
    from repro.serving import RecEngine, requests_from_ragged_batch
    cfg = DLRM_SMOKE
    spec = dlrm.arena_spec(cfg)
    params = dlrm.init(jax.random.PRNGKey(0), cfg)
    data = DLRMSynthetic(cfg, seed=4)
    rb = data.ragged_batch(4, dist="poisson", mean_l=3, max_l=6)

    ragged = RecEngine(cfg, params, source="ragged", max_l=6, max_batch=8,
                       max_wait_ms=0.0)
    for r in requests_from_ragged_batch(rb, cfg.n_tables):
        ragged.submit(r)
    ragged.step(force=True)
    ragged.drain()
    assert ragged.stats()["cache_hit_rate"] is None

    counts = se.trace_row_counts(spec, rb["indices"], rb["offsets"])
    cached = RecEngine(cfg, params, source="cached", cache_k=16,
                       cache_trace=counts, max_l=6, max_batch=8,
                       max_wait_ms=0.0)
    for r in requests_from_ragged_batch(rb, cfg.n_tables):
        cached.submit(r)
    cached.step(force=True)
    cached.drain()
    assert cached.stats()["cache_hit_rate"] > 0
    assert cached._lookups > 0
    cached.update_cache(se.build_hot_cache(params["arena"], spec, counts,
                                           16), version=5)
    assert cached._lookups == 0          # bump resets the counters
    assert cached.stats()["cache_hit_rate"] is None   # no post-swap data
    # republish at the SAME version (write-through) keeps the counters
    for r in requests_from_ragged_batch(rb, cfg.n_tables):
        cached.submit(r)
    cached.step(force=True)
    cached.drain()
    n = cached._lookups
    cached.update_cache(cached.cache, version=5)
    assert cached._lookups == n


# ---------------------------------------------------------------------------
# VersionedSource artifact
# ---------------------------------------------------------------------------

def test_versioned_source_roundtrip_every_composition(rng):
    spec = se.ArenaSpec(2, 12, 4)
    arena = se.init_arena(jax.random.PRNGKey(0), spec)
    counts = np.ones(spec.total_rows)
    cache = se.build_hot_cache(arena, spec, counts, 4)
    q = es.QuantizedArena.from_arena(arena)
    for src in (es.FpArena(arena), q,
                es.CachedSource(cache, es.FpArena(arena)),
                es.CachedSource(cache, q)):
        blob = es.VersionedSource(src, 7).serialize()
        back = es.VersionedSource.deserialize(blob)
        assert back.version == 7
        assert type(back.source) is type(src)
        for a, b in zip(jax.tree_util.tree_leaves(src),
                        jax.tree_util.tree_leaves(back.source)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    with pytest.raises(ValueError, match="artifact"):
        es.VersionedSource.deserialize(b"junk")


def test_versioned_source_apply_order_free():
    from repro.configs.dlrm import DLRM_SMOKE
    from repro.serving import RecEngine
    cfg = DLRM_SMOKE
    spec = dlrm.arena_spec(cfg)
    params = dlrm.init(jax.random.PRNGKey(0), cfg)
    counts = np.ones(spec.total_rows)
    eng = RecEngine(cfg, params, source="cached", cache_k=8,
                    cache_trace=counts, max_l=6, max_batch=4)
    art = es.VersionedSource(eng.source, 3)
    blob = art.serialize()
    back = es.VersionedSource.deserialize(blob)
    assert back.apply(eng) and eng.source_version == 3
    assert not back.apply(eng)                   # idempotent re-delivery
    stale = es.VersionedSource(eng.source, 1)
    assert not stale.apply(eng)                  # reordered: absorbed
    assert eng.source_version == 3


# ---------------------------------------------------------------------------
# deprecation shims: value-preserving, and they warn
# ---------------------------------------------------------------------------

def test_legacy_lookup_shims_warn_and_agree(rng):
    spec = se.ArenaSpec(3, 30, 8)
    arena = se.init_arena(jax.random.PRNGKey(4), spec, scale=1.0)
    q, scales = se.quantize_arena(arena)
    idx_f = jnp.asarray(rng.randint(0, 30, (2, 3, 4)), jnp.int32)
    idx, off = _ragged_case(np.random.RandomState(8), spec, b=2, max_l=4,
                            pad=3)
    counts = se.trace_row_counts(spec, idx, off)
    cache = se.build_hot_cache(arena, spec, counts, 8)
    fp = es.FpArena(arena)
    qa = es.QuantizedArena(q, scales)

    cases = [
        (lambda: se.lookup(arena, spec, idx_f),
         lambda: es.lookup_fixed(fp, spec, idx_f)),
        (lambda: se.lookup_auto(arena, spec, idx_f),
         lambda: es.lookup_fixed(fp, spec, idx_f)),
        (lambda: se.lookup_quantized(q, scales, spec, idx_f),
         lambda: es.lookup_fixed(qa, spec, idx_f)),
        (lambda: se.lookup_ragged(arena, spec, idx, off, max_l=4),
         lambda: es.lookup_bags(fp, spec, idx, off, max_l=4)),
        (lambda: se.lookup_ragged_auto(arena, spec, idx, off, max_l=4),
         lambda: es.lookup_bags(fp, spec, idx, off, max_l=4)),
        (lambda: se.lookup_ragged_quantized(q, scales, spec, idx, off),
         lambda: es.lookup_bags(qa, spec, idx, off, max_l=4)),
        (lambda: se.lookup_ragged_cached(cache, arena, spec, idx, off,
                                         max_l=4),
         lambda: es.lookup_bags(es.CachedSource(cache, fp), spec, idx,
                                off, max_l=4)),
        (lambda: se.lookup_ragged_cached_q(cache, q, scales, spec, idx,
                                           off, max_l=4),
         lambda: es.lookup_bags(es.CachedSource(cache, qa), spec, idx,
                                off, max_l=4)),
    ]
    for legacy, modern in cases:
        with pytest.warns(DeprecationWarning, match="deprecated"):
            old = np.asarray(legacy())
        np.testing.assert_array_equal(old, np.asarray(modern()))

    # the shard-local shims (must run under a named axis, padded arena)
    shards = 2
    arena = se.init_arena(jax.random.PRNGKey(4), spec, shards, scale=1.0)
    fp = es.FpArena(arena)
    view = arena.reshape(shards, -1, spec.dim)
    with pytest.warns(DeprecationWarning, match="deprecated"):
        outs = jax.vmap(lambda a: se.lookup_ragged_sharded(
            a, spec, idx, off, "x"), axis_name="x")(view)
    want = np.asarray(es.lookup_bags(fp, spec, idx, off, max_l=4))
    for s in range(shards):
        np.testing.assert_allclose(np.asarray(outs[s]), want, rtol=1e-5,
                                   atol=1e-5)
    with pytest.warns(DeprecationWarning, match="deprecated"):
        outs = jax.vmap(lambda a: se.lookup_sharded(a, spec, idx_f, "x"),
                        axis_name="x")(view)
    want = np.asarray(es.lookup_fixed(fp, spec, idx_f))
    for s in range(shards):
        np.testing.assert_allclose(np.asarray(outs[s]), want, rtol=1e-5,
                                   atol=1e-5)


def test_engine_and_dlrm_deprecated_kwargs_warn():
    from repro.configs.dlrm import DLRM_SMOKE
    from repro.data import DLRMSynthetic
    from repro.serving import RecEngine
    cfg = DLRM_SMOKE
    spec = dlrm.arena_spec(cfg)
    params = dlrm.init(jax.random.PRNGKey(0), cfg)
    data = DLRMSynthetic(cfg, seed=1)
    rb = data.ragged_batch(2, dist="poisson", mean_l=2, max_l=4)
    counts = se.trace_row_counts(spec, rb["indices"], rb["offsets"])
    cache = se.build_hot_cache(params["arena"], spec, counts, 8)
    args = (jnp.asarray(rb["dense"]), jnp.asarray(rb["indices"]),
            jnp.asarray(rb["offsets"]))
    with pytest.warns(DeprecationWarning, match="source="):
        old = dlrm.forward_ragged(params, cfg, *args, max_l=4,
                                  cache=cache)
    new = dlrm.forward_ragged(
        params, cfg, *args, max_l=4,
        source=es.CachedSource(cache, es.FpArena(params["arena"])))
    np.testing.assert_array_equal(np.asarray(old), np.asarray(new))
    with pytest.warns(DeprecationWarning, match="path"):
        RecEngine(cfg, params, path="ragged", max_l=4, max_batch=4)
    # conflicting source= + deprecated kwargs must be loud, not silent
    with pytest.raises(ValueError, match="BOTH"):
        dlrm.forward_ragged(params, cfg, *args, max_l=4,
                            source=es.FpArena(params["arena"]),
                            cache=cache)
    # make_ragged_serve_step back-compat: build-time cache= kwarg, and a
    # bare HotRowCache as the per-call third argument, both warn and
    # serve exactly what the equivalent CachedSource serves
    batch = {"dense": args[0], "indices": args[1], "offsets": args[2]}
    want = np.asarray(jax.nn.sigmoid(new))
    with pytest.warns(DeprecationWarning, match="cache="):
        legacy_step = dlrm.make_ragged_serve_step(cfg, max_l=4,
                                                  cache=cache)
    with pytest.warns(DeprecationWarning):     # _legacy_source at trace
        got = np.asarray(legacy_step(params, batch))
    np.testing.assert_array_equal(got, want)
    step = dlrm.make_ragged_serve_step(cfg, max_l=4)
    with pytest.warns(DeprecationWarning, match="HotRowCache"):
        got = np.asarray(step(params, batch, cache))
    np.testing.assert_array_equal(got, want)
    # a per-call bare-HotRowCache swap must keep the build-time int8
    # cold arena (the legacy cached_q contract), not degrade to fp
    q, scales = se.quantize_arena(params["arena"])
    with pytest.warns(DeprecationWarning):
        q_step = dlrm.make_ragged_serve_step(cfg, max_l=4, cache=cache,
                                             quantized=(q, scales))
        base = np.asarray(q_step(params, batch))
        swapped = np.asarray(q_step(params, batch, cache))
    np.testing.assert_array_equal(base, swapped)
    # SourceSpec string shorthands refuse silently-dropped cache config
    with pytest.raises(AssertionError, match="cached"):
        es.SourceSpec.from_path("ragged", cache_k=64)
    # fixed layout cannot consume cached/quantized sources (it serves
    # through the legacy fixed-L step) — refused at plan time, and a
    # fixed engine refuses source swaps it would never serve
    with pytest.raises(ValueError, match="fixed"):
        es.SourceSpec(layout="fixed", cache_k=8)
    fixed_eng = RecEngine(cfg, params, source="fixed", max_batch=4)
    with pytest.raises(AssertionError):
        fixed_eng.update_source(es.FpArena(params["arena"]), version=1)


# ---------------------------------------------------------------------------
# the REAL shard_map entry point (subprocess, fake host devices)
# ---------------------------------------------------------------------------

def _run_with_devices(code: str, n: int = 8, timeout: int = 480) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
    env["PYTHONPATH"] = SRC
    env.setdefault("JAX_PLATFORMS", "cpu")
    prelude = textwrap.dedent("""
        import json, jax, jax.numpy as jnp, numpy as np
        from repro.core import embedding_source as es
        from repro.core import sparse_engine as se
        from repro.launch.mesh import make_mesh
    """)
    out = subprocess.run([sys.executable, "-c", prelude + code],
                         capture_output=True, text=True, env=env,
                         timeout=timeout)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_sharded_source_matches_replicated_shard_map():
    """ShardedArena (fp and int8, bare and as a cached cold pass) on
    2/4/8-way meshes through the real shard_map == replicated."""
    r = _run_with_devices("""
spec = se.ArenaSpec(3, 37, 8)
rng = np.random.RandomState(0)
errs = {}
for shards in (2, 4, 8):
    mesh = make_mesh((shards,), ("model",))
    arena = se.init_arena(jax.random.PRNGKey(0), spec, shards, scale=1.0)
    lens = rng.randint(0, 5, 9).astype(np.int32)
    off = np.zeros(10, np.int32); off[1:] = np.cumsum(lens)
    idx = jnp.asarray(rng.randint(0, 37, int(off[-1]) + 4), jnp.int32)
    off = jnp.asarray(off)
    fp = es.FpArena(arena)
    q = es.QuantizedArena.from_arena(arena)
    counts = se.trace_row_counts(spec, idx, off)
    cache = se.build_hot_cache(arena, spec, counts, 8)
    want = es.lookup_bags(fp, spec, idx, off, max_l=4)
    want_q = es.lookup_bags(q, spec, idx, off, max_l=4)
    want_c = es.lookup_bags(es.CachedSource(cache, fp), spec, idx, off,
                            max_l=4)
    sh_fp = es.ShardedArena(fp, mesh)
    sh_q = es.ShardedArena(q, mesh)
    got = jax.jit(lambda i, o: es.lookup_bags(sh_fp, spec, i, o,
                                              max_l=4))(idx, off)
    got_q = jax.jit(lambda i, o: es.lookup_bags(sh_q, spec, i, o,
                                                max_l=4))(idx, off)
    got_c = jax.jit(lambda i, o: es.lookup_bags(
        es.CachedSource(cache, sh_fp), spec, i, o, max_l=4))(idx, off)
    errs[shards] = [float(jnp.abs(got - want).max()),
                    float(jnp.abs(got_q - want_q).max()),
                    float(jnp.abs(got_c - want_c).max())]
print(json.dumps({str(k): v for k, v in errs.items()}))
""")
    for shards, (e_fp, e_q, e_c) in r.items():
        assert e_fp < 1e-5, (shards, e_fp)
        assert e_q < 1e-5, (shards, e_q)
        assert e_c < 1e-5, (shards, e_c)
