"""Core-engine tests: sparse engine, dense engine, DLRM, hybrid executor."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs.dlrm import DLRM_CONFIGS, DLRM_SMOKE
from repro.core import dense_engine as de
from repro.core import dlrm, hybrid
from repro.core import embedding_source as es
from repro.core import sparse_engine as se


@pytest.fixture
def dlrm_setup(rng):
    cfg = DLRM_SMOKE
    params = dlrm.init(jax.random.PRNGKey(0), cfg)
    b = 16
    batch = {
        "dense": jnp.asarray(rng.randn(b, cfg.dense_features), jnp.float32),
        "indices": jnp.asarray(
            rng.randint(0, cfg.rows_per_table,
                        (b, cfg.n_tables, cfg.lookups_per_table)), jnp.int32),
        "labels": jnp.asarray(rng.randint(0, 2, (b,)), jnp.float32),
    }
    return cfg, params, batch


def test_arena_null_row_is_zero():
    spec = se.ArenaSpec(3, 100, 8)
    arena = se.init_arena(jax.random.PRNGKey(0), spec)
    assert np.allclose(np.asarray(arena)[spec.null_row:], 0.0)


def test_arena_flatten_indices_base_offsets(rng):
    spec = se.ArenaSpec(4, 50, 8)
    idx = rng.randint(0, 50, (2, 4, 3)).astype(np.int32)
    flat = np.asarray(se.flatten_indices(spec, jnp.asarray(idx)))
    assert flat.shape == (8, 3)
    # table t's rows live at [t*50, (t+1)*50)
    for b in range(2):
        for t in range(4):
            row = flat[b * 4 + t]
            assert ((row >= t * 50) & (row < (t + 1) * 50)).all()


def test_lookup_matches_manual(rng):
    spec = se.ArenaSpec(2, 30, 4)
    arena = se.init_arena(jax.random.PRNGKey(1), spec)
    idx = jnp.asarray(rng.randint(0, 30, (3, 2, 5)), jnp.int32)
    out = es.lookup_fixed(es.FpArena(arena), spec, idx)
    a = np.asarray(arena)
    for b in range(3):
        for t in range(2):
            want = a[np.asarray(idx)[b, t] + t * 30].sum(0)
            np.testing.assert_allclose(out[b, t], want, rtol=1e-5)


def test_dlrm_forward_baseline_pipelined_agree(dlrm_setup):
    cfg, params, batch = dlrm_setup
    f = dlrm.forward(params, cfg, batch["dense"], batch["indices"])
    b = hybrid.baseline_forward(params, cfg, batch["dense"],
                                batch["indices"])
    p = hybrid.pipelined_forward(params, cfg, batch["dense"],
                                 batch["indices"], n_micro=4)
    np.testing.assert_allclose(f, b, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(f, p, rtol=1e-4, atol=1e-4)


def test_dlrm_training_reduces_loss(dlrm_setup):
    cfg, params, batch = dlrm_setup
    opt, step = dlrm.make_train_step(cfg)
    state = opt.init(params)
    step = jax.jit(step)
    losses = []
    for _ in range(20):
        params, state, loss = step(params, state, batch)
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.7, losses
    assert not np.isnan(losses[-1])


def test_dlrm_all_six_table1_configs_instantiate():
    """Paper Table I: every config's arena matches the stated table size."""
    for name, cfg in DLRM_CONFIGS.items():
        expected = {"dlrm1": 128, "dlrm2": 1280, "dlrm3": 128,
                    "dlrm4": 1280, "dlrm5": 3200, "dlrm6": 128}[name]
        assert abs(cfg.table_bytes / 1e6 - expected) / expected < 0.01, name
        # smoke-scale instantiation of the same topology
        small = cfg.__class__(name=name, n_tables=cfg.n_tables,
                              rows_per_table=64,
                              lookups_per_table=cfg.lookups_per_table,
                              bottom_mlp=cfg.bottom_mlp,
                              top_mlp=cfg.top_mlp)
        params = dlrm.init(jax.random.PRNGKey(0), small)
        logit = dlrm.forward(
            params, small,
            jnp.zeros((2, small.dense_features), jnp.float32),
            jnp.zeros((2, small.n_tables, small.lookups_per_table),
                      jnp.int32))
        assert logit.shape == (2,)
        assert not np.isnan(np.asarray(logit)).any()


def test_mlp_engine_matches_reference(rng):
    params = de.init_mlp(jax.random.PRNGKey(0), (16, 32, 8))
    x = jnp.asarray(rng.randn(4, 16), jnp.float32)
    out = de.mlp_apply(params, x)
    h = np.maximum(np.asarray(x) @ np.asarray(params[0][0])
                   + np.asarray(params[0][1]), 0)
    want = h @ np.asarray(params[1][0]) + np.asarray(params[1][1])
    np.testing.assert_allclose(out, want, rtol=1e-4, atol=1e-5)


@settings(deadline=None, max_examples=10)
@given(st.integers(0, 3), st.integers(0, 2**31 - 1))
def test_dlrm_pipelined_equivalence_property(n_micro_pow, seed):
    """Property: the microbatch pipeline computes the same probabilities as
    single-shot execution for any microbatch count."""
    r = np.random.RandomState(seed % (2**32 - 1))
    cfg = DLRM_SMOKE
    params = dlrm.init(jax.random.PRNGKey(seed % 1000), cfg)
    b = 8
    dense = jnp.asarray(r.randn(b, cfg.dense_features), jnp.float32)
    idx = jnp.asarray(r.randint(0, cfg.rows_per_table,
                                (b, cfg.n_tables, cfg.lookups_per_table)),
                      jnp.int32)
    f = dlrm.forward(params, cfg, dense, idx)
    p = hybrid.pipelined_forward(params, cfg, dense, idx,
                                 n_micro=2 ** n_micro_pow)
    np.testing.assert_allclose(f, p, rtol=1e-3, atol=1e-3)


def test_pipelined_tail_dummy_is_null_and_equivalent(dlrm_setup):
    """The last microbatch's prefetch stream is all-null-row indices (a
    zero-cost dummy, not a wasted wrap-around gather of microbatch 0), and
    the pipeline output still equals single-shot execution exactly."""
    cfg, params, batch = dlrm_setup
    spec = dlrm.arena_spec(cfg)
    dummy = se.null_indices(spec, (4, spec.n_tables, 3))
    flat = np.asarray(se.flatten_indices(spec, dummy))
    assert (flat == spec.null_row).all()
    # the null row gathers to exactly zero
    out = es.lookup_fixed(es.FpArena(params["arena"]), spec, dummy)
    assert float(jnp.abs(out).max()) == 0.0
    f = dlrm.forward(params, cfg, batch["dense"], batch["indices"])
    for n_micro in (1, 2, 4, 8):
        p = hybrid.pipelined_forward(params, cfg, batch["dense"],
                                     batch["indices"], n_micro=n_micro)
        np.testing.assert_allclose(f, p, rtol=1e-4, atol=1e-4)


def test_quantized_arena_lookup_error_bound(rng):
    """int8 arena: 3.9x capacity, bounded dequantization error."""
    spec = se.ArenaSpec(2, 50, 16)
    arena = se.init_arena(jax.random.PRNGKey(0), spec, scale=1.0)
    q, scales = se.quantize_arena(arena)
    assert q.dtype == jnp.int8
    idx = jnp.asarray(rng.randint(0, 50, (4, 2, 6)), jnp.int32)
    exact = es.lookup_fixed(es.FpArena(arena), spec, idx)
    approx = es.lookup_fixed(es.QuantizedArena(q, scales), spec, idx)
    # error <= L * max_row_scale per component
    bound = 6 * float(scales.max()) + 1e-6
    assert float(jnp.abs(exact - approx).max()) <= bound
    # null row stays inert
    assert float(jnp.abs(q[spec.null_row:]).max()) == 0.0


def test_ragged_kernel_matches_ref(rng):
    from repro.kernels import embedding_gather as eg
    from repro.kernels import ref as kref
    table = jnp.asarray(rng.randn(80, 8), jnp.float32)
    indices = jnp.asarray(rng.randint(0, 80, (11,)), jnp.int32)
    offsets = jnp.asarray([0, 2, 2, 5, 11], jnp.int32)
    got = eg.sparse_lengths_sum(table, indices, offsets, max_l=8,
                                interpret=True)
    want = kref.sparse_lengths_sum(table, indices, offsets)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
