"""Substrate tests: optimizers, checkpointing, fault tolerance, compression,
data pipeline, serving engine."""
import json
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro import optim
from repro.checkpoint import CheckpointManager
from repro.configs.dlrm import DLRM_SMOKE
from repro.core import dlrm
from repro.data import DLRMSynthetic, LMSynthetic, Prefetcher
from repro.distributed import compression
from repro.distributed.fault_tolerance import (ResilientTrainer,
                                               SimulatedFailure,
                                               StragglerMonitor)


# ---------------------------------------------------------------------------
# Optimizers
# ---------------------------------------------------------------------------

def _quad_problem():
    params = {"w": jnp.asarray([3.0, -2.0, 1.5])}

    def loss(p):
        return jnp.sum(jnp.square(p["w"] - 1.0))
    return params, loss


@pytest.mark.parametrize("make", [
    lambda: optim.sgd(0.05), lambda: optim.adamw(0.1, weight_decay=0.0),
    lambda: optim.adafactor(0.5), lambda: optim.rowwise_adagrad(0.5)])
def test_optimizers_converge_on_quadratic(make):
    params, loss = _quad_problem()
    opt = make()
    state = opt.init(params)
    for _ in range(150):
        g = jax.grad(loss)(params)
        params, state = opt.update(g, state, params)
    assert float(loss(params)) < 0.05


def test_adamw_matches_manual_first_step():
    opt = optim.adamw(0.1, b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.0)
    params = {"w": jnp.asarray([2.0])}
    state = opt.init(params)
    g = {"w": jnp.asarray([0.5])}
    new_params, _ = opt.update(g, state, params)
    # bias-corrected first step = lr * g/|g| (approximately sign step)
    np.testing.assert_allclose(np.asarray(new_params["w"]),
                               [2.0 - 0.1 * 0.5 / (0.5 + 1e-8)], rtol=1e-4)


def test_rowwise_adagrad_state_is_per_row():
    opt = optim.rowwise_adagrad(0.1)
    params = {"table": jnp.ones((10, 4))}
    state = opt.init(params)
    assert state["acc"]["table"].shape == (10, 1)


def test_clip_by_global_norm():
    g = {"a": jnp.full((3,), 10.0)}
    clipped, norm = optim.clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(np.sqrt(300.0), rel=1e-5)
    assert float(optim.global_norm(clipped)) == pytest.approx(1.0, rel=1e-4)


def test_warmup_cosine_schedule():
    s = optim.warmup_cosine(1.0, 10, 100)
    assert float(s(0)) == pytest.approx(0.0)
    assert float(s(10)) == pytest.approx(1.0, rel=1e-3)
    assert float(s(100)) == pytest.approx(0.1, rel=1e-2)


def test_partitioned_optimizer_routes_by_key():
    opt = optim.partitioned({"arena": optim.rowwise_adagrad(0.1)},
                            optim.adamw(0.1))
    params = {"arena": jnp.ones((4, 2)), "mlp": jnp.ones((3,))}
    state = opt.init(params)
    assert "acc" in state["arena"] and "m" in state["mlp"]


def test_state_logical_specs_match_state_structure():
    params = {"w": jnp.ones((4, 8)), "b": jnp.ones((8,))}
    specs = {"w": (None, "model"), "b": (None,)}
    shapes = {"w": (4, 8), "b": (8,)}
    for name, make in [("adamw", lambda: optim.adamw(0.1)),
                       ("sgd", lambda: optim.sgd(0.1)),
                       ("adafactor", lambda: optim.adafactor(0.1)),
                       ("rowwise_adagrad",
                        lambda: optim.rowwise_adagrad(0.1))]:
        st_specs = optim.optimizers.state_logical_specs(name, specs, shapes)
        state = make().init(params)
        # same tree structure (specs tree leaves are tuples)
        jax.tree_util.tree_map(
            lambda leaf, spec: None, state, st_specs,
            is_leaf=lambda x: isinstance(x, tuple) and not x
            or isinstance(x, tuple) and all(
                v is None or isinstance(v, str) for v in x))


# ---------------------------------------------------------------------------
# Checkpointing
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(tmp_path, keep_n=2)
    state = {"w": jnp.arange(6.0).reshape(2, 3),
             "opt": [jnp.ones((3,)), jnp.zeros((1,))]}
    mgr.save(5, state, meta={"note": "x"})
    restored, manifest = mgr.restore(state)
    assert manifest["step"] == 5
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(state["w"]))


def test_checkpoint_keep_n_and_latest(tmp_path):
    mgr = CheckpointManager(tmp_path, keep_n=2)
    state = {"w": jnp.zeros((2,))}
    for s in (1, 2, 3, 4):
        mgr.save(s, state)
    assert mgr.steps() == [3, 4]
    assert mgr.latest_step() == 4


def test_checkpoint_async_and_atomicity(tmp_path):
    mgr = CheckpointManager(tmp_path, keep_n=3)
    state = {"w": jnp.ones((128, 128))}
    mgr.save_async(7, state)
    mgr.wait()
    assert mgr.latest_step() == 7
    # no tmp dirs left behind
    assert not list(tmp_path.glob("tmp.*"))


def test_checkpoint_structure_mismatch_raises(tmp_path):
    mgr = CheckpointManager(tmp_path)
    mgr.save(0, {"w": jnp.zeros((2,))})
    with pytest.raises(ValueError):
        mgr.restore({"w": jnp.zeros((2,)), "extra": jnp.zeros((1,))})


# ---------------------------------------------------------------------------
# Fault tolerance
# ---------------------------------------------------------------------------

def test_resilient_trainer_restores_after_failure(tmp_path):
    cfg = DLRM_SMOKE
    params = dlrm.init(jax.random.PRNGKey(0), cfg)
    opt, step_fn = dlrm.make_train_step(cfg)
    opt_state = opt.init(params)
    step_jit = jax.jit(step_fn)

    def wrapped(p, s, batch):
        p2, s2, loss = step_jit(p, s, batch)
        return p2, s2, {"loss": loss}

    data = DLRMSynthetic(cfg, seed=0)

    def batch_fn(step):
        b = DLRMSynthetic(cfg, seed=step).batch(8)
        return {k: jnp.asarray(v) for k, v in b.items()}

    ckpt = CheckpointManager(tmp_path, keep_n=2)
    trainer = ResilientTrainer(wrapped, ckpt, ckpt_every=5, max_restarts=2)
    state, metrics = trainer.run((params, opt_state), batch_fn,
                                 total_steps=20, fail_at=12)
    assert trainer.restarts == 1
    assert ckpt.latest_step() is not None
    assert not np.isnan(float(metrics["loss"]))


def test_straggler_monitor_flags_slow_step():
    mon = StragglerMonitor(threshold=2.0)
    for i in range(10):
        mon.record(i, 0.1)
    assert mon.record(10, 0.5) is True
    assert len(mon.events) == 1


# ---------------------------------------------------------------------------
# Gradient compression
# ---------------------------------------------------------------------------

def test_int8_quantization_error_bound(rng):
    x = jnp.asarray(rng.randn(64, 128), jnp.float32)
    q, s = compression.quantize_int8(x)
    deq = compression.dequantize_int8(q, s)
    amax = np.abs(np.asarray(x)).max(-1, keepdims=True)
    assert float(jnp.abs(deq - x).max()) <= float(amax.max()) / 127 + 1e-6


@settings(deadline=None, max_examples=10)
@given(st.integers(0, 2**31 - 1))
def test_error_feedback_preserves_long_run_average(seed):
    """Property: with error feedback, the cumulative decompressed gradient
    tracks the cumulative true gradient (residual stays bounded)."""
    r = np.random.RandomState(seed % (2**32 - 1))
    g_true = jnp.asarray(r.randn(16), jnp.float32)
    grads = {"w": g_true}
    err = compression.init_error_feedback(grads)
    total = np.zeros(16)
    for _ in range(50):
        deq, err = compression.compress_grads(grads, err)
        total += np.asarray(deq["w"])
    resid = np.abs(total - 50 * np.asarray(g_true)).max()
    scale = float(np.abs(np.asarray(g_true)).max()) / 127
    assert resid <= 2 * scale + 1e-5


def test_wire_bytes_accounting():
    params = {"w": jnp.zeros((1000,))}
    assert compression.wire_bytes(params, "f32") == 4000
    assert compression.wire_bytes(params, "bf16") == 2000
    assert compression.wire_bytes(params, "int8") == 1000


# ---------------------------------------------------------------------------
# Data pipeline
# ---------------------------------------------------------------------------

def test_dlrm_synthetic_deterministic_and_in_range():
    cfg = DLRM_SMOKE
    a = DLRMSynthetic(cfg, seed=3).batch(16)
    b = DLRMSynthetic(cfg, seed=3).batch(16)
    np.testing.assert_array_equal(a["indices"], b["indices"])
    assert a["indices"].min() >= 0
    assert a["indices"].max() < cfg.rows_per_table
    assert set(np.unique(a["labels"])) <= {0.0, 1.0}


def test_lm_synthetic_shapes():
    from repro.configs.registry import SMOKE_ARCHS
    cfg = SMOKE_ARCHS["internvl2-2b"]
    b = LMSynthetic(cfg, seed=0).batch(2, 16)
    assert b["tokens"].shape == (2, 16 - cfg.n_frontend_tokens)
    assert b["patches"].shape == (2, cfg.n_frontend_tokens, cfg.d_model)


def test_prefetcher_delivers_in_order_and_closes():
    def gen():
        for i in range(5):
            yield {"x": np.full((2,), i)}
    pf = Prefetcher(gen(), depth=2)
    got = [int(b["x"][0]) for b in pf]
    assert got == [0, 1, 2, 3, 4]
    pf.close()


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------

def test_decode_engine_wave_completes_requests(rng):
    from repro.configs.registry import SMOKE_ARCHS
    from repro.models import api
    from repro.serving import Batcher, DecodeEngine, Request
    cfg = SMOKE_ARCHS["smollm-360m"]
    params, _ = api.init(jax.random.PRNGKey(0), cfg)
    engine = DecodeEngine(cfg, params, n_slots=2, max_len=32)
    batcher = Batcher(max_batch=2, max_wait_ms=0.0)
    for rid in range(4):
        batcher.submit(Request(
            rid=rid, prompt=rng.randint(0, cfg.vocab_size, (4,))
            .astype(np.int32), max_new_tokens=3))
    for _ in range(100):
        if len(engine.latencies) >= 4:
            break
        if engine.idle():
            engine.admit(batcher.take())
        engine.step()
    assert len(engine.latencies) == 4
    stats = engine.stats()
    assert stats["n"] == 4 and stats["p99_ms"] >= stats["p50_ms"]


def test_layerwise_optimizer_equivalence_and_stack_detection():
    """layerwise(opt) must (a) be bit-equivalent to opt, (b) scan only
    multi-leaf layer stacks — NOT single big arrays like a (152k, d) vocab
    table (regression: that was scanned row-by-row, a 151936-trip loop)."""
    params = {"layers": {"w1": jnp.ones((12, 4, 4)), "w2": jnp.ones((12, 4))},
              "embed": jnp.ones((1000, 8))}
    grads = jax.tree_util.tree_map(lambda p: 0.1 * jnp.ones_like(p), params)
    o1, o2 = optim.adamw(0.1), optim.layerwise(optim.adamw(0.1))
    s1, s2 = o1.init(params), o2.init(params)
    p1, p2 = params, params
    for _ in range(3):
        p1, s1 = o1.update(grads, s1, p1)
        p2, s2 = o2.update(grads, s2, p2)
    d = jax.tree_util.tree_map(
        lambda a, b: float(jnp.abs(a - b).max()), p1, p2)
    assert max(jax.tree_util.tree_leaves(d)) < 1e-5
    # stack detection: the embed subtree (single leaf) must NOT be scanned —
    # verify via HLO: no while loop with a ~1000 trip count
    import re
    txt = jax.jit(o2.update).lower(grads, s2, p2).as_text()
    consts = [int(c) for c in re.findall(r"constant\((\d+)\)", txt)]
    assert 12 in consts or not consts    # layer scan ok
    assert 1000 not in consts, "embed table scanned row-wise!"
