"""Property suite for heterogeneous per-table sources (TableGroupSource).

THE composition law this file pins: a ``TableGroupSource`` lookup — and
its gradient — is bit-for-bit the per-table loop of its members' own
lookups, for every mix of member kinds (fp / int8 / hot-cached / cached
over int8), heterogeneous vocabs and dims (including vocab 1 and dim 1),
and shard counts {1, 2, 4} (real shard_map in a subprocess with fake host
devices). Also locked down here: the degenerate table-group shapes, the
group plan (``SourceSpec.tables``), per-table hit-rate accounting in
``RecEngine.stats()``, stale-version rejection of a single-member swap,
the group broadcast artifact, and the heterogeneous train step.
"""
import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs.dlrm import DLRM_HET_SMOKE
from repro.core import dlrm
from repro.core import embedding_source as es
from repro.core import sparse_engine as se
from repro.data import DLRMSynthetic
from repro.training import group_row_grads

SRC = str(Path(__file__).resolve().parents[1] / "src")

# (vocabs, dims) inventories exercising the hard shapes: a vocab-1 table,
# a dim-1 table, a single-table group, wildly uneven sizes
INVENTORIES = (
    ((40, 7, 1), (8, 4, 1)),
    ((1, 300, 12), (1, 16, 8)),
    ((25,), (8,)),                    # single-table group
    ((13, 13, 13, 13), (4, 8, 16, 2)),
)
# member-kind assignment patterns, cycled over the group's tables
KIND_PATTERNS = (("fp",), ("int8", "fp"), ("cached", "fp", "int8"),
                 ("cached_int8", "cached", "fp"))


def _specs_of(vocabs, dims):
    return tuple(se.ArenaSpec(1, v, d) for v, d in zip(vocabs, dims))


def _het_case(rng, vocabs, b, max_l, pad=0):
    """Interleaved (sample, table) ragged batch with the hard edges in:
    an empty bag, a full bag, an ALL-EMPTY table (every bag of table 0
    empty) while another table is dense, a duplicate index, and a padded
    tail."""
    t_count = len(vocabs)
    n_bags = b * t_count
    lens = rng.randint(0, max_l + 1, n_bags).astype(np.int32)
    if t_count > 1:
        lens[0::t_count] = 0                   # table 0: all bags empty
        lens[1::t_count] = max_l               # another table: dense
    else:
        lens[0], lens[-1] = 0, max_l           # an empty and a full bag
    off = np.zeros(n_bags + 1, np.int32)
    np.cumsum(lens, out=off[1:])
    n = int(off[-1])
    seg = np.searchsorted(off[1:], np.arange(n), side="right")
    table = seg % t_count
    idx = np.empty(n, np.int32)
    for t in range(t_count):
        m = table == t
        idx[m] = rng.randint(0, vocabs[t], int(m.sum()))
    if n >= 2:
        idx[n - 1] = idx[n - 2] if table[n - 1] == table[n - 2] else idx[n - 1]
    idx = np.concatenate([idx, np.zeros(pad, np.int32)])
    return idx, off


def _member(kind, arena, sp, rng):
    if kind == "fp":
        return es.FpArena(arena)
    if kind == "int8":
        return es.QuantizedArena.from_arena(arena)
    counts = rng.rand(sp.total_rows)
    hot = se.build_hot_cache(arena, sp, counts, k=min(4, sp.rows_per_table))
    cold = (es.QuantizedArena.from_arena(arena) if kind == "cached_int8"
            else es.FpArena(arena))
    return es.CachedSource(hot=hot, cold=cold)


def _mixed_group(vocabs, dims, kinds, seed):
    specs = _specs_of(vocabs, dims)
    arenas = [se.init_arena(jax.random.PRNGKey(seed + t), sp, scale=1.0)
              for t, sp in enumerate(specs)]
    rng = np.random.RandomState(seed)
    members = tuple(_member(kinds[t % len(kinds)], a, sp, rng)
                    for t, (a, sp) in enumerate(zip(arenas, specs)))
    return es.TableGroupSource(members=members, specs=specs)


def _streams(idx, off, t_count):
    batch = {"indices": idx, "offsets": off}
    idx_t, off_t = DLRMSynthetic.ragged_per_table(batch, t_count)
    return (tuple(jnp.asarray(i) for i in idx_t),
            tuple(jnp.asarray(o) for o in off_t))


# ---------------------------------------------------------------------------
# the tentpole law: grouped dispatch == per-table loop, bit for bit
# ---------------------------------------------------------------------------

@settings(deadline=None, max_examples=12)
@given(st.sampled_from(INVENTORIES), st.sampled_from(KIND_PATTERNS),
       st.integers(0, 2**31 - 1))
def test_group_lookup_equals_per_table_loop(inventory, kinds, seed):
    vocabs, dims = inventory
    rng = np.random.RandomState(seed % (2**32 - 1))
    group = _mixed_group(vocabs, dims, kinds, seed % 997)
    max_l = 5
    idx, off = _het_case(rng, vocabs, b=3, max_l=max_l, pad=4)
    idxj, offj = jnp.asarray(idx), jnp.asarray(off)
    spec = group.envelope_spec

    got = np.asarray(es.lookup_bags(group, spec, idxj, offj, max_l=max_l))

    # reference 1: the per-table-stream entry point
    idx_t, off_t = _streams(idx, off, len(vocabs))
    loop = np.asarray(es.lookup_bags_per_table(group, idx_t, off_t,
                                               max_l=max_l))
    np.testing.assert_array_equal(got, loop)

    # reference 2: a hand-written loop of each member's OWN lookup over
    # only its stream (independent of lookup_bags_per_table)
    for t, (m, sp) in enumerate(zip(group.members, group.specs)):
        own = np.asarray(es.lookup_bags(m, sp, idx_t[t], off_t[t],
                                        max_l=max_l))[:, 0, :]
        np.testing.assert_array_equal(got[:, t, :sp.dim],
                                      own.astype(got.dtype))
        # padded tail lanes are exactly zero
        assert (got[:, t, sp.dim:] == 0).all()


@settings(deadline=None, max_examples=8)
@given(st.sampled_from(INVENTORIES), st.sampled_from(KIND_PATTERNS),
       st.integers(0, 2**31 - 1))
def test_group_grads_equal_per_table_loop(inventory, kinds, seed):
    """jax.grad through grouped dispatch == jax.grad through the
    per-table loop, leaf for leaf, over mixed member kinds (hot rows,
    cold arenas, and int8 scale leaves all receive identical
    cotangents)."""
    vocabs, dims = inventory
    rng = np.random.RandomState(seed % (2**32 - 1))
    group = _mixed_group(vocabs, dims, kinds, seed % 997)
    max_l = 4
    idx, off = _het_case(rng, vocabs, b=2, max_l=max_l, pad=3)
    idxj, offj = jnp.asarray(idx), jnp.asarray(off)
    idx_t, off_t = _streams(idx, off, len(vocabs))
    spec = group.envelope_spec
    b = (off.shape[0] - 1) // len(vocabs)
    w = jnp.asarray(rng.randn(b, len(vocabs), spec.dim), jnp.float32)

    def loss_grouped(g):
        out = es.lookup_bags(g, spec, idxj, offj, max_l=max_l)
        return jnp.sum(out.astype(jnp.float32) * w)

    def loss_loop(g):
        out = es.lookup_bags_per_table(g, idx_t, off_t, max_l=max_l)
        return jnp.sum(out.astype(jnp.float32) * w)

    g1 = jax.grad(loss_grouped, allow_int=True)(group)
    g2 = jax.grad(loss_loop, allow_int=True)(group)
    leaves1 = jax.tree_util.tree_leaves(g1)
    leaves2 = jax.tree_util.tree_leaves(g2)
    assert len(leaves1) == len(leaves2) and leaves1
    for a, b_ in zip(leaves1, leaves2):
        if np.issubdtype(np.asarray(a).dtype, np.floating):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b_))


def test_group_fixed_layout_matches_ragged(rng):
    """lookup_fixed over a group == lookup_bags over the equivalent
    uniform ragged encoding."""
    vocabs, dims = (30, 9, 1), (8, 2, 1)
    group = _mixed_group(vocabs, dims, ("cached", "int8", "fp"), 5)
    spec = group.envelope_spec
    b, t, l = 3, len(vocabs), 4
    idx = np.stack([rng.randint(0, vocabs[j], (b, l))
                    for j in range(t)], axis=1).astype(np.int32)
    fixed = np.asarray(es.lookup_fixed(group, spec, jnp.asarray(idx)))
    off = jnp.asarray(np.arange(b * t + 1, dtype=np.int32) * l)
    ragged = np.asarray(es.lookup_bags(group, spec,
                                       jnp.asarray(idx.reshape(-1)), off,
                                       max_l=l))
    np.testing.assert_array_equal(fixed, ragged)


def test_group_degenerate_shapes():
    """Single-table group; vocab-1 table; one table all-empty while
    another is dense; a dim-1 member — all against the per-table loop."""
    for vocabs, dims in (((7,), (4,)), ((1, 50), (8, 8)),
                         ((5, 5), (1, 16))):
        rng = np.random.RandomState(0)
        group = _mixed_group(vocabs, dims, ("fp", "int8"), 3)
        idx, off = _het_case(rng, vocabs, b=2, max_l=3, pad=2)
        got = np.asarray(es.lookup_bags(group, group.envelope_spec,
                                        jnp.asarray(idx),
                                        jnp.asarray(off), max_l=3))
        idx_t, off_t = _streams(idx, off, len(vocabs))
        loop = np.asarray(es.lookup_bags_per_table(group, idx_t, off_t,
                                                   max_l=3))
        np.testing.assert_array_equal(got, loop)
        if len(vocabs) > 1:
            # table 0's bags are all empty by construction: exact zeros
            assert (got[:, 0, :] == 0).all()


def test_group_non_divisible_bag_count_raises():
    """A single-stream lookup whose bag count doesn't cover whole
    (sample, table) rows must fail loudly, naming the counts and the
    calling entry point — not silently mis-assign bags to tables."""
    group = _mixed_group((7, 9), (4, 4), ("fp", "fp"), 1)
    idx = jnp.asarray([0, 1, 2], jnp.int32)
    off = jnp.asarray([0, 1, 2, 3], jnp.int32)   # 3 bags, 2 tables
    with pytest.raises(ValueError) as exc:
        es.lookup_bags(group, group.envelope_spec, idx, off, max_l=2)
    msg = str(exc.value)
    assert "n_bags=3" in msg and "t_count=2" in msg
    assert "lookup_bags" in msg


# ---------------------------------------------------------------------------
# per-table training contract
# ---------------------------------------------------------------------------

def test_group_row_grads_match_autodiff(rng):
    """group_row_grads scatters == jax.grad of the group lookup w.r.t.
    each member arena (null rows pinned at zero) — the O(N) per-table
    training contract."""
    vocabs, dims = (20, 6, 1), (8, 4, 1)
    specs = _specs_of(vocabs, dims)
    arenas = [se.init_arena(jax.random.PRNGKey(t), sp, scale=1.0)
              for t, sp in enumerate(specs)]
    group = es.TableGroupSource(
        members=tuple(es.FpArena(a) for a in arenas), specs=specs)
    spec = group.envelope_spec
    idx, off = _het_case(np.random.RandomState(2), vocabs, b=3, max_l=4,
                         pad=2)
    idxj, offj = jnp.asarray(idx), jnp.asarray(off)
    n_bags = off.shape[0] - 1
    w = jnp.asarray(rng.randn(n_bags // len(vocabs), len(vocabs),
                              spec.dim), jnp.float32)

    def loss(g):
        return jnp.sum(es.lookup_bags(g, spec, idxj, offj, max_l=4) * w)

    g_auto = jax.grad(loss)(group)
    per_table = group_row_grads(specs, w.reshape(n_bags, spec.dim),
                                idxj, offj)
    for t, (sp, (rows, row_g)) in enumerate(zip(specs, per_table)):
        dense = np.zeros(arenas[t].shape, np.float32)
        for r, gr in zip(np.asarray(rows), np.asarray(row_g)):
            if r != sp.null_row:
                dense[r] += gr
        want = np.asarray(g_auto.members[t].arena).copy()
        want[sp.null_row] = 0.0
        np.testing.assert_allclose(dense, want, rtol=1e-5, atol=1e-5)


def test_group_train_step_sparse_equals_dense_grad():
    """The per-table row-wise sparse step == the dense-grad baseline,
    bit for bit over 3 steps (per-table Adagrad accumulators included)."""
    cfg = DLRM_HET_SMOKE
    params = dlrm.init(jax.random.PRNGKey(0), cfg)
    data = DLRMSynthetic(cfg, seed=1)
    rb = data.ragged_batch(4, dist="poisson", mean_l=3, max_l=6)
    batch = {k: jnp.asarray(rb[k])
             for k in ("dense", "indices", "offsets", "labels")}
    opt_s, step_s = dlrm.make_train_step_ragged(cfg, max_l=6, lr=1e-2,
                                                sparse=True)
    opt_d, step_d = dlrm.make_train_step_ragged(cfg, max_l=6, lr=1e-2,
                                                sparse=False)
    ps, ss = params, opt_s.init(params)
    pd, sd = params, opt_d.init(params)
    step_s, step_d = jax.jit(step_s), jax.jit(step_d)
    for _ in range(3):
        ps, ss, loss_s, touched = step_s(ps, ss, batch)
        pd, sd, loss_d, _ = step_d(pd, sd, batch)
        np.testing.assert_allclose(float(loss_s), float(loss_d),
                                   rtol=1e-6)
    assert isinstance(touched, tuple) and len(touched) == cfg.n_tables
    for a, b in zip(jax.tree_util.tree_leaves(ps),
                    jax.tree_util.tree_leaves(pd)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-7)
    # sharded group training is refused loudly, not silently wrong
    from repro.launch.mesh import make_mesh
    if len(jax.devices()) >= 2:
        mesh = make_mesh((2,), ("model",))
        with pytest.raises(ValueError, match="heterogeneous"):
            dlrm.make_train_step_ragged(cfg, max_l=6, mesh=mesh,
                                        sharded=True)


def test_online_group_trainer_protocol():
    """Per-table refresh under one version: caches stay write-through
    exact between rebuilds, the int8 mirror matches a full requant at
    every rebuild, and publish_source round-trips into an engine."""
    from repro.serving import RecEngine
    from repro.training import OnlineGroupTrainer
    cfg = DLRM_HET_SMOKE
    params = dlrm.init(jax.random.PRNGKey(0), cfg)
    plans = dlrm.table_plans(cfg, cache_k=(16, 8, 0),
                             quantize_rows_above=1000)
    assert plans[0].quantize and not plans[1].quantize
    tr = OnlineGroupTrainer(cfg, params, max_l=6, plans=plans, lr=1e-2,
                            refresh_every=3)
    data = DLRMSynthetic(cfg, seed=5)
    pad = 4 * cfg.n_tables * 6
    for _ in range(7):
        tr.train_step(data.ragged_batch(4, dist="poisson", mean_l=3,
                                        max_l=6, pad_to=pad))
    assert tr.version == 2 and len(tr.losses) == 7
    for t, plan in enumerate(plans):
        if plan.cache_k == 0:
            assert tr.caches[t] is None
            continue
        # write-through exactness: every pinned hot row equals its
        # live arena row right now
        cache = tr.caches[t]
        hot_ids = np.asarray(cache.hot_ids)
        np.testing.assert_array_equal(
            np.asarray(cache.hot_rows[:-1]),
            np.asarray(tr.params["tables"][t])[hot_ids])
        assert not np.asarray(cache.hot_rows[-1]).any()
    # int8 mirror == full requant at the last rebuild... after which one
    # more step may have dirtied rows again; force a rebuild to compare
    tr.rebuild()
    full = es.QuantizedArena.from_arena(tr.params["tables"][0])
    np.testing.assert_array_equal(np.asarray(tr.cold_q[0].q),
                                  np.asarray(full.q))

    blob = tr.publish_source()
    back = es.VersionedSource.deserialize(blob)
    assert isinstance(back.source, es.TableGroupSource)
    eng = RecEngine(cfg, tr.params, source=tr.serving_source(), max_l=6,
                    max_batch=4, max_wait_ms=0.0, buckets=(4,))
    assert back.apply(eng) and eng.source_version == back.version
    assert not back.apply(eng)            # idempotent re-delivery
    assert tr.sync_engine(eng)            # step-gate push
    assert not tr.sync_engine(eng)


# ---------------------------------------------------------------------------
# plans, serving, stats
# ---------------------------------------------------------------------------

def test_group_plan_validation():
    plans = (es.TablePlan(rows=10, dim=4),)
    with pytest.raises(ValueError, match="TablePlan"):
        es.SourceSpec(tables=plans, cache_k=8)
    with pytest.raises(ValueError, match="fixed"):
        es.SourceSpec(tables=plans, layout="fixed")
    spec = es.SourceSpec(tables=(es.TablePlan(rows=10, dim=4, cache_k=2),
                                 es.TablePlan(rows=5, dim=8,
                                              quantize=True)))
    assert spec.cached and spec.path_name() == "grouped"
    arenas = [se.init_arena(jax.random.PRNGKey(t), tp.arena_spec)
              for t, tp in enumerate(spec.tables)]
    src = spec.build(arenas, None)
    assert isinstance(src, es.TableGroupSource)
    assert isinstance(src.members[0], es.CachedSource)
    assert isinstance(src.members[1], es.QuantizedArena)
    # one-per-line rendering: every member gets its own indented line
    tree = es.describe_source(src, multiline=True)
    assert len(tree.splitlines()) >= 5 and "table[1]" in tree
    assert es.describe_source(src) == "group[cached(fp),int8]"


def test_group_engine_serves_with_per_table_hit_stats():
    """RecEngine over a group plan: per-table hit-rate mapping (None for
    non-cached members), correct probabilities vs forward_ragged, stale
    single-member swaps rejected, fresh member swap without recompile."""
    from repro.serving import RecEngine, requests_from_ragged_batch
    cfg = DLRM_HET_SMOKE
    spec = dlrm.arena_spec(cfg)
    specs = dlrm.member_specs(cfg)
    params = dlrm.init(jax.random.PRNGKey(0), cfg)
    data = DLRMSynthetic(cfg, seed=3)
    rb = data.ragged_batch(4, dist="poisson", mean_l=3, max_l=6)
    counts = es.group_trace_counts(specs, rb["indices"], rb["offsets"])
    plans = dlrm.table_plans(cfg, cache_k=(16, 8, 0))
    eng = RecEngine(cfg, params, source=es.SourceSpec(tables=plans),
                    cache_trace=counts, max_l=6, max_batch=8,
                    max_wait_ms=0.0, buckets=(4, 8))
    eng.warmup()
    compiled = (eng._serve._cache_size()
                if hasattr(eng._serve, "_cache_size") else None)
    reqs = requests_from_ragged_batch(rb, cfg.n_tables)
    for r in reqs:
        eng.submit(r)
    eng.step(force=True)
    eng.drain()
    s = eng.stats()
    assert s["path"] == "grouped"
    hr = s["cache_hit_rate"]
    assert set(hr) == {0, 1, 2}
    assert hr[2] is None                      # non-cached member
    assert hr[0] is not None and 0.0 <= hr[0] <= 1.0
    assert "table[2]" in s["source_tree"]
    # probabilities match the direct group forward
    want = np.asarray(jax.nn.sigmoid(dlrm.forward_ragged(
        params, cfg, jnp.asarray(rb["dense"]), jnp.asarray(rb["indices"]),
        jnp.asarray(rb["offsets"]), max_l=6, source=eng.source)))
    got = np.asarray([r.prob for r in reqs])
    np.testing.assert_allclose(got, want[:len(got)], rtol=1e-4, atol=1e-5)

    # swap ONE member's hot cache under a bumped version: no recompile,
    # counters reset
    new_hot = se.build_hot_cache(params["tables"][0], specs[0], counts[0],
                                 16)
    fresh = es.replace_member(
        eng.source, 0,
        es.with_hot_cache(eng.source.members[0], new_hot))
    eng.update_source(fresh, version=2)
    assert eng.stats()["cache_hit_rate"][0] is None   # no post-swap data
    for r in requests_from_ragged_batch(rb, cfg.n_tables):
        eng.submit(r)
    eng.step(force=True)
    eng.drain()
    if compiled is not None:
        assert eng._serve._cache_size() == compiled, \
            "a single-member swap recompiled the serve step"
    # a stale single-member swap is rejected at the same boundary
    stale = es.replace_member(eng.source, 0, eng.source.members[0])
    with pytest.raises(ValueError, match="stale"):
        eng.update_source(stale, version=1)
    # structure-changing swaps are refused (they would recompile)
    with pytest.raises(AssertionError):
        eng.update_source(es.replace_member(
            eng.source, 0, eng.source.members[0].cold), version=3)


def test_group_artifact_roundtrip_mixed_members(rng):
    group = _mixed_group((12, 5, 1), (8, 4, 1),
                         ("cached_int8", "fp", "int8"), 9)
    blob = es.VersionedSource(group, 11).serialize()
    back = es.VersionedSource.deserialize(blob)
    assert back.version == 11
    assert isinstance(back.source, es.TableGroupSource)
    assert back.source.specs == group.specs
    for a, b in zip(jax.tree_util.tree_leaves(group),
                    jax.tree_util.tree_leaves(back.source)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# sharded members through the REAL shard_map entry point
# ---------------------------------------------------------------------------

def _run_with_devices(code: str, n: int = 4, timeout: int = 480) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
    env["PYTHONPATH"] = SRC
    env.setdefault("JAX_PLATFORMS", "cpu")
    prelude = textwrap.dedent("""
        import json, jax, jax.numpy as jnp, numpy as np
        from repro.core import embedding_source as es
        from repro.core import sparse_engine as se
        from repro.launch.mesh import make_mesh
    """)
    out = subprocess.run([sys.executable, "-c", prelude + code],
                         capture_output=True, text=True, env=env,
                         timeout=timeout)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_group_sharded_members_match_replicated_shard_map():
    """A group whose members are row-sharded (ShardedArena) over
    {1, 2, 4}-way meshes == the replicated group, through the real
    shard_map entry point, for fp and cached members."""
    r = _run_with_devices("""
vocabs, dims = (37, 9), (8, 4)
specs = tuple(se.ArenaSpec(1, v, d) for v, d in zip(vocabs, dims))
rng = np.random.RandomState(0)
errs = {}
for shards in (1, 2, 4):
    mesh = make_mesh((shards,), ("model",))
    arenas = [se.init_arena(jax.random.PRNGKey(t), sp, shards, scale=1.0)
              for t, sp in enumerate(specs)]
    n_bags = 3 * len(vocabs)
    lens = rng.randint(0, 5, n_bags).astype(np.int32)
    off = np.zeros(n_bags + 1, np.int32); off[1:] = np.cumsum(lens)
    n = int(off[-1])
    seg = np.searchsorted(off[1:], np.arange(n), side="right")
    idx = np.empty(n, np.int32)
    for t, v in enumerate(vocabs):
        m = (seg % len(vocabs)) == t
        idx[m] = rng.randint(0, v, int(m.sum()))
    idxj = jnp.asarray(np.concatenate([idx, np.zeros(3, np.int32)]))
    offj = jnp.asarray(off)
    counts = rng.rand(specs[0].total_rows)
    hot = se.build_hot_cache(arenas[0], specs[0], counts, 4)
    repl = es.TableGroupSource(
        (es.CachedSource(hot, es.FpArena(arenas[0])),
         es.FpArena(arenas[1])), specs)
    shrd = es.TableGroupSource(
        (es.CachedSource(hot, es.ShardedArena(es.FpArena(arenas[0]),
                                              mesh)),
         es.ShardedArena(es.FpArena(arenas[1]), mesh)), specs)
    env = repl.envelope_spec
    want = es.lookup_bags(repl, env, idxj, offj, max_l=4)
    got = jax.jit(lambda i, o: es.lookup_bags(shrd, env, i, o,
                                              max_l=4))(idxj, offj)
    errs[shards] = float(jnp.abs(got - want).max())
print(json.dumps({str(k): v for k, v in errs.items()}))
""")
    for shards, err in r.items():
        assert err < 1e-5, (shards, err)
