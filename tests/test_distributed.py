"""Distribution tests that need >1 device: run in a subprocess with 8 fake
host devices (the main pytest process must keep seeing 1 device)."""
import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

SRC = str(Path(__file__).resolve().parents[1] / "src")


def run_with_devices(code: str, n: int = 8, timeout: int = 480) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
    env["PYTHONPATH"] = SRC
    prelude = textwrap.dedent("""
        import json, jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.launch.mesh import make_mesh
    """)
    out = subprocess.run([sys.executable, "-c", prelude + code],
                         capture_output=True, text=True, env=env,
                         timeout=timeout)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_sharded_embedding_matches_replicated():
    """Row-sharded vocab gather (the sparse engine) == plain table[tokens]."""
    r = run_with_devices("""
from repro.models import embedding as emb
from repro.distributed.sharding import use_mesh
mesh = make_mesh((2, 4), ('data', 'model'))
rng = np.random.RandomState(0)
table = jnp.asarray(rng.randn(128, 16), jnp.float32)
tokens = jnp.asarray(rng.randint(0, 100, (4, 8)), jnp.int32)
with use_mesh(mesh):
    out_sharded = jax.jit(emb.embed_tokens)(table, tokens)
out_ref = table[tokens]
err = float(jnp.abs(out_sharded - out_ref).max())
print(json.dumps({"err": err}))
""")
    assert r["err"] < 1e-5


def test_moe_ep_matches_local():
    """Expert-parallel a2a MoE == single-device MoE (same params/tokens)."""
    r = run_with_devices("""
from repro.configs.base import MoEConfig
from repro.models import moe
from repro.models.params import Builder, split
from repro.distributed.sharding import use_mesh
mcfg = MoEConfig(n_experts=8, top_k=2, expert_ff=32, capacity_factor=4.0)
params, _ = split(moe.init_moe(Builder(jax.random.PRNGKey(0),
                                       dtype=jnp.float32), mcfg, 16))
rng = np.random.RandomState(0)
x = jnp.asarray(rng.randn(2, 16, 16), jnp.float32)   # 32 tokens / 8 shards
y_local, aux_local = moe.apply_moe(params, mcfg, x)
mesh = make_mesh((2, 4), ('data', 'model'))
with use_mesh(mesh):
    y_ep, aux_ep = jax.jit(lambda p, x: moe.apply_moe(p, mcfg, x))(params, x)
err = float(jnp.abs(y_ep - y_local).max())
print(json.dumps({"err": err, "aux_local": float(aux_local),
                  "aux_ep": float(aux_ep)}))
""")
    # capacity differs per shard (T_loc), so tiny drop differences are
    # possible; with cf=4.0 nothing drops and results must match closely
    assert r["err"] < 1e-4
    # aux: EP averages per-shard (frac*imp) products; the local path computes
    # the global product of means — mathematically different estimators of
    # the same load-balance signal, so only sanity-check the range.
    assert 0.5 < r["aux_ep"] / r["aux_local"] < 2.0


def test_dlrm_sharded_lookup_matches_replicated():
    r = run_with_devices("""
from repro.core import embedding_source as es
from repro.core import sparse_engine as se
mesh = make_mesh((2, 4), ('data', 'model'))
spec = se.ArenaSpec(3, 64, 8)
arena = se.init_arena(jax.random.PRNGKey(0), spec, shards=4)
rng = np.random.RandomState(0)
idx = jnp.asarray(rng.randint(0, 64, (8, 3, 5)), jnp.int32)
out_rep = es.lookup_fixed(es.FpArena(arena), spec, idx)
out_sh = jax.jit(lambda a, i: es.lookup_fixed(
    es.ShardedArena(es.FpArena(a), mesh), spec, i))(arena, idx)
print(json.dumps({"err": float(jnp.abs(out_rep - out_sh).max())}))
""")
    assert r["err"] < 1e-5


def test_dlrm_ragged_forward_matches_fixed_on_2shard_mesh():
    """Acceptance: ragged forward == fixed forward on equal-length bags,
    on a real 2-way model-sharded mesh (shard_map path)."""
    r = run_with_devices("""
from repro.configs.dlrm import DLRM_SMOKE
from repro.core import dlrm
from repro.data import DLRMSynthetic
cfg = DLRM_SMOKE
mesh = make_mesh((4, 2), ('data', 'model'))
params = dlrm.init(jax.random.PRNGKey(0), cfg, shards=2)
data = DLRMSynthetic(cfg, seed=5)
rb = data.ragged_batch(8, dist='fixed')
fx = jnp.asarray(DLRMSynthetic.ragged_to_fixed(rb, cfg.n_tables))
f_fixed = dlrm.forward(params, cfg, jnp.asarray(rb['dense']), fx, mesh)
f_ragged = jax.jit(lambda p, d, i, o: dlrm.forward_ragged(
    p, cfg, d, i, o, max_l=int(rb['max_l']), mesh=mesh))(
    params, jnp.asarray(rb['dense']), jnp.asarray(rb['indices']),
    jnp.asarray(rb['offsets']))
print(json.dumps({"err": float(jnp.abs(f_fixed - f_ragged).max())}))
""")
    assert r["err"] < 1e-4


def test_ragged_sharded_lookup_matches_replicated():
    r = run_with_devices("""
from repro.core import embedding_source as es
from repro.core import sparse_engine as se
mesh = make_mesh((2, 4), ('data', 'model'))
spec = se.ArenaSpec(3, 64, 8)
arena = se.init_arena(jax.random.PRNGKey(0), spec, shards=4)
rng = np.random.RandomState(0)
lens = rng.randint(0, 6, 24).astype(np.int32)
off = np.zeros(25, np.int32); off[1:] = np.cumsum(lens)
idx = jnp.asarray(rng.randint(0, 64, int(off[-1]) + 4), jnp.int32)
off = jnp.asarray(off)
out_rep = es.lookup_bags(es.FpArena(arena), spec, idx, off, max_l=5)
out_sh = jax.jit(lambda a, i, o: es.lookup_bags(
    es.ShardedArena(es.FpArena(a), mesh), spec, i, o,
    max_l=5))(arena, idx, off)
print(json.dumps({"err": float(jnp.abs(out_rep - out_sh).max())}))
""")
    assert r["err"] < 1e-5


def test_train_step_lowering_small_mesh():
    """End-to-end mini dry-run: lower+compile a smoke train step on a
    (2,4) mesh and check the roofline pipeline produces sane numbers."""
    r = run_with_devices("""
from repro.configs.registry import SMOKE_ARCHS
from repro.models import api
from repro.launch import hlo_analysis
cfg = SMOKE_ARCHS['qwen1.5-4b']
mesh = make_mesh((2, 4), ('data', 'model'))
opt_name, opt, step = api.make_train_step(cfg, mesh=mesh)
params_sds, opt_sds, _ = api.train_state_specs(cfg, opt_name, opt, mesh)
import repro.configs.base as base
shape = base.ShapeConfig('mini', 64, 8, 'train')
batch_sds = api.input_specs(cfg, shape, mesh)
with mesh:
    co = jax.jit(step, donate_argnums=(0, 1)).lower(
        params_sds, opt_sds, batch_sds).compile()
h = hlo_analysis.analyze(co.as_text())
ma = co.memory_analysis()
print(json.dumps({"flops": h['flops'], "bytes": h['bytes'],
                  "coll": h['collectives'].get('total', 0.0),
                  "temp": ma.temp_size_in_bytes}))
""")
    assert r["flops"] > 1e6
    assert r["bytes"] > 1e5
    assert r["coll"] > 0        # grad all-reduce must exist on a DP mesh


def test_elastic_reshard_across_meshes(tmp_path):
    """Checkpoint on a (4,2) mesh, restore onto (2,4) — elastic rescale."""
    r = run_with_devices(f"""
from repro.checkpoint import CheckpointManager
from repro.distributed.sharding import resolve
mesh_a = make_mesh((4, 2), ('data', 'model'))
mesh_b = make_mesh((2, 4), ('data', 'model'))
state = {{"w": jax.device_put(jnp.arange(64.).reshape(8, 8),
          NamedSharding(mesh_a, P(None, 'model')))}}
mgr = CheckpointManager(r"{tmp_path}")
mgr.save(3, state)
shardings = {{"w": NamedSharding(mesh_b, P(None, 'model'))}}
restored, _ = mgr.restore(state, shardings=shardings)
ok = bool((np.asarray(restored['w']) == np.arange(64.).reshape(8, 8)).all())
nshards = len(restored['w'].sharding.device_set)
print(json.dumps({{"ok": ok, "nshards": nshards}}))
""")
    assert r["ok"] and r["nshards"] == 8
