"""End-to-end behaviour tests for the paper's system."""
import os
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import LM_SHAPES, shape_applicable
from repro.configs.registry import ARCHS, ARCH_IDS, iter_cells
from repro.launch import roofline


def test_assignment_has_10_archs_and_40_cells():
    assert len(ARCH_IDS) == 10
    cells = iter_cells()
    assert len(cells) == 40
    skipped = [c for c in cells if not c[2]]
    # long_500k skipped exactly for the pure full-attention archs
    assert all(c[1] == "long_500k" for c in skipped)
    assert len(skipped) == 7


def test_arch_configs_match_assignment_table():
    """Exact assigned hyperparameters (spot-check every arch)."""
    a = ARCHS
    c = a["h2o-danube-1.8b"]
    assert (c.n_layers, c.d_model, c.attention.n_heads,
            c.attention.n_kv_heads, c.d_ff, c.vocab_size) == \
        (24, 2560, 32, 8, 6912, 32_000)
    assert c.attention.window is not None          # SWA
    c = a["qwen1.5-4b"]
    assert (c.n_layers, c.d_model, c.attention.n_heads, c.d_ff,
            c.vocab_size) == (40, 2560, 20, 6912, 151_936)
    assert c.attention.qkv_bias
    c = a["minicpm3-4b"]
    assert (c.n_layers, c.d_model, c.attention.n_heads, c.d_ff,
            c.vocab_size) == (62, 2560, 40, 6400, 73_448)
    assert c.attention.kind == "mla"
    c = a["smollm-360m"]
    assert (c.n_layers, c.d_model, c.attention.n_heads,
            c.attention.n_kv_heads, c.d_ff, c.vocab_size) == \
        (32, 960, 15, 5, 2560, 49_152)
    c = a["internvl2-2b"]
    assert (c.n_layers, c.d_model, c.attention.n_heads,
            c.attention.n_kv_heads, c.d_ff, c.vocab_size) == \
        (24, 2048, 16, 8, 8192, 92_553)
    assert c.family == "vlm"
    c = a["recurrentgemma-9b"]
    assert (c.n_layers, c.d_model, c.attention.n_heads,
            c.attention.n_kv_heads, c.d_ff, c.vocab_size) == \
        (38, 4096, 16, 1, 12_288, 256_000)
    assert c.rglru is not None
    c = a["kimi-k2-1t-a32b"]
    assert (c.n_layers, c.d_model, c.attention.n_heads,
            c.attention.n_kv_heads, c.vocab_size) == \
        (61, 7168, 64, 8, 163_840)
    assert (c.moe.n_experts, c.moe.top_k) == (384, 8)
    c = a["arctic-480b"]
    assert (c.n_layers, c.d_model, c.attention.n_heads,
            c.attention.n_kv_heads, c.vocab_size) == \
        (35, 7168, 56, 8, 32_000)
    assert (c.moe.n_experts, c.moe.top_k) == (128, 2)
    assert c.moe.dense_residual_ff                 # dense residual branch
    c = a["seamless-m4t-large-v2"]
    assert (c.d_model, c.attention.n_heads, c.d_ff, c.vocab_size) == \
        (1024, 16, 8192, 256_206)
    assert c.is_encdec
    c = a["rwkv6-7b"]
    assert (c.n_layers, c.d_model, c.d_ff, c.vocab_size) == \
        (32, 4096, 14_336, 65_536)
    assert c.attention_free


def test_kimi_is_a_trillion_params():
    from repro.models import api
    cfg = ARCHS["kimi-k2-1t-a32b"]
    cell = {}

    def f(k):
        vals, specs = api.init(k, cfg)
        cell["s"] = specs
        return vals
    shapes = jax.eval_shape(f, jax.ShapeDtypeStruct((2,), jnp.uint32))
    total = sum(float(np.prod(x.shape))
                for x in jax.tree_util.tree_leaves(shapes))
    assert total > 0.9e12


def test_train_launcher_end_to_end_with_resume(tmp_path):
    """Full launcher run: train, checkpoint, kill, resume."""
    src = str(Path(__file__).resolve().parents[1] / "src")
    base = [sys.executable, "-m", "repro.launch.train", "--arch", "dlrm1",
            "--smoke", "--batch-size", "8", "--log-every", "5",
            "--ckpt-dir", str(tmp_path), "--ckpt-every", "5"]
    env = {"PYTHONPATH": src, "PATH": "/usr/bin:/bin:/usr/local/bin",
           # inherit the platform pin (hermetic CPU runs on images that
           # bundle libtpu would otherwise stall probing for TPU metadata)
           "JAX_PLATFORMS": os.environ.get("JAX_PLATFORMS", "cpu")}
    out1 = subprocess.run(base + ["--steps", "10"], capture_output=True,
                          text=True, env=env, timeout=300)
    assert out1.returncode == 0, out1.stderr[-2000:]
    out2 = subprocess.run(base + ["--steps", "15", "--resume"],
                          capture_output=True, text=True, env=env,
                          timeout=300)
    assert out2.returncode == 0, out2.stderr[-2000:]
    assert "resumed from step" in out2.stdout


def test_dryrun_results_exist_and_are_clean():
    """The committed dry-run artifacts cover all 40 cells x both meshes
    with zero errors (the multi-pod dry-run deliverable)."""
    results = Path(__file__).resolve().parents[1] / "benchmarks" / "results"
    if not results.exists():
        pytest.skip("dry-run results not yet generated")
    import json
    for mesh in ("pod", "multipod"):
        recs = [json.loads(p.read_text())
                for p in results.glob(f"dryrun_{mesh}_*.json")]
        if not recs:
            pytest.skip(f"no {mesh} results yet")
        assert len(recs) == 40, f"{mesh}: {len(recs)} cells"
        bad = [r for r in recs if r["status"] == "error"]
        assert not bad, [(r["arch"], r["shape"]) for r in bad]
        for r in recs:
            if r["status"] == "ok":
                assert r["flops_per_dev"] > 0
                assert r["roofline"]["dominant"] in (
                    "compute", "memory", "collective")


def test_roofline_model_flops_sane():
    from repro.configs.base import TRAIN_4K
    # 6ND for a known dense arch
    from repro.models import api
    cfg = ARCHS["smollm-360m"]

    def f(k):
        return api.init(k, cfg)[0]
    shapes = jax.eval_shape(f, jax.ShapeDtypeStruct((2,), jnp.uint32))
    mf = roofline.model_flops(cfg, TRAIN_4K, shapes)
    # ~360M params x 6 x 1M tokens ~ 2.3e15
    assert 1e15 < mf < 4e15


def test_multipod_scales_per_device_terms():
    """Going 256 -> 512 chips must not inflate per-device roofline terms
    (regression guard: a (B,S)->(B*S) reshape across mesh axes once cost a
    30 GB-per-layer all-gather that only manifested on the multi-pod mesh)."""
    import json
    results = Path(__file__).resolve().parents[1] / "benchmarks" / "results"
    if not results.exists():
        pytest.skip("dry-run results not generated")
    checked = 0
    for p in results.glob("dryrun_pod_*_train_4k.json"):
        pod = json.loads(p.read_text())
        if pod["status"] != "ok":
            continue
        mp_path = results / p.name.replace("dryrun_pod_", "dryrun_multipod_")
        if not mp_path.exists():
            continue
        multi = json.loads(mp_path.read_text())
        if multi["status"] != "ok":
            continue
        for term in ("t_memory", "t_collective"):
            assert multi["roofline"][term] <= pod["roofline"][term] * 1.3, (
                p.name, term, pod["roofline"][term], multi["roofline"][term])
        checked += 1
    assert checked >= 8
