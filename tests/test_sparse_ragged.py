"""Property + coverage tests: ragged sparse path, sharding, hot-row cache.

The sharded variants run WITHOUT a mesh by vmapping over the shard axis
with a named axis — axis_index/psum behave exactly as under shard_map, so
the ownership/masking protocol is exercised on a 1-device CPU. (The real
shard_map path is covered in test_distributed.py with 8 fake devices.)
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import embedding_source as es
from repro.core import sparse_engine as se
from repro.kernels import embedding_gather as eg
from repro.kernels import ref as kref


def _ragged_case(rng, spec, b, max_l, pad=0):
    """Random ragged batch with the hard edges forced in: an empty bag, a
    full-length bag, plus null-row and padded-row targeting indices."""
    n_bags = b * spec.n_tables
    lens = rng.randint(0, max_l + 1, n_bags).astype(np.int32)
    lens[0] = 0
    lens[-1] = max_l
    off = np.zeros(n_bags + 1, np.int32)
    np.cumsum(lens, out=off[1:])
    n = int(off[-1])
    idx = rng.randint(0, spec.rows_per_table, n + pad).astype(np.int32)
    return jnp.asarray(idx), jnp.asarray(off), lens


def _oracle(arena, spec, idx, off):
    a = np.asarray(arena)
    idx = np.asarray(idx)
    off = np.asarray(off)
    n_bags = len(off) - 1
    b = n_bags // spec.n_tables
    out = np.zeros((b, spec.n_tables, spec.dim), np.float32)
    for k in range(n_bags):
        t = k % spec.n_tables
        for p in range(off[k], off[k + 1]):
            out[k // spec.n_tables, t] += a[idx[p]
                                            + t * spec.rows_per_table]
    return out


# ---------------------------------------------------------------------------
# sparse_lengths_sum kernel vs jnp oracle (hypothesis-generated offsets)
# ---------------------------------------------------------------------------

@settings(deadline=None, max_examples=12)
@given(st.integers(1, 5), st.integers(1, 5), st.integers(0, 2**31 - 1))
def test_sls_kernel_vs_oracle_property(n_bags, max_l, seed):
    """Pallas ragged kernel (interpret) == jnp oracle over random ragged
    offsets including empty bags, full max_l bags, single-bag batches."""
    rng = np.random.RandomState(seed % (2**32 - 1))
    v, d = 32, 8
    table = jnp.asarray(rng.randn(v, d), jnp.float32)
    lens = rng.randint(0, max_l + 1, n_bags).astype(np.int32)
    if n_bags > 1:
        lens[0] = 0           # empty bag
        lens[-1] = max_l      # full bag
    off = np.zeros(n_bags + 1, np.int32)
    np.cumsum(lens, out=off[1:])
    idx = rng.randint(0, v, max(int(off[-1]), 1)).astype(np.int32)
    got = eg.sparse_lengths_sum(table, jnp.asarray(idx), jnp.asarray(off),
                                max_l=max_l, interpret=True)
    want = kref.sparse_lengths_sum(table, jnp.asarray(idx),
                                   jnp.asarray(off))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
    # and the oracle against a python loop, so both impls are anchored
    manual = np.zeros((n_bags, d), np.float32)
    for k in range(n_bags):
        for p in range(off[k], off[k + 1]):
            manual[k] += np.asarray(table)[idx[p]]
    np.testing.assert_allclose(np.asarray(want), manual, rtol=1e-5,
                               atol=1e-5)


def test_sls_single_bag_and_all_empty():
    table = jnp.asarray(np.arange(24, dtype=np.float32).reshape(6, 4))
    # single bag holding everything
    got = eg.sparse_lengths_sum(table, jnp.asarray([0, 2, 5], jnp.int32),
                                jnp.asarray([0, 3], jnp.int32), max_l=3,
                                interpret=True)
    np.testing.assert_allclose(np.asarray(got)[0],
                               np.asarray(table)[[0, 2, 5]].sum(0))
    # every bag empty -> all zeros (the pipeline's dummy tail stream)
    got = eg.sparse_lengths_sum(table, jnp.asarray([0, 0], jnp.int32),
                                jnp.asarray([0, 0, 0], jnp.int32), max_l=2,
                                interpret=True)
    assert np.abs(np.asarray(got)).max() == 0.0


# ---------------------------------------------------------------------------
# fixed-path sharding property: lookup_sharded == lookup, shards {1,2,4}
# ---------------------------------------------------------------------------

@settings(deadline=None, max_examples=9)
@given(st.sampled_from([1, 2, 4]), st.integers(0, 2**31 - 1))
def test_lookup_sharded_matches_lookup(shards, seed):
    rng = np.random.RandomState(seed % (2**32 - 1))
    spec = se.ArenaSpec(3, 30, 8)
    arena = se.init_arena(jax.random.PRNGKey(seed % 997), spec, shards)
    idx = rng.randint(0, spec.rows_per_table, (2, 3, 4)).astype(np.int32)
    # force the edge rows in: null row and the last padded row, expressed
    # as per-table ids (flatten adds t*rows_per_table back)
    idx[0, 0, 0] = spec.null_row                      # table 0: base 0
    idx[0, 1, 0] = spec.null_row - spec.rows_per_table
    idx[1, 2, 0] = (spec.padded_rows(shards) - 1
                    - 2 * spec.rows_per_table)        # padded (zero) row
    idx = jnp.asarray(idx)

    want = es.lookup_fixed(es.FpArena(arena), spec, idx)
    flat = se.flatten_indices(spec, idx)
    shard_view = jnp.reshape(arena, (shards, -1, spec.dim))
    outs = jax.vmap(
        lambda a: es.FpArena(a).shard_reduce_fixed(spec, flat, "x")
        .reshape(idx.shape[0], idx.shape[1], spec.dim)
        .astype(arena.dtype), axis_name="x")(shard_view)
    for s in range(shards):
        np.testing.assert_allclose(np.asarray(outs[s]), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# ragged path: replicated == sharded == oracle; quantized bound
# ---------------------------------------------------------------------------

@settings(deadline=None, max_examples=9)
@given(st.sampled_from([1, 2, 4]), st.integers(0, 2**31 - 1))
def test_lookup_ragged_sharded_matches_unsharded(shards, seed):
    rng = np.random.RandomState(seed % (2**32 - 1))
    spec = se.ArenaSpec(3, 30, 8)
    arena = se.init_arena(jax.random.PRNGKey(seed % 997), spec, shards)
    idx, off, _ = _ragged_case(rng, spec, b=3, max_l=4, pad=5)

    want = es.lookup_bags(es.FpArena(arena), spec, idx, off, max_l=4)
    np.testing.assert_allclose(np.asarray(want),
                               _oracle(arena, spec, idx, off),
                               rtol=1e-5, atol=1e-5)
    flat = se.flatten_ragged_indices(spec, idx, off)
    n_bags = off.shape[0] - 1
    shard_view = jnp.reshape(arena, (shards, -1, spec.dim))
    outs = jax.vmap(
        lambda a: es.FpArena(a).shard_reduce_flat(spec, flat, off, "x")
        .reshape(n_bags // spec.n_tables, spec.n_tables, spec.dim)
        .astype(arena.dtype), axis_name="x")(shard_view)
    for s in range(shards):
        np.testing.assert_allclose(np.asarray(outs[s]), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)


def test_lookup_ragged_quantized_error_bound(rng):
    spec = se.ArenaSpec(2, 50, 16)
    arena = se.init_arena(jax.random.PRNGKey(0), spec, scale=1.0)
    q, scales = se.quantize_arena(arena)
    idx, off, _ = _ragged_case(rng, spec, b=4, max_l=6, pad=3)
    exact = es.lookup_bags(es.FpArena(arena), spec, idx, off, max_l=6)
    approx = es.lookup_bags(es.QuantizedArena(q, scales), spec, idx, off,
                            max_l=6)
    bound = 6 * float(scales.max()) + 1e-6
    assert float(jnp.abs(exact - approx).max()) <= bound


def test_flatten_ragged_routes_padding_to_null(rng):
    spec = se.ArenaSpec(2, 10, 4)
    idx = jnp.asarray([3, 7, 5, 9, 1, 1, 1], jnp.int32)   # 3 padded
    off = jnp.asarray([0, 2, 4], jnp.int32)
    flat = np.asarray(se.flatten_ragged_indices(spec, idx, off))
    np.testing.assert_array_equal(flat[:4], [3, 7, 15, 19])
    assert (flat[4:] == spec.null_row).all()


# ---------------------------------------------------------------------------
# hot-row cache
# ---------------------------------------------------------------------------

def test_hot_cache_exact_vs_uncached(rng):
    spec = se.ArenaSpec(3, 40, 8)
    arena = se.init_arena(jax.random.PRNGKey(2), spec)
    idx, off, _ = _ragged_case(rng, spec, b=4, max_l=5, pad=4)
    counts = se.trace_row_counts(spec, idx, off)
    for k in (1, 8, 64):
        cache = se.build_hot_cache(arena, spec, counts, k)
        got = es.lookup_bags(es.CachedSource(cache, es.FpArena(arena)),
                             spec, idx, off, max_l=5)
        want = es.lookup_bags(es.FpArena(arena), spec, idx, off, max_l=5)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)


def test_hot_cache_hit_miss_accounting():
    """Hand-built trace: rows {0, 1} of table 0 hot, others cold."""
    spec = se.ArenaSpec(2, 10, 4)
    arena = se.init_arena(jax.random.PRNGKey(0), spec)
    counts = np.zeros(spec.total_rows)
    counts[0] = 100                        # table 0 row 0
    counts[1] = 50                         # table 0 row 1
    cache = se.build_hot_cache(arena, spec, counts, k=2)
    assert set(np.asarray(cache.hot_ids).tolist()) == {0, 1}
    # bags: table0=[0,1,5] (2 hits, 1 miss), table1=[0] (arena row 10: miss)
    idx = jnp.asarray([0, 1, 5, 0], jnp.int32)
    off = jnp.asarray([0, 3, 4], jnp.int32)
    hr = float(se.cache_hit_rate(cache, spec, idx, off))
    assert hr == pytest.approx(2 / 4)
    # padded positions must not count as hits or lookups
    idx_p = jnp.asarray([0, 1, 5, 0, 0, 0], jnp.int32)
    assert float(se.cache_hit_rate(cache, spec, idx_p, off)) \
        == pytest.approx(2 / 4)


def test_hot_cache_quantized_cold_bound(rng):
    spec = se.ArenaSpec(2, 30, 8)
    arena = se.init_arena(jax.random.PRNGKey(1), spec, scale=1.0)
    q, scales = se.quantize_arena(arena)
    idx, off, _ = _ragged_case(rng, spec, b=3, max_l=4)
    counts = se.trace_row_counts(spec, idx, off)
    cache = se.build_hot_cache(arena, spec, counts, k=16)
    got = es.lookup_bags(
        es.CachedSource(cache, es.QuantizedArena(q, scales)), spec, idx,
        off, max_l=4)
    want = es.lookup_bags(es.FpArena(arena), spec, idx, off, max_l=4)
    bound = 4 * float(scales.max()) + 1e-6
    assert float(jnp.abs(got - want).max()) <= bound


def test_hot_cache_all_rows_hot_makes_cold_pass_inert(rng):
    """K >= all touched rows: the cold pass reduces only null rows."""
    spec = se.ArenaSpec(2, 12, 4)
    arena = se.init_arena(jax.random.PRNGKey(3), spec)
    idx, off, _ = _ragged_case(rng, spec, b=2, max_l=3)
    counts = se.trace_row_counts(spec, idx, off)
    cache = se.build_hot_cache(arena, spec, counts, k=spec.null_row)
    assert float(se.cache_hit_rate(cache, spec, idx, off)) == 1.0
    got = es.lookup_bags(es.CachedSource(cache, es.FpArena(arena)), spec,
                         idx, off, max_l=3)
    want = es.lookup_bags(es.FpArena(arena), spec, idx, off, max_l=3)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
