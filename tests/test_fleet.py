"""Fleet chaos harness: fault-injected broadcast, exactness-asserted
recovery, crash consistency, and the determinism seams it leans on.

The property suite drives seeded drop/duplicate/delay/reorder schedules
(``repro.fleet.ChaosChannel``) between the online trainer's
``publish_source`` and replica ``RecEngine.update_source`` and asserts
the protocol invariants the fleet design claims:

(i)   every stale delivery the channel injects is rejected by the
      engine's version gate — ``stale_injected`` (channel side) equals
      the ``stale_rejected`` event count (engine side), exactly;
(ii)  after the chaos window, recovery within K clean version bumps is
      *bit-exact* against a trainer-synced reference engine — and takes
      zero new compile-cache entries (treedef-stable swaps);
(iii) per-version, per-model hit-rate attribution survives reordering:
      replicas only ever attribute traffic to versions the trainer
      actually published, monotonically.

Every scenario replays from its seed: no wall-clock randomness anywhere
in the chaos path. Under the real ``hypothesis`` package the pinned
``fleet`` profile (derandomized) keeps CI schedules reproducible; under
the conftest fallback the fixed example grid is deterministic already.
"""
import os
import sys
from pathlib import Path

import numpy as np
import pytest

import jax
import jax.numpy as jnp

import hypothesis
from hypothesis import given, settings
from hypothesis import strategies as st

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

from repro import obs  # noqa: E402
from repro.checkpoint import CheckpointManager  # noqa: E402
from repro.configs.dlrm import DLRM_SMOKE  # noqa: E402
from repro.core import dlrm  # noqa: E402
from repro.core import embedding_source as es  # noqa: E402
from repro.distributed.fault_tolerance import StragglerMonitor  # noqa: E402
from repro.fleet import CLEAN, ChaosChannel, FaultPlan, FleetRunner  # noqa: E402
from repro.fleet.runner import Replica, _serve_batch  # noqa: E402
from repro.launch.mesh import make_mesh  # noqa: E402
from repro.training.online import (OnlineCacheConfig, OnlineTrainer,  # noqa: E402
                                   OnlineGroupTrainer, _dense_head,
                                   make_drifting_zipf)

# Pinned hypothesis profile: CI runs HYPOTHESIS_PROFILE=fleet so chaos
# schedules are derandomized (replayable run to run). The conftest
# fallback stub has no profile machinery — and needs none, its example
# grid is already fixed.
if not getattr(hypothesis, "__is_repro_fallback__", False):
    settings.register_profile("fleet", deadline=None, max_examples=4,
                              derandomize=True, print_blob=True)
    settings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "fleet"))

# The fault mixes the properties quantify over: lossy, duplicating, and
# heavily delaying (delay is what manufactures genuine reordering).
MIXES = (
    FaultPlan(drop=0.3, dup=0.3, delay=0.6, max_delay=3),
    FaultPlan(drop=0.0, dup=0.5, delay=0.8, max_delay=2),
)
# The fixed bench/demo plan (scanned so its schedule injects stale
# deliveries on every replica — reordering actually exercised).
BENCH_PLAN = FaultPlan(seed=6, drop=0.3, dup=0.3, delay=0.6, max_delay=3)


# ---------------------------------------------------------------------------
# ChaosChannel: the schedule is a pure function of (plan, send sequence)
# ---------------------------------------------------------------------------

@settings(deadline=None, max_examples=8)
@given(seed=st.integers(min_value=0, max_value=1000),
       plan=st.sampled_from(MIXES))
def test_chaos_schedule_replays_from_seed(seed, plan):
    """Same plan seed + same send sequence => bit-identical fate
    transcript, counters, and delivery order — the replayability claim."""
    def run(chan):
        fates, delivered = [], []
        for v in range(1, 9):
            fates.append(chan.send(f"blob{v}".encode(), v))
            delivered += chan.poll()
        delivered += chan.flush()
        return fates, delivered, (chan.dropped, chan.duplicated,
                                  chan.delayed)

    p = plan.with_seed(seed)
    f1, d1, c1 = run(ChaosChannel(p))
    f2, d2, c2 = run(ChaosChannel(p))
    assert f1 == f2 and d1 == d2 and c1 == c2
    # conservation: every non-dropped copy is delivered exactly once
    n_copies = sum(0 if f["dropped"] else (2 if f["duplicated"] else 1)
                   for f in f1)
    assert len(d1) == n_copies
    # the transcript is complete and in send order
    assert [f["send"] for f in f1] == list(range(1, 9))
    drops = obs.Telemetry()
    chan = ChaosChannel(p, telemetry=drops)
    for v in range(1, 9):
        chan.send(f"blob{v}".encode(), v)
    assert len(drops.events.query("broadcast_dropped")) == chan.dropped


def test_chaos_clean_plan_is_perfect_transport():
    chan = ChaosChannel(CLEAN)
    for v in (1, 2, 3):
        chan.send(f"b{v}".encode(), v)
        got = chan.poll()
        assert [x[0] for x in got] == [v]
    assert chan.dropped == chan.duplicated == chan.delayed == 0
    assert chan.in_flight == 0


# ---------------------------------------------------------------------------
# (i)+(ii)+(iii): the grouped A/B fleet under chaos
# ---------------------------------------------------------------------------

def _assert_fleet_invariants(fr, published_versions):
    """The three chaos-suite assertions, shared by the property test and
    the deterministic bench-plan test."""
    # (i) channel-side injected staleness == engine-side rejections; the
    # reorder events carry the same count per replica
    for rep in fr.replicas:
        assert rep.stale_injected == rep.stale_rejections(), rep.name
        reordered = sum(
            len(e.telemetry.events.query("broadcast_reordered"))
            for e in rep.engines.values())
        assert reordered == rep.stale_injected, rep.name
        # both variant engines see identical delivery sequences
        v = rep.versions()
        assert v["a"] == v["b"], v

    # (ii) recovery to bit-exactness within K bumps, zero recompiles
    rec = fr.recover(k=3)
    assert all(all(flags) for flags in rec["exact"].values()), rec
    for per_model in rec["recompiles"]:
        for model, n in per_model.items():
            assert n in (0, None), (model, n)

    # (iii) attribution never invents versions: every version a replica
    # attributes traffic to was actually published by the trainer, and
    # rates are well-formed
    for rep in fr.replicas:
        for model in ("a", "b"):
            hrv = rep.hit_rate_by_version(model)
            # version 0 is the engine's initial pre-broadcast state (its
            # outgoing snapshot at the bootstrap swap); everything else
            # must be a version the trainer actually published
            assert set(hrv) <= set(published_versions) | {0}, (model, hrv)
            for rate in hrv.values():
                if rate is not None:
                    assert 0.0 <= rate <= 1.0, hrv


@settings(deadline=None, max_examples=4)
@given(seed=st.integers(min_value=3, max_value=6),
       plan=st.sampled_from(MIXES))
def test_fleet_chaos_property(seed, plan):
    """One trainer, two chaos-fed replicas, variants A/B over one shared
    TableGroupSource: protocol invariants hold for every seeded fault
    schedule."""
    fr = FleetRunner(n_replicas=2, plan=plan.with_seed(seed), seed=seed)
    for _ in range(3):
        fr.round()
    published = list(range(1, fr.trainer.version + 1))
    _assert_fleet_invariants(fr, published)


def test_fleet_bench_plan_injects_and_recovers():
    """The pinned bench plan (seed 6) actually produces reordering —
    stale injections are nonzero on every replica — and still recovers
    bit-exact. Also pins the A/B head semantics: variant B's dense head
    stays frozen through every broadcast; variant A's converges to the
    trainer's."""
    fr = FleetRunner(n_replicas=2, plan=BENCH_PLAN, seed=0)
    for _ in range(6):
        fr.round()
    assert all(rep.stale_injected > 0 for rep in fr.replicas), \
        [rep.stale_injected for rep in fr.replicas]
    _assert_fleet_invariants(fr, list(range(1, fr.trainer.version + 1)))

    want_b = jax.tree_util.tree_leaves(fr.head_b)
    want_a = jax.tree_util.tree_leaves(_dense_head(fr.trainer.params))
    for rep in fr.replicas:
        got_b = jax.tree_util.tree_leaves(
            _dense_head(rep.engines["b"].params))
        assert all(np.array_equal(g, w) for g, w in zip(got_b, want_b))
        got_a = jax.tree_util.tree_leaves(
            _dense_head(rep.engines["a"].params))
        assert all(np.array_equal(g, w) for g, w in zip(got_a, want_a))


# ---------------------------------------------------------------------------
# Sharded trainer, replicated replicas: shards in {1, 2, 4}
# ---------------------------------------------------------------------------

def _run_sharded_chaos(shards, seed=0):
    """A mesh-sharded OnlineTrainer broadcasts through a chaos channel to
    a replicated (meshless) replica; the clean-channel replica is the
    bit-exactness oracle. The blob round-trip is what makes the sharded
    and replicated worlds comparable: a replicated consumer deserializes
    the sharded artifact and the ShardedArena wrapper unwraps."""
    cfg = DLRM_SMOKE
    mesh = make_mesh((shards,), ("model",))
    max_l, B = 4, 8
    trainer = OnlineTrainer(
        cfg, dlrm.init(jax.random.PRNGKey(seed), cfg, shards),
        max_l=max_l, mesh=mesh,
        cache_cfg=OnlineCacheConfig(k=32, refresh_every=2))
    gen = make_drifting_zipf(cfg, batch_size=B, mean_l=2, max_l=max_l,
                             drift_per_batch=64, alpha=1.05, seed=seed)
    for _ in range(2):
        trainer.train_step(next(gen))
    vs0 = es.VersionedSource.deserialize(
        trainer.publish_source(include_head=True))
    assert isinstance(vs0.source.cold, es.FpArena)   # unwrapped for serving

    plan = FaultPlan(seed=seed + 11 * shards, drop=0.3, dup=0.3,
                     delay=0.6, max_delay=3)
    rep = Replica("replica0", cfg, vs0, ChaosChannel(plan), max_l=max_l,
                  batch_size=B, heads={"a": dict(vs0.head)},
                  params_seed=seed + 2, shards=shards)
    ref = Replica("ref", cfg, vs0, ChaosChannel(CLEAN), max_l=max_l,
                  batch_size=B, heads={"a": dict(vs0.head)},
                  params_seed=seed + 5, shards=shards)
    probe = next(gen)
    for _ in range(4):
        for _ in range(2):
            trainer.train_step(next(gen))
        blob = trainer.publish_source(include_head=True)
        ref.deliver(trainer.version, blob)
        rep.channel.send(blob, trainer.version)
        rep.pump()

    # (i) holds shard-independently
    assert rep.stale_injected == rep.stale_rejections()

    # drain in-flight, then one clean republish closes any dropped tail
    for v, blob in rep.channel.flush():
        rep.deliver(v, blob)
    blob = trainer.publish_source(include_head=True)
    rep.deliver(trainer.version, blob)

    # (ii) bit-exact vs the trainer source (via the clean-channel oracle)
    got = _serve_batch(rep.engines["a"], cfg, probe)
    want = _serve_batch(ref.engines["a"], cfg, probe)
    assert got == want
    assert rep.recompiles()["a"] in (0, None)
    assert rep.versions()["a"] == trainer.version
    return rep


@settings(deadline=None, max_examples=3)
@given(shards=st.sampled_from([1, 2, 4]))
def test_fleet_chaos_sharded(shards):
    if shards > jax.device_count():
        return      # single-device job covers shards=1; CI multidevice
        #             job (8 forced host devices) covers 2 and 4
    _run_sharded_chaos(shards)


# ---------------------------------------------------------------------------
# Crash scenarios: replica restart + trainer crash/resume
# ---------------------------------------------------------------------------

def test_replica_restart_restores_from_checkpoint(tmp_path):
    """Kill a replica mid-chaos; its replacement bootstraps from the
    latest checkpointed source artifact, emits ``replica_restore``, and
    recovers to bit-exact within K bumps with zero recompiles."""
    fr = FleetRunner(n_replicas=2, plan=BENCH_PLAN, seed=1,
                     ckpt_dir=tmp_path)
    for _ in range(2):
        fr.round()
    rep = fr.crash_replica(0)
    restores = [e for eng in rep.engines.values()
                for e in eng.telemetry.events.query("replica_restore")]
    assert len(restores) == len(rep.engines)
    # the restart bootstrapped from the newest persisted artifact
    vs, manifest = fr.ckpt.restore_source()
    assert all(e.version == vs.version for e in restores)
    assert all(e.attrs["step"] == manifest["step"] for e in restores)
    rec = fr.recover(k=3)
    assert all(all(flags) for flags in rec["exact"].values()), rec
    for per_model in rec["recompiles"]:
        assert all(n in (0, None) for n in per_model.values())


def test_trainer_crash_resume_data_skip_determinism(tmp_path):
    """ResilientTrainer + CheckpointManager through a mid-run crash: the
    resumed trainer's params are BIT-IDENTICAL to an uninterrupted
    control trainer fed the same step-seeded batches (data-skip
    determinism), the version stays monotone (replicas never see a
    rollback), and the fleet recovers to exactness afterwards."""
    fr = FleetRunner(n_replicas=1, plan=BENCH_PLAN.with_seed(2), seed=2,
                     ckpt_dir=tmp_path)
    fr.round()
    v_before = fr.trainer.version
    res = fr.run_trainer_with_crash(extra_steps=6, fail_after=3,
                                    ckpt_every=2)
    assert res["restarts"] == 1
    assert res["resume_events"] == 1
    assert res["version"] >= v_before      # monotone through the crash

    # control: same init seed, same memoized batch stream, no crash
    ctl = OnlineGroupTrainer(
        fr.cfg, dlrm.init(jax.random.PRNGKey(fr.seed), fr.cfg),
        max_l=fr.max_l, plans=dlrm.table_plans(fr.cfg, cache_k=64),
        refresh_every=fr.trainer.refresh_every)
    for step in range(fr.next_step):
        ctl.train_step(fr.batch_fn(step))
    got = jax.tree_util.tree_leaves(fr.trainer.params)
    want = jax.tree_util.tree_leaves(ctl.params)
    assert len(got) == len(want)
    assert all(np.array_equal(np.asarray(g), np.asarray(w))
               for g, w in zip(got, want))

    rec = fr.recover(k=3)
    assert all(all(flags) for flags in rec["exact"].values()), rec


# ---------------------------------------------------------------------------
# Checkpoint crash consistency (satellite): a writer dying mid-publish
# ---------------------------------------------------------------------------

def test_checkpoint_crash_consistency(tmp_path, monkeypatch):
    """Kill the writer between tmp-write and atomic rename: the latest
    PRIOR step must restore intact, and the orphaned ``tmp.<step>``
    debris must be GC'd by the next successful save."""
    ckpt = CheckpointManager(tmp_path, keep_n=3)
    ckpt.save(1, {"w": np.arange(4.0)})
    ckpt.save(2, {"w": np.arange(4.0) + 1})

    real_rename = Path.rename
    die = {"on": True}

    def dying_rename(self, target):
        if die["on"] and self.name.startswith("tmp."):
            raise OSError("writer killed mid-publish")
        return real_rename(self, target)

    monkeypatch.setattr(Path, "rename", dying_rename)
    with pytest.raises(OSError):
        ckpt.save(3, {"w": np.arange(4.0) + 2})

    # crash-consistent: the torn write is invisible to restore
    assert ckpt.latest_step() == 2
    state, manifest = ckpt.restore({"w": np.zeros(4)})
    assert manifest["step"] == 2
    assert np.array_equal(np.asarray(state["w"]), np.arange(4.0) + 1)
    assert any(p.name == "tmp.3" for p in tmp_path.iterdir())

    # the next successful save sweeps the debris
    die["on"] = False
    ckpt.save(4, {"w": np.arange(4.0) + 3})
    assert not list(tmp_path.glob("tmp.*"))
    assert ckpt.latest_step() == 4

    # same protocol for source artifacts (tmp.src.<step>)
    arena = jnp.arange(32.0, dtype=jnp.float32).reshape(8, 4)
    ckpt.save_source(5, es.VersionedSource(source=es.FpArena(arena),
                                           version=1))
    die["on"] = True
    with pytest.raises(OSError):
        ckpt.save_source(6, es.VersionedSource(source=es.FpArena(arena + 1),
                                               version=2))
    vs, manifest = ckpt.restore_source()
    assert manifest["step"] == 5 and vs.version == 1
    assert any(p.name == "tmp.src.6" for p in tmp_path.iterdir())
    die["on"] = False
    ckpt.save_source(7, es.VersionedSource(source=es.FpArena(arena + 2),
                                           version=3))
    assert not list(tmp_path.glob("tmp.*"))
    assert ckpt.latest_source_step() == 7
    vs, _ = ckpt.restore_source()
    assert np.array_equal(np.asarray(vs.source.arena), np.asarray(arena + 2))


# ---------------------------------------------------------------------------
# Loadgen determinism (satellite): the open-loop trace is seed-pure
# ---------------------------------------------------------------------------

def test_loadgen_trace_deterministic_from_seed():
    from benchmarks.loadgen import make_trace
    cfg = DLRM_SMOKE
    t1 = make_trace(cfg, 96, kind="poisson", rate_qps=500.0, mean_l=3,
                    max_l=6, drift_per_chunk=200, seed=11)
    t2 = make_trace(cfg, 96, kind="poisson", rate_qps=500.0, mean_l=3,
                    max_l=6, drift_per_chunk=200, seed=11)
    assert np.array_equal(t1.arrivals_s, t2.arrivals_s)
    assert len(t1.requests) == len(t2.requests) == 96
    for a, b in zip(t1.requests, t2.requests):
        assert a.rid == b.rid
        assert np.array_equal(a.dense, b.dense)
        assert len(a.sparse_ids) == len(b.sparse_ids)
        assert all(np.array_equal(x, y)
                   for x, y in zip(a.sparse_ids, b.sparse_ids))
    # a different seed actually changes the trace (the test would pass
    # vacuously if make_trace ignored its seed)
    t3 = make_trace(cfg, 96, kind="poisson", rate_qps=500.0, mean_l=3,
                    max_l=6, drift_per_chunk=200, seed=12)
    assert not np.array_equal(t1.arrivals_s, t3.arrivals_s)


def test_loadgen_drift_moves_hot_set_across_chunks():
    from benchmarks.loadgen import zipf_requests

    def hot_row(reqs):
        ids = np.concatenate([i for r in reqs for i in r.sparse_ids])
        vals, counts = np.unique(ids, return_counts=True)
        return int(vals[np.argmax(counts)])

    cfg = DLRM_SMOKE
    drift = zipf_requests(cfg, 128, mean_l=6, max_l=12, alpha=1.05,
                          drift_per_chunk=350, chunk=64, seed=4)
    hot0, hot1 = hot_row(drift[:64]), hot_row(drift[64:])
    assert hot0 != hot1
    # rank 1 maps to row shift % rows: the drift is a rotation
    assert hot1 == (hot0 + 350) % cfg.rows_per_table

    flat = zipf_requests(cfg, 128, mean_l=6, max_l=12, alpha=1.05,
                         drift_per_chunk=0, chunk=64, seed=4)
    assert hot_row(flat[:64]) == hot_row(flat[64:])


# ---------------------------------------------------------------------------
# StragglerMonitor window-pollution regression (satellite)
# ---------------------------------------------------------------------------

def test_straggler_monitor_consecutive_stragglers_all_flagged():
    """Flagged outliers must stay out of the median window: a run of
    consecutive stragglers previously raised the median enough to mask
    the next one of comparable magnitude."""
    mon = StragglerMonitor(threshold=2.0, window=8)
    for step in range(8):
        assert not mon.record(step, 1.0)
    # four consecutive 3x stragglers, then a 5x one: with window
    # pollution the 3.0s shift the median to 3.0 and the 5.0 sails
    # under the 2*median bar; with the fix the median stays 1.0
    for step, dt in enumerate([3.0, 3.0, 3.0, 3.0, 5.0], start=8):
        assert mon.record(step, dt), (step, dt)
    assert len(mon.events) == 5
    assert all(e["median"] == 1.0 for e in mon.events)
    assert set(mon.durations) == {1.0}      # window never polluted


def test_straggler_monitor_two_back_to_back(rng):
    """The minimal regression: two immediately consecutive stragglers
    are both flagged."""
    mon = StragglerMonitor(threshold=2.0, window=8)
    for step in range(8):
        mon.record(step, 1.0)
    assert mon.record(8, 10.0)
    assert mon.record(9, 10.0)
    assert len(mon.events) == 2
