"""Docs-sync: the README / ARCHITECTURE code snippets cannot rot.

Every fenced ```python block in README.md, docs/ARCHITECTURE.md, and
docs/OBSERVABILITY.md must
(1) parse, and (2) reference only public names that actually exist:
`from repro.x import name` imports and attribute accesses on repro-module
aliases (`es.lookup_bags`, `dlrm.table_plans`, ...) are resolved against
the live modules AND — for the modules pinned by the API-surface
snapshot — against tests/api_manifest.json. A doc referencing a renamed
or deleted public symbol fails here, in the same CI run that would have
let it rot silently.
"""
import ast
import importlib
import json
import re
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parents[1]
DOCS = (ROOT / "README.md", ROOT / "docs" / "ARCHITECTURE.md",
        ROOT / "docs" / "OBSERVABILITY.md")
MANIFEST = json.loads((ROOT / "tests" / "api_manifest.json").read_text())

_FENCE = re.compile(r"```python\n(.*?)```", re.DOTALL)


def python_blocks():
    out = []
    for doc in DOCS:
        assert doc.exists(), f"{doc} is referenced by the docs-sync test"
        for i, block in enumerate(_FENCE.findall(doc.read_text())):
            out.append((f"{doc.name}#{i}", block))
    assert out, "no fenced python blocks found — the regex rotted"
    return out


def _module_aliases(tree: ast.Module):
    """alias -> module path, for `import repro.x [as y]` and
    `from repro.x import y [as z]` where y is itself a module."""
    aliases = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                if a.name.startswith("repro"):
                    aliases[a.asname or a.name.split(".")[0]] = a.name
        elif isinstance(node, ast.ImportFrom) and node.module \
                and node.module.startswith("repro"):
            for a in node.names:
                full = f"{node.module}.{a.name}"
                try:
                    importlib.import_module(full)
                except ImportError:
                    continue
                aliases[a.asname or a.name] = full
    return aliases


def _check_name(module: str, name: str, where: str):
    mod = importlib.import_module(module)
    assert hasattr(mod, name), \
        f"{where}: {module}.{name} does not exist (doc rot)"
    if module in MANIFEST:
        assert name in MANIFEST[module], \
            (f"{where}: {module}.{name} exists but is not in the public "
             f"API manifest — docs must reference public surface only")


BLOCKS = python_blocks()


@pytest.mark.parametrize("where,block", BLOCKS,
                         ids=[w for w, _ in BLOCKS])
def test_doc_block_references_resolve(where, block):
    tree = ast.parse(block)          # (1) snippets must parse
    aliases = _module_aliases(tree)
    checked = 0
    for node in ast.walk(tree):
        if isinstance(node, ast.ImportFrom) and node.module \
                and node.module.startswith("repro"):
            for a in node.names:
                full = f"{node.module}.{a.name}"
                try:
                    importlib.import_module(full)
                    continue             # module import, not a name
                except ImportError:
                    pass
                _check_name(node.module, a.name, where)
                checked += 1
        elif isinstance(node, ast.Attribute) \
                and isinstance(node.value, ast.Name) \
                and node.value.id in aliases:
            _check_name(aliases[node.value.id], node.attr, where)
            checked += 1
    assert checked or not aliases, \
        f"{where}: repro imports present but nothing was checked"


def test_docs_mention_the_group_source():
    """The architecture tour documents the full source taxonomy."""
    text = (ROOT / "docs" / "ARCHITECTURE.md").read_text()
    for name in ("FpArena", "QuantizedArena", "ShardedArena",
                 "CachedSource", "TableGroupSource", "null row",
                 "update_source", "VersionedSource"):
        assert name in text, f"ARCHITECTURE.md lost its {name} section"
    readme = (ROOT / "README.md").read_text()
    assert "docs/ARCHITECTURE.md" in readme, \
        "README must link the architecture tour"
